#!/usr/bin/env python3
"""Python port of the loader-pipeline pricing model (band verification).

Stdlib-only twin of `rust/src/loader/mod.rs::sim::sim_pipeline` — the
discrete-event model of the Alg. 1 input pipeline: a child loader serving
batch requests (disk + spiky decode, LRU raw-byte cache) with a prefetch
depth Q of requests in flight, priced through the same float-op order as
`audit::Ledger` (`advance_to` for stalls, separate `charge` adds for H2D
and compute, `ServerClock::serve` for the child). Every numeric band
pinned by `rust/tests/loader_pipeline.rs` and asserted by
`rust/benches/bench_loader.rs` is derived here; run this script after
touching the model and update the Rust constants if the printed values
move.

    python3 scripts/verify_loader_bands.py
    python3 scripts/verify_loader_bands.py --write-baselines

`--write-baselines` regenerates `bench/baselines/BENCH_loader.json` (the
bench-smoke gate reference) with explicit better=lower/higher directions.

Exits non-zero if the model's own acceptance invariants fail: vtime must
be non-increasing in Q, prefetch depth >= 2 with a warm cache must
strictly beat the Q=1 double buffer at k=8 (cold *and* warm), and the
load stall must collapse toward zero as Q grows at warm cache.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from pricing_model import sim_loader_pipeline  # noqa: E402


# The bench workload (mirrored in rust/benches/bench_loader.rs and the
# pinned-band test): AlexNet-shaped batch of 32 — segment bytes per batch
# 32*3*36*36 f32 = 124416 on disk, 32*3*64*64 f32 = 393216 staged H2D
# (test-scale store/crop dims; the ratios, not the absolute sizes, drive
# the pipeline shape), 16 segment files cycled over 64 iterations.
N_FILES = 16
ITERS = 64
BATCH_BYTES = 124416
H2D_BYTES = 393216
COMPUTE_S = 0.0008

SWEEP_K = (1, 8)
SWEEP_Q = (0, 1, 2, 4)
SWEEP_C = (0, 4)


def run(k, q, c):
    return sim_loader_pipeline(
        workers=k, prefetch_depth=q, cache_mib=c, n_files=N_FILES,
        iters=ITERS, batch_bytes=BATCH_BYTES, h2d_bytes=H2D_BYTES,
        compute_s=COMPUTE_S,
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write-baselines", action="store_true",
                    help="regenerate bench/baselines/BENCH_loader.json")
    args = ap.parse_args()

    ok = True
    metrics = {}  # name -> (value, unit, better)
    res = {}

    def show(name, val):
        print(f"{name:44s} {val!r}")

    for k in SWEEP_K:
        for q in SWEEP_Q:
            for c in SWEEP_C:
                r = run(k, q, c)
                res[(k, q, c)] = r
                # breakdown == clock by construction: the memo'd hidden
                # share never lands on the clock (Ledger::audit tolerance:
                # per-kind sums vs the interleaved clock differ by ulps)
                bd = r["bd"]
                total = bd["load_stall"] + bd["h2d"] + bd["compute"]
                tol = 1e-9 * max(abs(total), abs(r["vtime"]), 1.0)
                ok &= abs(r["vtime"] - total) <= tol
                metrics[f"loader/vtime/k{k}/q{q}/c{c}"] = (
                    r["vtime"], "s_sim", "lower")

    for q in SWEEP_Q:
        for c in SWEEP_C:
            metrics[f"loader/stall/k8/q{q}/c{c}"] = (
                res[(8, q, c)]["bd"]["load_stall"], "s_sim", "lower")

    # cache behavior is q/k-independent (same request sequence): one metric
    warm = res[(8, 2, 4)]["cache"]
    hitrate = warm["hits"] / max(warm["hits"] + warm["misses"], 1)
    metrics["loader/hitrate/c4"] = (hitrate, "frac", "higher")
    metrics["loader/hidden/k8/q2/c4"] = (
        res[(8, 2, 4)]["bd"]["load_hidden"], "s_sim", "higher")

    for name in sorted(metrics):
        show(name, metrics[name][0])

    # --- acceptance invariants (mirrored by bench_loader.rs asserts) ------
    # 1. vtime is non-increasing in prefetch depth (q=0 direct is worst)
    for k in SWEEP_K:
        for c in SWEEP_C:
            vs = [res[(k, q, c)]["vtime"] for q in SWEEP_Q]
            mono = all(a >= b for a, b in zip(vs, vs[1:]))
            if not mono:
                print(f"FAIL: vtime not monotone in q at k={k} c={c}: {vs}")
            ok &= mono

    # 2. depth >= 2 + warm cache strictly beats the q=1 double buffer at
    #    k=8, against both the cold and the warm q=1 baselines
    q2warm = res[(8, 2, 4)]["vtime"]
    ok &= q2warm < res[(8, 1, 0)]["vtime"]
    ok &= q2warm < res[(8, 1, 4)]["vtime"]

    # 3. load stall collapses toward zero as q grows with a warm cache:
    #    q=4 warm stalls only during the cold first pass over the 16 files
    s_q1_cold = res[(8, 1, 0)]["bd"]["load_stall"]
    s_q4_warm = res[(8, 4, 4)]["bd"]["load_stall"]
    show("stall ratio q4c4 / q1c0", s_q4_warm / s_q1_cold)
    ok &= s_q4_warm < 0.5 * s_q1_cold
    ok &= s_q4_warm <= res[(8, 2, 4)]["bd"]["load_stall"]

    # 4. warm cache hit rate: every file misses once, then always hits
    ok &= abs(hitrate - (ITERS - N_FILES) / ITERS) < 1e-15
    ok &= warm["evictions"] == 0

    # 5. hidden load is a memo bounded by the work it hid under: with a
    #    warm cache and q>=2 most of the decode rides under compute
    ok &= res[(8, 2, 4)]["bd"]["load_hidden"] > 0.0

    if args.write_baselines:
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            os.pardir, "bench", "baselines",
                            "BENCH_loader.json")
        path = os.path.normpath(path)
        out = {"metrics": {
            name: {"value": v, "unit": unit, "better": better}
            for name, (v, unit, better) in sorted(metrics.items())
        }}
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
            f.write("\n")
        print(f"baselines -> {path}")

    print("\nbands", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
