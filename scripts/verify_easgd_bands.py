#!/usr/bin/env python3
"""Python port of the EASGD sharded-server pricing model.

Stdlib-only reference implementation of the Rust `simnet` pricing and the
`easgd::shard` conservative arrival-ordered queue (discrete-event form of
the thread implementation). Every numeric band pinned by the Rust suites
`rust/tests/easgd_sharded.rs` and `rust/benches/bench_easgd.rs` is derived
here; run this script after touching the pricing model and update the Rust
constants if the printed values move.

    python3 scripts/verify_easgd_bands.py

The script exits non-zero if the model's own invariants fail (S=4 not
beating S=1, queue waits not collapsing, serve order not round-sliced).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from pricing_model import (  # noqa: E402  (shared simnet/cluster constants)
    GPU_REDUCE_GBPS,
    HOST_MEM_GBPS,
    HOST_REDUCE_GBPS,
    IB_LAT_US,
    PCIE_GBPS,
    PCIE_LAT_US,
    QPI_GBPS,
    QPI_LAT_US,
    by_name,
    copper,
    path,
    split_even,
)


# --- simnet::phase_time (single transfer, cuda_aware=true) -----------------
def phase_time_single(topo, src, dst, bytes_):
    if src == dst or bytes_ == 0:
        return 0.0
    kind = path(topo, src, dst)
    if kind == "p2p":
        bw = bytes_ / (PCIE_GBPS * 1e9)  # up and down are separate resources
        lat = 2.0 * PCIE_LAT_US
    elif kind == "qpi":
        bw = max(
            bytes_ / (PCIE_GBPS * 1e9),
            bytes_ / (QPI_GBPS * 1e9),
            2 * bytes_ / (HOST_MEM_GBPS * 1e9),
        )
        lat = 2.0 * PCIE_LAT_US + QPI_LAT_US
    elif kind == "network":
        bw = max(
            bytes_ / (PCIE_GBPS * 1e9),
            bytes_ / (HOST_MEM_GBPS * 1e9),
            bytes_ / (topo["ib"] * 1e9),
        )
        lat = 2.0 * PCIE_LAT_US + IB_LAT_US
    else:
        return 0.0
    return bw + lat * 1e-6


# --- easgd pricing ---------------------------------------------------------
def exchange_cost(transport, topo, worker_gpu, server_gpu, bytes_):
    if transport == "mpi":
        down = phase_time_single(topo, worker_gpu, server_gpu, bytes_)
        up = phase_time_single(topo, server_gpu, worker_gpu, bytes_)
        return down + up
    # platoon-shm
    pcie = PCIE_LAT_US * 1e-6 + bytes_ / (PCIE_GBPS * 1e9)
    shm_copy = bytes_ / (HOST_MEM_GBPS * 1e9)
    return 2.0 * (pcie + 2.0 * shm_copy + pcie)


def server_update_cost(transport, bytes_):
    if transport == "mpi":
        return 2 * bytes_ / (GPU_REDUCE_GBPS * 1e9)
    return 2 * bytes_ / (HOST_REDUCE_GBPS * 1e9)


def server_handle_cost(transport, chunk_kib, pipeline, bytes_, down_wire):
    full = server_update_cost(transport, bytes_)
    if chunk_kib == 0 or not pipeline:
        return full
    chunks = max(-(-bytes_ // (chunk_kib * 1024)), 1)
    hidden = max(min(full - full / chunks, down_wire * (chunks - 1) / chunks), 0.0)
    return full - hidden


def shard_prices(transport, topo, k, servers, elems, half, chunk_kib, pipeline, scale):
    """wire_half[j][w] (scaled one-way) and handle[j][w] (scaled occupancy)."""
    slices = split_even(elems, servers)
    wire_half = [[0.0] * k for _ in range(servers)]
    handle = [[0.0] * k for _ in range(servers)]
    for j, (_, ln) in enumerate(slices):
        full_bytes = 4 * ln
        wire_bytes = full_bytes // 2 if half else full_bytes
        for w in range(k):
            rt = exchange_cost(transport, topo, w, k + j, wire_bytes)
            wire_half[j][w] = rt / 2.0 * scale
            handle[j][w] = (
                server_handle_cost(transport, chunk_kib, pipeline, full_bytes, rt / 2.0)
                * scale
            )
    return slices, wire_half, handle


# --- the conservative arrival-ordered queue (discrete-event port) ----------
def simulate(topo_name, transport, k, servers, elems, rounds, compute_s,
             half=False, chunk_kib=0, pipeline=True, scale=1.0,
             legacy_sent_keying=False):
    """Mirror of `easgd::shard::measure_sharded`'s virtual-time behavior.

    Returns per-worker comm totals, queue waits (binding slice), per-shard
    serve order / busy fraction — everything the Rust suites pin.
    """
    topo = by_name(topo_name, k + servers)
    slices, down, handle = shard_prices(
        transport, topo, k, servers, elems, half, chunk_kib, pipeline, scale
    )
    up = down  # symmetric paths
    INF = float("inf")

    clock = [0.0] * k
    rnd = [0] * k
    waiting = [False] * k
    alive = [True] * k
    heads = [[None] * k for _ in range(servers)]  # (arrival, sent C)
    last_finish = [[-INF] * k for _ in range(servers)]
    reply = [[None] * servers for _ in range(k)]  # finish time per shard
    shard_clock = [0.0] * servers
    busy = [0.0] * servers
    served = [[] for _ in range(servers)]
    comm = [0.0] * k
    waits = [[] for _ in range(k)]

    progress = True
    while progress:
        progress = False
        # workers: send the next round or stop
        for w in range(k):
            if not waiting[w] and alive[w]:
                if rnd[w] < rounds:
                    clock[w] = clock[w] + compute_s
                    for j in range(servers):
                        heads[j][w] = (clock[w] + down[j][w], clock[w])
                    waiting[w] = True
                else:
                    alive[w] = False
                progress = True
        # shards: serve every safely-servable head, earliest arrival first
        for j in range(servers):
            while True:
                best = None
                for w in range(k):
                    if heads[j][w] is not None and (
                        best is None or heads[j][w][0] < best[0]
                    ):
                        best = (heads[j][w][0], w)
                if best is None:
                    break
                a, w = best
                safe = True
                for v in range(k):
                    if v != w and alive[v] and heads[j][v] is None:
                        lb = last_finish[j][v] + up[j][v] + down[j][v]
                        if not lb > a:
                            safe = False
                            break
                if not safe:
                    break
                arrival, sent = heads[j][w]
                heads[j][w] = None
                key = sent if legacy_sent_keying else arrival
                shard_clock[j] = max(shard_clock[j], key) + handle[j][w]
                busy[j] += handle[j][w]
                last_finish[j][w] = shard_clock[j]
                reply[w][j] = shard_clock[j]
                served[j].append(w)
                progress = True
        # workers: complete an exchange once every shard replied
        for w in range(k):
            if waiting[w] and all(r is not None for r in reply[w]):
                if legacy_sent_keying:
                    # pre-fix accounting: t_comm = (finish - C) + down + up
                    # (queue keyed on sent time, wire charged separately)
                    assert servers == 1
                    f = reply[w][0]
                    new_clock = clock[w] + max(f - clock[w], 0.0) + 2 * down[0][w]
                    qwait = 0.0
                else:
                    new_clock = clock[w]
                    qwait = 0.0
                    for j in range(servers):
                        done = reply[w][j] + up[j][w]
                        if done > new_clock:
                            new_clock = done
                            qwait = max(
                                reply[w][j] - (clock[w] + down[j][w]) - handle[j][w],
                                0.0,
                            )
                comm[w] += new_clock - clock[w]
                waits[w].append(qwait)
                clock[w] = new_clock
                reply[w] = [None] * servers
                waiting[w] = False
                rnd[w] += 1
                progress = True

    all_waits = [q for w in range(k) for q in waits[w]]
    total = 0.0
    for w in range(k):
        total += comm[w]
    srt = sorted(all_waits)
    p95 = srt[round((len(srt) - 1) * 0.95)] if srt else 0.0
    return {
        "comm_total": total,
        "per_exchange": total / max(k * rounds, 1),
        "waits": all_waits,
        "wait_mean": sum(all_waits) / max(len(all_waits), 1),
        "wait_p95": p95,
        "busy_frac": [
            busy[j] / shard_clock[j] if shard_clock[j] > 0.0 else 0.0
            for j in range(servers)
        ],
        "served": served,
        "vtime": max(clock),
    }


def round_sliced(served, k, rounds):
    """Every k-block of a shard's serve order is a permutation of 0..k."""
    for order in served:
        if len(order) != k * rounds:
            return False
        for r in range(rounds):
            if sorted(order[r * k : (r + 1) * k]) != list(range(k)):
                return False
    return True


def main():
    ok = True

    def show(name, val):
        print(f"{name:58s} {val!r}")

    # Scenario A — the tau=1, k=8 contention band (satellite bugfix pin):
    # one exchange round, zero compute, copper, 1M f32 params, S=1.
    a = simulate("copper", "mpi", k=8, servers=1, elems=1_000_000, rounds=1,
                 compute_s=0.0)
    show("A: k=8 S=1 rounds=1 comm_total", a["comm_total"])
    show("A: wait_mean", a["wait_mean"])
    show("A: wait_p95", a["wait_p95"])
    # closed form: sum_i [down + (i+1)h + up] with equal arrivals
    topo = copper(2)
    rt = exchange_cost("mpi", topo, 0, 8, 4_000_000)
    h = server_update_cost("mpi", 4_000_000)
    closed = 8 * rt + h * 36
    show("A: closed-form comm_total", closed)
    ok &= abs(a["comm_total"] - closed) < 1e-12
    ok &= abs(a["wait_p95"] - 7 * h) < 1e-12

    # Scenario B — arrival-time keying pin. Legacy accounting keyed the
    # queue on the *sent* clock and charged the down leg again in t_comm.
    # With one uniform worker->server path those two errors cancel exactly
    # (the busy chain is the arrival-keyed chain shifted by `down`); they
    # diverge as soon as paths are heterogeneous. k=10 on copper: workers
    # 0..7 reach the server (gpu 10, node 1) over the NIC while workers
    # 8..9 share its PCIe switch.
    topo_b = by_name("copper", 11)
    kinds = {path(topo_b, w, 10) for w in range(10)}
    ok &= kinds == {"network", "p2p"}
    b = simulate("copper", "mpi", k=10, servers=1, elems=1_000_000, rounds=2,
                 compute_s=0.0)
    b_old = simulate("copper", "mpi", k=10, servers=1, elems=1_000_000, rounds=2,
                     compute_s=0.0, legacy_sent_keying=True)
    show("B: k=10 arrival-keyed comm_total", b["comm_total"])
    show("B: k=10 legacy sent-keyed comm_total", b_old["comm_total"])
    show("B: keying delta", b["comm_total"] - b_old["comm_total"])
    ok &= abs(b["comm_total"] - b_old["comm_total"]) > 1e-6

    # Scenario C — the bench sweep: k=8, copper, 4 rounds, 2ms compute,
    # S in {1, 2, 4}. S=4 must strictly beat S=1 with p95 collapsing.
    c = {}
    for s in (1, 2, 4):
        c[s] = simulate("copper", "mpi", k=8, servers=s, elems=1_000_000,
                        rounds=4, compute_s=2e-3)
        show(f"C: S={s} comm_total", c[s]["comm_total"])
        show(f"C: S={s} wait_p95", c[s]["wait_p95"])
        show(f"C: S={s} busy_frac[0]", c[s]["busy_frac"][0])
        ok &= round_sliced(c[s]["served"], 8, 4)
    ok &= c[4]["comm_total"] < c[1]["comm_total"]
    ok &= c[4]["wait_p95"] < 0.5 * c[1]["wait_p95"]

    # Scenario D — f16 wire halves the priced bytes (same queue structure).
    d = simulate("copper", "mpi", k=8, servers=1, elems=1_000_000, rounds=1,
                 compute_s=0.0, half=True)
    show("D: k=8 S=1 f16 comm_total", d["comm_total"])
    ok &= d["comm_total"] < a["comm_total"]

    print("\nbands", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
