#!/usr/bin/env python3
"""Charge-accounting lint: virtual time may only be spent through audit::Ledger.

Every correctness bug this repo has shipped was a cost-accounting bug — a
clock advanced without its breakdown entry, a Breakdown field dropped in a
merge, bytes compared against element counts. The Rust type system cannot
see any of these (they are all `f64 += f64`), so this lint enforces the
accounting discipline textually:

  CHARGE-CLOCK   arithmetic on a bare clock variable (`*clock`, `vtime`):
                 compound assignment or a self-referential re-assignment.
                 Clocks advance only inside `audit::` (Ledger / ServerClock).
  CHARGE-BD      compound assignment on a `Breakdown` time field. Breakdown
                 slots are filled only by `audit::Ledger` charges; the one
                 other owner is `metrics::` itself (its exhaustive `add`).
  CHARGE-CR      compound assignment on a `CommReport` time field
                 (`sim_*`, `real_kernel`). `collectives/mod.rs` owns the
                 report (exhaustive merge/scale); strategy impls that build
                 reports carry per-file waivers.
  BD-LITERAL     a `Breakdown { .. }` struct literal using the `..` rest
                 shorthand outside `metrics::`/`audit::` — non-exhaustive
                 construction silently zeroes fields added later.

The historical UNIT-SUFFIX rule (textually matching `_bytes + _s` style
mixing) is retired: the `units::` newtypes (Secs/Bytes/Kib/Elems/GbPerS)
make dimensional mixing a *compile* error, and `scripts/lint_units.py`
polices the remaining textual surface (float->int casts, hash-order
nondeterminism, new raw unit-suffixed fields).

Scope: `rust/src/**/*.rs` (unit tests included — they must follow the same
discipline; integration tests under `rust/tests/` assert *on* the ledger
and may do arithmetic to build expectations).

Waivers: `scripts/lint_waivers.txt`, one per line:

    RULE-ID<space>path-substring<space or tab># justification (required)

A finding whose rule and path match a waiver is suppressed. Waivers that
matched nothing are reported as STALE (warning; remove them). Exit status
is 1 iff any unwaived finding remains.

Stdlib only; run from the repo root: `python3 scripts/lint_charges.py`.
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "rust", "src")
WAIVER_FILE = os.path.join(REPO, "scripts", "lint_waivers.txt")

# Breakdown's simulated-time fields (metrics/mod.rs) — audit::Ledger slots.
BD_FIELDS = (
    "compute|comm_transfer|comm_kernel|comm_queue|comm_hidden|"
    "host_reduce|h2d|load_stall|load_hidden|apply"
)
# CommReport's time fields (collectives/mod.rs).
CR_FIELDS = "sim_transfer|sim_kernel|sim_overlapped|sim_intra|sim_inter|real_kernel"

# directory-level owners: (rule, path substrings where the rule never fires)
OWNERS = {
    "CHARGE-CLOCK": ("rust/src/audit/",),
    "CHARGE-BD": ("rust/src/audit/", "rust/src/metrics/"),
    "CHARGE-CR": ("rust/src/audit/", "rust/src/collectives/mod.rs"),
    "BD-LITERAL": ("rust/src/audit/", "rust/src/metrics/"),
}

# compound assignment on a *bare* clock identifier (field accesses like
# `st.max_clock` are aggregation over clocks, not a clock being spent —
# the `(?<![\w.])` guard excludes them)
RE_CLOCK_COMPOUND = re.compile(r"(?<![\w.])(\w*clock|vtime)\s*[-+*/]=")
# self-referential re-assignment: `x = <expr mentioning x>`
RE_CLOCK_ASSIGN = re.compile(r"(?<![\w.])(\w*clock|vtime)\s*=(?![=>])\s*(.+)$")
RE_BD_COMPOUND = re.compile(r"\.(%s)\s*[-+*/]=" % BD_FIELDS)
RE_CR_COMPOUND = re.compile(r"(?<![\w.(])(?:\w+\.)?(%s)\s*[-+*/]=" % CR_FIELDS)
RE_BD_LITERAL_OPEN = re.compile(r"(?<!\w)Breakdown\s*\{")
RE_LET_DESTRUCTURE = re.compile(r"\blet\s+Breakdown\s*\{")

RE_LINE_COMMENT = re.compile(r"//.*$")
RE_STRING = re.compile(r'"(?:[^"\\]|\\.)*"')
RE_CHAR = re.compile(r"'(?:[^'\\]|\\.)'")


def strip_noise(lines):
    """Blank out string/char literals and // and /* */ comments, keeping
    line numbers stable. Coarse but sufficient for this codebase (no raw
    strings or nested block comments in scope)."""
    out = []
    in_block = False
    for line in lines:
        if in_block:
            end = line.find("*/")
            if end < 0:
                out.append("")
                continue
            line = " " * (end + 2) + line[end + 2 :]
            in_block = False
        line = RE_STRING.sub('""', line)
        line = RE_CHAR.sub("' '", line)
        line = RE_LINE_COMMENT.sub("", line)
        while True:
            start = line.find("/*")
            if start < 0:
                break
            end = line.find("*/", start + 2)
            if end < 0:
                line = line[:start]
                in_block = True
                break
            line = line[:start] + " " * (end + 2 - start) + line[end + 2 :]
        out.append(line)
    return out


def lint_file(relpath, raw_lines):
    findings = []
    lines = strip_noise(raw_lines)

    def hit(rule, lineno, msg):
        findings.append((rule, relpath, lineno, msg))

    bd_literal_depth = None  # brace depth tracking for an open Breakdown literal
    depth = 0
    for i, line in enumerate(lines, start=1):
        m = RE_CLOCK_COMPOUND.search(line)
        if m:
            hit("CHARGE-CLOCK", i, f"compound assignment on `{m.group(1)}` — charge a Ledger instead")
        else:
            m = RE_CLOCK_ASSIGN.search(line)
            if m and re.search(r"(?<![\w.])%s\b" % re.escape(m.group(1)), m.group(2)):
                hit(
                    "CHARGE-CLOCK",
                    i,
                    f"self-referential update of `{m.group(1)}` — use Ledger::charge/advance_to",
                )
        m = RE_BD_COMPOUND.search(line)
        if m:
            hit("CHARGE-BD", i, f"raw arithmetic on Breakdown field `{m.group(1)}`")
        m = RE_CR_COMPOUND.search(line)
        if m:
            hit("CHARGE-CR", i, f"raw arithmetic on CommReport time field `{m.group(1)}`")
        # Breakdown literal exhaustiveness: track `..` inside the braces
        if bd_literal_depth is None:
            m = RE_BD_LITERAL_OPEN.search(line)
            if m and not RE_LET_DESTRUCTURE.search(line):
                bd_literal_depth = depth  # literal closes when depth returns here
                tail = line[m.end() :]
                depth += 1 + tail.count("{") - tail.count("}")
                if depth <= bd_literal_depth:
                    if re.search(r"\.\.[^=.]", tail) or tail.rstrip().endswith(".."):
                        hit("BD-LITERAL", i, "non-exhaustive `Breakdown { .. }` literal")
                    bd_literal_depth = None
                elif re.search(r"\.\.[^=.]", tail):
                    hit("BD-LITERAL", i, "non-exhaustive `Breakdown { .. }` literal")
                    bd_literal_depth = None
                continue
        else:
            if re.search(r"(?<!\.)\.\.(?![=.\d])", line):
                hit("BD-LITERAL", i, "non-exhaustive `Breakdown { .. }` literal")
                bd_literal_depth = None
            depth += line.count("{") - line.count("}")
            if bd_literal_depth is not None and depth <= bd_literal_depth:
                bd_literal_depth = None
            continue
        depth += line.count("{") - line.count("}")

    # drop findings the file owns
    return [
        f
        for f in findings
        if not any(owner in relpath for owner in OWNERS.get(f[0], ()))
    ]


def load_waivers():
    waivers = []
    if not os.path.exists(WAIVER_FILE):
        return waivers
    with open(WAIVER_FILE, encoding="utf-8") as fh:
        for n, raw in enumerate(fh, start=1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if "#" not in line:
                print(
                    f"lint_charges: {WAIVER_FILE}:{n}: waiver without a "
                    f"`# justification` comment — refusing it",
                    file=sys.stderr,
                )
                sys.exit(2)
            body = line.split("#", 1)[0].split()
            if len(body) != 2:
                print(
                    f"lint_charges: {WAIVER_FILE}:{n}: expected "
                    f"`RULE path # why`, got: {line}",
                    file=sys.stderr,
                )
                sys.exit(2)
            waivers.append({"rule": body[0], "path": body[1], "line": n, "used": False})
    return waivers


def main():
    all_findings = []
    for root, _dirs, files in os.walk(SRC):
        for name in sorted(files):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(root, name)
            rel = os.path.relpath(path, REPO).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                all_findings.extend(lint_file(rel, fh.read().splitlines()))

    waivers = load_waivers()
    unwaived = []
    for rule, rel, lineno, msg in all_findings:
        waived = False
        for w in waivers:
            if w["rule"] == rule and w["path"] in rel:
                w["used"] = True
                waived = True
                break
        if not waived:
            unwaived.append((rule, rel, lineno, msg))

    for rule, rel, lineno, msg in unwaived:
        print(f"{rel}:{lineno}: [{rule}] {msg}")

    stale = [w for w in waivers if not w["used"]]
    for w in stale:
        print(
            f"lint_charges: WARNING: stale waiver "
            f"({WAIVER_FILE}:{w['line']}: {w['rule']} {w['path']}) matched nothing — remove it",
            file=sys.stderr,
        )

    if unwaived:
        print(
            f"lint_charges: {len(unwaived)} finding(s) — spend time through "
            f"audit::Ledger or add a justified waiver to scripts/lint_waivers.txt",
            file=sys.stderr,
        )
        return 1
    suffix = f", {len(stale)} stale waiver(s)" if stale else ""
    print(
        f"lint_charges: clean ({len(all_findings) - len(unwaived)} waived finding(s){suffix})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
