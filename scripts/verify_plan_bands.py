#!/usr/bin/env python3
"""Python port of the `tmpi plan` exchange auto-tuner scoring.

Stdlib-only twin of `rust/src/plan/mod.rs`: the BSP plan objective
(`score_plan`) re-derived from the strategy pricers in
`verify_wfbp_bands.py`, driven through the identical search walk
(`pricing_model.plan_search` — hand-picked defaults first, exhaustive
discrete axes, greedy chunk/bucket ladders). Every score
`rust/benches/bench_plan.rs` reports over its sweep grid
(AlexNet-128 / GoogLeNet-32 x copper/mosaic x k in {2,4,8}) is recomputed
here; the committed baseline `bench/baselines/BENCH_plan.json` is
generated from this model and the CI `plan-smoke` step gates the bench
against it:

    python3 scripts/verify_plan_bands.py                    # verify bands
    python3 scripts/verify_plan_bands.py --write-baselines  # + regenerate
        bench/baselines/BENCH_plan.json

The default search is twin-portable by construction: flat strategies with
the dense f32 wire (the configurations this port prices to float
equality). `hier:<inner>` and compressed wires are explicit-plan-only in
Rust and are rejected here. EASGD plan scoring rides the threaded
`measure_sharded` probe and is pinned by Rust unit tests
(`plan::tests::easgd_search_never_loses_and_caches_round_trip`), not by
this port.

The script exits non-zero if any band fails. NOTE: this container carries
no Rust toolchain — this port is the only numeric verification the
planner bands get before the driver's tier-1 runs.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import verify_wfbp_bands as wb  # noqa: E402
from pricing_model import (  # noqa: E402
    by_name,
    elems_per_kib,
    plan_chunk_count,
    plan_half_wire,
    plan_search,
)

# The bench_plan sweep grid (ISSUE 10): paper models at their paper batch,
# both fabrics, 2 -> 8 workers.
SWEEP = [("alexnet", 128), ("googlenet", 32)]
TOPOLOGIES = ["copper", "mosaic"]
WORKER_COUNTS = [2, 4, 8]


def step_seconds(model, batch):
    """`PlanInputs::step_seconds`: Table 3 pace with the batch-32 fallback."""
    t5120 = wb.PAPER_TRAIN_5120.get((model, batch))
    if t5120 is None:
        t5120 = wb.PAPER_TRAIN_5120[(model, 32)]
    return t5120 * batch / 5120.0


def score_bsp(model, batch, workers, topology, plan, cuda_aware=True):
    """`plan::score_bsp`: comm_visible for bucketed-overlap plans,
    sim_total of the full-vector exchange otherwise."""
    if plan["wire"] not in (None, "f32"):
        raise ValueError(f"wire {plan['wire']!r} is explicit-plan-only (not ported)")
    table = wb.TABLES[model]
    full = sum(p for _, p in table)
    topo = by_name(topology, workers)
    strategy = plan["strategy"]
    if plan["overlap"] != "none":
        if plan["chunk_kib"]:
            raise ValueError("bucketed plans with chunk_kib are not ported")
        backward = step_seconds(model, batch) * wb.BWD_FRACTION
        bucket_elems = elems_per_kib(plan["bucket_kib"],
                                     plan_half_wire(strategy), "f32")
        out = wb.probe_wfbp(strategy, workers, topo, table, backward,
                            overlap=(plan["overlap"] == "wfbp"),
                            bucket_elems=bucket_elems, cuda_aware=cuda_aware)
        return out["comm_visible"]
    chunks = plan_chunk_count(full, plan)
    rep = wb.probe_exchange(strategy, workers, topo, full, chunks=chunks,
                            pipeline=plan["pipeline"], cuda_aware=cuda_aware)
    return wb.sim_total(rep)


def collect_metrics():
    """Recompute every metric bench_plan emits over the sweep grid,
    asserting the never-loses property along the way."""
    metrics = {}
    failures = []

    def put(name, value, better):
        metrics[name] = {"value": value, "better": better}

    def check(cond, msg):
        if not cond:
            failures.append(msg)

    for model, batch in SWEEP:
        for topo_name in TOPOLOGIES:
            for k in WORKER_COUNTS:
                tag = f"plan/{model}/{topo_name}/k{k}"
                choice = plan_search(
                    "bsp", k,
                    lambda p: score_bsp(model, batch, k, topo_name, p))
                default_best = min(s for _, s in choice["default_scores"])
                put(f"{tag}/best_score", choice["score"], "lower")
                put(f"{tag}/default_best", default_best, "lower")
                put(f"{tag}/advantage", default_best / choice["score"], "higher")
                put(f"{tag}/candidates", choice["evaluated"], "higher")
                for dplan, dscore in choice["default_scores"]:
                    check(choice["score"] <= dscore,
                          f"{tag}: planner pick {choice['plan']} "
                          f"({choice['score']:.6e}s) loses to default "
                          f"{dplan} ({dscore:.6e}s)")
                again = score_bsp(model, batch, k, topo_name, choice["plan"])
                check(again == choice["score"],
                      f"{tag}: re-scoring the winner gives {again!r}, "
                      f"search reported {choice['score']!r}")

    return metrics, failures


def write_baselines(metrics, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    note = ("generated by scripts/verify_plan_bands.py --write-baselines; "
            "values mirror bench_plan's runtime-free planner sweep")
    path = os.path.join(out_dir, "BENCH_plan.json")
    with open(path, "w") as f:
        json.dump({"note": note, "metrics": metrics}, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(metrics)} metrics)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write-baselines", action="store_true",
                    help="regenerate bench/baselines/BENCH_plan.json")
    ap.add_argument("--baseline-dir", default=None)
    args = ap.parse_args()
    baseline_dir = args.baseline_dir or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "bench", "baselines")

    metrics, failures = collect_metrics()

    width = max(len(k) for k in metrics)
    for name in sorted(metrics):
        print(f"{name:{width}s} {metrics[name]['value']!r}")

    if args.write_baselines:
        write_baselines(metrics, baseline_dir)

    print(f"\n{len(metrics)} metrics;", "bands OK" if not failures else "bands FAILED")
    for f in failures:
        print(" FAIL", f)
    return 0 if not failures else 1


if __name__ == "__main__":
    sys.exit(main())
