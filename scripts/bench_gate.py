#!/usr/bin/env python3
"""Bench-regression gate: diff a bench run's JSON against committed baselines.

Usage:
    python3 scripts/bench_gate.py BENCH_collectives.json bench/baselines/BENCH_collectives.json
    python3 scripts/bench_gate.py --tolerance 0.10 <current.json> <baseline.json> [...]

Current-run files come from the benches themselves: every `report()`ed
simulated metric is collected and, with TMPI_BENCH_JSON=<path> set, dumped
as {"metrics": {name: {"value": v, "unit": u}}}. The committed baselines
live under bench/baselines/ and additionally carry a "better" direction per
metric ("lower" for times, "higher" for throughput/overlap ratios).

Gate semantics (per metric present in the baseline):
  * better=lower  -> FAIL if current > baseline * (1 + tolerance)
  * better=higher -> FAIL if current < baseline * (1 - tolerance)
  * missing from the current run -> FAIL (a silently dropped metric is a
    regression of coverage)
Metrics in the current run but not in the baseline are listed as NEW and do
not fail the gate — refresh the baselines deliberately to start tracking
them (see README "Refreshing bench baselines"). Wall-clock metrics (unit
"s_wall") are machine-dependent and are never gated.

When `$GITHUB_STEP_SUMMARY` is set (GitHub Actions), a markdown table of
every gated metric with its delta vs baseline is appended to that file —
stdout output is unchanged, so local runs and log-scraping keep working.

Exit status: 0 clean, 1 on any regression or missing metric.
"""

import argparse
import json
import os
import sys


def load(path):
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        sys.exit(f"{path}: cannot read: {e}")
    except json.JSONDecodeError as e:
        sys.exit(f"{path}: not valid JSON: {e}")
    if not isinstance(data, dict) or not isinstance(data.get("metrics"), dict):
        sys.exit(f"{path}: expected a top-level 'metrics' object")
    return data["metrics"]


def entry_value(entry):
    """(value, error) from one metrics entry; never raises. A bench writer
    bug (entry not an object, no "value", non-numeric value) must surface
    as a reported finding against that metric, not a traceback that hides
    every other metric's result."""
    if not isinstance(entry, dict):
        return None, f"malformed entry (expected an object, got {type(entry).__name__})"
    if "value" not in entry:
        return None, f"malformed entry (no \"value\" key; keys: {sorted(entry)})"
    v = entry["value"]
    if v is None:
        return None, "value is null (non-finite)"
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None, f"non-numeric value {v!r}"
    return v, None


def entry_unit(entry):
    return entry.get("unit") if isinstance(entry, dict) else None


def gate(current_path, baseline_path, tolerance, summary=None):
    """Gate one (current, baseline) pair. When `summary` is a list, one row
    dict per considered metric is appended for the markdown step summary."""
    current = load(current_path)
    baseline = load(baseline_path)
    failures, checked, new = [], 0, []

    def note(name, status, cur=None, ref=None, better=None):
        if summary is not None:
            summary.append({"pair": f"{current_path} vs {baseline_path}",
                            "name": name, "status": status, "current": cur,
                            "baseline": ref, "better": better})

    for name, base in sorted(baseline.items()):
        if entry_unit(base) == "s_wall":
            continue
        ref, err = entry_value(base)
        if err is not None:
            failures.append(f"{name}: baseline {err} — fix {baseline_path}")
            note(name, "MALFORMED")
            continue
        # direction must be explicit: a silently-defaulted direction would
        # gate higher-is-better metrics (overlap fractions, speedups)
        # backwards. verify_wfbp_bands.py --write-baselines sets it.
        better = base.get("better")
        if better not in ("lower", "higher"):
            failures.append(
                f"{name}: baseline must declare \"better\": \"lower\"|\"higher\" "
                f"(got {better!r}) — regenerate with "
                f"scripts/verify_wfbp_bands.py --write-baselines"
            )
            note(name, "MALFORMED", ref=ref)
            continue
        if name not in current:
            failures.append(f"{name}: missing from the current run (baseline {ref})")
            note(name, "MISSING", ref=ref, better=better)
            continue
        cur, err = entry_value(current[name])
        if err is not None:
            failures.append(f"{name}: current {err}")
            note(name, "MALFORMED", ref=ref, better=better)
            continue
        checked += 1
        # budget around a zero reference degenerates to an absolute epsilon
        # (no division: ref can legitimately be 0.0, e.g. a kernel-free win)
        eps = 1e-12
        regressed = (
            cur > ref * (1.0 + tolerance) + eps
            if better == "lower"
            else cur < ref * (1.0 - tolerance) - eps
        )
        if regressed:
            pct = f" ({(cur / ref - 1.0) * 100.0:+.1f}%)" if ref else ""
            failures.append(
                f"{name}: {cur:.6g} regressed vs {ref:.6g}{pct} "
                f"(budget {tolerance * 100.0:.0f}%, better={better})"
            )
        note(name, "FAIL" if regressed else "OK", cur=cur, ref=ref, better=better)

    for name, m in sorted(current.items()):
        if name not in baseline and entry_unit(m) != "s_wall":
            new.append(name)
            v, _ = entry_value(m)
            note(name, "NEW", cur=v)

    tag = f"{current_path} vs {baseline_path}"
    print(f"bench-gate: {tag}: {checked} metrics checked, {len(new)} new, {len(failures)} failing")
    for name in new:
        v, err = entry_value(current[name])
        print(f"  NEW (unbaselined, not gated): {name} = {err if err else v}")
    for f in failures:
        print(f"  FAIL {f}")
    return not failures


def fmt_num(v):
    return "—" if v is None else f"{v:.6g}"


def render_step_summary(rows, tolerance, ok):
    """Markdown for $GITHUB_STEP_SUMMARY: one table per gated pair with
    deltas vs baseline. Pure function of the collected rows (testable)."""
    lines = [f"## bench-gate: {'OK' if ok else 'FAILED'} "
             f"(budget {tolerance * 100.0:.0f}%)", ""]
    by_pair = {}
    for r in rows:
        by_pair.setdefault(r["pair"], []).append(r)
    for pair, pair_rows in by_pair.items():
        lines += [f"### {pair}", "",
                  "| metric | current | baseline | delta | better | status |",
                  "|---|---|---|---|---|---|"]
        for r in pair_rows:
            cur, ref = r["current"], r["baseline"]
            if cur is not None and ref:
                delta = f"{(cur / ref - 1.0) * 100.0:+.2f}%"
            else:
                delta = "—"
            status = r["status"]
            if status in ("FAIL", "MISSING", "MALFORMED"):
                status = f"**{status}**"
            lines.append(f"| {r['name']} | {fmt_num(cur)} | {fmt_num(ref)} "
                         f"| {delta} | {r['better'] or '—'} | {status} |")
        lines.append("")
    return "\n".join(lines) + "\n"


def write_step_summary(rows, tolerance, ok, path):
    with open(path, "a") as f:
        f.write(render_step_summary(rows, tolerance, ok))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="fractional regression budget (default 0.10 = 10%%)")
    ap.add_argument("pairs", nargs="+",
                    help="alternating <current.json> <baseline.json> pairs")
    args = ap.parse_args()
    if len(args.pairs) % 2:
        ap.error("arguments must come in <current.json> <baseline.json> pairs")
    ok = True
    rows = []
    for cur, base in zip(args.pairs[::2], args.pairs[1::2]):
        ok &= gate(cur, base, args.tolerance, summary=rows)
    print("bench-gate:", "OK" if ok else "FAILED")
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        write_step_summary(rows, args.tolerance, ok, summary_path)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
