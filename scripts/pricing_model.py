#!/usr/bin/env python3
"""Shared pricing-model substrate for the band-verification scripts.

The stdlib-only Python ports of the Rust pricing model
(`scripts/verify_wfbp_bands.py`, `scripts/verify_easgd_bands.py`) used to
each carry their own copy of the link-parameter constants, the
copper/mosaic topologies, and the scatterv split — two copies of numbers
that must mirror `rust/src/simnet` / `rust/src/cluster` exactly. This
module is the single copy both import; keep it byte-faithful to the Rust
defaults (`LinkParams::default()`, `Topology::{copper,mosaic}`,
`util::split_even`).

Everything model-specific (EASGD queue simulation, strategy pricing, WFBP
timeline) stays in the owning script — only code that was *duplicated*
lives here.
"""

# --- simnet::LinkParams::default() -----------------------------------------
PCIE_GBPS = 12.0
PCIE_LAT_US = 10.0
QPI_GBPS = 16.0
QPI_LAT_US = 1.0
IB_FDR_GBPS = 6.8
IB_QDR_GBPS = 4.0
IB_LAT_US = 1.5
HOST_MEM_GBPS = 10.0
HOST_REDUCE_GBPS = 5.0
GPU_REDUCE_GBPS = 150.0
GPU_CAST_GBPS = 200.0


# --- cluster::Topology ------------------------------------------------------
class Topo:
    """GPU placement table: (node, socket, switch) per GPU + IB tier.

    Supports both attribute access (`topo.gpus`, the wfbp port's idiom)
    and mapping access (`topo["gpus"]`, the easgd port's legacy dict
    idiom) so both scripts read it natively.
    """

    def __init__(self, gpus, ib_gbps):
        self.gpus = gpus
        self.ib = ib_gbps

    def __getitem__(self, key):
        return {"gpus": self.gpus, "ib": self.ib}[key]

    def path(self, a, b):
        if a == b:
            return "local"
        ga, gb = self.gpus[a], self.gpus[b]
        if ga[0] != gb[0]:
            return "network"
        if ga[2] == gb[2]:
            return "p2p"
        return "qpi"


def path(topo, a, b):
    """Free-function form of `Topo.path` (the easgd port's idiom)."""
    return topo.path(a, b)


def copper(nodes):
    """(node, socket, switch) per GPU: 2 sockets x 4 dies per node."""
    gpus = []
    for n in range(nodes):
        for socket in range(2):
            for _ in range(4):
                gpus.append((n, socket, n * 2 + socket))
    return Topo(gpus, IB_FDR_GBPS)


def mosaic(nodes):
    return Topo([(n, 0, n * 2) for n in range(nodes)], IB_QDR_GBPS)


def by_name(name, workers):
    if name == "mosaic":
        return mosaic(max(workers, 1))
    if name == "copper":
        return copper(-(-max(workers, 1) // 8))
    raise ValueError(name)


# --- util::split_even (MPI_Scatterv convention) -----------------------------
def split_even(n, k):
    base, extra = n // k, n % k
    out, off = [], 0
    for i in range(k):
        ln = base + (1 if i < extra else 0)
        out.append((off, ln))
        off += ln
    return out
