#!/usr/bin/env python3
"""Shared pricing-model substrate for the band-verification scripts.

The stdlib-only Python ports of the Rust pricing model
(`scripts/verify_wfbp_bands.py`, `scripts/verify_easgd_bands.py`) used to
each carry their own copy of the link-parameter constants, the
copper/mosaic topologies, and the scatterv split — two copies of numbers
that must mirror `rust/src/simnet` / `rust/src/cluster` exactly. This
module is the single copy both import; keep it byte-faithful to the Rust
defaults (`LinkParams::default()`, `Topology::{copper,mosaic}`,
`util::split_even`).

Everything model-specific (EASGD queue simulation, strategy pricing, WFBP
timeline) stays in the owning script — only code that was *duplicated*
lives here.
"""

import math

# --- simnet::LinkParams::default() -----------------------------------------
PCIE_GBPS = 12.0
PCIE_LAT_US = 10.0
QPI_GBPS = 16.0
QPI_LAT_US = 1.0
IB_FDR_GBPS = 6.8
IB_QDR_GBPS = 4.0
IB_LAT_US = 1.5
HOST_MEM_GBPS = 10.0
HOST_REDUCE_GBPS = 5.0
GPU_REDUCE_GBPS = 150.0
GPU_CAST_GBPS = 200.0


# --- cluster::Topology ------------------------------------------------------
class Topo:
    """GPU placement table: (node, socket, switch) per GPU + IB tier.

    Supports both attribute access (`topo.gpus`, the wfbp port's idiom)
    and mapping access (`topo["gpus"]`, the easgd port's legacy dict
    idiom) so both scripts read it natively.
    """

    def __init__(self, gpus, ib_gbps):
        self.gpus = gpus
        self.ib = ib_gbps

    def __getitem__(self, key):
        return {"gpus": self.gpus, "ib": self.ib}[key]

    def path(self, a, b):
        if a == b:
            return "local"
        ga, gb = self.gpus[a], self.gpus[b]
        if ga[0] != gb[0]:
            return "network"
        if ga[2] == gb[2]:
            return "p2p"
        return "qpi"


def path(topo, a, b):
    """Free-function form of `Topo.path` (the easgd port's idiom)."""
    return topo.path(a, b)


def copper(nodes):
    """(node, socket, switch) per GPU: 2 sockets x 4 dies per node."""
    gpus = []
    for n in range(nodes):
        for socket in range(2):
            for _ in range(4):
                gpus.append((n, socket, n * 2 + socket))
    return Topo(gpus, IB_FDR_GBPS)


def mosaic(nodes):
    return Topo([(n, 0, n * 2) for n in range(nodes)], IB_QDR_GBPS)


def by_name(name, workers):
    if name == "mosaic":
        return mosaic(max(workers, 1))
    if name == "copper":
        return copper(-(-max(workers, 1) // 8))
    raise ValueError(name)


# --- util::split_even (MPI_Scatterv convention) -----------------------------
def split_even(n, k):
    base, extra = n // k, n % k
    out, off = [], 0
    for i in range(k):
        ln = base + (1 if i < extra else 0)
        out.append((off, ln))
        off += ln
    return out


# --- simnet::LinkParams::pcie_time -------------------------------------------
def pcie_time(nbytes, pcie_gbps=PCIE_GBPS, pcie_lat_us=PCIE_LAT_US):
    return pcie_lat_us * 1e-6 + nbytes / (pcie_gbps * 1e9)


# --- collectives::wire codec byte formulas -----------------------------------
# Mirrors `rust/src/collectives/wire.rs`: closed-form on-wire byte counts
# per format (they depend only on n, never on the data), the wire-width
# sizing helper, and f64 half-away-from-zero rounding (`f64::round`).
# Formats are the CLI names: "f32" | "f16" | "bf16" | "topk:<p>" |
# "onebit" | "sf".

def round_half_away(x):
    """Rust `f64::round`: half away from zero (Python round() is banker's)."""
    return math.floor(x + 0.5) if x >= 0.0 else math.ceil(x - 0.5)


def topk_count(n, p):
    """`⌈p·n⌉` clamped to [1, n] — how many elements topk:<p> ships."""
    if n == 0:
        return 0
    return min(max(math.ceil(p * n), 1), n)


def codec_wire_bytes(fmt, n, sf_bytes=None):
    """`wire::encode(...).wire_bytes` for an n-element f32 buffer."""
    if fmt == "f32":
        return 4 * n
    if fmt in ("f16", "bf16"):
        return 2 * n
    if fmt.startswith("topk:"):
        # 8 bytes per shipped element: (u32 index, f32 value)
        return 8 * topk_count(n, float(fmt.split(":", 1)[1]))
    if fmt == "onebit":
        # one sign bit per element + one f32 scale
        return -(-n // 8) + 4
    if fmt == "sf":
        dense = 4 * n
        return sf_bytes if sf_bytes is not None and sf_bytes < dense else dense
    raise ValueError(fmt)


def wire_bytes_per_elem(half_wire, fmt):
    """`wire::wire_bytes_per_elem` (sizing, not pricing): nominal on-wire
    bytes per f32 element; `half_wire` is the strategy's native width."""
    if fmt == "f32":
        b = 2.0 if half_wire else 4.0
    elif fmt in ("f16", "bf16"):
        b = 2.0
    elif fmt.startswith("topk:"):
        b = 8.0 * float(fmt.split(":", 1)[1])
    elif fmt == "onebit":
        b = 0.125
    elif fmt == "sf":
        b = 4.0
    else:
        raise ValueError(fmt)
    return max(b, 0.125)


def elems_per_kib(kib, half_wire, fmt):
    """`wire::elems_per_kib`: elements per KiB of on-wire budget."""
    return math.floor((kib * 1024.0) / wire_bytes_per_elem(half_wire, fmt))


# --- plan::ExchangePlan / plan::search mirror --------------------------------
# The `tmpi plan` auto-tuner (`rust/src/plan/mod.rs`): plans are dicts with
# the same fields as `ExchangePlan`, and `plan_search` walks the identical
# candidate order (hand-picked defaults first, then exhaustive discrete
# axes with greedy chunk/bucket ladders, strict `<` so earlier candidates
# win ties). Scoring is injected as a callback: `verify_plan_bands.py`
# wires in the strategy pricers from `verify_wfbp_bands.py`, keeping this
# module free of anything that wasn't shared.

PLAN_CHUNK_LADDER = [64, 256, 1024, 4096, 16384]
PLAN_BUCKET_LADDER = [0, 1024, 4096, 16384]
PLAN_SEARCH_STRATEGIES = ["ar", "asa", "asa16", "ring"]


def plan_default():
    """`ExchangePlan::default()` as a dict."""
    return {"strategy": "asa", "wire": None, "chunk_kib": 0, "pipeline": True,
            "overlap": "none", "bucket_kib": 0, "servers": 1}


def plan_half_wire(strategy):
    """`StrategyKind::half_wire`: asa16 (flat or hier inner) ships f16."""
    return strategy.split(":")[-1] == "asa16"


def plan_chunk_count(full_elems, plan):
    """`plan::score_bsp`'s chunk-count derivation: a full-scale on-wire
    chunk budget (`Kib::elems`) becomes a chunk *count* the probe projects
    onto its capped buffer."""
    if plan["chunk_kib"] == 0:
        return 0
    chunk_elems = max(
        elems_per_kib(plan["chunk_kib"], plan_half_wire(plan["strategy"]),
                      plan["wire"] or "f32"), 1)
    return -(-full_elems // chunk_elems)


def plan_hand_picked_defaults(mode):
    """`plan::hand_picked_defaults`: the never-loses baseline set."""
    base = plan_default()
    if mode == "bsp":
        return [base,
                {**plan_default(), "strategy": "ar"},
                {**plan_default(), "strategy": "ring"},
                {**plan_default(), "strategy": "asa16"},
                {**plan_default(), "chunk_kib": 4096},
                {**plan_default(), "overlap": "wfbp"}]
    return [base,
            {**plan_default(), "strategy": "asa16"},
            {**plan_default(), "chunk_kib": 256}]


def plan_search(mode, workers, score):
    """`plan::search` twin: same candidate order, same greedy pruning
    (`s >= rung_best` stops a ladder walk), same strict-`<` argmin.
    `score(plan) -> seconds`. Returns the Rust `PlanChoice` as a dict."""
    state = {"plan": None, "score": float("inf"), "evaluated": 0}

    def ev(plan):
        s = score(plan)
        state["evaluated"] += 1
        if s < state["score"]:
            state["plan"], state["score"] = plan, s
        return s

    default_scores = [(p, ev(p)) for p in plan_hand_picked_defaults(mode)]

    if mode == "bsp":
        for strategy in PLAN_SEARCH_STRATEGIES:
            mono = {**plan_default(), "strategy": strategy}
            rung_best = ev(mono)
            for kib in PLAN_CHUNK_LADDER:
                s = ev({**mono, "chunk_kib": kib})
                if s >= rung_best:
                    break
                rung_best = s
            rung_best = float("inf")
            for kib in PLAN_BUCKET_LADDER:
                s = ev({**plan_default(), "strategy": strategy,
                        "overlap": "wfbp", "bucket_kib": kib})
                if s >= rung_best:
                    break
                rung_best = s
    elif mode == "easgd":
        servers_axis, s = [], 1
        while s <= workers:
            servers_axis.append(s)
            s *= 2
        for servers in servers_axis:
            for strategy in ("asa", "asa16"):
                mono = {**plan_default(), "strategy": strategy,
                        "servers": servers}
                rung_best = ev(mono)
                for kib in PLAN_CHUNK_LADDER:
                    sc = ev({**mono, "chunk_kib": kib})
                    if sc >= rung_best:
                        break
                    rung_best = sc
    else:
        raise ValueError(mode)

    return {"plan": state["plan"], "score": state["score"],
            "evaluated": state["evaluated"], "default_scores": default_scores}


# --- loader::sim::DiskParams::default() -------------------------------------
DISK_GBPS = 1.0
DISK_LAT_US = 100.0
DECODE_GBPS = 0.5
DECODE_SPIKE_EVERY = 8
DECODE_SPIKE_FACTOR = 8.0


def _sim_cache(cache_mib, n_files, iters, batch_bytes):
    """LRU over the cyclic file sequence i mod n_files, uniform size —
    mirrors `loader::sim::sim_cache` exactly. Returns (hit flags, stats)."""
    cap = cache_mib << 20
    order, resident = [], 0
    st = {"hits": 0, "misses": 0, "evictions": 0, "resident_bytes": 0,
          "capacity_bytes": cap}
    hits = []
    for i in range(iters):
        f = i % n_files
        if f in order:
            order.remove(f)
            order.append(f)
            st["hits"] += 1
            hits.append(True)
        else:
            st["misses"] += 1
            hits.append(False)
            if batch_bytes <= cap:
                while resident + batch_bytes > cap:
                    order.pop(0)
                    resident -= batch_bytes
                    st["evictions"] += 1
                order.append(f)
                resident += batch_bytes
    st["resident_bytes"] = resident
    return hits, st


def _child_cost(i, hit, workers, batch_bytes,
                disk_gbps=DISK_GBPS, disk_lat_us=DISK_LAT_US,
                decode_gbps=DECODE_GBPS, spike_every=DECODE_SPIKE_EVERY,
                spike_factor=DECODE_SPIKE_FACTOR):
    """Mirrors `loader::sim::child_cost`: disk (free on hit) + decode
    (spiky every Nth batch)."""
    if hit:
        disk_s = 0.0
    else:
        disk_s = disk_lat_us * 1e-6 + batch_bytes / ((disk_gbps / workers) * 1e9)
    spike = spike_factor if (i + 1) % spike_every == 0 else 1.0
    decode_s = batch_bytes / (decode_gbps * 1e9) * spike
    return disk_s + decode_s


def sim_loader_pipeline(workers, prefetch_depth, cache_mib, n_files, iters,
                        batch_bytes, h2d_bytes, compute_s):
    """Python twin of `loader::sim::sim_pipeline` (same float op order).

    Returns a dict with the final virtual clock and its decomposition:
    vtime == load_stall + h2d + compute exactly (load_hidden is a memo).
    prefetch_depth == 0 is the direct (synchronous) path.
    """
    hits, cache = _sim_cache(cache_mib, n_files, iters, batch_bytes)
    h2d_s = pcie_time(h2d_bytes)
    clk = 0.0
    bd = {"load_stall": 0.0, "load_hidden": 0.0, "h2d": 0.0, "compute": 0.0}
    if prefetch_depth == 0:
        for i in range(iters):
            cost = _child_cost(i, hits[i], workers, batch_bytes)
            bd["load_stall"] += cost
            clk += cost
            bd["h2d"] += h2d_s
            clk += h2d_s
            bd["compute"] += compute_s
            clk += compute_s
    else:
        q = prefetch_depth
        child = 0.0  # the child ServerClock: max(clock, arrival) + handle
        finish = [0.0] * iters
        for j in range(min(q, iters)):
            child = max(child, 0.0) + _child_cost(j, hits[j], workers, batch_bytes)
            finish[j] = child
        for i in range(iters):
            cost_i = _child_cost(i, hits[i], workers, batch_bytes)
            stall = max(finish[i] - clk, 0.0)
            # Ledger::advance_to charges delta = new_clock - clock, which
            # can differ from `stall` in the last ulp — mirror it exactly
            new_clk = clk + stall
            bd["load_stall"] += new_clk - clk
            clk = new_clk
            bd["load_hidden"] += max(cost_i - stall, 0.0)
            bd["h2d"] += h2d_s
            clk += h2d_s
            nxt = i + q
            if nxt < iters:
                child = max(child, clk) + _child_cost(nxt, hits[nxt], workers, batch_bytes)
                finish[nxt] = child
            bd["compute"] += compute_s
            clk += compute_s
    return {"vtime": clk, "bd": bd, "cache": cache}
