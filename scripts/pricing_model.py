#!/usr/bin/env python3
"""Shared pricing-model substrate for the band-verification scripts.

The stdlib-only Python ports of the Rust pricing model
(`scripts/verify_wfbp_bands.py`, `scripts/verify_easgd_bands.py`) used to
each carry their own copy of the link-parameter constants, the
copper/mosaic topologies, and the scatterv split — two copies of numbers
that must mirror `rust/src/simnet` / `rust/src/cluster` exactly. This
module is the single copy both import; keep it byte-faithful to the Rust
defaults (`LinkParams::default()`, `Topology::{copper,mosaic}`,
`util::split_even`).

Everything model-specific (EASGD queue simulation, strategy pricing, WFBP
timeline) stays in the owning script — only code that was *duplicated*
lives here.
"""

# --- simnet::LinkParams::default() -----------------------------------------
PCIE_GBPS = 12.0
PCIE_LAT_US = 10.0
QPI_GBPS = 16.0
QPI_LAT_US = 1.0
IB_FDR_GBPS = 6.8
IB_QDR_GBPS = 4.0
IB_LAT_US = 1.5
HOST_MEM_GBPS = 10.0
HOST_REDUCE_GBPS = 5.0
GPU_REDUCE_GBPS = 150.0
GPU_CAST_GBPS = 200.0


# --- cluster::Topology ------------------------------------------------------
class Topo:
    """GPU placement table: (node, socket, switch) per GPU + IB tier.

    Supports both attribute access (`topo.gpus`, the wfbp port's idiom)
    and mapping access (`topo["gpus"]`, the easgd port's legacy dict
    idiom) so both scripts read it natively.
    """

    def __init__(self, gpus, ib_gbps):
        self.gpus = gpus
        self.ib = ib_gbps

    def __getitem__(self, key):
        return {"gpus": self.gpus, "ib": self.ib}[key]

    def path(self, a, b):
        if a == b:
            return "local"
        ga, gb = self.gpus[a], self.gpus[b]
        if ga[0] != gb[0]:
            return "network"
        if ga[2] == gb[2]:
            return "p2p"
        return "qpi"


def path(topo, a, b):
    """Free-function form of `Topo.path` (the easgd port's idiom)."""
    return topo.path(a, b)


def copper(nodes):
    """(node, socket, switch) per GPU: 2 sockets x 4 dies per node."""
    gpus = []
    for n in range(nodes):
        for socket in range(2):
            for _ in range(4):
                gpus.append((n, socket, n * 2 + socket))
    return Topo(gpus, IB_FDR_GBPS)


def mosaic(nodes):
    return Topo([(n, 0, n * 2) for n in range(nodes)], IB_QDR_GBPS)


def by_name(name, workers):
    if name == "mosaic":
        return mosaic(max(workers, 1))
    if name == "copper":
        return copper(-(-max(workers, 1) // 8))
    raise ValueError(name)


# --- util::split_even (MPI_Scatterv convention) -----------------------------
def split_even(n, k):
    base, extra = n // k, n % k
    out, off = [], 0
    for i in range(k):
        ln = base + (1 if i < extra else 0)
        out.append((off, ln))
        off += ln
    return out


# --- simnet::LinkParams::pcie_time -------------------------------------------
def pcie_time(nbytes, pcie_gbps=PCIE_GBPS, pcie_lat_us=PCIE_LAT_US):
    return pcie_lat_us * 1e-6 + nbytes / (pcie_gbps * 1e9)


# --- loader::sim::DiskParams::default() -------------------------------------
DISK_GBPS = 1.0
DISK_LAT_US = 100.0
DECODE_GBPS = 0.5
DECODE_SPIKE_EVERY = 8
DECODE_SPIKE_FACTOR = 8.0


def _sim_cache(cache_mib, n_files, iters, batch_bytes):
    """LRU over the cyclic file sequence i mod n_files, uniform size —
    mirrors `loader::sim::sim_cache` exactly. Returns (hit flags, stats)."""
    cap = cache_mib << 20
    order, resident = [], 0
    st = {"hits": 0, "misses": 0, "evictions": 0, "resident_bytes": 0,
          "capacity_bytes": cap}
    hits = []
    for i in range(iters):
        f = i % n_files
        if f in order:
            order.remove(f)
            order.append(f)
            st["hits"] += 1
            hits.append(True)
        else:
            st["misses"] += 1
            hits.append(False)
            if batch_bytes <= cap:
                while resident + batch_bytes > cap:
                    order.pop(0)
                    resident -= batch_bytes
                    st["evictions"] += 1
                order.append(f)
                resident += batch_bytes
    st["resident_bytes"] = resident
    return hits, st


def _child_cost(i, hit, workers, batch_bytes,
                disk_gbps=DISK_GBPS, disk_lat_us=DISK_LAT_US,
                decode_gbps=DECODE_GBPS, spike_every=DECODE_SPIKE_EVERY,
                spike_factor=DECODE_SPIKE_FACTOR):
    """Mirrors `loader::sim::child_cost`: disk (free on hit) + decode
    (spiky every Nth batch)."""
    if hit:
        disk_s = 0.0
    else:
        disk_s = disk_lat_us * 1e-6 + batch_bytes / ((disk_gbps / workers) * 1e9)
    spike = spike_factor if (i + 1) % spike_every == 0 else 1.0
    decode_s = batch_bytes / (decode_gbps * 1e9) * spike
    return disk_s + decode_s


def sim_loader_pipeline(workers, prefetch_depth, cache_mib, n_files, iters,
                        batch_bytes, h2d_bytes, compute_s):
    """Python twin of `loader::sim::sim_pipeline` (same float op order).

    Returns a dict with the final virtual clock and its decomposition:
    vtime == load_stall + h2d + compute exactly (load_hidden is a memo).
    prefetch_depth == 0 is the direct (synchronous) path.
    """
    hits, cache = _sim_cache(cache_mib, n_files, iters, batch_bytes)
    h2d_s = pcie_time(h2d_bytes)
    clk = 0.0
    bd = {"load_stall": 0.0, "load_hidden": 0.0, "h2d": 0.0, "compute": 0.0}
    if prefetch_depth == 0:
        for i in range(iters):
            cost = _child_cost(i, hits[i], workers, batch_bytes)
            bd["load_stall"] += cost
            clk += cost
            bd["h2d"] += h2d_s
            clk += h2d_s
            bd["compute"] += compute_s
            clk += compute_s
    else:
        q = prefetch_depth
        child = 0.0  # the child ServerClock: max(clock, arrival) + handle
        finish = [0.0] * iters
        for j in range(min(q, iters)):
            child = max(child, 0.0) + _child_cost(j, hits[j], workers, batch_bytes)
            finish[j] = child
        for i in range(iters):
            cost_i = _child_cost(i, hits[i], workers, batch_bytes)
            stall = max(finish[i] - clk, 0.0)
            # Ledger::advance_to charges delta = new_clock - clock, which
            # can differ from `stall` in the last ulp — mirror it exactly
            new_clk = clk + stall
            bd["load_stall"] += new_clk - clk
            clk = new_clk
            bd["load_hidden"] += max(cost_i - stall, 0.0)
            bd["h2d"] += h2d_s
            clk += h2d_s
            nxt = i + q
            if nxt < iters:
                child = max(child, clk) + _child_cost(nxt, hits[nxt], workers, batch_bytes)
                finish[nxt] = child
            bd["compute"] += compute_s
            clk += compute_s
    return {"vtime": clk, "bd": bd, "cache": cache}
