#!/usr/bin/env bash
# Tier-1 verification: the gate every PR must keep green.
#
#   rust:   cargo build --release && cargo test -q   (offline workspace;
#           artifact-dependent tests skip when artifacts/ is absent)
#   python: pytest python/tests -q                   (L1/L2 kernel + model
#           oracles; uses the in-repo hypothesis shim when offline)
#
# Usage: scripts/tier1.sh  (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== tier-1: pytest python/tests -q =="
python3 -m pytest python/tests -q

echo "tier-1 green"
