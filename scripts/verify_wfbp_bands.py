#!/usr/bin/env python3
"""Python port of the WFBP + collectives pricing model.

Stdlib-only reference implementation of the Rust `simnet` device-level
phase pricing, the AR/ASA/ASA16/Ring strategy cost structure, the chunked
pipeline, and the wait-free backprop (WFBP) bucket timeline. Every
deterministic numeric band asserted by `rust/benches/bench_collectives.rs`
(smoke set) and `rust/tests/wfbp_overlap.rs`'s pricing checks is re-derived
here; run this script after touching the pricing model and refresh the
committed baselines if the printed values move:

    python3 scripts/verify_wfbp_bands.py                  # verify bands
    python3 scripts/verify_wfbp_bands.py --write-baselines  # + regenerate
        bench/baselines/BENCH_collectives.json / BENCH_easgd.json

The hierarchical (hier:*) sweeps are full-bench only (not part of the CI
smoke set) and are not ported here; their bands were verified in PR 2.

The script exits non-zero if any band fails. NOTE: this container carries
no Rust toolchain — this port is the only numeric verification the bands
get before the driver's tier-1 runs, so keep it faithful to the Rust
arithmetic (same model, same operation structure; f64 round-off apart).
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from pricing_model import (  # noqa: E402  (shared simnet/cluster constants)
    GPU_CAST_GBPS,
    GPU_REDUCE_GBPS,
    HOST_MEM_GBPS,
    HOST_REDUCE_GBPS,
    IB_LAT_US,
    PCIE_GBPS,
    PCIE_LAT_US,
    QPI_GBPS,
    QPI_LAT_US,
    by_name,
    copper,
    mosaic,
    split_even,
)

# --- collectives::wfbp constants -------------------------------------------
BWD_FRACTION = 2.0 / 3.0
CONV_COMPUTE_REUSE = 169.0

PROBE_CAP = 1_000_000


# --- simnet::phase_cost (device-level resource map) -------------------------
def phase_cost(topo, transfers, cuda_aware=True):
    """transfers: [(src, dst, bytes)] -> (bandwidth_s, latency_s)."""
    load = {}
    max_lat = 0.0

    def add(key, b, gbps):
        load[key] = load.get(key, 0.0) + b / (gbps * 1e9)

    for src, dst, b in transfers:
        if src == dst or b == 0:
            continue
        gs, gd = topo.gpus[src], topo.gpus[dst]
        lat = 0.0
        kind = topo.path(src, dst)
        if kind == "p2p":
            add(("pu", src), b, PCIE_GBPS)
            add(("pd", dst), b, PCIE_GBPS)
            lat += 2.0 * PCIE_LAT_US
            if not cuda_aware:
                add(("hm", gs[0]), 2 * b, HOST_MEM_GBPS)
                lat += 2.0 * PCIE_LAT_US
        elif kind == "qpi":
            add(("pu", src), b, PCIE_GBPS)
            add(("qp", gs[0]), b, QPI_GBPS)
            add(("hm", gs[0]), 2 * b, HOST_MEM_GBPS)
            add(("pd", dst), b, PCIE_GBPS)
            lat += 2.0 * PCIE_LAT_US + QPI_LAT_US
        elif kind == "network":
            add(("pu", src), b, PCIE_GBPS)
            add(("hm", gs[0]), b, HOST_MEM_GBPS)
            add(("no", gs[0]), b, topo.ib)
            add(("ni", gd[0]), b, topo.ib)
            add(("hm", gd[0]), b, HOST_MEM_GBPS)
            add(("pd", dst), b, PCIE_GBPS)
            lat += 2.0 * PCIE_LAT_US + IB_LAT_US
        max_lat = max(max_lat, lat * 1e-6)
    return (max(load.values(), default=0.0), max_lat)


def gpu_reduce_time(b):
    return b / (GPU_REDUCE_GBPS * 1e9)


def gpu_cast_time(b):
    return b / (GPU_CAST_GBPS * 1e9)


def host_reduce_time(b):
    return b / (HOST_REDUCE_GBPS * 1e9)


def pcie_time(b):
    return PCIE_LAT_US * 1e-6 + b / (PCIE_GBPS * 1e9)


# --- strategy pricing (rank 0's CommReport, kernels unbound) ---------------
def rep_zero(name):
    return {
        "strategy": name,
        "wire_bytes": 0.0,
        "wire_raw_bytes": 0.0,
        "sim_transfer": 0.0,
        "sim_latency": 0.0,
        "sim_kernel": 0.0,
        "sim_host_reduce": 0.0,
        "sim_overlapped": 0.0,
        "chunks": 0,
    }


def sim_total(rep):
    return (
        rep["sim_transfer"]
        + rep["sim_kernel"]
        + rep["sim_host_reduce"]
        - rep["sim_overlapped"]
    )


def scale_times(rep, s):
    for key in ("sim_transfer", "sim_latency", "sim_kernel", "sim_host_reduce",
                "sim_overlapped", "wire_bytes", "wire_raw_bytes"):
        rep[key] = rep.get(key, 0.0) * s
    return rep


def price_asa(topo, k, n, half=False, cuda_aware=True):
    """collectives::asa::asa_exchange, rank 0's report (kernels=None)."""
    rep = rep_zero("asa16" if half else "asa")
    if k == 1:
        return rep
    parts = split_even(n, k)
    eb = 2 if half else 4
    rank = 0
    # phase 1: alltoall
    for j in range(k):
        if j == rank:
            continue
        if half:
            rep["sim_kernel"] += gpu_cast_time(4 * parts[j][1])  # pack seg j
        rep["wire_bytes"] += eb * parts[j][1]
    for j in range(k):
        if j == rank:
            continue
        if half:
            rep["sim_kernel"] += gpu_cast_time(2 * parts[rank][1])  # unpack
    transfers = [
        (s, d, eb * parts[d][1]) for s in range(k) for d in range(k) if s != d
    ]
    bw, lat = phase_cost(topo, transfers, cuda_aware)
    rep["sim_transfer"] += bw + lat
    rep["sim_latency"] += lat
    # sum on the "GPU" at the largest segment
    max_len = max(p[1] for p in parts)
    rep["sim_kernel"] += gpu_reduce_time(4 * k * max_len)
    # phase 2: allgather
    my_len = parts[rank][1]
    for j in range(k):
        if j == rank:
            continue
        if half:
            rep["sim_kernel"] += gpu_cast_time(4 * my_len)  # pack reduced
        rep["wire_bytes"] += eb * my_len
    for j in range(k):
        if j == rank:
            continue
        if half:
            rep["sim_kernel"] += gpu_cast_time(2 * parts[j][1])  # unpack
    transfers = [
        (s, d, eb * parts[s][1]) for s in range(k) for d in range(k) if s != d
    ]
    bw, lat = phase_cost(topo, transfers, cuda_aware)
    rep["sim_transfer"] += bw + lat
    rep["sim_latency"] += lat
    return rep


def host_phase(topo, transfers):
    """collectives::allreduce::host_phase: host-resident buffers."""
    nic_out, nic_in, mem, qpi = {}, {}, {}, {}
    lat = 0.0
    for src, dst, b in transfers:
        if src == dst or b == 0:
            continue
        a, d = topo.gpus[src], topo.gpus[dst]
        gb = b / 1e9
        if a[0] != d[0]:
            nic_out[a[0]] = nic_out.get(a[0], 0.0) + gb / topo.ib
            nic_in[d[0]] = nic_in.get(d[0], 0.0) + gb / topo.ib
            mem[a[0]] = mem.get(a[0], 0.0) + gb / HOST_MEM_GBPS
            mem[d[0]] = mem.get(d[0], 0.0) + gb / HOST_MEM_GBPS
            lat = max(lat, IB_LAT_US * 1e-6)
        elif a[1] != d[1]:
            qpi[a[0]] = qpi.get(a[0], 0.0) + gb / QPI_GBPS
            lat = max(lat, QPI_LAT_US * 1e-6)
        else:
            mem[a[0]] = mem.get(a[0], 0.0) + gb / HOST_MEM_GBPS
    mx = lambda d: max(d.values(), default=0.0)  # noqa: E731
    return (max(mx(nic_out), mx(nic_in), mx(mem), mx(qpi)), lat)


def price_ar(topo, k, n, cuda_aware=True):
    """collectives::allreduce (power-of-two k only — the bench sweeps)."""
    assert k & (k - 1) == 0, "port covers power-of-two worlds"
    rep = rep_zero("ar")
    if k == 1:
        return rep
    bytes_ = 4 * n
    rep["sim_transfer"] += pcie_time(bytes_)
    rep["sim_latency"] += PCIE_LAT_US * 1e-6
    dist = 1
    while dist < k:
        transfers = [(r, r ^ dist, bytes_) for r in range(k)]
        bw, lat = host_phase(topo, transfers)
        rep["sim_transfer"] += bw + lat
        rep["sim_latency"] += lat
        rep["sim_host_reduce"] += host_reduce_time(bytes_)
        rep["wire_bytes"] += bytes_
        dist <<= 1
    rep["sim_transfer"] += pcie_time(bytes_)
    rep["sim_latency"] += PCIE_LAT_US * 1e-6
    return rep


def price_ring(topo, k, n, cuda_aware=True):
    """collectives::ring (kernels unbound: no GPU kernel charge)."""
    rep = rep_zero("ring")
    if k == 1:
        return rep
    parts = split_even(n, k)
    for phase_seg in (lambda r, step: (r + k - step) % k,
                      lambda r, step: (r + 1 + k - step) % k):
        for step in range(k - 1):
            transfers = [
                (r, (r + 1) % k, 4 * parts[phase_seg(r, step)][1]) for r in range(k)
            ]
            bw, lat = phase_cost(topo, transfers, cuda_aware)
            rep["sim_transfer"] += bw + lat
            rep["sim_latency"] += lat
    # rank 0 sends one segment per step in both phases
    rank = 0
    send = 0.0
    for step in range(k - 1):
        send += 4 * parts[(rank + k - step) % k][1]
        send += 4 * parts[(rank + 1 + k - step) % k][1]
    rep["wire_bytes"] += send
    return rep


PRICERS = {"ar": price_ar, "asa": price_asa, "asa16": lambda t, k, n, cuda_aware=True: price_asa(t, k, n, half=True, cuda_aware=cuda_aware), "ring": price_ring}


# --- simnet::pipeline_time + chunked pipeline ------------------------------
def pipeline_time(stages):
    wire_free = 0.0
    kernel_free = 0.0
    for i, (transfer, latency, kernel) in enumerate(stages):
        t = transfer if i == 0 else max(transfer - latency, 0.0)
        wire_free += t
        kernel_free = max(kernel_free, wire_free) + kernel
    return max(kernel_free, wire_free)


def price_chunked(strategy, topo, k, n, chunks, pipeline=True, cuda_aware=True):
    """collectives::chunked::ChunkedPipeline over a flat inner strategy."""
    chunk_elems = -(-n // chunks) if chunks > 1 else 0
    if k <= 1 or chunk_elems == 0 or n <= chunk_elems:
        rep = PRICERS[strategy](topo, k, n, cuda_aware=cuda_aware)
        rep["chunks"] = 1
        return rep
    m = -(-n // chunk_elems)
    parts = split_even(n, k)
    slices = [split_even(ln, m) for (_, ln) in parts]
    rep = rep_zero(f"chunked({strategy})")
    stages = []
    for c in range(m):
        chunk_len = sum(slices[r][c][1] for r in range(k))
        if chunk_len == 0:
            continue
        sub = PRICERS[strategy](topo, k, chunk_len, cuda_aware=cuda_aware)
        for key in ("wire_bytes", "sim_transfer", "sim_latency", "sim_kernel",
                    "sim_host_reduce", "sim_overlapped"):
            rep[key] += sub[key]
        rep["chunks"] += 1
        stages.append((sub["sim_transfer"], sub["sim_latency"],
                       sub["sim_kernel"] + sub["sim_host_reduce"]))
    if pipeline:
        serial = sum(t + kern for (t, _, kern) in stages)
        rep["sim_overlapped"] = max(serial - pipeline_time(stages), 0.0)
    return rep


def probe_exchange(strategy, k, topo, full_elems, chunks=0, pipeline=False,
                   cuda_aware=True):
    """coordinator::probe_exchange: capped probe, linear time scaling."""
    probe = max(min(PROBE_CAP, full_elems), 1)
    scale = full_elems / probe
    rep = price_chunked(strategy, topo, k, probe, chunks, pipeline, cuda_aware)
    return scale_times(rep, scale)


# --- wait-free backprop ----------------------------------------------------
def is_fc(name):
    low = name.lower()
    return "fc" in low or "classifier" in low or "dense" in low


def backward_weight(name, params):
    return params if is_fc(name) else params * CONV_COMPUTE_REUSE


def release_fractions(table):
    total = sum(backward_weight(n, p) for n, p in table)
    if total <= 0.0:
        return [1.0] * len(table)
    out = [0.0] * len(table)
    cum = 0.0
    for i in range(len(table) - 1, -1, -1):
        cum += backward_weight(*table[i])
        out[i] = cum / total
    out[0] = 1.0
    return out


def plan_from_layers(table, bucket_elems=0):
    """collectives::wfbp::WfbpPlan::from_layers -> [(off, len, release)]."""
    total = sum(p for _, p in table)
    if not table or total == 0:
        return [], total
    rel = release_fractions(table)
    offs, off = [], 0
    for _, p in table:
        offs.append(off)
        off += p
    buckets, acc, hi_end = [], 0, total
    for i in range(len(table) - 1, -1, -1):
        acc += table[i][1]
        if (acc >= max(bucket_elems, 1) or i == 0) and acc > 0:
            buckets.append((offs[i], hi_end - offs[i], rel[i]))
            hi_end = offs[i]
            acc = 0
    return buckets, total


def project_plan(buckets, total, n):
    if total == 0 or total == n:
        return buckets
    scale = lambda x: (x * n + total // 2) // total  # noqa: E731
    return [
        (scale(o), scale(o + ln) - scale(o), r) for (o, ln, r) in buckets
    ]


def wfbp_timeline(jobs):
    """simnet::wfbp_timeline for single-wire jobs:
    jobs = [(release, transfer, latency, kernel)] in release order."""
    machine_free = None
    seen = False
    kernel_free = 0.0
    last_release = 0.0
    for release, transfer, latency, kernel in jobs:
        last_release = max(last_release, release)
        prev_done = release
        free = machine_free if machine_free is not None else 0.0
        start = max(free, prev_done)
        if not seen or start > free:
            t = transfer
        else:
            t = max(transfer - latency, 0.0)
        seen = True
        prev_done = start + t
        machine_free = prev_done
        kernel_free = max(kernel_free, prev_done) + kernel
    floor = max(kernel_free, last_release)
    return max(floor, machine_free or 0.0)


def probe_wfbp(strategy, k, topo, table, backward, overlap, bucket_elems=0,
               cuda_aware=True):
    """coordinator::probe_wfbp -> dict mirroring WfbpOutcome."""
    full = sum(p for _, p in table)
    probe = max(min(PROBE_CAP, full), 1)
    comm_scale = max(full, 1) / probe
    buckets, total = plan_from_layers(table, bucket_elems)
    buckets = project_plan(buckets, total, probe)
    serial = 0.0
    jobs = []
    n_buckets = 0
    agg = rep_zero(f"wfbp({strategy})")
    for off, ln, release_frac in buckets:
        if ln == 0:
            continue
        sub = PRICERS[strategy](topo, k, ln, cuda_aware=cuda_aware)
        scale_times(sub, comm_scale)
        serial += sim_total(sub)
        jobs.append((release_frac * backward, sub["sim_transfer"],
                     sub["sim_latency"], sub["sim_kernel"] + sub["sim_host_reduce"]))
        for key in ("wire_bytes", "sim_transfer", "sim_latency", "sim_kernel",
                    "sim_host_reduce", "sim_overlapped"):
            agg[key] += sub[key]
        n_buckets += 1
    if overlap:
        makespan = wfbp_timeline(jobs)
        visible = max(makespan - backward, 0.0)
    else:
        makespan = backward + serial
        visible = serial
    hidden = max(serial - visible, 0.0)
    agg["sim_overlapped"] += hidden
    return {
        "comm": agg,
        "serial_comm": serial,
        "comm_visible": visible,
        "comm_hidden": hidden,
        "makespan": makespan,
        "overlap_fraction": (hidden / serial) if serial > 0.0 else 0.0,
        "buckets": n_buckets,
    }


# --- models (python/compile/models/registry.py mirror) ----------------------
def _conv(name, kh, kw, in_c, out_c, groups=1):
    return (name, kh * kw * (in_c // groups) * out_c + out_c)


def _fc(name, n_in, n_out):
    return (name, n_in * n_out + n_out)


def alexnet_layers():
    return [
        _conv("conv1", 11, 11, 3, 96),
        _conv("conv2", 5, 5, 96, 256, groups=2),
        _conv("conv3", 3, 3, 256, 384),
        _conv("conv4", 3, 3, 384, 384, groups=2),
        _conv("conv5", 3, 3, 384, 256, groups=2),
        _fc("fc6", 9216, 4096),
        _fc("fc7", 4096, 4096),
        _fc("fc8", 4096, 1000),
    ]


def _inception(name, in_c, c1, c3r, c3, c5r, c5, cp):
    return [
        _conv(f"{name}/1x1", 1, 1, in_c, c1),
        _conv(f"{name}/3x3_reduce", 1, 1, in_c, c3r),
        _conv(f"{name}/3x3", 3, 3, c3r, c3),
        _conv(f"{name}/5x5_reduce", 1, 1, in_c, c5r),
        _conv(f"{name}/5x5", 5, 5, c5r, c5),
        _conv(f"{name}/pool_proj", 1, 1, in_c, cp),
    ]


def _aux(name, in_c):
    return [
        _conv(f"{name}/conv", 1, 1, in_c, 128),
        _fc(f"{name}/fc", 128 * 4 * 4, 1024),
        _fc(f"{name}/classifier", 1024, 1000),
    ]


def googlenet_layers():
    layers = [
        _conv("conv1/7x7_s2", 7, 7, 3, 64),
        _conv("conv2/3x3_reduce", 1, 1, 64, 64),
        _conv("conv2/3x3", 3, 3, 64, 192),
    ]
    layers += _inception("inception_3a", 192, 64, 96, 128, 16, 32, 32)
    layers += _inception("inception_3b", 256, 128, 128, 192, 32, 96, 64)
    layers += _inception("inception_4a", 480, 192, 96, 208, 16, 48, 64)
    layers += _aux("loss1", 512)
    layers += _inception("inception_4b", 512, 160, 112, 224, 24, 64, 64)
    layers += _inception("inception_4c", 512, 128, 128, 256, 24, 64, 64)
    layers += _inception("inception_4d", 512, 112, 144, 288, 32, 64, 64)
    layers += _aux("loss2", 528)
    layers += _inception("inception_4e", 528, 256, 160, 320, 32, 128, 128)
    layers += _inception("inception_5a", 832, 256, 160, 320, 32, 128, 128)
    layers += _inception("inception_5b", 832, 384, 192, 384, 48, 128, 128)
    layers.append(_fc("loss3/classifier", 1024, 1000))
    return layers


def vggnet_layers():
    cfg = [(3, 64), (64, 64), (64, 128), (128, 128), (128, 256), (256, 256),
           (256, 256), (256, 512), (512, 512), (512, 512), (512, 512),
           (512, 512), (512, 512)]
    layers = [_conv(f"conv{i + 1}", 3, 3, i_c, o_c) for i, (i_c, o_c) in enumerate(cfg)]
    layers += [_fc("fc6", 25088, 4096), _fc("fc7", 4096, 4096), _fc("fc8", 4096, 1000)]
    return layers


TABLES = {
    "alexnet": alexnet_layers(),
    "googlenet": googlenet_layers(),
    "vggnet": vggnet_layers(),
}
PAPER_COUNTS = {"alexnet": 60_965_224, "googlenet": 13_378_280, "vggnet": 138_357_544}
PAPER_TOPO = {"alexnet": "mosaic", "googlenet": "mosaic", "vggnet": "copper"}
PAPER_TRAIN_5120 = {("alexnet", 128): 31.2, ("alexnet", 32): 36.40,
                    ("googlenet", 32): 134.9, ("vggnet", 32): 405.2}


def paper_backward(model, batch):
    return PAPER_TRAIN_5120[(model, batch)] * batch / 5120.0 * BWD_FRACTION


def uniform_split(params, depth):
    return [(f"layer{i}", ln) for i, (_, ln) in enumerate(split_even(params, depth))]


# --- the bench metric set ---------------------------------------------------
def collect_metrics():
    """Recompute every deterministic metric the smoke benches emit,
    asserting the bench bands along the way. Returns (metrics, failures)."""
    metrics = {}
    failures = []

    def put(name, value, better):
        metrics[name] = {"value": value, "better": better}

    def check(cond, msg):
        if not cond:
            failures.append(msg)

    for name, want in PAPER_COUNTS.items():
        check(sum(p for _, p in TABLES[name]) == want,
              f"{name}: layer table sums to {sum(p for _, p in TABLES[name])}, want {want}")

    # comm_sim: Fig 3 / Table 3 backbone
    for model in ("alexnet", "googlenet", "vggnet"):
        n = PAPER_COUNTS[model]
        topo = by_name(PAPER_TOPO[model], 8)
        totals = {}
        for strat in ("ar", "asa", "asa16", "ring"):
            rep = probe_exchange(strat, 8, topo, n)
            totals[strat] = sim_total(rep)
            put(f"comm_sim/{model}/{strat}", sim_total(rep), "lower")
        check(totals["asa"] < totals["ar"], f"{model}: ASA must beat AR")
        check(totals["asa16"] < totals["asa"], f"{model}: ASA16 must beat ASA")

    # worker scaling + CUDA ablation (alexnet)
    n_alex = PAPER_COUNTS["alexnet"]
    for k in (2, 4, 8):
        rep = probe_exchange("asa", k, mosaic(k), n_alex)
        put(f"comm_sim/alexnet/asa_k{k}", sim_total(rep), "lower")
    for aware in (True, False):
        rep = probe_exchange("asa", 8, copper(1), n_alex, cuda_aware=aware)
        put(f"comm_sim/alexnet/asa_cuda_aware_{str(aware).lower()}",
            sim_total(rep), "lower")
    check(metrics["comm_sim/alexnet/asa_cuda_aware_true"]["value"]
          < metrics["comm_sim/alexnet/asa_cuda_aware_false"]["value"],
          "cuda-aware must beat host-staged")

    # chunked overlap (smoke subset: alexnet / asa / m8)
    mono = probe_exchange("asa", 8, copper(1), n_alex)
    piped = probe_exchange("asa", 8, copper(1), n_alex, chunks=8, pipeline=True)
    serial = probe_exchange("asa", 8, copper(1), n_alex, chunks=8, pipeline=False)
    put("overlap/alexnet/asa/m8/win", sim_total(mono) - sim_total(piped), "higher")
    put("overlap/alexnet/asa/m8/eff_gbps",
        piped["wire_bytes"] / sim_total(piped) / 1e9, "higher")
    put("overlap/alexnet/asa/m8/mono_vs_piped",
        sim_total(mono) / sim_total(piped), "higher")
    check(sim_total(piped) < sim_total(mono), "alexnet/asa/m8: piped !< mono")
    check(sim_total(serial) >= sim_total(mono) - 1e-12,
          "alexnet/asa/m8: serial chunking must not beat monolithic")

    # full-bench overlap matrix (not in smoke JSON, but the asserts must hold)
    for model in ("googlenet", "alexnet", "vggnet"):
        n = PAPER_COUNTS[model]
        for strat in ("ar", "asa", "asa16", "ring"):
            m0 = probe_exchange(strat, 8, copper(1), n)
            for chunks in (8, 32):
                p = probe_exchange(strat, 8, copper(1), n, chunks=chunks, pipeline=True)
                s = probe_exchange(strat, 8, copper(1), n, chunks=chunks, pipeline=False)
                if strat == "ring":
                    check(sim_total(p) <= sim_total(m0) + 1e-12,
                          f"{model}/ring/m{chunks}: piped > mono")
                else:
                    check(sim_total(p) < sim_total(m0),
                          f"{model}/{strat}/m{chunks}: piped !< mono")
                check(sim_total(s) >= sim_total(m0) - 1e-12,
                      f"{model}/{strat}/m{chunks}: serial beats mono")

    # WFBP sweep
    for model, batch in (("alexnet", 128), ("vggnet", 32)):
        table = TABLES[model]
        backward = paper_backward(model, batch)
        for topo_name in ("copper", "mosaic"):
            for k in (4, 8):
                topo = by_name(topo_name, k)
                post = probe_wfbp("asa", k, topo, table, backward, overlap=False)
                wf = probe_wfbp("asa", k, topo, table, backward, overlap=True)
                tag = f"wfbp/{model}/{topo_name}/k{k}"
                put(f"{tag}/post_comm", post["comm_visible"], "lower")
                put(f"{tag}/wfbp_comm", wf["comm_visible"], "lower")
                put(f"{tag}/overlap_fraction", wf["overlap_fraction"], "higher")
                check(wf["comm_visible"] < post["comm_visible"],
                      f"{tag}: wfbp {wf['comm_visible']} !< post {post['comm_visible']}")
                m0 = probe_exchange("asa", k, topo, sum(p for _, p in table))
                check(wf["comm_visible"] < sim_total(m0),
                      f"{tag}: wfbp !< monolithic {sim_total(m0)}")
                check(0.0 < wf["overlap_fraction"] <= 1.0,
                      f"{tag}: overlap_fraction {wf['overlap_fraction']}")
                check(backward <= wf["makespan"] < backward + post["serial_comm"],
                      f"{tag}: makespan {wf['makespan']} out of band")
                check(abs(sim_total(wf["comm"]) - wf["comm_visible"]) < 1e-9,
                      f"{tag}: report total != visible")

    # depth-skew ablation
    alex = TABLES["alexnet"]
    backward = paper_backward("alexnet", 128)
    fc_heavy = probe_wfbp("asa", 8, copper(1), alex, backward, overlap=True)
    uni = probe_wfbp("asa", 8, copper(1),
                     uniform_split(sum(p for _, p in alex), len(alex)),
                     backward, overlap=True)
    goog = probe_wfbp("asa", 8, copper(1), TABLES["googlenet"],
                      paper_backward("googlenet", 32), overlap=True)
    put("wfbp/skew/alexnet_overlap_fraction", fc_heavy["overlap_fraction"], "higher")
    put("wfbp/skew/uniform_overlap_fraction", uni["overlap_fraction"], "higher")
    put("wfbp/skew/googlenet_overlap_fraction", goog["overlap_fraction"], "higher")
    check(fc_heavy["overlap_fraction"] > uni["overlap_fraction"],
          f"skew: fc-heavy {fc_heavy['overlap_fraction']} !> uniform {uni['overlap_fraction']}")

    # single-bucket degeneracy: wfbp == post == monolithic price
    one_bucket = probe_wfbp("asa", 8, copper(1), alex, backward, overlap=True,
                            bucket_elems=1 << 60)
    mono = probe_exchange("asa", 8, copper(1), sum(p for _, p in alex))
    check(one_bucket["buckets"] == 1, "single-bucket plan must have 1 bucket")
    check(abs(one_bucket["comm_visible"] - sim_total(mono)) < 1e-9,
          f"single bucket: {one_bucket['comm_visible']} != mono {sim_total(mono)}")
    check(one_bucket["comm_hidden"] < 1e-12, "single bucket hides nothing")

    return metrics, failures


def easgd_metrics():
    """Scenario C of verify_easgd_bands == bench_easgd's sharded sweep."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import verify_easgd_bands as eb

    metrics = {}
    runs = {}
    for s in (1, 2, 4):
        r = eb.simulate("copper", "mpi", k=8, servers=s, elems=1_000_000,
                        rounds=4, compute_s=2e-3)
        runs[s] = r
        metrics[f"easgd/sharded/comm_total/S{s}"] = {
            "value": r["comm_total"], "better": "lower"}
        metrics[f"easgd/sharded/queue_p95/S{s}"] = {
            "value": r["wait_p95"], "better": "lower"}
        metrics[f"easgd/sharded/shard_busy/S{s}"] = {
            "value": sum(r["busy_frac"]) / len(r["busy_frac"]), "better": "higher"}
    metrics["easgd/sharded/comm_speedup_S4_vs_S1"] = {
        "value": runs[1]["comm_total"] / runs[4]["comm_total"], "better": "higher"}
    metrics["easgd/sharded/queue_p95_drop_S4_vs_S1"] = {
        "value": runs[1]["wait_p95"] / runs[4]["wait_p95"], "better": "higher"}
    ok = runs[4]["comm_total"] < runs[1]["comm_total"] and \
        runs[4]["wait_p95"] < 0.5 * runs[1]["wait_p95"]
    return metrics, ([] if ok else ["easgd: S=4 must beat S=1 with p95 collapsing"])


def write_baselines(coll, easgd, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    note = ("generated by scripts/verify_wfbp_bands.py --write-baselines; "
            "values mirror the kernel-free (runtime-less) bench probes")
    for name, metrics in (("BENCH_collectives.json", coll), ("BENCH_easgd.json", easgd)):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            json.dump({"note": note, "metrics": metrics}, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path} ({len(metrics)} metrics)")


def main_with_args(write_baselines_flag=False, baseline_dir=None):
    if baseline_dir is None:
        baseline_dir = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "..", "bench", "baselines")

    coll, failures = collect_metrics()
    # the wire-family sweep lives in its own port; merged here so
    # BENCH_collectives.json carries one consistent metric set (lazy
    # import: verify_wire_bands imports this module at top level)
    import verify_wire_bands
    wire, wfail = verify_wire_bands.collect_wire_metrics()
    coll.update(wire)
    failures += wfail
    easgd, efail = easgd_metrics()
    failures += efail

    width = max(len(k) for k in coll)
    for name in sorted(coll):
        print(f"{name:{width}s} {coll[name]['value']!r}")
    for name in sorted(easgd):
        print(f"{name:{width}s} {easgd[name]['value']!r}")

    if write_baselines_flag:
        write_baselines(coll, easgd, baseline_dir)

    print(f"\n{len(coll) + len(easgd)} metrics;", "bands OK" if not failures else "bands FAILED")
    for f in failures:
        print(" FAIL", f)
    return 0 if not failures else 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write-baselines", action="store_true",
                    help="regenerate bench/baselines/*.json from this model")
    ap.add_argument("--baseline-dir", default=None)
    args = ap.parse_args()
    return main_with_args(args.write_baselines, args.baseline_dir)


if __name__ == "__main__":
    sys.exit(main())
