#!/usr/bin/env python3
"""Dimensional-safety lint: the textual rules the `units::` newtypes can't enforce.

`rust/src/units/` makes mixing seconds with microseconds or bytes with
element counts a *compile* error, but three bug families still live outside
the type system's reach:

  CAST-TRUNC   a float -> integer `as` cast outside `units/`. Rust's `as`
               silently truncates toward zero; every deliberate conversion
               goes through a checked door (`Bytes::scale_round`,
               `Kib::elems`) or carries an explicit `.round()`/`.ceil()`/
               `.floor()` plus a justified waiver here.
  MAP-ITER     a `HashMap`/`HashSet` mention in `rust/src` or `rust/benches`.
               Hash iteration order is seeded per process; anything feeding
               reports, JSON, or the priced clock must use BTreeMap/BTreeSet
               (or sorted iteration). Keyed-only maps that are never
               iterated carry waivers saying so.
  RAW-UNIT     a new `pub` struct field with a unit suffix (`_s`, `_us`,
               `_bytes`, `_kib`, `_gbps`, `_elems`, `_secs`) declared as a
               raw numeric type outside `units/`. New quantities take a
               newtype; the pre-existing config knobs and wire-codec
               counters are waived where they stand.

Scope: `rust/src/**/*.rs` and `rust/benches/**/*.rs` (unit tests included).
`rust/src/units/` owns all three rules — the doors live there.

Waivers: `scripts/lint_units_waivers.txt`, one per line:

    RULE-ID<space>path-substring<space or tab># justification (required)

A finding whose rule and path match a waiver is suppressed. Waivers that
matched nothing are reported as STALE (warning; remove them). Exit status
is 1 iff any unwaived finding remains, 2 on a malformed waiver file.

Stdlib only; run from the repo root: `python3 scripts/lint_units.py`.
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = (
    os.path.join(REPO, "rust", "src"),
    os.path.join(REPO, "rust", "benches"),
)
WAIVER_FILE = os.path.join(REPO, "scripts", "lint_units_waivers.txt")

# units/ owns every rule: the checked doors themselves live there
OWNERS = {
    "CAST-TRUNC": ("rust/src/units/",),
    "MAP-ITER": ("rust/src/units/",),
    "RAW-UNIT": ("rust/src/units/",),
}

INT_TYPES = r"(?:u8|u16|u32|u64|u128|usize|i8|i16|i32|i64|i128|isize)"
RE_AS_INT = re.compile(r"\bas\s+(%s)\b" % INT_TYPES)
# float evidence inside the cast operand: literals, f32/f64 mentions,
# float-producing method tails
RE_FLOAT_MARK = re.compile(
    r"\d\.\d|\de[+-]?\d|\bf32\b|\bf64\b|\.floor\(\)|\.ceil\(\)|\.round\(\)"
    r"|\.sqrt\(\)|\.fract\(\)|\.as_f64\(\)|\.to_secs\(\)"
)
RE_MAP = re.compile(r"\b(HashMap|HashSet)\b")
UNIT_SUFFIXES = ("_s", "_us", "_secs", "_bytes", "_kib", "_gbps", "_elems")
RE_RAW_FIELD = re.compile(
    r"\bpub\s+([a-z_]\w*)\s*:\s*(?:Option<\s*)?(f32|f64|%s)\b" % INT_TYPES
)

RE_LINE_COMMENT = re.compile(r"//.*$")
RE_STRING = re.compile(r'"(?:[^"\\]|\\.)*"')
RE_CHAR = re.compile(r"'(?:[^'\\]|\\.)'")


def strip_noise(lines):
    """Blank out string/char literals and // and /* */ comments, keeping
    line numbers stable (same coarse pass as lint_charges.py)."""
    out = []
    in_block = False
    for line in lines:
        if in_block:
            end = line.find("*/")
            if end < 0:
                out.append("")
                continue
            line = " " * (end + 2) + line[end + 2 :]
            in_block = False
        line = RE_STRING.sub('""', line)
        line = RE_CHAR.sub("' '", line)
        line = RE_LINE_COMMENT.sub("", line)
        while True:
            start = line.find("/*")
            if start < 0:
                break
            end = line.find("*/", start + 2)
            if end < 0:
                line = line[:start]
                in_block = True
                break
            line = line[:start] + " " * (end + 2 - start) + line[end + 2 :]
        out.append(line)
    return out


def cast_operand(line, cast_start):
    """The expression text a trailing `as <int>` applies to: a balanced
    parenthesized group, or the chain of ident/field/index tokens, scanned
    backward from the cast keyword."""
    j = cast_start
    while j > 0 and line[j - 1].isspace():
        j -= 1
    if j == 0:
        return ""
    if line[j - 1] in ")]":
        close, open_ = line[j - 1], "(" if line[j - 1] == ")" else "["
        depth = 0
        k = j - 1
        while k >= 0:
            if line[k] == close:
                depth += 1
            elif line[k] == open_:
                depth -= 1
                if depth == 0:
                    break
            k -= 1
        start = max(k, 0)
        # include a leading method/ident chain: `x.clamp(...)`, `v[0]`
        while start > 0 and (line[start - 1].isalnum() or line[start - 1] in "_."):
            start -= 1
        return line[start:j]
    start = j
    while start > 0 and (line[start - 1].isalnum() or line[start - 1] in "_."):
        start -= 1
    return line[start:j]


def lint_file(relpath, raw_lines):
    findings = []
    lines = strip_noise(raw_lines)

    def hit(rule, lineno, msg):
        findings.append((rule, relpath, lineno, msg))

    for i, line in enumerate(lines, start=1):
        for m in RE_AS_INT.finditer(line):
            operand = cast_operand(line, m.start())
            if RE_FLOAT_MARK.search(operand):
                hit(
                    "CAST-TRUNC",
                    i,
                    f"float -> {m.group(1)} `as` cast (`{operand.strip()} as "
                    f"{m.group(1)}`) — use a units:: door or waive with the "
                    f"rounding rationale",
                )
        m = RE_MAP.search(line)
        if m:
            hit(
                "MAP-ITER",
                i,
                f"`{m.group(1)}` — hash iteration order is nondeterministic; "
                f"use BTreeMap/BTreeSet or waive a keyed-only map",
            )
        m = RE_RAW_FIELD.search(line)
        if m and any(
            m.group(1).endswith(suf) and len(m.group(1)) > len(suf)
            for suf in UNIT_SUFFIXES
        ):
            hit(
                "RAW-UNIT",
                i,
                f"raw unit-suffixed field `{m.group(1)}: {m.group(2)}` — "
                f"new quantities take a units:: newtype",
            )

    return [
        f
        for f in findings
        if not any(owner in relpath for owner in OWNERS.get(f[0], ()))
    ]


def load_waivers():
    waivers = []
    if not os.path.exists(WAIVER_FILE):
        return waivers
    with open(WAIVER_FILE, encoding="utf-8") as fh:
        for n, raw in enumerate(fh, start=1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if "#" not in line:
                print(
                    f"lint_units: {WAIVER_FILE}:{n}: waiver without a "
                    f"`# justification` comment — refusing it",
                    file=sys.stderr,
                )
                sys.exit(2)
            body = line.split("#", 1)[0].split()
            if len(body) != 2:
                print(
                    f"lint_units: {WAIVER_FILE}:{n}: expected "
                    f"`RULE path # why`, got: {line}",
                    file=sys.stderr,
                )
                sys.exit(2)
            waivers.append({"rule": body[0], "path": body[1], "line": n, "used": False})
    return waivers


def collect_findings():
    all_findings = []
    for scan in SCAN_DIRS:
        for root, _dirs, files in os.walk(scan):
            for name in sorted(files):
                if not name.endswith(".rs"):
                    continue
                path = os.path.join(root, name)
                rel = os.path.relpath(path, REPO).replace(os.sep, "/")
                with open(path, encoding="utf-8") as fh:
                    all_findings.extend(lint_file(rel, fh.read().splitlines()))
    return all_findings


def main():
    all_findings = collect_findings()
    waivers = load_waivers()
    unwaived = []
    for rule, rel, lineno, msg in all_findings:
        waived = False
        for w in waivers:
            if w["rule"] == rule and w["path"] in rel:
                w["used"] = True
                waived = True
                break
        if not waived:
            unwaived.append((rule, rel, lineno, msg))

    for rule, rel, lineno, msg in unwaived:
        print(f"{rel}:{lineno}: [{rule}] {msg}")

    stale = [w for w in waivers if not w["used"]]
    for w in stale:
        print(
            f"lint_units: WARNING: stale waiver "
            f"({WAIVER_FILE}:{w['line']}: {w['rule']} {w['path']}) matched nothing — remove it",
            file=sys.stderr,
        )

    if unwaived:
        print(
            f"lint_units: {len(unwaived)} finding(s) — go through units:: "
            f"or add a justified waiver to scripts/lint_units_waivers.txt",
            file=sys.stderr,
        )
        return 1
    suffix = f", {len(stale)} stale waiver(s)" if stale else ""
    print(
        f"lint_units: clean ({len(all_findings) - len(unwaived)} waived finding(s){suffix})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
