#!/usr/bin/env python3
"""Unit tests for scripts/lint_units.py (stdlib only).

Run from the repo root:
    python3 -m unittest discover -s scripts -p "test_*.py"
"""

import os
import sys
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import lint_units  # noqa: E402


def lint(src, path="rust/src/somewhere/mod.rs"):
    return [f[0] for f in lint_units.lint_file(path, src.splitlines())]


class CastTrunc(unittest.TestCase):
    def test_float_literal_cast_flagged(self):
        self.assertEqual(lint("let b = (x * 255.0) as u8;"), ["CAST-TRUNC"])
        self.assertEqual(lint("let n = (p * 1e9) as u64;"), ["CAST-TRUNC"])

    def test_rounded_float_cast_still_flagged(self):
        # explicit rounding is float evidence too: the waiver records the
        # rounding rationale, the lint does not silently bless it
        self.assertEqual(lint("let i = (x / y).round() as usize;"), ["CAST-TRUNC"])
        self.assertEqual(lint("let k = (p * n as f64).ceil() as usize;"), ["CAST-TRUNC"])
        self.assertEqual(lint("let k = frac.floor() as u64;"), ["CAST-TRUNC"])

    def test_integer_casts_pass(self):
        self.assertEqual(lint("let b = bytes as usize;"), [])
        self.assertEqual(lint("let t = step as u64;"), [])
        self.assertEqual(lint("let w = (v[0] as u32 as u64) << 32;"), [])
        # int -> float is widening, not truncation
        self.assertEqual(lint("let f = n as f64;"), [])

    def test_operand_binding_not_line_binding(self):
        # a float elsewhere on the line must not taint an integer cast
        self.assertEqual(lint("comm.send(next, TAG + step as u64, p, 0.0)?;"), [])
        # ...but the cast's own parenthesized operand is inspected
        self.assertEqual(lint("f((a * 2.5) as usize, 7);"), ["CAST-TRUNC"])

    def test_units_module_owns_the_rule(self):
        src = "let e = (kib as f64 * 1024.0 / bpe).floor() as usize;"
        self.assertEqual(lint(src, "rust/src/units/mod.rs"), [])
        self.assertEqual(lint(src, "rust/src/bsp/mod.rs"), ["CAST-TRUNC"])

    def test_comments_and_strings_ignored(self):
        self.assertEqual(lint("// (x * 2.0) as usize"), [])
        self.assertEqual(lint('let s = "(x * 2.0) as usize";'), [])


class MapIter(unittest.TestCase):
    def test_hash_containers_flagged(self):
        self.assertEqual(lint("use std::collections::HashMap;"), ["MAP-ITER"])
        self.assertEqual(lint("let mut seen = HashSet::new();"), ["MAP-ITER"])
        self.assertEqual(lint("pending: HashMap<(usize, u64), VecDeque<Msg>>,"), ["MAP-ITER"])

    def test_btree_containers_pass(self):
        self.assertEqual(lint("use std::collections::BTreeMap;"), [])
        self.assertEqual(lint("let mut m = BTreeMap::new();"), [])
        self.assertEqual(lint("waiting: BTreeSet<usize>,"), [])


class RawUnit(unittest.TestCase):
    def test_new_raw_suffixed_field_flagged(self):
        self.assertEqual(lint("    pub stall_s: f64,"), ["RAW-UNIT"])
        self.assertEqual(lint("    pub spill_bytes: u64,"), ["RAW-UNIT"])
        self.assertEqual(lint("    pub link_gbps: f32,"), ["RAW-UNIT"])
        self.assertEqual(lint("    pub hint_bytes: Option<u64>,"), ["RAW-UNIT"])

    def test_typed_fields_pass(self):
        self.assertEqual(lint("    pub load_stall: Secs,"), [])
        self.assertEqual(lint("    pub wire_inter_bytes: Bytes,"), [])
        self.assertEqual(lint("    pub pcie_gbps: GbPerS,"), [])

    def test_unsuffixed_and_private_fields_pass(self):
        self.assertEqual(lint("    pub workers: usize,"), [])
        # private fields are module-internal; the lint polices the API
        self.assertEqual(lint("    total_bytes: u64,"), [])
        # a bare suffix is not a unit-carrying name
        self.assertEqual(lint("    pub _s: f64,"), [])


class RepoIsClean(unittest.TestCase):
    def test_tree_lints_clean_with_committed_waivers(self):
        """The acceptance bar: zero unwaived findings on rust/src + benches."""
        findings = lint_units.collect_findings()
        waivers = lint_units.load_waivers()
        for rule, rel, line, msg in findings:
            matched = any(w["rule"] == rule and w["path"] in rel for w in waivers)
            self.assertTrue(matched, f"unwaived: {rel}:{line} [{rule}] {msg}")

    def test_waiver_count_is_pinned(self):
        """Every waiver is a standing debt; growing the list is a deliberate
        act that must show up in review as an edit to this pin."""
        waivers = lint_units.load_waivers()
        by_rule = {}
        for w in waivers:
            by_rule[w["rule"]] = by_rule.get(w["rule"], 0) + 1
        self.assertEqual(
            by_rule,
            {"CAST-TRUNC": 5, "MAP-ITER": 3, "RAW-UNIT": 5},
            "waiver census moved — fix the code through units:: or update "
            "this pin alongside a justified new waiver",
        )

    def test_no_stale_waivers(self):
        findings = lint_units.collect_findings()
        for w in lint_units.load_waivers():
            used = any(
                w["rule"] == rule and w["path"] in rel for rule, rel, _l, _m in findings
            )
            self.assertTrue(used, f"stale waiver: {w['rule']} {w['path']}")

    def test_waiver_without_justification_rejected(self):
        import tempfile

        with tempfile.NamedTemporaryFile("w", suffix=".txt", delete=False) as f:
            f.write("CAST-TRUNC rust/src/data/mod.rs\n")  # no `# why`
            bad = f.name
        old = lint_units.WAIVER_FILE
        lint_units.WAIVER_FILE = bad
        try:
            with self.assertRaises(SystemExit):
                lint_units.load_waivers()
        finally:
            lint_units.WAIVER_FILE = old
            os.unlink(bad)


if __name__ == "__main__":
    unittest.main()
