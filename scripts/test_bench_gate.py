#!/usr/bin/env python3
"""Unit tests for scripts/bench_gate.py (stdlib only).

Run from the repo root:
    python3 -m unittest discover -s scripts -p "test_*.py"
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_gate  # noqa: E402


class GateHarness(unittest.TestCase):
    def run_gate(self, current, baseline, tolerance=0.10):
        """Write both metric dicts to temp files, run the gate, return
        (ok, printed output)."""
        with tempfile.TemporaryDirectory() as d:
            cur_p = os.path.join(d, "current.json")
            base_p = os.path.join(d, "baseline.json")
            with open(cur_p, "w") as f:
                json.dump({"metrics": current}, f)
            with open(base_p, "w") as f:
                json.dump({"metrics": baseline}, f)
            out = io.StringIO()
            with contextlib.redirect_stdout(out):
                ok = bench_gate.gate(cur_p, base_p, tolerance)
        return ok, out.getvalue()

    @staticmethod
    def m(value, better="lower", unit=None):
        e = {"value": value, "better": better}
        if unit:
            e["unit"] = unit
        return e


class DirectionAware(GateHarness):
    def test_lower_is_better_regression_fails(self):
        ok, out = self.run_gate({"t": self.m(1.2)}, {"t": self.m(1.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("regressed", out)

    def test_lower_is_better_improvement_passes(self):
        ok, _ = self.run_gate({"t": self.m(0.5)}, {"t": self.m(1.0, "lower")})
        self.assertTrue(ok)

    def test_higher_is_better_regression_fails(self):
        ok, out = self.run_gate({"f": self.m(0.5)}, {"f": self.m(1.0, "higher")})
        self.assertFalse(ok)
        self.assertIn("regressed", out)

    def test_higher_is_better_improvement_passes(self):
        ok, _ = self.run_gate({"f": self.m(2.0)}, {"f": self.m(1.0, "higher")})
        self.assertTrue(ok)

    def test_within_tolerance_passes_both_directions(self):
        ok, _ = self.run_gate(
            {"t": self.m(1.05), "f": self.m(0.95)},
            {"t": self.m(1.0, "lower"), "f": self.m(1.0, "higher")},
        )
        self.assertTrue(ok)

    def test_missing_direction_fails_not_crashes(self):
        ok, out = self.run_gate({"t": self.m(1.0)}, {"t": {"value": 1.0}})
        self.assertFalse(ok)
        self.assertIn('"better"', out)

    def test_zero_reference_uses_absolute_epsilon(self):
        ok, _ = self.run_gate({"w": self.m(0.0)}, {"w": self.m(0.0, "lower")})
        self.assertTrue(ok)
        ok, out = self.run_gate({"w": self.m(1e-6)}, {"w": self.m(0.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("regressed", out)


class MissingAndNew(GateHarness):
    def test_baselined_metric_missing_from_current_fails(self):
        ok, out = self.run_gate({}, {"t": self.m(1.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("missing from the current run", out)

    def test_new_metric_reported_but_not_gated(self):
        ok, out = self.run_gate(
            {"t": self.m(1.0), "brand_new": self.m(9.9)}, {"t": self.m(1.0, "lower")}
        )
        self.assertTrue(ok)
        self.assertIn("NEW", out)
        self.assertIn("brand_new", out)

    def test_wall_clock_never_gated(self):
        # a 10x wall-clock "regression" must not fail the gate
        ok, out = self.run_gate(
            {"wall": self.m(10.0, unit="s_wall")},
            {"wall": self.m(1.0, "lower", unit="s_wall")},
        )
        self.assertTrue(ok)
        self.assertNotIn("wall", out.split(":", 2)[2] if out.count(":") >= 2 else out)


class MalformedEntries(GateHarness):
    """A bench-writer bug must be reported against its metric, not crash
    the gate (the pre-hardening gate raised KeyError/TypeError here and
    every other metric's verdict was lost)."""

    def test_current_entry_without_value_key(self):
        ok, out = self.run_gate({"t": {"unit": "s"}}, {"t": self.m(1.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("malformed entry", out)
        self.assertIn('"value"', out)

    def test_current_entry_not_an_object(self):
        ok, out = self.run_gate({"t": 3.14}, {"t": self.m(1.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("malformed entry", out)

    def test_current_null_value_reported(self):
        ok, out = self.run_gate({"t": self.m(None)}, {"t": self.m(1.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("null", out)

    def test_current_non_numeric_value_reported(self):
        ok, out = self.run_gate({"t": self.m("fast")}, {"t": self.m(1.0, "lower")})
        self.assertFalse(ok)
        self.assertIn("non-numeric", out)

    def test_malformed_baseline_entry_reported(self):
        ok, out = self.run_gate({"t": self.m(1.0)}, {"t": "oops"})
        self.assertFalse(ok)
        self.assertIn("baseline", out)
        self.assertIn("malformed entry", out)

    def test_malformed_new_entry_does_not_crash_listing(self):
        ok, out = self.run_gate(
            {"t": self.m(1.0), "weird_new": ["not", "an", "object"]},
            {"t": self.m(1.0, "lower")},
        )
        self.assertTrue(ok)
        self.assertIn("weird_new", out)

    def test_other_metrics_still_gated_alongside_malformed_one(self):
        ok, out = self.run_gate(
            {"bad": {"no_value": 1}, "good": self.m(0.9), "slow": self.m(5.0)},
            {
                "bad": self.m(1.0, "lower"),
                "good": self.m(1.0, "lower"),
                "slow": self.m(1.0, "lower"),
            },
        )
        self.assertFalse(ok)
        self.assertIn("bad", out)
        self.assertIn("slow", out)  # the real regression is still caught
        self.assertIn("2 failing", out)


class StepSummary(GateHarness):
    """The $GITHUB_STEP_SUMMARY markdown table (ISSUE 10 satellite):
    stdout must be unchanged; the summary is an additive side channel."""

    def run_gate_with_summary(self, current, baseline, tolerance=0.10):
        rows = []
        with tempfile.TemporaryDirectory() as d:
            cur_p = os.path.join(d, "current.json")
            base_p = os.path.join(d, "baseline.json")
            with open(cur_p, "w") as f:
                json.dump({"metrics": current}, f)
            with open(base_p, "w") as f:
                json.dump({"metrics": baseline}, f)
            out = io.StringIO()
            with contextlib.redirect_stdout(out):
                ok = bench_gate.gate(cur_p, base_p, tolerance, summary=rows)
        return ok, out.getvalue(), rows

    def test_stdout_identical_with_and_without_summary(self):
        current = {"t": self.m(1.2), "brand_new": self.m(2.0)}
        baseline = {"t": self.m(1.0, "lower"), "gone": self.m(3.0, "lower")}
        _, out_plain = self.run_gate(current, baseline)
        _, out_summary, _ = self.run_gate_with_summary(current, baseline)
        # the temp dir differs per run; everything after the path header must
        # be byte-identical
        strip = lambda s: s.split("baseline.json: ", 1)[1]  # noqa: E731
        self.assertEqual(strip(out_plain), strip(out_summary))

    def test_rows_cover_every_metric_with_status(self):
        ok, _, rows = self.run_gate_with_summary(
            {"t": self.m(1.2), "f": self.m(0.9), "brand_new": self.m(2.0)},
            {"t": self.m(1.0, "lower"), "f": self.m(1.0, "lower"),
             "gone": self.m(3.0, "lower")},
        )
        self.assertFalse(ok)
        by_name = {r["name"]: r["status"] for r in rows}
        self.assertEqual(by_name["t"], "FAIL")
        self.assertEqual(by_name["f"], "OK")
        self.assertEqual(by_name["gone"], "MISSING")
        self.assertEqual(by_name["brand_new"], "NEW")

    def test_markdown_table_has_deltas_and_bolded_failures(self):
        _, _, rows = self.run_gate_with_summary(
            {"t": self.m(1.2), "f": self.m(1.0)},
            {"t": self.m(1.0, "lower"), "f": self.m(1.0, "higher")},
        )
        md = bench_gate.render_step_summary(rows, 0.10, ok=False)
        self.assertIn("## bench-gate: FAILED (budget 10%)", md)
        self.assertIn("| metric | current | baseline | delta | better | status |", md)
        self.assertIn("+20.00%", md)  # t regressed by 20%
        self.assertIn("**FAIL**", md)
        self.assertIn("+0.00%", md)  # f unchanged
        # plain OK rows are not bolded
        self.assertIn("| OK |", md)

    def test_write_step_summary_appends_to_file(self):
        _, _, rows = self.run_gate_with_summary(
            {"t": self.m(0.5)}, {"t": self.m(1.0, "lower")})
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "summary.md")
            with open(path, "w") as f:
                f.write("pre-existing content\n")
            bench_gate.write_step_summary(rows, 0.10, True, path)
            with open(path) as f:
                text = f.read()
        self.assertTrue(text.startswith("pre-existing content\n"))
        self.assertIn("## bench-gate: OK", text)
        self.assertIn("-50.00%", text)

    def test_missing_baseline_value_renders_dash(self):
        _, _, rows = self.run_gate_with_summary(
            {"brand_new": self.m(2.0), "t": self.m(1.0)},
            {"t": self.m(1.0, "lower")})
        md = bench_gate.render_step_summary(rows, 0.10, ok=True)
        self.assertIn("| brand_new | 2 | — | — | — | NEW |", md)


class EntryValueUnit(unittest.TestCase):
    def test_entry_value_accepts_ints_and_floats(self):
        self.assertEqual(bench_gate.entry_value({"value": 3})[0], 3)
        self.assertEqual(bench_gate.entry_value({"value": 3.5})[0], 3.5)

    def test_entry_value_rejects_bool(self):
        v, err = bench_gate.entry_value({"value": True})
        self.assertIsNone(v)
        self.assertIn("non-numeric", err)

    def test_entry_unit_on_malformed_entry(self):
        self.assertIsNone(bench_gate.entry_unit("not a dict"))
        self.assertEqual(bench_gate.entry_unit({"unit": "s_wall"}), "s_wall")


if __name__ == "__main__":
    unittest.main()
