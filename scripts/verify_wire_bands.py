#!/usr/bin/env python3
"""Python port of the gradient-compression wire pricing (collectives::wire).

Stdlib-only mirror of the Rust `WireCodec` repricing: the codec encodes the
send buffer (error feedback is invisible to pricing — byte counts depend
only on n), scales the inner strategy's bandwidth-proportional costs by the
real on-wire byte ratio, keeps per-message latency, and charges the
encode/decode passes as cast kernels (sf excepted: its factors fall out of
the backward pass). Every wire band asserted by the smoke set of
`rust/benches/bench_collectives.rs`'s wire sweep is re-derived here.

    python3 scripts/verify_wire_bands.py                    # verify bands
    python3 scripts/verify_wire_bands.py --write-baselines  # regenerate
        bench/baselines/*.json (delegates to verify_wfbp_bands, which
        merges these wire metrics into BENCH_collectives.json)

The script exits non-zero if any band fails. NOTE: this container carries
no Rust toolchain — this port is the only numeric verification the wire
bands get before the driver's tier-1 runs, so keep it faithful to the Rust
arithmetic (same model, same operation structure; f64 round-off apart).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from pricing_model import (  # noqa: E402
    by_name,
    codec_wire_bytes,
    copper,
    round_half_away,
    topk_count,
)
from verify_wfbp_bands import (  # noqa: E402  (strategy pricers + probe cap)
    PAPER_COUNTS,
    PRICERS,
    PROBE_CAP,
    gpu_cast_time,
    probe_exchange,
    scale_times,
    sim_total,
)

# AlexNet fc6 (in, out) from models::builtin_fc_dims — the sf showcase.
FC6_ALEXNET = (9216, 4096)


def price_wire(strategy, fmt, topo, k, n, sf_bytes=None, cuda_aware=True):
    """collectives::wire::WireCodec::exchange — rank 0's repriced report.

    `fmt` is a CLI wire name ("f32" runs the bare strategy). The bandwidth
    term of every phase is linear in a uniform byte scaling, so the codec
    reprices exactly: transfer = latency + (transfer - latency) * r with
    r = real_wire_bytes / dense_bytes.
    """
    rep = PRICERS[strategy](topo, k, n, cuda_aware=cuda_aware)
    rep.setdefault("wire_raw_bytes", 0.0)
    if fmt == "f32":
        return rep
    wire_b = codec_wire_bytes(fmt, n, sf_bytes)
    r = wire_b / (4.0 * max(n, 1))
    raw = rep["wire_bytes"]
    rep["wire_raw_bytes"] = raw
    rep["wire_bytes"] = float(round_half_away(raw * r))
    rep["sim_transfer"] = rep["sim_latency"] + (rep["sim_transfer"] - rep["sim_latency"]) * r
    if fmt != "sf":
        rep["sim_kernel"] += gpu_cast_time(8 * n)
        rep["sim_kernel"] += gpu_cast_time(4 * n)
    rep["strategy"] = f"{rep['strategy']}/{fmt}"
    return rep


def probe_exchange_wire(strategy, fmt, k, topo, full_elems, sf_bytes=None,
                        cuda_aware=True):
    """coordinator::probe_exchange_wire: capped probe, hint scaled into the
    probe domain, byte fields rounded as the Rust u64 fields are."""
    probe = max(min(PROBE_CAP, full_elems), 1)
    scale = full_elems / probe
    hint = round_half_away(sf_bytes / scale) if sf_bytes is not None else None
    rep = price_wire(strategy, fmt, topo, k, probe, sf_bytes=hint,
                     cuda_aware=cuda_aware)
    scale_times(rep, scale)
    # the Rust byte fields are u64: round after scaling, as scale_times does
    for key in ("wire_bytes", "wire_raw_bytes"):
        rep[key] = float(round_half_away(rep[key]))
    return rep


def compression_ratio(rep):
    """CommReport::compression_ratio: dense-equivalent over real bytes."""
    raw, wire = rep.get("wire_raw_bytes", 0.0), rep["wire_bytes"]
    return raw / wire if raw > 0.0 and wire > 0.0 else 1.0


WIRES = ("f32", "f16", "topk:0.01", "topk:0.5", "onebit")


def collect_wire_metrics():
    """Recompute every wire metric the bench sweep emits, asserting its
    bands along the way. Returns (metrics, failures)."""
    metrics = {}
    failures = []

    def put(name, value, better):
        metrics[name] = {"value": value, "better": better}

    def check(cond, msg):
        if not cond:
            failures.append(msg)

    # codec byte-formula goldens (cross-pinned bitwise by the Rust unit
    # tests and rust/tests/prop_wire.rs)
    check(codec_wire_bytes("topk:0.01", 1000) == 80, "topk:0.01/1000 != 80 B")
    check(codec_wire_bytes("onebit", 1000) == 129, "onebit/1000 != 129 B")
    check(codec_wire_bytes("f16", 1000) == 2000, "f16/1000 != 2000 B")
    check(codec_wire_bytes("sf", 1000, 640) == 640, "sf hint not honoured")
    check(codec_wire_bytes("sf", 1000, 5000) == 4000, "sf must dense-fallback")
    check(topk_count(1001, 0.01) == 11, "topk_count must ceil")

    n_alex = PAPER_COUNTS["alexnet"]
    for fabric in ("copper", "mosaic"):
        topo = by_name(fabric, 8)
        reps = {}
        for w in WIRES:
            rep = probe_exchange_wire("asa", w, 8, topo, n_alex)
            reps[w] = rep
            put(f"wire/{fabric}/{w}/sim", sim_total(rep), "lower")
            put(f"wire/{fabric}/{w}/gib", rep["wire_bytes"] / float(1 << 30), "lower")
        dense = reps["f32"]
        for w in ("topk:0.01", "onebit"):
            check(reps[w]["wire_bytes"] * 10 <= dense["wire_bytes"],
                  f"{fabric}/{w}: bytes not a 10x cut")
            check(compression_ratio(reps[w]) >= 10.0,
                  f"{fabric}/{w}: ratio {compression_ratio(reps[w])} < 10")
            check(sim_total(reps[w]) < sim_total(dense),
                  f"{fabric}/{w}: byte cut must pay ({sim_total(reps[w])} !< "
                  f"{sim_total(dense)})")
        check(sim_total(reps["f16"]) < sim_total(dense),
              f"{fabric}: f16 must beat f32")
        check(reps["topk:0.5"]["wire_bytes"] == dense["wire_bytes"],
              f"{fabric}: topk:0.5 pairs must be dense-width")
        check(sim_total(dense) < sim_total(reps["topk:0.5"]),
              f"{fabric}: dense must beat a no-cut sparsifier")
        if fabric == "copper":
            asa16 = probe_exchange("asa16", 8, topo, n_alex)
            tk01 = reps["topk:0.01"]
            put("wire/copper/topk:0.01_vs_asa16",
                sim_total(asa16) / sim_total(tk01), "higher")
            check(sim_total(tk01) < sim_total(asa16),
                  f"topk:0.01 {sim_total(tk01)} !< asa16 {sim_total(asa16)} "
                  "at k=8 copper")

    # sf on fc6: batch·(in + out) factor bytes instead of the in·out matrix
    din, dout = FC6_ALEXNET
    sf = probe_exchange_wire("asa", "sf", 8, copper(1), din * dout,
                             sf_bytes=4 * 128 * (din + dout))
    put("wire/copper/sf_fc6/ratio", compression_ratio(sf), "higher")
    check(compression_ratio(sf) >= 10.0,
          f"sf fc6 ratio {compression_ratio(sf)} < 10")

    return metrics, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write-baselines", action="store_true",
                    help="regenerate bench/baselines/*.json (delegates to "
                         "verify_wfbp_bands, which merges these metrics)")
    args = ap.parse_args()

    metrics, failures = collect_wire_metrics()
    width = max(len(k) for k in metrics)
    for name in sorted(metrics):
        print(f"{name:{width}s} {metrics[name]['value']!r}")
    print(f"\n{len(metrics)} wire metrics;",
          "bands OK" if not failures else "bands FAILED")
    for f in failures:
        print(" FAIL", f)
    if failures:
        return 1

    if args.write_baselines:
        import verify_wfbp_bands
        return verify_wfbp_bands.main_with_args(write_baselines_flag=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
