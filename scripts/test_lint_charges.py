#!/usr/bin/env python3
"""Unit tests for scripts/lint_charges.py (stdlib only).

Run from the repo root:
    python3 -m unittest discover -s scripts -p "test_*.py"
"""

import os
import sys
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import lint_charges  # noqa: E402


def lint(src, path="rust/src/somewhere/mod.rs"):
    rules = [f[0] for f in lint_charges.lint_file(path, src.splitlines())]
    return rules


class ChargeClock(unittest.TestCase):
    def test_compound_assign_on_clock_flagged(self):
        self.assertEqual(lint("clock += dt;"), ["CHARGE-CLOCK"])
        self.assertEqual(lint("worker_clock -= x;"), ["CHARGE-CLOCK"])
        self.assertEqual(lint("vtime *= 2.0;"), ["CHARGE-CLOCK"])

    def test_self_referential_assign_flagged(self):
        self.assertEqual(lint("clock = clock.max(a) + h;"), ["CHARGE-CLOCK"])
        self.assertEqual(lint("my_clock = my_clock + dt;"), ["CHARGE-CLOCK"])

    def test_plain_rebinding_allowed(self):
        self.assertEqual(lint("let mut new_clock = clock;"), [])
        self.assertEqual(lint("new_clock = done;"), [])

    def test_field_access_clocks_not_flagged(self):
        # aggregation over clocks (mpi barrier bookkeeping, report maxing)
        # is not a clock being spent
        self.assertEqual(lint("st.max_clock = st.max_clock.max(clock);"), [])
        self.assertEqual(lint("probe.vtime = probe.vtime.max(clock);"), [])

    def test_audit_module_owns_the_rule(self):
        self.assertEqual(lint("self.clock += secs;", "rust/src/audit/mod.rs"), [])
        # bare-identifier form is flagged everywhere else
        self.assertEqual(lint("clock += secs;", "rust/src/bsp/mod.rs"), ["CHARGE-CLOCK"])

    def test_comments_and_strings_ignored(self):
        self.assertEqual(lint("// clock += dt;"), [])
        self.assertEqual(lint('let s = "clock += dt";'), [])
        self.assertEqual(lint("/* vtime *= 2.0 */"), [])
        self.assertEqual(lint("/*\nvtime *= 2.0;\n*/"), [])


class ChargeBreakdown(unittest.TestCase):
    def test_breakdown_field_arithmetic_flagged(self):
        self.assertEqual(lint("bd.comm_queue += w;"), ["CHARGE-BD"])
        self.assertEqual(lint("self.breakdown.load_stall += s;"), ["CHARGE-BD"])

    def test_owners_exempt(self):
        self.assertEqual(lint("self.compute += compute;", "rust/src/metrics/mod.rs"), [])
        self.assertEqual(lint("self.bd.comm_hidden += h;", "rust/src/audit/mod.rs"), [])

    def test_non_breakdown_fields_pass(self):
        self.assertEqual(lint("bd.not_a_time_field += x;"), [])


class ChargeCommReport(unittest.TestCase):
    def test_comm_report_time_arithmetic_flagged(self):
        self.assertEqual(lint("rep.sim_transfer += c.total();"), ["CHARGE-CR"])
        self.assertEqual(lint("rep.real_kernel += t;"), ["CHARGE-CR"])
        self.assertEqual(lint("self.sim_overlapped *= s;"), ["CHARGE-CR"])

    def test_report_owner_exempt(self):
        self.assertEqual(
            lint("self.sim_kernel += sim_kernel;", "rust/src/collectives/mod.rs"), []
        )

    def test_waiver_shape_matches_strategy_files(self):
        # the committed waivers cover exactly the strategy impls; this pins
        # that a CHARGE-CR finding in one of them is waivable by path
        rules = lint("rep.sim_transfer += bw;", "rust/src/collectives/ring.rs")
        self.assertEqual(rules, ["CHARGE-CR"])


class UnitSuffixRetired(unittest.TestCase):
    def test_unit_mixing_is_the_type_systems_job_now(self):
        # the regex rule is gone: units:: newtypes make `Bytes + Secs` a
        # compile error, and lint_units.py owns the remaining textual rules
        self.assertEqual(lint("let x = n_bytes + t_s;"), [])
        self.assertNotIn("UNIT-SUFFIX", dir(lint_charges))


class BreakdownLiteral(unittest.TestCase):
    def test_rest_literal_flagged(self):
        self.assertEqual(
            lint("let b = Breakdown { compute, ..Default::default() };"), ["BD-LITERAL"]
        )

    def test_multiline_rest_literal_flagged(self):
        src = "let b = Breakdown {\n    compute: 1.0,\n    ..base\n};"
        self.assertEqual(lint(src), ["BD-LITERAL"])

    def test_destructuring_allowed(self):
        self.assertEqual(lint("let Breakdown { compute, .. } = b;"), [])

    def test_exhaustive_literal_allowed(self):
        src = (
            "let b = Breakdown { compute: c, comm_transfer: t, comm_kernel: k,\n"
            "    comm_queue: q, comm_hidden: h, host_reduce: r, h2d: d,\n"
            "    load_stall: l, apply: a };"
        )
        self.assertEqual(lint(src), [])

    def test_owners_exempt(self):
        src = "let b = Breakdown { compute, ..Default::default() };"
        self.assertEqual(lint(src, "rust/src/metrics/mod.rs"), [])


class RepoIsClean(unittest.TestCase):
    def test_tree_lints_clean_with_committed_waivers(self):
        """The acceptance bar: zero unwaived findings on rust/src, and no
        clock/Breakdown waivers at all."""
        findings = []
        for root, _dirs, files in os.walk(lint_charges.SRC):
            for name in sorted(files):
                if not name.endswith(".rs"):
                    continue
                p = os.path.join(root, name)
                rel = os.path.relpath(p, lint_charges.REPO).replace(os.sep, "/")
                with open(p, encoding="utf-8") as fh:
                    findings.extend(lint_charges.lint_file(rel, fh.read().splitlines()))
        waivers = lint_charges.load_waivers()
        for rule, rel, line, msg in findings:
            matched = any(w["rule"] == rule and w["path"] in rel for w in waivers)
            self.assertTrue(matched, f"unwaived: {rel}:{line} [{rule}] {msg}")
        for w in waivers:
            self.assertEqual(
                w["rule"], "CHARGE-CR",
                "policy: only CommReport-producer waivers are acceptable; "
                f"found a {w['rule']} waiver for {w['path']}",
            )

    def test_waiver_without_justification_rejected(self):
        import tempfile

        with tempfile.NamedTemporaryFile("w", suffix=".txt", delete=False) as f:
            f.write("CHARGE-CR rust/src/collectives/ring.rs\n")  # no `# why`
            bad = f.name
        old = lint_charges.WAIVER_FILE
        lint_charges.WAIVER_FILE = bad
        try:
            with self.assertRaises(SystemExit):
                lint_charges.load_waivers()
        finally:
            lint_charges.WAIVER_FILE = old
            os.unlink(bad)


if __name__ == "__main__":
    unittest.main()
