//! Offline stub of the `xla` crate's PJRT surface.
//!
//! The runtime's device service (`runtime/service.rs`) is written against
//! the real `xla` crate (PJRT C API wrappers). This container has neither
//! the crate nor the PJRT shared library, so this stub mirrors the exact
//! type/method surface the service uses and reports PJRT as unavailable at
//! client construction. Everything artifact-dependent already gates on the
//! artifacts directory existing, so tests and tools skip cleanly; swapping
//! the workspace `xla` path dependency back to the real crate re-enables
//! real execution with zero source changes in the framework.

use std::fmt;
use std::path::Path;

/// Stub error: every fallible call reports PJRT as unavailable.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (theano-mpi built against the offline `xla` stub)"
    )))
}

/// XLA primitive types the framework exchanges with PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U8,
    U16,
    U32,
    Pred,
}

/// Host dtypes accepted by buffer upload / literal download.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u16 {}
impl NativeType for u32 {}

pub struct PjRtClient(());
pub struct PjRtLoadedExecutable(());
pub struct PjRtBuffer(());
pub struct Literal(());
pub struct HloModuleProto(());
pub struct XlaComputation(());

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_buffer")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_literal")
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("array_shape")
    }

    pub fn ty(&self) -> Result<ElementType> {
        unavailable("ty")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("PJRT unavailable"));
    }
}
