//! Offline subset of the `anyhow` crate.
//!
//! The container vendors no registry crates, so this in-tree shim provides
//! the exact API surface the framework uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the [`Context`]
//! extension trait. Errors are a flattened message chain (no backtraces,
//! no downcasting) — enough for every `{e}` / `{e:?}` / `.to_string()`
//! call site in the framework, and drop-in replaceable by the real crate
//! whenever the build environment has network access.

use std::fmt;

/// A string-chain error value. Deliberately does **not** implement
/// `std::error::Error`, matching the real `anyhow::Error`, which is what
/// makes the blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context layer ("context: cause").
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Any std error converts via `?`, flattening its `source()` chain.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`), like the real crate.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {} at {}", "value", 7);
        assert_eq!(e.to_string(), "bad value at 7");
        assert_eq!(format!("{e:?}"), "bad value at 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_layers() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
        let n: Option<u8> = None;
        let e = n.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
    }
}
