//! Pinned bands for the loader-pipeline DES (`loader::sim`).
//!
//! Every constant below is derived by the stdlib Python twin
//! (`scripts/verify_loader_bands.py`, which imports the shared
//! `scripts/pricing_model.py` port); the two implementations share float-op
//! order with `audit::Ledger`, so the pins are effectively bit-exact — the
//! tolerance only absorbs last-ulp platform drift. If a pin moves, rerun
//! the script and update both sides deliberately.
//!
//! Runtime-free: no PJRT artifacts, no threads — pure DES.

use theano_mpi::loader::sim::{sim_pipeline, DiskParams, SimOutcome, SimPipelineCfg};
use theano_mpi::simnet::LinkParams;
use theano_mpi::units::Bytes;

const N_FILES: usize = 16;
const ITERS: usize = 64;
const BATCH_BYTES: u64 = 124_416;
const H2D_BYTES: u64 = 393_216;
const COMPUTE_S: f64 = 0.0008;

fn run(workers: usize, prefetch_depth: usize, cache_mib: usize) -> SimOutcome {
    sim_pipeline(
        &SimPipelineCfg {
            workers,
            prefetch_depth,
            cache_mib,
            n_files: N_FILES,
            iters: ITERS,
            batch_bytes: BATCH_BYTES,
            h2d_bytes: H2D_BYTES,
            compute_s: COMPUTE_S,
        },
        &DiskParams::default(),
        &LinkParams::default(),
    )
}

fn pin(got: impl Into<f64>, want: f64, what: &str) {
    let got: f64 = got.into();
    let tol = 1e-12 * want.abs().max(1.0);
    assert!(
        (got - want).abs() <= tol,
        "{what}: {got:.17} drifted from the Python-pinned {want:.17}"
    );
}

// scripts/verify_loader_bands.py output, full f64 precision
const VTIME_K8_Q0_C0: f64 = 0.153_897_983_999_999_77;
const VTIME_K8_Q1_C0: f64 = 0.103_497_983_999_999_82;
const VTIME_K8_Q2_C4: f64 = 0.068_373_167_999_999_96;
const VTIME_K8_Q4_C4: f64 = 0.066_285_839_999_999_98;
const VTIME_K1_Q4_C0: f64 = 0.054_410_399_999_999_98;
const STALL_K8_Q1_C0: f64 = 0.049_560_831_999_999_985;
const STALL_K8_Q2_C4: f64 = 0.014_436_016_000_000_006;
const HIDDEN_K8_Q2_C4: f64 = 0.032_949_072_000_000_024;
const H2D_TOTAL: f64 = 0.002_737_152; // 64 × pcie_time(393216) on defaults

#[test]
fn bands_pinned_against_python_port() {
    pin(run(8, 0, 0).vtime, VTIME_K8_Q0_C0, "vtime k8 q0 c0");
    pin(run(8, 1, 0).vtime, VTIME_K8_Q1_C0, "vtime k8 q1 c0");
    pin(run(8, 2, 4).vtime, VTIME_K8_Q2_C4, "vtime k8 q2 c4");
    pin(run(8, 4, 4).vtime, VTIME_K8_Q4_C4, "vtime k8 q4 c4");
    pin(run(1, 4, 0).vtime, VTIME_K1_Q4_C0, "vtime k1 q4 c0");
    pin(run(8, 1, 0).bd.load_stall, STALL_K8_Q1_C0, "stall k8 q1 c0");
    let warm = run(8, 2, 4);
    pin(warm.bd.load_stall, STALL_K8_Q2_C4, "stall k8 q2 c4");
    pin(warm.bd.load_hidden, HIDDEN_K8_Q2_C4, "hidden k8 q2 c4");
    for out in [run(8, 0, 0), run(8, 1, 0), warm] {
        pin(out.bd.h2d, H2D_TOTAL, "h2d total (both paths, like-for-like)");
    }
}

#[test]
fn direct_path_matches_closed_form() {
    // q=0 cold serializes everything on the worker clock: the DES must
    // equal the hand-summed cost model (disk + spiky decode + H2D +
    // compute per iteration, no overlap anywhere)
    let links = LinkParams::default();
    let disk = DiskParams::default();
    let mut want = 0.0;
    for i in 0..ITERS {
        let disk_s = disk.disk_lat_us * 1e-6 + BATCH_BYTES as f64 / ((disk.disk_gbps / 8.0) * 1e9);
        let spike = if (i + 1) % disk.spike_every == 0 { disk.spike_factor } else { 1.0 };
        let decode_s = BATCH_BYTES as f64 / (disk.decode_gbps * 1e9) * spike;
        want += disk_s + decode_s;
        want += links.pcie_time(Bytes(H2D_BYTES)).0;
        want += COMPUTE_S;
    }
    let got = run(8, 0, 0).vtime.0;
    assert!((got - want).abs() <= 1e-9 * want, "direct DES {got} vs closed form {want}");
}

#[test]
fn breakdown_reconciles_and_memo_stays_off_clock() {
    for (q, c) in [(0usize, 0usize), (1, 0), (2, 4), (4, 4)] {
        let out = run(8, q, c);
        let tol = 1e-9 * out.vtime.abs().max(1.0);
        assert!(
            (out.bd.total() - out.vtime).abs() <= tol,
            "breakdown != clock at q={q} c={c}: {} vs {}",
            out.bd.total(),
            out.vtime
        );
        if q == 0 {
            assert_eq!(out.bd.load_hidden, 0.0, "direct path overlaps nothing");
        } else {
            assert!(out.bd.load_hidden > 0.0, "parallel path must memo hidden load");
        }
    }
}

#[test]
fn vtime_monotone_in_prefetch_depth_and_cache() {
    for k in [1usize, 8] {
        for c in [0usize, 4] {
            let v: Vec<f64> =
                [0usize, 1, 2, 4].iter().map(|&q| run(k, q, c).vtime.0).collect();
            assert!(
                v.windows(2).all(|w| w[0] >= w[1]),
                "vtime not monotone in q at k={k} c={c}: {v:?}"
            );
        }
        for q in [0usize, 1, 2, 4] {
            assert!(
                run(k, q, 4).vtime <= run(k, q, 0).vtime,
                "a warm cache must never slow the pipeline (k={k} q={q})"
            );
        }
    }
}

#[test]
fn acceptance_depth_two_warm_beats_double_buffer() {
    let q2_warm = run(8, 2, 4);
    assert!(q2_warm.vtime < run(8, 1, 0).vtime, "q=2 warm must beat the cold double buffer");
    assert!(q2_warm.vtime < run(8, 1, 4).vtime, "q=2 warm must beat the warm double buffer");
    let q4_warm = run(8, 4, 4);
    assert!(
        q4_warm.bd.load_stall < 0.5 * run(8, 1, 0).bd.load_stall,
        "stall must collapse toward zero at q=4 warm"
    );
}

#[test]
fn cache_stats_one_cold_pass_then_hits() {
    let out = run(8, 2, 4);
    assert_eq!(out.cache.misses, N_FILES as u64);
    assert_eq!(out.cache.hits, (ITERS - N_FILES) as u64);
    assert_eq!(out.cache.evictions, 0);
    assert_eq!(out.cache.resident_bytes, N_FILES as u64 * BATCH_BYTES);
    let want_rate = (ITERS - N_FILES) as f64 / ITERS as f64;
    assert!((out.cache.hit_rate() - want_rate).abs() < 1e-15);
    // a 0 MiB cache bypasses entirely: all misses, nothing resident
    let cold = run(8, 2, 0);
    assert_eq!(cold.cache.hits, 0);
    assert_eq!(cold.cache.misses, ITERS as u64);
    assert_eq!(cold.cache.resident_bytes, 0);
}
