//! DES schedule race explorer: virtual-time pricing must be independent
//! of real-world thread scheduling.
//!
//! The sharded-EASGD serve queue and the WFBP release-gated flow shop are
//! discrete-event simulations driven by genuinely concurrent threads, so
//! the classic failure mode is a race where physical delivery order leaks
//! into the virtual clock. This suite promotes PR 3's random-schedule
//! Python check into an in-tree *exhaustive* detector at small scale:
//!
//! * every per-round permutation of worker send order, forced with a
//!   [`Turnstile`] gate between `worker_push` and `worker_collect`
//!   (k ≤ 3, S ≤ 2 — `(k!)^rounds` schedules);
//! * every real-sleep perturbation pattern under skewed compute, where
//!   gating would add artificial dependencies;
//! * every per-rank stagger pattern entering the WFBP bucketed exchange
//!   (≤ 4 buckets), which exercises the mpi pending-buffer out-of-order
//!   matching.
//!
//! Each run must be **bit-identical** to the baseline schedule: centers,
//! final worker params, serve orders, queue waits, clocks, and reports.
//!
//! **Repro:** a failure names the schedule/pattern index and its content;
//! re-run just this suite with `cargo test --test race_explorer`. The
//! default scale is a tier-1 smoke slice; set `TMPI_RACE_EXHAUSTIVE=1`
//! (nightly deep-props) for the full k=3 / 3-round / all-pattern sweep.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{
    exchange_wfbp, ChunkedPipeline, ExchangeCtx, ExchangeStrategy, ReduceOp, StrategyKind,
    WfbpOutcome, WfbpPlan, WireFormat,
};
use theano_mpi::easgd::shard::{self, ShardPlan, ShardPrices};
use theano_mpi::easgd::EasgdConfig;
use theano_mpi::mpi::{self, tags, Payload};
use theano_mpi::simnet::LinkParams;
use theano_mpi::testkit::{permutations, Turnstile};
use theano_mpi::units::Secs;

fn exhaustive() -> bool {
    std::env::var("TMPI_RACE_EXHAUSTIVE").map(|v| v == "1").unwrap_or(false)
}

/// Everything one probe run produces, in deterministic (rank) order.
#[derive(Clone, Debug, PartialEq)]
struct RunOut {
    centers: Vec<Vec<f32>>,
    served: Vec<Vec<usize>>,
    busy: Vec<f64>,
    shard_clocks: Vec<f64>,
    final_params: Vec<Vec<f32>>,
    worker_clocks: Vec<f64>,
    queue_waits: Vec<Vec<f64>>,
}

/// One sharded-EASGD probe run with explicit control over the physical
/// schedule: `gate` forces the global order of worker pushes (a flattened
/// per-round permutation schedule), `sleeps[rank]` injects a real delay
/// between a worker's push and its collect. Virtual pricing must not see
/// either.
fn run_probe(
    k: usize,
    s: usize,
    elems: usize,
    rounds: usize,
    compute_s: &[f64],
    gate: Option<Arc<Turnstile>>,
    sleeps: &[u64],
) -> RunOut {
    let mut cfg = EasgdConfig::quick("mlp", k, rounds);
    cfg.plan.servers = s;
    cfg.topology = "copper".into();
    let plan = Arc::new(ShardPlan::new(elems, k, s).unwrap());
    let topo = Topology::by_name(&cfg.topology, plan.world_size()).unwrap();
    let links = LinkParams::default();
    let prices = Arc::new(ShardPrices::new(&cfg, &topo, &links, &plan, 1.0));
    let alpha = cfg.alpha as f32;
    let compute_s = compute_s.to_vec();
    let sleeps = sleeps.to_vec();

    enum Out {
        Worker { rank: usize, clock: f64, waits: Vec<f64>, params: Vec<f32> },
        Server(shard::ServerOut),
    }

    let world = mpi::world(plan.world_size());
    let mut handles = Vec::new();
    for (rank, mut comm) in world.into_iter().enumerate() {
        let plan = plan.clone();
        let prices = prices.clone();
        let gate = gate.clone();
        let compute_s = compute_s.clone();
        let sleeps = sleeps.clone();
        handles.push(thread::spawn(move || -> anyhow::Result<Out> {
            if rank >= plan.workers {
                let shard_id = rank - plan.workers;
                let (lo, len) = plan.slices[shard_id];
                let init = shard::probe_center(plan.slices.iter().map(|&(_, l)| l).sum())
                    [lo..lo + len]
                    .to_vec();
                let out =
                    shard::server_shard_main(&mut comm, &plan, shard_id, &prices, alpha, init)?;
                Ok(Out::Server(out))
            } else {
                let elems: usize = plan.slices.iter().map(|&(_, l)| l).sum();
                let mut params = shard::probe_params(rank, elems);
                let mut clock = 0.0f64;
                let mut waits = Vec::with_capacity(rounds);
                for _round in 0..rounds {
                    clock += compute_s[rank];
                    if let Some(g) = &gate {
                        g.wait_turn(rank);
                    }
                    shard::worker_push(&mut comm, rank, &plan, None, &params, Secs(clock))?;
                    if let Some(g) = &gate {
                        g.advance();
                    }
                    if sleeps[rank] > 0 {
                        thread::sleep(Duration::from_micros(sleeps[rank]));
                    }
                    let t = shard::worker_collect(
                        &mut comm, rank, &plan, &prices, alpha, &mut params, Secs(clock),
                    )?;
                    clock = t.new_clock.0;
                    waits.push(t.queue_wait.0);
                }
                for j in 0..plan.servers {
                    comm.send(plan.server_rank(j), tags::CTL, Payload::Ctl("stop".into()), clock)?;
                }
                Ok(Out::Worker { rank, clock, waits, params })
            }
        }));
    }

    let mut out = RunOut {
        centers: vec![Vec::new(); s],
        served: vec![Vec::new(); s],
        busy: vec![0.0; s],
        shard_clocks: vec![0.0; s],
        final_params: vec![Vec::new(); k],
        worker_clocks: vec![0.0; k],
        queue_waits: vec![Vec::new(); k],
    };
    for h in handles {
        match h.join().unwrap().unwrap() {
            Out::Worker { rank, clock, waits, params } => {
                out.worker_clocks[rank] = clock;
                out.queue_waits[rank] = waits;
                out.final_params[rank] = params;
            }
            Out::Server(so) => {
                out.busy[so.shard] = so.busy;
                out.shard_clocks[so.shard] = so.clock_end;
                out.centers[so.shard] = so.center;
                out.served[so.shard] = so.served;
            }
        }
    }
    out
}

/// Flatten one per-round permutation choice into a Turnstile schedule.
fn flat_schedule(perms_per_round: &[&Vec<usize>]) -> Vec<usize> {
    perms_per_round.iter().flat_map(|p| p.iter().copied()).collect()
}

/// Enumerate all `(k!)^rounds` send schedules (index-vector odometer).
fn all_schedules(k: usize, rounds: usize) -> Vec<Vec<usize>> {
    let perms = permutations(k);
    let mut out = Vec::new();
    let mut idx = vec![0usize; rounds];
    loop {
        let chosen: Vec<&Vec<usize>> = idx.iter().map(|&i| &perms[i]).collect();
        out.push(flat_schedule(&chosen));
        // odometer increment
        let mut d = 0;
        loop {
            if d == rounds {
                return out;
            }
            idx[d] += 1;
            if idx[d] < perms.len() {
                break;
            }
            idx[d] = 0;
            d += 1;
        }
    }
}

/// Exhaustive permutation sweep: with tied compute, every physical send
/// order must price identically (serve ties break by rank, not by arrival
/// race). Equal compute keeps the round-robin gate deadlock-free: worker
/// arrival spread per round is at most `(k-1)·handle`, far below the
/// `2·wire_half + handle` liveness bound of the conservative queue.
#[test]
fn sharded_queue_is_send_schedule_independent() {
    let elems = 96;
    let configs: &[(usize, usize, usize)] = if exhaustive() {
        // (k, S, rounds): full k≤3 / S≤2 grid
        &[(2, 1, 3), (2, 2, 3), (3, 1, 3), (3, 2, 3)]
    } else {
        &[(2, 2, 3), (3, 2, 2)]
    };
    for &(k, s, rounds) in configs {
        let compute = vec![0.0; k];
        let schedules = all_schedules(k, rounds);
        let baseline = run_probe(k, s, elems, rounds, &compute, None, &vec![0; k]);
        for (i, sched) in schedules.iter().enumerate() {
            let gate = Arc::new(Turnstile::new(sched.clone()));
            let got = run_probe(k, s, elems, rounds, &compute, Some(gate), &vec![0; k]);
            assert!(
                got == baseline,
                "k={k} S={s} rounds={rounds}: schedule {i}/{} {sched:?} diverged:\n\
                 got {got:?}\nbaseline {baseline:?}",
                schedules.len()
            );
        }
    }
}

/// Perturbation sweep under *skewed* compute (where a global send gate
/// would itself create artificial cross-worker dependencies): real sleeps
/// between push and collect reorder physical delivery; the virtual clock
/// must not move.
#[test]
fn sharded_queue_is_perturbation_independent() {
    let elems = 96;
    let rounds = 3;
    let sleep_levels: &[u64] = if exhaustive() { &[0, 300, 900, 1700] } else { &[0, 700, 1500] };
    for &(k, s) in &[(3usize, 2usize), (2, 1)] {
        // skewed compute: worker w computes (w+1)·80µs of virtual time
        let compute: Vec<f64> = (0..k).map(|w| (w + 1) as f64 * 8e-5).collect();
        let baseline = run_probe(k, s, elems, rounds, &compute, None, &vec![0; k]);
        // every assignment of a sleep level to each worker
        let mut pattern = vec![0usize; k];
        loop {
            let sleeps: Vec<u64> = pattern.iter().map(|&i| sleep_levels[i]).collect();
            let got = run_probe(k, s, elems, rounds, &compute, None, &sleeps);
            assert!(
                got == baseline,
                "k={k} S={s}: sleep pattern {sleeps:?}µs diverged:\n\
                 got {got:?}\nbaseline {baseline:?}"
            );
            let mut d = 0;
            loop {
                if d == k {
                    break;
                }
                pattern[d] += 1;
                if pattern[d] < sleep_levels.len() {
                    break;
                }
                pattern[d] = 0;
                d += 1;
            }
            if d == k {
                break;
            }
        }
    }
}

/// Run one WFBP bucketed exchange across k threads, each rank entering
/// after a real stagger sleep. Returns every rank's buffer and outcome.
fn run_wfbp_staggered(
    kind: StrategyKind,
    fmt: WireFormat,
    chunk_elems: Option<usize>,
    topo: &Topology,
    plan: &Arc<WfbpPlan>,
    bufs: Vec<Vec<f32>>,
    stagger_us: &[u64],
) -> (Vec<Vec<f32>>, Vec<WfbpOutcome>) {
    let k = bufs.len();
    let world = mpi::world(k);
    let links = LinkParams::default();
    let handles: Vec<_> = world
        .into_iter()
        .zip(bufs)
        .enumerate()
        .map(|(rank, (mut comm, mut buf))| {
            let topo = topo.clone();
            let plan = plan.clone();
            let delay = stagger_us[rank];
            thread::spawn(move || {
                if delay > 0 {
                    thread::sleep(Duration::from_micros(delay));
                }
                // fresh strategy per run: a codec wire's error-feedback
                // residual starts at zero, so runs stay comparable
                let strat: Box<dyn ExchangeStrategy> = match chunk_elems {
                    Some(c) => Box::new(ChunkedPipeline::new(kind.build(fmt), c, true)),
                    None => kind.build(fmt),
                };
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo: &topo,
                    links: &links,
                    kernels: None,
                    cuda_aware: true,
                    chunk_elems: 0,
                    slice_off: 0,
                    sf_bytes: None,
                };
                let out = exchange_wfbp(
                    strat.as_ref(),
                    &plan,
                    &mut buf,
                    ReduceOp::Sum,
                    &mut ctx,
                    Secs(1e-3), // backward-pass seconds the buckets overlap
                    1.0,
                    true,
                )
                .unwrap();
                (buf, out)
            })
        })
        .collect();
    let mut bufs_out = Vec::new();
    let mut outcomes = Vec::new();
    for h in handles {
        let (b, o) = h.join().unwrap();
        bufs_out.push(b);
        outcomes.push(o);
    }
    (bufs_out, outcomes)
}

/// WFBP flow-shop sweep: a fast rank can run several buckets ahead of a
/// staggered peer (its sub-exchange messages sit in the mpi pending
/// buffers out of order), yet buffers and reports must be bit-identical
/// across all stagger patterns.
#[test]
fn wfbp_flow_shop_is_stagger_independent() {
    let k = 3;
    // 4 buckets: a fc-heavy head and conv tail, AlexNet-shaped in miniature
    let table: Vec<(String, usize)> = [("conv1", 60), ("conv2", 500), ("fc6", 1200), ("fc7", 800)]
        .iter()
        .map(|&(n, p)| (n.to_string(), p))
        .collect();
    let plan = Arc::new(WfbpPlan::from_layers(&table, 0));
    assert_eq!(plan.buckets.len(), 4);
    let n = plan.total_elems;
    let bufs: Vec<Vec<f32>> =
        (0..k).map(|r| (0..n).map(|i| ((r * 13 + i * 7) % 31) as f32 * 0.125).collect()).collect();

    // compressed wires ride the same sweep: the codec's error-feedback
    // residual is per-rank strategy state, so stagger independence also
    // pins the residual stream bit-for-bit
    let configs: Vec<(StrategyKind, WireFormat, Option<usize>, &str)> = if exhaustive() {
        vec![
            (StrategyKind::Asa, WireFormat::F32, None, "mosaic"),
            (StrategyKind::Ring, WireFormat::F32, None, "mosaic"),
            (
                StrategyKind::Hier { inner: theano_mpi::collectives::FlatKind::Ring },
                WireFormat::F32,
                None,
                "copper",
            ),
            (StrategyKind::Asa, WireFormat::F32, Some(128), "copper"),
            (StrategyKind::Asa, WireFormat::OneBit, None, "mosaic"),
            (StrategyKind::Asa, WireFormat::TopK { p: 0.25 }, Some(128), "copper"),
            (
                StrategyKind::Hier { inner: theano_mpi::collectives::FlatKind::Asa },
                WireFormat::TopK { p: 0.25 },
                None,
                "copper",
            ),
        ]
    } else {
        vec![
            (StrategyKind::Asa, WireFormat::F32, None, "mosaic"),
            (StrategyKind::Asa, WireFormat::F32, Some(128), "copper"),
            (StrategyKind::Asa, WireFormat::TopK { p: 0.25 }, None, "mosaic"),
            (StrategyKind::Asa, WireFormat::OneBit, Some(128), "copper"),
        ]
    };
    let patterns: Vec<Vec<u64>> = {
        let levels: &[u64] = if exhaustive() { &[0, 600, 1400] } else { &[0, 1200] };
        // every assignment of a stagger level per rank, baseline first
        let mut pats = vec![vec![0; k]];
        let mut idx = vec![0usize; k];
        loop {
            let mut d = 0;
            loop {
                if d == k {
                    break;
                }
                idx[d] += 1;
                if idx[d] < levels.len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
            }
            if d == k {
                break;
            }
            pats.push(idx.iter().map(|&i| levels[i]).collect());
        }
        pats
    };

    for (kind, fmt, chunk, topo_name) in configs {
        let topo = Topology::by_name(topo_name, k).unwrap();
        let (base_bufs, base_outs) =
            run_wfbp_staggered(kind, fmt, chunk, &topo, &plan, bufs.clone(), &patterns[0]);
        // the simulated schedule is global: every rank reports identically
        for (r, o) in base_outs.iter().enumerate() {
            assert!(o == &base_outs[0], "{}: rank {r} outcome differs from rank 0", kind.name());
        }
        for pat in &patterns[1..] {
            let (got_bufs, got_outs) =
                run_wfbp_staggered(kind, fmt, chunk, &topo, &plan, bufs.clone(), pat);
            assert!(
                got_bufs == base_bufs,
                "{} wire={} chunk={chunk:?} topo={topo_name}: stagger {pat:?}µs changed the data path",
                kind.name(),
                fmt.name()
            );
            assert!(
                got_outs == base_outs,
                "{} wire={} chunk={chunk:?} topo={topo_name}: stagger {pat:?}µs changed the reports:\n\
                 got {got_outs:?}\nbaseline {base_outs:?}",
                kind.name(),
                fmt.name()
            );
        }
    }
}

/// The ledger-instrumented probe (`measure_sharded`) agrees with the
/// explorer's hand-rolled harness on the same workload — the production
/// accounting path and the race harness price one physics.
#[test]
fn measure_sharded_matches_explorer_baseline() {
    let (k, s, elems, rounds) = (3, 2, 96, 3);
    let mut cfg = EasgdConfig::quick("mlp", k, rounds);
    cfg.plan.servers = s;
    cfg.topology = "copper".into();
    let probe = shard::measure_sharded(&cfg, elems, rounds, 0.0, 1.0).unwrap();
    let baseline = run_probe(k, s, elems, rounds, &vec![0.0; k], None, &vec![0; k]);
    assert_eq!(probe.centers, baseline.centers);
    assert_eq!(probe.final_params, baseline.final_params);
    assert_eq!(probe.served, baseline.served);
    assert_eq!(probe.worker_clocks, baseline.worker_clocks);
    // and the ledger reconciles each worker's breakdown with its clock
    for (w, bd) in probe.breakdowns.iter().enumerate() {
        let clock = probe.worker_clocks[w];
        assert!(
            (bd.total().0 - clock).abs() <= 1e-9 * clock.max(1.0),
            "worker {w}: breakdown {} != clock {clock}",
            bd.total()
        );
        let comm = bd.comm_transfer + bd.comm_queue;
        assert!(comm > 0.0 && bd.comm_kernel == 0.0 && bd.host_reduce == 0.0);
    }
}
