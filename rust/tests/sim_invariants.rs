//! Simnet pricing invariants: no strategy may price its transfer time
//! below the physics of its own traffic, and the hierarchical exchange
//! must actually deliver its NIC-byte reduction.
//!
//! Lower bounds are derived from the priced transfer sets themselves
//! (`CommReport::wire_{intra,inter}_bytes` are global, identical across
//! ranks):
//!
//! * **NIC bound** — every inter-node byte occupies its source node's
//!   NIC-out at `ib_gbps`; with `n_nodes` NICs working perfectly in
//!   parallel, `sim_transfer >= inter_bytes / (n_nodes * ib_gbps)`.
//! * **intra bound** — every intra-node byte loads at least one of the
//!   per-rank PCIe up/down links or per-node QPI/host-RAM resources, none
//!   faster than `max(pcie, qpi, host_mem)` GB/s, so with `2k + 2*nodes`
//!   such resources `sim_transfer >= intra_bytes / (fastest * (2k + 2n))`.
//!
//! Both were verified against a Python port of the pricing model before
//! landing; they are deliberately loose (resource counts are upper bounds)
//! so they stay true under topology-routing changes while still catching
//! under-pricing bugs of 10x and up.

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{CommReport, FlatKind, ReduceOp, StrategyKind};
use theano_mpi::simnet::LinkParams;
use theano_mpi::testkit::{all_strategy_kinds, run_exchange};

fn run_kind(
    kind: StrategyKind,
    chunk_elems: Option<usize>,
    k: usize,
    n: usize,
    topo: Topology,
) -> CommReport {
    let bufs: Vec<Vec<f32>> =
        (0..k).map(|r| (0..n).map(|i| ((r * 31 + i) % 1000) as f32 * 1e-3).collect()).collect();
    run_exchange(kind, chunk_elems, bufs, ReduceOp::Sum, &topo).1
}

fn assert_lower_bounds(rep: &CommReport, topo: &Topology, k: usize, label: &str) {
    let links = LinkParams::default();
    let ib = links.ib_gbps(topo.ib).0;
    let inter_bound = rep.wire_inter_bytes.as_f64() / (topo.n_nodes as f64 * ib * 1e9);
    let fastest = links.pcie_gbps.0.max(links.qpi_gbps.0).max(links.host_mem_gbps.0);
    let resources = (2 * k + 2 * topo.n_nodes) as f64;
    let intra_bound = rep.wire_intra_bytes.as_f64() / (fastest * 1e9 * resources);
    assert!(
        rep.sim_transfer.0 + 1e-15 >= inter_bound,
        "{label}: sim_transfer {} prices below the NIC bound {} ({} inter bytes over {} NICs)",
        rep.sim_transfer,
        inter_bound,
        rep.wire_inter_bytes,
        topo.n_nodes
    );
    assert!(
        rep.sim_transfer.0 + 1e-15 >= intra_bound,
        "{label}: sim_transfer {} prices below the intra bound {}",
        rep.sim_transfer,
        intra_bound
    );
}

#[test]
fn no_strategy_prices_below_its_traffic_bounds() {
    let n = 40_000;
    for (topo, k) in [
        (Topology::mosaic(5), 5usize),
        (Topology::copper(2), 16),
        (Topology::copper(1), 8),
    ] {
        for kind in all_strategy_kinds() {
            let rep = run_kind(kind, None, k, n, topo.clone());
            assert_lower_bounds(&rep, &topo, k, &format!("{} on {}", kind.name(), topo.name));
            // chunking moves the same bytes; the bound holds per chunk and
            // therefore in sum, and even the overlapped makespan cannot
            // dip below the NIC machine's serialized load
            let chunked = run_kind(kind, Some(n.div_ceil(8)), k, n, topo.clone());
            assert_eq!(chunked.wire_inter_bytes, rep.wire_inter_bytes, "{}", kind.name());
            assert_lower_bounds(
                &chunked,
                &topo,
                k,
                &format!("chunked({}) on {}", kind.name(), topo.name),
            );
            let links = LinkParams::default();
            let ib = links.ib_gbps(topo.ib).0;
            let inter_bound =
                chunked.wire_inter_bytes.as_f64() / (topo.n_nodes as f64 * ib * 1e9);
            assert!(
                chunked.sim_total().0 + 1e-15 >= inter_bound,
                "{}: overlapped total {} below NIC bound {}",
                kind.name(),
                chunked.sim_total(),
                inter_bound
            );
        }
    }
}

#[test]
fn hier_moves_strictly_fewer_nic_bytes_than_flat_inner_on_copper() {
    // the tentpole's byte-level claim, per inner strategy, on >= 2 nodes
    let n = 40_000;
    for nodes in [2usize, 3] {
        let k = nodes * 8;
        let topo = Topology::copper(nodes);
        for inner in [FlatKind::Ar, FlatKind::Asa, FlatKind::Asa16, FlatKind::Ring] {
            let flat = run_kind(StrategyKind::from(inner), None, k, n, topo.clone());
            let hier = run_kind(StrategyKind::Hier { inner }, None, k, n, topo.clone());
            assert!(
                hier.wire_inter_bytes < flat.wire_inter_bytes,
                "copper({nodes}) {}: hier {} !< flat {}",
                inner.name(),
                hier.wire_inter_bytes,
                flat.wire_inter_bytes
            );
            assert!(hier.wire_inter_bytes > 0, "leaders still cross the NIC");
        }
        // all-pairs flat strategies push ~every GPU's vector through the
        // NIC; the leader tree cuts that by ~the GPUs-per-node factor
        let flat_asa = run_kind(StrategyKind::Asa, None, k, n, topo.clone());
        let hier_asa =
            run_kind(StrategyKind::Hier { inner: FlatKind::Asa }, None, k, n, topo.clone());
        let cut = flat_asa.wire_inter_bytes.as_f64() / hier_asa.wire_inter_bytes.as_f64();
        assert!(cut > 7.0, "copper({nodes}): expected ~8x NIC cut vs flat ASA, got {cut}x");
    }
}

#[test]
fn hier_level_split_is_consistent() {
    let topo = Topology::copper(2);
    let rep = run_kind(StrategyKind::Hier { inner: FlatKind::Ring }, None, 16, 10_000, topo);
    assert!(rep.sim_intra > 0.0 && rep.sim_inter > 0.0);
    assert!((rep.sim_intra + rep.sim_inter - rep.sim_transfer).abs() < 1e-12);
    // flat strategies don't populate the level split
    let flat = run_kind(StrategyKind::Ring, None, 16, 10_000, Topology::copper(2));
    assert_eq!(flat.sim_intra, 0.0);
    assert_eq!(flat.sim_inter, 0.0);
    assert!(flat.wire_inter_bytes > 0, "but the byte split is universal");
}

#[test]
fn hier_asa16_inner_halves_leader_nic_bytes() {
    let topo = Topology::copper(2);
    let h32 = run_kind(StrategyKind::Hier { inner: FlatKind::Asa }, None, 16, 40_000, topo.clone());
    let h16 =
        run_kind(StrategyKind::Hier { inner: FlatKind::Asa16 }, None, 16, 40_000, topo);
    assert_eq!(h32.wire_inter_bytes, 2 * h16.wire_inter_bytes);
    assert!(h16.sim_inter < h32.sim_inter);
}
