//! Golden-output tests: the exact `tmpi topo` rendering (node leaders
//! annotated for the hier exchange) and the config-TOML surface for the
//! `exchange` / `chunk_kib` / `pipeline` knobs, including the error text a
//! user sees for a bad hier inner.

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{FlatKind, StrategyKind};
use theano_mpi::config;

#[test]
fn topo_render_copper_golden() {
    // what `tmpi topo copper` prints for one node (8 workers)
    let got = Topology::by_name("copper", 8).unwrap().render();
    let want = "\
topology copper-1n (8 GPUs, IB Fdr)
node 0
  socket 0 (CPU)--PCIe switch--[gpu0* gpu1 gpu2 gpu3]
  socket 1 (CPU)--PCIe switch--[gpu4 gpu5 gpu6 gpu7]
(sockets joined by QPI; GPUDirect P2P only within a switch)
(* = node leader: root of the hier exchange's intra-node reduce tree)
";
    assert_eq!(got, want);
}

#[test]
fn topo_render_copper_two_nodes_golden() {
    let got = Topology::copper(2).render();
    let want = "\
topology copper-2n (16 GPUs, IB Fdr)
node 0
  socket 0 (CPU)--PCIe switch--[gpu0* gpu1 gpu2 gpu3]
  socket 1 (CPU)--PCIe switch--[gpu4 gpu5 gpu6 gpu7]
  |-- IB NIC
node 1
  socket 0 (CPU)--PCIe switch--[gpu8* gpu9 gpu10 gpu11]
  socket 1 (CPU)--PCIe switch--[gpu12 gpu13 gpu14 gpu15]
  |-- IB NIC
(sockets joined by QPI; GPUDirect P2P only within a switch)
(* = node leader: root of the hier exchange's intra-node reduce tree)
";
    assert_eq!(got, want);
}

#[test]
fn topo_render_mosaic_golden() {
    let got = Topology::mosaic(2).render();
    let want = "\
topology mosaic-2n (2 GPUs, IB Qdr)
node 0
  socket 0 (CPU)--PCIe switch--[gpu0*]
  |-- IB NIC
node 1
  socket 0 (CPU)--PCIe switch--[gpu1*]
  |-- IB NIC
(* = node leader: root of the hier exchange's intra-node reduce tree)
";
    assert_eq!(got, want);
}

const HIER_TOML: &str = r#"
[train]
model = "alexnet"
workers = 16
topology = "copper"
exchange = "hier:asa16"
chunk_kib = 256
pipeline = true

[easgd]
model = "mlp"
workers = 4
exchange = "hier:asa16"
chunk_kib = 128
pipeline = false
"#;

#[test]
fn config_toml_roundtrip_for_hier_knobs() {
    let table = config::parse(HIER_TOML).unwrap();
    let cfg = config::bsp_from_table(&table).unwrap();
    assert_eq!(cfg.plan.strategy, StrategyKind::Hier { inner: FlatKind::Asa16 });
    assert_eq!(cfg.plan.strategy.name(), "hier:asa16");
    assert_eq!(cfg.plan.chunk_kib, 256);
    assert!(cfg.plan.pipeline);
    assert_eq!(cfg.topology, "copper");

    let p = std::env::temp_dir().join(format!("tmpi_golden_{}.toml", std::process::id()));
    std::fs::write(&p, HIER_TOML).unwrap();
    let ecfg = config::easgd_from_file(&p).unwrap();
    assert_eq!(ecfg.plan.strategy, StrategyKind::Hier { inner: FlatKind::Asa16 });
    assert!(ecfg.plan.strategy.half_wire());
    assert_eq!(ecfg.plan.chunk_kib, 128);
    assert!(!ecfg.plan.pipeline);
    let _ = std::fs::remove_file(p);
}

#[test]
fn bad_hier_inner_error_is_exact() {
    // the error text a user sees for `exchange = "hier:warp"`
    let table = config::parse("[train]\nexchange = \"hier:warp\"").unwrap();
    let err = config::bsp_from_table(&table).unwrap_err().to_string();
    assert_eq!(
        err,
        "unknown inner strategy 'warp' for hier (valid: hier:{ar|allreduce|asa|asa16|ring})"
    );
    // and a plain bad name still lists the full strategy set
    let table = config::parse("[train]\nexchange = \"warp\"").unwrap();
    let err = config::bsp_from_table(&table).unwrap_err().to_string();
    assert_eq!(
        err,
        "unknown exchange strategy 'warp' (valid: ar|allreduce|asa|asa16|ring|hier:<inner>)"
    );
}

#[test]
fn strategy_names_roundtrip_through_config_text() {
    for name in ["ar", "asa", "asa16", "ring", "hier:ar", "hier:asa", "hier:asa16", "hier:ring"] {
        let toml = format!("[train]\nexchange = \"{name}\"");
        let cfg = config::bsp_from_table(&config::parse(&toml).unwrap()).unwrap();
        assert_eq!(cfg.plan.strategy.name(), name, "{name} must round-trip");
    }
}
