//! Wire-codec property suite: the gradient-compression family
//! (f16/bf16/topk/onebit/sf) must be deterministic, error-feedback-exact,
//! and schedule-independent through every scheduler that can drive it.
//!
//! * top-k selection: exactly `⌈p·n⌉` elements, largest |x| first, ties
//!   broken toward the lower index — twice over the same input yields the
//!   same indices (rank determinism is what keeps exchanges coherent).
//! * onebit: the scale is the sequentially-accumulated f64 mean |x| cast
//!   to f32 once, and every decoded element is exactly `±scale`.
//! * error feedback: the `WireCodec` residual bookkeeping is bit-identical
//!   to the pure-function replay `send = grad + res; res' = send −
//!   decode(encode(send))` — the conservation law that makes lossy wires
//!   convergence-preserving.
//! * delivery schedules: for every wire × {flat, hier, chunked, wfbp},
//!   staggering rank entry (the race-explorer pattern) must not change a
//!   single bit of the buffers or the reports.
//!
//! Byte-count goldens mirror `scripts/pricing_model.py`'s codec formulas;
//! the simnet band pinning lives in `scripts/verify_wire_bands.py`.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::wire::{encode, topk_count, topk_indices};
use theano_mpi::collectives::{
    exchange_wfbp, Asa, ChunkedPipeline, ExchangeCtx, ExchangeStrategy, FlatKind, ReduceOp,
    StrategyKind, WfbpPlan, WireCodec, WireFormat,
};
use theano_mpi::coordinator::{probe_exchange, probe_exchange_wire};
use theano_mpi::mpi;
use theano_mpi::simnet::LinkParams;
use theano_mpi::testkit::{allclose, gauss_vec, prop, run_exchange_wire};
use theano_mpi::units::Secs;

fn lossy_formats() -> [WireFormat; 5] {
    [
        WireFormat::F16,
        WireFormat::Bf16,
        WireFormat::TopK { p: 0.3 },
        WireFormat::OneBit,
        WireFormat::Sf,
    ]
}

#[test]
fn prop_topk_selects_exact_count_of_largest_magnitudes() {
    prop("topk: exact count, |x| dominance, determinism", 30, |rng| {
        let n = 1 + rng.below(800);
        let p = 0.01 + (rng.below(100) as f64) / 100.0;
        let xs = gauss_vec(rng, n, 2.0);
        let idx = topk_indices(&xs, p);
        let m = topk_count(n, p);
        if m != (p * n as f64).ceil() as usize && m != n && m != 1 {
            return Err(format!("count {m} is not ceil({p}*{n}) nor a clamp"));
        }
        if idx.len() != m {
            return Err(format!("selected {} != topk_count {m}", idx.len()));
        }
        let selected: std::collections::HashSet<u32> = idx.iter().copied().collect();
        if selected.len() != idx.len() {
            return Err("duplicate indices selected".into());
        }
        let min_sel =
            idx.iter().map(|&i| xs[i as usize].abs()).fold(f32::INFINITY, f32::min);
        for (i, &x) in xs.iter().enumerate() {
            if !selected.contains(&(i as u32)) && x.abs() > min_sel {
                return Err(format!(
                    "unselected |xs[{i}]|={} beats selected minimum {min_sel}",
                    x.abs()
                ));
            }
        }
        if topk_indices(&xs, p) != idx {
            return Err("selection is not deterministic".into());
        }
        Ok(())
    });
}

#[test]
fn prop_onebit_ships_signs_with_mean_abs_scale() {
    prop("onebit: decoded == ±(mean |x| as f32)", 30, |rng| {
        let n = 1 + rng.below(1200);
        let xs = gauss_vec(rng, n, 3.0);
        let enc = encode(WireFormat::OneBit, &xs, None);
        let scale = (xs.iter().map(|&x| x.abs() as f64).sum::<f64>() / n as f64) as f32;
        for (i, (&x, &d)) in xs.iter().zip(&enc.decoded).enumerate() {
            let want = if x.to_bits() >> 31 == 1 { -scale } else { scale };
            if d.to_bits() != want.to_bits() {
                return Err(format!("elem {i}: decoded {d} != {want} (x={x})"));
            }
        }
        if enc.wire_bytes != n.div_ceil(8) as u64 + 4 {
            return Err(format!("wire bytes {} != ceil({n}/8)+4", enc.wire_bytes));
        }
        Ok(())
    });
}

/// Deterministic per-(rank, round) gradient for the error-feedback harness.
fn round_grad(rank: usize, round: usize, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (((rank * 131 + round * 37 + i * 7) % 223) as f32 - 111.0) * 3e-3)
        .collect()
}

#[test]
fn error_feedback_residual_matches_pure_replay_bitwise() {
    let k = 2;
    let n = 257;
    let rounds = 4;
    for fmt in lossy_formats() {
        let world = mpi::world(k);
        let links = LinkParams::default();
        let topo = Topology::mosaic(k);
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let topo = topo.clone();
                thread::spawn(move || {
                    let codec = WireCodec::new(Box::new(Asa), fmt);
                    let mut bufs_out = Vec::new();
                    for round in 0..rounds {
                        let mut buf = round_grad(rank, round, n);
                        let mut ctx = ExchangeCtx {
                            comm: &mut comm,
                            topo: &topo,
                            links: &links,
                            kernels: None,
                            cuda_aware: true,
                            chunk_elems: 0,
                            slice_off: 0,
                            sf_bytes: None,
                        };
                        codec.exchange(&mut buf, ReduceOp::Sum, &mut ctx).unwrap();
                        bufs_out.push(buf);
                    }
                    (codec.residual_snapshot(), bufs_out)
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let (snapshot, _) = h.join().unwrap();
            // pure replay: the residual stream depends only on the grads fed
            // in (the codec banks it *before* the inner exchange runs)
            let mut res = vec![0.0f32; n];
            for round in 0..rounds {
                let mut send = round_grad(rank, round, n);
                for (s, r) in send.iter_mut().zip(&res) {
                    *s += r;
                }
                let enc = encode(fmt, &send, None);
                for i in 0..n {
                    res[i] = send[i] - enc.decoded[i];
                }
            }
            assert_eq!(snapshot.len(), n, "{}: residual length", fmt.name());
            for i in 0..n {
                assert_eq!(
                    snapshot[i].to_bits(),
                    res[i].to_bits(),
                    "{} rank {rank} elem {i}: codec residual {} != replay {}",
                    fmt.name(),
                    snapshot[i],
                    res[i]
                );
            }
        }
    }
}

#[test]
fn wire_exchange_agrees_across_ranks_and_with_encoded_reference() {
    prop("wire exchange vs encoded host reference", 6, |rng| {
        let k = 2 + rng.below(5);
        let n = 1 + rng.below(900);
        let bufs: Vec<Vec<f32>> = (0..k).map(|_| gauss_vec(rng, n, 2.0)).collect();
        let topo = Topology::mosaic(k);
        for fmt in lossy_formats() {
            for kind in [StrategyKind::Asa, StrategyKind::Hier { inner: FlatKind::Asa }] {
                // a fresh codec has residual 0, so one exchange reduces the
                // per-rank decode(encode(grad)) values exactly
                let mut want = vec![0.0f32; n];
                for b in &bufs {
                    for (w, d) in want.iter_mut().zip(&encode(fmt, b, None).decoded) {
                        *w += d;
                    }
                }
                let (outs, rep) =
                    run_exchange_wire(kind, fmt, None, bufs.clone(), ReduceOp::Sum, &topo);
                for (r, out) in outs.iter().enumerate().skip(1) {
                    if out != &outs[0] {
                        return Err(format!(
                            "{}/{} k={k} n={n}: rank {r} disagrees with rank 0",
                            kind.name(),
                            fmt.name()
                        ));
                    }
                }
                allclose(&outs[0], &want, 1e-4, 1e-4).map_err(|e| {
                    format!("{}/{} k={k} n={n}: {e}", kind.name(), fmt.name())
                })?;
                if rep.wire_raw_bytes == 0 {
                    return Err(format!(
                        "{}/{}: codec must record dense-equivalent bytes",
                        kind.name(),
                        fmt.name()
                    ));
                }
                if rep.compression_ratio() < 1.0 - 1e-9 {
                    return Err(format!(
                        "{}/{}: compression ratio {} < 1",
                        kind.name(),
                        fmt.name(),
                        rep.compression_ratio()
                    ));
                }
                if !rep.strategy.ends_with(&format!("/{}", fmt.name())) {
                    return Err(format!(
                        "report strategy '{}' does not name the wire {}",
                        rep.strategy,
                        fmt.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Run one wire exchange (flat, hier, chunked, or wfbp) across k staggered
/// threads; returns every rank's buffer plus a debug rendering of rank 0's
/// report/outcome for bit-level comparison.
fn run_staggered(
    kind: StrategyKind,
    fmt: WireFormat,
    chunk_elems: Option<usize>,
    wfbp: Option<&Arc<WfbpPlan>>,
    bufs: Vec<Vec<f32>>,
    topo: &Topology,
    stagger_us: &[u64],
) -> (Vec<Vec<f32>>, String) {
    let k = bufs.len();
    let world = mpi::world(k);
    let links = LinkParams::default();
    let handles: Vec<_> = world
        .into_iter()
        .zip(bufs)
        .enumerate()
        .map(|(rank, (mut comm, mut buf))| {
            let topo = topo.clone();
            let wfbp = wfbp.cloned();
            let delay = stagger_us[rank];
            thread::spawn(move || {
                if delay > 0 {
                    thread::sleep(Duration::from_micros(delay));
                }
                let strat: Box<dyn ExchangeStrategy> = match chunk_elems {
                    Some(c) => Box::new(ChunkedPipeline::new(kind.build(fmt), c, true)),
                    None => kind.build(fmt),
                };
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo: &topo,
                    links: &links,
                    kernels: None,
                    cuda_aware: true,
                    chunk_elems: 0,
                    slice_off: 0,
                    sf_bytes: None,
                };
                let rendered = match wfbp {
                    Some(plan) => {
                        let out = exchange_wfbp(
                            strat.as_ref(),
                            &plan,
                            &mut buf,
                            ReduceOp::Sum,
                            &mut ctx,
                            Secs(1e-3),
                            1.0,
                            true,
                        )
                        .unwrap();
                        format!("{out:?}")
                    }
                    None => {
                        let rep = strat.exchange(&mut buf, ReduceOp::Sum, &mut ctx).unwrap();
                        format!("{rep:?}")
                    }
                };
                (buf, rendered)
            })
        })
        .collect();
    let mut outs = Vec::new();
    let mut rendered0 = String::new();
    for (i, h) in handles.into_iter().enumerate() {
        let (buf, rendered) = h.join().unwrap();
        if i == 0 {
            rendered0 = rendered;
        }
        outs.push(buf);
    }
    (outs, rendered0)
}

#[test]
fn every_wire_is_delivery_schedule_independent_across_schedulers() {
    let k = 3;
    // fc-heavy miniature so the wfbp plan has several buckets
    let table: Vec<(String, usize)> =
        [("conv1", 90), ("fc6", 700), ("fc7", 410)].iter().map(|&(s, p)| (s.into(), p)).collect();
    let plan = Arc::new(WfbpPlan::from_layers(&table, 0));
    let n = plan.total_elems;
    let bufs: Vec<Vec<f32>> =
        (0..k).map(|r| (0..n).map(|i| ((r * 17 + i * 5) % 41) as f32 * 0.0625 - 1.0).collect()).collect();
    let topo = Topology::by_name("copper", k).unwrap();
    let patterns: [[u64; 3]; 3] = [[0, 0, 0], [0, 1200, 400], [900, 0, 300]];

    // scheduler matrix: flat, hier, chunked, wfbp
    let schedulers: [(StrategyKind, Option<usize>, bool); 4] = [
        (StrategyKind::Asa, None, false),
        (StrategyKind::Hier { inner: FlatKind::Asa }, None, false),
        (StrategyKind::Asa, Some(128), false),
        (StrategyKind::Asa, None, true),
    ];
    for fmt in lossy_formats() {
        for &(kind, chunk, use_wfbp) in &schedulers {
            let wfbp = if use_wfbp { Some(&plan) } else { None };
            let (base_bufs, base_rep) =
                run_staggered(kind, fmt, chunk, wfbp, bufs.clone(), &topo, &patterns[0]);
            for pat in &patterns[1..] {
                let (got_bufs, got_rep) =
                    run_staggered(kind, fmt, chunk, wfbp, bufs.clone(), &topo, pat);
                assert!(
                    got_bufs == base_bufs,
                    "{}/{} chunk={chunk:?} wfbp={use_wfbp}: stagger {pat:?}µs changed the data path",
                    kind.name(),
                    fmt.name()
                );
                assert_eq!(
                    got_rep,
                    base_rep,
                    "{}/{} chunk={chunk:?} wfbp={use_wfbp}: stagger {pat:?}µs changed the report",
                    kind.name(),
                    fmt.name()
                );
            }
        }
    }
}

#[test]
fn codec_byte_goldens_match_the_python_port() {
    // the same closed forms scripts/pricing_model.py prices with
    let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.173).sin()).collect();
    assert_eq!(encode(WireFormat::TopK { p: 0.01 }, &xs, None).wire_bytes, 80);
    assert_eq!(encode(WireFormat::OneBit, &xs, None).wire_bytes, 129);
    assert_eq!(encode(WireFormat::F16, &xs, None).wire_bytes, 2000);
    assert_eq!(encode(WireFormat::Bf16, &xs, None).wire_bytes, 2000);
    assert_eq!(encode(WireFormat::Sf, &xs, Some(640)).wire_bytes, 640);
    assert_eq!(encode(WireFormat::Sf, &xs, None).wire_bytes, 4000);
}

#[test]
fn compressed_probes_cut_wire_bytes_at_alexnet_scale() {
    // the acceptance floor: topk:0.01 and onebit move >= 10x fewer bytes
    // than dense f32 on an AlexNet-sized exchange, and the NIC-bound
    // copper fabric turns that into simulated time
    let bytes = 4 * 60_965_224u64;
    let dense = probe_exchange(
        StrategyKind::Asa,
        8,
        Topology::by_name("copper", 8).unwrap(),
        bytes,
        true,
        0,
        false,
    )
    .unwrap();
    for fmt in [WireFormat::TopK { p: 0.01 }, WireFormat::OneBit] {
        let rep = probe_exchange_wire(
            StrategyKind::Asa,
            fmt,
            8,
            Topology::by_name("copper", 8).unwrap(),
            bytes,
            true,
            0,
            false,
            None,
        )
        .unwrap();
        assert!(
            rep.compression_ratio() >= 10.0,
            "{}: compression ratio {} < 10x",
            fmt.name(),
            rep.compression_ratio()
        );
        assert!(
            rep.wire_bytes.as_f64() * 10.0 <= dense.wire_bytes.as_f64(),
            "{}: wire bytes {} not >= 10x under dense {}",
            fmt.name(),
            rep.wire_bytes,
            dense.wire_bytes
        );
        assert!(
            rep.sim_total() < dense.sim_total(),
            "{}: sim {} !< dense {}",
            fmt.name(),
            rep.sim_total(),
            dense.sim_total()
        );
    }
}
