//! Integration: the full BSP stack over real AOT artifacts.
//!
//! These tests need `make artifacts` to have run (skipped otherwise) and
//! exercise runtime + collectives + sgd + data + loader + bsp end to end.

use std::sync::Arc;

use theano_mpi::bsp::{run_bsp, BspConfig};
use theano_mpi::collectives::{FlatKind, OverlapMode, StrategyKind, WireFormat};
use theano_mpi::runtime::Runtime;
use theano_mpi::sgd::{LrSchedule, Scheme};

fn rt() -> Option<Arc<Runtime>> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Arc::new(Runtime::load(dir).unwrap()))
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn subgd_mlp_converges_and_stays_in_sync() {
    let Some(rt) = rt() else { return };
    let mut cfg = BspConfig::quick("mlp", 4, 40);
    cfg.lr = LrSchedule::Const { base: 0.05 };
    cfg.eval_every = 10;
    cfg.integrity_every = 10; // cross-rank checksum assertion
    let rep = run_bsp(&rt, &cfg).unwrap();
    assert!(rep.final_train_loss < 0.8, "loss={}", rep.final_train_loss);
    assert!(rep.final_val_err < 0.5, "val_err={}", rep.final_val_err);
    assert!(rep.vtime_total > 0.0);
    assert!(rep.breakdown.compute > 0.0);
    assert!(rep.breakdown.comm() > 0.0);
}

#[test]
fn awagd_and_subgd_reach_similar_loss() {
    let Some(rt) = rt() else { return };
    // paper §4: the schemes are equivalent up to LR scaling; with identical
    // data order both should train the MLP to low loss
    let mut losses = Vec::new();
    for scheme in [Scheme::Awagd, Scheme::Subgd] {
        let mut cfg = BspConfig::quick("mlp", 2, 40);
        cfg.scheme = scheme;
        // AWAGD scales LR by k (paper [15]); SUBGD averages grads at lr
        cfg.lr = LrSchedule::Const { base: if scheme == Scheme::Awagd { 0.05 } else { 0.05 } };
        cfg.seed = 7;
        let rep = run_bsp(&rt, &cfg).unwrap();
        losses.push(rep.final_train_loss);
    }
    assert!(losses[0] < 1.0 && losses[1] < 1.0, "{losses:?}");
}

#[test]
fn all_strategies_train_mlp() {
    let Some(rt) = rt() else { return };
    for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
        let mut cfg = BspConfig::quick("mlp", 3, 25);
        cfg.plan.strategy = strat;
        cfg.lr = LrSchedule::Const { base: 0.05 };
        cfg.integrity_every = 5;
        let rep = run_bsp(&rt, &cfg).unwrap();
        assert!(
            rep.final_train_loss < 1.5,
            "{}: loss={}",
            strat.name(),
            rep.final_train_loss
        );
    }
}

#[test]
fn asa16_bf16_wire_works() {
    let Some(rt) = rt() else { return };
    let mut cfg = BspConfig::quick("mlp", 2, 15);
    cfg.plan.strategy = StrategyKind::Asa16;
    cfg.plan.wire = Some(WireFormat::Bf16);
    cfg.lr = LrSchedule::Const { base: 0.05 };
    let rep = run_bsp(&rt, &cfg).unwrap();
    assert!(rep.final_train_loss < 2.5);
}

#[test]
fn sim_model_scales_comm_time() {
    let Some(rt) = rt() else { return };
    let mut small = BspConfig::quick("mlp", 4, 6);
    small.seed = 3;
    let mut big = small.clone();
    big.sim_model = Some("vggnet".to_string()); // 138M params vs 267k
    let rs = run_bsp(&rt, &small).unwrap();
    let rb = run_bsp(&rt, &big).unwrap();
    assert!(
        rb.breakdown.comm() > 50.0 * rs.breakdown.comm(),
        "big={} small={}",
        rb.breakdown.comm(),
        rs.breakdown.comm()
    );
}

#[test]
fn alexnet_proxy_with_parallel_loader_trains() {
    let Some(rt) = rt() else { return };
    let mut cfg = BspConfig::quick("alexnet", 2, 8);
    cfg.use_loader = true;
    cfg.lr = LrSchedule::Const { base: 0.01 };
    cfg.eval_every = 4;
    let rep = run_bsp(&rt, &cfg).unwrap();
    assert!(rep.final_train_loss.is_finite());
    assert!(rep.curve.len() >= 2);
}

#[test]
fn transformer_lm_step_runs_under_bsp() {
    let Some(rt) = rt() else { return };
    let mut cfg = BspConfig::quick("transformer", 2, 3);
    cfg.lr = LrSchedule::Const { base: 1e-3 };
    cfg.eval_every = 3;
    let rep = run_bsp(&rt, &cfg).unwrap();
    // 3 iters: just sanity — finite loss near ln(2048) ≈ 7.6 and a curve
    assert!(rep.final_train_loss.is_finite());
    assert!(rep.final_train_loss < 12.0);
}

#[test]
fn breakdown_reconciles_with_virtual_clock() {
    let Some(rt) = rt() else { return };
    // direct loader path (use_loader = false) charges H2D staging; with a
    // single worker there is no barrier skew, so the breakdown must
    // account for every simulated second on the clock
    let mut cfg = BspConfig::quick("alexnet", 1, 4);
    cfg.use_loader = false;
    cfg.lr = LrSchedule::Const { base: 0.01 };
    let rep = run_bsp(&rt, &cfg).unwrap();
    assert!(rep.breakdown.h2d > 0.0, "direct path must charge h2d");
    let total = rep.breakdown.total();
    assert!(
        (total - rep.vtime_total).abs() < 1e-9 * total.max(1.0),
        "breakdown {total} != clock {}",
        rep.vtime_total
    );
    // multi-worker: straggle is charged to comm_queue and the final barrier
    // reconciles every rank, so equality is exact at k>1 too (the grid test
    // below sweeps it; this pins the loader-free alexnet proxy path)
    let mut cfg = BspConfig::quick("alexnet", 2, 4);
    cfg.use_loader = false;
    cfg.lr = LrSchedule::Const { base: 0.01 };
    let rep = run_bsp(&rt, &cfg).unwrap();
    let total = rep.breakdown.total();
    assert!(
        (total - rep.vtime_total).abs() < 1e-9 * total.max(1.0),
        "k=2 breakdown {total} != clock {}",
        rep.vtime_total
    );
}

#[test]
fn breakdown_reconciles_exactly_across_grid() {
    let Some(rt) = rt() else { return };
    // breakdown==clock holds by construction (audit::Ledger), barrier
    // straggle included: sweep worker count x overlap mode x exchange
    // strategy (flat, hierarchical, chunk-pipelined) x topology and demand
    // exact reconciliation everywhere, not just the k=1 no-straggle case
    let exchanges: [(StrategyKind, usize); 4] = [
        (StrategyKind::Ar, 0),
        (StrategyKind::Ring, 0),
        (StrategyKind::Hier { inner: FlatKind::Ring }, 0),
        (StrategyKind::Asa, 64), // chunk-pipelined flat exchange
    ];
    for k in [2usize, 8] {
        for overlap in [OverlapMode::Post, OverlapMode::Wfbp] {
            for (strat, chunk_kib) in exchanges {
                for topo in ["copper", "mosaic"] {
                    let mut cfg = BspConfig::quick("mlp", k, 2);
                    cfg.plan.strategy = strat;
                    cfg.plan.chunk_kib = chunk_kib;
                    cfg.plan.overlap = overlap;
                    cfg.topology = topo.to_string();
                    cfg.lr = LrSchedule::Const { base: 0.01 };
                    let rep = run_bsp(&rt, &cfg).unwrap();
                    let tag = format!(
                        "k={k} overlap={} strat={} chunk={chunk_kib} topo={topo}",
                        overlap.name(),
                        strat.name()
                    );
                    let total = rep.breakdown.total();
                    assert!(
                        (total - rep.vtime_total).abs() < 1e-9 * total.max(1.0),
                        "{tag}: breakdown {total} != clock {}",
                        rep.vtime_total
                    );
                    assert!(rep.breakdown.comm_queue >= 0.0, "{tag}");
                    // hidden time is a memo, never clock-charged: it must
                    // stay within what the serial schedule would have paid
                    assert!(
                        rep.breakdown.comm_hidden >= 0.0
                            && (overlap == OverlapMode::Wfbp
                                || rep.breakdown.comm_hidden == 0.0),
                        "{tag}: comm_hidden {}",
                        rep.breakdown.comm_hidden
                    );
                }
            }
        }
    }
}

#[test]
fn loader_grid_reconciles_and_charges_h2d() {
    let Some(rt) = rt() else { return };
    // the input-pipeline grid: both loader paths x prefetch depth must keep
    // breakdown==clock exact and charge H2D like-for-like (the parallel
    // child overlaps disk+decode, never the PCIe crossing)
    for use_loader in [false, true] {
        for q in [1usize, 2, 4] {
            let mut cfg = BspConfig::quick("alexnet", 2, 6);
            cfg.use_loader = use_loader;
            cfg.prefetch_depth = q;
            cfg.lr = LrSchedule::Const { base: 0.01 };
            let rep = run_bsp(&rt, &cfg).unwrap();
            let tag = format!("use_loader={use_loader} q={q}");
            let total = rep.breakdown.total();
            assert!(
                (total - rep.vtime_total).abs() < 1e-9 * total.max(1.0),
                "{tag}: breakdown {total} != clock {}",
                rep.vtime_total
            );
            assert!(rep.breakdown.h2d > 0.0, "{tag}: H2D must be charged on both paths");
            let lr = rep.loader.expect("image workloads report the input pipeline");
            assert_eq!(lr.prefetch_depth, if use_loader { q } else { 0 }, "{tag}");
            assert_eq!(lr.batches_loaded, cfg.iters, "{tag}: every batch collected once");
            if use_loader {
                assert!(lr.load_time > 0.0, "{tag}: child must report its work");
            } else {
                assert_eq!(rep.breakdown.load_hidden, 0.0, "{tag}: direct path hides nothing");
                assert!(rep.breakdown.load_stall > 0.0, "{tag}: direct load is all stall");
            }
        }
    }
}

#[test]
fn workers_must_fit_topology() {
    let Some(rt) = rt() else { return };
    let mut cfg = BspConfig::quick("mlp", 2, 2);
    cfg.topology = "nope".to_string();
    assert!(run_bsp(&rt, &cfg).is_err());
    let mut cfg = BspConfig::quick("definitely-not-a-model", 2, 2);
    cfg.topology = "mosaic".to_string();
    assert!(run_bsp(&rt, &cfg).is_err());
}

#[test]
fn unknown_batch_size_is_rejected() {
    let Some(rt) = rt() else { return };
    let mut cfg = BspConfig::quick("mlp", 2, 2);
    cfg.batch = 999; // no artifact compiled at this batch
    assert!(run_bsp(&rt, &cfg).is_err());
}
