//! Property tests over the substrates: topology routing, simnet pricing
//! monotonicity, JSON round-trips, config parsing, f16 conversion.

use theano_mpi::cluster::{PathKind, Topology};
use theano_mpi::precision::{f16_bits_to_f32, f32_to_f16_bits, Wire};
use theano_mpi::simnet::{phase_time, LinkParams, Transfer};
use theano_mpi::testkit::prop;
use theano_mpi::units::Bytes;
use theano_mpi::util::json::Json;
use theano_mpi::util::{split_even, Rng};

fn random_topo(rng: &mut Rng) -> Topology {
    if rng.below(2) == 0 {
        Topology::mosaic(1 + rng.below(12))
    } else {
        Topology::copper(1 + rng.below(3))
    }
}

#[test]
fn prop_routing_symmetric_and_classified() {
    prop("routing symmetric", 50, |rng| {
        let t = random_topo(rng);
        let n = t.n_gpus();
        let a = rng.below(n);
        let b = rng.below(n);
        let ab = t.path(a, b);
        let ba = t.path(b, a);
        if ab != ba {
            return Err(format!("asymmetric path {a}<->{b}"));
        }
        let (ga, gb) = (t.gpus[a], t.gpus[b]);
        let want = if a == b {
            PathKind::Local
        } else if ga.node != gb.node {
            PathKind::Network
        } else if ga.switch == gb.switch {
            PathKind::P2p
        } else {
            PathKind::QpiStaged
        };
        if ab != want {
            return Err(format!("misclassified {a}->{b}: {ab:?} vs {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_phase_time_monotone_in_bytes() {
    prop("phase time monotone", 50, |rng| {
        let t = random_topo(rng);
        let n = t.n_gpus();
        if n < 2 {
            return Ok(());
        }
        let p = LinkParams::default();
        let a = rng.below(n);
        let mut b = rng.below(n);
        if a == b {
            b = (b + 1) % n;
        }
        let small = 1 + rng.below(1 << 20) as u64;
        let big = small * (2 + rng.below(8) as u64);
        let ts = phase_time(&t, &p, &[Transfer { src: a, dst: b, bytes: Bytes(small) }], true);
        let tb = phase_time(&t, &p, &[Transfer { src: a, dst: b, bytes: Bytes(big) }], true);
        if tb < ts {
            return Err(format!("bigger transfer cheaper: {tb} < {ts}"));
        }
        Ok(())
    });
}

#[test]
fn prop_adding_transfers_never_speeds_a_phase() {
    prop("phase superadditive", 40, |rng| {
        let t = random_topo(rng);
        let n = t.n_gpus();
        if n < 2 {
            return Ok(());
        }
        let p = LinkParams::default();
        let mk = |rng: &mut Rng| {
            let a = rng.below(n);
            let mut b = rng.below(n);
            if a == b {
                b = (b + 1) % n;
            }
            Transfer { src: a, dst: b, bytes: Bytes(1 + rng.below(1 << 22) as u64) }
        };
        let t1 = mk(rng);
        let t2 = mk(rng);
        let one = phase_time(&t, &p, &[t1], true);
        let both = phase_time(&t, &p, &[t1, t2], true);
        if both.0 + 1e-12 < one.0 {
            return Err(format!("adding a transfer reduced phase time: {both} < {one}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cuda_aware_never_slower() {
    prop("cuda-aware <= staged", 40, |rng| {
        let t = random_topo(rng);
        let n = t.n_gpus();
        if n < 2 {
            return Ok(());
        }
        let p = LinkParams::default();
        let a = rng.below(n);
        let mut b = rng.below(n);
        if a == b {
            b = (b + 1) % n;
        }
        let tr = Transfer { src: a, dst: b, bytes: Bytes(1 + rng.below(1 << 24) as u64) };
        let aware = phase_time(&t, &p, &[tr], true);
        let staged = phase_time(&t, &p, &[tr], false);
        if aware.0 > staged.0 + 1e-12 {
            return Err(format!("cuda-aware slower: {aware} > {staged}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_trees() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(1_000_000) as f64) - 500_000.0),
            3 => Json::Str(format!("s{}\n\"x\"", rng.below(1000))),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop("json roundtrip", 100, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("reparse failed: {e} on {text}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

#[test]
fn prop_f16_order_preserved() {
    // monotone: a <= b implies f16(a) <= f16(b) as floats (finite range)
    prop("f16 monotone", 60, |rng| {
        let a = (rng.next_f32() - 0.5) * 100.0;
        let b = (rng.next_f32() - 0.5) * 100.0;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let flo = f16_bits_to_f32(f32_to_f16_bits(lo));
        let fhi = f16_bits_to_f32(f32_to_f16_bits(hi));
        if flo > fhi {
            return Err(format!("order broken: {lo}->{flo} vs {hi}->{fhi}"));
        }
        Ok(())
    });
}

#[test]
fn prop_wire_pack_unpack_idempotent() {
    // pack(unpack(pack(x))) == pack(x): half-precision projection is stable
    prop("wire idempotent", 30, |rng| {
        let xs: Vec<f32> = (0..64).map(|_| rng.gauss_f32() * 10.0).collect();
        for wire in [Wire::F16, Wire::Bf16] {
            let mut b1 = Vec::new();
            wire.pack(&xs, &mut b1);
            let mut back = Vec::new();
            wire.unpack(&b1, &mut back);
            let mut b2 = Vec::new();
            wire.pack(&back, &mut b2);
            if b1 != b2 {
                return Err(format!("{} projection unstable", wire.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_split_even_matches_mpi_scatterv() {
    prop("split_even", 50, |rng| {
        let n = rng.below(100_000);
        let k = 1 + rng.below(16);
        let parts = split_even(n, k);
        let total: usize = parts.iter().map(|p| p.1).sum();
        if total != n || parts.len() != k {
            return Err(format!("bad split n={n} k={k}"));
        }
        Ok(())
    });
}
