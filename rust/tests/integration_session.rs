//! Integration: the coordinator's fast experiment drivers (Fig. 3, Tables
//! 2–3, topology render) against real artifacts + the paper's claims.

use theano_mpi::collectives::StrategyKind;
use theano_mpi::models;
use theano_mpi::Session;

fn session() -> Option<Session> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let out = std::env::temp_dir().join(format!("tmpi_sess_test_{}", std::process::id()));
        Some(Session::new(dir, out).unwrap())
    } else {
        None
    }
}

#[test]
fn fig3_ratios_land_in_paper_band() {
    // paper Fig. 3: ASA ~3x and ASA16 ~6x faster comm than AR for
    // AlexNet-128b on 8 mosaic nodes; GPU sum kernel ~1.6 % of comm time
    let Some(s) = session() else { return };
    let bytes = models::full_scale_bytes(&s.rt.manifest, "alexnet").unwrap();
    let ar = s.measure_exchange(StrategyKind::Ar, 8, "mosaic", bytes, true).unwrap();
    let asa = s.measure_exchange(StrategyKind::Asa, 8, "mosaic", bytes, true).unwrap();
    let asa16 = s.measure_exchange(StrategyKind::Asa16, 8, "mosaic", bytes, true).unwrap();

    let r_asa = ar.sim_total() / asa.sim_total();
    let r_asa16 = ar.sim_total() / asa16.sim_total();
    assert!((2.0..4.6).contains(&r_asa), "AR/ASA = {r_asa} (paper ~3)");
    assert!((4.0..8.5).contains(&r_asa16), "AR/ASA16 = {r_asa16} (paper ~6)");
    let share = asa.kernel_share();
    assert!((0.004..0.06).contains(&share), "kernel share {share} (paper 0.016)");
}

#[test]
fn table2_is_exact() {
    let Some(s) = session() else { return };
    let out = s.table2().unwrap();
    assert!(out.contains("60965224"));
    assert!(out.contains("13378280"));
    assert!(out.contains("138357544"));
    assert!(!out.contains("MISMATCH"));
}

#[test]
fn table3_speedups_ordered_and_plausible() {
    let Some(s) = session() else { return };
    // ASA16 >= ASA >= AR in speedup for every model; VGG (138M params)
    // scales worst among bs-32 rows under AR (the paper's comm stress case)
    let k = 8;
    let mut vgg_ar_speedup = 0.0;
    let mut goog_ar_speedup = 0.0;
    for (model, batch) in [("alexnet", 32), ("googlenet", 32), ("vggnet", 32)] {
        let topo = models::paper_topology(model);
        let bytes = models::full_scale_bytes(&s.rt.manifest, model).unwrap();
        let t1 = models::paper_train_5120(model, batch).unwrap();
        let iters = 5120.0 / (batch as f64 * k as f64);
        let mut speedups = Vec::new();
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16] {
            let rep = s.measure_exchange(strat, k, topo, bytes, true).unwrap();
            let total = t1 / k as f64 + rep.sim_total().0 * iters;
            speedups.push(t1 / total);
        }
        assert!(
            speedups[0] <= speedups[1] && speedups[1] <= speedups[2],
            "{model}: {speedups:?}"
        );
        assert!(speedups[2] <= 8.0 + 1e-9, "{model}: {speedups:?}");
        if model == "vggnet" {
            vgg_ar_speedup = speedups[0];
        }
        if model == "googlenet" {
            goog_ar_speedup = speedups[0];
        }
    }
    // GoogLeNet (13M params, heavy compute) scales better than VGG (138M)
    assert!(goog_ar_speedup > vgg_ar_speedup);
}

#[test]
fn ring_competitive_with_asa_on_mosaic() {
    // DESIGN.md §6 ablation: on 1-GPU-per-node fabrics the ring and ASA
    // move the same bytes; ring should be within 2x either way
    let Some(s) = session() else { return };
    let bytes = models::full_scale_bytes(&s.rt.manifest, "alexnet").unwrap();
    let asa = s.measure_exchange(StrategyKind::Asa, 8, "mosaic", bytes, true).unwrap();
    let ring = s.measure_exchange(StrategyKind::Ring, 8, "mosaic", bytes, true).unwrap();
    let ratio = ring.sim_total() / asa.sim_total();
    assert!((0.5..2.5).contains(&ratio), "ring/asa = {ratio}");
}

#[test]
fn cuda_awareness_matters_on_copper() {
    // §3.2: CUDA-aware transfers avoid host staging within a PCIe switch
    let Some(s) = session() else { return };
    let bytes = models::full_scale_bytes(&s.rt.manifest, "vggnet").unwrap();
    let aware = s.measure_exchange(StrategyKind::Asa, 8, "copper", bytes, true).unwrap();
    let staged = s.measure_exchange(StrategyKind::Asa, 8, "copper", bytes, false).unwrap();
    assert!(
        staged.sim_total() > aware.sim_total(),
        "staged {} <= aware {}",
        staged.sim_total(),
        aware.sim_total()
    );
}

#[test]
fn topology_renderings() {
    let Some(s) = session() else { return };
    let copper = s.topo("copper").unwrap();
    assert!(copper.contains("socket 1"));
    assert!(copper.contains("QPI"));
    let mosaic = s.topo("mosaic").unwrap();
    assert!(mosaic.contains("node 7"));
    assert!(s.topo("gibberish").is_err());
}
