//! Wait-free backprop differential suite: WFBP changes *when* bytes move,
//! never *what* is exchanged.
//!
//! * Data path: for every strategy × op × ragged bucket plan, the wait-free
//!   schedule produces **bit-identical** parameters to the post-backward
//!   (serially-priced) schedule — they run the same inner exchanges over
//!   the same slices. A single-bucket plan must additionally be
//!   bit-identical to (and priced exactly as) today's whole-vector
//!   post-backward exchange.
//! * Pricing invariants: `comm_hidden <= serial_comm`,
//!   `overlap_fraction ∈ [0, 1]`, and the joint makespan respects the
//!   max(compute, comm) lower bounds.

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{
    exchange_wfbp, ChunkedPipeline, ExchangeCtx, ExchangeStrategy, ReduceOp, StrategyKind,
    WfbpOutcome, WfbpPlan, WireFormat,
};
use theano_mpi::simnet::LinkParams;
use theano_mpi::testkit::{all_strategy_kinds, run_exchange};
use theano_mpi::units::Secs;
use theano_mpi::{mpi, models};

/// Run one bucketed exchange across `bufs.len()` threads; rank 0's outcome.
#[allow(clippy::too_many_arguments)]
fn run_wfbp(
    kind: StrategyKind,
    chunk_elems: Option<usize>,
    plan: &WfbpPlan,
    bufs: Vec<Vec<f32>>,
    op: ReduceOp,
    topo: &Topology,
    backward: f64,
    overlap: bool,
) -> (Vec<Vec<f32>>, WfbpOutcome) {
    let k = bufs.len();
    let world = mpi::world(k);
    let links = LinkParams::default();
    let handles: Vec<_> = world
        .into_iter()
        .zip(bufs)
        .map(|(mut comm, mut buf)| {
            let topo = topo.clone();
            let plan = plan.clone();
            std::thread::spawn(move || {
                // native wire per strategy (asa16-family ships f16 itself)
                let fmt = if kind.half_wire() { WireFormat::F16 } else { WireFormat::F32 };
                let inner: Box<dyn ExchangeStrategy> = match chunk_elems {
                    Some(c) => Box::new(ChunkedPipeline::new(kind.build(fmt), c, true)),
                    None => kind.build(fmt),
                };
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo: &topo,
                    links: &links,
                    kernels: None,
                    cuda_aware: true,
                    chunk_elems: 0,
                    slice_off: 0,
                    sf_bytes: None,
                };
                let out = exchange_wfbp(
                    inner.as_ref(),
                    &plan,
                    &mut buf,
                    op,
                    &mut ctx,
                    Secs(backward),
                    1.0,
                    overlap,
                )
                .unwrap();
                (buf, out)
            })
        })
        .collect();
    let mut outs = Vec::new();
    let mut out0 = WfbpOutcome::default();
    for (i, h) in handles.into_iter().enumerate() {
        let (buf, out) = h.join().unwrap();
        if i == 0 {
            out0 = out;
        }
        outs.push(buf);
    }
    (outs, out0)
}

/// A ragged fc-heavy layer table summing to `n`.
fn ragged_table(n: usize) -> Vec<(String, usize)> {
    assert!(n >= 16);
    let conv1 = n / 16;
    let conv2 = n / 8 + 1;
    let fc6 = n / 2 + 3;
    let fc7 = n / 5;
    let fc8 = n - conv1 - conv2 - fc6 - fc7;
    vec![
        ("conv1".into(), conv1),
        ("conv2".into(), conv2),
        ("fc6".into(), fc6),
        ("fc7".into(), fc7),
        ("fc8".into(), fc8),
    ]
}

fn mk_bufs(k: usize, n: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|r| (0..n).map(|i| (((r * 131 + i * 17) % 997) as f32 - 498.0) * 1e-3).collect())
        .collect()
}

#[test]
fn wfbp_bit_identical_to_post_for_every_strategy_op_and_plan() {
    let n = 1003;
    let table = ragged_table(n);
    for kind in all_strategy_kinds() {
        // hier needs a multi-node copper world to exercise every level
        let (k, topo) = if matches!(kind, StrategyKind::Hier { .. }) {
            (16, Topology::by_name("copper", 16).unwrap())
        } else {
            (4, Topology::mosaic(4))
        };
        for op in [ReduceOp::Sum, ReduceOp::Mean] {
            for bucket_elems in [0usize, 7, 300, 5000] {
                let plan = WfbpPlan::from_layers(&table, bucket_elems);
                assert_eq!(plan.total_elems, n);
                let (wf, out_wf) =
                    run_wfbp(kind, None, &plan, mk_bufs(k, n), op, &topo, 1e-3, true);
                let (post, out_post) =
                    run_wfbp(kind, None, &plan, mk_bufs(k, n), op, &topo, 1e-3, false);
                for (r, (a, b)) in wf.iter().zip(&post).enumerate() {
                    assert_eq!(
                        a,
                        b,
                        "{}: rank {r} diverged (op={op:?} bucket_elems={bucket_elems})",
                        kind.name()
                    );
                }
                // same buckets priced serially: identical serial comm
                assert!(
                    (out_wf.serial_comm - out_post.serial_comm).abs() < 1e-12,
                    "{}: serial comm drifted",
                    kind.name()
                );
                assert_eq!(out_post.comm_hidden, 0.0);
                assert_eq!(out_post.overlap_fraction, 0.0);
            }
        }
    }
}

#[test]
fn wfbp_with_chunked_inner_is_bit_identical_to_plain_inner() {
    // ChunkedPipeline is bit-identical per exchange, so composing it under
    // WFBP must not change a single bit either
    let n = 2000;
    let table = ragged_table(n);
    let plan = WfbpPlan::from_layers(&table, 0);
    let topo = Topology::mosaic(4);
    for kind in [StrategyKind::Asa, StrategyKind::Ring, StrategyKind::Ar] {
        let (plain, _) =
            run_wfbp(kind, None, &plan, mk_bufs(4, n), ReduceOp::Sum, &topo, 1e-3, true);
        let (chunked, out) =
            run_wfbp(kind, Some(97), &plan, mk_bufs(4, n), ReduceOp::Sum, &topo, 1e-3, true);
        assert_eq!(plain, chunked, "{}", kind.name());
        assert!(out.comm.chunks > plan.n_buckets(), "chunking engaged");
    }
}

#[test]
fn single_bucket_prices_and_computes_exactly_as_today() {
    // one bucket == the whole vector released at the end of the backward
    // pass: data and price must both reduce to the plain exchange
    let n = 1003;
    let topo = Topology::mosaic(4);
    for kind in [StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring, StrategyKind::Ar] {
        let (mono_bufs, mono_rep) =
            run_exchange(kind, None, mk_bufs(4, n), ReduceOp::Sum, &topo);
        let plan = WfbpPlan::single(n);
        let backward = 0.125;
        let (wf_bufs, out) =
            run_wfbp(kind, None, &plan, mk_bufs(4, n), ReduceOp::Sum, &topo, backward, true);
        assert_eq!(mono_bufs, wf_bufs, "{}", kind.name());
        assert!(
            (out.comm_visible - mono_rep.sim_total()).abs() < 1e-12,
            "{}: single-bucket wfbp {} != monolithic {}",
            kind.name(),
            out.comm_visible,
            mono_rep.sim_total()
        );
        assert_eq!(out.buckets, 1);
        assert!(out.comm_hidden.abs() < 1e-12, "nothing can hide after the pass");
        assert!(
            (out.makespan - (Secs(backward) + mono_rep.sim_total())).abs() < 1e-12,
            "{}",
            kind.name()
        );
    }
}

#[test]
fn pricing_invariants_hold_across_strategies_and_backward_scales() {
    let n = 100_000;
    let table = ragged_table(n);
    let plan = WfbpPlan::from_layers(&table, 0);
    let topo = Topology::by_name("copper", 8).unwrap();
    for kind in [StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ar, StrategyKind::Ring] {
        // backward spanning comm-bound (tiny) to compute-bound (huge)
        for backward in [0.0, 1e-5, 1e-3, 10.0] {
            let (_, out) =
                run_wfbp(kind, None, &plan, mk_bufs(8, n), ReduceOp::Sum, &topo, backward, true);
            let label = format!("{} backward={backward}", kind.name());
            assert!(out.comm_hidden >= 0.0, "{label}");
            assert!(
                out.comm_hidden.0 <= out.serial_comm.0 + 1e-15,
                "{label}: hidden {} > serial {}",
                out.comm_hidden,
                out.serial_comm
            );
            assert!(
                (0.0..=1.0).contains(&out.overlap_fraction),
                "{label}: overlap_fraction {}",
                out.overlap_fraction
            );
            // the worker clock pays exactly the visible part
            assert!(
                (out.comm.sim_total() - out.comm_visible).abs() < 1e-12,
                "{label}: report total {} != visible {}",
                out.comm.sim_total(),
                out.comm_visible
            );
            // max(compute, comm) lower bounds on the joint makespan
            assert!(out.makespan >= backward - 1e-15, "{label}");
            let wire_floor = out.comm.sim_transfer - out.comm.sim_latency;
            assert!(
                out.makespan.0 + 1e-12 >= wire_floor.0,
                "{label}: makespan {} below wire floor {wire_floor}",
                out.makespan
            );
            assert!(
                out.makespan.0 <= backward + out.serial_comm.0 + 1e-12,
                "{label}: makespan {} exceeds the no-overlap schedule",
                out.makespan
            );
            // conservation: visible + hidden == serial
            assert!(
                (out.comm_visible + out.comm_hidden - out.serial_comm).abs() < 1e-9,
                "{label}: visible {} + hidden {} != serial {}",
                out.comm_visible,
                out.comm_hidden,
                out.serial_comm
            );
        }
    }
}

#[test]
fn wait_free_strictly_beats_post_backward_when_compute_can_hide_it() {
    // the bench acceptance property in miniature: fc-heavy layer skew on
    // copper at k=8 with a backward pass comparable to the comm time
    let n = 200_000;
    let table = ragged_table(n);
    let plan = WfbpPlan::from_layers(&table, 0);
    let topo = Topology::by_name("copper", 8).unwrap();
    // post comm for this probe is ~1e-4..1e-3 s; give backward the same order
    let backward = 2e-3;
    let asa = StrategyKind::Asa;
    let (_, post) =
        run_wfbp(asa, None, &plan, mk_bufs(8, n), ReduceOp::Sum, &topo, backward, false);
    let (_, wf) =
        run_wfbp(asa, None, &plan, mk_bufs(8, n), ReduceOp::Sum, &topo, backward, true);
    assert!(
        wf.comm_visible < post.comm_visible,
        "wfbp {} !< post {}",
        wf.comm_visible,
        post.comm_visible
    );
    assert!(wf.overlap_fraction > 0.0);
    assert!(wf.makespan < post.makespan);
    // and the end-to-end iteration wins: makespan < backward + serial comm
    assert!(wf.makespan.0 < backward + post.serial_comm.0);
}

#[test]
fn fc_heavy_skew_hides_more_than_uniform() {
    // depth-skew monotonicity at equal bytes: AlexNet's real split hides a
    // strictly larger fraction than a uniform split of the same vector
    let alex = models::builtin_full_scale_layers("alexnet").unwrap();
    let total: usize = alex.iter().map(|(_, p)| p).sum();
    let uniform = models::proxy_layer_split(total, alex.len());
    let n = 150_000;
    let plan_fc = WfbpPlan::from_layers(&alex, 0).project(n);
    let plan_uni = WfbpPlan::from_layers(&uniform, 0).project(n);
    let topo = Topology::by_name("copper", 8).unwrap();
    let backward = 5e-3; // comfortably covers this probe's comm time
    let asa = StrategyKind::Asa;
    let (_, fc) =
        run_wfbp(asa, None, &plan_fc, mk_bufs(8, n), ReduceOp::Sum, &topo, backward, true);
    let (_, uni) =
        run_wfbp(asa, None, &plan_uni, mk_bufs(8, n), ReduceOp::Sum, &topo, backward, true);
    assert!(
        fc.overlap_fraction > uni.overlap_fraction,
        "fc-heavy {} !> uniform {}",
        fc.overlap_fraction,
        uni.overlap_fraction
    );
}

#[test]
fn projected_plans_skip_empty_buckets_consistently() {
    // projecting a many-layer table onto a tiny vector rounds some buckets
    // to zero length; every rank must skip the same ones and the data must
    // still be a correct allreduce
    let goog = models::builtin_full_scale_layers("googlenet").unwrap();
    let n = 64; // far fewer elements than layers' worth of buckets
    let plan = WfbpPlan::from_layers(&goog, 0).project(n);
    assert!(plan.n_buckets() < plan.buckets.len(), "some buckets must round to zero");
    let topo = Topology::mosaic(3);
    let bufs = mk_bufs(3, n);
    let mut want = vec![0.0f32; n];
    for b in &bufs {
        for (o, x) in want.iter_mut().zip(b) {
            *o += x;
        }
    }
    let (outs, out) =
        run_wfbp(StrategyKind::Asa, None, &plan, bufs, ReduceOp::Sum, &topo, 1e-3, true);
    assert_eq!(out.buckets, plan.n_buckets());
    for (r, o) in outs.iter().enumerate() {
        theano_mpi::testkit::allclose(o, &want, 1e-5, 1e-5)
            .unwrap_or_else(|e| panic!("rank {r}: {e}"));
    }
}
