//! Differential + pinned-band suite for the sharded EASGD parameter
//! server. Runtime-free: everything drives `easgd::shard::measure_sharded`
//! (real buffers, simulated time), so the suite runs without AOT
//! artifacts.
//!
//! Numeric bands are derived from `scripts/verify_easgd_bands.py`, the
//! Python port of the pricing model + conservative arrival-ordered queue;
//! re-run it after touching the model and update the constants here.

use theano_mpi::collectives::StrategyKind;
use theano_mpi::easgd::shard::{measure_sharded, probe_center, probe_params, ShardPlan};
use theano_mpi::easgd::EasgdConfig;
use theano_mpi::precision::Wire;

fn cfg(workers: usize, servers: usize, topo: &str) -> EasgdConfig {
    let mut c = EasgdConfig::quick("mlp", workers, 0);
    c.plan.servers = servers;
    c.topology = topo.to_string();
    c
}

/// Serial host reference: replay the per-slice elastic updates in each
/// shard's recorded (virtual-arrival) serve order, round by round. Returns
/// (center slices, worker params) to compare bit-exactly against the
/// threaded run.
fn replay(
    k: usize,
    rounds: usize,
    elems: usize,
    servers: usize,
    half: bool,
    alpha: f32,
    served: &[Vec<usize>],
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let plan = ShardPlan::new(elems, k, servers).unwrap();
    let mut params: Vec<Vec<f32>> = (0..k).map(|r| probe_params(r, elems)).collect();
    let center_full = probe_center(elems);
    let mut centers: Vec<Vec<f32>> = plan
        .slices
        .iter()
        .map(|&(lo, len)| center_full[lo..lo + len].to_vec())
        .collect();
    let wire = |xs: &[f32]| -> Vec<f32> {
        if half {
            let mut bits = Vec::new();
            Wire::F16.pack(xs, &mut bits);
            let mut out = Vec::new();
            Wire::F16.unpack(&bits, &mut out);
            out
        } else {
            xs.to_vec()
        }
    };
    for r in 0..rounds {
        let mut replies: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); servers]; k];
        for (j, order) in served.iter().enumerate() {
            let slot = &order[r * k..(r + 1) * k];
            let mut sorted = slot.to_vec();
            sorted.sort_unstable();
            assert_eq!(
                sorted,
                (0..k).collect::<Vec<_>>(),
                "shard {j} serve order must be round-sliced"
            );
            let (lo, len) = plan.slices[j];
            for &w in slot {
                let sent = wire(&params[w][lo..lo + len]);
                replies[w][j] = wire(&centers[j]);
                for (c, wi) in centers[j].iter_mut().zip(&sent) {
                    *c += alpha * (wi - *c);
                }
            }
        }
        for (w, reply) in replies.iter().enumerate() {
            for (j, center) in reply.iter().enumerate() {
                let (lo, len) = plan.slices[j];
                for (p, c) in params[w][lo..lo + len].iter_mut().zip(center) {
                    *p -= alpha * (*p - c);
                }
            }
        }
    }
    (centers, params)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// servers = 1 keeps the single-server data path bit-identical: the final
/// center equals a serial host replay in arrival order, for both the f32
/// and the real f16 wire.
#[test]
fn single_server_matches_serial_reference_bit_exact() {
    for half in [false, true] {
        let mut c = cfg(3, 1, "mosaic");
        if half {
            c.plan.strategy = StrategyKind::Asa16;
        }
        let probe = measure_sharded(&c, 10_000, 3, 1e-3, 1.0).unwrap();
        let (centers, params) = replay(3, 3, 10_000, 1, half, c.alpha as f32, &probe.served);
        assert_bits_eq(&probe.centers[0], &centers[0], "center");
        for w in 0..3 {
            assert_bits_eq(&probe.final_params[w], &params[w], "params");
        }
    }
}

/// S > 1: the concatenated final center matches the serial reference
/// applying per-slice elastic updates in each shard's arrival order
/// (ragged slice sizes included).
#[test]
fn multi_shard_matches_serial_reference_bit_exact() {
    for half in [false, true] {
        let mut c = cfg(4, 3, "copper");
        if half {
            c.plan.strategy = StrategyKind::Asa16;
        }
        let probe = measure_sharded(&c, 10_001, 3, 1e-3, 1.0).unwrap();
        let (centers, params) = replay(4, 3, 10_001, 3, half, c.alpha as f32, &probe.served);
        for j in 0..3 {
            assert_bits_eq(&probe.centers[j], &centers[j], "center");
        }
        for w in 0..4 {
            assert_bits_eq(&probe.final_params[w], &params[w], "params");
        }
    }
}

/// The serve discipline is deterministic: identical probes give identical
/// timing, waits and serve orders (real thread scheduling must not leak
/// into the virtual clock).
#[test]
fn probe_is_deterministic_across_runs() {
    let c = cfg(6, 2, "copper");
    let a = measure_sharded(&c, 50_000, 3, 5e-4, 1.0).unwrap();
    let b = measure_sharded(&c, 50_000, 3, 5e-4, 1.0).unwrap();
    assert_eq!(a.comm_total.to_bits(), b.comm_total.to_bits());
    assert_eq!(a.queue_waits, b.queue_waits);
    assert_eq!(a.served, b.served);
    assert_eq!(a.vtime.to_bits(), b.vtime.to_bits());
}

/// Satellite bugfix pin — the k-worker τ=1 contention band. One exchange
/// round, zero compute, copper, 1M f32 params: every worker arrives
/// together, so worker i waits i handling slots; the aggregate is
/// 8·(down+up) + 36·handle. Band from scripts/verify_easgd_bands.py
/// (scenario A).
#[test]
fn tau1_k8_contention_band_matches_python_model() {
    let c = cfg(8, 1, "copper");
    let probe = measure_sharded(&c, 1_000_000, 1, 0.0, 1.0).unwrap();
    assert!(
        (probe.comm_total - 0.011675764705882353).abs() < 1e-10,
        "comm_total {} off the python band",
        probe.comm_total
    );
    assert!(
        (probe.queue_wait_mean - 1.866666666666665e-4).abs() < 1e-10,
        "wait mean {}",
        probe.queue_wait_mean
    );
    // p95 (nearest-rank of 8 samples) is the 7-slot wait: 7 × 53.3 µs
    assert!(
        (probe.queue_wait_p95 - 3.733333333333332e-4).abs() < 1e-10,
        "wait p95 {}",
        probe.queue_wait_p95
    );
    // the wait ladder itself: i handling slots for the i-th served
    let handle = 2.0 * 4_000_000.0 / 150e9;
    let mut waits = probe.queue_waits.clone();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (i, w) in waits.iter().enumerate() {
        assert!((w - i as f64 * handle).abs() < 1e-12, "wait[{i}] = {w}");
    }
}

/// Satellite bugfix pin — arrival-time keying. With one uniform
/// worker→server path, sent-keying plus the old double-charged down leg
/// cancel exactly; they diverge on heterogeneous paths. k=10 on copper
/// puts workers 0..7 across the NIC and workers 8..9 on the server's PCIe
/// switch; the arrival-keyed model prices 0.0249515…, the legacy
/// sent-keyed model 0.0258049… (scenario B of the python port).
#[test]
fn arrival_keyed_queue_band_on_heterogeneous_paths() {
    let c = cfg(10, 1, "copper");
    let probe = measure_sharded(&c, 1_000_000, 2, 0.0, 1.0).unwrap();
    assert!(
        (probe.comm_total - 0.024951529411764702).abs() < 1e-10,
        "comm_total {} must match the arrival-keyed band (legacy was 0.02580486…)",
        probe.comm_total
    );
}

/// Tentpole acceptance — S=4 strictly beats S=1 at τ=1, k=8 on copper,
/// with the p95 queue wait collapsing (scenario C bands).
#[test]
fn four_shards_beat_one_at_tau1_k8_on_copper() {
    let expect = [
        (1usize, 0.04222305882352944, 2.6666666666666576e-4),
        (2, 0.02179952941176473, 1.3333333333333288e-4),
        (4, 0.011587764705882367, 6.666666666666774e-5),
    ];
    let mut results = Vec::new();
    for &(servers, comm, p95) in &expect {
        let c = cfg(8, servers, "copper");
        let probe = measure_sharded(&c, 1_000_000, 4, 2e-3, 1.0).unwrap();
        assert!(
            (probe.comm_total - comm).abs() < 1e-10,
            "S={servers}: comm_total {} vs python {comm}",
            probe.comm_total
        );
        assert!(
            (probe.queue_wait_p95 - p95).abs() < 1e-10,
            "S={servers}: p95 {} vs python {p95}",
            probe.queue_wait_p95
        );
        results.push(probe);
    }
    assert!(results[2].comm_total < results[0].comm_total, "S=4 must beat S=1");
    assert!(
        results[2].queue_wait_p95 < 0.5 * results[0].queue_wait_p95,
        "queue wait must collapse"
    );
    // per-shard busy fraction falls as the load spreads (scenario C)
    assert!((results[0].shard_busy[0] - 0.13276479170464103).abs() < 1e-10);
    assert!((results[2].shard_busy[0] - 0.045747394910812554).abs() < 1e-10);
    assert!(results[2].shard_busy.iter().all(|b| *b < results[0].shard_busy[0]));
}

/// The asa16-family wire halves the priced bytes of the sharded exchange
/// (scenario D band) while the queue structure is unchanged.
#[test]
fn f16_wire_halves_sharded_comm() {
    let mut c = cfg(8, 1, "copper");
    c.plan.strategy = StrategyKind::Asa16;
    let probe = measure_sharded(&c, 1_000_000, 1, 0.0, 1.0).unwrap();
    assert!(
        (probe.comm_total - 0.006969882352941175).abs() < 1e-10,
        "f16 comm_total {}",
        probe.comm_total
    );
    assert!(probe.comm_total < 0.011675764705882353);
}

/// chunk_kib pipelining composes with sharding: streamed slices hide the
/// shard's elastic update under the incoming wire, strictly shrinking
/// total comm when chunks > 1.
#[test]
fn chunk_pipelining_composes_with_sharding() {
    let mut mono = cfg(8, 2, "copper");
    mono.plan.chunk_kib = 0;
    let mut piped = cfg(8, 2, "copper");
    piped.plan.chunk_kib = 256;
    piped.plan.pipeline = true;
    let a = measure_sharded(&mono, 1_000_000, 2, 1e-3, 1.0).unwrap();
    let b = measure_sharded(&piped, 1_000_000, 2, 1e-3, 1.0).unwrap();
    assert!(
        b.comm_total < a.comm_total,
        "pipelined {} must beat monolithic {}",
        b.comm_total,
        a.comm_total
    );
    // the ablation: chunking without the pipeline prices like monolithic
    let mut serial = piped.clone();
    serial.plan.pipeline = false;
    let c = measure_sharded(&serial, 1_000_000, 2, 1e-3, 1.0).unwrap();
    assert!((c.comm_total - a.comm_total).abs() < 1e-12);
}

/// comm_scale stretches the sharded exchange like sim_model does for the
/// trained runner (wire and handling both scale linearly).
#[test]
fn comm_scale_stretches_the_probe() {
    let c = cfg(4, 2, "mosaic");
    let base = measure_sharded(&c, 100_000, 1, 0.0, 1.0).unwrap();
    let big = measure_sharded(&c, 100_000, 1, 0.0, 10.0).unwrap();
    assert!(
        (big.comm_total - 10.0 * base.comm_total).abs() < 1e-9 * big.comm_total.max(1.0),
        "big {} vs 10x base {}",
        big.comm_total,
        base.comm_total
    );
}
