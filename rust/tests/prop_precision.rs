//! Property tests: the bit-twiddling f32→f16/bf16 converters against a
//! bit-twiddling-free round-to-nearest-even reference.
//!
//! The reference converts through f64 and *searches* the decoded half
//! codes for the nearest value (ties to the even code), so it shares no
//! logic with the shift-and-round implementation under test. The decoders
//! (`f16_bits_to_f32` / `bf16_bits_to_f32`) are themselves pinned by the
//! exhaustive round-trip test in `precision::tests`, which makes them a
//! sound oracle here. Overflow is clamped to infinity at the IEEE halfway
//! threshold; the comparisons are exact because every tie midpoint is a
//! short dyadic rational and f64 carries 53 bits.

use theano_mpi::precision::{
    bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits,
};
use theano_mpi::util::Rng;

/// Round-to-nearest-even f32 -> f16 by exhaustive nearest-value search.
fn f16_ref(x: f32) -> u16 {
    assert!(!x.is_nan());
    let sign: u16 = if x.is_sign_negative() { 0x8000 } else { 0 };
    let mag = (x as f64).abs();
    // halfway between the largest finite f16 (65504) and the next step
    // (65536): at and beyond, RTNE overflows to infinity
    if mag >= 65520.0 {
        return sign | 0x7C00;
    }
    let mut best = 0u16;
    let mut best_err = f64::INFINITY;
    for h in 0..=0x7BFFu16 {
        let err = (f16_bits_to_f32(h) as f64 - mag).abs();
        if err < best_err || (err == best_err && h & 1 == 0) {
            best = h;
            best_err = err;
        }
    }
    sign | best
}

/// Round-to-nearest-even f32 -> bf16 by exhaustive nearest-value search.
fn bf16_ref(x: f32) -> u16 {
    assert!(!x.is_nan());
    let sign: u16 = if x.is_sign_negative() { 0x8000 } else { 0 };
    let mag = (x as f64).abs();
    let max_finite = bf16_bits_to_f32(0x7F7F) as f64;
    let ulp_top = 2.0f64.powi(120); // ulp in the top binade (exp 127, 7-bit mantissa)
    if mag >= max_finite + ulp_top / 2.0 {
        return sign | 0x7F80;
    }
    let mut best = 0u16;
    let mut best_err = f64::INFINITY;
    for h in 0..=0x7F7Fu16 {
        let err = (bf16_bits_to_f32(h) as f64 - mag).abs();
        if err < best_err || (err == best_err && h & 1 == 0) {
            best = h;
            best_err = err;
        }
    }
    sign | best
}

fn check_f16(x: f32) {
    let got = f32_to_f16_bits(x);
    let want = f16_ref(x);
    assert_eq!(
        got, want,
        "f16({x:e} = {:#010x}): got {got:#06x} ({}), want {want:#06x} ({})",
        x.to_bits(),
        f16_bits_to_f32(got),
        f16_bits_to_f32(want)
    );
}

fn check_bf16(x: f32) {
    let got = f32_to_bf16_bits(x);
    let want = bf16_ref(x);
    assert_eq!(
        got, want,
        "bf16({x:e} = {:#010x}): got {got:#06x} ({}), want {want:#06x} ({})",
        x.to_bits(),
        bf16_bits_to_f32(got),
        bf16_bits_to_f32(want)
    );
}

#[test]
fn prop_f16_matches_nearest_even_reference_on_random_values() {
    let mut rng = Rng::new(0x5EED_F16);
    for case in 0..120 {
        // sweep magnitudes across binades: normals, f16 subnormals,
        // underflow-to-zero and overflow-to-inf regions
        let exp = (case % 60) as i32 - 30; // 2^-30 .. 2^29
        let x = rng.gauss_f32() * 2.0f32.powi(exp);
        check_f16(x);
    }
}

#[test]
fn prop_bf16_matches_nearest_even_reference_on_random_values() {
    let mut rng = Rng::new(0x5EED_BF16);
    for case in 0..120 {
        let exp = (case as i32 % 80) * 2 - 80; // 2^-80 .. 2^78
        let x = rng.gauss_f32() * 2.0f32.powi(exp);
        check_bf16(x);
    }
}

#[test]
fn f16_reference_agrees_on_edge_cases() {
    let edges: &[f32] = &[
        0.0,
        -0.0,
        1.0,
        -1.0,
        65504.0,                  // largest finite f16
        65519.96,                 // just below the overflow threshold
        65520.0,                  // exact halfway: ties to even -> inf
        65536.0,                  // beyond: inf
        f32::MAX,                 // deep overflow
        f32::INFINITY,
        f32::NEG_INFINITY,
        5.960_464_5e-8,           // smallest f16 subnormal (2^-24)
        2.980_232_2e-8,           // 2^-25: halfway to zero, ties to even -> 0
        4.470_348_4e-8,           // 1.5 * 2^-24: rounds up
        8.940_697e-8,             // 1.5 * 2^-25 * 2 = 3 * 2^-25: tie -> even (2^-23)
        6.103_515_6e-5,           // smallest f16 normal (2^-14)
        6.097_555_1e-5,           // largest f16 subnormal region value
        1.0 + 2.0f32.powi(-11),   // tie at the 1.0 binade -> stays 1.0
        1.0 + 3.0 * 2.0f32.powi(-11), // tie -> rounds to even (up)
        -123.456,
        0.1,
        3.141_592_7,
    ];
    for &x in edges {
        check_f16(x);
    }
}

#[test]
fn bf16_reference_agrees_on_edge_cases() {
    let tie_down = f32::from_bits(0x3F80_8000); // halfway, even below
    let tie_up = f32::from_bits(0x3F81_8000); // halfway, even above
    let max_bf16 = bf16_bits_to_f32(0x7F7F);
    let edges: &[f32] = &[
        0.0,
        -0.0,
        1.0,
        -1.0,
        tie_down,
        tie_up,
        max_bf16,
        f32::MAX, // overflows to inf in bf16
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,       // 2^-126: exactly representable in bf16
        1e-40,                   // f32 subnormal -> bf16 subnormal region
        -3.912e7,
        0.333_333_34,
    ];
    for &x in edges {
        check_bf16(x);
    }
}

#[test]
fn nan_payloads_stay_nan() {
    assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    let neg_nan = f32::from_bits(0xFFC0_0001);
    assert!(f16_bits_to_f32(f32_to_f16_bits(neg_nan)).is_nan());
    assert!(bf16_bits_to_f32(f32_to_bf16_bits(neg_nan)).is_nan());
}
