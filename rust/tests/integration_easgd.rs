//! Integration: asynchronous EASGD over real artifacts (paper §4).

use std::sync::Arc;

use theano_mpi::easgd::{run_easgd, EasgdConfig, Transport};
use theano_mpi::runtime::Runtime;
use theano_mpi::sgd::LrSchedule;

fn rt() -> Option<Arc<Runtime>> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Arc::new(Runtime::load(dir).unwrap()))
    } else {
        None
    }
}

#[test]
fn easgd_trains_and_reports_comm() {
    let Some(rt) = rt() else { return };
    let mut cfg = EasgdConfig::quick("mlp", 3, 60);
    cfg.eval_every = 20;
    cfg.lr = LrSchedule::Const { base: 0.05 };
    let rep = run_easgd(&rt, &cfg).unwrap();
    assert!(rep.final_val_err < 0.6, "val_err={}", rep.final_val_err);
    assert!(rep.comm_per_exchange > 0.0);
    assert!(rep.vtime_total > 0.0);
}

#[test]
fn mpi_transport_beats_platoon_shm_at_tau1() {
    // the paper's §4 claim: CUDA-aware SendRecv has lower comm overhead
    // than Platoon's posix-shm path (42 % lower on their testbed)
    let Some(rt) = rt() else { return };
    let mut per = Vec::new();
    for transport in [Transport::PlatoonShm, Transport::CudaAwareMpi] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 30);
        cfg.transport = transport;
        cfg.topology = "copper".into();
        cfg.sim_model = Some("alexnet".into());
        let rep = run_easgd(&rt, &cfg).unwrap();
        per.push(rep.comm_per_exchange);
    }
    let reduction = (per[0] - per[1]) / per[0];
    assert!(
        reduction > 0.2 && reduction < 0.8,
        "reduction {reduction} out of plausible band (paper 0.42)"
    );
}

#[test]
fn larger_tau_reduces_comm_total() {
    let Some(rt) = rt() else { return };
    let mut totals = Vec::new();
    for tau in [1usize, 4] {
        let mut cfg = EasgdConfig::quick("mlp", 3, 40);
        cfg.tau = tau;
        let rep = run_easgd(&rt, &cfg).unwrap();
        totals.push(rep.comm_total);
    }
    assert!(totals[1] < totals[0] / 2.0, "{totals:?}");
}

#[test]
fn sharded_easgd_trains_and_reports_queue_metrics() {
    let Some(rt) = rt() else { return };
    let mut cfg = EasgdConfig::quick("mlp", 4, 30);
    cfg.plan.servers = 2;
    cfg.lr = LrSchedule::Const { base: 0.05 };
    cfg.eval_every = 10;
    let rep = run_easgd(&rt, &cfg).unwrap();
    assert_eq!(rep.servers, 2);
    assert_eq!(rep.shard_busy.len(), 2);
    assert!(rep.shard_busy.iter().all(|b| (0.0..=1.0).contains(b)), "{:?}", rep.shard_busy);
    assert!(rep.final_val_err < 0.6, "val_err={}", rep.final_val_err);
    assert!(rep.comm_per_exchange > 0.0);
    assert!(rep.queue_wait_mean >= 0.0 && rep.queue_wait_p95 >= 0.0);
    // the breakdown's comm split reconciles with the aggregated comm time
    let comm = rep.breakdown.comm_transfer + rep.breakdown.comm_queue;
    assert!(
        (comm - rep.comm_total).abs() < 1e-9 * rep.comm_total.max(1.0),
        "breakdown comm {comm} vs comm_total {}",
        rep.comm_total
    );
}

#[test]
fn breakdown_reconciles_across_shard_grid() {
    let Some(rt) = rt() else { return };
    // the report's breakdown is the sum over workers (audit::Ledger per
    // worker), so its comm split must reconcile with the summed comm time
    // at every shard count and on both cluster topologies
    for servers in [1usize, 4] {
        for topo in ["copper", "mosaic"] {
            let mut cfg = EasgdConfig::quick("mlp", 4, 12);
            cfg.plan.servers = servers;
            cfg.topology = topo.into();
            cfg.lr = LrSchedule::Const { base: 0.05 };
            let rep = run_easgd(&rt, &cfg).unwrap();
            let tag = format!("S={servers} topo={topo}");
            let comm = rep.breakdown.comm_transfer + rep.breakdown.comm_queue;
            assert!(
                (comm - rep.comm_total).abs() < 1e-9 * rep.comm_total.max(1.0),
                "{tag}: breakdown comm {comm} vs comm_total {}",
                rep.comm_total
            );
            // workers charge only compute + exchange time: the summed
            // breakdown must account for every worker's whole clock, and
            // the straggler's clock can never exceed the summed total
            assert!(
                (rep.breakdown.total() - (rep.breakdown.compute + comm)).abs()
                    < 1e-9 * rep.breakdown.total().max(1.0),
                "{tag}: unexpected charge kinds in {:?}",
                rep.breakdown
            );
            assert!(rep.breakdown.total() >= rep.vtime_total.0 - 1e-9, "{tag}");
            assert!(rep.shard_busy.len() == servers, "{tag}");
        }
    }
}

#[test]
fn alpha_zero_never_mixes() {
    // α=0: elastic force off; center never moves and workers free-run.
    // The run must still terminate and produce finite results.
    let Some(rt) = rt() else { return };
    let mut cfg = EasgdConfig::quick("mlp", 2, 20);
    cfg.alpha = 0.0;
    let rep = run_easgd(&rt, &cfg).unwrap();
    assert!(rep.vtime_total.is_finite());
}
