//! Property tests: collective-exchange invariants over random shapes/values
//! (in-tree testkit harness; DESIGN.md §6 scheme-equivalence properties).

use std::thread;

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{
    Asa, Asa16, ExchangeCtx, ExchangeStrategy, HostAllreduce, ReduceOp, Ring,
};
use theano_mpi::mpi;
use theano_mpi::precision::Wire;
use theano_mpi::simnet::LinkParams;
use theano_mpi::testkit::{allclose, gauss_vec, prop};
use theano_mpi::util::Rng;

fn run<S: ExchangeStrategy + Clone + 'static>(
    strat: S,
    bufs: Vec<Vec<f32>>,
    op: ReduceOp,
    topo: Topology,
) -> Vec<Vec<f32>> {
    let k = bufs.len();
    let world = mpi::world(k);
    let links = LinkParams::default();
    let handles: Vec<_> = world
        .into_iter()
        .zip(bufs)
        .map(|(mut comm, mut buf)| {
            let topo = topo.clone();
            let strat = strat.clone();
            thread::spawn(move || {
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo: &topo,
                    links: &links,
                    kernels: None,
                    cuda_aware: true,
                    chunk_elems: 0,
                };
                strat.exchange(&mut buf, op, &mut ctx).unwrap();
                buf
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn host_reduce(bufs: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
    let mut out = vec![0.0f32; bufs[0].len()];
    for b in bufs {
        for (o, x) in out.iter_mut().zip(b) {
            *o += x;
        }
    }
    if op == ReduceOp::Mean {
        for o in out.iter_mut() {
            *o /= bufs.len() as f32;
        }
    }
    out
}

fn random_world(rng: &mut Rng) -> (usize, usize, Vec<Vec<f32>>, Topology) {
    let k = 1 + rng.below(8);
    let n = 1 + rng.below(3000);
    let bufs: Vec<Vec<f32>> = (0..k).map(|_| gauss_vec(rng, n, 2.0)).collect();
    let topo = if rng.below(2) == 0 {
        Topology::mosaic(k.max(1))
    } else {
        Topology::copper(k.div_ceil(8).max(1))
    };
    (k, n, bufs, topo)
}

#[test]
fn prop_asa_equals_host_sum() {
    prop("asa == host sum", 40, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let want = host_reduce(&bufs, ReduceOp::Sum);
        let outs = run(Asa, bufs, ReduceOp::Sum, topo);
        for out in &outs {
            allclose(out, &want, 1e-4, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_ring_equals_allreduce() {
    prop("ring == allreduce", 40, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let a = run(Ring, bufs.clone(), ReduceOp::Sum, topo.clone());
        let b = run(HostAllreduce, bufs, ReduceOp::Sum, topo);
        for (x, y) in a.iter().zip(&b) {
            allclose(x, y, 1e-4, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_all_ranks_agree_after_exchange() {
    prop("replica consistency", 30, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let outs = run(Asa, bufs, ReduceOp::Mean, topo);
        for out in &outs[1..] {
            // every rank must hold exactly rank 0's result (exact, since
            // each segment is computed once and broadcast)
            if out != &outs[0] {
                return Err("ranks disagree after ASA".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_asa16_close_to_f32_sum() {
    prop("asa16 within half-precision error", 30, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let want = host_reduce(&bufs, ReduceOp::Sum);
        let outs = run(Asa16::new(Wire::F16), bufs, ReduceOp::Sum, topo);
        // |err| bounded by k * eps_f16 * magnitude; generous band
        for out in &outs {
            allclose(out, &want, 2e-2, 2e-2)?;
        }
        Ok(())
    });
}

#[test]
fn prop_mean_is_sum_over_k() {
    prop("mean == sum/k", 30, |rng| {
        let (k, _, bufs, topo) = random_world(rng);
        let sums = run(Asa, bufs.clone(), ReduceOp::Sum, topo.clone());
        let means = run(Asa, bufs, ReduceOp::Mean, topo);
        let scaled: Vec<f32> = sums[0].iter().map(|x| x / k as f32).collect();
        allclose(&means[0], &scaled, 1e-5, 1e-5)
    });
}

#[test]
fn prop_sim_times_identical_across_ranks_and_positive() {
    prop("sim time sane", 20, |rng| {
        let (k, n, bufs, topo) = random_world(rng);
        if k == 1 {
            return Ok(());
        }
        let world = mpi::world(k);
        let links = LinkParams::default();
        let handles: Vec<_> = world
            .into_iter()
            .zip(bufs)
            .map(|(mut comm, mut buf)| {
                let topo = topo.clone();
                thread::spawn(move || {
                    let mut ctx = ExchangeCtx {
                        comm: &mut comm,
                        topo: &topo,
                        links: &links,
                        kernels: None,
                        cuda_aware: true,
                        chunk_elems: 0,
                    };
                    Asa.exchange(&mut buf, ReduceOp::Sum, &mut ctx).unwrap().sim_total()
                })
            })
            .collect();
        let times: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for t in &times {
            if *t <= 0.0 {
                return Err(format!("non-positive sim time {t} (k={k}, n={n})"));
            }
            if (t - times[0]).abs() > 1e-12 {
                return Err("ranks computed different sim times".into());
            }
        }
        Ok(())
    });
}
