//! Property tests: collective-exchange invariants over random shapes/values
//! (in-tree testkit harness; DESIGN.md §6 scheme-equivalence properties).
//!
//! The differential suite runs **every** strategy — AR/ASA/ASA16/Ring and
//! each `hier:*` composition, each also wrapped in `ChunkedPipeline` —
//! against a serial host reference over topology ∈ {copper, mosaic} ×
//! op ∈ {Sum, Mean} × ragged n (including n < k and n = 0). Agreement is
//! `allclose` everywhere, plus bit-identity where the strategy guarantees
//! it today (chunked == monolithic for flat strategies; rank agreement for
//! f32 data paths).
//!
//! Failing seeds reproduce with `testkit::check_one` — see the testkit
//! module docs. `TMPI_PROP_CASES` deepens the sweep (nightly CI runs 500).

use std::thread;

use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{Asa, ExchangeCtx, ExchangeStrategy, FlatKind, ReduceOp, StrategyKind};
use theano_mpi::mpi;
use theano_mpi::simnet::LinkParams;
use theano_mpi::testkit::{all_strategy_kinds, allclose, gauss_vec, prop, run_exchange};
use theano_mpi::util::Rng;

fn host_reduce(bufs: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
    let mut out = vec![0.0f32; bufs[0].len()];
    for b in bufs {
        for (o, x) in out.iter_mut().zip(b) {
            *o += x;
        }
    }
    if op == ReduceOp::Mean {
        for o in out.iter_mut() {
            *o /= bufs.len() as f32;
        }
    }
    out
}

fn random_world(rng: &mut Rng) -> (usize, usize, Vec<Vec<f32>>, Topology) {
    let k = 1 + rng.below(8);
    let n = 1 + rng.below(3000);
    let bufs: Vec<Vec<f32>> = (0..k).map(|_| gauss_vec(rng, n, 2.0)).collect();
    let topo = if rng.below(2) == 0 {
        Topology::mosaic(k.max(1))
    } else {
        Topology::copper(k.div_ceil(8).max(1))
    };
    (k, n, bufs, topo)
}

/// Ragged world for the differential suite: k up to 16 (two copper nodes),
/// n skewed small so n < k and n = 0 genuinely occur.
fn random_ragged_world(rng: &mut Rng) -> (usize, usize, Vec<Vec<f32>>, Topology) {
    let k = 1 + rng.below(16);
    let n = match rng.below(4) {
        0 => 0,
        1 => rng.below(k.max(2)), // n < k
        _ => 1 + rng.below(2400),
    };
    let bufs: Vec<Vec<f32>> = (0..k).map(|_| gauss_vec(rng, n, 2.0)).collect();
    let topo = if rng.below(2) == 0 {
        Topology::mosaic(k.max(1))
    } else {
        Topology::copper(k.div_ceil(8).max(1))
    };
    (k, n, bufs, topo)
}

/// asa16-family data paths round through f16; everything else is f32-exact
/// against the serial reference up to accumulation-order rounding. The
/// half-precision band is sized for k up to 16 ranks of N(0,2) values
/// (error ~ sqrt(k)·|x|·2^-11 per element, tail-padded for deep sweeps).
fn tolerance(kind: StrategyKind) -> (f32, f32) {
    if kind.half_wire() {
        (4e-2, 4e-2)
    } else {
        (1e-4, 1e-4)
    }
}

#[test]
fn prop_differential_every_strategy_vs_host_reference() {
    prop("differential: strategy vs serial host reference", 12, |rng| {
        let (k, n, bufs, topo) = random_ragged_world(rng);
        let op = if rng.below(2) == 0 { ReduceOp::Sum } else { ReduceOp::Mean };
        let want = host_reduce(&bufs, op);
        for kind in all_strategy_kinds() {
            // monolithic, and wrapped in the chunked pipeline scheduler
            for chunk in [None, Some(n.div_ceil(3).max(1))] {
                let (outs, _) = run_exchange(kind, chunk, bufs.clone(), op, &topo);
                let (rtol, atol) = tolerance(kind);
                for (r, out) in outs.iter().enumerate() {
                    allclose(out, &want, rtol, atol).map_err(|e| {
                        format!(
                            "{} chunk={chunk:?} k={k} n={n} topo={} op={op:?} rank={r}: {e}",
                            kind.name(),
                            topo.name
                        )
                    })?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_bit_identical_for_flat_strategies() {
    // the guarantee chunking makes today: rank-segment-aligned chunks keep
    // every element's owner rank, so flat strategies are bit-identical
    // chunked vs monolithic (hier's leader-level segmentation shifts with
    // the chunk size, so it promises allclose only — covered above)
    prop("chunked == monolithic (flat)", 10, |rng| {
        let (k, n, bufs, topo) = random_ragged_world(rng);
        for kind in
            [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let (mono, _) = run_exchange(kind, None, bufs.clone(), ReduceOp::Sum, &topo);
            let chunk = n.div_ceil(4).max(1);
            let (chun, _) = run_exchange(kind, Some(chunk), bufs.clone(), ReduceOp::Sum, &topo);
            for (r, (a, b)) in mono.iter().zip(&chun).enumerate() {
                if a != b {
                    return Err(format!(
                        "{} k={k} n={n} chunk={chunk} rank {r}: chunked diverged",
                        kind.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_f32_strategies_leave_all_ranks_identical() {
    // broadcast/allgather phases copy one reduced value everywhere; only
    // the 16-bit wire paths may leave ranks with different bytes
    prop("rank agreement (f32 paths)", 10, |rng| {
        let (k, n, bufs, topo) = random_ragged_world(rng);
        for kind in [
            StrategyKind::Ar,
            StrategyKind::Asa,
            StrategyKind::Ring,
            StrategyKind::Hier { inner: FlatKind::Ar },
            StrategyKind::Hier { inner: FlatKind::Asa },
            StrategyKind::Hier { inner: FlatKind::Ring },
        ] {
            let (outs, _) = run_exchange(kind, None, bufs.clone(), ReduceOp::Sum, &topo);
            for (r, out) in outs.iter().enumerate().skip(1) {
                if out != &outs[0] {
                    return Err(format!(
                        "{} k={k} n={n}: rank {r} disagrees with rank 0",
                        kind.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_asa_equals_host_sum() {
    prop("asa == host sum", 40, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let want = host_reduce(&bufs, ReduceOp::Sum);
        let (outs, _) = run_exchange(StrategyKind::Asa, None, bufs, ReduceOp::Sum, &topo);
        for out in &outs {
            allclose(out, &want, 1e-4, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_ring_equals_allreduce() {
    prop("ring == allreduce", 40, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let (a, _) = run_exchange(StrategyKind::Ring, None, bufs.clone(), ReduceOp::Sum, &topo);
        let (b, _) = run_exchange(StrategyKind::Ar, None, bufs, ReduceOp::Sum, &topo);
        for (x, y) in a.iter().zip(&b) {
            allclose(x, y, 1e-4, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_all_ranks_agree_after_exchange() {
    prop("replica consistency", 30, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let (outs, _) = run_exchange(StrategyKind::Asa, None, bufs, ReduceOp::Mean, &topo);
        for out in &outs[1..] {
            // every rank must hold exactly rank 0's result (exact, since
            // each segment is computed once and broadcast)
            if out != &outs[0] {
                return Err("ranks disagree after ASA".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_asa16_close_to_f32_sum() {
    prop("asa16 within half-precision error", 30, |rng| {
        let (_, _, bufs, topo) = random_world(rng);
        let want = host_reduce(&bufs, ReduceOp::Sum);
        let (outs, _) = run_exchange(StrategyKind::Asa16, None, bufs, ReduceOp::Sum, &topo);
        // |err| bounded by k * eps_f16 * magnitude; generous band
        for out in &outs {
            allclose(out, &want, 2e-2, 2e-2)?;
        }
        Ok(())
    });
}

#[test]
fn prop_mean_is_sum_over_k() {
    prop("mean == sum/k", 30, |rng| {
        let (k, _, bufs, topo) = random_world(rng);
        let (sums, _) = run_exchange(StrategyKind::Asa, None, bufs.clone(), ReduceOp::Sum, &topo);
        let (means, _) = run_exchange(StrategyKind::Asa, None, bufs, ReduceOp::Mean, &topo);
        let scaled: Vec<f32> = sums[0].iter().map(|x| x / k as f32).collect();
        allclose(&means[0], &scaled, 1e-5, 1e-5)
    });
}

#[test]
fn prop_sim_times_identical_across_ranks_and_positive() {
    prop("sim time sane", 20, |rng| {
        let (k, n, bufs, topo) = random_world(rng);
        if k == 1 {
            return Ok(());
        }
        let world = mpi::world(k);
        let links = LinkParams::default();
        let handles: Vec<_> = world
            .into_iter()
            .zip(bufs)
            .map(|(mut comm, mut buf)| {
                let topo = topo.clone();
                thread::spawn(move || {
                    let mut ctx = ExchangeCtx {
                        comm: &mut comm,
                        topo: &topo,
                        links: &links,
                        kernels: None,
                        cuda_aware: true,
                        chunk_elems: 0,
                        slice_off: 0,
                        sf_bytes: None,
                    };
                    Asa.exchange(&mut buf, ReduceOp::Sum, &mut ctx).unwrap().sim_total()
                })
            })
            .collect();
        let times: Vec<theano_mpi::units::Secs> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for t in &times {
            if *t <= 0.0 {
                return Err(format!("non-positive sim time {t} (k={k}, n={n})"));
            }
            if (*t - times[0]).abs() > 1e-12 {
                return Err("ranks computed different sim times".into());
            }
        }
        Ok(())
    });
}
