//! Bench: exchange strategies (regenerates the Fig. 3 / Table 3 numbers and
//! the segmentation/worker-count ablations from DESIGN.md §6).
//!
//! `cargo bench --offline --bench bench_collectives`

mod bench_common;

use bench_common::{bench, report};
use theano_mpi::collectives::StrategyKind;
use theano_mpi::models;
use theano_mpi::Session;

fn main() -> anyhow::Result<()> {
    let sess = Session::new(
        std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "runs",
    )?;

    // --- Fig. 3 / Table 3: simulated comm time at full model scale ---------
    for model in ["alexnet", "googlenet", "vggnet"] {
        let bytes = models::full_scale_bytes(&sess.rt.manifest, model)?;
        let topo = models::paper_topology(model);
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let rep = sess.measure_exchange(strat, 8, topo, bytes, true)?;
            report(
                &format!("comm_sim/{model}/{}", strat.name()),
                rep.sim_total(),
                "s",
            );
        }
    }

    // --- worker-count scaling of ASA (Table 1's speedup backbone) ----------
    let bytes = models::full_scale_bytes(&sess.rt.manifest, "alexnet")?;
    for k in [2usize, 4, 8] {
        let rep = sess.measure_exchange(StrategyKind::Asa, k, "mosaic", bytes, true)?;
        report(&format!("comm_sim/alexnet/asa_k{k}"), rep.sim_total(), "s");
    }

    // --- CUDA-awareness ablation -------------------------------------------
    for aware in [true, false] {
        let rep = sess.measure_exchange(StrategyKind::Asa, 8, "copper", bytes, aware)?;
        report(&format!("comm_sim/alexnet/asa_cuda_aware_{aware}"), rep.sim_total(), "s");
    }

    // --- chunked pipeline overlap sweep: monolithic vs chunked+pipelined ---
    // On copper (multi-GPU nodes, 8 workers) the pipeline hides the sum /
    // cast / host-reduce kernels of chunk i-1 under chunk i's wire time;
    // the win grows with model size (more bytes => more kernel time hidden
    // behind the same per-stream latency) — the Poseidon trend.
    for model in ["googlenet", "alexnet", "vggnet"] {
        // ascending parameter count: 13.4M, 61.0M, 138.4M
        let bytes = models::full_scale_bytes(&sess.rt.manifest, model)?;
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let mono = sess.measure_exchange(strat, 8, "copper", bytes, true)?;
            for chunks in [8usize, 32] {
                let piped =
                    sess.measure_exchange_opts(strat, 8, "copper", bytes, true, chunks, true)?;
                let serial =
                    sess.measure_exchange_opts(strat, 8, "copper", bytes, true, chunks, false)?;
                report(
                    &format!("overlap/{model}/{}/m{chunks}/win", strat.name()),
                    mono.sim_total() - piped.sim_total(),
                    "s",
                );
                report(
                    &format!("overlap/{model}/{}/m{chunks}/eff_gbps", strat.name()),
                    piped.effective_gbps(),
                    "",
                );
                if strat == StrategyKind::Asa && chunks == 8 {
                    report(
                        &format!("overlap/{model}/asa/m8/mono_vs_piped"),
                        mono.sim_total() / piped.sim_total(),
                        "x",
                    );
                }
                assert!(
                    piped.sim_total() < mono.sim_total(),
                    "{model}/{}/m{chunks}: pipelined {} !< monolithic {}",
                    strat.name(),
                    piped.sim_total(),
                    mono.sim_total()
                );
                assert!(
                    serial.sim_total() >= mono.sim_total() - 1e-12,
                    "{model}/{}/m{chunks}: serial chunking must not beat monolithic",
                    strat.name()
                );
            }
        }
    }

    // --- real wall time of the exchange machinery (1M f32, 4 workers) ------
    for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
        bench(&format!("exchange_wall/{}/1Mf32x4", strat.name()), 5, || {
            sess.measure_exchange(strat, 4, "mosaic", 4_000_000, true).unwrap();
        });
    }
    Ok(())
}
