//! Bench: exchange strategies (regenerates the Fig. 3 / Table 3 numbers and
//! the segmentation/worker-count ablations from DESIGN.md §6).
//!
//! `cargo bench --offline --bench bench_collectives`

mod bench_common;

use bench_common::{bench, report};
use theano_mpi::collectives::StrategyKind;
use theano_mpi::models;
use theano_mpi::Session;

fn main() -> anyhow::Result<()> {
    let sess = Session::new(
        std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "runs",
    )?;

    // --- Fig. 3 / Table 3: simulated comm time at full model scale ---------
    for model in ["alexnet", "googlenet", "vggnet"] {
        let bytes = models::full_scale_bytes(&sess.rt.manifest, model)?;
        let topo = models::paper_topology(model);
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let rep = sess.measure_exchange(strat, 8, topo, bytes, true)?;
            report(
                &format!("comm_sim/{model}/{}", strat.name()),
                rep.sim_total(),
                "s",
            );
        }
    }

    // --- worker-count scaling of ASA (Table 1's speedup backbone) ----------
    let bytes = models::full_scale_bytes(&sess.rt.manifest, "alexnet")?;
    for k in [2usize, 4, 8] {
        let rep = sess.measure_exchange(StrategyKind::Asa, k, "mosaic", bytes, true)?;
        report(&format!("comm_sim/alexnet/asa_k{k}"), rep.sim_total(), "s");
    }

    // --- CUDA-awareness ablation -------------------------------------------
    for aware in [true, false] {
        let rep = sess.measure_exchange(StrategyKind::Asa, 8, "copper", bytes, aware)?;
        report(&format!("comm_sim/alexnet/asa_cuda_aware_{aware}"), rep.sim_total(), "s");
    }

    // --- real wall time of the exchange machinery (1M f32, 4 workers) ------
    for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
        bench(&format!("exchange_wall/{}/1Mf32x4", strat.name()), 5, || {
            sess.measure_exchange(strat, 4, "mosaic", 4_000_000, true).unwrap();
        });
    }
    Ok(())
}
