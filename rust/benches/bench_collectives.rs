//! Bench: exchange strategies (regenerates the Fig. 3 / Table 3 numbers,
//! the segmentation/worker-count ablations from DESIGN.md §6, and the
//! wait-free backprop sweep).
//!
//! All simulated sweeps run through the runtime-free probes
//! (`coordinator::probe_exchange` / `probe_wfbp`): the priced values depend
//! only on the interconnect model, so they are deterministic and identical
//! with or without AOT artifacts — which is what lets CI's bench-smoke job
//! gate them against committed baselines (`scripts/bench_gate.py`).
//! Wall-time sections still need the runtime and skip themselves.
//!
//! `cargo bench --offline --bench bench_collectives`
//! `TMPI_BENCH_SMOKE=1 TMPI_BENCH_JSON=BENCH_collectives.json cargo bench ...`

mod bench_common;

use bench_common::{bench, report, smoke, write_json};
use theano_mpi::cluster::Topology;
use theano_mpi::collectives::wfbp::BWD_FRACTION;
use theano_mpi::collectives::{FlatKind, StrategyKind, WireFormat};
use theano_mpi::coordinator::{probe_exchange, probe_exchange_wire, probe_wfbp};
use theano_mpi::models;
use theano_mpi::Session;

/// Per-layer table of a full-scale model: manifest when a runtime is
/// present (identical numbers), in-tree registry mirror otherwise.
fn layer_table(sess: &Option<Session>, model: &str) -> Vec<(String, usize)> {
    match sess {
        Some(s) => models::full_scale_layer_table(&s.rt.manifest, model).unwrap(),
        None => models::builtin_full_scale_layers(model).unwrap(),
    }
}

fn table_bytes(table: &[(String, usize)]) -> u64 {
    4 * table.iter().map(|(_, p)| *p as u64).sum::<u64>()
}

/// Paper backward-pass seconds per iteration: Table 3's 1-GPU train time
/// for 5,120 images, scaled to one batch, times the backward fraction.
fn paper_backward(model: &str, batch: usize) -> f64 {
    models::paper_train_5120(model, batch).unwrap() * batch as f64 / 5120.0 * BWD_FRACTION
}

fn topo(name: &str, k: usize) -> Topology {
    Topology::by_name(name, k).unwrap()
}

fn main() -> anyhow::Result<()> {
    let sess = Session::new(
        std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "runs",
    )
    .ok();
    if sess.is_none() {
        println!("runtime unavailable: sim sweeps run kernel-free; wall-time benches skip");
    }
    let smoke = smoke();

    // --- Fig. 3 / Table 3: simulated comm time at full model scale ---------
    for model in ["alexnet", "googlenet", "vggnet"] {
        let bytes = table_bytes(&layer_table(&sess, model));
        let t = models::paper_topology(model);
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let rep = probe_exchange(strat, 8, topo(t, 8), bytes, true, 0, false)?;
            report(&format!("comm_sim/{model}/{}", strat.name()), rep.sim_total(), "s");
        }
    }

    // --- worker-count scaling of ASA (Table 1's speedup backbone) ----------
    let alex_bytes = table_bytes(&layer_table(&sess, "alexnet"));
    for k in [2usize, 4, 8] {
        let rep =
            probe_exchange(StrategyKind::Asa, k, topo("mosaic", k), alex_bytes, true, 0, false)?;
        report(&format!("comm_sim/alexnet/asa_k{k}"), rep.sim_total(), "s");
    }

    // --- CUDA-awareness ablation -------------------------------------------
    for aware in [true, false] {
        let rep =
            probe_exchange(StrategyKind::Asa, 8, topo("copper", 8), alex_bytes, aware, 0, false)?;
        report(&format!("comm_sim/alexnet/asa_cuda_aware_{aware}"), rep.sim_total(), "s");
    }

    // --- chunked pipeline overlap sweep: monolithic vs chunked+pipelined ---
    // On copper (multi-GPU nodes, 8 workers) the pipeline hides the sum /
    // cast / host-reduce kernels of chunk i-1 under chunk i's wire time;
    // the win grows with model size (more bytes => more kernel time hidden
    // behind the same per-stream latency) — the Poseidon trend. Ring sums
    // per step; kernel-free probes price that at zero, so its pipelined
    // total only ties the monolithic one (asserted as <=, not <).
    let overlap_models: &[&str] = if smoke {
        &["alexnet"]
    } else {
        &["googlenet", "alexnet", "vggnet"]
    };
    let overlap_strats: &[StrategyKind] = if smoke {
        &[StrategyKind::Asa]
    } else {
        &[StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
    };
    let chunk_counts: &[usize] = if smoke { &[8] } else { &[8, 32] };
    for model in overlap_models {
        let bytes = table_bytes(&layer_table(&sess, model));
        for &strat in overlap_strats {
            let mono = probe_exchange(strat, 8, topo("copper", 8), bytes, true, 0, false)?;
            for &chunks in chunk_counts {
                let piped =
                    probe_exchange(strat, 8, topo("copper", 8), bytes, true, chunks, true)?;
                let serial =
                    probe_exchange(strat, 8, topo("copper", 8), bytes, true, chunks, false)?;
                report(
                    &format!("overlap/{model}/{}/m{chunks}/win", strat.name()),
                    mono.sim_total() - piped.sim_total(),
                    "s",
                );
                report(
                    &format!("overlap/{model}/{}/m{chunks}/eff_gbps", strat.name()),
                    piped.effective_gbps(),
                    "",
                );
                if strat == StrategyKind::Asa && chunks == 8 {
                    report(
                        &format!("overlap/{model}/asa/m8/mono_vs_piped"),
                        mono.sim_total() / piped.sim_total(),
                        "x",
                    );
                }
                if strat == StrategyKind::Ring {
                    assert!(
                        piped.sim_total().0 <= mono.sim_total().0 + 1e-12,
                        "{model}/ring/m{chunks}: pipelined {} > monolithic {}",
                        piped.sim_total(),
                        mono.sim_total()
                    );
                } else {
                    assert!(
                        piped.sim_total() < mono.sim_total(),
                        "{model}/{}/m{chunks}: pipelined {} !< monolithic {}",
                        strat.name(),
                        piped.sim_total(),
                        mono.sim_total()
                    );
                }
                assert!(
                    serial.sim_total().0 >= mono.sim_total().0 - 1e-12,
                    "{model}/{}/m{chunks}: serial chunking must not beat monolithic",
                    strat.name()
                );
            }
        }
    }

    // --- wait-free backprop (WFBP) sweep ------------------------------------
    // Per-layer buckets exchanged the moment their gradients are ready
    // (Poseidon-style): bucket i's wire time hides under layers i-1..0's
    // remaining backward compute. "post" is the identical bucketed data
    // path priced after the backward pass — the ablation WFBP must beat.
    for (model, batch) in [("alexnet", 128usize), ("vggnet", 32)] {
        let table = layer_table(&sess, model);
        let backward = paper_backward(model, batch);
        for topo_name in ["copper", "mosaic"] {
            for k in [4usize, 8] {
                let asa = StrategyKind::Asa;
                let t = || topo(topo_name, k);
                let post = probe_wfbp(asa, k, t(), &table, true, 0, 0, backward, false)?;
                let wf = probe_wfbp(asa, k, t(), &table, true, 0, 0, backward, true)?;
                let tag = format!("wfbp/{model}/{topo_name}/k{k}");
                report(&format!("{tag}/post_comm"), post.comm_visible, "s");
                report(&format!("{tag}/wfbp_comm"), wf.comm_visible, "s");
                report(&format!("{tag}/overlap_fraction"), wf.overlap_fraction, "");
                // the acceptance property: wait-free strictly beats the
                // post-backward exchange, bucketed AND monolithic
                assert!(
                    wf.comm_visible < post.comm_visible,
                    "{tag}: wfbp {} !< post {}",
                    wf.comm_visible,
                    post.comm_visible
                );
                let mono = probe_exchange(
                    StrategyKind::Asa,
                    k,
                    topo(topo_name, k),
                    table_bytes(&table),
                    true,
                    0,
                    false,
                )?;
                assert!(
                    wf.comm_visible < mono.sim_total(),
                    "{tag}: wfbp {} !< monolithic post-backward {}",
                    wf.comm_visible,
                    mono.sim_total()
                );
                assert!(
                    wf.overlap_fraction > 0.0 && wf.overlap_fraction <= 1.0,
                    "{tag}: overlap_fraction {} out of (0, 1]",
                    wf.overlap_fraction
                );
                assert!(
                    wf.makespan >= backward && wf.makespan.0 < backward + post.serial_comm.0,
                    "{tag}: makespan {} outside (backward, backward + serial)",
                    wf.makespan
                );
            }
        }
    }

    // --- depth-skew ablation: the WFBP win grows with fc-heaviness ----------
    // Same total bytes and bucket count, k=8 on copper: AlexNet's real
    // split (96% of params in the fc layers backprop reaches *first*, conv
    // compute dominating the tail) must hide strictly more than a uniform
    // split of the same vector.
    {
        let alex = layer_table(&sess, "alexnet");
        let total: usize = alex.iter().map(|(_, p)| p).sum();
        let uniform = models::proxy_layer_split(total, alex.len());
        let backward = paper_backward("alexnet", 128);
        let cu8 = || topo("copper", 8);
        let fc_heavy =
            probe_wfbp(StrategyKind::Asa, 8, cu8(), &alex, true, 0, 0, backward, true)?;
        let uni =
            probe_wfbp(StrategyKind::Asa, 8, cu8(), &uniform, true, 0, 0, backward, true)?;
        report("wfbp/skew/alexnet_overlap_fraction", fc_heavy.overlap_fraction, "");
        report("wfbp/skew/uniform_overlap_fraction", uni.overlap_fraction, "");
        assert!(
            fc_heavy.overlap_fraction > uni.overlap_fraction,
            "fc-heavy skew must hide more: {} !> {}",
            fc_heavy.overlap_fraction,
            uni.overlap_fraction
        );
        // GoogLeNet for reference (uncontrolled: different bytes AND a far
        // larger backward/comm ratio, so its fraction is not comparable to
        // AlexNet's — the uniform split above is the controlled skew test)
        let goog = layer_table(&sess, "googlenet");
        let g = probe_wfbp(
            StrategyKind::Asa,
            8,
            cu8(),
            &goog,
            true,
            0,
            0,
            paper_backward("googlenet", 32),
            true,
        )?;
        report("wfbp/skew/googlenet_overlap_fraction", g.overlap_fraction, "");
    }

    // --- hierarchical two-level exchange (hier) sweep -----------------------
    // On copper every flat strategy funnels each of a node's 8 GPUs through
    // the node's single NIC. hier reduces up the switch/socket tree, runs
    // the inner strategy across node leaders only (~8x fewer NIC bytes vs
    // flat ASA/AR), and — composed with the chunked pipeline — streams
    // chunks through the level flow-shop so the leader-level NIC leg of
    // chunk i overlaps the intra-node tree of chunk i+1. Monolithic hier
    // loses to the neighbour-placed flat ring (full-vector tree legs);
    // pipelined hier beats it, and the win grows with GPUs per node.
    if !smoke {
        let bytes = alex_bytes;
        let hier_ring = StrategyKind::Hier { inner: FlatKind::Ring };
        for nodes in [2usize, 4] {
            let k = nodes * 8;
            let flat =
                probe_exchange(StrategyKind::Ring, k, topo("copper", k), bytes, true, 0, false)?;
            let flat_piped =
                probe_exchange(StrategyKind::Ring, k, topo("copper", k), bytes, true, 8, true)?;
            let hier = probe_exchange(hier_ring, k, topo("copper", k), bytes, true, 8, true)?;
            report(&format!("hier/copper{nodes}n/flat_ring"), flat.sim_total(), "s");
            report(&format!("hier/copper{nodes}n/hier_ring_piped"), hier.sim_total(), "s");
            report(
                &format!("hier/copper{nodes}n/nic_bytes_cut"),
                flat.wire_inter_bytes.as_f64() / hier.wire_inter_bytes.as_f64(),
                "x",
            );
            assert!(
                hier.sim_total() < flat.sim_total(),
                "copper {nodes}n: hier:ring piped {} !< flat ring {}",
                hier.sim_total(),
                flat.sim_total()
            );
            assert!(
                hier.sim_total() < flat_piped.sim_total(),
                "copper {nodes}n: hier:ring piped {} !< chunked flat ring {}",
                hier.sim_total(),
                flat_piped.sim_total()
            );
            assert!(
                hier.wire_inter_bytes < flat.wire_inter_bytes,
                "copper {nodes}n: hier must move fewer NIC bytes"
            );
        }
        // GPUs-per-node ablation on explicit grid fabrics: the flat/hier
        // ratio grows with GPU density (Shi et al. 2017's regime)
        let mut prev_ratio = 0.0;
        for dies in [1usize, 2, 4] {
            let gpn = 2 * dies;
            let k = 2 * gpn;
            let grid = Topology::grid(2, 2, dies);
            let flat = probe_exchange(StrategyKind::Ring, k, grid.clone(), bytes, true, 8, true)?;
            let hier = probe_exchange(hier_ring, k, grid, bytes, true, 8, true)?;
            let ratio = flat.sim_total() / hier.sim_total();
            report(&format!("hier/gpn{gpn}/flat_over_hier"), ratio, "x");
            assert!(
                ratio > prev_ratio,
                "gpn={gpn}: hier win must grow with GPUs/node ({ratio} <= {prev_ratio})"
            );
            prev_ratio = ratio;
        }
    }

    // --- gradient-compression wire sweep ------------------------------------
    // The `wire =` family end-to-end at AlexNet scale (flat ASA, k = 8):
    // per fabric, simulated exchange time, on-wire GiB, and the compression
    // ratio. The interconnect model is byte-dominated on both fabrics
    // (copper funnels 8 GPUs through one node's PCIe lanes, mosaic through
    // per-node QDR NICs), so a wire wins exactly where it cuts real bytes:
    // topk:0.01 and onebit cut >= 10x, f16 halves them, and topk:0.5's
    // 8-byte (index, value) pairs cut nothing — dense f32 must beat it,
    // since the sparsifiers also pay their encode/decode cast kernels.
    {
        let wires = [
            WireFormat::F32,
            WireFormat::F16,
            WireFormat::TopK { p: 0.01 },
            WireFormat::TopK { p: 0.5 },
            WireFormat::OneBit,
        ];
        for fabric in ["copper", "mosaic"] {
            let mut reps = Vec::new();
            for w in wires {
                let rep = probe_exchange_wire(
                    StrategyKind::Asa,
                    w,
                    8,
                    topo(fabric, 8),
                    alex_bytes,
                    true,
                    0,
                    false,
                    None,
                )?;
                report(&format!("wire/{fabric}/{}/sim", w.name()), rep.sim_total(), "s");
                report(
                    &format!("wire/{fabric}/{}/gib", w.name()),
                    rep.wire_bytes.as_f64() / (1u64 << 30) as f64,
                    "GiB",
                );
                reps.push(rep);
            }
            let (dense, f16w, tk01, tk50, onebit) =
                (&reps[0], &reps[1], &reps[2], &reps[3], &reps[4]);
            for (name, rep) in [("topk:0.01", tk01), ("onebit", onebit)] {
                assert!(
                    rep.wire_bytes * 10 <= dense.wire_bytes,
                    "{fabric}/{name}: {} bytes not a 10x cut of dense {}",
                    rep.wire_bytes,
                    dense.wire_bytes
                );
                assert!(
                    rep.compression_ratio() >= 10.0,
                    "{fabric}/{name}: ratio {} < 10x",
                    rep.compression_ratio()
                );
                assert!(
                    rep.sim_total() < dense.sim_total(),
                    "{fabric}/{name}: byte cut must pay on a byte-bound fabric ({} !< {})",
                    rep.sim_total(),
                    dense.sim_total()
                );
            }
            assert!(
                f16w.sim_total() < dense.sim_total(),
                "{fabric}: f16 halves the wire; must beat f32 ({} !< {})",
                f16w.sim_total(),
                dense.sim_total()
            );
            assert_eq!(
                tk50.wire_bytes, dense.wire_bytes,
                "{fabric}: topk:0.5's (index, value) pairs are dense-width"
            );
            assert!(
                dense.sim_total() < tk50.sim_total(),
                "{fabric}: dense f32 must beat a sparsifier that cuts no bytes ({} !< {})",
                dense.sim_total(),
                tk50.sim_total()
            );
            if fabric == "copper" {
                // the end-to-end acceptance bar: a compressed wire strictly
                // beats the native half-precision wire at k = 8 copper
                let asa16 = probe_exchange(
                    StrategyKind::Asa16,
                    8,
                    topo("copper", 8),
                    alex_bytes,
                    true,
                    0,
                    false,
                )?;
                report(
                    "wire/copper/topk:0.01_vs_asa16",
                    asa16.sim_total() / tk01.sim_total(),
                    "x",
                );
                assert!(
                    tk01.sim_total() < asa16.sim_total(),
                    "topk:0.01 {} !< asa16 {} at k=8 copper",
                    tk01.sim_total(),
                    asa16.sim_total()
                );
            }
        }
        // sf: the all-fc wire — fc6's outer-product gradient ships as
        // batch·(in + out) factor elements instead of the in·out matrix
        let dims = models::builtin_fc_dims("alexnet").unwrap();
        let (_, din, dout) = dims.iter().find(|d| d.0 == "fc6").cloned().unwrap();
        let full = 4 * (din * dout) as u64;
        let hint = 4 * (128 * (din + dout)) as u64; // batch 128
        let sf = probe_exchange_wire(
            StrategyKind::Asa,
            WireFormat::Sf,
            8,
            topo("copper", 8),
            full,
            true,
            0,
            false,
            Some(hint),
        )?;
        report("wire/copper/sf_fc6/ratio", sf.compression_ratio(), "x");
        assert!(
            sf.compression_ratio() >= 10.0,
            "sf must cut fc6's outer-product bytes >= 10x (got {})",
            sf.compression_ratio()
        );
    }

    // --- real wall time of the exchange machinery (1M f32, 4 workers) ------
    // Kernel-bound data path: needs the runtime; excluded from the gate.
    if let Some(sess) = &sess {
        if !smoke {
            for strat in
                [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
            {
                bench(&format!("exchange_wall/{}/1Mf32x4", strat.name()), 5, || {
                    sess.measure_exchange(strat, 4, "mosaic", 4_000_000, true).unwrap();
                });
            }
        }
    }

    write_json()?;
    Ok(())
}
