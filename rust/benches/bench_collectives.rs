//! Bench: exchange strategies (regenerates the Fig. 3 / Table 3 numbers and
//! the segmentation/worker-count ablations from DESIGN.md §6).
//!
//! `cargo bench --offline --bench bench_collectives`

mod bench_common;

use bench_common::{bench, report};
use theano_mpi::cluster::Topology;
use theano_mpi::collectives::{FlatKind, StrategyKind};
use theano_mpi::models;
use theano_mpi::Session;

fn main() -> anyhow::Result<()> {
    let sess = Session::new(
        std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "runs",
    )?;

    // --- Fig. 3 / Table 3: simulated comm time at full model scale ---------
    for model in ["alexnet", "googlenet", "vggnet"] {
        let bytes = models::full_scale_bytes(&sess.rt.manifest, model)?;
        let topo = models::paper_topology(model);
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let rep = sess.measure_exchange(strat, 8, topo, bytes, true)?;
            report(
                &format!("comm_sim/{model}/{}", strat.name()),
                rep.sim_total(),
                "s",
            );
        }
    }

    // --- worker-count scaling of ASA (Table 1's speedup backbone) ----------
    let bytes = models::full_scale_bytes(&sess.rt.manifest, "alexnet")?;
    for k in [2usize, 4, 8] {
        let rep = sess.measure_exchange(StrategyKind::Asa, k, "mosaic", bytes, true)?;
        report(&format!("comm_sim/alexnet/asa_k{k}"), rep.sim_total(), "s");
    }

    // --- CUDA-awareness ablation -------------------------------------------
    for aware in [true, false] {
        let rep = sess.measure_exchange(StrategyKind::Asa, 8, "copper", bytes, aware)?;
        report(&format!("comm_sim/alexnet/asa_cuda_aware_{aware}"), rep.sim_total(), "s");
    }

    // --- chunked pipeline overlap sweep: monolithic vs chunked+pipelined ---
    // On copper (multi-GPU nodes, 8 workers) the pipeline hides the sum /
    // cast / host-reduce kernels of chunk i-1 under chunk i's wire time;
    // the win grows with model size (more bytes => more kernel time hidden
    // behind the same per-stream latency) — the Poseidon trend.
    for model in ["googlenet", "alexnet", "vggnet"] {
        // ascending parameter count: 13.4M, 61.0M, 138.4M
        let bytes = models::full_scale_bytes(&sess.rt.manifest, model)?;
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            let mono = sess.measure_exchange(strat, 8, "copper", bytes, true)?;
            for chunks in [8usize, 32] {
                let piped =
                    sess.measure_exchange_opts(strat, 8, "copper", bytes, true, chunks, true)?;
                let serial =
                    sess.measure_exchange_opts(strat, 8, "copper", bytes, true, chunks, false)?;
                report(
                    &format!("overlap/{model}/{}/m{chunks}/win", strat.name()),
                    mono.sim_total() - piped.sim_total(),
                    "s",
                );
                report(
                    &format!("overlap/{model}/{}/m{chunks}/eff_gbps", strat.name()),
                    piped.effective_gbps(),
                    "",
                );
                if strat == StrategyKind::Asa && chunks == 8 {
                    report(
                        &format!("overlap/{model}/asa/m8/mono_vs_piped"),
                        mono.sim_total() / piped.sim_total(),
                        "x",
                    );
                }
                assert!(
                    piped.sim_total() < mono.sim_total(),
                    "{model}/{}/m{chunks}: pipelined {} !< monolithic {}",
                    strat.name(),
                    piped.sim_total(),
                    mono.sim_total()
                );
                assert!(
                    serial.sim_total() >= mono.sim_total() - 1e-12,
                    "{model}/{}/m{chunks}: serial chunking must not beat monolithic",
                    strat.name()
                );
            }
        }
    }

    // --- hierarchical two-level exchange (hier) sweep -----------------------
    // On copper every flat strategy funnels each of a node's 8 GPUs through
    // the node's single NIC. hier reduces up the switch/socket tree, runs
    // the inner strategy across node leaders only (~8x fewer NIC bytes vs
    // flat ASA/AR), and — composed with the chunked pipeline — streams
    // chunks through the level flow-shop so the leader-level NIC leg of
    // chunk i overlaps the intra-node tree of chunk i+1. Monolithic hier
    // loses to the neighbour-placed flat ring (full-vector tree legs);
    // pipelined hier beats it, and the win grows with GPUs per node.
    let bytes = models::full_scale_bytes(&sess.rt.manifest, "alexnet")?;
    let hier_ring = StrategyKind::Hier { inner: FlatKind::Ring };
    for nodes in [2usize, 4] {
        let k = nodes * 8;
        let flat = sess.measure_exchange(StrategyKind::Ring, k, "copper", bytes, true)?;
        let flat_piped =
            sess.measure_exchange_opts(StrategyKind::Ring, k, "copper", bytes, true, 8, true)?;
        let hier = sess.measure_exchange_opts(hier_ring, k, "copper", bytes, true, 8, true)?;
        report(&format!("hier/copper{nodes}n/flat_ring"), flat.sim_total(), "s");
        report(&format!("hier/copper{nodes}n/hier_ring_piped"), hier.sim_total(), "s");
        report(
            &format!("hier/copper{nodes}n/nic_bytes_cut"),
            flat.wire_inter_bytes as f64 / hier.wire_inter_bytes as f64,
            "x",
        );
        assert!(
            hier.sim_total() < flat.sim_total(),
            "copper {nodes}n: hier:ring piped {} !< flat ring {}",
            hier.sim_total(),
            flat.sim_total()
        );
        assert!(
            hier.sim_total() < flat_piped.sim_total(),
            "copper {nodes}n: hier:ring piped {} !< chunked flat ring {}",
            hier.sim_total(),
            flat_piped.sim_total()
        );
        assert!(
            hier.wire_inter_bytes < flat.wire_inter_bytes,
            "copper {nodes}n: hier must move fewer NIC bytes"
        );
    }
    // GPUs-per-node ablation on explicit grid fabrics: the flat/hier ratio
    // grows with GPU density (Shi et al. 2017's regime)
    let mut prev_ratio = 0.0;
    for dies in [1usize, 2, 4] {
        let gpn = 2 * dies;
        let k = 2 * gpn;
        let topo = Topology::grid(2, 2, dies);
        let flat = sess.measure_exchange_on(
            StrategyKind::Ring, k, topo.clone(), bytes, true, 8, true,
        )?;
        let hier = sess.measure_exchange_on(hier_ring, k, topo, bytes, true, 8, true)?;
        let ratio = flat.sim_total() / hier.sim_total();
        report(&format!("hier/gpn{gpn}/flat_over_hier"), ratio, "x");
        assert!(
            ratio > prev_ratio,
            "gpn={gpn}: hier win must grow with GPUs/node ({ratio} <= {prev_ratio})"
        );
        prev_ratio = ratio;
    }

    // --- real wall time of the exchange machinery (1M f32, 4 workers) ------
    for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
        bench(&format!("exchange_wall/{}/1Mf32x4", strat.name()), 5, || {
            sess.measure_exchange(strat, 4, "mosaic", 4_000_000, true).unwrap();
        });
    }
    Ok(())
}
