//! Bench: host f16/bf16 conversion throughput (the ASA16 host mirror) and
//! round-trip error magnitudes.
//!
//! `cargo bench --offline --bench bench_precision`

mod bench_common;

use bench_common::{bench, report};
use theano_mpi::precision::{roundtrip_rel_error, Wire};

fn main() {
    let xs: Vec<f32> = (0..4_000_000).map(|i| ((i as f32) * 1e-4).sin() * 30.0).collect();
    let mut bits = Vec::new();
    let mut back = Vec::new();

    for wire in [Wire::F16, Wire::Bf16] {
        bench(&format!("precision/pack_{}/4M", wire.name()), 10, || {
            wire.pack(&xs, &mut bits);
        });
        bench(&format!("precision/unpack_{}/4M", wire.name()), 10, || {
            wire.unpack(&bits, &mut back);
        });
        report(
            &format!("precision/rel_err_{}", wire.name()),
            roundtrip_rel_error(wire, &xs[..100_000]),
            "",
        );
    }
}
