//! Bench: EASGD comm overhead — the sharded-server contention sweep
//! (S ∈ {1, 2, 4} at τ=1, k=8 on copper), the CUDA-aware MPI vs
//! Platoon-shm comparison (the §4 "42 % lower" claim) and the τ sweep.
//!
//! The sharded sweep drives the comm-only probe and needs no AOT
//! artifacts; the trained-run sections skip themselves when the runtime
//! is unavailable.
//!
//! `cargo bench --offline --bench bench_easgd`

mod bench_common;

use std::sync::Arc;

use bench_common::{report, smoke, write_json};
use theano_mpi::easgd::shard::measure_sharded;
use theano_mpi::easgd::{run_easgd, EasgdConfig, Transport};
use theano_mpi::runtime::Runtime;

/// τ=1, k=8, copper, 1M-f32 center: S=4 must strictly beat S=1 on total
/// comm overhead with the p95 queue wait collapsing (bands verified against
/// scripts/verify_easgd_bands.py).
fn sharded_contention_sweep() -> anyhow::Result<()> {
    let mut s1 = None;
    for servers in [1usize, 2, 4] {
        let mut cfg = EasgdConfig::quick("mlp", 8, 0);
        cfg.plan.servers = servers;
        cfg.tau = 1;
        cfg.topology = "copper".into();
        let probe = measure_sharded(&cfg, 1_000_000, 4, 2e-3, 1.0)?;
        report(&format!("easgd/sharded/comm_total/S{servers}"), probe.comm_total, "s");
        report(&format!("easgd/sharded/queue_p95/S{servers}"), probe.queue_wait_p95, "s");
        report(
            &format!("easgd/sharded/shard_busy/S{servers}"),
            probe.shard_busy.iter().sum::<f64>() / probe.shard_busy.len() as f64,
            " (busy fraction)",
        );
        if servers == 1 {
            s1 = Some((probe.comm_total, probe.queue_wait_p95));
        }
        if servers == 4 {
            let (t1, p1) = s1.unwrap();
            assert!(
                probe.comm_total < t1,
                "S=4 comm {} must beat S=1 {}",
                probe.comm_total,
                t1
            );
            assert!(
                probe.queue_wait_p95 < 0.5 * p1,
                "S=4 p95 queue wait {} must collapse vs S=1 {}",
                probe.queue_wait_p95,
                p1
            );
            report("easgd/sharded/comm_speedup_S4_vs_S1", t1 / probe.comm_total, "x");
            report("easgd/sharded/queue_p95_drop_S4_vs_S1", p1 / probe.queue_wait_p95, "x");
        }
    }
    Ok(())
}

fn trained_benches(rt: &Arc<Runtime>) -> anyhow::Result<()> {
    let mut per = Vec::new();
    for transport in [Transport::PlatoonShm, Transport::CudaAwareMpi] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 60);
        cfg.transport = transport;
        cfg.topology = "copper".into();
        cfg.sim_model = Some("alexnet".into());
        let rep = run_easgd(rt, &cfg)?;
        report(
            &format!("easgd/comm_per_exchange/{}", transport.name()),
            rep.comm_per_exchange,
            "s",
        );
        report(
            &format!("easgd/queue_wait_p95/{}", transport.name()),
            rep.queue_wait_p95,
            "s",
        );
        per.push(rep.comm_per_exchange);
    }
    report("easgd/mpi_vs_shm_reduction", (per[0] - per[1]) / per[0], " (paper 0.42)");

    for tau in [1usize, 2, 4, 8] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 60);
        cfg.tau = tau;
        cfg.sim_model = Some("alexnet".into());
        let rep = run_easgd(rt, &cfg)?;
        report(&format!("easgd/comm_total/tau{tau}"), rep.comm_total, "s");
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    sharded_contention_sweep()?;
    if smoke() {
        // CI bench-smoke: only the deterministic sharded sweep feeds the
        // regression gate; trained runs are wall-clock noise + artifacts
        println!("smoke mode: skipping trained-run benches");
    } else {
        match Runtime::load_default() {
            Ok(rt) => trained_benches(&Arc::new(rt))?,
            Err(e) => println!("skipping trained-run benches (runtime unavailable: {e})"),
        }
    }
    write_json()?;
    Ok(())
}
