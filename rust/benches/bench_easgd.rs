//! Bench: EASGD comm overhead — CUDA-aware MPI vs Platoon-shm (the §4
//! "42 % lower" comparison) and the τ sweep.
//!
//! `cargo bench --offline --bench bench_easgd`

mod bench_common;

use std::sync::Arc;

use bench_common::report;
use theano_mpi::easgd::{run_easgd, EasgdConfig, Transport};
use theano_mpi::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);

    let mut per = Vec::new();
    for transport in [Transport::PlatoonShm, Transport::CudaAwareMpi] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 60);
        cfg.transport = transport;
        cfg.topology = "copper".into();
        cfg.sim_model = Some("alexnet".into());
        let rep = run_easgd(&rt, &cfg)?;
        report(
            &format!("easgd/comm_per_exchange/{}", transport.name()),
            rep.comm_per_exchange,
            "s",
        );
        per.push(rep.comm_per_exchange);
    }
    report("easgd/mpi_vs_shm_reduction", (per[0] - per[1]) / per[0], " (paper 0.42)");

    for tau in [1usize, 2, 4, 8] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 60);
        cfg.tau = tau;
        cfg.sim_model = Some("alexnet".into());
        let rep = run_easgd(&rt, &cfg)?;
        report(&format!("easgd/comm_total/tau{tau}"), rep.comm_total, "s");
    }
    Ok(())
}
