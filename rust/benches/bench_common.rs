//! Tiny in-tree micro-bench harness (criterion is not vendored offline).
//!
//! `bench(name, iters, f)` runs `f` `iters` times after 2 warmups and prints
//! mean / p10 / p90 wall time per call, in a stable grep-friendly format:
//!
//! ```text
//! bench <name>  mean=1.234ms  p10=1.1ms  p90=1.4ms  n=20
//! ```

// each bench binary compiles its own copy; not every bench uses every helper
#![allow(dead_code)]

use std::time::Instant;

pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    for _ in 0..2 {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    println!(
        "bench {name}  mean={}  p10={}  p90={}  n={iters}",
        fmt(mean),
        fmt(p(0.1)),
        fmt(p(0.9))
    );
}

pub fn fmt(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Report a derived scalar (simulated seconds etc.) in the same format.
pub fn report(name: &str, value: f64, unit: &str) {
    println!("bench {name}  value={value:.6}{unit}");
}
