//! Tiny in-tree micro-bench harness (criterion is not vendored offline).
//!
//! `bench(name, iters, f)` runs `f` `iters` times after 2 warmups and prints
//! mean / p10 / p90 wall time per call, in a stable grep-friendly format:
//!
//! ```text
//! bench <name>  mean=1.234ms  p10=1.1ms  p90=1.4ms  n=20
//! ```
//!
//! ## Machine-readable output (the CI bench-regression gate)
//!
//! Every `report()`ed value (and every `bench()` mean, tagged `s_wall`) is
//! also collected in-process; when `TMPI_BENCH_JSON=<path>` is set,
//! `write_json()` dumps them as `{"metrics": {name: {value, unit}}}` —
//! what `.github/workflows/tier1.yml`'s bench-smoke job uploads and
//! `scripts/bench_gate.py` diffs against the committed baselines. Simulated
//! (`report`) values are deterministic; wall times (`s_wall`) are not and
//! the gate ignores them. `TMPI_BENCH_SMOKE=1` asks benches to run their
//! reduced, artifact-free sweep (see `smoke()`).

// each bench binary compiles its own copy; not every bench uses every helper
#![allow(dead_code)]

use std::sync::Mutex;
use std::time::Instant;

static COLLECTED: Mutex<Vec<(String, f64, String)>> = Mutex::new(Vec::new());

/// Reduced-sweep mode for CI smoke runs (`TMPI_BENCH_SMOKE=1`).
pub fn smoke() -> bool {
    std::env::var("TMPI_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    for _ in 0..2 {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    println!(
        "bench {name}  mean={}  p10={}  p90={}  n={iters}",
        fmt(mean),
        fmt(p(0.1)),
        fmt(p(0.9))
    );
    collect(name, mean, "s_wall");
}

pub fn fmt(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Report a derived scalar (simulated seconds etc.) in the same format.
/// Takes `impl Into<f64>` so unit newtypes ([`theano_mpi::units::Secs`]
/// etc.) report without a manual projection.
pub fn report(name: &str, value: impl Into<f64>, unit: &str) {
    let value: f64 = value.into();
    println!("bench {name}  value={value:.6}{unit}");
    collect(name, value, unit.trim());
}

fn collect(name: &str, value: f64, unit: &str) {
    COLLECTED.lock().unwrap().push((name.to_string(), value, unit.to_string()));
    // flush after every metric: a tripped bench assertion aborts before
    // main's final write_json(), and the partial JSON is exactly what the
    // CI artifact needs to show which metrics moved
    if std::env::var("TMPI_BENCH_JSON").is_ok() {
        let _ = write_json_quiet();
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write collected metrics to `$TMPI_BENCH_JSON` (no-op when unset).
/// Call at the end of a bench main; `collect()` also flushes after every
/// metric so an aborted run leaves the partial file behind.
pub fn write_json() -> std::io::Result<()> {
    let Ok(path) = std::env::var("TMPI_BENCH_JSON") else { return Ok(()) };
    write_json_quiet()?;
    println!("bench-json -> {path}");
    Ok(())
}

fn write_json_quiet() -> std::io::Result<()> {
    let Ok(path) = std::env::var("TMPI_BENCH_JSON") else { return Ok(()) };
    let rows = COLLECTED.lock().unwrap();
    let mut out = String::from("{\n \"metrics\": {\n");
    for (i, (name, value, unit)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "  \"{}\": {{\"value\": {}, \"unit\": \"{}\"}}{comma}\n",
            json_escape(name),
            if value.is_finite() { format!("{value:.9}") } else { "null".to_string() },
            json_escape(unit)
        ));
    }
    out.push_str(" }\n}\n");
    std::fs::write(&path, out)
}
