//! Bench: PJRT runtime hot path — train/grad/apply artifact execution and
//! Literal marshalling overhead (the §Perf L3 targets).
//!
//! `cargo bench --offline --bench bench_runtime`

mod bench_common;

use std::sync::Arc;

use bench_common::{bench, report, write_json};
use theano_mpi::bsp::{run_bsp, BspConfig};
use theano_mpi::collectives::OverlapMode;
use theano_mpi::runtime::{HostTensor, Runtime};

/// End-to-end wait-free backprop on the runnable proxy: same model, same
/// seed, exchange priced post-backward vs wait-free. The overlap must be
/// visible from `tmpi train`'s accounting path (BspReport), not only from
/// the comm-only probes.
fn wfbp_e2e(rt: &Arc<Runtime>) -> anyhow::Result<()> {
    let mut base = BspConfig::quick("mlp", 4, 8);
    base.topology = "copper".into();
    base.sim_model = Some("alexnet".into());
    for overlap in [OverlapMode::Post, OverlapMode::Wfbp] {
        let mut cfg = base.clone();
        cfg.plan.overlap = overlap;
        let rep = run_bsp(rt, &cfg)?;
        report(&format!("wfbp_e2e/mlp_simalexnet/{}/vtime", overlap.name()), rep.vtime_total, "s");
        report(
            &format!("wfbp_e2e/mlp_simalexnet/{}/overlap_fraction", overlap.name()),
            rep.overlap_fraction,
            "",
        );
        if overlap == OverlapMode::Wfbp {
            assert!(
                rep.overlap_fraction > 0.0 && rep.overlap_fraction <= 1.0,
                "wfbp run must report overlap_fraction in (0,1], got {}",
                rep.overlap_fraction
            );
            assert!(rep.breakdown.comm_hidden > 0.0, "wfbp must hide comm time");
        } else {
            assert_eq!(rep.overlap_fraction, 0.0, "post ablation hides nothing");
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;

    for model in ["mlp", "alexnet", "googlenet", "vgg"] {
        let info = rt.manifest.models[model].clone();
        let n = info.param_count;
        let params = rt.init_params(model)?;
        let mom = vec![0.0f32; n];
        let key = info.key_for_batch(info.batch)?.to_string();
        let x_len: usize = info.input_shape.iter().product();
        let x = HostTensor::f32(info.input_shape.clone(), vec![0.1; x_len]);
        let y = HostTensor::i32(vec![info.batch], vec![0; info.batch]);

        rt.warmup(&format!("{key}_grad"))?;
        let mut exec_t = 0.0;
        let mut marshal_t = 0.0;
        bench(&format!("grad_step/{model}"), 5, || {
            let r = rt
                .exec(
                    &format!("{key}_grad"),
                    vec![HostTensor::f32(vec![n], params.clone()), x.clone(), y.clone()],
                )
                .unwrap();
            exec_t = r.exec_time;
            marshal_t = r.marshal_time;
        });
        report(&format!("grad_step/{model}/exec"), exec_t, "s");
        report(&format!("grad_step/{model}/marshal"), marshal_t, "s");

        rt.warmup(&info.sgd_apply)?;
        bench(&format!("sgd_apply/{model}"), 10, || {
            rt.exec(
                &info.sgd_apply,
                vec![
                    HostTensor::f32(vec![n], params.clone()),
                    HostTensor::f32(vec![n], mom.clone()),
                    HostTensor::f32(vec![n], params.clone()),
                    HostTensor::scalar_f32(0.01),
                    HostTensor::scalar_f32(0.9),
                    HostTensor::scalar_f32(1.0),
                ],
            )
            .unwrap();
        });
    }

    // kernel helpers: the ASA hot path pieces
    let k = rt.kernels();
    let a: Vec<f32> = (0..1_000_000).map(|i| i as f32 * 1e-6).collect();
    let b = a.clone();
    bench("kernels/sum_parts/2x1M", 5, || {
        k.sum_parts(&[&a, &b]).unwrap();
    });
    bench("kernels/pack_f16/1M", 5, || {
        k.pack(theano_mpi::precision::Wire::F16, &a).unwrap();
    });

    wfbp_e2e(&Arc::new(rt))?;
    write_json()?;
    Ok(())
}
