//! Bench: parallel loader (Alg. 1) — load+preprocess throughput and the
//! double-buffering ablation (DESIGN.md §6).
//!
//! `cargo bench --offline --bench bench_loader`

mod bench_common;

use bench_common::bench;
use theano_mpi::data::{ImageDataset, ImageSpec};
use theano_mpi::loader::{load_one, ParallelLoader};
use theano_mpi::simnet::LinkParams;
use theano_mpi::util::Rng;

fn main() -> anyhow::Result<()> {
    let spec = ImageSpec::default();
    let ds = ImageDataset::new(spec.clone());
    let dir = std::env::temp_dir().join(format!("tmpi_bench_loader_{}", std::process::id()));
    let batch = 32;
    let shard = ds.write_shard(&dir, 0, 1, batch, 8)?;
    let links = LinkParams::default();

    let mut rng = Rng::new(1);
    bench("loader/load_one/b32", 10, || {
        load_one(&spec, &shard.mean, batch, &links, &mut rng, "train", &shard.files[0]).unwrap();
    });

    // parallel pipeline: request-ahead then drain (double-buffered)
    bench("loader/pipeline8/parallel", 3, || {
        let mut l = ParallelLoader::spawn(spec.clone(), shard.mean.clone(), batch, links, 2);
        l.set_mode("train");
        l.request(shard.files[0].clone());
        for i in 0..8 {
            if i + 1 < 8 {
                l.request(shard.files[i + 1].clone());
            }
            let _ = l.ready().unwrap();
        }
        l.stop();
    });

    // sequential baseline for the same 8 files
    bench("loader/pipeline8/direct", 3, || {
        let mut r = Rng::new(2);
        for f in &shard.files {
            load_one(&spec, &shard.mean, batch, &links, &mut r, "train", f).unwrap();
        }
    });

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
