//! Dimensional newtypes for the pricing model.
//!
//! Every headline this repo reports is a claim about a virtual-clock
//! pricing model, so its unit discipline (seconds vs microseconds, bytes
//! vs KiB vs elements, GB/s) is the central correctness invariant. These
//! newtypes make it a *compile-time* guarantee instead of a naming
//! convention: only dimensionally valid operators exist, so mixing
//! microseconds into a seconds sum, dividing bytes by the wrong rate, or
//! truncating a byte field simply does not compile.
//!
//! * [`Secs`] — the one time currency. Every `Breakdown` field, ledger
//!   charge, simnet phase/timeline result, and `CommReport` time is a
//!   `Secs`. Supports only time-shaped arithmetic: `Secs ± Secs`,
//!   `Secs × f64` (scaling), `Secs / Secs → f64` (ratios), sums,
//!   comparisons against raw `f64` tolerances.
//! * [`Micros`] — link latencies as configured (µs). Deliberately has
//!   **no** arithmetic with [`Secs`]; the only exit is
//!   [`Micros::to_secs`]. `Secs(1.0) + Micros(5.0)` is a compile error.
//! * [`Bytes`] — traffic volume. `Bytes / GbPerS → Secs` is the pricing
//!   rule; `Bytes × f64` exists only as the checked-rounding door
//!   [`Bytes::scale_round`] (the PR 7 `as u64` truncation bug class).
//! * [`Kib`] / [`Elems`] — sizing knobs (`chunk_kib`, `bucket_kib`) and
//!   the element counts they translate to via [`Kib::elems`], the single
//!   wire-width-aware sizing rule.
//! * [`GbPerS`] — link bandwidth as configured (GB/s, decimal).
//!
//! **Adding a unit:** wrap the raw repr in a one-field tuple struct,
//! derive the comparison traits the raw type supports, implement *only*
//! the operators that are dimensionally meaningful (prefer a named
//! method over `impl Mul` when the operation does something besides pure
//! scaling — see [`Bytes::scale_round`]), give it a `Display` that
//! forwards to the repr so format precision (`{:.3}`) keeps working, and
//! add a round-trip test below. `scripts/lint_units.py`'s RAW-UNIT rule
//! flags new unit-suffixed raw fields outside this module, so the type
//! is the path of least resistance.
//!
//! The newtypes are `repr`-transparent wrappers in the informal sense:
//! `.0` projects the raw value, and conversions are written to preserve
//! the exact float operation order of the code they replaced — the
//! committed bench baselines and `scripts/verify_*_bands.py` pins are
//! byte-identical across the typed refactor.
//!
//! ```compile_fail
//! use theano_mpi::units::{Micros, Secs};
//! // microseconds cannot leak into a seconds sum without to_secs()
//! let _ = Secs(1.0) + Micros(5.0);
//! ```
//!
//! ```compile_fail
//! use theano_mpi::units::{Bytes, Secs};
//! // bytes are not time
//! let _ = Secs(1.0) + Bytes(5);
//! ```
//!
//! ```compile_fail
//! use theano_mpi::units::Bytes;
//! // no unchecked byte scaling: the only float scale is scale_round()
//! let _ = Bytes(100) * 1.5;
//! ```

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Sub, SubAssign};

use crate::collectives::{wire, StrategyKind, WireFormat};

/// Simulated/measured time in seconds — the virtual clock's only currency.
#[derive(Clone, Copy, Debug, Default, PartialEq, PartialOrd)]
pub struct Secs(pub f64);

impl Secs {
    pub const ZERO: Secs = Secs(0.0);

    pub fn abs(self) -> Secs {
        Secs(self.0.abs())
    }

    pub fn is_finite(self) -> bool {
        self.0.is_finite()
    }

    /// Larger of the two; accepts a raw `f64` so tolerance floors like
    /// `total.max(1.0)` read naturally (the literal is in seconds).
    pub fn max(self, other: impl Into<Secs>) -> Secs {
        Secs(self.0.max(other.into().0))
    }

    /// Smaller of the two (see [`max`](Self::max) for the `f64` story).
    pub fn min(self, other: impl Into<Secs>) -> Secs {
        Secs(self.0.min(other.into().0))
    }
}

impl From<f64> for Secs {
    fn from(v: f64) -> Secs {
        Secs(v)
    }
}

impl From<Secs> for f64 {
    fn from(v: Secs) -> f64 {
        v.0
    }
}

impl fmt::Display for Secs {
    /// Forwards to `f64` so precision/width specs (`{:.3}`) work.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

impl Add for Secs {
    type Output = Secs;
    fn add(self, rhs: Secs) -> Secs {
        Secs(self.0 + rhs.0)
    }
}

impl Sub for Secs {
    type Output = Secs;
    fn sub(self, rhs: Secs) -> Secs {
        Secs(self.0 - rhs.0)
    }
}

impl AddAssign for Secs {
    fn add_assign(&mut self, rhs: Secs) {
        self.0 += rhs.0;
    }
}

impl SubAssign for Secs {
    fn sub_assign(&mut self, rhs: Secs) {
        self.0 -= rhs.0;
    }
}

impl Sum for Secs {
    fn sum<I: Iterator<Item = Secs>>(iter: I) -> Secs {
        Secs(iter.map(|s| s.0).sum())
    }
}

/// Dimensionless scaling (probe→full projection, per-iteration counts).
impl Mul<f64> for Secs {
    type Output = Secs;
    fn mul(self, rhs: f64) -> Secs {
        Secs(self.0 * rhs)
    }
}

impl Mul<Secs> for f64 {
    type Output = Secs;
    fn mul(self, rhs: Secs) -> Secs {
        Secs(self * rhs.0)
    }
}

impl MulAssign<f64> for Secs {
    fn mul_assign(&mut self, rhs: f64) {
        self.0 *= rhs;
    }
}

impl Div<f64> for Secs {
    type Output = Secs;
    fn div(self, rhs: f64) -> Secs {
        Secs(self.0 / rhs)
    }
}

/// Time over time is a dimensionless ratio (speedups, shares).
impl Div<Secs> for Secs {
    type Output = f64;
    fn div(self, rhs: Secs) -> f64 {
        self.0 / rhs.0
    }
}

impl PartialEq<f64> for Secs {
    fn eq(&self, other: &f64) -> bool {
        self.0 == *other
    }
}

impl PartialEq<Secs> for f64 {
    fn eq(&self, other: &Secs) -> bool {
        *self == other.0
    }
}

impl PartialOrd<f64> for Secs {
    fn partial_cmp(&self, other: &f64) -> Option<std::cmp::Ordering> {
        self.0.partial_cmp(other)
    }
}

impl PartialOrd<Secs> for f64 {
    fn partial_cmp(&self, other: &Secs) -> Option<std::cmp::Ordering> {
        self.partial_cmp(&other.0)
    }
}

/// Link latency in microseconds, as configured. No arithmetic with
/// [`Secs`] exists on purpose — normalize through [`to_secs`](Self::to_secs).
#[derive(Clone, Copy, Debug, Default, PartialEq, PartialOrd)]
pub struct Micros(pub f64);

impl Micros {
    /// The one exit into the clock's currency.
    pub fn to_secs(self) -> Secs {
        Secs(self.0 * 1e-6)
    }
}

impl fmt::Display for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

/// Traffic volume in bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Bytes(pub u64);

impl Bytes {
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }

    pub fn abs_diff(self, other: Bytes) -> Bytes {
        Bytes(self.0.abs_diff(other.0))
    }

    /// The single checked door for float-scaling a byte count
    /// (probe→full projection, codec repricing). Rounds — a bare
    /// `as u64` floors, silently dropping bytes under fractional scales
    /// (the PR 7 `scale_times` bug).
    pub fn scale_round(self, s: f64) -> Bytes {
        debug_assert!(s.is_finite() && s >= 0.0, "bad byte scale: {s}");
        Bytes((self.0 as f64 * s).round() as u64)
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

impl Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}

impl AddAssign for Bytes {
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}

impl Sub for Bytes {
    type Output = Bytes;
    fn sub(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 - rhs.0)
    }
}

impl Sum for Bytes {
    fn sum<I: Iterator<Item = Bytes>>(iter: I) -> Bytes {
        Bytes(iter.map(|b| b.0).sum())
    }
}

/// Integer fan-out (k ranks each sending a buffer) keeps bytes exact.
impl Mul<u64> for Bytes {
    type Output = Bytes;
    fn mul(self, rhs: u64) -> Bytes {
        Bytes(self.0 * rhs)
    }
}

impl Mul<Bytes> for u64 {
    type Output = Bytes;
    fn mul(self, rhs: Bytes) -> Bytes {
        Bytes(self * rhs.0)
    }
}

/// The pricing rule: volume over bandwidth is time.
impl Div<GbPerS> for Bytes {
    type Output = Secs;
    fn div(self, rhs: GbPerS) -> Secs {
        Secs(self.0 as f64 / (rhs.0 * 1e9))
    }
}

impl PartialEq<u64> for Bytes {
    fn eq(&self, other: &u64) -> bool {
        self.0 == *other
    }
}

impl PartialEq<Bytes> for u64 {
    fn eq(&self, other: &Bytes) -> bool {
        *self == other.0
    }
}

impl PartialOrd<u64> for Bytes {
    fn partial_cmp(&self, other: &u64) -> Option<std::cmp::Ordering> {
        self.0.partial_cmp(other)
    }
}

impl PartialOrd<Bytes> for u64 {
    fn partial_cmp(&self, other: &Bytes) -> Option<std::cmp::Ordering> {
        self.partial_cmp(&other.0)
    }
}

/// A sizing knob in KiB (`chunk_kib`, `bucket_kib`) — *on-wire* KiB, so
/// translating to element counts needs the active wire's width.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Kib(pub usize);

impl Kib {
    /// Elements per this many KiB of on-wire bytes for a strategy × wire —
    /// the one shared sizing rule for `chunk_kib` and `bucket_kib`
    /// (subsumes `wire::elems_per_kib`, which delegates here). The
    /// f32 × full-width path reproduces the historical `kib * 1024 / 4`
    /// exactly (bit-identical bands).
    pub fn elems(self, strategy: StrategyKind, fmt: WireFormat) -> Elems {
        let bpe = wire::wire_bytes_per_elem(strategy, fmt);
        Elems(((self.0 as f64 * 1024.0) / bpe).floor() as usize)
    }
}

impl fmt::Display for Kib {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

/// A count of f32 elements (what sizing rules hand to the slicers).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Elems(pub usize);

impl fmt::Display for Elems {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

/// Link bandwidth in GB/s (decimal, as configured in [`crate::simnet::LinkParams`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, PartialOrd)]
pub struct GbPerS(pub f64);

impl fmt::Display for GbPerS {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::FlatKind;

    #[test]
    fn secs_arithmetic_and_comparisons() {
        let a = Secs(0.25) + Secs(0.5);
        assert_eq!(a, 0.75);
        assert_eq!(a - Secs(0.25), Secs(0.5));
        let mut b = a;
        b += Secs(0.25);
        b -= Secs(0.5);
        assert!((b - Secs(0.5)).abs() < 1e-15);
        assert_eq!(a * 2.0, 1.5);
        assert_eq!(2.0 * a, 1.5);
        assert_eq!(a / 3.0, 0.25);
        assert_eq!(Secs(1.0) / Secs(0.25), 4.0, "time ratio is dimensionless");
        let mut c = Secs(2.0);
        c *= 0.5;
        assert_eq!(c, 1.0);
        assert!(Secs(1.0) > 0.5 && 0.5 < Secs(1.0) && Secs(-1.0).abs() == 1.0);
        assert_eq!(Secs(0.2).max(1.0), 1.0);
        assert_eq!(Secs(0.2).max(Secs(0.1)), 0.2);
        assert_eq!(Secs(0.2).min(0.1), 0.1);
        assert_eq!([Secs(1.0), Secs(2.0), Secs(4.0)].into_iter().sum::<Secs>(), 7.0);
        assert_eq!(Secs::ZERO, 0.0);
        assert!(Secs(1.0).is_finite() && !Secs(f64::NAN).is_finite());
        assert_eq!(f64::from(Secs(0.5)), 0.5);
    }

    #[test]
    fn micros_normalize_through_to_secs_only() {
        assert_eq!(Micros(1.5e6).to_secs(), 1.5);
        assert_eq!(Micros(150.0).to_secs().0.to_bits(), (150.0 * 1e-6f64).to_bits());
    }

    #[test]
    fn bytes_over_bandwidth_is_the_pricing_rule() {
        let t = Bytes(2_000_000_000) / GbPerS(2.0);
        assert_eq!(t, 1.0);
        // exact float op order of the code this replaced: b / (g * 1e9)
        let b = 100u64 << 20;
        assert_eq!(
            (Bytes(b) / GbPerS(6.8)).0.to_bits(),
            (b as f64 / (6.8 * 1e9)).to_bits()
        );
    }

    #[test]
    fn bytes_arithmetic_stays_integer_exact() {
        assert_eq!(Bytes(10) + Bytes(20), 30);
        assert_eq!(Bytes(30) - Bytes(10), 20);
        assert_eq!(Bytes(10) * 3u64, 30);
        assert_eq!(3u64 * Bytes(10), Bytes(30));
        assert_eq!([Bytes(1), Bytes(2)].into_iter().sum::<Bytes>(), 3);
        assert_eq!(Bytes(7).abs_diff(Bytes(10)), 3);
        assert_eq!(Bytes(5).as_f64(), 5.0);
        let mut b = Bytes(1);
        b += Bytes(2);
        assert_eq!(b, 3);
        assert!(Bytes(10) > 5 && 5 < Bytes(10));
    }

    #[test]
    fn scale_round_rounds_instead_of_truncating() {
        // the PR 7 scale_times regression values, now pinned at the door
        assert_eq!(Bytes(999).scale_round(1.5), 1_499, "1498.5 rounds up");
        assert_eq!(Bytes(333).scale_round(1.5), 500);
        assert_eq!(Bytes(667).scale_round(1.5), 1_001);
        assert_eq!(Bytes(4_000_000).scale_round(60_965_224.0 / 1_000_000.0), 243_860_896);
        assert_eq!(Bytes(100).scale_round(1.0), 100);
    }

    #[test]
    fn kib_elems_bit_identical_to_wire_elems_per_kib() {
        let strategies = [
            StrategyKind::Ar,
            StrategyKind::Asa,
            StrategyKind::Asa16,
            StrategyKind::Ring,
            StrategyKind::Hier { inner: FlatKind::Asa16 },
            StrategyKind::Hier { inner: FlatKind::Ring },
        ];
        let formats = [
            WireFormat::F32,
            WireFormat::F16,
            WireFormat::Bf16,
            WireFormat::TopK { p: 0.01 },
            WireFormat::TopK { p: 0.5 },
            WireFormat::OneBit,
            WireFormat::Sf,
        ];
        for s in strategies {
            for f in formats {
                for kib in [0usize, 1, 7, 64, 256, 4096] {
                    assert_eq!(
                        Kib(kib).elems(s, f).0,
                        wire::elems_per_kib(kib, s, f),
                        "kib={kib} strategy={} fmt={}",
                        s.name(),
                        f.name()
                    );
                }
            }
        }
        // the historical f32 integer rule, exactly
        assert_eq!(Kib(256).elems(StrategyKind::Asa, WireFormat::F32), Elems(256 * 1024 / 4));
    }

    #[test]
    fn display_forwards_format_specs() {
        assert_eq!(format!("{:.3}", Secs(1.23456)), "1.235");
        assert_eq!(format!("{:.2}", Secs(0.5)), "0.50");
        assert_eq!(format!("{}", Bytes(1024)), "1024");
        assert_eq!(format!("{}", Kib(256)), "256");
        assert_eq!(format!("{}", Elems(64)), "64");
        assert_eq!(format!("{:.1}", GbPerS(6.8)), "6.8");
        assert_eq!(format!("{:.1}", Micros(150.0)), "150.0");
    }
}
