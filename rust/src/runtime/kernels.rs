//! Kernel-artifact helpers: the L1 Pallas kernels as callable operations.
//!
//! The ASA exchange's arithmetic — k-way segment summation and fp16
//! pack/unpack — runs through these AOT-compiled Pallas kernels, so the L1
//! kernels sit on the L3 exchange hot path exactly as the paper's CUDA
//! summation kernel did (§3.2). Buffers are chunked/padded to the fixed
//! artifact shape (`chunk` from the manifest, default 65536) and worker
//! counts are rounded up to the nearest compiled k with zero rows.

use anyhow::{anyhow, Result};

use crate::precision::Wire;

use super::tensor::HostTensor;
use super::Runtime;

pub struct Kernels<'a> {
    rt: &'a Runtime,
    chunk: usize,
}

/// Output of a kernel helper: result + time spent in PJRT execution.
pub struct KernelOut<T> {
    pub value: T,
    pub exec_time: f64,
}

impl<'a> Kernels<'a> {
    pub fn new(rt: &'a Runtime) -> Kernels<'a> {
        Kernels { rt, chunk: rt.manifest.kernels.chunk }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Smallest compiled sum-stack k that fits `k` inputs.
    fn sum_k_for(&self, k: usize) -> Result<usize> {
        let mut ks: Vec<usize> = self.rt.manifest.kernels.sum_stack.keys().copied().collect();
        ks.sort_unstable();
        ks.into_iter()
            .find(|&kk| kk >= k)
            .ok_or_else(|| anyhow!("no sum_stack artifact holds k={k}"))
    }

    /// Sum `parts` (equal-length f32 slices) elementwise via the Pallas
    /// sum-stack kernel. Returns the sum and accumulated kernel time.
    pub fn sum_parts(&self, parts: &[&[f32]]) -> Result<KernelOut<Vec<f32>>> {
        let k = parts.len();
        assert!(k >= 1);
        let n = parts[0].len();
        for p in parts {
            assert_eq!(p.len(), n, "sum_parts: ragged inputs");
        }
        if k == 1 {
            return Ok(KernelOut { value: parts[0].to_vec(), exec_time: 0.0 });
        }
        let kk = self.sum_k_for(k)?;
        let art = self.rt.manifest.kernels.sum_stack[&kk].clone();

        let mut out = vec![0.0f32; n];
        let mut exec_time = 0.0;
        let mut off = 0;
        while off < n {
            let len = (n - off).min(self.chunk);
            // (kk, chunk) stack: real rows then zero padding rows
            let mut stack = vec![0.0f32; kk * self.chunk];
            for (row, p) in parts.iter().enumerate() {
                stack[row * self.chunk..row * self.chunk + len]
                    .copy_from_slice(&p[off..off + len]);
            }
            let t = HostTensor::f32(vec![kk, self.chunk], stack);
            let r = self.rt.exec(&art, vec![t])?;
            exec_time += r.exec_time;
            out[off..off + len].copy_from_slice(&r.outputs[0].as_f32()?[..len]);
            off += len;
        }
        Ok(KernelOut { value: out, exec_time })
    }

    /// f32 -> 16-bit wire bits via the Pallas pack kernel.
    pub fn pack(&self, wire: Wire, xs: &[f32]) -> Result<KernelOut<Vec<u16>>> {
        let art = self
            .rt
            .manifest
            .kernels
            .fp16_pack
            .get(wire.name())
            .ok_or_else(|| anyhow!("no pack artifact for {}", wire.name()))?
            .clone();
        let n = xs.len();
        let mut out = vec![0u16; n];
        let mut exec_time = 0.0;
        let mut off = 0;
        while off < n {
            let len = (n - off).min(self.chunk);
            let mut buf = vec![0.0f32; self.chunk];
            buf[..len].copy_from_slice(&xs[off..off + len]);
            let r = self.rt.exec(&art, vec![HostTensor::f32(vec![self.chunk], buf)])?;
            exec_time += r.exec_time;
            out[off..off + len].copy_from_slice(&r.outputs[0].as_u16()?[..len]);
            off += len;
        }
        Ok(KernelOut { value: out, exec_time })
    }

    /// 16-bit wire bits -> f32 via the Pallas unpack kernel.
    pub fn unpack(&self, wire: Wire, bits: &[u16]) -> Result<KernelOut<Vec<f32>>> {
        let art = self
            .rt
            .manifest
            .kernels
            .fp16_unpack
            .get(wire.name())
            .ok_or_else(|| anyhow!("no unpack artifact for {}", wire.name()))?
            .clone();
        let n = bits.len();
        let mut out = vec![0.0f32; n];
        let mut exec_time = 0.0;
        let mut off = 0;
        while off < n {
            let len = (n - off).min(self.chunk);
            let mut buf = vec![0u16; self.chunk];
            buf[..len].copy_from_slice(&bits[off..off + len]);
            let r = self.rt.exec(&art, vec![HostTensor::u16(vec![self.chunk], buf)])?;
            exec_time += r.exec_time;
            out[off..off + len].copy_from_slice(&r.outputs[0].as_f32()?[..len]);
            off += len;
        }
        Ok(KernelOut { value: out, exec_time })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision;
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn sum_parts_matches_scalar_sum_across_sizes() {
        let Some(rt) = rt() else { return };
        let k = rt.kernels();
        for n in [1usize, 100, 65536, 65537, 200_000] {
            let a: Vec<f32> = (0..n).map(|i| (i % 97) as f32).collect();
            let b: Vec<f32> = (0..n).map(|i| (i % 31) as f32 * 0.5).collect();
            let c: Vec<f32> = (0..n).map(|i| -((i % 13) as f32)).collect();
            let out = k.sum_parts(&[&a, &b, &c]).unwrap(); // k=3 -> padded to 4
            for i in (0..n).step_by((n / 7).max(1)) {
                let want = a[i] + b[i] + c[i];
                assert!((out.value[i] - want).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn pack_unpack_kernel_matches_host_precision_bitexact() {
        let Some(rt) = rt() else { return };
        let k = rt.kernels();
        let xs: Vec<f32> = (0..70_000).map(|i| ((i as f32) - 35_000.0) * 0.123).collect();
        for wire in [Wire::F16, Wire::Bf16] {
            let bits = k.pack(wire, &xs).unwrap().value;
            let mut host_bits = Vec::new();
            wire.pack(&xs, &mut host_bits);
            assert_eq!(bits, host_bits, "{}", wire.name());
            let back = k.unpack(wire, &bits).unwrap().value;
            let mut host_back = Vec::new();
            wire.unpack(&bits, &mut host_back);
            assert_eq!(back, host_back, "{}", wire.name());
        }
        let _ = precision::roundtrip_rel_error(Wire::F16, &xs);
    }
}
