//! The device-service thread: sole owner of all PJRT state.
//!
//! `xla` crate wrappers hold raw pointers and are not `Send`; everything
//! PJRT lives on this thread. Requests arrive over an mpsc channel (the
//! "command queue") and replies go back on per-request channels. Execution
//! wall time is measured here, around the PJRT calls only, and reported to
//! the caller for virtual-clock accounting.

use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::tensor::{Data, HostTensor};

enum Cmd {
    Load { path: String, resp: Sender<Result<usize>> },
    Exec { exe: usize, inputs: Vec<HostTensor>, resp: Sender<Result<ExecOut>> },
    Shutdown,
}

pub struct ExecOut {
    pub outputs: Vec<HostTensor>,
    pub exec_time: f64,
    pub marshal_time: f64,
}

pub struct DeviceService {
    /// Mutex makes the service `Sync` so workers can share one `Runtime`
    /// behind an `Arc` (the lock is held only for the enqueue).
    tx: Mutex<Sender<Cmd>>,
    handle: Option<JoinHandle<()>>,
}

impl DeviceService {
    pub fn start() -> Result<DeviceService> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("PjRtClient::cpu: {e:?}")));
                        return;
                    }
                };
                let mut exes: Vec<xla::PjRtLoadedExecutable> = Vec::new();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Load { path, resp } => {
                            let r = load_one(&client, &path).map(|exe| {
                                exes.push(exe);
                                exes.len() - 1
                            });
                            let _ = resp.send(r);
                        }
                        Cmd::Exec { exe, inputs, resp } => {
                            let r = match exes.get(exe) {
                                Some(e) => exec_one(&client, e, inputs),
                                None => Err(anyhow!("bad exe id {exe}")),
                            };
                            let _ = resp.send(r);
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow!("device thread died at startup"))??;
        Ok(DeviceService { tx: Mutex::new(tx), handle: Some(handle) })
    }

    pub fn load(&self, path: &str) -> Result<usize> {
        let (resp, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Cmd::Load { path: path.to_string(), resp })
            .map_err(|_| anyhow!("device service down"))?;
        rx.recv().map_err(|_| anyhow!("device service down"))?
    }

    pub fn exec(&self, exe: usize, inputs: Vec<HostTensor>) -> Result<ExecOut> {
        let (resp, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Cmd::Exec { exe, inputs, resp })
            .map_err(|_| anyhow!("device service down"))?;
        rx.recv().map_err(|_| anyhow!("device service down"))?
    }
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn load_one(client: &xla::PjRtClient, path: &str) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse HLO {path}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile {path}: {e:?}"))
}

/// Host tensor -> device buffer, directly via `buffer_from_host_buffer`.
///
/// §Perf + leak note: the crate's `execute::<Literal>` path converts every
/// input literal to a device buffer inside the C++ shim and never frees
/// those intermediates (~tens of MB per train step at our sizes — confirmed
/// by RSS growth). Building `PjRtBuffer`s here keeps ownership in rust
/// (freed on Drop) and also saves one host-side copy per input.
/// Returns the device buffer plus an optional host-side keepalive: PJRT CPU
/// copies host memory **asynchronously**, so the source (the u16 literal
/// here; the HostTensor vecs for f32/i32) must outlive the execution —
/// dropping the literal right after `buffer_from_host_literal` is a
/// use-after-free race (crashed ~1 in 10 fp16 exchanges before keepalives).
fn to_buffer(
    client: &xla::PjRtClient,
    t: &HostTensor,
) -> Result<(xla::PjRtBuffer, Option<xla::Literal>)> {
    let out = match &t.data {
        Data::F32(v) => (
            client
                .buffer_from_host_buffer(v, &t.shape, None)
                .map_err(|e| anyhow!("f32 buffer: {e:?}"))?,
            None,
        ),
        Data::I32(v) => (
            client
                .buffer_from_host_buffer(v, &t.shape, None)
                .map_err(|e| anyhow!("i32 buffer: {e:?}"))?,
            None,
        ),
        Data::U16(v) => {
            // u16 has no NativeType in the crate, and buffer_from_host_raw_
            // bytes passes `ElementType as i32` where the C shim expects
            // PrimitiveType numbering (U16 would arrive as U8 and build a
            // half-sized buffer). Go through a rust-owned Literal instead.
            let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U16,
                &t.shape,
                &bytes,
            )
            .map_err(|e| anyhow!("u16 literal: {e:?}"))?;
            let buf = client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("u16 buffer: {e:?}"))?;
            (buf, Some(lit))
        }
    };
    Ok(out)
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| anyhow!("ty: {e:?}"))?;
    let data = match ty {
        xla::ElementType::F32 => Data::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?),
        xla::ElementType::S32 => Data::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?),
        xla::ElementType::U16 => Data::U16(lit.to_vec::<u16>().map_err(|e| anyhow!("{e:?}"))?),
        other => return Err(anyhow!("unsupported output dtype {other:?}")),
    };
    Ok(HostTensor { shape: dims, data })
}

fn exec_one(
    client: &xla::PjRtClient,
    exe: &xla::PjRtLoadedExecutable,
    inputs: Vec<HostTensor>,
) -> Result<ExecOut> {
    let m0 = Instant::now();
    let pairs: Vec<(xla::PjRtBuffer, Option<xla::Literal>)> =
        inputs.iter().map(|t| to_buffer(client, t)).collect::<Result<_>>()?;
    let (in_bufs, _keepalive): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
    let marshal_in = m0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let bufs = exe.execute_b::<xla::PjRtBuffer>(&in_bufs).map_err(|e| anyhow!("execute: {e:?}"))?;
    // to_literal_sync blocks on the output; `inputs` and `_keepalive` both
    // live past this point, covering PJRT's async host->device copies
    let result = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
    let exec_time = t0.elapsed().as_secs_f64();

    let m1 = Instant::now();
    // aot.py lowers with return_tuple=True: always a tuple, possibly 1-ary
    let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
    let outputs: Vec<HostTensor> = parts.iter().map(from_literal).collect::<Result<_>>()?;
    let marshal_time = marshal_in + m1.elapsed().as_secs_f64();

    Ok(ExecOut { outputs, exec_time, marshal_time })
}
