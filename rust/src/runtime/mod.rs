//! PJRT runtime — loads AOT artifacts and executes them on the hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `client.compile` → `execute`. The PJRT wrapper types
//! hold raw pointers (not `Send`), so a dedicated **device-service thread**
//! owns the client and all compiled executables; worker threads submit
//! requests over a channel and block on a reply. That mirrors a GPU command
//! queue and serializes device work exactly like the single-accelerator
//! testbed the virtual-time model assumes.
//!
//! Execution wall time is measured inside the service around the PJRT call
//! and returned with the outputs; it is the *compute* component of a
//! worker's virtual clock (DESIGN.md §2).

mod kernels;
mod manifest;
mod service;
mod tensor;

pub use kernels::Kernels;
pub use manifest::{ArtifactSig, FullScaleModel, Manifest, ModelInfo, TensorSig};
pub use service::{DeviceService, ExecOut};
pub use tensor::{Data, Dtype, HostTensor};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// The shared runtime: manifest + device service + lazy executable cache.
pub struct Runtime {
    svc: DeviceService,
    pub manifest: Manifest,
    dir: PathBuf,
    exes: Mutex<HashMap<String, usize>>,
}

/// Result of one artifact execution.
pub struct ExecResult {
    pub outputs: Vec<HostTensor>,
    /// Seconds spent in the PJRT execute call (device compute time).
    pub exec_time: f64,
    /// Seconds spent converting HostTensor <-> Literal (host marshalling).
    pub marshal_time: f64,
}

impl Runtime {
    /// Load the manifest and start the device service. Artifacts are
    /// compiled lazily on first execution and cached for the process life.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&text)?;
        let svc = DeviceService::start()?;
        Ok(Runtime { svc, manifest, dir, exes: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts dir: $TMPI_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::load(dir)
    }

    fn exe_id(&self, name: &str) -> Result<usize> {
        if let Some(&id) = self.exes.lock().unwrap().get(name) {
            return Ok(id);
        }
        let art = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&art.file);
        let id = self.svc.load(path.to_str().unwrap())?;
        self.exes.lock().unwrap().insert(name.to_string(), id);
        Ok(id)
    }

    /// Pre-compile an artifact (hide XLA compile latency before timing).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.exe_id(name).map(|_| ())
    }

    /// Execute artifact `name` with shape/dtype validation from the manifest.
    pub fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<ExecResult> {
        let sig = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if inputs.len() != sig.inputs.len() {
            return Err(anyhow!(
                "'{name}' wants {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(&sig.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                return Err(anyhow!(
                    "'{name}' input {i}: expected {:?}{:?}, got {:?}{:?}",
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape
                ));
            }
        }
        let id = self.exe_id(name)?;
        if std::env::var("TMPI_TRACE_EXEC").is_ok() {
            eprintln!("[exec] {name}");
        }
        let out = self.svc.exec(id, inputs)?;
        Ok(ExecResult {
            outputs: out.outputs,
            exec_time: out.exec_time,
            marshal_time: out.marshal_time,
        })
    }

    /// Initial flat parameter vector for a model (raw f32 LE from aot.py).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let info = self
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
        let path = self.dir.join(&info.init_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != 4 * info.param_count {
            return Err(anyhow!(
                "{path:?}: expected {} f32s, file has {} bytes",
                info.param_count,
                bytes.len()
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Kernel helpers (sum/pack/unpack artifacts) bound to this runtime.
    pub fn kernels(&self) -> Kernels<'_> {
        Kernels::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rt() -> Option<Runtime> {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn manifest_loads_and_models_present() {
        let Some(rt) = rt() else { return };
        for m in ["mlp", "alexnet", "googlenet", "vgg", "transformer"] {
            assert!(rt.manifest.models.contains_key(m), "{m}");
        }
    }

    #[test]
    fn exec_validates_shapes() {
        let Some(rt) = rt() else { return };
        // wrong arity
        assert!(rt.exec("sum_stack_k2", vec![]).is_err());
        // wrong shape
        let bad = HostTensor::f32(vec![2, 2], vec![0.0; 4]);
        assert!(rt.exec("sum_stack_k2", vec![bad]).is_err());
    }

    #[test]
    fn init_params_match_manifest_count() {
        let Some(rt) = rt() else { return };
        let p = rt.init_params("mlp").unwrap();
        assert_eq!(p.len(), rt.manifest.models["mlp"].param_count);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sum_stack_kernel_runs_and_sums() {
        let Some(rt) = rt() else { return };
        let n = rt.manifest.kernels.chunk;
        let mut stack = vec![0.0f32; 2 * n];
        for (i, v) in stack.iter_mut().enumerate() {
            *v = (i % 1000) as f32 * 0.25;
        }
        let t = HostTensor::f32(vec![2, n], stack.clone());
        let out = rt.exec("sum_stack_k2", vec![t]).unwrap();
        let got = out.outputs[0].as_f32().unwrap();
        for i in (0..n).step_by(4097) {
            let want = stack[i] + stack[n + i];
            assert!((got[i] - want).abs() < 1e-5, "i={i}");
        }
        assert!(out.exec_time > 0.0);
    }
}
