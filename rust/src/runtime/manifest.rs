//! manifest.json — the contract between aot.py (L2) and this runtime (L3).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

use super::tensor::Dtype;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// A runnable proxy model binding (train/grad/eval/sgd_apply artifacts).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub kind: String,
    pub param_count: usize,
    pub batch: usize,
    pub eval_batch: usize,
    /// per-worker batch size -> artifact key prefix (e.g. 128 -> "alexnet128")
    pub batches: BTreeMap<usize, String>,
    pub classes: Option<usize>,
    pub input_shape: Vec<usize>,
    pub init_file: String,
    /// (name, offset, size) per parameter tensor — the ASA split points.
    pub segments: Vec<(String, usize, usize)>,
    pub sgd_apply: String,
}

impl ModelInfo {
    /// Artifact name prefix for a per-worker batch size.
    pub fn key_for_batch(&self, bs: usize) -> Result<&str> {
        self.batches
            .get(&bs)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("no artifact for batch {bs} (have {:?})", self.batches.keys()))
    }
}

/// Full-scale architecture metadata (the paper's Table 2 — drives comm sim).
#[derive(Clone, Debug)]
pub struct FullScaleModel {
    pub depth: usize,
    pub params: usize,
    pub paper_params: usize,
    pub batches: Vec<usize>,
    /// (layer name, param count) in exchange order.
    pub segments: Vec<(String, usize)>,
    /// Per-layer parameter counts in exchange order — the wait-free
    /// backprop bucket boundaries. Emitted by aot.py as `layers`; older
    /// manifests fall back to the `segments` counts (same granularity).
    pub layers: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct KernelIndex {
    pub chunk: usize,
    /// worker count -> sum artifact name
    pub sum_stack: BTreeMap<usize, String>,
    /// wire name ("f16"/"bf16") -> artifact names
    pub fp16_pack: BTreeMap<String, String>,
    pub fp16_unpack: BTreeMap<String, String>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSig>,
    pub models: BTreeMap<String, ModelInfo>,
    pub full_scale: BTreeMap<String, FullScaleModel>,
    pub kernels: KernelIndex,
}

fn sig_list(v: &Json) -> Result<Vec<TensorSig>> {
    v.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                shape: t.get("shape")?.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?,
                dtype: Dtype::parse(t.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in root.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: sig_list(a.get("inputs")?)?,
                    outputs: sig_list(a.get("outputs")?)?,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_obj()? {
            let mut batches = BTreeMap::new();
            for (bs, key) in m.get("batches")?.as_obj()? {
                batches.insert(bs.parse::<usize>()?, key.as_str()?.to_string());
            }
            let segments = m
                .get("segments")?
                .as_arr()?
                .iter()
                .map(|s| {
                    let s = s.as_arr()?;
                    Ok((s[0].as_str()?.to_string(), s[1].as_usize()?, s[2].as_usize()?))
                })
                .collect::<Result<_>>()?;
            models.insert(
                name.clone(),
                ModelInfo {
                    kind: m.get("kind")?.as_str()?.to_string(),
                    param_count: m.get("param_count")?.as_usize()?,
                    batch: m.get("batch")?.as_usize()?,
                    eval_batch: m.get("eval_batch")?.as_usize()?,
                    batches,
                    classes: m.opt("classes").and_then(|c| c.as_usize().ok()),
                    input_shape: m
                        .get("input_shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    init_file: m.get("init_file")?.as_str()?.to_string(),
                    segments,
                    sgd_apply: m.get("sgd_apply")?.as_str()?.to_string(),
                },
            );
        }

        let mut full_scale = BTreeMap::new();
        for (name, f) in root.get("full_scale")?.as_obj()? {
            let segments = f
                .get("segments")?
                .as_arr()?
                .iter()
                .map(|s| {
                    let s = s.as_arr()?;
                    Ok((s[0].as_str()?.to_string(), s[1].as_usize()?))
                })
                .collect::<Result<_>>()?;
            let layers = match f.opt("layers") {
                Some(v) => v.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?,
                None => segments.iter().map(|(_, p)| *p).collect(),
            };
            full_scale.insert(
                name.clone(),
                FullScaleModel {
                    depth: f.get("depth")?.as_usize()?,
                    params: f.get("params")?.as_usize()?,
                    paper_params: f.get("paper_params")?.as_usize()?,
                    batches: f
                        .get("batches")?
                        .as_arr()?
                        .iter()
                        .map(|b| b.as_usize())
                        .collect::<Result<_>>()?,
                    segments,
                    layers,
                },
            );
        }

        let k = root.get("kernels")?;
        let mut sum_stack = BTreeMap::new();
        for (ks, name) in k.get("sum_stack")?.as_obj()? {
            sum_stack.insert(ks.parse::<usize>()?, name.as_str()?.to_string());
        }
        let str_map = |v: &Json| -> Result<BTreeMap<String, String>> {
            Ok(v.as_obj()?
                .iter()
                .map(|(a, b)| Ok((a.clone(), b.as_str()?.to_string())))
                .collect::<Result<_>>()?)
        };
        let kernels = KernelIndex {
            chunk: k.get("chunk")?.as_usize()?,
            sum_stack,
            fp16_pack: str_map(k.get("fp16_pack")?)?,
            fp16_unpack: str_map(k.get("fp16_unpack")?)?,
        };

        Ok(Manifest { artifacts, models, full_scale, kernels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "artifacts": {
        "m_train": {"file": "m_train.hlo.txt",
          "inputs": [{"shape": [10], "dtype": "f32"}],
          "outputs": [{"shape": [], "dtype": "f32"}]}
      },
      "models": {
        "m": {"kind": "cls", "param_count": 10, "batch": 4, "eval_batch": 8,
              "batches": {"4": "m"}, "classes": 2, "input_shape": [4, 3],
              "init_file": "m_init.bin",
              "segments": [["w", 0, 6], ["b", 6, 4]],
              "sgd_apply": "sgd_apply_m"}
      },
      "full_scale": {
        "alexnet": {"depth": 8, "params": 60965224, "paper_params": 60965224,
                    "batches": [128, 32], "segments": [["conv1", 34944]]}
      },
      "kernels": {"chunk": 65536,
        "sum_stack": {"2": "sum_stack_k2"},
        "fp16_pack": {"f16": "fp16_pack_f16"},
        "fp16_unpack": {"f16": "fp16_unpack_f16"}}
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.artifacts["m_train"].inputs[0].shape, vec![10]);
        assert_eq!(m.models["m"].segments[1], ("b".to_string(), 6, 4));
        assert_eq!(m.models["m"].key_for_batch(4).unwrap(), "m");
        assert!(m.models["m"].key_for_batch(99).is_err());
        assert_eq!(m.full_scale["alexnet"].params, 60_965_224);
        // no "layers" key: fall back to the segments' per-layer counts
        assert_eq!(m.full_scale["alexnet"].layers, vec![34944]);
        assert_eq!(m.kernels.sum_stack[&2], "sum_stack_k2");
    }

    #[test]
    fn explicit_layers_key_wins_over_segments() {
        let text = MINI.replace(
            r#""segments": [["conv1", 34944]]"#,
            r#""segments": [["conv1", 34944]], "layers": [30000, 4944]"#,
        );
        let m = Manifest::parse(&text).unwrap();
        assert_eq!(m.full_scale["alexnet"].layers, vec![30000, 4944]);
        assert_eq!(m.full_scale["alexnet"].segments.len(), 1);
    }

    #[test]
    fn map_iteration_is_sorted_regardless_of_source_order() {
        // every map here is a BTreeMap so `tmpi info` and anything else
        // that enumerates the manifest emits one fixed order; feed keys
        // out of order and demand sorted iteration back
        let text = MINI
            .replace(
                r#""m_train": {"#,
                r#""z_last": {"file": "z.hlo.txt", "inputs": [], "outputs": []},
                   "a_first": {"file": "a.hlo.txt", "inputs": [], "outputs": []},
                   "m_train": {"#,
            )
            .replace(r#""batches": {"4": "m"}"#, r#""batches": {"32": "m32", "4": "m"}"#);
        let m = Manifest::parse(&text).unwrap();
        let names: Vec<&str> = m.artifacts.keys().map(|s| s.as_str()).collect();
        assert_eq!(names, ["a_first", "m_train", "z_last"]);
        let batches: Vec<usize> = m.models["m"].batches.keys().copied().collect();
        assert_eq!(batches, [4, 32], "numeric batch keys sort numerically, not lexically");
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.len() >= 20);
            assert_eq!(m.full_scale["vggnet"].params, 138_357_544);
            // segments sum to param_count for every model
            for (name, info) in &m.models {
                let sum: usize = info.segments.iter().map(|s| s.2).sum();
                assert_eq!(sum, info.param_count, "{name}");
            }
        }
    }
}
