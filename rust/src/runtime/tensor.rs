//! Host-side tensors: the rust/PJRT interchange type.

use anyhow::{anyhow, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U16,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u16" => Ok(Dtype::U16),
            _ => Err(anyhow!("unknown dtype '{s}'")),
        }
    }
}

#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U16(Vec<u16>),
}

/// A dense host tensor (row-major) moving to/from PJRT literals.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::I32(data) }
    }

    pub fn u16(shape: Vec<usize>, data: Vec<u16>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::U16(data) }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            Data::F32(_) => Dtype::F32,
            Data::I32(_) => Dtype::I32,
            Data::U16(_) => Dtype::U16,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.shape.iter().product::<usize>() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn as_u16(&self) -> Result<&[u16]> {
        match &self.data {
            Data::U16(v) => Ok(v),
            _ => Err(anyhow!("tensor is not u16")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn into_u16(self) -> Result<Vec<u16>> {
        match self.data {
            Data::U16(v) => Ok(v),
            _ => Err(anyhow!("tensor is not u16")),
        }
    }

    /// Scalar f32 value (loss outputs).
    pub fn scalar(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(anyhow!("not a scalar: {:?}", self.shape));
        }
        Ok(v[0])
    }

    /// Scalar i32 value (correct-count outputs).
    pub fn scalar_i32(&self) -> Result<i32> {
        let v = self.as_i32()?;
        if v.len() != 1 {
            return Err(anyhow!("not a scalar: {:?}", self.shape));
        }
        Ok(v[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.len(), 6);
        assert!(t.as_i32().is_err());

        let s = HostTensor::scalar_f32(7.0);
        assert_eq!(s.scalar().unwrap(), 7.0);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("u16").unwrap(), Dtype::U16);
        assert!(Dtype::parse("f64").is_err());
    }
}
