//! Exchange auto-tuner: one [`ExchangePlan`] for every exchange knob, and
//! the `tmpi plan` search that fills it in.
//!
//! The paper hand-tunes its exchange per model and cluster (AlexNet vs
//! GoogLeNet, 2→8 GPUs, BSP vs EASGD); our reproduction exposes a config
//! space — exchange × chunk_kib × bucket_kib × overlap × servers × wire ×
//! topology — far too large for hand-picking, while one simnet evaluation
//! costs microseconds. [`search`] walks that space with the runtime-free
//! probes ([`crate::coordinator::probe_exchange_wire`],
//! [`crate::coordinator::probe_wfbp`], [`crate::easgd::shard::measure_sharded`]):
//! exhaustive over the discrete axes (strategy, overlap, servers), greedy
//! over the chunk/bucket size ladders (each ladder walk stops at the first
//! rung that fails to improve — the cost curves are unimodal in practice,
//! and the hand-picked defaults are scored first so pruning can never cost
//! the never-loses guarantee).
//!
//! The winning plan is emitted as a `[plan]` TOML section and cached under
//! a `(model, topology)` slug plus an FNV-1a fingerprint of everything the
//! score depends on (mode, batch, workers, cuda_aware, topology, and the
//! full-scale layer table) — a stale cache entry is therefore *unreachable*:
//! any input change moves the fingerprint and so the file name.
//!
//! Search-space scope: the default search covers the flat strategies
//! (`ar|asa|asa16|ring`) with the dense f32 wire, overlap off or wait-free,
//! because those are the configurations the stdlib Python twin
//! (`scripts/verify_plan_bands.py`) can price to float equality — the CI
//! bench gate pins every planner score against it. `hier:<inner>` and the
//! compressed wires remain reachable through explicit plan files
//! (`tmpi train --plan <path>`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::cluster::Topology;
use crate::collectives::wfbp::BWD_FRACTION;
use crate::collectives::{OverlapMode, StrategyKind, WireFormat};
use crate::coordinator::{probe_exchange_wire, probe_wfbp};
use crate::easgd::{shard, EasgdConfig};
use crate::models;
use crate::units::{Kib, Secs};

/// Bump when the fingerprinted input set or the TOML schema changes: old
/// cache entries must miss rather than be misread.
pub const PLAN_FORMAT_VERSION: u64 = 1;

/// Upper bound on `chunk_kib` / `bucket_kib` (1 GiB): anything larger than
/// the largest full-scale model is a typo, not a tuning choice.
pub const SIZING_KIB_MAX: usize = 1_048_576;

/// Validate an *explicitly written* sizing knob (`chunk_kib` / `bucket_kib`
/// in TOML, `--chunk-kib` / `--bucket-kib` on the CLI). `0` spells the
/// monolithic/off behavior only by omission — written out it is almost
/// always a typo'd real size, so it is rejected like any other bad value.
pub fn validate_sizing_kib(key: &str, kib: usize) -> Result<usize> {
    if kib == 0 || kib > SIZING_KIB_MAX {
        bail!(
            "{key} = {kib} out of range (valid: 1..={SIZING_KIB_MAX} KiB; \
             omit the key for the monolithic/off default)"
        );
    }
    Ok(kib)
}

/// Every exchange-shaping knob in one place: how gradients (BSP) or
/// parameters (EASGD) move between ranks. `BspConfig`/`EasgdConfig` embed
/// one of these instead of loose fields; legacy TOML keys and CLI flags
/// still parse into it (`crate::config::apply_plan_keys`).
#[derive(Clone, Debug, PartialEq)]
pub struct ExchangePlan {
    /// collective structure (`ar|allreduce|asa|asa16|ring|hier:<inner>`)
    pub strategy: StrategyKind,
    /// on-wire format override (`f32|f16|bf16|topk:<p>|onebit|sf`).
    /// `None` = no override: f32 for BSP ([`Self::wire_format`]), the
    /// strategy-derived wire for EASGD (`EasgdConfig::elastic_wire`).
    pub wire: Option<WireFormat>,
    /// KiB per pipeline chunk of the exchange (0 = monolithic)
    pub chunk_kib: usize,
    /// overlap chunk transfers with the previous chunk's kernels; `false`
    /// prices chunks serially (the ablation knob)
    pub pipeline: bool,
    /// when to exchange gradients relative to the backward pass (BSP/SUBGD
    /// only): whole-vector after the step (`None`), layer buckets after
    /// the step (`Post`), or wait-free per bucket (`Wfbp`)
    pub overlap: OverlapMode,
    /// KiB per WFBP gradient bucket (0 = one bucket per layer); full-scale
    /// KiB when the run prices against a `sim_model`
    pub bucket_kib: usize,
    /// EASGD parameter-server shards (BSP ignores this axis)
    pub servers: usize,
}

impl Default for ExchangePlan {
    fn default() -> ExchangePlan {
        ExchangePlan {
            strategy: StrategyKind::Asa,
            wire: None,
            chunk_kib: 0,
            pipeline: true,
            overlap: OverlapMode::None,
            bucket_kib: 0,
            servers: 1,
        }
    }
}

impl ExchangePlan {
    /// The dense-default wire of the BSP exchange: an explicit override
    /// wins, otherwise full-width f32.
    pub fn wire_format(&self) -> WireFormat {
        self.wire.unwrap_or(WireFormat::F32)
    }

    /// Emit the `[plan]` TOML section this plan parses back from
    /// (`crate::config::plan_from_text`). Sizing knobs at their off
    /// default (0) and an unset wire are omitted rather than written —
    /// written-out zeros are rejected by [`validate_sizing_kib`].
    pub fn to_toml(&self) -> String {
        let mut out = String::from("[plan]\n");
        out.push_str(&format!("exchange = \"{}\"\n", self.strategy.name()));
        if let Some(w) = self.wire {
            out.push_str(&format!("wire = \"{}\"\n", w.name()));
        }
        if self.chunk_kib > 0 {
            out.push_str(&format!("chunk_kib = {}\n", self.chunk_kib));
        }
        out.push_str(&format!("pipeline = {}\n", self.pipeline));
        out.push_str(&format!("overlap = \"{}\"\n", self.overlap.name()));
        if self.bucket_kib > 0 {
            out.push_str(&format!("bucket_kib = {}\n", self.bucket_kib));
        }
        out.push_str(&format!("servers = {}\n", self.servers));
        out
    }

    /// One-line human summary (`tmpi plan` output, cache-file header).
    pub fn summary(&self) -> String {
        let mut parts = vec![format!("exchange={}", self.strategy.name())];
        if let Some(w) = self.wire {
            parts.push(format!("wire={}", w.name()));
        }
        if self.chunk_kib > 0 {
            parts.push(format!("chunk_kib={}", self.chunk_kib));
            parts.push(format!("pipeline={}", self.pipeline));
        }
        if self.overlap.bucketed() {
            parts.push(format!("overlap={}", self.overlap.name()));
            parts.push(format!("bucket_kib={}", self.bucket_kib));
        }
        if self.servers > 1 {
            parts.push(format!("servers={}", self.servers));
        }
        parts.join(" ")
    }
}

/// Which training loop the plan drives — the two score different
/// quantities (visible gradient-exchange time vs elastic round-trip).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    Bsp,
    Easgd,
}

impl PlanMode {
    pub const NAMES: &'static str = "bsp|easgd";

    pub fn name(self) -> &'static str {
        match self {
            PlanMode::Bsp => "bsp",
            PlanMode::Easgd => "easgd",
        }
    }

    pub fn from_name(s: &str) -> Result<PlanMode> {
        match s.to_ascii_lowercase().as_str() {
            "bsp" | "train" => Ok(PlanMode::Bsp),
            "easgd" => Ok(PlanMode::Easgd),
            _ => Err(anyhow!("unknown plan mode '{s}' (valid: {})", Self::NAMES)),
        }
    }
}

/// Everything the planner's score depends on — and therefore everything
/// the cache fingerprint must cover.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanInputs {
    /// full-scale model name (proxy names resolve via
    /// [`models::full_scale_of`])
    pub model: String,
    /// per-worker batch size (sets the backward-pass overlap budget)
    pub batch: usize,
    pub workers: usize,
    /// "mosaic" (1 GPU/node) or "copper" (8 GPU/node)
    pub topology: String,
    pub cuda_aware: bool,
    pub mode: PlanMode,
}

impl PlanInputs {
    fn full_scale_name(&self) -> &str {
        models::full_scale_of(&self.model).unwrap_or(self.model.as_str())
    }

    /// The full-scale `(layer, params)` table the probes price against.
    pub fn layer_table(&self) -> Result<Vec<(String, usize)>> {
        models::builtin_full_scale_layers(self.full_scale_name()).ok_or_else(|| {
            anyhow!(
                "no built-in full-scale layer table for model '{}' \
                 (valid: alexnet|googlenet|vggnet and their proxies)",
                self.model
            )
        })
    }

    fn full_elems(&self) -> Result<usize> {
        Ok(self.layer_table()?.iter().map(|(_, p)| p).sum())
    }

    /// Paper-calibrated 1-GPU seconds for one `batch`-sized step
    /// (Table 3's per-5120-image pace, falling back to the model's
    /// batch-32 row like `Session::table1`).
    fn step_seconds(&self) -> Result<f64> {
        let full = self.full_scale_name();
        let t5120 = models::paper_train_5120(full, self.batch)
            .or_else(|| models::paper_train_5120(full, 32))
            .ok_or_else(|| anyhow!("no paper train-time row for model '{full}'"))?;
        Ok(t5120 * self.batch as f64 / 5120.0)
    }

    /// Backward-pass seconds WFBP may hide wire time under.
    fn backward_total(&self) -> Result<f64> {
        Ok(self.step_seconds()? * BWD_FRACTION)
    }

    /// Human-readable cache-key prefix; the fingerprint carries the rest.
    pub fn slug(&self) -> String {
        format!("{}-{}-k{}", self.model, self.topology, self.workers)
    }

    /// FNV-1a over every score input (format version first, then mode,
    /// batch, workers, cuda_aware, topology, model, and the layer table
    /// name-by-name). The layer table arrives as an ordered `Vec`, so the
    /// digest is independent of whatever map a caller assembled inputs
    /// from — pinned by `fingerprint_stable_across_map_ordering`.
    pub fn fingerprint(&self) -> Result<u64> {
        let layers = self.layer_table()?;
        let mut h = Fnv::new();
        h.eat(PLAN_FORMAT_VERSION);
        h.eat(match self.mode {
            PlanMode::Bsp => 0,
            PlanMode::Easgd => 1,
        });
        h.eat(self.batch as u64);
        h.eat(self.workers as u64);
        h.eat(u64::from(self.cuda_aware));
        h.eat_str(&self.topology);
        h.eat_str(&self.model);
        h.eat(layers.len() as u64);
        for (name, params) in &layers {
            h.eat_str(name);
            h.eat(*params as u64);
        }
        Ok(h.finish())
    }

    /// Cache location under `dir`: `{slug}-{fingerprint:016x}.toml`.
    pub fn cache_file(&self, dir: &Path) -> Result<PathBuf> {
        Ok(dir.join(format!("{}-{:016x}.toml", self.slug(), self.fingerprint()?)))
    }
}

/// FNV-1a (same constants as the dataset segment-store fingerprint in
/// [`crate::data`]); strings are length-prefixed so `("ab","c")` and
/// `("a","bc")` cannot collide.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn eat_byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn eat(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.eat_byte(b);
        }
    }

    fn eat_str(&mut self, s: &str) {
        self.eat(s.len() as u64);
        for b in s.bytes() {
            self.eat_byte(b);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

// ---------------------------------------------------------------------------
// Scoring: one simnet number per candidate plan.

/// Simulated seconds one exchange of `plan` costs under `inputs` — the
/// planner's objective. BSP monolithic/chunked plans price a full-vector
/// exchange ([`probe_exchange_wire`], `sim_total`); bucketed-overlap plans
/// price the *visible* (non-hidden) exchange time ([`probe_wfbp`],
/// `comm_visible`); EASGD plans price one elastic round-trip per worker
/// ([`shard::measure_sharded`], `comm_per_exchange`).
pub fn score_plan(inputs: &PlanInputs, plan: &ExchangePlan) -> Result<Secs> {
    match inputs.mode {
        PlanMode::Bsp => score_bsp(inputs, plan),
        PlanMode::Easgd => score_easgd(inputs, plan),
    }
}

fn score_bsp(inputs: &PlanInputs, plan: &ExchangePlan) -> Result<Secs> {
    let layers = inputs.layer_table()?;
    let full_elems: usize = layers.iter().map(|(_, p)| p).sum();
    let topo = Topology::by_name(&inputs.topology, inputs.workers)
        .ok_or_else(|| anyhow!("unknown topology '{}'", inputs.topology))?;
    if plan.overlap.bucketed() {
        let out = probe_wfbp(
            plan.strategy,
            inputs.workers,
            topo,
            &layers,
            inputs.cuda_aware,
            plan.bucket_kib,
            plan.chunk_kib,
            inputs.backward_total()?,
            plan.overlap == OverlapMode::Wfbp,
        )?;
        return Ok(out.comm_visible);
    }
    // a full-scale chunk size becomes a chunk *count*, which the probe
    // projects back onto its capped buffer at the same ratio
    let chunks = if plan.chunk_kib > 0 {
        let chunk_elems = Kib(plan.chunk_kib).elems(plan.strategy, plan.wire_format()).0.max(1);
        full_elems.div_ceil(chunk_elems)
    } else {
        0
    };
    let rep = probe_exchange_wire(
        plan.strategy,
        plan.wire_format(),
        inputs.workers,
        topo,
        4 * full_elems as u64,
        inputs.cuda_aware,
        chunks,
        plan.pipeline,
        None,
    )?;
    Ok(rep.sim_total())
}

fn score_easgd(inputs: &PlanInputs, plan: &ExchangePlan) -> Result<Secs> {
    let full_elems = inputs.full_elems()?;
    let probe_elems = 1_000_000.min(full_elems).max(1);
    let comm_scale = full_elems as f64 / probe_elems as f64;
    let mut cfg = EasgdConfig::quick(&inputs.model, inputs.workers, 1);
    cfg.topology = inputs.topology.clone();
    cfg.batch = inputs.batch;
    cfg.plan = plan.clone();
    let probe = shard::measure_sharded(&cfg, probe_elems, 3, inputs.step_seconds()?, comm_scale)?;
    Ok(Secs(probe.comm_per_exchange))
}

// ---------------------------------------------------------------------------
// Search.

/// Chunk-size rungs (KiB) the greedy walk descends while improving.
pub const CHUNK_LADDER: [usize; 5] = [64, 256, 1024, 4096, 16384];

/// Bucket-size rungs (KiB) for the WFBP axis; 0 = one bucket per layer.
pub const BUCKET_LADDER: [usize; 4] = [0, 1024, 4096, 16384];

/// The flat strategies the default search sweeps — exactly the set the
/// stdlib Python twin prices, so every searched score is CI-pinnable.
pub const SEARCH_STRATEGIES: [StrategyKind; 4] =
    [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring];

/// A search result: the winning plan, its score, how many candidates were
/// priced, and the scored hand-picked defaults (the never-loses baseline —
/// `bench_plan` asserts against these).
#[derive(Clone, Debug)]
pub struct PlanChoice {
    pub plan: ExchangePlan,
    pub score: Secs,
    pub evaluated: usize,
    pub default_scores: Vec<(ExchangePlan, Secs)>,
}

/// The configurations a careful operator would try by hand — the paper's
/// per-model settings and this repo's own example configs. [`search`]
/// scores these *first*, so its argmin can never lose to any of them
/// (pinned by `planner_never_loses_to_hand_picked_defaults`).
pub fn hand_picked_defaults(mode: PlanMode) -> Vec<ExchangePlan> {
    let base = ExchangePlan::default();
    match mode {
        PlanMode::Bsp => vec![
            // the quick() default: monolithic ASA
            base.clone(),
            ExchangePlan { strategy: StrategyKind::Ar, ..base.clone() },
            ExchangePlan { strategy: StrategyKind::Ring, ..base.clone() },
            ExchangePlan { strategy: StrategyKind::Asa16, ..base.clone() },
            // the chunked-pipeline example config
            ExchangePlan { chunk_kib: 4096, ..base.clone() },
            // wait-free backprop, per-layer buckets
            ExchangePlan { overlap: OverlapMode::Wfbp, ..base },
        ],
        PlanMode::Easgd => vec![
            // the paper's single-server elastic setup
            base.clone(),
            ExchangePlan { strategy: StrategyKind::Asa16, ..base.clone() },
            ExchangePlan { chunk_kib: 256, ..base },
        ],
    }
}

/// Search the exchange space for `inputs`: exhaustive over the discrete
/// axes (strategy × overlap for BSP; strategy × servers for EASGD), greedy
/// over the chunk/bucket ladders (a ladder walk stops at the first rung
/// that fails to improve on the axis' running best). Hand-picked defaults
/// are scored first, so pruning can never surrender the never-loses
/// guarantee.
pub fn search(inputs: &PlanInputs) -> Result<PlanChoice> {
    let mut best_plan = ExchangePlan::default();
    let mut best_score = Secs(f64::INFINITY);
    let mut evaluated = 0usize;
    let mut default_scores = Vec::new();

    {
        let mut eval = |plan: ExchangePlan| -> Result<Secs> {
            let s = score_plan(inputs, &plan)?;
            evaluated += 1;
            // strict `<`: earlier candidates (the defaults) win ties, so
            // the choice is deterministic across sweep orderings
            if s.0 < best_score.0 {
                best_score = s;
                best_plan = plan;
            }
            Ok(s)
        };

        for plan in hand_picked_defaults(inputs.mode) {
            let s = eval(plan.clone())?;
            default_scores.push((plan, s));
        }

        match inputs.mode {
            PlanMode::Bsp => {
                for strategy in SEARCH_STRATEGIES {
                    let mono = ExchangePlan { strategy, ..ExchangePlan::default() };
                    let mut rung_best = eval(mono.clone())?;
                    for kib in CHUNK_LADDER {
                        let s = eval(ExchangePlan { chunk_kib: kib, ..mono.clone() })?;
                        if s.0 >= rung_best.0 {
                            break;
                        }
                        rung_best = s;
                    }
                    let wfbp =
                        ExchangePlan { overlap: OverlapMode::Wfbp, ..ExchangePlan::default() };
                    let mut rung_best = Secs(f64::INFINITY);
                    for kib in BUCKET_LADDER {
                        let s = eval(ExchangePlan { strategy, bucket_kib: kib, ..wfbp.clone() })?;
                        if s.0 >= rung_best.0 {
                            break;
                        }
                        rung_best = s;
                    }
                }
            }
            PlanMode::Easgd => {
                let mut servers_axis = Vec::new();
                let mut s = 1usize;
                while s <= inputs.workers {
                    servers_axis.push(s);
                    s *= 2;
                }
                for servers in servers_axis {
                    for strategy in [StrategyKind::Asa, StrategyKind::Asa16] {
                        let mono =
                            ExchangePlan { strategy, servers, ..ExchangePlan::default() };
                        let mut rung_best = eval(mono.clone())?;
                        for kib in CHUNK_LADDER {
                            let s = eval(ExchangePlan { chunk_kib: kib, ..mono.clone() })?;
                            if s.0 >= rung_best.0 {
                                break;
                            }
                            rung_best = s;
                        }
                    }
                }
            }
        }
    }

    Ok(PlanChoice { plan: best_plan, score: best_score, evaluated, default_scores })
}

// ---------------------------------------------------------------------------
// Cache: emitted-TOML files keyed by slug + fingerprint.

/// Write `choice` to its fingerprinted cache file under `dir` and return
/// the path. The file is a self-contained `[plan]` TOML (header comments
/// record provenance) that [`load_plan`] / `tmpi train --plan` read back.
pub fn store_plan(inputs: &PlanInputs, choice: &PlanChoice, dir: &Path) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = inputs.cache_file(dir)?;
    let mut text = String::new();
    text.push_str("# tmpi plan — auto-tuned exchange plan (simnet-scored)\n");
    text.push_str(&format!(
        "# model = {}  batch = {}  workers = {}  topology = {}  mode = {}\n",
        inputs.model,
        inputs.batch,
        inputs.workers,
        inputs.topology,
        inputs.mode.name()
    ));
    text.push_str(&format!(
        "# fingerprint = {:016x}  candidates = {}  score = {:.6e} s\n",
        inputs.fingerprint()?,
        choice.evaluated,
        choice.score.0
    ));
    text.push_str(&choice.plan.to_toml());
    std::fs::write(&path, &text).map_err(|e| anyhow!("writing {path:?}: {e}"))?;
    Ok(path)
}

/// Read a plan file (`[plan]` section over [`ExchangePlan::default`]).
pub fn load_plan(path: &Path) -> Result<ExchangePlan> {
    crate::config::plan_from_file(path)
}

/// `--plan auto`: load the cached plan for `inputs` if its fingerprint
/// matches, otherwise run [`search`] and cache the result. Returns the
/// plan, the cache path, and whether it was a cache hit.
pub fn auto_plan(inputs: &PlanInputs, dir: &Path) -> Result<(ExchangePlan, PathBuf, bool)> {
    let path = inputs.cache_file(dir)?;
    if path.is_file() {
        return Ok((load_plan(&path)?, path, true));
    }
    let choice = search(inputs)?;
    let path = store_plan(inputs, &choice, dir)?;
    Ok((choice.plan, path, false))
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;

    fn inputs(model: &str, workers: usize, mode: PlanMode) -> PlanInputs {
        PlanInputs {
            model: model.to_string(),
            batch: 128,
            workers,
            topology: "mosaic".to_string(),
            cuda_aware: true,
            mode,
        }
    }

    #[test]
    fn sizing_validation_names_the_range() {
        assert_eq!(validate_sizing_kib("chunk_kib", 1).unwrap(), 1);
        assert_eq!(validate_sizing_kib("chunk_kib", SIZING_KIB_MAX).unwrap(), SIZING_KIB_MAX);
        let err = validate_sizing_kib("chunk_kib", 0).unwrap_err().to_string();
        assert!(err.contains("chunk_kib = 0"), "{err}");
        assert!(err.contains("1..=1048576"), "{err}");
        assert!(err.contains("omit the key"), "{err}");
        let err = validate_sizing_kib("bucket_kib", SIZING_KIB_MAX + 1).unwrap_err().to_string();
        assert!(err.contains("bucket_kib"), "{err}");
    }

    #[test]
    fn toml_round_trips_through_config_parser() {
        use crate::collectives::FlatKind;
        let plans = [
            ExchangePlan::default(),
            ExchangePlan {
                strategy: StrategyKind::Hier { inner: FlatKind::Asa16 },
                wire: Some(WireFormat::TopK { p: 0.01 }),
                chunk_kib: 256,
                pipeline: false,
                ..ExchangePlan::default()
            },
            ExchangePlan {
                strategy: StrategyKind::Ring,
                overlap: OverlapMode::Wfbp,
                bucket_kib: 4096,
                ..ExchangePlan::default()
            },
            ExchangePlan { servers: 4, wire: Some(WireFormat::Bf16), ..ExchangePlan::default() },
        ];
        for plan in plans {
            let parsed = crate::config::plan_from_text(&plan.to_toml()).unwrap();
            assert_eq!(parsed, plan, "round-trip through:\n{}", plan.to_toml());
        }
    }

    #[test]
    fn fingerprint_stable_across_map_ordering() {
        // a caller assembling inputs out of a key-value map must land on
        // the same fingerprint regardless of insertion order
        let build = |pairs: &[(&str, &str)]| {
            let mut m = BTreeMap::new();
            for (k, v) in pairs {
                m.insert(k.to_string(), v.to_string());
            }
            PlanInputs {
                model: m["model"].clone(),
                batch: m["batch"].parse().unwrap(),
                workers: m["workers"].parse().unwrap(),
                topology: m["topology"].clone(),
                cuda_aware: m["cuda_aware"] == "true",
                mode: PlanMode::from_name(&m["mode"]).unwrap(),
            }
        };
        let fwd = build(&[
            ("model", "alexnet"),
            ("batch", "128"),
            ("workers", "4"),
            ("topology", "mosaic"),
            ("cuda_aware", "true"),
            ("mode", "bsp"),
        ]);
        let rev = build(&[
            ("mode", "bsp"),
            ("cuda_aware", "true"),
            ("topology", "mosaic"),
            ("workers", "4"),
            ("batch", "128"),
            ("model", "alexnet"),
        ]);
        assert_eq!(fwd.fingerprint().unwrap(), rev.fingerprint().unwrap());
        // ...and every scored input moves it
        let fp = fwd.fingerprint().unwrap();
        for other in [
            PlanInputs { workers: 8, ..fwd.clone() },
            PlanInputs { batch: 32, ..fwd.clone() },
            PlanInputs { topology: "copper".into(), ..fwd.clone() },
            PlanInputs { cuda_aware: false, ..fwd.clone() },
            PlanInputs { mode: PlanMode::Easgd, ..fwd.clone() },
            PlanInputs { model: "googlenet".into(), ..fwd.clone() },
        ] {
            assert_ne!(fp, other.fingerprint().unwrap(), "{other:?}");
        }
    }

    #[test]
    fn proxy_names_resolve_to_full_scale_tables() {
        let vgg = inputs("vgg", 2, PlanMode::Bsp);
        let vggnet = inputs("vggnet", 2, PlanMode::Bsp);
        assert_eq!(vgg.layer_table().unwrap(), vggnet.layer_table().unwrap());
        let err = inputs("mlp", 2, PlanMode::Bsp).layer_table().unwrap_err().to_string();
        assert!(err.contains("mlp"), "{err}");
    }

    #[test]
    fn planner_never_loses_to_hand_picked_defaults() {
        let ins = inputs("alexnet", 2, PlanMode::Bsp);
        let choice = search(&ins).unwrap();
        assert_eq!(choice.default_scores.len(), hand_picked_defaults(PlanMode::Bsp).len());
        for (plan, score) in &choice.default_scores {
            assert!(
                choice.score.0 <= score.0,
                "planner pick {:?} ({:.6}s) loses to default {:?} ({:.6}s)",
                choice.plan,
                choice.score.0,
                plan,
                score.0
            );
        }
        // re-scoring the winner reproduces its reported score exactly
        let again = score_plan(&ins, &choice.plan).unwrap();
        assert_eq!(again.0.to_bits(), choice.score.0.to_bits());
    }

    #[test]
    fn easgd_search_never_loses_and_caches_round_trip() {
        let ins = inputs("googlenet", 2, PlanMode::Easgd);
        let choice = search(&ins).unwrap();
        for (_, score) in &choice.default_scores {
            assert!(choice.score.0 <= score.0);
        }
        let dir = std::env::temp_dir().join(format!("tmpi_plans_{}", std::process::id()));
        let path = store_plan(&ins, &choice, &dir).unwrap();
        assert_eq!(load_plan(&path).unwrap(), choice.plan);
        // auto_plan now hits the cache without re-searching
        let (plan, hit_path, hit) = auto_plan(&ins, &dir).unwrap();
        assert!(hit);
        assert_eq!(hit_path, path);
        assert_eq!(plan, choice.plan);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
