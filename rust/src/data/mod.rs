//! Synthetic workloads — the ImageNet / corpus substitute (DESIGN.md §2).
//!
//! * [`ImageDataset`]: a deterministic image-classification task. Each class
//!   has a fixed random prototype image; samples are prototype + Gaussian
//!   noise + random shift, quantized to u8 and written as **batch files on
//!   disk** exactly like the paper's preprocessed ImageNet batches (§3.3) so
//!   the parallel loader exercises real file I/O, mean subtraction,
//!   cropping and mirroring. Labels (small) stay in memory, as in the paper
//!   (footnote 6).
//! * [`TokenStream`]: an order-1 Markov chain over a vocabulary (4 likely
//!   successors per state ⇒ optimal LM loss ≈ ln 4); the e2e transformer
//!   trains on it.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Rng;

/// Storage resolution is larger than the model input so the loader's random
/// crop (Alg. 1 step 11) is a real operation: store 36×36, crop to 32×32.
#[derive(Clone, Debug)]
pub struct ImageSpec {
    pub classes: usize,
    pub channels: usize,
    /// stored resolution (pre-crop)
    pub store_hw: usize,
    /// model input resolution (crop target)
    pub crop_hw: usize,
    pub noise: f32,
    pub label_noise: f32,
    pub seed: u64,
}

impl Default for ImageSpec {
    fn default() -> Self {
        ImageSpec {
            classes: 16,
            channels: 3,
            store_hw: 36,
            crop_hw: 32,
            noise: 0.18,
            label_noise: 0.02,
            seed: 1234,
        }
    }
}

/// In-memory generator (prototypes) + on-disk batch store.
pub struct ImageDataset {
    pub spec: ImageSpec,
    prototypes: Vec<Vec<f32>>, // classes × (C*store*store), values in [0,1]
}

impl ImageDataset {
    pub fn new(spec: ImageSpec) -> ImageDataset {
        let mut rng = Rng::new(spec.seed);
        let px = spec.channels * spec.store_hw * spec.store_hw;
        // smooth prototypes: low-frequency random fields so crops stay
        // class-informative
        let prototypes = (0..spec.classes)
            .map(|_| {
                let mut base = vec![0.0f32; px];
                let hw = spec.store_hw;
                for c in 0..spec.channels {
                    // random plane waves per channel
                    let (fx, fy) = (rng.next_f64() * 0.6 + 0.1, rng.next_f64() * 0.6 + 0.1);
                    let (px_, py_) = (rng.next_f64() * 6.0, rng.next_f64() * 6.0);
                    let amp = 0.35 + 0.15 * rng.next_f64();
                    for y in 0..hw {
                        for x in 0..hw {
                            let v = ((x as f64 * fx + px_).sin() * (y as f64 * fy + py_).cos())
                                * amp
                                + 0.5;
                            base[c * hw * hw + y * hw + x] = v as f32;
                        }
                    }
                }
                base
            })
            .collect();
        ImageDataset { spec, prototypes }
    }

    /// Deterministic example by global index: (u8 pixels, label).
    pub fn example(&self, index: u64) -> (Vec<u8>, i32) {
        let s = &self.spec;
        let mut rng = Rng::new(s.seed ^ 0x1111).fork(index + 1);
        let true_class = (index as usize) % s.classes;
        let label = if rng.next_f64() < s.label_noise as f64 {
            rng.below(s.classes) as i32
        } else {
            true_class as i32
        };
        let proto = &self.prototypes[true_class];
        let px = proto.len();
        let mut img = Vec::with_capacity(px);
        for i in 0..px {
            let v = proto[i] + s.noise * rng.gauss_f32();
            img.push((v.clamp(0.0, 1.0) * 255.0) as u8);
        }
        (img, label)
    }

    /// Mean image over the prototype set (the paper subtracts a fixed
    /// ImageNet mean image) as f32 in pixel units.
    pub fn mean_image(&self) -> Vec<f32> {
        let px = self.prototypes[0].len();
        let mut mean = vec![0.0f32; px];
        for p in &self.prototypes {
            for (m, v) in mean.iter_mut().zip(p) {
                *m += v * 255.0;
            }
        }
        for m in mean.iter_mut() {
            *m /= self.prototypes.len() as f32;
        }
        mean
    }

    /// Write `n_batches` batch files of `batch` examples (shard `shard` of
    /// `n_shards`) under `dir`, plus labels and the mean image. Returns the
    /// file paths in order — the training process feeds these to its loader
    /// child one filename at a time (Alg. 1).
    pub fn write_shard(
        &self,
        dir: &Path,
        shard: usize,
        n_shards: usize,
        batch: usize,
        n_batches: usize,
    ) -> Result<ShardFiles> {
        fs::create_dir_all(dir)?;
        let mut files = Vec::with_capacity(n_batches);
        let mut labels = Vec::with_capacity(n_batches * batch);
        for b in 0..n_batches {
            let path = dir.join(format!("shard{shard}_batch{b:05}.bin"));
            let mut buf = Vec::with_capacity(batch * self.prototypes[0].len());
            for i in 0..batch {
                // global index interleaves shards (disjoint coverage)
                let idx = ((b * batch + i) * n_shards + shard) as u64;
                let (img, label) = self.example(idx);
                buf.extend_from_slice(&img);
                labels.push(label);
            }
            let mut f = fs::File::create(&path).with_context(|| format!("{path:?}"))?;
            f.write_all(&buf)?;
            files.push(path);
        }
        let mean = self.mean_image();
        Ok(ShardFiles { files, labels, mean, batch, spec: self.spec.clone() })
    }

    /// An in-memory eval batch (already mean-subtracted + center-cropped):
    /// returns (x: f32 NCHW, y) ready for the eval artifact.
    pub fn eval_batch(&self, start_index: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let s = &self.spec;
        let mean = self.mean_image();
        let mut xs = Vec::with_capacity(batch * s.channels * s.crop_hw * s.crop_hw);
        let mut ys = Vec::with_capacity(batch);
        let off = (s.store_hw - s.crop_hw) / 2;
        for i in 0..batch {
            // eval stream offset far from train indices
            let (img, label) = self.example(1_000_000_007 + start_index + i as u64);
            xs.extend(crop(&img, &mean, s, off, off, false));
            ys.push(label);
        }
        (xs, ys)
    }
}

/// One worker's on-disk shard.
pub struct ShardFiles {
    pub files: Vec<PathBuf>,
    /// labels for batch b are labels[b*batch..(b+1)*batch] — in memory,
    /// like the paper's label handling (footnote 6)
    pub labels: Vec<i32>,
    pub mean: Vec<f32>,
    pub batch: usize,
    pub spec: ImageSpec,
}

/// Mean-subtract + crop (+ optional horizontal mirror) one stored image.
/// `img` is u8 at store_hw; output is f32 NCHW at crop_hw. This is Alg. 1
/// steps 10–11, shared by the loader and the eval path.
pub fn crop(img: &[u8], mean: &[f32], s: &ImageSpec, ox: usize, oy: usize, mirror: bool) -> Vec<f32> {
    let (hw, chw) = (s.store_hw, s.crop_hw);
    let mut out = Vec::with_capacity(s.channels * chw * chw);
    for c in 0..s.channels {
        for y in 0..chw {
            for x in 0..chw {
                let sx = if mirror { ox + chw - 1 - x } else { ox + x };
                let idx = c * hw * hw + (oy + y) * hw + sx;
                out.push((img[idx] as f32 - mean[idx]) / 255.0);
            }
        }
    }
    out
}

/// Flat-feature classification task for the MLP (class prototypes in R^d +
/// Gaussian noise; the fast model for scheme/strategy studies).
pub struct FeatureDataset {
    pub dim: usize,
    pub classes: usize,
    pub noise: f32,
    pub label_noise: f32,
    seed: u64,
    prototypes: Vec<Vec<f32>>,
}

impl FeatureDataset {
    pub fn new(dim: usize, classes: usize, seed: u64) -> FeatureDataset {
        let mut rng = Rng::new(seed ^ 0xFEA7);
        let prototypes = (0..classes)
            .map(|_| (0..dim).map(|_| rng.gauss_f32()).collect())
            .collect();
        FeatureDataset { dim, classes, noise: 0.8, label_noise: 0.02, seed, prototypes }
    }

    pub fn example(&self, index: u64) -> (Vec<f32>, i32) {
        let mut rng = Rng::new(self.seed ^ 0x2222).fork(index + 1);
        let true_class = (index as usize) % self.classes;
        let label = if rng.next_f64() < self.label_noise as f64 {
            rng.below(self.classes) as i32
        } else {
            true_class as i32
        };
        let proto = &self.prototypes[true_class];
        let x = proto.iter().map(|&p| p + self.noise * rng.gauss_f32()).collect();
        (x, label)
    }

    /// Shard-disjoint training batch (worker `shard` of `n_shards`).
    pub fn batch(
        &self,
        shard: usize,
        n_shards: usize,
        iter: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let idx = (((iter * batch + i) * n_shards) + shard) as u64;
            let (x, y) = self.example(idx);
            xs.extend(x);
            ys.push(y);
        }
        (xs, ys)
    }

    pub fn eval_batch(&self, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let (x, y) = self.example(2_000_000_011 + i as u64);
            xs.extend(x);
            ys.push(y);
        }
        (xs, ys)
    }
}

/// Markov-chain token stream for the LM workload.
pub struct TokenStream {
    pub vocab: usize,
    seed: u64,
}

impl TokenStream {
    pub fn new(vocab: usize, seed: u64) -> TokenStream {
        TokenStream { vocab, seed }
    }

    /// successors of state s: 4 deterministic pseudo-random candidates
    fn successors(&self, s: i32) -> [i32; 4] {
        let v = self.vocab as u64;
        let h = (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.seed;
        [
            (h % v) as i32,
            ((h >> 16) % v) as i32,
            ((h >> 32) % v) as i32,
            ((h >> 48) % v) as i32,
        ]
    }

    /// Generate a stream of `n` tokens for `stream_id` (worker shard).
    pub fn generate(&self, stream_id: u64, n: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.seed ^ 0xBEEF).fork(stream_id);
        let mut out = Vec::with_capacity(n);
        let mut s = rng.below(self.vocab) as i32;
        for _ in 0..n {
            out.push(s);
            s = self.successors(s)[rng.below(4)];
        }
        out
    }

    /// (x, y) next-token batch: x = tokens[i..i+L], y = tokens[i+1..i+L+1].
    pub fn lm_batch(
        &self,
        stream_id: u64,
        cursor: usize,
        batch: usize,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let need = cursor + batch * (seq + 1) + 1;
        let toks = self.generate(stream_id, need);
        let mut xs = Vec::with_capacity(batch * seq);
        let mut ys = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let start = cursor + b * (seq + 1);
            xs.extend_from_slice(&toks[start..start + seq]);
            ys.extend_from_slice(&toks[start + 1..start + seq + 1]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_deterministic() {
        let d = ImageDataset::new(ImageSpec::default());
        let (a1, l1) = d.example(42);
        let (a2, l2) = d.example(42);
        assert_eq!(a1, a2);
        assert_eq!(l1, l2);
        let (b, _) = d.example(43);
        assert_ne!(a1, b);
    }

    #[test]
    fn labels_mostly_match_class() {
        let d = ImageDataset::new(ImageSpec::default());
        let n = 1000u64;
        let matches = (0..n)
            .filter(|&i| d.example(i).1 as u64 == i % d.spec.classes as u64)
            .count();
        assert!(matches as f64 / n as f64 > 0.95, "{matches}");
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        let d = ImageDataset::new(ImageSpec::default());
        let tmp = std::env::temp_dir().join(format!("tmpi_data_test_{}", std::process::id()));
        let k = 3;
        let mut all_first_pixels = Vec::new();
        for shard in 0..k {
            let sf = d.write_shard(&tmp, shard, k, 4, 2).unwrap();
            assert_eq!(sf.files.len(), 2);
            assert_eq!(sf.labels.len(), 8);
            for f in &sf.files {
                let bytes = std::fs::read(f).unwrap();
                assert_eq!(bytes.len(), 4 * 3 * 36 * 36);
                all_first_pixels.push(bytes[..8].to_vec());
            }
        }
        // shards saw different examples
        all_first_pixels.dedup();
        assert!(all_first_pixels.len() > 1);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn crop_shapes_and_mirror() {
        let s = ImageSpec::default();
        let d = ImageDataset::new(s.clone());
        let (img, _) = d.example(0);
        let mean = d.mean_image();
        let a = crop(&img, &mean, &s, 0, 0, false);
        let m = crop(&img, &mean, &s, 0, 0, true);
        assert_eq!(a.len(), 3 * 32 * 32);
        // mirror flips x within each row
        assert_eq!(a[0], m[31]);
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 1.0 + 1e-6));
    }

    #[test]
    fn token_stream_learnable_and_deterministic() {
        let t = TokenStream::new(256, 7);
        let a = t.generate(0, 1000);
        let b = t.generate(0, 1000);
        assert_eq!(a, b);
        // every transition lands in the 4-successor set
        for w in a.windows(2) {
            assert!(t.successors(w[0]).contains(&w[1]));
        }
        // different stream ids decorrelate
        let c = t.generate(1, 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn lm_batch_shifted_by_one() {
        let t = TokenStream::new(64, 3);
        let (x, y) = t.lm_batch(0, 0, 2, 8);
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        // y is x shifted within each row
        assert_eq!(x[1], y[0]);
        assert_eq!(x[9], y[8]);
    }
}
