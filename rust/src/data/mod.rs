//! Synthetic workloads — the ImageNet / corpus substitute (DESIGN.md §2).
//!
//! * [`ImageDataset`]: a deterministic image-classification task. Each class
//!   has a fixed random prototype image; samples are prototype + Gaussian
//!   noise + random shift, quantized to u8 and written as **batch files on
//!   disk** exactly like the paper's preprocessed ImageNet batches (§3.3) so
//!   the parallel loader exercises real file I/O, mean subtraction,
//!   cropping and mirroring. Labels (small) stay in memory, as in the paper
//!   (footnote 6).
//! * [`TokenStream`]: an order-1 Markov chain over a vocabulary (4 likely
//!   successors per state ⇒ optimal LM loss ≈ ln 4); the e2e transformer
//!   trains on it.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Rng;

/// Storage resolution is larger than the model input so the loader's random
/// crop (Alg. 1 step 11) is a real operation: store 36×36, crop to 32×32.
#[derive(Clone, Debug)]
pub struct ImageSpec {
    pub classes: usize,
    pub channels: usize,
    /// stored resolution (pre-crop)
    pub store_hw: usize,
    /// model input resolution (crop target)
    pub crop_hw: usize,
    pub noise: f32,
    pub label_noise: f32,
    pub seed: u64,
}

impl Default for ImageSpec {
    fn default() -> Self {
        ImageSpec {
            classes: 16,
            channels: 3,
            store_hw: 36,
            crop_hw: 32,
            noise: 0.18,
            label_noise: 0.02,
            seed: 1234,
        }
    }
}

/// In-memory generator (prototypes) + on-disk batch store.
pub struct ImageDataset {
    pub spec: ImageSpec,
    prototypes: Vec<Vec<f32>>, // classes × (C*store*store), values in [0,1]
}

impl ImageDataset {
    pub fn new(spec: ImageSpec) -> ImageDataset {
        let mut rng = Rng::new(spec.seed);
        let px = spec.channels * spec.store_hw * spec.store_hw;
        // smooth prototypes: low-frequency random fields so crops stay
        // class-informative
        let prototypes = (0..spec.classes)
            .map(|_| {
                let mut base = vec![0.0f32; px];
                let hw = spec.store_hw;
                for c in 0..spec.channels {
                    // random plane waves per channel
                    let (fx, fy) = (rng.next_f64() * 0.6 + 0.1, rng.next_f64() * 0.6 + 0.1);
                    let (px_, py_) = (rng.next_f64() * 6.0, rng.next_f64() * 6.0);
                    let amp = 0.35 + 0.15 * rng.next_f64();
                    for y in 0..hw {
                        for x in 0..hw {
                            let v = ((x as f64 * fx + px_).sin() * (y as f64 * fy + py_).cos())
                                * amp
                                + 0.5;
                            base[c * hw * hw + y * hw + x] = v as f32;
                        }
                    }
                }
                base
            })
            .collect();
        ImageDataset { spec, prototypes }
    }

    /// Deterministic example by global index: (u8 pixels, label).
    pub fn example(&self, index: u64) -> (Vec<u8>, i32) {
        let s = &self.spec;
        let mut rng = Rng::new(s.seed ^ 0x1111).fork(index + 1);
        let true_class = (index as usize) % s.classes;
        let label = if rng.next_f64() < s.label_noise as f64 {
            rng.below(s.classes) as i32
        } else {
            true_class as i32
        };
        let proto = &self.prototypes[true_class];
        let px = proto.len();
        let mut img = Vec::with_capacity(px);
        for i in 0..px {
            let v = proto[i] + s.noise * rng.gauss_f32();
            img.push((v.clamp(0.0, 1.0) * 255.0) as u8);
        }
        (img, label)
    }

    /// Mean image over the prototype set (the paper subtracts a fixed
    /// ImageNet mean image) as f32 in pixel units.
    pub fn mean_image(&self) -> Vec<f32> {
        let px = self.prototypes[0].len();
        let mut mean = vec![0.0f32; px];
        for p in &self.prototypes {
            for (m, v) in mean.iter_mut().zip(p) {
                *m += v * 255.0;
            }
        }
        for m in mean.iter_mut() {
            *m /= self.prototypes.len() as f32;
        }
        mean
    }

    /// Write `n_batches` batch files of `batch` examples (shard `shard` of
    /// `n_shards`) under `dir`, plus labels and the mean image. Returns the
    /// file paths in order — the training process feeds these to its loader
    /// child one filename at a time (Alg. 1).
    pub fn write_shard(
        &self,
        dir: &Path,
        shard: usize,
        n_shards: usize,
        batch: usize,
        n_batches: usize,
    ) -> Result<ShardFiles> {
        fs::create_dir_all(dir)?;
        let mut files = Vec::with_capacity(n_batches);
        let mut labels = Vec::with_capacity(n_batches * batch);
        for b in 0..n_batches {
            let path = dir.join(format!("shard{shard}_batch{b:05}.bin"));
            let mut buf = Vec::with_capacity(batch * self.prototypes[0].len());
            for i in 0..batch {
                // global index interleaves shards (disjoint coverage)
                let idx = ((b * batch + i) * n_shards + shard) as u64;
                let (img, label) = self.example(idx);
                buf.extend_from_slice(&img);
                labels.push(label);
            }
            let mut f = fs::File::create(&path).with_context(|| format!("{path:?}"))?;
            f.write_all(&buf)?;
            files.push(path);
        }
        let mean = self.mean_image();
        Ok(ShardFiles { files, labels, mean, batch, spec: self.spec.clone(), reused: false })
    }

    /// Epoch-scale segment store: like [`write_shard`](Self::write_shard),
    /// but the segment is written **once** and reused across runs. The
    /// directory is keyed by the (spec, shard) [`fingerprint`]
    /// (`seg-<fp>/` under `root`), labels persist in `labels.bin`, and a
    /// `MANIFEST` file — written *last*, after every batch file is on disk
    /// via tmp+rename — marks the segment complete. A later run (or a
    /// concurrent worker) that finds a valid manifest skips generation
    /// entirely and gets `reused = true`.
    pub fn ensure_shard(
        &self,
        root: &Path,
        shard: usize,
        n_shards: usize,
        batch: usize,
        n_batches: usize,
    ) -> Result<ShardFiles> {
        let fp = fingerprint(&self.spec, shard, n_shards, batch, n_batches);
        let dir = root.join(format!("seg-{fp:016x}"));
        let manifest = dir.join("MANIFEST");
        let manifest_body = format!(
            "tmpi-seg v{SEG_FORMAT_VERSION} fp={fp:016x} shard={shard}/{n_shards} \
             batch={batch} n_batches={n_batches}\n"
        );
        let assemble = |reused: bool| -> Result<ShardFiles> {
            let files: Vec<PathBuf> =
                (0..n_batches).map(|b| dir.join(format!("shard{shard}_batch{b:05}.bin"))).collect();
            let raw = fs::read(dir.join("labels.bin"))
                .with_context(|| format!("labels.bin in {dir:?}"))?;
            if raw.len() != 4 * batch * n_batches {
                anyhow::bail!(
                    "{dir:?}: labels.bin has {} bytes, want {}",
                    raw.len(),
                    4 * batch * n_batches
                );
            }
            let labels =
                raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
            let mean = self.mean_image();
            Ok(ShardFiles { files, labels, mean, batch, spec: self.spec.clone(), reused })
        };
        if matches!(fs::read_to_string(&manifest), Ok(got) if got == manifest_body) {
            return assemble(true);
        }
        // (Re)generate into a private tmp dir, then rename into place so a
        // crash or a concurrent writer can never expose a half-built
        // segment — the manifest only ever coexists with complete data.
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = root.join(format!(".seg-{fp:016x}.tmp-{}-{seq}", std::process::id()));
        fs::create_dir_all(&tmp)?;
        let px = self.prototypes[0].len();
        let mut labels_buf = Vec::with_capacity(4 * batch * n_batches);
        for b in 0..n_batches {
            let mut buf = Vec::with_capacity(batch * px);
            for i in 0..batch {
                let idx = ((b * batch + i) * n_shards + shard) as u64;
                let (img, label) = self.example(idx);
                buf.extend_from_slice(&img);
                labels_buf.extend_from_slice(&label.to_le_bytes());
            }
            let path = tmp.join(format!("shard{shard}_batch{b:05}.bin"));
            let mut f = fs::File::create(&path).with_context(|| format!("{path:?}"))?;
            f.write_all(&buf)?;
        }
        fs::write(tmp.join("labels.bin"), &labels_buf)?;
        fs::write(tmp.join("MANIFEST"), manifest_body.as_bytes())?;
        match fs::rename(&tmp, &dir) {
            Ok(()) => assemble(false),
            Err(e) => {
                // a concurrent run may have won the rename — their segment
                // is bit-identical (same fingerprint), so reuse it
                let _ = fs::remove_dir_all(&tmp);
                if matches!(fs::read_to_string(&manifest), Ok(got) if got == manifest_body) {
                    assemble(true)
                } else {
                    Err(e).with_context(|| format!("publish segment {dir:?}"))
                }
            }
        }
    }

    /// An in-memory eval batch (already mean-subtracted + center-cropped):
    /// returns (x: f32 NCHW, y) ready for the eval artifact.
    pub fn eval_batch(&self, start_index: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let s = &self.spec;
        let mean = self.mean_image();
        let mut xs = Vec::with_capacity(batch * s.channels * s.crop_hw * s.crop_hw);
        let mut ys = Vec::with_capacity(batch);
        let off = (s.store_hw - s.crop_hw) / 2;
        for i in 0..batch {
            // eval stream offset far from train indices
            let (img, label) = self.example(1_000_000_007 + start_index + i as u64);
            xs.extend(crop(&img, &mean, s, off, off, false));
            ys.push(label);
        }
        (xs, ys)
    }
}

/// One worker's on-disk shard.
pub struct ShardFiles {
    pub files: Vec<PathBuf>,
    /// labels for batch b are labels[b*batch..(b+1)*batch] — in memory,
    /// like the paper's label handling (footnote 6)
    pub labels: Vec<i32>,
    pub mean: Vec<f32>,
    pub batch: usize,
    pub spec: ImageSpec,
    /// true when `ensure_shard` found a complete fingerprint-matched
    /// segment on disk instead of generating one
    pub reused: bool,
}

/// Segment layout version — bump to invalidate every on-disk segment.
const SEG_FORMAT_VERSION: u64 = 1;

/// FNV-1a over everything that determines a segment's bytes: the image
/// spec (f32 fields via `to_bits`, so the hash is exact, not approximate),
/// the shard coordinates, and the layout version. Two runs with equal
/// fingerprints may share segment files byte-for-byte.
pub fn fingerprint(
    spec: &ImageSpec,
    shard: usize,
    n_shards: usize,
    batch: usize,
    n_batches: usize,
) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for v in [
        SEG_FORMAT_VERSION,
        spec.classes as u64,
        spec.channels as u64,
        spec.store_hw as u64,
        spec.crop_hw as u64,
        spec.noise.to_bits() as u64,
        spec.label_noise.to_bits() as u64,
        spec.seed,
        shard as u64,
        n_shards as u64,
        batch as u64,
        n_batches as u64,
    ] {
        eat(v);
    }
    h
}

/// Epoch-scale addressing: maps millions of samples to (shard, batch,
/// offset) deterministically, without materializing anything. Uses the
/// same interleaved global-index convention as `write_shard` /
/// `ensure_shard` (`idx = (batch_idx*batch + i)*shards + shard`), so a
/// plan and the segment store agree on which worker sees which sample.
#[derive(Clone, Copy, Debug)]
pub struct EpochPlan {
    pub epoch_samples: u64,
    pub shards: usize,
    pub batch: usize,
}

impl EpochPlan {
    /// Whole batches each shard owns (trailing ragged samples dropped, as
    /// in the paper's fixed-size batch files).
    pub fn batches_per_shard(&self) -> usize {
        (self.epoch_samples / (self.shards as u64 * self.batch as u64)) as usize
    }

    /// Global dataset index of sample `i` of batch `batch_idx` on `shard`.
    pub fn global_index(&self, shard: usize, batch_idx: usize, i: usize) -> u64 {
        ((batch_idx * self.batch + i) * self.shards + shard) as u64
    }

    /// Which shard owns a global index (inverse of the interleaving).
    pub fn shard_of(&self, global_idx: u64) -> usize {
        (global_idx % self.shards as u64) as usize
    }
}

/// Mean-subtract + crop (+ optional horizontal mirror) one stored image.
/// `img` is u8 at store_hw; output is f32 NCHW at crop_hw. This is Alg. 1
/// steps 10–11, shared by the loader and the eval path.
pub fn crop(img: &[u8], mean: &[f32], s: &ImageSpec, ox: usize, oy: usize, mirror: bool) -> Vec<f32> {
    let (hw, chw) = (s.store_hw, s.crop_hw);
    let mut out = Vec::with_capacity(s.channels * chw * chw);
    for c in 0..s.channels {
        for y in 0..chw {
            for x in 0..chw {
                let sx = if mirror { ox + chw - 1 - x } else { ox + x };
                let idx = c * hw * hw + (oy + y) * hw + sx;
                out.push((img[idx] as f32 - mean[idx]) / 255.0);
            }
        }
    }
    out
}

/// Flat-feature classification task for the MLP (class prototypes in R^d +
/// Gaussian noise; the fast model for scheme/strategy studies).
pub struct FeatureDataset {
    pub dim: usize,
    pub classes: usize,
    pub noise: f32,
    pub label_noise: f32,
    seed: u64,
    prototypes: Vec<Vec<f32>>,
}

impl FeatureDataset {
    pub fn new(dim: usize, classes: usize, seed: u64) -> FeatureDataset {
        let mut rng = Rng::new(seed ^ 0xFEA7);
        let prototypes = (0..classes)
            .map(|_| (0..dim).map(|_| rng.gauss_f32()).collect())
            .collect();
        FeatureDataset { dim, classes, noise: 0.8, label_noise: 0.02, seed, prototypes }
    }

    pub fn example(&self, index: u64) -> (Vec<f32>, i32) {
        let mut rng = Rng::new(self.seed ^ 0x2222).fork(index + 1);
        let true_class = (index as usize) % self.classes;
        let label = if rng.next_f64() < self.label_noise as f64 {
            rng.below(self.classes) as i32
        } else {
            true_class as i32
        };
        let proto = &self.prototypes[true_class];
        let x = proto.iter().map(|&p| p + self.noise * rng.gauss_f32()).collect();
        (x, label)
    }

    /// Shard-disjoint training batch (worker `shard` of `n_shards`).
    pub fn batch(
        &self,
        shard: usize,
        n_shards: usize,
        iter: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let idx = (((iter * batch + i) * n_shards) + shard) as u64;
            let (x, y) = self.example(idx);
            xs.extend(x);
            ys.push(y);
        }
        (xs, ys)
    }

    pub fn eval_batch(&self, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let (x, y) = self.example(2_000_000_011 + i as u64);
            xs.extend(x);
            ys.push(y);
        }
        (xs, ys)
    }
}

/// Markov-chain token stream for the LM workload.
pub struct TokenStream {
    pub vocab: usize,
    seed: u64,
}

impl TokenStream {
    pub fn new(vocab: usize, seed: u64) -> TokenStream {
        TokenStream { vocab, seed }
    }

    /// successors of state s: 4 deterministic pseudo-random candidates
    fn successors(&self, s: i32) -> [i32; 4] {
        let v = self.vocab as u64;
        let h = (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.seed;
        [
            (h % v) as i32,
            ((h >> 16) % v) as i32,
            ((h >> 32) % v) as i32,
            ((h >> 48) % v) as i32,
        ]
    }

    /// Generate a stream of `n` tokens for `stream_id` (worker shard).
    pub fn generate(&self, stream_id: u64, n: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.seed ^ 0xBEEF).fork(stream_id);
        let mut out = Vec::with_capacity(n);
        let mut s = rng.below(self.vocab) as i32;
        for _ in 0..n {
            out.push(s);
            s = self.successors(s)[rng.below(4)];
        }
        out
    }

    /// (x, y) next-token batch: x = tokens[i..i+L], y = tokens[i+1..i+L+1].
    pub fn lm_batch(
        &self,
        stream_id: u64,
        cursor: usize,
        batch: usize,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let need = cursor + batch * (seq + 1) + 1;
        let toks = self.generate(stream_id, need);
        let mut xs = Vec::with_capacity(batch * seq);
        let mut ys = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let start = cursor + b * (seq + 1);
            xs.extend_from_slice(&toks[start..start + seq]);
            ys.extend_from_slice(&toks[start + 1..start + seq + 1]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_deterministic() {
        let d = ImageDataset::new(ImageSpec::default());
        let (a1, l1) = d.example(42);
        let (a2, l2) = d.example(42);
        assert_eq!(a1, a2);
        assert_eq!(l1, l2);
        let (b, _) = d.example(43);
        assert_ne!(a1, b);
    }

    #[test]
    fn labels_mostly_match_class() {
        let d = ImageDataset::new(ImageSpec::default());
        let n = 1000u64;
        let matches = (0..n)
            .filter(|&i| d.example(i).1 as u64 == i % d.spec.classes as u64)
            .count();
        assert!(matches as f64 / n as f64 > 0.95, "{matches}");
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        let d = ImageDataset::new(ImageSpec::default());
        let tmp = std::env::temp_dir().join(format!("tmpi_data_test_{}", std::process::id()));
        let k = 3;
        let mut all_first_pixels = Vec::new();
        for shard in 0..k {
            let sf = d.write_shard(&tmp, shard, k, 4, 2).unwrap();
            assert_eq!(sf.files.len(), 2);
            assert_eq!(sf.labels.len(), 8);
            for f in &sf.files {
                let bytes = std::fs::read(f).unwrap();
                assert_eq!(bytes.len(), 4 * 3 * 36 * 36);
                all_first_pixels.push(bytes[..8].to_vec());
            }
        }
        // shards saw different examples
        all_first_pixels.dedup();
        assert!(all_first_pixels.len() > 1);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn crop_shapes_and_mirror() {
        let s = ImageSpec::default();
        let d = ImageDataset::new(s.clone());
        let (img, _) = d.example(0);
        let mean = d.mean_image();
        let a = crop(&img, &mean, &s, 0, 0, false);
        let m = crop(&img, &mean, &s, 0, 0, true);
        assert_eq!(a.len(), 3 * 32 * 32);
        // mirror flips x within each row
        assert_eq!(a[0], m[31]);
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 1.0 + 1e-6));
    }

    #[test]
    fn ensure_shard_writes_once_and_reuses() {
        let d = ImageDataset::new(ImageSpec::default());
        let tmp = std::env::temp_dir().join(format!("tmpi_seg_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let a = d.ensure_shard(&tmp, 1, 2, 4, 3).unwrap();
        assert!(!a.reused);
        assert_eq!(a.files.len(), 3);
        assert_eq!(a.labels.len(), 12);
        let first = std::fs::read(&a.files[0]).unwrap();
        // second run: fingerprint matches ⇒ no regeneration, same bytes
        let b = d.ensure_shard(&tmp, 1, 2, 4, 3).unwrap();
        assert!(b.reused);
        assert_eq!(b.files, a.files);
        assert_eq!(b.labels, a.labels);
        assert_eq!(std::fs::read(&b.files[0]).unwrap(), first);
        // segment content matches the per-run writer exactly (same global
        // index convention), so loader/bsp behavior is unchanged
        let w = d.write_shard(&tmp.join("per_run"), 1, 2, 4, 3).unwrap();
        assert_eq!(w.labels, a.labels);
        assert_eq!(std::fs::read(&w.files[0]).unwrap(), first);
        // a different shard coordinate lands in a different segment dir
        let c = d.ensure_shard(&tmp, 0, 2, 4, 3).unwrap();
        assert!(!c.reused);
        assert_ne!(c.files[0], a.files[0]);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn fingerprint_sensitive_to_spec_and_coords() {
        let s = ImageSpec::default();
        let base = fingerprint(&s, 0, 4, 32, 10);
        assert_ne!(base, fingerprint(&s, 1, 4, 32, 10));
        assert_ne!(base, fingerprint(&s, 0, 8, 32, 10));
        assert_ne!(base, fingerprint(&s, 0, 4, 16, 10));
        assert_ne!(base, fingerprint(&s, 0, 4, 32, 20));
        let mut s2 = s.clone();
        s2.noise += 0.01;
        assert_ne!(base, fingerprint(&s2, 0, 4, 32, 10));
        let mut s3 = s.clone();
        s3.seed ^= 1;
        assert_ne!(base, fingerprint(&s3, 0, 4, 32, 10));
        // determinism
        assert_eq!(base, fingerprint(&ImageSpec::default(), 0, 4, 32, 10));
    }

    #[test]
    fn epoch_plan_covers_millions_disjointly() {
        // 1.28M samples over 8 shards of batch 32 — the epoch scale the
        // segment store is built for
        let p = EpochPlan { epoch_samples: 1_280_000, shards: 8, batch: 32 };
        assert_eq!(p.batches_per_shard(), 5000);
        // extremes of the index range stay inside the epoch
        assert_eq!(p.global_index(0, 0, 0), 0);
        assert_eq!(p.global_index(7, 4999, 31), 1_279_999);
        // ownership is the exact inverse of the interleaving
        for shard in 0..8 {
            for &bi in &[0usize, 17, 4999] {
                for &i in &[0usize, 1, 31] {
                    let g = p.global_index(shard, bi, i);
                    assert!(g < p.epoch_samples);
                    assert_eq!(p.shard_of(g), shard);
                }
            }
        }
        // disjointness: distinct (shard, batch, i) ⇒ distinct global index
        let mut seen = std::collections::HashSet::new();
        for shard in 0..8 {
            for bi in 0..4 {
                for i in 0..32 {
                    assert!(seen.insert(p.global_index(shard, bi, i)));
                }
            }
        }
        // ...and the first 4 batches per shard tile a contiguous prefix
        assert_eq!(seen.len(), 8 * 4 * 32);
        assert!((0..(8 * 4 * 32) as u64).all(|g| seen.contains(&g)));
    }

    #[test]
    fn token_stream_learnable_and_deterministic() {
        let t = TokenStream::new(256, 7);
        let a = t.generate(0, 1000);
        let b = t.generate(0, 1000);
        assert_eq!(a, b);
        // every transition lands in the 4-successor set
        for w in a.windows(2) {
            assert!(t.successors(w[0]).contains(&w[1]));
        }
        // different stream ids decorrelate
        let c = t.generate(1, 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn lm_batch_shifted_by_one() {
        let t = TokenStream::new(64, 3);
        let (x, y) = t.lm_batch(0, 0, 2, 8);
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        // y is x shifted within each row
        assert_eq!(x[1], y[0]);
        assert_eq!(x[9], y[8]);
    }
}
