//! Half-precision (IEEE f16 / bfloat16) software conversion.
//!
//! The ASA16 strategy (paper §3.2) transfers parameters as 16-bit halves and
//! sums at full precision. On the wire the bits are `u16`; the Pallas
//! pack/unpack kernels (L1) produce/consume the same format, and this module
//! is the host-side mirror: it must match XLA's f32->f16 conversion
//! **bit-exactly** (round-to-nearest-even, as both IEEE 754 and XLA use) so
//! the rust baseline path and the kernel path are interchangeable —
//! integration tests assert equality against the AOT kernels.

/// f32 -> IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: keep a quiet-NaN payload bit if NaN
        return sign | 0x7C00 | u16::from(man != 0) << 9;
    }

    // unbiased exponent; f16 bias is 15, f32 bias is 127
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign; // underflow to signed zero
        }
        // implicit leading 1, shifted into subnormal position
        let man = man | 0x0080_0000;
        let shift = 14 - e; // 14..24
        let half = 1u32 << (shift - 1);
        let rounded = man + (half - 1) + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // normal: round mantissa from 23 to 10 bits, round-to-nearest-even
    let rounded = man + 0x0FFF + ((man >> 13) & 1);
    if rounded & 0x0080_0000 != 0 {
        // mantissa overflow bumps the exponent
        let e = e + 1;
        if e >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e as u16) << 10);
    }
    sign | ((e as u16) << 10) | (rounded >> 13) as u16
}

/// IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = u32::from(h & 0x03FF);
    let bits = match exp {
        0 => {
            if man == 0 {
                sign // signed zero
            } else {
                // subnormal: value = man * 2^-24; normalize to 1.f * 2^(p-24)
                // where p is the highest set bit of man (0..=9)
                let p = 31 - man.leading_zeros();
                let frac = (man << (10 - p)) & 0x03FF;
                let e = p + 103; // (p - 24) + 127
                sign | (e << 23) | (frac << 13)
            }
        }
        0x1F => sign | 0x7F80_0000 | (man << 13), // inf / nan
        _ => {
            let e = u32::from(exp) + 127 - 15;
            sign | (e << 23) | (man << 13)
        }
    };
    f32::from_bits(bits)
}

/// f32 -> bfloat16 bits, round-to-nearest-even (XLA semantics).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet the NaN
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// bfloat16 bits -> f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits(u32::from(h) << 16)
}

/// Wire format used by the ASA16 exchange (paper uses CUDA half = IEEE f16;
/// bf16 is the TPU-native option — DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wire {
    F16,
    Bf16,
}

impl Wire {
    pub fn name(self) -> &'static str {
        match self {
            Wire::F16 => "f16",
            Wire::Bf16 => "bf16",
        }
    }

    #[inline]
    pub fn pack_one(self, x: f32) -> u16 {
        match self {
            Wire::F16 => f32_to_f16_bits(x),
            Wire::Bf16 => f32_to_bf16_bits(x),
        }
    }

    #[inline]
    pub fn unpack_one(self, h: u16) -> f32 {
        match self {
            Wire::F16 => f16_bits_to_f32(h),
            Wire::Bf16 => bf16_bits_to_f32(h),
        }
    }

    pub fn pack(self, xs: &[f32], out: &mut Vec<u16>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.pack_one(x)));
    }

    pub fn unpack(self, hs: &[u16], out: &mut Vec<f32>) {
        out.clear();
        out.extend(hs.iter().map(|&h| self.unpack_one(h)));
    }
}

/// Max relative error of a half-precision round trip (for reports/tests).
pub fn roundtrip_rel_error(wire: Wire, xs: &[f32]) -> f64 {
    xs.iter()
        .map(|&x| {
            let back = wire.unpack_one(wire.pack_one(x));
            if x.abs() > 1e-20 {
                ((back - x).abs() / x.abs()) as f64
            } else {
                (back - x).abs() as f64
            }
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds to inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(5.9604645e-8), 0x0001); // smallest subnormal
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_roundtrip_exact_for_representables() {
        // all 2^16 f16 bit patterns (minus NaNs) round-trip exactly
        for h in 0..=u16::MAX {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), h, "bits {h:#06x} -> {f}");
        }
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10: ties-to-even -> 1.0
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C00);
        // 1.0 + 3*2^-11 is halfway between consecutive halves: rounds up to even
        let y = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(y), 0x3C02);
    }

    #[test]
    fn bf16_known_values() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3F80);
        assert_eq!(f32_to_bf16_bits(-1.0), 0xBF80);
        assert_eq!(bf16_bits_to_f32(0x3F80), 1.0);
        // round-to-nearest-even on the 16th bit
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8000)), 0x3F80); // tie -> even
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F81_8000)), 0x3F82); // tie -> even (up)
    }

    #[test]
    fn rel_error_bounds() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.37).collect();
        assert!(roundtrip_rel_error(Wire::F16, &xs) < 1e-3);
        assert!(roundtrip_rel_error(Wire::Bf16, &xs) < 1e-2);
    }
}
