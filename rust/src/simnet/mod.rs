//! Interconnect timing model — the simulated clock behind every exchange.
//!
//! The paper's communication results (Fig. 3, Table 3) are bandwidth
//! phenomena: who crosses PCIe/QPI/IB how many times, with or without host
//! staging. This module prices a *phase* — a set of point-to-point transfers
//! that proceed concurrently — with a contention-aware model:
//!
//!   phase time = max over shared link resources (total bytes / bandwidth)
//!              + max over transfers (sum of per-hop latencies)
//!
//! Pipelined hops (MPI chunking) justify the `max` across a single
//! transfer's hops; serialization on a shared resource (one PCIe lane per
//! GPU, one NIC per node, one QPI per node) justifies the byte accumulation.
//!
//! CUDA-awareness (paper §3.2): with `cuda_aware`, a P2P transfer under one
//! PCIe switch moves device-to-device (GPUDirect); without it, the buffer
//! staged through host RAM, adding host-memory crossings. QPI-crossing and
//! inter-node paths always stage through the host on the paper's testbed
//! (no GPUDirect RDMA; P2P limited to one switch — §6).
//!
//! All public boundaries are dimensional ([`crate::units`]): volumes are
//! [`Bytes`], rates [`GbPerS`], configured latencies [`Micros`], and every
//! priced duration a [`Secs`] — so a caller cannot feed microseconds into
//! a timeline or a KiB knob into a byte lane without a conversion.

use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::{IbGen, PathKind, Topology};
use crate::units::{Bytes, GbPerS, Micros, Secs};

/// Bandwidths in GB/s, latencies in microseconds.
#[derive(Clone, Copy, Debug)]
pub struct LinkParams {
    pub pcie_gbps: GbPerS,
    pub pcie_lat_us: Micros,
    pub qpi_gbps: GbPerS,
    pub qpi_lat_us: Micros,
    pub ib_fdr_gbps: GbPerS,
    pub ib_qdr_gbps: GbPerS,
    pub ib_lat_us: Micros,
    /// Host memcpy bandwidth for staged paths.
    pub host_mem_gbps: GbPerS,
    /// CPU-side elementwise reduction (the AR baseline sums on the host).
    pub host_reduce_gbps: GbPerS,
    /// GPU summation kernel effective bandwidth (the ASA sum — §3.2 measured
    /// it at 1.6 % of communication time).
    pub gpu_reduce_gbps: GbPerS,
    /// GPU cast kernel effective bandwidth (fp16 pack/unpack).
    pub gpu_cast_gbps: GbPerS,
}

impl Default for LinkParams {
    fn default() -> Self {
        // K80-era constants: PCIe gen3 x16 effective ~12 GB/s, QPI ~16 GB/s,
        // IB FDR ~6.8 GB/s, IB QDR ~4 GB/s; host reduction is memory-bound.
        LinkParams {
            pcie_gbps: GbPerS(12.0),
            pcie_lat_us: Micros(10.0),
            qpi_gbps: GbPerS(16.0),
            qpi_lat_us: Micros(1.0),
            ib_fdr_gbps: GbPerS(6.8),
            ib_qdr_gbps: GbPerS(4.0),
            ib_lat_us: Micros(1.5),
            host_mem_gbps: GbPerS(10.0),
            host_reduce_gbps: GbPerS(5.0),
            gpu_reduce_gbps: GbPerS(150.0),
            gpu_cast_gbps: GbPerS(200.0),
        }
    }
}

impl LinkParams {
    pub fn ib_gbps(&self, gen: IbGen) -> GbPerS {
        match gen {
            IbGen::Fdr => self.ib_fdr_gbps,
            IbGen::Qdr => self.ib_qdr_gbps,
        }
    }

    /// Time to reduce `bytes` of f32 on the host CPU (AR baseline).
    pub fn host_reduce_time(&self, bytes: Bytes) -> Secs {
        bytes / self.host_reduce_gbps
    }

    /// Time for the GPU summation kernel over `bytes` (ASA sum).
    pub fn gpu_reduce_time(&self, bytes: Bytes) -> Secs {
        bytes / self.gpu_reduce_gbps
    }

    /// Time for the GPU fp16 cast kernel over `bytes` of f32 input.
    pub fn gpu_cast_time(&self, bytes: Bytes) -> Secs {
        bytes / self.gpu_cast_gbps
    }

    /// Host-staged D2H or H2D copy of `bytes` (one PCIe crossing).
    pub fn pcie_time(&self, bytes: Bytes) -> Secs {
        self.pcie_lat_us.to_secs() + bytes / self.pcie_gbps
    }
}

/// One point-to-point transfer inside a phase.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub src: usize,
    pub dst: usize,
    pub bytes: Bytes,
}

/// Shared fabric resources that serialize concurrent transfers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
enum Resource {
    PcieUp(usize),
    PcieDown(usize),
    Qpi(usize),
    NicOut(usize),
    NicIn(usize),
    HostMem(usize),
}

/// A phase's cost split into its bandwidth and latency components.
///
/// The split exists for the chunked pipeline scheduler: when the same
/// logical phase repeats back-to-back over a stream of chunks, the
/// per-message latency of chunk *i* rides under chunk *i−1*'s bandwidth
/// occupancy (the same wormhole-pipelining argument that justifies the
/// per-hop `max` inside one transfer), so a pipeline charges latency once
/// per stream while bandwidth accumulates per chunk.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseCost {
    /// Serialized byte time on the most-loaded shared resource.
    pub bandwidth: Secs,
    /// Worst per-transfer hop-latency sum in the phase.
    pub latency: Secs,
}

impl PhaseCost {
    pub fn total(&self) -> Secs {
        self.bandwidth + self.latency
    }
}

/// One stage of a chunked software pipeline: the wire time of a chunk's
/// transfers and the kernel/arithmetic time that must follow them.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStage {
    /// Full wire time of this chunk (bandwidth + latency), as priced by
    /// the strategy for the chunk in isolation.
    pub transfer: Secs,
    /// Latency part of `transfer` — hidden under the previous chunk's
    /// bandwidth for every stage after the first.
    pub latency: Secs,
    /// Summation/cast/host-reduce time gated on this chunk's arrival.
    pub kernel: Secs,
}

/// Overlap-aware makespan of a chunked exchange: the wire and the kernel
/// engine are each serial resources, a chunk's kernel starts only after its
/// own transfer, and transfers stream back-to-back (later chunks' latency is
/// pipelined away). Per stage this takes `max(transfer, kernel)` instead of
/// their sum — chunk *i*'s wire time overlaps chunk *i−1*'s kernels.
pub fn pipeline_time(stages: &[PipelineStage]) -> Secs {
    let mut wire_free = 0.0f64;
    let mut kernel_free = 0.0f64;
    for (i, s) in stages.iter().enumerate() {
        let t = if i == 0 { s.transfer.0 } else { (s.transfer.0 - s.latency.0).max(0.0) };
        wire_free += t;
        kernel_free = kernel_free.max(wire_free) + s.kernel.0;
    }
    Secs(kernel_free.max(wire_free))
}

/// Global intra-node vs inter-node byte split of one transfer set. Every
/// rank derives the same split from the same (global) transfer list, so
/// `CommReport`'s byte-split fields stay identical across ranks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficSplit {
    /// Bytes moved on intra-node paths (P2P or QPI-staged).
    pub intra_bytes: Bytes,
    /// Bytes that crossed a node boundary — each counted once, though it
    /// occupies both the sender's NIC-out and the receiver's NIC-in.
    pub inter_bytes: Bytes,
}

/// Classify a transfer set's bytes by whether they cross a node boundary.
pub fn split_traffic(topo: &Topology, transfers: &[Transfer]) -> TrafficSplit {
    let mut out = TrafficSplit::default();
    for t in transfers {
        if t.src == t.dst || t.bytes == 0 {
            continue;
        }
        if topo.gpus[t.src].node == topo.gpus[t.dst].node {
            out.intra_bytes += t.bytes;
        } else {
            out.inter_bytes += t.bytes;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Flow-shop pipeline: the two-level exchange's cross-level overlap model.
//
// A hierarchical exchange moves each chunk through an ordered chain of
// *serial fabric resources* ("machines"): the PCIe up-tree, the QPI/host-RAM
// socket hop, the node-leader NIC exchange, and the PCIe down-tree. When the
// chunked scheduler streams chunks through those levels, chunk *i*'s NIC leg
// runs while chunk *i+1* is still climbing its intra-node tree — the
// flow-shop makespan below prices exactly that. Levels whose dominant
// physical resource is shared (the socket hops up and down both serialize on
// host RAM) share one machine id so the model never overlaps load that would
// really contend.

/// Machine ids of the two-level exchange pipeline.
pub const MACHINE_INTRA_UP: usize = 0;
/// Socket-level hops, both directions: they share host RAM, so one machine.
pub const MACHINE_HOST: usize = 1;
/// The node-leader inter-node exchange (NIC-dominated).
pub const MACHINE_INTER: usize = 2;
pub const MACHINE_INTRA_DOWN: usize = 3;

/// One leg of a chunk's path: occupancy of a single serial machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Leg {
    pub machine: usize,
    /// Full wire time of the leg (bandwidth + latency).
    pub transfer: Secs,
    /// Latency part of `transfer`; per machine, only the stream's first
    /// chunk pays it (the wormhole argument of [`PhaseCost`]).
    pub latency: Secs,
}

/// One chunk's path through the pipeline: its legs in order, then the
/// kernel time gated on the chunk's arrival.
#[derive(Clone, Debug, Default)]
pub struct FlowJob {
    pub legs: Vec<Leg>,
    pub kernel: Secs,
}

/// Machine id of the single wire resource a *flat* strategy's exchange
/// occupies end-to-end — what the wait-free backprop scheduler prices a
/// bucket's transfer on when the strategy reports no per-level legs.
pub const MACHINE_WIRE: usize = 100;

/// One gradient bucket's job on the joint compute+comm timeline: the
/// backward-compute "machine" releases it at `release` (seconds after the
/// backward pass starts), and only then may its wire legs begin.
#[derive(Clone, Debug, Default)]
pub struct TimedJob {
    /// Gradient-ready time of the bucket's last (input-most) layer.
    pub release: Secs,
    pub job: FlowJob,
}

/// Release-gated flow-shop makespan — the wait-free-backprop timeline.
///
/// Identical machine semantics to [`flow_pipeline_time`], with two
/// differences that model the backward pass feeding the wire:
///
/// * a job's first leg cannot start before its `release` time (the bucket's
///   gradients do not exist yet), and
/// * the wormhole latency discount applies only while a machine streams
///   back-to-back: if a bucket finds the machine *idle* (its release came
///   after the previous bucket drained), the stream restarts and the full
///   per-message latency is paid again. [`flow_pipeline_time`] never stalls
///   (all jobs are released at 0), so it keeps the simpler once-per-machine
///   rule; a `TimedJob` list with all releases at 0 and a single machine
///   reduces exactly to [`pipeline_time`].
///
/// Jobs must be passed in release order (the backward pass emits buckets
/// top layer first); machines serve FIFO in that order. The returned
/// makespan is measured from the start of the backward pass, so it is
/// always `>= release` of the last job.
pub fn wfbp_timeline(jobs: &[TimedJob]) -> Secs {
    let mut machine_free: BTreeMap<usize, f64> = BTreeMap::new();
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    let mut kernel_free = 0.0f64;
    let mut last_release = 0.0f64;
    for tj in jobs {
        last_release = last_release.max(tj.release.0);
        let mut prev_done = tj.release.0;
        for leg in &tj.job.legs {
            let free = machine_free.entry(leg.machine).or_insert(0.0);
            let start = free.max(prev_done);
            // pay latency on first use or whenever the stream stalled
            let t = if seen.insert(leg.machine) || start > *free {
                leg.transfer.0
            } else {
                (leg.transfer.0 - leg.latency.0).max(0.0)
            };
            prev_done = start + t;
            *free = prev_done;
        }
        kernel_free = kernel_free.max(prev_done) + tj.job.kernel.0;
    }
    Secs(machine_free.values().copied().fold(kernel_free.max(last_release), f64::max))
}

/// Flow-shop makespan of a chunk stream: machines are serial, a chunk's
/// legs run in order, and chunks queue FIFO per machine (greedy, no
/// reordering). A job list whose legs all name one machine plus trailing
/// kernels reduces exactly to [`pipeline_time`].
pub fn flow_pipeline_time(jobs: &[FlowJob]) -> Secs {
    let mut machine_free: BTreeMap<usize, f64> = BTreeMap::new();
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    let mut kernel_free = 0.0f64;
    for job in jobs {
        let mut prev_done = 0.0f64;
        for leg in &job.legs {
            let t = if seen.insert(leg.machine) {
                leg.transfer.0
            } else {
                (leg.transfer.0 - leg.latency.0).max(0.0)
            };
            let free = machine_free.entry(leg.machine).or_insert(0.0);
            prev_done = free.max(prev_done) + t;
            *free = prev_done;
        }
        kernel_free = kernel_free.max(prev_done) + job.kernel.0;
    }
    Secs(machine_free.values().copied().fold(kernel_free, f64::max))
}

/// Price one phase of concurrent transfers on the topology.
pub fn phase_time(
    topo: &Topology,
    p: &LinkParams,
    transfers: &[Transfer],
    cuda_aware: bool,
) -> Secs {
    phase_cost(topo, p, transfers, cuda_aware).total()
}

/// Like [`phase_time`] but keeps bandwidth and latency separable.
pub fn phase_cost(
    topo: &Topology,
    p: &LinkParams,
    transfers: &[Transfer],
    cuda_aware: bool,
) -> PhaseCost {
    let mut load: BTreeMap<Resource, f64> = BTreeMap::new();
    let mut max_lat = 0.0f64;
    let add = |load: &mut BTreeMap<Resource, f64>, r: Resource, bytes: Bytes, gbps: GbPerS| {
        *load.entry(r).or_insert(0.0) += bytes.0 as f64 / (gbps.0 * 1e9);
    };

    for t in transfers {
        if t.src == t.dst || t.bytes == 0 {
            continue;
        }
        let (src, dst) = (topo.gpus[t.src], topo.gpus[t.dst]);
        let mut lat = 0.0;
        match topo.path(t.src, t.dst) {
            PathKind::Local => {}
            PathKind::P2p => {
                add(&mut load, Resource::PcieUp(t.src), t.bytes, p.pcie_gbps);
                add(&mut load, Resource::PcieDown(t.dst), t.bytes, p.pcie_gbps);
                lat += 2.0 * p.pcie_lat_us.0;
                if !cuda_aware {
                    // staged through host RAM: two extra memory crossings
                    add(&mut load, Resource::HostMem(src.node), 2 * t.bytes, p.host_mem_gbps);
                    lat += 2.0 * p.pcie_lat_us.0;
                }
            }
            PathKind::QpiStaged => {
                // always via CPU RAM (paper §6: P2P requires one switch)
                add(&mut load, Resource::PcieUp(t.src), t.bytes, p.pcie_gbps);
                add(&mut load, Resource::Qpi(src.node), t.bytes, p.qpi_gbps);
                add(&mut load, Resource::HostMem(src.node), 2 * t.bytes, p.host_mem_gbps);
                add(&mut load, Resource::PcieDown(t.dst), t.bytes, p.pcie_gbps);
                lat += 2.0 * p.pcie_lat_us.0 + p.qpi_lat_us.0;
            }
            PathKind::Network => {
                // no GPUDirect RDMA: D2H, NIC out, NIC in, H2D
                let ib = p.ib_gbps(topo.ib);
                add(&mut load, Resource::PcieUp(t.src), t.bytes, p.pcie_gbps);
                add(&mut load, Resource::HostMem(src.node), t.bytes, p.host_mem_gbps);
                add(&mut load, Resource::NicOut(src.node), t.bytes, ib);
                add(&mut load, Resource::NicIn(dst.node), t.bytes, ib);
                add(&mut load, Resource::HostMem(dst.node), t.bytes, p.host_mem_gbps);
                add(&mut load, Resource::PcieDown(t.dst), t.bytes, p.pcie_gbps);
                lat += 2.0 * p.pcie_lat_us.0 + p.ib_lat_us.0;
            }
        }
        max_lat = max_lat.max(lat * 1e-6);
    }

    PhaseCost {
        bandwidth: Secs(load.values().copied().fold(0.0, f64::max)),
        latency: Secs(max_lat),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;

    fn p() -> LinkParams {
        LinkParams::default()
    }

    #[test]
    fn zero_bytes_costs_nothing() {
        let t = Topology::mosaic(2);
        assert_eq!(
            phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes: Bytes(0) }], true),
            0.0
        );
    }

    #[test]
    fn p2p_cheaper_than_network() {
        let t = Topology::copper(2);
        let bytes = Bytes(100 << 20);
        let p2p = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes }], true);
        let net = phase_time(&t, &p(), &[Transfer { src: 0, dst: 8, bytes }], true);
        assert!(p2p < net, "p2p={p2p} net={net}");
    }

    #[test]
    fn cuda_aware_helps_p2p_only_when_host_is_bottleneck() {
        let t = Topology::copper(1);
        let bytes = Bytes(256 << 20);
        let aware = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes }], true);
        let staged = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes }], false);
        assert!(staged > aware, "staged={staged} aware={aware}");
    }

    #[test]
    fn qpi_crossing_costs_more_than_switch_local() {
        let t = Topology::copper(1);
        let bytes = Bytes(64 << 20);
        let local = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes }], true);
        let cross = phase_time(&t, &p(), &[Transfer { src: 0, dst: 4, bytes }], true);
        assert!(cross > local, "cross={cross} local={local}");
    }

    #[test]
    fn shared_nic_serializes() {
        let t = Topology::mosaic(3);
        let bytes = Bytes(64 << 20);
        // two transfers out of node 0 share its NIC -> ~2x one transfer
        let one = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes }], true);
        let two = phase_time(
            &t,
            &p(),
            &[Transfer { src: 0, dst: 1, bytes }, Transfer { src: 0, dst: 2, bytes }],
            true,
        );
        assert!(two > 1.8 * one, "two={two} one={one}");
    }

    #[test]
    fn disjoint_transfers_parallelize() {
        let t = Topology::mosaic(4);
        let bytes = Bytes(64 << 20);
        let one = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes }], true);
        // 0->1 and 2->3 share nothing: phase is as fast as one transfer
        let both = phase_time(
            &t,
            &p(),
            &[Transfer { src: 0, dst: 1, bytes }, Transfer { src: 2, dst: 3, bytes }],
            true,
        );
        assert!((both - one).abs() < 1e-9, "both={both} one={one}");
    }

    #[test]
    fn latency_counted_once_per_phase() {
        let t = Topology::mosaic(2);
        let tiny = phase_time(&t, &p(), &[Transfer { src: 0, dst: 1, bytes: Bytes(4) }], true);
        // dominated by latency terms (μs scale), far below 1 ms
        assert!(tiny < 1e-3 && tiny > 0.0);
    }

    #[test]
    fn phase_cost_splits_time() {
        let t = Topology::mosaic(2);
        let tr = [Transfer { src: 0, dst: 1, bytes: Bytes(64 << 20) }];
        let c = phase_cost(&t, &p(), &tr, true);
        assert!(c.bandwidth > 0.0 && c.latency > 0.0);
        assert!((c.total() - phase_time(&t, &p(), &tr, true)).abs() < 1e-15);
        // latency is the per-message term: μs scale, independent of bytes
        let c2 = phase_cost(&t, &p(), &[Transfer { src: 0, dst: 1, bytes: Bytes(4) }], true);
        assert!((c.latency - c2.latency).abs() < 1e-15);
    }

    #[test]
    fn pipeline_time_matches_hand_computation() {
        // two stages, no latency: t0 | max(t1 overlaps k0) | k1 drain
        let s = [
            PipelineStage { transfer: Secs(1.0), latency: Secs(0.0), kernel: Secs(0.5) },
            PipelineStage { transfer: Secs(1.0), latency: Secs(0.0), kernel: Secs(0.5) },
        ];
        // wire: 1.0 then 2.0; k0 runs 1.0..1.5; k1 starts max(2.0, 1.5)=2.0
        assert!((pipeline_time(&s) - Secs(2.5)).abs() < 1e-12);
    }

    #[test]
    fn pipeline_never_exceeds_serial_sum() {
        let mk = |t: f64, l: f64, k: f64| PipelineStage {
            transfer: Secs(t),
            latency: Secs(l),
            kernel: Secs(k),
        };
        let stages = [mk(0.3, 0.01, 0.2), mk(0.5, 0.01, 0.1), mk(0.2, 0.01, 0.4)];
        let serial: Secs = stages.iter().map(|s| s.transfer + s.kernel).sum();
        let piped = pipeline_time(&stages);
        assert!(piped <= serial + Secs(1e-12), "piped={piped} serial={serial}");
        // with >1 stage and nonzero kernels there is genuine overlap
        assert!(piped < serial, "no overlap: piped={piped} serial={serial}");
    }

    #[test]
    fn pipeline_kernel_bound_when_kernels_dominate() {
        // kernels much larger than transfers: makespan ~= t0 + sum(kernels)
        let stages: Vec<PipelineStage> = (0..4)
            .map(|_| PipelineStage { transfer: Secs(0.01), latency: Secs(0.0), kernel: Secs(1.0) })
            .collect();
        let t = pipeline_time(&stages);
        assert!((t - Secs(0.01 + 4.0)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn pipeline_single_stage_is_plain_sum() {
        let s = [PipelineStage { transfer: Secs(0.7), latency: Secs(0.1), kernel: Secs(0.2) }];
        assert!((pipeline_time(&s) - Secs(0.9)).abs() < 1e-12);
    }

    #[test]
    fn split_traffic_classifies_by_node() {
        let t = Topology::copper(2);
        let s = split_traffic(
            &t,
            &[
                Transfer { src: 0, dst: 1, bytes: Bytes(10) }, // same switch
                Transfer { src: 0, dst: 4, bytes: Bytes(20) }, // cross socket
                Transfer { src: 0, dst: 8, bytes: Bytes(40) }, // cross node
                Transfer { src: 3, dst: 3, bytes: Bytes(99) }, // self: ignored
                Transfer { src: 1, dst: 9, bytes: Bytes(0) },  // empty: ignored
            ],
        );
        assert_eq!(s.intra_bytes, 30);
        assert_eq!(s.inter_bytes, 40);
    }

    #[test]
    fn flow_single_machine_matches_pipeline_time() {
        let stages = [
            PipelineStage { transfer: Secs(0.3), latency: Secs(0.01), kernel: Secs(0.2) },
            PipelineStage { transfer: Secs(0.5), latency: Secs(0.01), kernel: Secs(0.1) },
            PipelineStage { transfer: Secs(0.2), latency: Secs(0.01), kernel: Secs(0.4) },
        ];
        let jobs: Vec<FlowJob> = stages
            .iter()
            .map(|s| FlowJob {
                legs: vec![Leg { machine: 7, transfer: s.transfer, latency: s.latency }],
                kernel: s.kernel,
            })
            .collect();
        let a = pipeline_time(&stages);
        let b = flow_pipeline_time(&jobs);
        assert!((a - b).abs() < 1e-15, "pipeline {a} != flow {b}");
    }

    #[test]
    fn flow_two_machines_overlap() {
        // 3 chunks x 2 machines, 1.0s each leg: machine 1 trails machine 0
        // by one leg -> makespan 4.0 instead of the serial 6.0
        let jobs: Vec<FlowJob> = (0..3)
            .map(|_| FlowJob {
                legs: vec![
                    Leg { machine: 0, transfer: Secs(1.0), latency: Secs(0.0) },
                    Leg { machine: 1, transfer: Secs(1.0), latency: Secs(0.0) },
                ],
                kernel: Secs(0.0),
            })
            .collect();
        assert!((flow_pipeline_time(&jobs) - Secs(4.0)).abs() < 1e-12);
    }

    #[test]
    fn flow_shared_machine_serializes() {
        // up and down legs share machine 0 (host RAM both ways): a chunk's
        // own legs cannot overlap each other, and the shared machine's
        // total load is a hard floor of the makespan
        let jobs: Vec<FlowJob> = (0..4)
            .map(|_| FlowJob {
                legs: vec![
                    Leg { machine: 0, transfer: Secs(1.0), latency: Secs(0.0) },
                    Leg { machine: 1, transfer: Secs(0.1), latency: Secs(0.0) },
                    Leg { machine: 0, transfer: Secs(1.0), latency: Secs(0.0) },
                ],
                kernel: Secs(0.0),
            })
            .collect();
        let t = flow_pipeline_time(&jobs);
        assert!(t >= 8.0 - 1e-12, "shared-machine load must serialize: {t}");
    }

    #[test]
    fn flow_never_beats_bottleneck_machine_or_exceeds_serial() {
        let jobs: Vec<FlowJob> = (0..6)
            .map(|i| FlowJob {
                legs: vec![
                    Leg { machine: MACHINE_INTRA_UP, transfer: Secs(0.2), latency: Secs(0.01) },
                    Leg { machine: MACHINE_HOST, transfer: Secs(0.5), latency: Secs(0.01) },
                    Leg { machine: MACHINE_INTER, transfer: Secs(0.3), latency: Secs(0.02) },
                    Leg { machine: MACHINE_HOST, transfer: Secs(0.5), latency: Secs(0.01) },
                    Leg { machine: MACHINE_INTRA_DOWN, transfer: Secs(0.2), latency: Secs(0.01) },
                ],
                kernel: Secs(0.05 * (i % 2) as f64),
            })
            .collect();
        let serial: Secs = jobs
            .iter()
            .map(|j| j.legs.iter().map(|l| l.transfer).sum::<Secs>() + j.kernel)
            .sum();
        let t = flow_pipeline_time(&jobs);
        // bottleneck: MACHINE_HOST carries 2 legs x 0.5 per job (latency
        // discounted after the first touch)
        let host_floor = 6.0 * 2.0 * 0.5 - 11.0 * 0.01;
        assert!(t >= host_floor - 1e-12, "{t} < host floor {host_floor}");
        assert!(t <= serial + Secs(1e-12), "{t} > serial {serial}");
        assert!(t < serial, "streams must overlap");
    }

    #[test]
    fn flow_latency_charged_once_per_machine() {
        let mk = |lat: f64| FlowJob {
            legs: vec![Leg { machine: 0, transfer: Secs(1.0 + lat), latency: Secs(lat) }],
            kernel: Secs(0.0),
        };
        let jobs = [mk(0.25), mk(0.25), mk(0.25)];
        // first chunk pays 1.25, later chunks 1.0
        assert!((flow_pipeline_time(&jobs) - Secs(3.25)).abs() < 1e-12);
    }

    #[test]
    fn wfbp_all_released_at_zero_matches_pipeline_time() {
        let stages = [
            PipelineStage { transfer: Secs(0.3), latency: Secs(0.01), kernel: Secs(0.2) },
            PipelineStage { transfer: Secs(0.5), latency: Secs(0.01), kernel: Secs(0.1) },
            PipelineStage { transfer: Secs(0.2), latency: Secs(0.01), kernel: Secs(0.4) },
        ];
        let jobs: Vec<TimedJob> = stages
            .iter()
            .map(|s| TimedJob {
                release: Secs(0.0),
                job: FlowJob {
                    legs: vec![Leg {
                        machine: MACHINE_WIRE,
                        transfer: s.transfer,
                        latency: s.latency,
                    }],
                    kernel: s.kernel,
                },
            })
            .collect();
        let a = pipeline_time(&stages);
        let b = wfbp_timeline(&jobs);
        assert!((a - b).abs() < 1e-15, "pipeline {a} != wfbp {b}");
    }

    #[test]
    fn wfbp_single_job_is_release_plus_serial() {
        let jobs = [TimedJob {
            release: Secs(2.0),
            job: FlowJob {
                legs: vec![Leg { machine: MACHINE_WIRE, transfer: Secs(0.7), latency: Secs(0.1) }],
                kernel: Secs(0.2),
            },
        }];
        assert!((wfbp_timeline(&jobs) - Secs(2.9)).abs() < 1e-12);
    }

    #[test]
    fn wfbp_release_gates_the_wire() {
        // bucket 0 released early, bucket 1 late: the wire drains and idles
        // until release 5.0, so the makespan is release-bound, not comm-bound
        let mk = |release: f64| TimedJob {
            release: Secs(release),
            job: FlowJob {
                legs: vec![Leg { machine: MACHINE_WIRE, transfer: Secs(1.0), latency: Secs(0.25) }],
                kernel: Secs(0.0),
            },
        };
        let t = wfbp_timeline(&[mk(0.0), mk(5.0)]);
        // the stalled stream restarts: the second bucket pays latency again
        assert!((t - Secs(6.0)).abs() < 1e-12, "{t}");
        // back-to-back releases keep the discount
        let t2 = wfbp_timeline(&[mk(0.0), mk(0.0)]);
        assert!((t2 - Secs(1.75)).abs() < 1e-12, "{t2}");
    }

    #[test]
    fn wfbp_busy_wire_queues_fifo() {
        // releases at 0.0 and 0.1 but each transfer takes 1.0: job 2 waits
        // for the wire, then streams back-to-back (latency discounted)
        let mk = |release: f64| TimedJob {
            release: Secs(release),
            job: FlowJob {
                legs: vec![Leg { machine: MACHINE_WIRE, transfer: Secs(1.0), latency: Secs(0.2) }],
                kernel: Secs(0.3),
            },
        };
        let t = wfbp_timeline(&[mk(0.0), mk(0.1)]);
        // wire: [0,1.0] then [1.0,1.8]; kernels: [1.0,1.3] then [1.8,2.1]
        assert!((t - Secs(2.1)).abs() < 1e-12, "{t}");
    }

    #[test]
    fn wfbp_never_beats_lower_bounds_or_exceeds_serial() {
        let jobs: Vec<TimedJob> = (0..5)
            .map(|i| TimedJob {
                release: Secs(0.2 * i as f64),
                job: FlowJob {
                    legs: vec![Leg {
                        machine: MACHINE_WIRE,
                        transfer: Secs(0.3 + 0.1 * (i % 2) as f64),
                        latency: Secs(0.02),
                    }],
                    kernel: Secs(0.05),
                },
            })
            .collect();
        let t = wfbp_timeline(&jobs);
        let wire: Secs = jobs.iter().map(|j| j.job.legs[0].transfer).sum();
        let comm: Secs = wire + jobs.iter().map(|j| j.job.kernel).sum::<Secs>();
        let last_release = jobs.last().unwrap().release;
        assert!(t.0 >= wire.0 - 4.0 * 0.02 - 1e-12, "wire load is a floor: {t}");
        assert!(t >= last_release, "cannot finish before the last release");
        // post-backward serial: everything after the last release
        let serial = last_release + comm;
        assert!(t <= serial + Secs(1e-12), "{t} > serial {serial}");
        assert!(t < serial, "early releases must overlap");
    }

    #[test]
    fn fdr_beats_qdr() {
        let params = p();
        let f = Topology::copper(2); // FDR
        let q = Topology::mosaic(2); // QDR
        let bytes = Bytes(100 << 20);
        let tf = phase_time(&f, &params, &[Transfer { src: 0, dst: 8, bytes }], true);
        let tq = phase_time(&q, &params, &[Transfer { src: 0, dst: 1, bytes }], true);
        assert!(tf < tq, "fdr={tf} qdr={tq}");
    }
}
