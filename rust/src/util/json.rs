//! Minimal JSON: parser + writer (manifest.json in, reports/traces out).
//!
//! Supports the full JSON value grammar; numbers are f64 (the manifest's
//! integers are well below 2^53). No serde in the offline container.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // --- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (wanted key '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    // --- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: impl Into<f64>) -> Json {
    Json::Num(n.into())
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs unsupported (manifest is ASCII)
                            out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        out.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_err());
    }

    #[test]
    fn serialization_is_byte_stable_regardless_of_insertion_order() {
        // Obj is a BTreeMap precisely so emitted reports/benchmark JSON are
        // byte-identical run to run; pin the bytes, not just the value
        let fwd = obj(vec![("zeta", num(1.0)), ("alpha", s("x")), ("mid", Json::Null)]);
        let rev = obj(vec![("mid", Json::Null), ("alpha", s("x")), ("zeta", num(1.0))]);
        let want = r#"{"alpha":"x","mid":null,"zeta":1}"#;
        assert_eq!(fwd.to_string(), want);
        assert_eq!(rev.to_string(), want);
        // and a parse -> serialize round trip normalizes source key order
        let parsed = Json::parse(r#"{"zeta": 1, "mid": null, "alpha": "x"}"#).unwrap();
        assert_eq!(parsed.to_string(), want);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo A");
    }
}
