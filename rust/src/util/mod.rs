//! Dependency-free utilities: deterministic PRNG, JSON, small helpers.
//!
//! The offline container only vendors the `xla` crate's dependency tree, so
//! the framework carries its own tiny substrate here instead of pulling
//! `rand`/`serde_json` (DESIGN.md §2, Cargo.toml note).

pub mod json;
pub mod rng;

pub use rng::Rng;

/// Round `x` up to a multiple of `m`.
pub fn ceil_to(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Split `n` items into `k` contiguous near-equal parts; returns (offset, len)
/// per part. The first `n % k` parts get one extra item (MPI_Scatterv style).
pub fn split_even(n: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k > 0);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut off = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((off, len));
        off += len;
    }
    debug_assert_eq!(off, n);
    out
}

/// Mean of a slice (0.0 for empty — callers use it for timing summaries).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// p-quantile (0..=1) by sorting a copy; nearest-rank.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * p).round() as usize;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even_covers_disjointly() {
        for n in [0usize, 1, 7, 64, 65, 1000] {
            for k in [1usize, 2, 3, 4, 8] {
                let parts = split_even(n, k);
                assert_eq!(parts.len(), k);
                let mut covered = 0;
                for (i, (off, len)) in parts.iter().enumerate() {
                    assert_eq!(*off, covered, "n={n} k={k} i={i}");
                    covered += len;
                }
                assert_eq!(covered, n);
                // sizes differ by at most 1
                let min = parts.iter().map(|p| p.1).min().unwrap();
                let max = parts.iter().map(|p| p.1).max().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn ceil_to_basics() {
        assert_eq!(ceil_to(0, 128), 0);
        assert_eq!(ceil_to(1, 128), 128);
        assert_eq!(ceil_to(128, 128), 128);
        assert_eq!(ceil_to(129, 128), 256);
    }

    #[test]
    fn quantile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }
}
