//! Deterministic xorshift64* PRNG + Gaussian sampling.
//!
//! Every stochastic component (synthetic data, shard shuffling, augmentation)
//! derives its stream from an explicit seed so whole experiments replay
//! bit-identically — the property the convergence reproductions rely on.

/// xorshift64* — tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point; mix the seed with splitmix64
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Rng { state: z | 1 }
    }

    /// Derive an independent stream (worker shards, loader children, ...).
    pub fn fork(&self, stream: u64) -> Rng {
        Rng::new(self.state ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits in [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn gauss(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mean = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn gauss_moments_reasonable() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn fork_streams_independent() {
        let base = Rng::new(3);
        let mut s1 = base.fork(1);
        let mut s2 = base.fork(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
