//! Model registry glue: runnable proxies ↔ full-scale paper architectures.
//!
//! The communication benchmarks (Fig. 3 / Table 3) exchange buffers at the
//! *true* parameter counts of Table 2 (from `manifest.full_scale`), while
//! convergence runs execute the reduced proxies. `PAPER_TRAIN_5120` carries
//! the paper's measured 1-GPU train times per 5,120 images (Table 3's
//! "Train(1GPU)" column) so the simulated speedup column reproduces the
//! paper's accounting; our own measured proxy step times are reported
//! alongside (EXPERIMENTS.md).

use anyhow::{anyhow, Result};

use crate::runtime::{Manifest, ModelInfo};

/// Table 3 constants: (model, per-worker batch) -> 1-GPU training time for
/// 5,120 images, seconds, as measured by the paper on the K20m/K80 testbed.
pub const PAPER_TRAIN_5120: &[(&str, usize, f64)] = &[
    ("alexnet", 128, 31.2),
    ("alexnet", 32, 36.40),
    ("googlenet", 32, 134.9),
    ("vggnet", 32, 405.2),
];

pub fn paper_train_5120(model: &str, batch: usize) -> Option<f64> {
    PAPER_TRAIN_5120
        .iter()
        .find(|(m, b, _)| *m == model && *b == batch)
        .map(|(_, _, t)| *t)
}

/// Which cluster the paper benchmarked each full-scale model on (§4):
/// AlexNet/GoogLeNet on 8 distributed mosaic nodes; VGG on one copper node
/// with 8 GPUs (its memory needs shared-memory locality).
pub fn paper_topology(model: &str) -> &'static str {
    match model {
        "vggnet" => "copper",
        _ => "mosaic",
    }
}

/// Bytes on the wire for one full parameter exchange of a full-scale model.
pub fn full_scale_bytes(manifest: &Manifest, model: &str) -> Result<u64> {
    manifest
        .full_scale
        .get(model)
        .map(|m| 4 * m.params as u64)
        .ok_or_else(|| anyhow!("unknown full-scale model '{model}'"))
}

/// Map a proxy model name to its full-scale counterpart for comm simulation.
pub fn full_scale_of(proxy: &str) -> Option<&'static str> {
    match proxy {
        "alexnet" => Some("alexnet"),
        "googlenet" => Some("googlenet"),
        "vgg" => Some("vggnet"),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Per-layer parameter tables — the wait-free backprop bucket boundaries.
//
// Mirrors python/compile/models/registry.py (the aot.py source of
// manifest.full_scale); kept in-tree too so the comm-only benches and the
// WFBP probes run without AOT artifacts. The sums are pinned to the paper's
// Table 2 counts by `builtin_tables_match_paper_counts`.

fn conv(
    name: &str,
    kh: usize,
    kw: usize,
    in_c: usize,
    out_c: usize,
    groups: usize,
) -> (String, usize) {
    (name.to_string(), kh * kw * (in_c / groups) * out_c + out_c)
}

fn fc(name: &str, n_in: usize, n_out: usize) -> (String, usize) {
    (name.to_string(), n_in * n_out + n_out)
}

fn alexnet_layers() -> Vec<(String, usize)> {
    vec![
        conv("conv1", 11, 11, 3, 96, 1),
        conv("conv2", 5, 5, 96, 256, 2),
        conv("conv3", 3, 3, 256, 384, 1),
        conv("conv4", 3, 3, 384, 384, 2),
        conv("conv5", 3, 3, 384, 256, 2),
        fc("fc6", 9216, 4096),
        fc("fc7", 4096, 4096),
        fc("fc8", 4096, 1000),
    ]
}

#[allow(clippy::too_many_arguments)]
fn inception(
    name: &str,
    in_c: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    cp: usize,
) -> Vec<(String, usize)> {
    vec![
        conv(&format!("{name}/1x1"), 1, 1, in_c, c1, 1),
        conv(&format!("{name}/3x3_reduce"), 1, 1, in_c, c3r, 1),
        conv(&format!("{name}/3x3"), 3, 3, c3r, c3, 1),
        conv(&format!("{name}/5x5_reduce"), 1, 1, in_c, c5r, 1),
        conv(&format!("{name}/5x5"), 5, 5, c5r, c5, 1),
        conv(&format!("{name}/pool_proj"), 1, 1, in_c, cp, 1),
    ]
}

fn aux(name: &str, in_c: usize) -> Vec<(String, usize)> {
    vec![
        conv(&format!("{name}/conv"), 1, 1, in_c, 128, 1),
        fc(&format!("{name}/fc"), 128 * 4 * 4, 1024),
        fc(&format!("{name}/classifier"), 1024, 1000),
    ]
}

fn googlenet_layers() -> Vec<(String, usize)> {
    let mut layers = vec![
        conv("conv1/7x7_s2", 7, 7, 3, 64, 1),
        conv("conv2/3x3_reduce", 1, 1, 64, 64, 1),
        conv("conv2/3x3", 3, 3, 64, 192, 1),
    ];
    layers.extend(inception("inception_3a", 192, 64, 96, 128, 16, 32, 32));
    layers.extend(inception("inception_3b", 256, 128, 128, 192, 32, 96, 64));
    layers.extend(inception("inception_4a", 480, 192, 96, 208, 16, 48, 64));
    layers.extend(aux("loss1", 512));
    layers.extend(inception("inception_4b", 512, 160, 112, 224, 24, 64, 64));
    layers.extend(inception("inception_4c", 512, 128, 128, 256, 24, 64, 64));
    layers.extend(inception("inception_4d", 512, 112, 144, 288, 32, 64, 64));
    layers.extend(aux("loss2", 528));
    layers.extend(inception("inception_4e", 528, 256, 160, 320, 32, 128, 128));
    layers.extend(inception("inception_5a", 832, 256, 160, 320, 32, 128, 128));
    layers.extend(inception("inception_5b", 832, 384, 192, 384, 48, 128, 128));
    layers.push(fc("loss3/classifier", 1024, 1000));
    layers
}

fn vggnet_layers() -> Vec<(String, usize)> {
    let cfg: [(usize, usize); 13] = [
        (3, 64), (64, 64),
        (64, 128), (128, 128),
        (128, 256), (256, 256), (256, 256),
        (256, 512), (512, 512), (512, 512),
        (512, 512), (512, 512), (512, 512),
    ];
    let mut layers: Vec<(String, usize)> = cfg
        .iter()
        .enumerate()
        .map(|(i, &(i_c, o_c))| conv(&format!("conv{}", i + 1), 3, 3, i_c, o_c, 1))
        .collect();
    layers.push(fc("fc6", 25088, 4096));
    layers.push(fc("fc7", 4096, 4096));
    layers.push(fc("fc8", 4096, 1000));
    layers
}

/// In-tree `(layer, params)` table of a full-scale paper architecture —
/// what the runtime-free comm benches use when no manifest is present.
pub fn builtin_full_scale_layers(name: &str) -> Option<Vec<(String, usize)>> {
    match name {
        "alexnet" => Some(alexnet_layers()),
        "googlenet" => Some(googlenet_layers()),
        "vggnet" => Some(vggnet_layers()),
        _ => None,
    }
}

/// `(layer, n_in, n_out)` dims of every fc layer of a full-scale paper
/// architecture — what the `sf` wire's sufficient-factor sizing
/// ([`crate::collectives::WfbpPlan::annotate_sf`]) needs: an fc gradient is
/// `Σ_b δ_b·x_bᵀ`, so its factors cost `batch·(n_in + n_out)` elements. The
/// names match [`builtin_full_scale_layers`] entry for entry (pinned by
/// `fc_dims_agree_with_layer_tables`).
pub fn builtin_fc_dims(name: &str) -> Option<Vec<(String, usize, usize)>> {
    let fc3 = |a: usize| {
        vec![
            ("fc6".to_string(), a, 4096),
            ("fc7".to_string(), 4096, 4096),
            ("fc8".to_string(), 4096, 1000),
        ]
    };
    match name {
        "alexnet" => Some(fc3(9216)),
        "vggnet" => Some(fc3(25088)),
        "googlenet" => Some(vec![
            ("loss1/fc".to_string(), 128 * 4 * 4, 1024),
            ("loss1/classifier".to_string(), 1024, 1000),
            ("loss2/fc".to_string(), 128 * 4 * 4, 1024),
            ("loss2/classifier".to_string(), 1024, 1000),
            ("loss3/classifier".to_string(), 1024, 1000),
        ]),
        _ => None,
    }
}

/// Per-layer `(name, params)` table of a full-scale model from the
/// manifest: the `layers` counts (falling back to `segments` counts —
/// they coincide in current manifests) named by the `segments` entries.
pub fn full_scale_layer_table(manifest: &Manifest, model: &str) -> Result<Vec<(String, usize)>> {
    let m = manifest
        .full_scale
        .get(model)
        .ok_or_else(|| anyhow!("unknown full-scale model '{model}'"))?;
    if m.layers.len() == m.segments.len() {
        Ok(m.segments
            .iter()
            .zip(&m.layers)
            .map(|((name, _), &p)| (name.clone(), p))
            .collect())
    } else {
        Ok(m.layers.iter().enumerate().map(|(i, &p)| (format!("layer{i}"), p)).collect())
    }
}

/// The documented proxy split for models without a per-layer breakdown:
/// `depth` near-equal layers (MPI_Scatterv-style remainder on the lowest
/// indices). Deliberately uniform — with no architecture information, a
/// uniform split neither invents fc-heaviness (which would overstate the
/// wait-free win) nor compute skew.
pub fn proxy_layer_split(params: usize, depth: usize) -> Vec<(String, usize)> {
    crate::util::split_even(params, depth.max(1))
        .into_iter()
        .enumerate()
        .map(|(i, (_, len))| (format!("layer{i}"), len))
        .collect()
}

/// Artifact names for a model at a per-worker batch size.
pub struct ModelArtifacts {
    pub train: String,
    pub grad: String,
    pub eval: String,
    pub sgd_apply: String,
}

pub fn artifacts_for(info: &ModelInfo, model: &str, batch: usize) -> Result<ModelArtifacts> {
    let key = info.key_for_batch(batch)?;
    Ok(ModelArtifacts {
        train: format!("{key}_train"),
        grad: format!("{key}_grad"),
        // eval is only built at the default batch's key
        eval: format!("{model}_eval"),
        sgd_apply: info.sgd_apply.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants_present() {
        assert_eq!(paper_train_5120("alexnet", 128), Some(31.2));
        assert_eq!(paper_train_5120("vggnet", 32), Some(405.2));
        assert_eq!(paper_train_5120("alexnet", 64), None);
    }

    #[test]
    fn topology_assignment_matches_paper() {
        assert_eq!(paper_topology("vggnet"), "copper");
        assert_eq!(paper_topology("alexnet"), "mosaic");
        assert_eq!(paper_topology("googlenet"), "mosaic");
    }

    #[test]
    fn full_scale_mapping() {
        assert_eq!(full_scale_of("vgg"), Some("vggnet"));
        assert_eq!(full_scale_of("mlp"), None);
    }

    #[test]
    fn builtin_tables_match_paper_counts() {
        // Table 2, exactly — and therefore python/compile/models/registry.py
        for (name, want) in
            [("alexnet", 60_965_224usize), ("googlenet", 13_378_280), ("vggnet", 138_357_544)]
        {
            let t = builtin_full_scale_layers(name).unwrap();
            let sum: usize = t.iter().map(|(_, p)| p).sum();
            assert_eq!(sum, want, "{name}");
        }
        assert!(builtin_full_scale_layers("lenet").is_none());
        // AlexNet's famous skew: fc6-8 hold ~96% of the parameters
        let alex = builtin_full_scale_layers("alexnet").unwrap();
        let fc: usize = alex
            .iter()
            .filter(|(n, _)| crate::collectives::wfbp::is_fc_layer(n))
            .map(|(_, p)| p)
            .sum();
        assert!(fc as f64 / 60_965_224.0 > 0.95, "fc share {fc}");
        assert_eq!(alex.len(), 8);
        // 3 stem convs + 9 inceptions x 6 + 2 aux heads x 3 + classifier
        assert_eq!(builtin_full_scale_layers("googlenet").unwrap().len(), 64);
        assert_eq!(builtin_full_scale_layers("vggnet").unwrap().len(), 16);
    }

    #[test]
    fn fc_dims_agree_with_layer_tables() {
        for model in ["alexnet", "googlenet", "vggnet"] {
            let layers = builtin_full_scale_layers(model).unwrap();
            let dims = builtin_fc_dims(model).unwrap();
            // every dims entry names an fc layer whose param count is
            // exactly n_in*n_out + n_out
            for (name, n_in, n_out) in &dims {
                assert!(crate::collectives::wfbp::is_fc_layer(name), "{model}/{name}");
                let (_, p) = layers
                    .iter()
                    .find(|(ln, _)| ln == name)
                    .unwrap_or_else(|| panic!("{model}/{name} not in layer table"));
                assert_eq!(*p, n_in * n_out + n_out, "{model}/{name}");
            }
            // and every fc layer in the table has a dims entry
            for (name, _) in layers.iter().filter(|(n, _)| {
                crate::collectives::wfbp::is_fc_layer(n)
            }) {
                assert!(
                    dims.iter().any(|(dn, _, _)| dn == name),
                    "{model}/{name} missing from builtin_fc_dims"
                );
            }
        }
        assert!(builtin_fc_dims("lenet").is_none());
    }

    #[test]
    fn proxy_split_is_uniform_and_covers() {
        let t = proxy_layer_split(1003, 8);
        assert_eq!(t.len(), 8);
        let sum: usize = t.iter().map(|(_, p)| p).sum();
        assert_eq!(sum, 1003);
        let min = t.iter().map(|(_, p)| *p).min().unwrap();
        let max = t.iter().map(|(_, p)| *p).max().unwrap();
        assert!(max - min <= 1);
        assert_eq!(proxy_layer_split(5, 0).len(), 1, "depth 0 clamps to 1");
    }
}
