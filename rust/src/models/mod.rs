//! Model registry glue: runnable proxies ↔ full-scale paper architectures.
//!
//! The communication benchmarks (Fig. 3 / Table 3) exchange buffers at the
//! *true* parameter counts of Table 2 (from `manifest.full_scale`), while
//! convergence runs execute the reduced proxies. `PAPER_TRAIN_5120` carries
//! the paper's measured 1-GPU train times per 5,120 images (Table 3's
//! "Train(1GPU)" column) so the simulated speedup column reproduces the
//! paper's accounting; our own measured proxy step times are reported
//! alongside (EXPERIMENTS.md).

use anyhow::{anyhow, Result};

use crate::runtime::{Manifest, ModelInfo};

/// Table 3 constants: (model, per-worker batch) -> 1-GPU training time for
/// 5,120 images, seconds, as measured by the paper on the K20m/K80 testbed.
pub const PAPER_TRAIN_5120: &[(&str, usize, f64)] = &[
    ("alexnet", 128, 31.2),
    ("alexnet", 32, 36.40),
    ("googlenet", 32, 134.9),
    ("vggnet", 32, 405.2),
];

pub fn paper_train_5120(model: &str, batch: usize) -> Option<f64> {
    PAPER_TRAIN_5120
        .iter()
        .find(|(m, b, _)| *m == model && *b == batch)
        .map(|(_, _, t)| *t)
}

/// Which cluster the paper benchmarked each full-scale model on (§4):
/// AlexNet/GoogLeNet on 8 distributed mosaic nodes; VGG on one copper node
/// with 8 GPUs (its memory needs shared-memory locality).
pub fn paper_topology(model: &str) -> &'static str {
    match model {
        "vggnet" => "copper",
        _ => "mosaic",
    }
}

/// Bytes on the wire for one full parameter exchange of a full-scale model.
pub fn full_scale_bytes(manifest: &Manifest, model: &str) -> Result<u64> {
    manifest
        .full_scale
        .get(model)
        .map(|m| 4 * m.params as u64)
        .ok_or_else(|| anyhow!("unknown full-scale model '{model}'"))
}

/// Map a proxy model name to its full-scale counterpart for comm simulation.
pub fn full_scale_of(proxy: &str) -> Option<&'static str> {
    match proxy {
        "alexnet" => Some("alexnet"),
        "googlenet" => Some("googlenet"),
        "vgg" => Some("vggnet"),
        _ => None,
    }
}

/// Artifact names for a model at a per-worker batch size.
pub struct ModelArtifacts {
    pub train: String,
    pub grad: String,
    pub eval: String,
    pub sgd_apply: String,
}

pub fn artifacts_for(info: &ModelInfo, model: &str, batch: usize) -> Result<ModelArtifacts> {
    let key = info.key_for_batch(batch)?;
    Ok(ModelArtifacts {
        train: format!("{key}_train"),
        grad: format!("{key}_grad"),
        // eval is only built at the default batch's key
        eval: format!("{model}_eval"),
        sgd_apply: info.sgd_apply.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants_present() {
        assert_eq!(paper_train_5120("alexnet", 128), Some(31.2));
        assert_eq!(paper_train_5120("vggnet", 32), Some(405.2));
        assert_eq!(paper_train_5120("alexnet", 64), None);
    }

    #[test]
    fn topology_assignment_matches_paper() {
        assert_eq!(paper_topology("vggnet"), "copper");
        assert_eq!(paper_topology("alexnet"), "mosaic");
        assert_eq!(paper_topology("googlenet"), "mosaic");
    }

    #[test]
    fn full_scale_mapping() {
        assert_eq!(full_scale_of("vgg"), Some("vggnet"));
        assert_eq!(full_scale_of("mlp"), None);
    }
}
