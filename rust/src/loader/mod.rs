//! Parallel data loading — the paper's Algorithm 1 (§3.3), production shape.
//!
//! Each training worker spawns a **loader child** (the paper uses
//! `MPI_Spawn` + an intra-communicator; here a thread + channel pair, same
//! protocol). The child loads a batch file from disk, subtracts the mean
//! image, crops and mirrors according to the mode, "transfers" to the GPU
//! (a real HostTensor build + a simulated H2D charge), then waits for the
//! next filename — so steps 9–13 of Alg. 1 overlap with the training
//! process's fwd/bwd on earlier batches.
//!
//! The seed's hardcoded double buffer generalizes to a **prefetch depth Q**
//! ([`LoaderConfig::prefetch_depth`]): the worker keeps Q requests in
//! flight, so slack from cheap batches absorbs decode spikes that a 1-deep
//! pipeline would stall on. A [`DecodeCache`] (raw file bytes, LRU) lets
//! repeat epochs skip disk entirely; it caches *stored* bytes, never
//! decoded tensors, because train-mode crop/mirror is randomized per visit
//! and caching outputs would freeze the augmentation.
//!
//! The worker-side handle measures its own blocked time on `ready()` — the
//! *load stall*, i.e. the part of loading the overlap failed to hide — and
//! summarizes the run in a [`LoaderReport`]. The `direct` mode (no child,
//! synchronous load) is the ablation baseline. The [`sim`] submodule is the
//! runtime-free DES twin of this pipeline, priced through `audit::Ledger`
//! and mirrored line-for-line by `scripts/pricing_model.py`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::{crop, ImageSpec};
use crate::runtime::HostTensor;
use crate::simnet::LinkParams;
use crate::units::{Bytes, Secs};
use crate::util::Rng;

/// Pipeline knobs (CLI: `--prefetch-depth`, `--cache-mib`).
#[derive(Clone, Copy, Debug)]
pub struct LoaderConfig {
    /// Number of in-flight batch requests the worker keeps queued at the
    /// child. 1 ≡ the seed's double buffer (request i+1 issued right after
    /// collecting batch i, before computing on it). Must be ≥ 1.
    pub prefetch_depth: usize,
    /// Decode-cache capacity in MiB; 0 disables the cache.
    pub cache_mib: usize,
}

impl Default for LoaderConfig {
    fn default() -> Self {
        LoaderConfig { prefetch_depth: 2, cache_mib: 0 }
    }
}

/// Shared hit/miss/evict counters — the child owns the cache, the worker
/// handle snapshots these for the [`LoaderReport`].
#[derive(Debug, Default)]
pub struct CacheCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    resident_bytes: AtomicU64,
    capacity_bytes: AtomicU64,
}

impl CacheCounters {
    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            capacity_bytes: self.capacity_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time cache metrics (all-zero when the cache is disabled).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub resident_bytes: u64,
    pub capacity_bytes: u64,
}

impl CacheStats {
    /// hits / (hits + misses); 0 when the cache never fielded a fetch.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// LRU cache of **raw stored batch files** keyed by path. Hits skip disk
/// I/O; decode/augment always reruns (see module docs for why outputs are
/// never cached). Files larger than the whole capacity bypass the cache.
pub struct DecodeCache {
    capacity: u64,
    resident: u64,
    map: HashMap<PathBuf, Vec<u8>>,
    /// LRU order, front = oldest.
    order: Vec<PathBuf>,
    counters: Arc<CacheCounters>,
}

impl DecodeCache {
    pub fn new(cache_mib: usize) -> DecodeCache {
        DecodeCache::with_capacity_bytes((cache_mib as u64) << 20)
    }

    /// Byte-granular capacity (tests; `new` is the MiB-knob front end).
    pub fn with_capacity_bytes(capacity: u64) -> DecodeCache {
        let counters = Arc::new(CacheCounters::default());
        counters.capacity_bytes.store(capacity, Ordering::Relaxed);
        DecodeCache { capacity, resident: 0, map: HashMap::new(), order: Vec::new(), counters }
    }

    /// Clone of the shared counter block — grab before moving the cache
    /// into a loader child.
    pub fn counters(&self) -> Arc<CacheCounters> {
        Arc::clone(&self.counters)
    }

    pub fn stats(&self) -> CacheStats {
        self.counters.snapshot()
    }

    /// Fetch the raw bytes of `file`, from cache or disk. Returns
    /// `(bytes, hit)`.
    pub fn fetch(&mut self, file: &Path) -> Result<(Vec<u8>, bool)> {
        if let Some(bytes) = self.map.get(file) {
            let out = bytes.clone();
            if let Some(pos) = self.order.iter().position(|p| p == file) {
                let p = self.order.remove(pos);
                self.order.push(p);
            }
            self.counters.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((out, true));
        }
        let bytes = std::fs::read(file).map_err(|e| anyhow!("read {file:?}: {e}"))?;
        self.counters.misses.fetch_add(1, Ordering::Relaxed);
        let len = bytes.len() as u64;
        if len <= self.capacity {
            while self.resident + len > self.capacity {
                let oldest = self.order.remove(0);
                if let Some(old) = self.map.remove(&oldest) {
                    self.resident -= old.len() as u64;
                }
                self.counters.evictions.fetch_add(1, Ordering::Relaxed);
            }
            self.map.insert(file.to_path_buf(), bytes.clone());
            self.order.push(file.to_path_buf());
            self.resident += len;
            self.counters.resident_bytes.store(self.resident, Ordering::Relaxed);
        }
        Ok((bytes, false))
    }
}

/// Worker -> loader messages (Alg. 1's `recv`).
enum Ctl {
    /// mode: "train" (random crop + mirror) or "val" (center crop)
    Mode(String),
    /// next filename to prefetch
    File(PathBuf),
    Stop,
}

/// One preprocessed batch, ready for the train artifact.
pub struct LoadedBatch {
    pub x: HostTensor,
    /// real seconds the child spent on disk + preprocess + tensor build
    pub load_time: Secs,
    /// simulated H2D time (PCIe) for the preprocessed bytes
    pub h2d_sim: Secs,
    /// whether the raw file bytes came from the decode cache
    pub cache_hit: bool,
}

/// End-of-run pipeline summary (surfaced as `BspReport::loader`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoaderReport {
    /// successfully delivered batches (child `Err`s are not counted)
    pub batches_loaded: usize,
    /// real seconds the worker spent blocked in `ready()` on successes
    pub stall_time: Secs,
    /// total child-side load seconds across successful batches
    pub load_time: Secs,
    /// total simulated H2D seconds across successful batches
    pub h2d_sim: Secs,
    /// 0 = direct (synchronous) path, ≥ 1 = parallel child
    pub prefetch_depth: usize,
    pub cache: CacheStats,
}

/// Worker-side handle to its loader child.
pub struct ParallelLoader {
    tx: Sender<Ctl>,
    rx: Receiver<Result<LoadedBatch>>,
    handle: Option<JoinHandle<()>>,
    /// accumulated time the worker spent blocked waiting on the child
    /// (successful deliveries only)
    pub stall_time: Secs,
    pub batches_loaded: usize,
    /// total child-side load seconds (successful deliveries only)
    pub load_time: Secs,
    /// total simulated H2D seconds (successful deliveries only)
    pub h2d_sim: Secs,
    prefetch_depth: usize,
    cache_counters: Option<Arc<CacheCounters>>,
}

impl ParallelLoader {
    /// Spawn the child (Alg. 1 start) with the shard's static context.
    pub fn spawn(
        spec: ImageSpec,
        mean: Vec<f32>,
        batch: usize,
        links: LinkParams,
        seed: u64,
        cfg: LoaderConfig,
    ) -> ParallelLoader {
        let (tx, crx) = channel::<Ctl>();
        let (ctx_, rx) = channel::<Result<LoadedBatch>>();
        let (cache, cache_counters) = if cfg.cache_mib > 0 {
            let c = DecodeCache::new(cfg.cache_mib);
            let counters = c.counters();
            (Some(c), Some(counters))
        } else {
            (None, None)
        };
        let handle = std::thread::Builder::new()
            .name("loader-child".into())
            .spawn(move || child_main(spec, mean, batch, links, seed, cache, crx, ctx_))
            .expect("spawn loader child");
        ParallelLoader {
            tx,
            rx,
            handle: Some(handle),
            stall_time: Secs::ZERO,
            batches_loaded: 0,
            load_time: Secs::ZERO,
            h2d_sim: Secs::ZERO,
            prefetch_depth: cfg.prefetch_depth.max(1),
            cache_counters,
        }
    }

    /// Set the mode (Alg. 1 step 2/6).
    pub fn set_mode(&self, mode: &str) {
        let _ = self.tx.send(Ctl::Mode(mode.to_string()));
    }

    /// Send the next filename to prefetch (Alg. 1 step 7/13-17).
    pub fn request(&self, file: PathBuf) {
        let _ = self.tx.send(Ctl::File(file));
    }

    /// Block until the oldest in-flight batch is resident ("notify training
    /// process to proceed", Alg. 1 step 20). Measures the stall. Only
    /// successful deliveries count toward `batches_loaded`/`stall_time` —
    /// an `Err` from the child is the caller's problem, not pipeline work.
    pub fn ready(&mut self) -> Result<LoadedBatch> {
        let t0 = Instant::now();
        let out = self.rx.recv().map_err(|_| anyhow!("loader child died"))?;
        if let Ok(b) = &out {
            self.stall_time += Secs(t0.elapsed().as_secs_f64());
            self.batches_loaded += 1;
            self.load_time += b.load_time;
            self.h2d_sim += b.h2d_sim;
        }
        out
    }

    /// Pipeline summary for reporting (see [`LoaderReport`]).
    pub fn report(&self) -> LoaderReport {
        LoaderReport {
            batches_loaded: self.batches_loaded,
            stall_time: self.stall_time,
            load_time: self.load_time,
            h2d_sim: self.h2d_sim,
            prefetch_depth: self.prefetch_depth,
            cache: self.cache_counters.as_ref().map(|c| c.snapshot()).unwrap_or_default(),
        }
    }

    pub fn stop(&mut self) {
        let _ = self.tx.send(Ctl::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ParallelLoader {
    fn drop(&mut self) {
        self.stop();
    }
}

#[allow(clippy::too_many_arguments)]
fn child_main(
    spec: ImageSpec,
    mean: Vec<f32>,
    batch: usize,
    links: LinkParams,
    seed: u64,
    mut cache: Option<DecodeCache>,
    rx: Receiver<Ctl>,
    tx: Sender<Result<LoadedBatch>>,
) {
    let mut mode = "train".to_string();
    let mut rng = Rng::new(seed ^ 0x10AD);
    while let Ok(ctl) = rx.recv() {
        let file = match ctl {
            Ctl::Stop => break,
            Ctl::Mode(m) => {
                mode = m;
                continue;
            }
            Ctl::File(f) => f,
        };
        let out = load_one(&spec, &mean, batch, &links, &mut rng, &mode, &file, cache.as_mut());
        if tx.send(out).is_err() {
            break;
        }
    }
}

/// Alg. 1 steps 9–12 for one batch file (also used by the direct loader).
#[allow(clippy::too_many_arguments)]
pub fn load_one(
    spec: &ImageSpec,
    mean: &[f32],
    batch: usize,
    links: &LinkParams,
    rng: &mut Rng,
    mode: &str,
    file: &PathBuf,
    cache: Option<&mut DecodeCache>,
) -> Result<LoadedBatch> {
    let t0 = Instant::now();
    // step 9: load file from disk (or the decode cache) into host memory
    let (bytes, cache_hit) = match cache {
        Some(c) => c.fetch(file)?,
        None => (std::fs::read(file).map_err(|e| anyhow!("read {file:?}: {e}"))?, false),
    };
    let px = spec.channels * spec.store_hw * spec.store_hw;
    if bytes.len() != batch * px {
        return Err(anyhow!(
            "{file:?}: expected {} bytes ({batch}x{px}), got {}",
            batch * px,
            bytes.len()
        ));
    }
    // steps 10-11: mean subtract + crop/mirror per mode
    let margin = spec.store_hw - spec.crop_hw;
    let mut xs = Vec::with_capacity(batch * spec.channels * spec.crop_hw * spec.crop_hw);
    for b in 0..batch {
        let img = &bytes[b * px..(b + 1) * px];
        let (ox, oy, mirror) = if mode == "train" {
            (rng.below(margin + 1), rng.below(margin + 1), rng.next_f64() < 0.5)
        } else {
            (margin / 2, margin / 2, false)
        };
        xs.extend(crop(img, mean, spec, ox, oy, mirror));
    }
    // step 12: host -> device transfer (simulated PCIe charge; the tensor
    // build is the real representational work)
    let h2d_bytes = Bytes(4 * xs.len() as u64);
    let h2d_sim = links.pcie_time(h2d_bytes);
    let x = HostTensor::f32(vec![batch, spec.channels, spec.crop_hw, spec.crop_hw], xs);
    Ok(LoadedBatch { x, load_time: Secs(t0.elapsed().as_secs_f64()), h2d_sim, cache_hit })
}

/// Runtime-free DES twin of the pipeline: one symmetric worker + its loader
/// child, priced through [`audit::Ledger`](crate::audit::Ledger) /
/// [`ServerClock`](crate::audit::ServerClock) so `breakdown == clock` holds
/// by construction. `scripts/pricing_model.py::sim_loader_pipeline` mirrors
/// this function float-op for float-op; `bench_loader` sweeps it and
/// `tests/loader_pipeline.rs` pins its bands against the Python port.
pub mod sim {
    use super::CacheStats;
    use crate::audit::{ChargeKind, Ledger, ServerClock};
    use crate::metrics::Breakdown;
    use crate::simnet::LinkParams;
    use crate::units::{Bytes, Secs};

    /// Disk + decode cost model for the simulated child.
    #[derive(Clone, Copy, Debug)]
    pub struct DiskParams {
        /// aggregate disk bandwidth, shared by all k workers' children
        pub disk_gbps: f64,
        /// per-file seek/open latency
        pub disk_lat_us: f64,
        /// decode/augment throughput per child
        pub decode_gbps: f64,
        /// every Nth batch decodes `spike_factor` slower (jpeg-outlier
        /// stand-in) — the non-uniformity that makes prefetch depth matter
        pub spike_every: usize,
        pub spike_factor: f64,
    }

    impl Default for DiskParams {
        fn default() -> Self {
            DiskParams {
                disk_gbps: 1.0,
                disk_lat_us: 100.0,
                decode_gbps: 0.5,
                spike_every: 8,
                spike_factor: 8.0,
            }
        }
    }

    /// One sweep point of the pipeline DES.
    #[derive(Clone, Copy, Debug)]
    pub struct SimPipelineCfg {
        /// k — scales the per-child share of `disk_gbps`
        pub workers: usize,
        /// 0 = direct (synchronous) path; ≥ 1 = parallel child with Q
        /// requests in flight
        pub prefetch_depth: usize,
        pub cache_mib: usize,
        /// distinct batch files in the shard (epoch length; iteration i
        /// reads file i mod n_files)
        pub n_files: usize,
        pub iters: usize,
        /// stored bytes per batch file (disk + decode work)
        pub batch_bytes: u64,
        /// bytes staged to the device per batch (post-crop f32)
        pub h2d_bytes: u64,
        /// fwd+bwd seconds per iteration on the worker
        pub compute_s: f64,
    }

    /// DES result: final virtual clock + its exact decomposition.
    #[derive(Clone, Copy, Debug)]
    pub struct SimOutcome {
        pub vtime: Secs,
        pub bd: Breakdown,
        pub cache: CacheStats,
    }

    /// LRU over the cyclic file sequence `i mod n_files`, uniform file
    /// size — the closed-form twin of [`super::DecodeCache`]. Returns the
    /// per-iteration hit flags plus final counters.
    fn sim_cache(cfg: &SimPipelineCfg) -> (Vec<bool>, CacheStats) {
        let cap = (cfg.cache_mib as u64) << 20;
        let mut order: Vec<usize> = Vec::new();
        let mut resident: u64 = 0;
        let mut st = CacheStats { capacity_bytes: cap, ..CacheStats::default() };
        let mut hits = Vec::with_capacity(cfg.iters);
        for i in 0..cfg.iters {
            let f = i % cfg.n_files;
            if let Some(pos) = order.iter().position(|&x| x == f) {
                order.remove(pos);
                order.push(f);
                st.hits += 1;
                hits.push(true);
            } else {
                st.misses += 1;
                hits.push(false);
                if cfg.batch_bytes <= cap {
                    while resident + cfg.batch_bytes > cap {
                        order.remove(0);
                        resident -= cfg.batch_bytes;
                        st.evictions += 1;
                    }
                    order.push(f);
                    resident += cfg.batch_bytes;
                }
            }
        }
        st.resident_bytes = resident;
        (hits, st)
    }

    /// Disk + decode seconds for request `i` (hit ⇒ disk is free; decode
    /// always runs — the cache stores raw bytes, not outputs).
    fn child_cost(cfg: &SimPipelineCfg, disk: &DiskParams, i: usize, hit: bool) -> f64 {
        let disk_s = if hit {
            0.0
        } else {
            disk.disk_lat_us * 1e-6
                + cfg.batch_bytes as f64 / ((disk.disk_gbps / cfg.workers as f64) * 1e9)
        };
        let spike = if (i + 1) % disk.spike_every == 0 { disk.spike_factor } else { 1.0 };
        let decode_s = cfg.batch_bytes as f64 / (disk.decode_gbps * 1e9) * spike;
        disk_s + decode_s
    }

    /// Run the DES at one sweep point. Parallel path: prime Q requests at
    /// t=0; after collecting batch i, request i+Q goes to the child *before*
    /// computing on i (Q=1 ≡ the seed's double buffer). Direct path
    /// (`prefetch_depth == 0`): the worker pays the full child cost on its
    /// own clock as `LoadStall`.
    pub fn sim_pipeline(cfg: &SimPipelineCfg, disk: &DiskParams, links: &LinkParams) -> SimOutcome {
        let (hits, cache) = sim_cache(cfg);
        let h2d_s = links.pcie_time(Bytes(cfg.h2d_bytes));
        let mut led = Ledger::new();
        if cfg.prefetch_depth == 0 {
            for i in 0..cfg.iters {
                let cost = Secs(child_cost(cfg, disk, i, hits[i]));
                led.charge(ChargeKind::LoadStall, "loader.sim.direct", cost);
                led.charge(ChargeKind::H2d, "loader.sim.h2d", h2d_s);
                led.charge(ChargeKind::Compute, "loader.sim.compute", Secs(cfg.compute_s));
            }
        } else {
            let q = cfg.prefetch_depth;
            let mut child = ServerClock::new();
            let mut finish = vec![Secs::ZERO; cfg.iters];
            for j in 0..q.min(cfg.iters) {
                finish[j] = child.serve(Secs::ZERO, Secs(child_cost(cfg, disk, j, hits[j])));
            }
            for i in 0..cfg.iters {
                let cost_i = Secs(child_cost(cfg, disk, i, hits[i]));
                let stall = (finish[i] - led.clock()).max(0.0);
                led.advance_to(ChargeKind::LoadStall, "loader.sim.stall", led.clock() + stall);
                // the rest of the child's work hid under earlier compute
                led.charge_hidden_load("loader.sim.hidden", (cost_i - stall).max(0.0), cost_i);
                led.charge(ChargeKind::H2d, "loader.sim.h2d", h2d_s);
                let nxt = i + q;
                if nxt < cfg.iters {
                    let cost_n = Secs(child_cost(cfg, disk, nxt, hits[nxt]));
                    finish[nxt] = child.serve(led.clock(), cost_n);
                }
                led.charge(ChargeKind::Compute, "loader.sim.compute", Secs(cfg.compute_s));
            }
            child.audit().expect("loader sim child clock");
        }
        led.audit().expect("loader sim ledger");
        let (vtime, bd) = led.finish();
        SimOutcome { vtime, bd, cache }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ImageDataset, ImageSpec};

    /// `tag` must be unique per test: tests run in parallel and each one
    /// removes its own shard dir at the end.
    fn setup(tag: &str, n_batches: usize) -> (crate::data::ShardFiles, ImageSpec) {
        let spec = ImageSpec::default();
        let d = ImageDataset::new(spec.clone());
        let tmp = std::env::temp_dir().join(format!(
            "tmpi_loader_test_{tag}_{}_{n_batches}",
            std::process::id()
        ));
        let sf = d.write_shard(&tmp, 0, 1, 8, n_batches).unwrap();
        (sf, spec)
    }

    #[test]
    fn loads_and_preprocesses_batches_in_order() {
        let (sf, spec) = setup("order", 3);
        let mut loader = ParallelLoader::spawn(
            spec,
            sf.mean.clone(),
            sf.batch,
            LinkParams::default(),
            1,
            LoaderConfig::default(),
        );
        loader.set_mode("train");
        for f in &sf.files {
            loader.request(f.clone());
        }
        for _ in 0..3 {
            let b = loader.ready().unwrap();
            assert_eq!(b.x.shape, vec![8, 3, 32, 32]);
            assert!(b.load_time > 0.0);
            assert!(b.h2d_sim > 0.0);
            let xs = b.x.as_f32().unwrap();
            assert!(xs.iter().all(|v| v.is_finite()));
        }
        let rep = loader.report();
        assert_eq!(rep.batches_loaded, 3);
        assert!(rep.load_time > 0.0 && rep.h2d_sim > 0.0);
        assert_eq!(rep.cache, CacheStats::default(), "cache disabled by default");
        loader.stop();
        let _ = std::fs::remove_dir_all(sf.files[0].parent().unwrap());
    }

    #[test]
    fn val_mode_is_deterministic_train_mode_augments() {
        let (sf, spec) = setup("valmode", 1);
        let links = LinkParams::default();
        let f = &sf.files[0];
        let mut rng = Rng::new(9);
        let v1 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "val", f, None).unwrap();
        let v2 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "val", f, None).unwrap();
        assert_eq!(v1.x.as_f32().unwrap(), v2.x.as_f32().unwrap());
        let t1 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "train", f, None).unwrap();
        let t2 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "train", f, None).unwrap();
        assert_ne!(t1.x.as_f32().unwrap(), t2.x.as_f32().unwrap());
        let _ = std::fs::remove_dir_all(f.parent().unwrap());
    }

    #[test]
    fn missing_file_reports_error_not_panic() {
        let spec = ImageSpec::default();
        let mut loader = ParallelLoader::spawn(
            spec.clone(),
            vec![0.0; spec.channels * spec.store_hw * spec.store_hw],
            4,
            LinkParams::default(),
            2,
            LoaderConfig::default(),
        );
        loader.request(PathBuf::from("/nonexistent/batch.bin"));
        let err = match loader.ready() {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected load error"),
        };
        assert!(err.contains("read"), "{err}");
        // the failed delivery is not pipeline work (ISSUE 7 satellite):
        assert_eq!(loader.batches_loaded, 0);
        assert_eq!(loader.stall_time, 0.0);
        loader.stop();
    }

    #[test]
    fn double_buffering_overlaps() {
        // request two files up-front; while the worker "trains" (sleeps),
        // the child prefetches, so the second ready() stall is near zero.
        let (sf, spec) = setup("dbuf", 2);
        let mut loader = ParallelLoader::spawn(
            spec,
            sf.mean.clone(),
            sf.batch,
            LinkParams::default(),
            3,
            LoaderConfig::default(),
        );
        loader.request(sf.files[0].clone());
        let _first = loader.ready().unwrap();
        loader.request(sf.files[1].clone());
        std::thread::sleep(std::time::Duration::from_millis(60)); // "training"
        let stall_before = loader.stall_time;
        let _second = loader.ready().unwrap();
        let second_stall = loader.stall_time - stall_before;
        assert!(
            second_stall < 0.03,
            "prefetch failed to hide load: stall={second_stall}s"
        );
        loader.stop();
        let _ = std::fs::remove_dir_all(sf.files[0].parent().unwrap());
    }

    #[test]
    fn decode_cache_lru_hits_misses_evictions() {
        let spec = ImageSpec::default();
        let d = ImageDataset::new(spec.clone());
        let tmp =
            std::env::temp_dir().join(format!("tmpi_cache_test_{}", std::process::id()));
        let sf = d.write_shard(&tmp, 0, 1, 2, 3).unwrap();
        let file_len = std::fs::metadata(&sf.files[0]).unwrap().len();
        assert!(2 * file_len <= 1 << 20, "test assumes 2 files fit in 1 MiB");
        let mut cache = DecodeCache::new(1);
        // first pass misses, second pass hits
        for f in sf.files.iter().take(2) {
            let (_, hit) = cache.fetch(f).unwrap();
            assert!(!hit);
        }
        for f in sf.files.iter().take(2) {
            let (_, hit) = cache.fetch(f).unwrap();
            assert!(hit);
        }
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (2, 2, 0));
        assert_eq!(st.resident_bytes, 2 * file_len);
        assert!(st.hit_rate() > 0.49 && st.hit_rate() < 0.51);
        // bytes from the cache match disk exactly
        let (cached, hit) = cache.fetch(&sf.files[0]).unwrap();
        assert!(hit);
        assert_eq!(cached, std::fs::read(&sf.files[0]).unwrap());
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn cache_evicts_lru_not_mru() {
        let spec = ImageSpec::default();
        let d = ImageDataset::new(spec.clone());
        let tmp =
            std::env::temp_dir().join(format!("tmpi_cache_lru_test_{}", std::process::id()));
        let sf = d.write_shard(&tmp, 0, 1, 2, 3).unwrap();
        let file_len = std::fs::metadata(&sf.files[0]).unwrap().len();
        let mut cache = DecodeCache::with_capacity_bytes(2 * file_len);
        let (f0, f1, f2) = (&sf.files[0], &sf.files[1], &sf.files[2]);
        assert!(!cache.fetch(f0).unwrap().1);
        assert!(!cache.fetch(f1).unwrap().1);
        // touch f0: it becomes MRU, so f1 is now the eviction candidate
        assert!(cache.fetch(f0).unwrap().1);
        assert!(!cache.fetch(f2).unwrap().1); // evicts f1, not f0
        assert!(cache.fetch(f0).unwrap().1, "f0 was MRU — must survive");
        assert!(!cache.fetch(f1).unwrap().1, "f1 was LRU — must be gone");
        let st = cache.stats();
        assert_eq!(st.evictions, 2); // f1 for f2's entry, then f2 for f1's re-entry
        assert_eq!(st.resident_bytes, 2 * file_len);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn loader_with_cache_reports_hits() {
        let (sf, spec) = setup("cachehits", 2);
        let mut loader = ParallelLoader::spawn(
            spec,
            sf.mean.clone(),
            sf.batch,
            LinkParams::default(),
            5,
            LoaderConfig { prefetch_depth: 1, cache_mib: 8 },
        );
        // two epochs over the same two files
        for f in sf.files.iter().chain(sf.files.iter()) {
            loader.request(f.clone());
        }
        let mut hits = 0;
        for _ in 0..4 {
            let b = loader.ready().unwrap();
            if b.cache_hit {
                hits += 1;
            }
        }
        assert_eq!(hits, 2, "second epoch must hit the cache");
        let rep = loader.report();
        assert_eq!((rep.cache.hits, rep.cache.misses), (2, 2));
        assert_eq!(rep.batches_loaded, 4);
        loader.stop();
        let _ = std::fs::remove_dir_all(sf.files[0].parent().unwrap());
    }
}
