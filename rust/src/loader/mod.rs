//! Parallel data loading — the paper's Algorithm 1 (§3.3).
//!
//! Each training worker spawns a **loader child** (the paper uses
//! `MPI_Spawn` + an intra-communicator; here a thread + channel pair, same
//! protocol). The child loads a batch file from disk, subtracts the mean
//! image, crops and mirrors according to the mode, "transfers" to the GPU
//! (a real HostTensor build + a simulated H2D charge), then waits for the
//! next filename before flipping the double buffer — so steps 9–13 of
//! Alg. 1 overlap with the training process's fwd/bwd on the previous
//! batch.
//!
//! The worker-side handle measures its own blocked time on `ready()` — the
//! *load stall*, i.e. the part of loading the overlap failed to hide. The
//! `direct` mode (no child, synchronous load) is the ablation baseline.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::{crop, ImageSpec};
use crate::runtime::HostTensor;
use crate::simnet::LinkParams;
use crate::util::Rng;

/// Worker -> loader messages (Alg. 1's `recv`).
enum Ctl {
    /// mode: "train" (random crop + mirror) or "val" (center crop)
    Mode(String),
    /// next filename to prefetch
    File(PathBuf),
    Stop,
}

/// One preprocessed batch, ready for the train artifact.
pub struct LoadedBatch {
    pub x: HostTensor,
    /// real seconds the child spent on disk + preprocess + tensor build
    pub load_time: f64,
    /// simulated H2D time (PCIe) for the preprocessed bytes
    pub h2d_sim: f64,
}

/// Worker-side handle to its loader child.
pub struct ParallelLoader {
    tx: Sender<Ctl>,
    rx: Receiver<Result<LoadedBatch>>,
    handle: Option<JoinHandle<()>>,
    /// accumulated time the worker spent blocked waiting on the child
    pub stall_time: f64,
    pub batches_loaded: usize,
}

impl ParallelLoader {
    /// Spawn the child (Alg. 1 start) with the shard's static context.
    pub fn spawn(spec: ImageSpec, mean: Vec<f32>, batch: usize, links: LinkParams, seed: u64) -> ParallelLoader {
        let (tx, crx) = channel::<Ctl>();
        let (ctx_, rx) = channel::<Result<LoadedBatch>>();
        let handle = std::thread::Builder::new()
            .name("loader-child".into())
            .spawn(move || child_main(spec, mean, batch, links, seed, crx, ctx_))
            .expect("spawn loader child");
        ParallelLoader { tx, rx, handle: Some(handle), stall_time: 0.0, batches_loaded: 0 }
    }

    /// Set the mode (Alg. 1 step 2/6).
    pub fn set_mode(&self, mode: &str) {
        let _ = self.tx.send(Ctl::Mode(mode.to_string()));
    }

    /// Send the next filename to prefetch (Alg. 1 step 7/13-17).
    pub fn request(&self, file: PathBuf) {
        let _ = self.tx.send(Ctl::File(file));
    }

    /// Block until the previously-requested batch is resident ("notify
    /// training process to proceed", Alg. 1 step 20). Measures the stall.
    pub fn ready(&mut self) -> Result<LoadedBatch> {
        let t0 = Instant::now();
        let out = self.rx.recv().map_err(|_| anyhow!("loader child died"))?;
        self.stall_time += t0.elapsed().as_secs_f64();
        self.batches_loaded += 1;
        out
    }

    pub fn stop(&mut self) {
        let _ = self.tx.send(Ctl::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ParallelLoader {
    fn drop(&mut self) {
        self.stop();
    }
}

fn child_main(
    spec: ImageSpec,
    mean: Vec<f32>,
    batch: usize,
    links: LinkParams,
    seed: u64,
    rx: Receiver<Ctl>,
    tx: Sender<Result<LoadedBatch>>,
) {
    let mut mode = "train".to_string();
    let mut rng = Rng::new(seed ^ 0x10AD);
    while let Ok(ctl) = rx.recv() {
        let file = match ctl {
            Ctl::Stop => break,
            Ctl::Mode(m) => {
                mode = m;
                continue;
            }
            Ctl::File(f) => f,
        };
        let out = load_one(&spec, &mean, batch, &links, &mut rng, &mode, &file);
        if tx.send(out).is_err() {
            break;
        }
    }
}

/// Alg. 1 steps 9–12 for one batch file (also used by the direct loader).
pub fn load_one(
    spec: &ImageSpec,
    mean: &[f32],
    batch: usize,
    links: &LinkParams,
    rng: &mut Rng,
    mode: &str,
    file: &PathBuf,
) -> Result<LoadedBatch> {
    let t0 = Instant::now();
    // step 9: load file from disk into host memory
    let bytes = std::fs::read(file).map_err(|e| anyhow!("read {file:?}: {e}"))?;
    let px = spec.channels * spec.store_hw * spec.store_hw;
    if bytes.len() != batch * px {
        return Err(anyhow!(
            "{file:?}: expected {} bytes ({batch}x{px}), got {}",
            batch * px,
            bytes.len()
        ));
    }
    // steps 10-11: mean subtract + crop/mirror per mode
    let margin = spec.store_hw - spec.crop_hw;
    let mut xs = Vec::with_capacity(batch * spec.channels * spec.crop_hw * spec.crop_hw);
    for b in 0..batch {
        let img = &bytes[b * px..(b + 1) * px];
        let (ox, oy, mirror) = if mode == "train" {
            (rng.below(margin + 1), rng.below(margin + 1), rng.next_f64() < 0.5)
        } else {
            (margin / 2, margin / 2, false)
        };
        xs.extend(crop(img, mean, spec, ox, oy, mirror));
    }
    // step 12: host -> device transfer (simulated PCIe charge; the tensor
    // build is the real representational work)
    let h2d_bytes = 4 * xs.len() as u64;
    let h2d_sim = links.pcie_time(h2d_bytes);
    let x = HostTensor::f32(
        vec![batch, spec.channels, spec.crop_hw, spec.crop_hw],
        xs,
    );
    Ok(LoadedBatch { x, load_time: t0.elapsed().as_secs_f64(), h2d_sim })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ImageDataset, ImageSpec};

    fn setup(n_batches: usize) -> (crate::data::ShardFiles, ImageSpec) {
        let spec = ImageSpec::default();
        let d = ImageDataset::new(spec.clone());
        let tmp = std::env::temp_dir().join(format!(
            "tmpi_loader_test_{}_{n_batches}",
            std::process::id()
        ));
        let sf = d.write_shard(&tmp, 0, 1, 8, n_batches).unwrap();
        (sf, spec)
    }

    #[test]
    fn loads_and_preprocesses_batches_in_order() {
        let (sf, spec) = setup(3);
        let mut loader =
            ParallelLoader::spawn(spec, sf.mean.clone(), sf.batch, LinkParams::default(), 1);
        loader.set_mode("train");
        for f in &sf.files {
            loader.request(f.clone());
        }
        for _ in 0..3 {
            let b = loader.ready().unwrap();
            assert_eq!(b.x.shape, vec![8, 3, 32, 32]);
            assert!(b.load_time > 0.0);
            assert!(b.h2d_sim > 0.0);
            let xs = b.x.as_f32().unwrap();
            assert!(xs.iter().all(|v| v.is_finite()));
        }
        loader.stop();
        let _ = std::fs::remove_dir_all(sf.files[0].parent().unwrap());
    }

    #[test]
    fn val_mode_is_deterministic_train_mode_augments() {
        let (sf, spec) = setup(1);
        let links = LinkParams::default();
        let f = &sf.files[0];
        let mut rng = Rng::new(9);
        let v1 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "val", f).unwrap();
        let v2 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "val", f).unwrap();
        assert_eq!(v1.x.as_f32().unwrap(), v2.x.as_f32().unwrap());
        let t1 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "train", f).unwrap();
        let t2 = load_one(&spec, &sf.mean, 8, &links, &mut rng, "train", f).unwrap();
        assert_ne!(t1.x.as_f32().unwrap(), t2.x.as_f32().unwrap());
        let _ = std::fs::remove_dir_all(f.parent().unwrap());
    }

    #[test]
    fn missing_file_reports_error_not_panic() {
        let spec = ImageSpec::default();
        let mut loader = ParallelLoader::spawn(
            spec.clone(),
            vec![0.0; spec.channels * spec.store_hw * spec.store_hw],
            4,
            LinkParams::default(),
            2,
        );
        loader.request(PathBuf::from("/nonexistent/batch.bin"));
        let err = match loader.ready() {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected load error"),
        };
        assert!(err.contains("read"), "{err}");
        loader.stop();
    }

    #[test]
    fn double_buffering_overlaps() {
        // request two files up-front; while the worker "trains" (sleeps),
        // the child prefetches, so the second ready() stall is near zero.
        let (sf, spec) = setup(2);
        let mut loader =
            ParallelLoader::spawn(spec, sf.mean.clone(), sf.batch, LinkParams::default(), 3);
        loader.request(sf.files[0].clone());
        let _first = loader.ready().unwrap();
        loader.request(sf.files[1].clone());
        std::thread::sleep(std::time::Duration::from_millis(60)); // "training"
        let stall_before = loader.stall_time;
        let _second = loader.ready().unwrap();
        let second_stall = loader.stall_time - stall_before;
        assert!(
            second_stall < 0.03,
            "prefetch failed to hide load: stall={second_stall}s"
        );
        loader.stop();
        let _ = std::fs::remove_dir_all(sf.files[0].parent().unwrap());
    }
}
