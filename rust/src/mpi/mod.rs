//! In-process message-passing layer — the MPI substitute (DESIGN.md §2).
//!
//! The paper drives one GPU per MPI process; here each "process" is an OS
//! thread holding a [`Comm`] endpoint. The layer reproduces the MPI surface
//! the framework uses — ranked point-to-point `send`/`recv` with tags,
//! `sendrecv` (the EASGD exchange), and a clock-reconciling `barrier` (the
//! BSP superstep boundary) — over std channels, with out-of-order tag
//! buffering like a real MPI matching engine.
//!
//! Buffers really move (the payloads are the actual parameter vectors);
//! only *time* is simulated: every message carries the sender's virtual
//! clock, and receivers reconcile via `max(local, sent + wire_time)` where
//! wire time comes from `simnet`.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

/// Message payloads: real data, not placeholders.
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    U16(Vec<u16>),
    I32(Vec<i32>),
    Ctl(String),
    Empty,
}

impl Payload {
    pub fn bytes(&self) -> u64 {
        match self {
            Payload::F32(v) => 4 * v.len() as u64,
            Payload::U16(v) => 2 * v.len() as u64,
            Payload::I32(v) => 4 * v.len() as u64,
            Payload::Ctl(s) => s.len() as u64,
            Payload::Empty => 0,
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Payload::F32(v) => Ok(v),
            other => Err(anyhow!("expected F32 payload, got {other:?}")),
        }
    }

    pub fn into_u16(self) -> Result<Vec<u16>> {
        match self {
            Payload::U16(v) => Ok(v),
            other => Err(anyhow!("expected U16 payload, got {other:?}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Msg {
    pub from: usize,
    pub tag: u64,
    pub payload: Payload,
    /// Sender's virtual clock at send time (seconds).
    pub sent_clock: f64,
}

/// Generation-counted barrier that also reconciles virtual clocks: every
/// participant contributes its clock and all leave with the maximum — the
/// BSP superstep semantics (stragglers gate the step).
struct ClockBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    size: usize,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
    max_clock: f64,
    /// max clock of the generation that just completed
    released_clock: f64,
}

impl ClockBarrier {
    fn new(size: usize) -> Self {
        ClockBarrier {
            state: Mutex::new(BarrierState {
                arrived: 0,
                generation: 0,
                max_clock: 0.0,
                released_clock: 0.0,
            }),
            cv: Condvar::new(),
            size,
        }
    }

    fn wait(&self, clock: f64) -> f64 {
        let mut st = self.state.lock().unwrap();
        st.max_clock = st.max_clock.max(clock);
        st.arrived += 1;
        if st.arrived == self.size {
            st.arrived = 0;
            st.released_clock = st.max_clock;
            st.max_clock = 0.0;
            st.generation += 1;
            self.cv.notify_all();
            return st.released_clock;
        }
        let gen = st.generation;
        while st.generation == gen {
            st = self.cv.wait(st).unwrap();
        }
        st.released_clock
    }
}

/// One rank's endpoint into the world.
pub struct Comm {
    pub rank: usize,
    pub size: usize,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order buffer: messages received while waiting for another
    /// (from, tag) match — MPI's unexpected-message queue. Keys are global
    /// ranks (group views translate before matching). A `BTreeMap` so
    /// `recv_any*` scans queues in (from, tag) order — wildcard receives
    /// must not depend on hash iteration order.
    pending: BTreeMap<(usize, u64), VecDeque<Msg>>,
    barrier: Arc<ClockBarrier>,
    /// Active subgroup view: `group[local] = global` ([`Comm::push_group`]).
    group: Option<Vec<usize>>,
}

/// Saved communicator state returned by [`Comm::push_group`]; hand it back
/// to [`Comm::pop_group`] to leave the subgroup.
pub struct GroupFrame {
    rank: usize,
    size: usize,
    group: Option<Vec<usize>>,
}

/// Create a fully-connected world of `size` ranks.
pub fn world(size: usize) -> Vec<Comm> {
    assert!(size > 0);
    let mut txs = Vec::with_capacity(size);
    let mut rxs = Vec::with_capacity(size);
    for _ in 0..size {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
    }
    let barrier = Arc::new(ClockBarrier::new(size));
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Comm {
            rank,
            size,
            senders: txs.clone(),
            rx,
            pending: BTreeMap::new(),
            barrier: barrier.clone(),
            group: None,
        })
        .collect()
}

impl Comm {
    /// Global rank of a (possibly group-local) rank id.
    fn to_global(&self, r: usize) -> usize {
        self.group.as_ref().map_or(r, |m| m[r])
    }

    /// Restrict this endpoint to the subgroup `ranks` (global ids; their
    /// order defines the group-local ranks — MPI_Comm_split in spirit).
    /// While active, `rank`/`size` and every rank argument to
    /// send/recv/sendrecv are group-local, so an unmodified collective runs
    /// across the subgroup — this is how `hier` drives its inner strategy
    /// over node leaders only. Messages still carry global ids on the wire,
    /// so un-grouped peers interoperate. Restore with [`pop_group`]
    /// (always, even on error — a stale view corrupts later matching).
    ///
    /// [`pop_group`]: Self::pop_group
    pub fn push_group(&mut self, ranks: &[usize]) -> Result<GroupFrame> {
        let global = self.to_global(self.rank);
        let local = ranks
            .iter()
            .position(|&r| r == global)
            .ok_or_else(|| anyhow!("rank {global} is not a member of group {ranks:?}"))?;
        let frame = GroupFrame { rank: self.rank, size: self.size, group: self.group.take() };
        self.rank = local;
        self.size = ranks.len();
        self.group = Some(ranks.to_vec());
        Ok(frame)
    }

    /// Leave the subgroup entered by the matching [`push_group`](Self::push_group).
    pub fn pop_group(&mut self, frame: GroupFrame) {
        self.rank = frame.rank;
        self.size = frame.size;
        self.group = frame.group;
    }

    /// Non-blocking ranked send (MPI_Isend-like; channels buffer).
    pub fn send(&self, to: usize, tag: u64, payload: Payload, clock: f64) -> Result<()> {
        let to = self.to_global(to);
        self.senders[to]
            .send(Msg { from: self.to_global(self.rank), tag, payload, sent_clock: clock })
            .map_err(|_| anyhow!("rank {to} hung up"))
    }

    /// Blocking matched receive: returns the first message from `from` with
    /// `tag`, buffering non-matching arrivals. `Msg::from` is always the
    /// sender's global rank, even under a group view.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Msg> {
        let from = self.to_global(from);
        if let Some(q) = self.pending.get_mut(&(from, tag)) {
            if let Some(m) = q.pop_front() {
                return Ok(m);
            }
        }
        loop {
            let m = self.rx.recv().map_err(|_| anyhow!("world torn down"))?;
            if m.from == from && m.tag == tag {
                return Ok(m);
            }
            self.pending.entry((m.from, m.tag)).or_default().push_back(m);
        }
    }

    /// Receive from any rank with `tag` (MPI_ANY_SOURCE) — the EASGD server
    /// loop uses this to serve whichever worker arrives first.
    pub fn recv_any(&mut self, tag: u64) -> Result<Msg> {
        for ((_, t), q) in self.pending.iter_mut() {
            if *t == tag {
                if let Some(m) = q.pop_front() {
                    return Ok(m);
                }
            }
        }
        loop {
            let m = self.rx.recv().map_err(|_| anyhow!("world torn down"))?;
            if m.tag == tag {
                return Ok(m);
            }
            self.pending.entry((m.from, m.tag)).or_default().push_back(m);
        }
    }

    /// Receive the next message whose tag is in `tag_set`, from any rank —
    /// the EASGD server multiplexes pushes and stop-controls this way.
    pub fn recv_any_of(&mut self, tag_set: &[u64]) -> Result<Msg> {
        for ((_, t), q) in self.pending.iter_mut() {
            if tag_set.contains(t) {
                if let Some(m) = q.pop_front() {
                    return Ok(m);
                }
            }
        }
        loop {
            let m = self.rx.recv().map_err(|_| anyhow!("world torn down"))?;
            if tag_set.contains(&m.tag) {
                return Ok(m);
            }
            self.pending.entry((m.from, m.tag)).or_default().push_back(m);
        }
    }

    /// MPI_Sendrecv: simultaneous exchange with one peer.
    pub fn sendrecv(
        &mut self,
        peer: usize,
        tag: u64,
        payload: Payload,
        clock: f64,
    ) -> Result<Msg> {
        self.send(peer, tag, payload, clock)?;
        self.recv(peer, tag)
    }

    /// BSP barrier; returns the reconciled (max) virtual clock.
    pub fn barrier(&self, clock: f64) -> f64 {
        self.barrier.wait(clock)
    }
}

/// Tag namespaces (keep p2p traffic of different subsystems disjoint).
pub mod tags {
    pub const EXCHANGE: u64 = 0x10;
    pub const ALLGATHER: u64 = 0x11;
    pub const REDUCE: u64 = 0x12;
    pub const EASGD_PUSH: u64 = 0x20;
    pub const EASGD_PULL: u64 = 0x21;
    pub const CTL: u64 = 0x30;
    /// Hier up-tree: +0 switch level, +1 socket level.
    pub const HIER_UP: u64 = 0x40;
    /// Hier down-tree: +0 socket level, +1 switch level.
    pub const HIER_DOWN: u64 = 0x48;
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn p2p_roundtrip() {
        let mut w = world(2);
        let mut c1 = w.pop().unwrap();
        let mut c0 = w.pop().unwrap();
        let t = thread::spawn(move || {
            let m = c1.recv(0, 7).unwrap();
            assert_eq!(m.payload.bytes(), 12);
            c1.send(0, 8, Payload::Ctl("done".into()), 1.0).unwrap();
        });
        c0.send(1, 7, Payload::F32(vec![1.0, 2.0, 3.0]), 0.5).unwrap();
        let m = c0.recv(1, 8).unwrap();
        assert_eq!(m.sent_clock, 1.0);
        t.join().unwrap();
    }

    #[test]
    fn out_of_order_tags_buffered() {
        let mut w = world(2);
        let mut c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        c0.send(1, 2, Payload::Ctl("second".into()), 0.0).unwrap();
        c0.send(1, 1, Payload::Ctl("first".into()), 0.0).unwrap();
        // ask for tag 1 first even though tag 2 arrived first
        let m1 = c1.recv(0, 1).unwrap();
        let m2 = c1.recv(0, 2).unwrap();
        match (m1.payload, m2.payload) {
            (Payload::Ctl(a), Payload::Ctl(b)) => {
                assert_eq!(a, "first");
                assert_eq!(b, "second");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn barrier_reconciles_clocks() {
        let w = world(4);
        let hs: Vec<_> = w
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                thread::spawn(move || {
                    let released = c.barrier(i as f64);
                    assert_eq!(released, 3.0);
                    // second generation gets fresh max
                    let released = c.barrier(10.0 + i as f64);
                    assert_eq!(released, 13.0);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn fifo_per_sender_same_tag() {
        let mut w = world(2);
        let mut c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        for i in 0..10 {
            c0.send(1, 5, Payload::I32(vec![i]), 0.0).unwrap();
        }
        for i in 0..10 {
            match c1.recv(0, 5).unwrap().payload {
                Payload::I32(v) => assert_eq!(v[0], i),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn group_view_translates_ranks_both_ways() {
        // world of 4; ranks 1 and 3 form a subgroup and talk by local id
        let mut w = world(4);
        let c3 = w.pop().unwrap();
        let _c2 = w.pop().unwrap();
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        let t3 = thread::spawn(move || {
            let mut c3 = c3;
            let frame = c3.push_group(&[1, 3]).unwrap();
            assert_eq!((c3.rank, c3.size), (1, 2));
            // local rank 0 is global rank 1
            let m = c3.recv(0, 7).unwrap();
            assert_eq!(m.from, 1, "Msg::from stays global");
            c3.send(0, 8, Payload::Ctl("ok".into()), 0.0).unwrap();
            c3.pop_group(frame);
            assert_eq!((c3.rank, c3.size), (3, 4));
        });
        let t1 = thread::spawn(move || {
            let mut c1 = c1;
            let frame = c1.push_group(&[1, 3]).unwrap();
            assert_eq!((c1.rank, c1.size), (0, 2));
            c1.send(1, 7, Payload::F32(vec![1.0]), 0.0).unwrap();
            let m = c1.recv(1, 8).unwrap();
            assert_eq!(m.from, 3);
            c1.pop_group(frame);
        });
        t1.join().unwrap();
        t3.join().unwrap();
        // rank 0 was never in the group; its endpoint is unaffected
        assert_eq!((c0.rank, c0.size), (0, 4));
        assert!(c0.group.is_none());
    }

    #[test]
    fn push_group_rejects_non_members() {
        let mut w = world(3);
        let mut c2 = w.pop().unwrap();
        let err = c2.push_group(&[0, 1]).unwrap_err().to_string();
        assert!(err.contains("rank 2"), "{err}");
        assert_eq!((c2.rank, c2.size), (2, 3), "failed push must not mutate");
    }

    #[test]
    fn grouped_and_ungrouped_traffic_interleaves() {
        // a grouped endpoint still receives (buffers) world traffic sent
        // with global ids, and can read it after popping the view
        let mut w = world(3);
        let mut c2 = w.pop().unwrap();
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        c0.send(2, 99, Payload::Ctl("world".into()), 0.0).unwrap();
        let frame = c2.push_group(&[1, 2]).unwrap();
        c1.send(2, 5, Payload::Ctl("hi".into()), 0.0).unwrap(); // ungrouped: global ids
        let m = c2.recv(0, 5).unwrap(); // group-local 0 == global 1
        assert_eq!(m.from, 1);
        c2.pop_group(frame);
        let m = c2.recv(0, 99).unwrap();
        assert_eq!(m.from, 0);
    }

    #[test]
    fn multi_peer_slice_roundtrip_with_out_of_order_replies() {
        // the sharded EASGD pattern: a worker pushes slices to two shard
        // servers and collects replies in shard order, even when the
        // replies arrive in reversed real order (server 1 only replies
        // after server 2 signals it already did)
        let mut w = world(3);
        let mut s2 = w.pop().unwrap();
        let mut s1 = w.pop().unwrap();
        let mut c0 = w.pop().unwrap();
        let t1 = thread::spawn(move || {
            let m = s1.recv(0, tags::EASGD_PUSH).unwrap();
            let _ = s1.recv(2, tags::CTL).unwrap();
            s1.send(0, tags::EASGD_PULL, m.payload, 1.0).unwrap();
        });
        let t2 = thread::spawn(move || {
            let m = s2.recv(0, tags::EASGD_PUSH).unwrap();
            s2.send(0, tags::EASGD_PULL, m.payload, 2.0).unwrap();
            s2.send(1, tags::CTL, Payload::Ctl("sent".into()), 0.0).unwrap();
        });
        c0.send(1, tags::EASGD_PUSH, Payload::F32(vec![1.0, 2.0]), 0.0).unwrap();
        c0.send(2, tags::EASGD_PUSH, Payload::F32(vec![3.0]), 0.0).unwrap();
        let m1 = c0.recv(1, tags::EASGD_PULL).unwrap(); // buffers server 2's reply
        let m2 = c0.recv(2, tags::EASGD_PULL).unwrap();
        assert_eq!(m1.payload.bytes(), 8);
        assert_eq!(m2.payload.bytes(), 4);
        assert_eq!((m1.sent_clock, m2.sent_clock), (1.0, 2.0));
        t1.join().unwrap();
        t2.join().unwrap();
    }

    #[test]
    fn recv_any_serves_all_ranks() {
        let mut w = world(3);
        let mut server = w.remove(0);
        let hs: Vec<_> = w
            .into_iter()
            .map(|c| {
                thread::spawn(move || {
                    c.send(0, tags::EASGD_PUSH, Payload::F32(vec![c.rank as f32]), 0.0).unwrap();
                })
            })
            .collect();
        let mut seen = vec![];
        for _ in 0..2 {
            let m = server.recv_any(tags::EASGD_PUSH).unwrap();
            seen.push(m.from);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![1, 2]);
        for h in hs {
            h.join().unwrap();
        }
    }
}
