//! Cluster topology — the paper's Fig. 6 hardware, as data.
//!
//! *copper*: PI-contributed SHARCNET cluster; each node is a dual-socket
//! system with two NVIDIA Tesla K80s per socket (a K80 is a dual-GPU card,
//! so 4 GPU dies per socket / 8 per node), one PCIe switch per socket, QPI
//! between sockets, Infiniband FDR between nodes.
//!
//! *mosaic*: one K20m GPU per node, Infiniband QDR between nodes.
//!
//! Routing rules (paper §6): GPUDirect P2P works only under a single PCIe
//! switch; any path crossing the QPI goes through CPU RAM; multi-node
//! transfers had no GPUDirect RDMA on the testbed, so they stage through
//! host memory on both ends.

/// Where a GPU sits in the fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GpuInfo {
    pub node: usize,
    pub socket: usize,
    /// Globally-unique PCIe switch id (GPUDirect P2P domain).
    pub switch: usize,
}

/// What kind of path connects two GPUs (drives the simnet cost model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathKind {
    /// Same GPU (no transfer).
    Local,
    /// Same PCIe switch: GPUDirect P2P eligible.
    P2p,
    /// Same node, different socket: must traverse QPI via CPU RAM.
    QpiStaged,
    /// Different nodes: Infiniband, host-staged on both ends (no GPUDirect
    /// RDMA on the paper's testbed).
    Network,
}

#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub gpus: Vec<GpuInfo>,
    pub n_nodes: usize,
    /// Interconnect generation between nodes (FDR on copper, QDR on mosaic);
    /// simnet maps this to bandwidth.
    pub ib: IbGen,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IbGen {
    Fdr,
    Qdr,
}

impl Topology {
    /// copper: `nodes` nodes × 8 GPUs (2 sockets × 2 K80 cards × 2 dies).
    /// One PCIe switch per socket — Fig. 6.
    pub fn copper(nodes: usize) -> Topology {
        let mut gpus = Vec::new();
        for n in 0..nodes {
            for socket in 0..2 {
                for _die in 0..4 {
                    gpus.push(GpuInfo { node: n, socket, switch: n * 2 + socket });
                }
            }
        }
        Topology { name: format!("copper-{nodes}n"), gpus, n_nodes: nodes, ib: IbGen::Fdr }
    }

    /// mosaic: `nodes` nodes × 1 K20m GPU.
    pub fn mosaic(nodes: usize) -> Topology {
        let gpus = (0..nodes)
            .map(|n| GpuInfo { node: n, socket: 0, switch: n * 2 })
            .collect();
        Topology { name: format!("mosaic-{nodes}n"), gpus, n_nodes: nodes, ib: IbGen::Qdr }
    }

    /// First `k` GPUs of a preset — how experiments place k workers.
    pub fn by_name(name: &str, workers: usize) -> Option<Topology> {
        let t = match name {
            // one worker per node, like the paper's multi-node benchmarks
            "mosaic" => Topology::mosaic(workers.max(1)),
            // fill a single copper node first (the VGG single-node setup),
            // then more nodes
            "copper" => Topology::copper(workers.max(1).div_ceil(8)),
            _ => return None,
        };
        Some(t)
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn path(&self, a: usize, b: usize) -> PathKind {
        if a == b {
            return PathKind::Local;
        }
        let (ga, gb) = (self.gpus[a], self.gpus[b]);
        if ga.node != gb.node {
            PathKind::Network
        } else if ga.switch == gb.switch {
            PathKind::P2p
        } else {
            PathKind::QpiStaged
        }
    }

    /// ASCII rendering of the layout (the `tmpi topo` command → Fig. 6).
    pub fn render(&self) -> String {
        let mut out = format!("topology {} ({} GPUs, IB {:?})\n", self.name, self.n_gpus(), self.ib);
        for n in 0..self.n_nodes {
            out.push_str(&format!("node {n}\n"));
            let mut sockets: Vec<usize> =
                self.gpus.iter().filter(|g| g.node == n).map(|g| g.socket).collect();
            sockets.sort_unstable();
            sockets.dedup();
            for s in sockets {
                let ids: Vec<String> = self
                    .gpus
                    .iter()
                    .enumerate()
                    .filter(|(_, g)| g.node == n && g.socket == s)
                    .map(|(i, _)| format!("gpu{i}"))
                    .collect();
                out.push_str(&format!("  socket {s} (CPU)--PCIe switch--[{}]\n", ids.join(" ")));
            }
            if self.n_nodes > 1 {
                out.push_str("  |-- IB NIC\n");
            }
        }
        if self.gpus.iter().any(|g| g.node == 0 && g.socket == 1) {
            out.push_str("(sockets joined by QPI; GPUDirect P2P only within a switch)\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copper_shape_matches_fig6() {
        let t = Topology::copper(2);
        assert_eq!(t.n_gpus(), 16);
        // 4 dies per socket
        for n in 0..2 {
            for s in 0..2 {
                let count = t.gpus.iter().filter(|g| g.node == n && g.socket == s).count();
                assert_eq!(count, 4);
            }
        }
    }

    #[test]
    fn mosaic_one_gpu_per_node() {
        let t = Topology::mosaic(8);
        assert_eq!(t.n_gpus(), 8);
        assert_eq!(t.n_nodes, 8);
        assert_eq!(t.ib, IbGen::Qdr);
    }

    #[test]
    fn path_classification() {
        let t = Topology::copper(2);
        assert_eq!(t.path(0, 0), PathKind::Local);
        assert_eq!(t.path(0, 1), PathKind::P2p); // same socket switch
        assert_eq!(t.path(0, 4), PathKind::QpiStaged); // cross-socket
        assert_eq!(t.path(0, 8), PathKind::Network); // cross-node
        let m = Topology::mosaic(4);
        assert_eq!(m.path(1, 2), PathKind::Network);
    }

    #[test]
    fn path_is_symmetric() {
        let t = Topology::copper(2);
        for a in 0..t.n_gpus() {
            for b in 0..t.n_gpus() {
                assert_eq!(t.path(a, b), t.path(b, a));
            }
        }
    }

    #[test]
    fn render_contains_every_gpu() {
        let t = Topology::copper(1);
        let r = t.render();
        for i in 0..8 {
            assert!(r.contains(&format!("gpu{i}")), "{r}");
        }
    }
}
