//! Cluster topology — the paper's Fig. 6 hardware, as data.
//!
//! *copper*: PI-contributed SHARCNET cluster; each node is a dual-socket
//! system with two NVIDIA Tesla K80s per socket (a K80 is a dual-GPU card,
//! so 4 GPU dies per socket / 8 per node), one PCIe switch per socket, QPI
//! between sockets, Infiniband FDR between nodes.
//!
//! *mosaic*: one K20m GPU per node, Infiniband QDR between nodes.
//!
//! Routing rules (paper §6): GPUDirect P2P works only under a single PCIe
//! switch; any path crossing the QPI goes through CPU RAM; multi-node
//! transfers had no GPUDirect RDMA on the testbed, so they stage through
//! host memory on both ends.

use std::collections::BTreeMap;

/// Where a GPU sits in the fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GpuInfo {
    pub node: usize,
    pub socket: usize,
    /// Globally-unique PCIe switch id (GPUDirect P2P domain).
    pub switch: usize,
}

/// What kind of path connects two GPUs (drives the simnet cost model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathKind {
    /// Same GPU (no transfer).
    Local,
    /// Same PCIe switch: GPUDirect P2P eligible.
    P2p,
    /// Same node, different socket: must traverse QPI via CPU RAM.
    QpiStaged,
    /// Different nodes: Infiniband, host-staged on both ends (no GPUDirect
    /// RDMA on the paper's testbed).
    Network,
}

#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub gpus: Vec<GpuInfo>,
    pub n_nodes: usize,
    /// Interconnect generation between nodes (FDR on copper, QDR on mosaic);
    /// simnet maps this to bandwidth.
    pub ib: IbGen,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IbGen {
    Fdr,
    Qdr,
}

impl Topology {
    /// copper: `nodes` nodes × 8 GPUs (2 sockets × 2 K80 cards × 2 dies).
    /// One PCIe switch per socket — Fig. 6.
    pub fn copper(nodes: usize) -> Topology {
        let mut gpus = Vec::new();
        for n in 0..nodes {
            for socket in 0..2 {
                for _die in 0..4 {
                    gpus.push(GpuInfo { node: n, socket, switch: n * 2 + socket });
                }
            }
        }
        Topology { name: format!("copper-{nodes}n"), gpus, n_nodes: nodes, ib: IbGen::Fdr }
    }

    /// Parameterized copper-style fabric: `nodes` × `sockets` ×
    /// `dies_per_socket` GPUs, one PCIe switch per socket, FDR between
    /// nodes — the GPUs-per-node ablation axis of the hierarchical
    /// exchange benchmarks (copper itself is `grid(n, 2, 4)`).
    pub fn grid(nodes: usize, sockets: usize, dies_per_socket: usize) -> Topology {
        assert!(nodes > 0 && sockets > 0 && dies_per_socket > 0);
        let mut gpus = Vec::new();
        for n in 0..nodes {
            for socket in 0..sockets {
                for _die in 0..dies_per_socket {
                    gpus.push(GpuInfo { node: n, socket, switch: n * sockets + socket });
                }
            }
        }
        Topology {
            name: format!("grid-{nodes}n{sockets}s{dies_per_socket}d"),
            gpus,
            n_nodes: nodes,
            ib: IbGen::Fdr,
        }
    }

    /// mosaic: `nodes` nodes × 1 K20m GPU.
    pub fn mosaic(nodes: usize) -> Topology {
        let gpus = (0..nodes)
            .map(|n| GpuInfo { node: n, socket: 0, switch: n * 2 })
            .collect();
        Topology { name: format!("mosaic-{nodes}n"), gpus, n_nodes: nodes, ib: IbGen::Qdr }
    }

    /// First `k` GPUs of a preset — how experiments place k workers.
    pub fn by_name(name: &str, workers: usize) -> Option<Topology> {
        let t = match name {
            // one worker per node, like the paper's multi-node benchmarks
            "mosaic" => Topology::mosaic(workers.max(1)),
            // fill a single copper node first (the VGG single-node setup),
            // then more nodes
            "copper" => Topology::copper(workers.max(1).div_ceil(8)),
            _ => return None,
        };
        Some(t)
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn path(&self, a: usize, b: usize) -> PathKind {
        if a == b {
            return PathKind::Local;
        }
        let (ga, gb) = (self.gpus[a], self.gpus[b]);
        if ga.node != gb.node {
            PathKind::Network
        } else if ga.switch == gb.switch {
            PathKind::P2p
        } else {
            PathKind::QpiStaged
        }
    }

    /// Topology restricted to `ranks` (in order): what a leader-level inner
    /// strategy prices against. GPUs keep their node/socket/switch
    /// coordinates, so path classification is unchanged.
    pub fn subset(&self, ranks: &[usize]) -> Topology {
        let gpus: Vec<GpuInfo> = ranks.iter().map(|&r| self.gpus[r]).collect();
        let n_nodes = gpus.iter().map(|g| g.node + 1).max().unwrap_or(0);
        Topology {
            name: format!("{}[{}]", self.name, ranks.len()),
            gpus,
            n_nodes,
            ib: self.ib,
        }
    }

    fn groups_by(&self, k: usize, key: impl Fn(&GpuInfo) -> usize) -> Vec<Vec<usize>> {
        assert!(
            k <= self.gpus.len(),
            "{k} workers exceed the {}-GPU topology {}",
            self.gpus.len(),
            self.name
        );
        let mut map: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for r in 0..k {
            map.entry(key(&self.gpus[r])).or_default().push(r);
        }
        map.into_values().collect()
    }

    /// Ranks `0..k` grouped by PCIe switch (the GPUDirect P2P domains),
    /// ascending switch id; each group is ascending, so `group[0]` is the
    /// switch leader.
    pub fn switch_groups(&self, k: usize) -> Vec<Vec<usize>> {
        self.groups_by(k, |g| g.switch)
    }

    /// Ranks `0..k` grouped by node, ascending node id; `group[0]` is the
    /// node leader. Rank 0 always leads node 0, so rank 0's exchange
    /// report covers every level of a hierarchical exchange.
    pub fn node_groups(&self, k: usize) -> Vec<Vec<usize>> {
        self.groups_by(k, |g| g.node)
    }

    /// One leader rank per populated node — the `hier` strategies run
    /// their inner collective across exactly these ranks.
    pub fn node_leaders(&self, k: usize) -> Vec<usize> {
        self.node_groups(k).into_iter().map(|g| g[0]).collect()
    }

    /// ASCII rendering of the layout (the `tmpi topo` command → Fig. 6).
    pub fn render(&self) -> String {
        let leaders = self.node_leaders(self.n_gpus());
        let mut out = format!("topology {} ({} GPUs, IB {:?})\n", self.name, self.n_gpus(), self.ib);
        for n in 0..self.n_nodes {
            out.push_str(&format!("node {n}\n"));
            let mut sockets: Vec<usize> =
                self.gpus.iter().filter(|g| g.node == n).map(|g| g.socket).collect();
            sockets.sort_unstable();
            sockets.dedup();
            for s in sockets {
                let ids: Vec<String> = self
                    .gpus
                    .iter()
                    .enumerate()
                    .filter(|(_, g)| g.node == n && g.socket == s)
                    .map(|(i, _)| {
                        if leaders.contains(&i) {
                            format!("gpu{i}*")
                        } else {
                            format!("gpu{i}")
                        }
                    })
                    .collect();
                out.push_str(&format!("  socket {s} (CPU)--PCIe switch--[{}]\n", ids.join(" ")));
            }
            if self.n_nodes > 1 {
                out.push_str("  |-- IB NIC\n");
            }
        }
        if self.gpus.iter().any(|g| g.node == 0 && g.socket == 1) {
            out.push_str("(sockets joined by QPI; GPUDirect P2P only within a switch)\n");
        }
        out.push_str("(* = node leader: root of the hier exchange's intra-node reduce tree)\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copper_shape_matches_fig6() {
        let t = Topology::copper(2);
        assert_eq!(t.n_gpus(), 16);
        // 4 dies per socket
        for n in 0..2 {
            for s in 0..2 {
                let count = t.gpus.iter().filter(|g| g.node == n && g.socket == s).count();
                assert_eq!(count, 4);
            }
        }
    }

    #[test]
    fn mosaic_one_gpu_per_node() {
        let t = Topology::mosaic(8);
        assert_eq!(t.n_gpus(), 8);
        assert_eq!(t.n_nodes, 8);
        assert_eq!(t.ib, IbGen::Qdr);
    }

    #[test]
    fn path_classification() {
        let t = Topology::copper(2);
        assert_eq!(t.path(0, 0), PathKind::Local);
        assert_eq!(t.path(0, 1), PathKind::P2p); // same socket switch
        assert_eq!(t.path(0, 4), PathKind::QpiStaged); // cross-socket
        assert_eq!(t.path(0, 8), PathKind::Network); // cross-node
        let m = Topology::mosaic(4);
        assert_eq!(m.path(1, 2), PathKind::Network);
    }

    #[test]
    fn path_is_symmetric() {
        let t = Topology::copper(2);
        for a in 0..t.n_gpus() {
            for b in 0..t.n_gpus() {
                assert_eq!(t.path(a, b), t.path(b, a));
            }
        }
    }

    #[test]
    fn render_contains_every_gpu() {
        let t = Topology::copper(1);
        let r = t.render();
        for i in 0..8 {
            assert!(r.contains(&format!("gpu{i}")), "{r}");
        }
    }

    #[test]
    fn render_annotates_node_leaders() {
        let r = Topology::copper(2).render();
        assert!(r.contains("gpu0*"), "{r}");
        assert!(r.contains("gpu8*"), "{r}");
        assert!(!r.contains("gpu1*") && !r.contains("gpu4*"), "{r}");
        assert!(r.contains("node leader"), "{r}");
    }

    #[test]
    fn grid_generalizes_copper() {
        let g = Topology::grid(2, 2, 4);
        let c = Topology::copper(2);
        assert_eq!(g.gpus, c.gpus);
        assert_eq!(g.ib, IbGen::Fdr);
        let small = Topology::grid(3, 2, 1);
        assert_eq!(small.n_gpus(), 6);
        assert_eq!(small.path(0, 1), PathKind::QpiStaged);
        assert_eq!(small.path(1, 2), PathKind::Network);
    }

    #[test]
    fn switch_and_node_groups_partition_ranks() {
        let t = Topology::copper(2);
        for k in [1usize, 3, 8, 11, 16] {
            for groups in [t.switch_groups(k), t.node_groups(k)] {
                let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..k).collect::<Vec<_>>(), "k={k}");
                for g in &groups {
                    assert!(!g.is_empty());
                    assert!(g.windows(2).all(|w| w[0] < w[1]), "groups ascend");
                }
            }
        }
        // copper 16 ranks: 4 switches of 4, 2 nodes of 8
        assert_eq!(t.switch_groups(16).len(), 4);
        assert_eq!(t.node_groups(16).len(), 2);
        assert_eq!(t.node_leaders(16), vec![0, 8]);
        // partial fill: 11 ranks leave node 1 with 3 GPUs
        assert_eq!(t.node_groups(11)[1], vec![8, 9, 10]);
        // rank 0 is always the first node's leader
        assert_eq!(t.node_leaders(5)[0], 0);
        let m = Topology::mosaic(4);
        assert_eq!(m.node_leaders(4), vec![0, 1, 2, 3]);
        assert_eq!(m.switch_groups(4).len(), 4);
    }

    #[test]
    fn subset_keeps_coordinates() {
        let t = Topology::copper(2);
        let s = t.subset(&[0, 8]);
        assert_eq!(s.n_gpus(), 2);
        assert_eq!(s.path(0, 1), PathKind::Network);
        assert_eq!(s.ib, IbGen::Fdr);
        assert_eq!(s.n_nodes, 2);
        let one = t.subset(&[4, 5]);
        assert_eq!(one.path(0, 1), PathKind::P2p);
        assert_eq!(one.n_nodes, 1);
    }
}
