//! In-tree property-testing harness (proptest is not vendored offline).
//!
//! `prop(name, cases, f)` runs `f` against `cases` independent seeded RNGs
//! and panics with the failing seed on the first counterexample, so failures
//! reproduce with `check_one(name, seed, f)`.

use crate::util::Rng;

/// Run a property over `cases` random seeds. `f` returns Err(description)
/// on a counterexample.
pub fn prop<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Re-run a single failing case.
pub fn check_one<F>(name: &str, seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

/// Random f32 vector with entries in roughly N(0, scale).
pub fn gauss_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gauss_f32() * scale).collect()
}

/// Assert two f32 slices are elementwise close; Err with first offender.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_passes_for_tautology() {
        prop("tautology", 50, |rng| {
            let v = gauss_vec(rng, 10, 1.0);
            if v.len() == 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn prop_reports_failures() {
        prop("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(allclose(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(allclose(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }
}
