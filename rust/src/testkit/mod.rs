//! In-tree property-testing harness (proptest is not vendored offline).
//!
//! `prop(name, cases, f)` runs `f` against `cases` independent seeded RNGs
//! and panics with the failing seed on the first counterexample.
//!
//! **Reproducing a failure:** the panic message names the seed, e.g.
//! `property 'differential' failed at seed 0x5eed002a: ...`. Re-run just
//! that case with `check_one("differential", 0x5eed002a, f)` — the seed
//! fully determines the generated inputs, no sweep needed.
//!
//! **Deep sweeps:** the `TMPI_PROP_CASES` env var overrides every `prop`
//! call's case count (the in-code count is the default), so CI can run
//! `TMPI_PROP_CASES=500 cargo test` nightly without slowing local runs.

use crate::cluster::Topology;
use crate::collectives::{
    ChunkedPipeline, CommReport, ExchangeCtx, ExchangeStrategy, FlatKind, ReduceOp, StrategyKind,
    WireFormat,
};
use crate::mpi;
use crate::simnet::LinkParams;
use crate::util::Rng;

/// Every selectable exchange strategy: the flat kinds and each `hier:*`
/// composition — the matrix the differential and invariant suites sweep.
pub fn all_strategy_kinds() -> [StrategyKind; 8] {
    [
        StrategyKind::Ar,
        StrategyKind::Asa,
        StrategyKind::Asa16,
        StrategyKind::Ring,
        StrategyKind::Hier { inner: FlatKind::Ar },
        StrategyKind::Hier { inner: FlatKind::Asa },
        StrategyKind::Hier { inner: FlatKind::Asa16 },
        StrategyKind::Hier { inner: FlatKind::Ring },
    ]
}

/// Run a named strategy — optionally wrapped in the chunked pipeline
/// scheduler — across `bufs.len()` worker threads on `topo` with no
/// kernels bound. Returns every rank's final buffer and rank 0's report
/// (rank 0 is always a hier node leader, so its report is complete). The
/// one exchange-test harness the integration suites share.
pub fn run_exchange(
    kind: StrategyKind,
    chunk_elems: Option<usize>,
    bufs: Vec<Vec<f32>>,
    op: ReduceOp,
    topo: &Topology,
) -> (Vec<Vec<f32>>, CommReport) {
    // historical default: asa16-family runs its native f16 wire, everything
    // else stays dense f32 (no codec wrapper)
    let fmt = if kind.half_wire() { WireFormat::F16 } else { WireFormat::F32 };
    run_exchange_wire(kind, fmt, chunk_elems, bufs, op, topo)
}

/// [`run_exchange`] with an explicit wire format — the codec-aware variant
/// the wire property suites sweep (compressed formats get the
/// error-feedback `WireCodec` wrapper exactly as `StrategyKind::build`
/// wires them in production).
pub fn run_exchange_wire(
    kind: StrategyKind,
    fmt: WireFormat,
    chunk_elems: Option<usize>,
    bufs: Vec<Vec<f32>>,
    op: ReduceOp,
    topo: &Topology,
) -> (Vec<Vec<f32>>, CommReport) {
    let k = bufs.len();
    let world = mpi::world(k);
    let links = LinkParams::default();
    let handles: Vec<_> = world
        .into_iter()
        .zip(bufs)
        .map(|(mut comm, mut buf)| {
            let topo = topo.clone();
            std::thread::spawn(move || {
                let strat: Box<dyn ExchangeStrategy> = match chunk_elems {
                    Some(c) => Box::new(ChunkedPipeline::new(kind.build(fmt), c, true)),
                    None => kind.build(fmt),
                };
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo: &topo,
                    links: &links,
                    kernels: None,
                    cuda_aware: true,
                    chunk_elems: 0,
                    slice_off: 0,
                    sf_bytes: None,
                };
                let rep = strat.exchange(&mut buf, op, &mut ctx).unwrap();
                (buf, rep)
            })
        })
        .collect();
    let mut outs = Vec::new();
    let mut rep0 = CommReport::default();
    for (i, h) in handles.into_iter().enumerate() {
        let (buf, rep) = h.join().unwrap();
        if i == 0 {
            rep0 = rep;
        }
        outs.push(buf);
    }
    (outs, rep0)
}

/// Case count for a property: the caller's default unless `TMPI_PROP_CASES`
/// overrides it.
pub fn prop_cases(default_cases: u64) -> u64 {
    std::env::var("TMPI_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run a property over `cases` random seeds (`TMPI_PROP_CASES` overrides).
/// `f` returns Err(description) on a counterexample.
pub fn prop<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..prop_cases(cases) {
        let seed = 0x5EED_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Re-run a single failing case.
pub fn check_one<F>(name: &str, seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

/// Random f32 vector with entries in roughly N(0, scale).
pub fn gauss_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gauss_f32() * scale).collect()
}

/// All permutations of `0..n` in lexicographic order (Heap's algorithm is
/// not stable-ordered; lexicographic keeps failure reports reproducible).
/// The race explorer enumerates delivery schedules with this — keep `n`
/// small (n! grows fast; the explorer uses n ≤ 4).
pub fn permutations(n: usize) -> Vec<Vec<usize>> {
    fn rec(prefix: &mut Vec<usize>, rest: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if rest.is_empty() {
            out.push(prefix.clone());
            return;
        }
        for i in 0..rest.len() {
            let v = rest.remove(i);
            prefix.push(v);
            rec(prefix, rest, out);
            prefix.pop();
            rest.insert(i, v);
        }
    }
    let mut out = Vec::new();
    rec(&mut Vec::new(), &mut (0..n).collect(), &mut out);
    out
}

/// A schedule gate: threads block until the flattened schedule reaches
/// their id, forcing a chosen real-time interleaving of otherwise-racy
/// steps (the race explorer serializes worker *sends* with this while the
/// virtual-time pricing must stay schedule-independent).
pub struct Turnstile {
    schedule: Vec<usize>,
    pos: std::sync::Mutex<usize>,
    cv: std::sync::Condvar,
}

impl Turnstile {
    pub fn new(schedule: Vec<usize>) -> Turnstile {
        Turnstile { schedule, pos: std::sync::Mutex::new(0), cv: std::sync::Condvar::new() }
    }

    /// Block until the next unconsumed schedule slot is `id`, then claim
    /// it. Ids past the end of the schedule pass freely.
    pub fn wait_turn(&self, id: usize) {
        let mut pos = self.pos.lock().unwrap();
        while *pos < self.schedule.len() && self.schedule[*pos] != id {
            pos = self.cv.wait(pos).unwrap();
        }
    }

    /// Release the claimed slot, waking the next thread in the schedule.
    pub fn advance(&self) {
        let mut pos = self.pos.lock().unwrap();
        *pos += 1;
        self.cv.notify_all();
    }
}

/// Assert two f32 slices are elementwise close; Err with first offender.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_passes_for_tautology() {
        prop("tautology", 50, |rng| {
            let v = gauss_vec(rng, 10, 1.0);
            if v.len() == 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn prop_reports_failures() {
        prop("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn prop_cases_env_override() {
        // must hold even when the suite itself runs under an external
        // TMPI_PROP_CASES=... (the nightly deep sweep), so snapshot and
        // restore. Briefly mutating process env can at worst make a
        // concurrently-starting prop() run fewer cases, never fail.
        let saved = std::env::var("TMPI_PROP_CASES").ok();
        std::env::set_var("TMPI_PROP_CASES", "7");
        assert_eq!(prop_cases(40), 7);
        std::env::set_var("TMPI_PROP_CASES", "not-a-number");
        assert_eq!(prop_cases(40), 40, "unparseable values fall back");
        match &saved {
            Some(v) => std::env::set_var("TMPI_PROP_CASES", v),
            None => std::env::remove_var("TMPI_PROP_CASES"),
        }
        let expect = saved.as_deref().and_then(|s| s.parse().ok()).unwrap_or(40);
        assert_eq!(prop_cases(40), expect);
    }

    #[test]
    fn permutations_enumerate_lexicographically() {
        assert_eq!(permutations(0), vec![Vec::<usize>::new()]);
        assert_eq!(permutations(1), vec![vec![0]]);
        let p3 = permutations(3);
        assert_eq!(p3.len(), 6);
        assert_eq!(p3[0], vec![0, 1, 2]);
        assert_eq!(p3[5], vec![2, 1, 0]);
        let mut sorted = p3.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted, p3, "lexicographic and duplicate-free");
    }

    #[test]
    fn turnstile_enforces_its_schedule() {
        use std::sync::Arc;
        let gate = Arc::new(Turnstile::new(vec![2, 0, 1]));
        let log = Arc::new(std::sync::Mutex::new(Vec::new()));
        let hs: Vec<_> = (0..3)
            .map(|id| {
                let gate = gate.clone();
                let log = log.clone();
                std::thread::spawn(move || {
                    gate.wait_turn(id);
                    log.lock().unwrap().push(id);
                    gate.advance();
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(*log.lock().unwrap(), vec![2, 0, 1]);
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(allclose(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(allclose(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }
}
