//! The BSP engine — synchronous data-parallel training (paper §3.1, Fig. 1).
//!
//! k worker threads ("processes", one simulated GPU each) run the superstep
//! loop: **load** (parallel loader child, Alg. 1) → **compute** (the AOT
//! train/grad artifact via PJRT — real, measured) → **barrier** → **exchange**
//! (an `ExchangeStrategy` over the flat vector — real data, simulated wire
//! time). Virtual clocks reconcile at every barrier: the straggler gates the
//! superstep, exactly the BSP accounting the paper's speedup numbers use.
//!
//! Two parallel-SGD schemes (§4):
//! * **AWAGD** — train artifact locally, then average weights (optionally
//!   momentum too — `exchange_momentum`, the [7] variant) across ranks.
//! * **SUBGD** — grad artifact, *sum* gradients across ranks, then the fused
//!   Pallas `sgd_apply` artifact applies one identical update per rank.
//!
//! When `sim_model` names a full-scale architecture, exchange time is scaled
//! to that model's true parameter bytes (Table 2) so speedups reproduce the
//! paper's communication regime while compute runs the proxy (DESIGN.md §2).

use std::path::PathBuf;
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::audit::{ChargeKind, Ledger};
use crate::cluster::Topology;
use crate::collectives::{
    wfbp, CommReport, ExchangeCtx, OverlapMode, ReduceOp, WfbpPlan, WireFormat,
};
use crate::data::{FeatureDataset, ImageDataset, ImageSpec, TokenStream};
use crate::loader::{DecodeCache, LoaderConfig, LoaderReport, ParallelLoader};
use crate::metrics::Breakdown;
use crate::models;
use crate::mpi::{self, Comm};
use crate::plan::ExchangePlan;
use crate::runtime::{HostTensor, Runtime};
use crate::sgd::{LrSchedule, Scheme};
use crate::simnet::LinkParams;
use crate::units::{Kib, Secs};

/// Full configuration of one BSP training session.
#[derive(Clone, Debug)]
pub struct BspConfig {
    /// proxy model name from the manifest ("mlp", "alexnet", ...)
    pub model: String,
    pub workers: usize,
    /// per-worker batch size (must have an AOT artifact)
    pub batch: usize,
    pub scheme: Scheme,
    /// every exchange-shaping knob (strategy, wire, chunking, overlap):
    /// one [`ExchangePlan`], fed by legacy keys/flags or `tmpi plan`
    pub plan: ExchangePlan,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub iters: usize,
    /// evaluate on rank 0 every this many iterations (0 = never)
    pub eval_every: usize,
    /// "mosaic" (1 GPU/node) or "copper" (8 GPU/node) — Fig. 6
    pub topology: String,
    pub cuda_aware: bool,
    pub seed: u64,
    /// parallel loader child (Alg. 1) vs direct synchronous loading
    pub use_loader: bool,
    /// in-flight batch requests kept at the loader child (1 ≡ the seed's
    /// hardcoded double buffer); must be ≥ 1 when `use_loader` is set
    pub prefetch_depth: usize,
    /// decode-cache capacity in MiB (0 = no cache); applies to both the
    /// parallel child and the direct path
    pub cache_mib: usize,
    /// scale exchange time to this full-scale model's parameter bytes
    pub sim_model: Option<String>,
    /// where shard batch files are written (default: temp dir)
    pub data_dir: Option<PathBuf>,
    /// AWAGD: also average momentum (the [7] two-GPU framework did)
    pub exchange_momentum: bool,
    /// cross-rank parameter checksum every N iters (0 = off; test hook)
    pub integrity_every: usize,
}

impl BspConfig {
    /// Wait-free/bucketed overlap exchanges *gradients* while the backward
    /// pass still runs, so it only composes with SUBGD; AWAGD exchanges
    /// post-update weights, whose backward pass is already over. Checked
    /// at the top of [`run_bsp`]; pure so config handling can test it.
    pub fn validate_overlap(&self) -> Result<()> {
        if self.plan.overlap.bucketed() && self.scheme != Scheme::Subgd {
            return Err(anyhow!(
                "overlap={} exchanges gradients during the backward pass and so \
                 requires scheme=subgd (awagd exchanges post-update weights)",
                self.plan.overlap.name()
            ));
        }
        Ok(())
    }

    pub fn quick(model: &str, workers: usize, iters: usize) -> BspConfig {
        BspConfig {
            model: model.to_string(),
            workers,
            batch: 0, // filled from manifest default at run time
            scheme: Scheme::Subgd,
            plan: ExchangePlan::default(),
            lr: LrSchedule::Const { base: 0.01 },
            momentum: 0.9,
            iters,
            eval_every: 0,
            topology: "mosaic".to_string(),
            cuda_aware: true,
            seed: 42,
            use_loader: false,
            prefetch_depth: 2,
            cache_mib: 0,
            sim_model: None,
            data_dir: None,
            exchange_momentum: false,
            integrity_every: 0,
        }
    }
}

/// One point of the convergence curve (rank 0's view).
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub iter: usize,
    /// virtual seconds since training start (train + comm accounting)
    pub vtime: Secs,
    pub train_loss: f64,
    /// validation error = 1 - accuracy (the paper plots top-k error)
    pub val_err: f64,
}

/// Everything a BSP run reports.
#[derive(Clone, Debug, Default)]
pub struct BspReport {
    pub curve: Vec<EvalPoint>,
    pub iters: usize,
    pub workers: usize,
    pub batch: usize,
    /// final reconciled virtual clock (seconds)
    pub vtime_total: Secs,
    /// rank-0 time decomposition
    pub breakdown: Breakdown,
    /// sum over iterations of one rank's exchange reports
    pub comm: CommReport,
    /// examples per virtual second across all workers
    pub throughput: f64,
    /// share of exchange time hidden under the backward pass by wait-free
    /// backprop: `comm_hidden / (comm_hidden + visible comm)`; 0.0 when
    /// `overlap != wfbp` or nothing was exchanged
    pub overlap_fraction: f64,
    pub final_train_loss: f64,
    pub final_val_err: f64,
    /// input-pipeline summary (Some for image workloads, rank 0's view);
    /// `prefetch_depth == 0` marks the direct (synchronous) path
    pub loader: Option<LoaderReport>,
}

impl BspReport {
    /// Virtual seconds to process `n` examples (Table 3's unit: per-5120).
    /// Degenerate runs (0 iters/batch/workers) processed no examples, so
    /// any per-example time is 0 — never NaN/inf from a zero denominator.
    pub fn time_per_examples(&self, n: usize) -> f64 {
        let total_examples = (self.iters * self.batch * self.workers) as f64;
        if total_examples <= 0.0 {
            return 0.0;
        }
        self.vtime_total.0 * n as f64 / total_examples
    }
}

enum WorkerData {
    Images {
        shard: crate::data::ShardFiles,
        loader: Option<ParallelLoader>,
        /// direct-path decode cache (the parallel child owns its own)
        cache: Option<DecodeCache>,
        dataset: Arc<ImageDataset>,
    },
    /// flat-feature models (MLP): in-memory batches, no file loader
    Features {
        dataset: Arc<FeatureDataset>,
    },
    Tokens {
        stream: Arc<TokenStream>,
        seq: usize,
    },
}

/// Run one BSP training session. Blocks until all workers finish.
pub fn run_bsp(rt: &Arc<Runtime>, cfg: &BspConfig) -> Result<BspReport> {
    let mut cfg = cfg.clone();
    let info = rt
        .manifest
        .models
        .get(&cfg.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", cfg.model))?
        .clone();
    if cfg.batch == 0 {
        cfg.batch = info.batch;
    }
    if cfg.use_loader && cfg.prefetch_depth == 0 {
        return Err(anyhow!(
            "use_loader requires prefetch_depth >= 1 (1 is the classic double buffer)"
        ));
    }
    let arts = models::artifacts_for(&info, &cfg.model, cfg.batch)?;
    let topo = Topology::by_name(&cfg.topology, cfg.workers)
        .ok_or_else(|| anyhow!("unknown topology '{}'", cfg.topology))?;
    if cfg.workers > topo.n_gpus() {
        return Err(anyhow!("{} workers > {} gpus", cfg.workers, topo.n_gpus()));
    }
    let links = LinkParams::default();

    // exchange-time scaling to a full-scale model (comm sim at true bytes)
    let comm_scale = match &cfg.sim_model {
        Some(fs) => {
            let full = models::full_scale_bytes(&rt.manifest, fs)? as f64;
            full / (4.0 * info.param_count as f64)
        }
        None => 1.0,
    };

    // wait-free backprop: bucket the parameter vector by layer. The layer
    // table comes from the simulated full-scale model when one is set
    // (projected onto the proxy vector), else from the proxy's own
    // segment table.
    cfg.validate_overlap()?;
    let wfbp_plan: Option<Arc<WfbpPlan>> = if cfg.plan.overlap.bucketed() {
        let table: Vec<(String, usize)> = match &cfg.sim_model {
            Some(fs) => models::full_scale_layer_table(&rt.manifest, fs)?,
            None => info.segments.iter().map(|(n, _, sz)| (n.clone(), *sz)).collect(),
        };
        // the bucket budget is *on-wire* KiB: elems come from the active
        // wire's bytes-per-elem, not a hardcoded 4 (the sizing bugfix)
        let bucket_elems = Kib(cfg.plan.bucket_kib).elems(cfg.plan.strategy, cfg.plan.wire_format()).0;
        let mut plan = WfbpPlan::from_layers(&table, bucket_elems);
        if cfg.plan.wire_format() == WireFormat::Sf {
            // sufficient factors apply to all-fc buckets only; the fc dims
            // tables tell annotate_sf which those are
            let dims_model = cfg
                .sim_model
                .clone()
                .or_else(|| models::full_scale_of(&cfg.model).map(str::to_string));
            if let Some(dims) = dims_model.and_then(|m| models::builtin_fc_dims(&m)) {
                plan.annotate_sf(&table, &dims, cfg.batch);
            }
        }
        Some(Arc::new(plan.project(info.param_count)))
    } else {
        None
    };

    // warm up artifacts once (XLA compile outside the timed loop)
    rt.warmup(&arts.train).ok();
    rt.warmup(&arts.grad).ok();
    if cfg.eval_every > 0 {
        rt.warmup(&arts.eval).ok();
    }
    if cfg.scheme == Scheme::Subgd {
        rt.warmup(&arts.sgd_apply)?;
    }

    let init = Arc::new(rt.init_params(&cfg.model)?);
    let is_lm = info.kind == "lm";
    let is_flat = !is_lm && info.input_shape.len() == 2;

    // dataset setup
    let data_dir = cfg
        .data_dir
        .clone()
        .unwrap_or_else(|| std::env::temp_dir().join(format!("tmpi_bsp_{}", std::process::id())));
    let dataset: Option<Arc<ImageDataset>> = if is_lm || is_flat {
        None
    } else {
        let mut spec = ImageSpec::default();
        spec.classes = info.classes.unwrap_or(16);
        spec.seed = cfg.seed;
        Some(Arc::new(ImageDataset::new(spec)))
    };
    let features: Option<Arc<FeatureDataset>> = if is_flat {
        Some(Arc::new(FeatureDataset::new(
            info.input_shape[1],
            info.classes.unwrap_or(16),
            cfg.seed,
        )))
    } else {
        None
    };
    let stream: Option<Arc<TokenStream>> = if is_lm {
        Some(Arc::new(TokenStream::new(lm_vocab(rt, &cfg.model)?, cfg.seed)))
    } else {
        None
    };

    let world = mpi::world(cfg.workers);
    let mut handles = Vec::new();
    for (rank, comm) in world.into_iter().enumerate() {
        let rt = rt.clone();
        let cfg = cfg.clone();
        let topo = topo.clone();
        let init = init.clone();
        let info = info.clone();
        let arts = models::artifacts_for(&info, &cfg.model, cfg.batch)?;
        let dataset = dataset.clone();
        let features = features.clone();
        let stream = stream.clone();
        let data_dir = data_dir.clone();
        let wfbp_plan = wfbp_plan.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("bsp-worker-{rank}"))
                .spawn(move || {
                    worker_main(
                        rank, comm, &rt, &cfg, &topo, &links, &init, &info, &arts, dataset,
                        features, stream, &data_dir, comm_scale, wfbp_plan.as_deref(),
                    )
                })
                .context("spawn worker")?,
        );
    }

    let mut report = BspReport::default();
    for (rank, h) in handles.into_iter().enumerate() {
        let r = h.join().map_err(|_| anyhow!("worker {rank} panicked"))??;
        if rank == 0 {
            report = r;
        } else {
            report.vtime_total = report.vtime_total.max(r.vtime_total);
        }
    }
    report.workers = cfg.workers;
    report.batch = cfg.batch;
    report.iters = cfg.iters;
    report.throughput =
        (cfg.iters * cfg.batch * cfg.workers) as f64 / report.vtime_total.0.max(1e-12);
    Ok(report)
}

/// Vocab size of an LM model. The grad artifact signature carries only
/// flat shapes, so the vocab comes from the model's configured class
/// count, defaulting to 2048.
fn lm_vocab(rt: &Runtime, model: &str) -> Result<usize> {
    Ok(rt.manifest.models[model].classes.unwrap_or(2048))
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    rank: usize,
    mut comm: Comm,
    rt: &Arc<Runtime>,
    cfg: &BspConfig,
    topo: &Topology,
    links: &LinkParams,
    init: &Arc<Vec<f32>>,
    info: &crate::runtime::ModelInfo,
    arts: &models::ModelArtifacts,
    dataset: Option<Arc<ImageDataset>>,
    features: Option<Arc<FeatureDataset>>,
    stream: Option<Arc<TokenStream>>,
    data_dir: &PathBuf,
    comm_scale: f64,
    wfbp_plan: Option<&WfbpPlan>,
) -> Result<BspReport> {
    let mut params = (**init).clone();
    let mut momentum = vec![0.0f32; params.len()];
    // every virtual-time charge goes through the ledger, which derives the
    // clock and the Breakdown from one stream (breakdown==clock by
    // construction; see rust/src/audit)
    let mut led = Ledger::new();
    let mut comm_total = CommReport::default();
    let mut serial_comm = Secs::ZERO; // what post-backward pricing would charge
    let mut curve = Vec::new();
    let mut last_loss = f64::NAN;
    let kernels = rt.kernels();
    // route the exchange through the chunked pipeline scheduler when asked
    let strategy: Box<dyn crate::collectives::ExchangeStrategy> = if cfg.plan.chunk_kib > 0 {
        Box::new(crate::collectives::ChunkedPipeline::new(
            cfg.plan.strategy.build(cfg.plan.wire_format()),
            // on-wire KiB per chunk (the sizing bugfix): wire-width-aware
            Kib(cfg.plan.chunk_kib).elems(cfg.plan.strategy, cfg.plan.wire_format()).0.max(1),
            cfg.plan.pipeline,
        ))
    } else {
        cfg.plan.strategy.build(cfg.plan.wire_format())
    };
    let mut rng = crate::util::Rng::new(cfg.seed).fork(rank as u64 + 1);

    // --- data source ---------------------------------------------------------
    let mut data = match (&dataset, &features, &stream) {
        (None, Some(fd), None) => WorkerData::Features { dataset: fd.clone() },
        (Some(ds), None, None) => images_data(ds, data_dir, rank, cfg, links)?,
        (None, None, Some(ts)) => {
            WorkerData::Tokens { stream: ts.clone(), seq: info.input_shape[1] }
        }
        _ => unreachable!(),
    };

    // eval set (rank 0 only)
    let eval_data: Option<(HostTensor, HostTensor)> = if rank == 0 && cfg.eval_every > 0 {
        Some(build_eval(&data, info, cfg)?)
    } else {
        None
    };

    for iter in 0..cfg.iters {
        let lr = cfg.lr.at(iter) as f32;

        // --- load (charges LoadStall/H2d/LoadHidden on the ledger) -----------
        let (x, y) = next_batch(&mut data, cfg, rank, iter, &mut rng, links, &mut led)?;

        // --- compute -----------------------------------------------------------
        match cfg.scheme {
            Scheme::Awagd => {
                let res = rt.exec(
                    &arts.train,
                    vec![
                        HostTensor::f32(vec![params.len()], std::mem::take(&mut params)),
                        HostTensor::f32(vec![momentum.len()], std::mem::take(&mut momentum)),
                        x,
                        y,
                        HostTensor::scalar_f32(lr),
                        HostTensor::scalar_f32(cfg.momentum as f32),
                    ],
                )?;
                let mut outs = res.outputs.into_iter();
                params = outs.next().unwrap().into_f32()?;
                momentum = outs.next().unwrap().into_f32()?;
                last_loss = outs.next().unwrap().scalar()? as f64;
                led.charge(ChargeKind::Compute, "bsp.train", Secs(res.exec_time));

                // --- barrier + exchange (average weights) ----------------------
                // straggle (the gap to the superstep's slowest rank) is peer
                // waiting: charged to comm_queue so breakdown==clock at k>1
                let reconciled = comm.barrier(led.clock().0);
                led.advance_to(ChargeKind::CommQueue, "bsp.barrier", Secs(reconciled));
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo,
                    links,
                    kernels: Some(&kernels),
                    cuda_aware: cfg.cuda_aware,
                    chunk_elems: 0,
                    slice_off: 0,
                    sf_bytes: None,
                };
                let rep = strategy.exchange(&mut params, ReduceOp::Mean, &mut ctx)?;
                led.charge_report("bsp.exchange", &rep, comm_scale);
                comm_total.absorb(&rep);
                if cfg.exchange_momentum {
                    // caveat: a compressed wire's error-feedback residual is
                    // indexed by vector offset, so this second exchange
                    // shares the params exchange's residual slots (both run
                    // at slice_off 0) — harmless for f32/f16/bf16, lossy
                    // wires are not recommended with exchange_momentum
                    let rep2 = strategy.exchange(&mut momentum, ReduceOp::Mean, &mut ctx)?;
                    led.charge_report("bsp.exchange_momentum", &rep2, comm_scale);
                    comm_total.absorb(&rep2);
                }
            }
            Scheme::Subgd => {
                let res = rt.exec(
                    &arts.grad,
                    vec![HostTensor::f32(vec![params.len()], params.clone()), x, y],
                )?;
                let mut outs = res.outputs.into_iter();
                let mut grads = outs.next().unwrap().into_f32()?;
                last_loss = outs.next().unwrap().scalar()? as f64;
                led.charge(ChargeKind::Compute, "bsp.grad", Secs(res.exec_time));

                // --- barrier + exchange (sum gradients) ------------------------
                let reconciled = comm.barrier(led.clock().0);
                led.advance_to(ChargeKind::CommQueue, "bsp.barrier", Secs(reconciled));
                let mut ctx = ExchangeCtx {
                    comm: &mut comm,
                    topo,
                    links,
                    kernels: Some(&kernels),
                    cuda_aware: cfg.cuda_aware,
                    chunk_elems: 0,
                    slice_off: 0,
                    sf_bytes: None,
                };
                match wfbp_plan {
                    Some(plan) => {
                        // wait-free backprop: the bucketed exchange overlaps
                        // this rank's backward tail, so the clock pays
                        // max(backward, joint makespan) - backward instead of
                        // backward + comm (the backward time is already on
                        // the clock from the compute charge above)
                        let backward = Secs(res.exec_time * wfbp::BWD_FRACTION);
                        let out = wfbp::exchange_wfbp(
                            strategy.as_ref(),
                            plan,
                            &mut grads,
                            ReduceOp::Sum,
                            &mut ctx,
                            backward,
                            comm_scale,
                            cfg.plan.overlap == OverlapMode::Wfbp,
                        )?;
                        // out.comm.sim_total() == out.comm_visible, so the
                        // ledger's clock pays exactly the visible time; the
                        // hidden share is memo'd against the serial cost it
                        // came out of
                        led.charge_report("bsp.wfbp", &out.comm, 1.0); // already scaled
                        led.charge_hidden("bsp.wfbp", out.comm_hidden, out.serial_comm);
                        serial_comm += out.serial_comm;
                        comm_total.absorb(&out.comm);
                    }
                    None => {
                        let rep = strategy.exchange(&mut grads, ReduceOp::Sum, &mut ctx)?;
                        led.charge_report("bsp.exchange", &rep, comm_scale);
                        serial_comm += rep.sim_total() * comm_scale;
                        comm_total.absorb(&rep);
                    }
                }

                // --- apply (identical update on every rank; summed grads are
                // averaged so the effective batch is batch*k at the worker lr,
                // the paper's SUBGD-without-LR-scaling form) -----------------------
                let n = params.len();
                let apply = rt.exec(
                    &arts.sgd_apply,
                    vec![
                        HostTensor::f32(vec![n], std::mem::take(&mut params)),
                        HostTensor::f32(vec![n], std::mem::take(&mut momentum)),
                        HostTensor::f32(vec![n], grads),
                        HostTensor::scalar_f32(lr),
                        HostTensor::scalar_f32(cfg.momentum as f32),
                        HostTensor::scalar_f32(1.0 / cfg.workers as f32),
                    ],
                )?;
                let mut outs = apply.outputs.into_iter();
                params = outs.next().unwrap().into_f32()?;
                momentum = outs.next().unwrap().into_f32()?;
                led.charge(ChargeKind::Apply, "bsp.apply", Secs(apply.exec_time));
            }
        }

        // --- integrity: all ranks must hold identical parameters -------------
        if cfg.integrity_every > 0 && (iter + 1) % cfg.integrity_every == 0 {
            integrity_check(&mut comm, &params, iter)?;
        }

        // --- eval (rank 0; not charged to the virtual clock) -----------------
        if rank == 0 && cfg.eval_every > 0 && ((iter + 1) % cfg.eval_every == 0 || iter + 1 == cfg.iters)
        {
            let (ex, ey) = eval_data.as_ref().unwrap();
            let val_err = run_eval(rt, &arts.eval, &params, ex, ey, info)?;
            curve.push(EvalPoint {
                iter: iter + 1,
                vtime: led.clock(),
                train_loss: last_loss,
                val_err,
            });
        }
    }

    // final clock reconciliation (straggle is peer waiting, like any barrier)
    let reconciled = comm.barrier(led.clock().0);
    led.advance_to(ChargeKind::CommQueue, "bsp.final_barrier", Secs(reconciled));
    let loader_report = match &mut data {
        WorkerData::Images { loader: Some(l), .. } => {
            // the per-iteration stall charges already cover the loader's
            // total (each ready() call accounts its own wait); the child
            // can only accrue more stall time after the last collect,
            // never less
            debug_assert!(
                l.stall_time.0 >= led.breakdown().load_stall.0 - 1e-9,
                "loader stall accounting regressed: {} < {}",
                l.stall_time,
                led.breakdown().load_stall
            );
            let rep = l.report();
            l.stop();
            Some(rep)
        }
        WorkerData::Images { loader: None, cache, .. } => Some(LoaderReport {
            batches_loaded: cfg.iters,
            stall_time: Secs::ZERO,
            load_time: led.breakdown().load_stall,
            h2d_sim: led.breakdown().h2d,
            prefetch_depth: 0, // marks the direct (synchronous) path
            cache: cache.as_ref().map(|c| c.stats()).unwrap_or_default(),
        }),
        _ => None,
    };

    let final_val_err = curve.last().map(|p| p.val_err).unwrap_or(f64::NAN);
    let (clock, bd) = led.finish();
    let overlap_fraction = if serial_comm > 0.0 {
        bd.comm_hidden / serial_comm
    } else {
        0.0
    };
    Ok(BspReport {
        curve,
        iters: cfg.iters,
        workers: cfg.workers,
        batch: cfg.batch,
        vtime_total: clock,
        breakdown: bd,
        comm: comm_total,
        throughput: 0.0, // filled by run_bsp
        overlap_fraction,
        final_train_loss: last_loss,
        final_val_err,
        loader: loader_report,
    })
}

/// Build the on-disk images data source: a fingerprint-keyed segment
/// (written once, reused across runs/ranks via `ensure_shard`), plus
/// either a parallel loader child primed with `prefetch_depth` requests or
/// a direct-path decode cache.
fn images_data(
    ds: &Arc<ImageDataset>,
    data_dir: &PathBuf,
    rank: usize,
    cfg: &BspConfig,
    links: &LinkParams,
) -> Result<WorkerData> {
    // enough distinct files for the run, cycled (an "epoch" = one pass)
    let n_files = cfg.iters.min(64).max(1);
    let shard = ds.ensure_shard(data_dir, rank, cfg.workers, cfg.batch, n_files)?;
    let loader = if cfg.use_loader {
        let l = ParallelLoader::spawn(
            shard.spec.clone(),
            shard.mean.clone(),
            cfg.batch,
            *links,
            cfg.seed ^ rank as u64,
            LoaderConfig { prefetch_depth: cfg.prefetch_depth, cache_mib: cfg.cache_mib },
        );
        l.set_mode("train");
        // prime Q in-flight requests (Alg. 1 step 7, generalized from the
        // seed's 1-deep double buffer)
        for j in 0..cfg.prefetch_depth.min(cfg.iters) {
            l.request(shard.files[j % shard.files.len()].clone());
        }
        Some(l)
    } else {
        None
    };
    let cache = if !cfg.use_loader && cfg.cache_mib > 0 {
        Some(DecodeCache::new(cfg.cache_mib))
    } else {
        None
    };
    Ok(WorkerData::Images { shard, loader, cache, dataset: ds.clone() })
}

/// Produce the next (x, y) batch, charging the ledger for everything the
/// load cost: `LoadStall` (time the worker was blocked), `H2d` (PCIe
/// staging, priced on the run's configured fabric on *both* paths — the
/// crossing is real either way), and the `LoadHidden` memo for child
/// disk+decode work that hid under earlier compute (parallel path only).
fn next_batch(
    data: &mut WorkerData,
    cfg: &BspConfig,
    rank: usize,
    iter: usize,
    rng: &mut crate::util::Rng,
    links: &LinkParams,
    led: &mut Ledger,
) -> Result<(HostTensor, HostTensor)> {
    match data {
        WorkerData::Images { shard, loader, cache, .. } => {
            let file_idx = iter % shard.files.len();
            let labels: Vec<i32> =
                shard.labels[file_idx * shard.batch..(file_idx + 1) * shard.batch].to_vec();
            let y = HostTensor::i32(vec![cfg.batch], labels);
            match loader {
                Some(l) => {
                    // Alg. 1 protocol, generalized: requests for files
                    // i..i+Q went out before training on file i — collect
                    // i, then request i+Q so Q stay in flight.
                    let stall0 = l.stall_time;
                    let b = l.ready()?;
                    let stall = l.stall_time - stall0;
                    let next_req = iter + cfg.prefetch_depth.max(1);
                    if next_req < cfg.iters {
                        l.request(shard.files[next_req % shard.files.len()].clone());
                    }
                    led.charge(ChargeKind::LoadStall, "bsp.load", stall);
                    // child work beyond the stall hid under earlier
                    // compute: a memo, never on the clock. The H2D charge
                    // is real on this path too — it used to vanish here.
                    led.charge_hidden_load(
                        "bsp.load_hidden",
                        (b.load_time - stall).max(0.0),
                        b.load_time,
                    );
                    led.charge(ChargeKind::H2d, "bsp.h2d", b.h2d_sim);
                    Ok((b.x, y))
                }
                None => {
                    // direct path: load + preprocess + H2D all on the
                    // worker's clock, priced on the run's fabric
                    let b = crate::loader::load_one(
                        &shard.spec,
                        &shard.mean,
                        cfg.batch,
                        links,
                        rng,
                        "train",
                        &shard.files[file_idx],
                        cache.as_mut(),
                    )?;
                    led.charge(ChargeKind::LoadStall, "bsp.load", b.load_time);
                    led.charge(ChargeKind::H2d, "bsp.h2d", b.h2d_sim);
                    Ok((b.x, y))
                }
            }
        }
        WorkerData::Features { dataset } => {
            let (xs, ys) = dataset.batch(rank, cfg.workers, iter, cfg.batch);
            Ok((
                HostTensor::f32(vec![cfg.batch, dataset.dim], xs),
                HostTensor::i32(vec![cfg.batch], ys),
            ))
        }
        WorkerData::Tokens { stream, seq } => {
            // streams are indexed by iteration; no cursor state to thread
            let (xs, ys) =
                stream.lm_batch(1000 + (iter * cfg.workers + rank) as u64, 0, cfg.batch, *seq);
            let shape = vec![cfg.batch, *seq];
            Ok((HostTensor::i32(shape.clone(), xs), HostTensor::i32(shape, ys)))
        }
    }
}

fn build_eval(
    data: &WorkerData,
    info: &crate::runtime::ModelInfo,
    cfg: &BspConfig,
) -> Result<(HostTensor, HostTensor)> {
    match data {
        WorkerData::Images { dataset, .. } => {
            let (xs, ys) = dataset.eval_batch(0, info.eval_batch);
            let s = &dataset.spec;
            Ok((
                HostTensor::f32(vec![info.eval_batch, s.channels, s.crop_hw, s.crop_hw], xs),
                HostTensor::i32(vec![info.eval_batch], ys),
            ))
        }
        WorkerData::Features { dataset } => {
            let (xs, ys) = dataset.eval_batch(info.eval_batch);
            Ok((
                HostTensor::f32(vec![info.eval_batch, dataset.dim], xs),
                HostTensor::i32(vec![info.eval_batch], ys),
            ))
        }
        WorkerData::Tokens { stream, seq, .. } => {
            let (xs, ys) = stream.lm_batch(0xEAAA, 0, info.eval_batch, *seq);
            let shape = vec![info.eval_batch, *seq];
            let _ = cfg;
            Ok((HostTensor::i32(shape.clone(), xs), HostTensor::i32(shape, ys)))
        }
    }
}

fn run_eval(
    rt: &Runtime,
    eval_art: &str,
    params: &[f32],
    ex: &HostTensor,
    ey: &HostTensor,
    info: &crate::runtime::ModelInfo,
) -> Result<f64> {
    let res = rt.exec(
        eval_art,
        vec![HostTensor::f32(vec![params.len()], params.to_vec()), ex.clone(), ey.clone()],
    )?;
    let correct = res.outputs[1].scalar_i32()? as f64;
    let total = if info.kind == "lm" {
        (info.eval_batch * info.input_shape[1]) as f64
    } else {
        info.eval_batch as f64
    };
    Ok(1.0 - correct / total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::{Bytes, GbPerS, Micros};

    #[test]
    fn time_per_examples_guards_zero_denominators() {
        // degenerate runs (the NaN/inf regression): no iters, no batch, or
        // no workers processed zero examples — per-example time is 0.0
        let degenerate = [(0usize, 32usize, 4usize), (10, 0, 4), (10, 32, 0), (0, 0, 0)];
        for (iters, batch, workers) in degenerate {
            let rep = BspReport {
                iters,
                batch,
                workers,
                vtime_total: Secs(3.0),
                ..Default::default()
            };
            let t = rep.time_per_examples(5120);
            assert_eq!(t, 0.0, "iters={iters} batch={batch} workers={workers} -> {t}");
            assert!(t.is_finite());
        }
        // and the healthy path still scales linearly
        let rep = BspReport {
            iters: 10,
            batch: 32,
            workers: 4,
            vtime_total: Secs(2.0),
            ..Default::default()
        };
        assert!((rep.time_per_examples(1280) - 2.0).abs() < 1e-12);
        assert!((rep.time_per_examples(640) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_requires_subgd() {
        // the same validation run_bsp applies before spawning workers
        let mut cfg = BspConfig::quick("mlp", 2, 1);
        assert!(cfg.validate_overlap().is_ok(), "default config is valid");
        cfg.scheme = Scheme::Awagd;
        assert!(cfg.validate_overlap().is_ok(), "awagd without overlap is valid");
        for overlap in [OverlapMode::Post, OverlapMode::Wfbp] {
            cfg.plan.overlap = overlap;
            cfg.scheme = Scheme::Awagd;
            let err = cfg.validate_overlap().unwrap_err().to_string();
            assert!(
                err.contains(overlap.name()) && err.contains("subgd"),
                "error must name the mode and the constraint: {err}"
            );
            cfg.scheme = Scheme::Subgd;
            assert!(cfg.validate_overlap().is_ok());
        }
    }

    #[test]
    fn direct_path_prices_h2d_on_the_run_fabric() {
        // ISSUE 7 satellite: the direct path used to price H2D with
        // LinkParams::default() regardless of the run's fabric
        let d = Arc::new(ImageDataset::new(ImageSpec::default()));
        let tmp =
            std::env::temp_dir().join(format!("tmpi_bsp_h2d_fabric_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let mut cfg = BspConfig::quick("alexnet", 1, 2);
        cfg.batch = 4;
        let links = LinkParams {
            pcie_gbps: GbPerS(6.0),
            pcie_lat_us: Micros(25.0),
            ..LinkParams::default()
        };
        let mut data = images_data(&d, &tmp, 0, &cfg, &links).unwrap();
        let mut rng = crate::util::Rng::new(7);
        let mut led = Ledger::new();
        let (x, _y) = next_batch(&mut data, &cfg, 0, 0, &mut rng, &links, &mut led).unwrap();
        let h2d_bytes = 4 * x.as_f32().unwrap().len() as u64;
        let got = led.breakdown().h2d;
        let want = links.pcie_time(Bytes(h2d_bytes));
        assert!((got - want).abs() < 1e-15, "priced {got}, fabric says {want}");
        let default_priced = LinkParams::default().pcie_time(Bytes(h2d_bytes));
        assert!(
            (got - default_priced).abs() > 1e-9,
            "test fabric must be distinguishable from the default"
        );
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn parallel_loader_charges_h2d_like_for_like_with_direct() {
        // ISSUE 7 satellite: the parallel path used to drop simulated H2D
        // entirely (returned 0.0 as "overlapped")
        let d = Arc::new(ImageDataset::new(ImageSpec::default()));
        let tmp =
            std::env::temp_dir().join(format!("tmpi_bsp_h2d_ll_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let links = LinkParams::default();
        let mut cfg = BspConfig::quick("alexnet", 1, 3);
        cfg.batch = 4;
        cfg.prefetch_depth = 2;

        let mut led_direct = Ledger::new();
        {
            let mut data = images_data(&d, &tmp, 0, &cfg, &links).unwrap();
            let mut rng = crate::util::Rng::new(7);
            for iter in 0..cfg.iters {
                next_batch(&mut data, &cfg, 0, iter, &mut rng, &links, &mut led_direct)
                    .unwrap();
            }
        }
        cfg.use_loader = true;
        let mut led_par = Ledger::new();
        {
            let mut data = images_data(&d, &tmp, 0, &cfg, &links).unwrap();
            let mut rng = crate::util::Rng::new(7);
            for iter in 0..cfg.iters {
                next_batch(&mut data, &cfg, 0, iter, &mut rng, &links, &mut led_par).unwrap();
            }
            if let WorkerData::Images { loader: Some(l), .. } = &mut data {
                l.stop();
            }
        }
        let (bd_d, bd_p) = (led_direct.breakdown(), led_par.breakdown());
        assert!(bd_p.h2d > 0.0, "parallel path must charge H2D, not drop it");
        assert!(
            (bd_p.h2d - bd_d.h2d).abs() < 1e-15,
            "loader-vs-direct must compare like-for-like: {} vs {}",
            bd_p.h2d,
            bd_d.h2d
        );
        // the overlap win is a memo on the parallel path only
        assert!(bd_p.load_hidden >= 0.0);
        assert_eq!(bd_d.load_hidden, 0.0);
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

/// All ranks compare a parameter checksum; after every exchange the replicas
/// must hold identical values (each strategy computes rank-symmetric sums).
/// The f64 checksum travels bit-exactly as two i32 words.
fn integrity_check(comm: &mut Comm, params: &[f32], iter: usize) -> Result<()> {
    let sum: f64 = params.iter().map(|&x| x as f64).sum();
    let bits = sum.to_bits();
    if comm.rank == 0 {
        for r in 1..comm.size {
            let m = comm.recv(r, mpi::tags::CTL)?;
            let other = match m.payload {
                mpi::Payload::I32(v) if v.len() == 2 => {
                    f64::from_bits(((v[0] as u32 as u64) << 32) | v[1] as u32 as u64)
                }
                _ => return Err(anyhow!("bad integrity payload")),
            };
            let rel = (other - sum).abs() / sum.abs().max(1e-9);
            if rel > 1e-5 {
                return Err(anyhow!(
                    "integrity: rank {r} diverged at iter {iter}: {other} vs {sum}"
                ));
            }
        }
    } else {
        let words = vec![(bits >> 32) as u32 as i32, bits as u32 as i32];
        comm.send(0, mpi::tags::CTL, mpi::Payload::I32(words), 0.0)?;
    }
    Ok(())
}
