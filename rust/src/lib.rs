//! # theano-mpi-rs
//!
//! A Rust + JAX + Pallas reproduction of **Theano-MPI: a Theano-based
//! Distributed Training Framework** (He Ma, Fei Mao, Graham W. Taylor, 2016).
//!
//! Theano-MPI trains data-parallel replicas of a deep model across GPUs with
//! MPI-based parameter exchange. This crate rebuilds the whole system as a
//! three-layer stack:
//!
//! * **L3 (this crate)** — the coordination contribution: BSP engine,
//!   CUDA-aware exchange strategies (`collectives`: AR / ASA / ASA16 / Ring),
//!   asynchronous EASGD with sharded multi-server parameter queues
//!   (`easgd`, `easgd::shard`), the parallel loading pipeline (`loader`),
//!   plus every substrate the paper depends on: an MPI-style message-passing
//!   layer (`mpi`), the copper/mosaic cluster topologies (`cluster`), and an
//!   interconnect timing model (`simnet`).
//! * **L2 (python/compile)** — jax model fwd/bwd lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels: tiled matmul, the ASA
//!   summation kernel, fp16 pack/unpack, fused momentum SGD.
//!
//! The `runtime` module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) and executes them from the hot path; Python never runs at
//! request time.
//!
//! Workers are OS threads ("processes" of the paper) whose **compute time is
//! real** (measured around PJRT execution) and whose **communication time is
//! simulated** from the cluster topology (DESIGN.md §2), giving
//! deterministic, paper-faithful speedup accounting on a single-core testbed.
//!
//! ## Chunked pipelined exchange (comm/compute overlap)
//!
//! [`collectives::ChunkedPipeline`] splits the flat vector into
//! rank-segment-aligned chunks and drives any inner strategy chunk-by-chunk
//! through a software pipeline: chunk *i*'s wire transfer overlaps chunk
//! *i−1*'s summation/cast kernels. The data path stays bit-identical to the
//! monolithic exchange (alignment preserves each element's owner rank and
//! f32 reduction order) while the virtual clock prices the overlap via
//! [`simnet::pipeline_time`] — per stage `max(transfer, kernel)` instead of
//! their sum, with later chunks' per-message latency pipelined away
//! ([`simnet::PhaseCost`] keeps bandwidth and latency separable). The win
//! is reported as `CommReport::sim_overlapped` / `effective_gbps()` and is
//! enabled with `BspConfig::chunk_kib` / `--chunk-kib` (`--pipeline false`
//! is the serially-priced ablation). The EASGD server uses the same idea:
//! with chunking enabled its elastic update of chunk *i−1* overlaps chunk
//! *i*'s arrival.
//!
//! ## Wait-free backprop (`overlap = "wfbp"`)
//!
//! [`collectives::wfbp`] removes the last serialization the chunked
//! pipeline left: waiting for the *whole* backward pass before exchanging.
//! The parameter vector splits into per-layer buckets (the manifest's
//! full-scale `layers` table, a proxy model's own segments, or
//! [`models::proxy_layer_split`]; coalesced by `bucket_kib`), a documented
//! backward cost model (fc layers weigh `params`, conv layers
//! `params ×` [`collectives::wfbp::CONV_COMPUTE_REUSE`]) turns the
//! measured grad-step time × [`collectives::wfbp::BWD_FRACTION`] into
//! per-bucket gradient-ready times, and
//! [`simnet::wfbp_timeline`] — a release-gated flow shop whose implicit
//! first machine is the backward pass — prices bucket *i*'s wire time
//! hiding under layers *i−1..0*'s remaining compute. The BSP worker then
//! charges `max(backward tail, comm)` instead of `backward + comm`
//! ([`metrics::Breakdown::comm_hidden`] / `BspReport::overlap_fraction`
//! report the win; `overlap = "post"` is the serially-priced ablation).
//! The data path is untouched: any inner strategy (flat, `hier:*`, chunk-
//! pipelined) runs per bucket, bit-identical to the post-backward
//! schedule (`tests/wfbp_overlap.rs`). AlexNet is the motivating skew:
//! ~96 % of its parameters sit in fc6-8, which backprop reaches first at
//! ~8.5 % of the backward compute — nearly the whole exchange hides.
//!
//! ## Gradient-compression wires (`wire = ...`)
//!
//! Orthogonal to the exchange *schedule* is the on-wire *format*:
//! [`collectives::WireFormat`] (`f32 | f16 | bf16 | topk:<p> | onebit |
//! sf`, TOML `wire =` / `--wire`). Formats needing a codec are applied by
//! [`collectives::WireCodec`], a wrapper [`collectives::ExchangeStrategy`]
//! that composes outermost around any strategy — flat, `hier:*`,
//! chunk-pipelined, or WFBP-bucketed (the latter two drive it per slice
//! via `ExchangeCtx::slice_off` so the per-rank **error-feedback
//! residual** stays aligned: each round sends `grad + residual`, ships
//! `encode(send)`, and banks `send − decode(encode(send))` into the next
//! round — compression delays gradient mass, never drops it). `topk:<p>`
//! ships the `⌈p·n⌉` largest-|x| coordinates as `(u32, f32)` pairs;
//! `onebit` ships sign bits plus one mean-|x| scale; `sf` (sufficient
//! factors) applies only to all-fc WFBP buckets — the scheduler passes a
//! `batch·(in+out)` byte hint, dense fallback anywhere else. The codec
//! reprices the inner report against real on-wire bytes
//! ([`collectives::CommReport::wire_bytes`] vs `wire_raw_bytes`,
//! `compression_ratio()`): bandwidth terms scale by the byte ratio,
//! per-message latency stays, and the encode/decode passes are charged as
//! cast kernels (`sf` excepted — its factors fall out of the backward
//! pass). Byte counts depend only on element count, never values, so every
//! wire stays bit-identical across delivery schedules
//! (`tests/prop_wire.rs`). Sizing is wire-width-aware: `--chunk-kib` /
//! `bucket_kib` budgets are on-wire KiB via
//! [`units::Kib::elems`], fixing the old hardcoded
//! f32-width `kib·1024/4` rule that halved `asa16` chunk depth. The
//! elastic EASGD exchange ships full parameters (no gradient stream for a
//! sparsifier to ride), so `[easgd] wire` accepts dense formats only.
//!
//! ## Sharded EASGD parameter servers (`servers = S`)
//!
//! The §4 async framework's single server queues every elastic exchange;
//! at τ=1 and k=8 that queue dominates comm overhead. [`easgd::shard`]
//! splits the center variable into S rank-segment-aligned slices, one
//! server rank (own simulated GPU, own queue) per slice: workers push/pull
//! their S slices concurrently and complete at the max slice round-trip.
//! Each shard serves in deterministic virtual-arrival order, keyed
//! `max(server_clock, sent + down_wire) + handle_cost`, and the
//! per-exchange queue wait (mean/p95) plus per-shard busy fraction surface
//! in [`easgd::EasgdReport`] and [`metrics::Breakdown::comm_queue`].
//!
//! ## Hierarchical two-level exchange (`hier:<inner>`)
//!
//! [`collectives::Hierarchical`] answers the paper's §7 future work: on
//! copper every flat strategy pushes each of a node's 8 GPUs through the
//! node's single NIC. `hier` reduces switch → socket → node leader, runs
//! any flat inner strategy across node leaders only (a
//! [`mpi::Comm::push_group`] subgroup view over a
//! [`cluster::Topology::subset`]), then broadcasts back down — cutting
//! per-node NIC bytes by ~the GPUs-per-node factor
//! ([`collectives::CommReport::wire_inter_bytes`] vs `wire_intra_bytes`;
//! `sim_intra`/`sim_inter` split the time per level).
//!
//! **Strategy selection.** On mosaic (1 GPU/node) `hier` degenerates to
//! its inner — use flat ASA/ASA16. On a single copper node there is no NIC
//! to save — flat ASA wins. On copper at ≥ 2 nodes, flat ring is the best
//! *flat* choice (neighbour placement), and `hier:*` composed with
//! [`collectives::ChunkedPipeline`] beats it: each level occupies a
//! distinct serial fabric resource (switch PCIe up / host RAM + QPI / NIC /
//! switch PCIe down), so chunks stream through a flow-shop pipeline
//! ([`simnet::flow_pipeline_time`] over the per-level
//! [`simnet::Leg`]s in `CommReport::legs`) — chunk *i*'s NIC leg overlaps
//! chunk *i+1*'s intra-node tree, and the win grows with GPUs per node.
//! Monolithic (unchunked) `hier` loses to flat ring; the composition is
//! the point. Select with `exchange = "hier:asa16"` / `--exchange
//! hier:ring` plus `--chunk-kib`.
//!
//! ## Data pipeline at scale (`loader` / `data`)
//!
//! The paper's Algorithm 1 (§3.3) — a loader child process per worker that
//! overlaps disk + decode with training — generalizes here from the seed's
//! hardcoded double buffer to a **prefetch depth Q**
//! ([`loader::LoaderConfig::prefetch_depth`], `--prefetch-depth`): the
//! worker keeps Q batch requests in flight at its [`loader::ParallelLoader`]
//! child, so slack from cheap batches absorbs decode spikes a 1-deep
//! pipeline stalls on. [`data::ImageDataset::ensure_shard`] makes the
//! dataset epoch-scale: segment files are keyed by a (spec, shard)
//! [`data::fingerprint`], written once (tmp+rename, `MANIFEST` last) and
//! reused by every later run; [`data::EpochPlan`] addresses millions of
//! samples by deterministic index ranges without materializing them. A
//! [`loader::DecodeCache`] (`--cache-mib`) holds raw file bytes under LRU
//! with hit/miss/evict counters ([`loader::CacheStats`], surfaced in
//! [`loader::LoaderReport`] / `BspReport::loader`). Accounting is honest on
//! both paths: H2D staging is charged on-clock ([`audit::ChargeKind::H2d`])
//! whether or not the child overlapped the load — the PCIe crossing is
//! real either way — while the hidden disk+decode share is memo'd via
//! [`audit::Ledger::charge_hidden_load`] into
//! [`metrics::Breakdown::load_hidden`], bounded by the load it hid under.
//! The [`loader::sim`] DES twin prices the whole pipeline
//! (`bench_loader` sweeps depth × cache × k) and is mirrored exactly by
//! `scripts/pricing_model.py`, which pins every test band.
//!
//! ## Charge-conservation audit (`audit::Ledger`)
//!
//! Every correctness bug this repo has shipped was a cost-accounting bug,
//! so virtual time is now spent through exactly one API: engines call
//! [`audit::Ledger::charge`] with an [`audit::ChargeKind`] (compute,
//! comm_transfer, comm_kernel, comm_queue, comm_hidden, host_reduce, h2d,
//! load_stall, load_hidden, apply) and a source tag, and the ledger derives both the
//! clock and [`metrics::Breakdown`] from the same charge stream —
//! `breakdown == clock` holds by construction, barrier straggle included
//! (charged to `comm_queue`). [`audit::Ledger::audit`] additionally checks
//! sign/monotonicity and that WFBP's hidden time stays within the serial
//! comm it was hidden under; it is debug-asserted in every run and
//! hard-asserted in tests. `Breakdown` totals/merge/printers are generated
//! by exhaustive destructuring, so a new field cannot be silently omitted,
//! and `scripts/lint_charges.py` (CI `lint` job) rejects raw arithmetic on
//! clock/`Breakdown`/`CommReport` time fields outside `audit::` — see the
//! README for the taxonomy table, the recipe for adding a `ChargeKind`,
//! and the lint-waiver policy. `tests/race_explorer.rs` closes the loop on
//! the DES side: it drives the sharded-EASGD queue and the WFBP flow shop
//! through exhaustive delivery schedules and real-time perturbations,
//! asserting bit-identical centers/params/reports for each.
//!
//! ## Exchange planner (`plan` / `tmpi plan`)
//!
//! The knobs above — exchange strategy, wire format, `chunk_kib`,
//! `pipeline`, `overlap`, `bucket_kib`, `servers` — used to live as
//! scattered fields on `BspConfig`/`EasgdConfig`. They are now one value:
//! [`plan::ExchangePlan`], the single exchange configuration both engines
//! consume and every legacy TOML key / CLI flag parses into
//! ([`config::apply_plan_keys`]; a `[plan]` section overrides legacy
//! spellings). On top of that struct sits the planner: [`plan::search`]
//! sweeps the exchange space with the same simnet probes the benches use
//! (`coordinator::probe_exchange_wire`, `probe_wfbp`,
//! `easgd::shard::measure_sharded`) — exhaustive over the discrete axes
//! (strategy × overlap × servers), greedy with pruning over the
//! `chunk_kib`/`bucket_kib` ladders — and is guaranteed never to score
//! worse than any hand-picked default because the defaults are scored
//! first under the same objective. `tmpi plan` emits the winner as a
//! `[plan]` TOML cached under a `(model, topology, …)`
//! [`plan::PlanInputs::fingerprint`]; `tmpi train --plan auto` /
//! `tmpi easgd --plan auto` load (or rebuild) the cached plan, and
//! explicit flags still win over a loaded plan.
//! `scripts/verify_plan_bands.py` is the stdlib twin that pins
//! `bench_plan`'s scores in CI.
//!
//! ## Dimensional types (`units`)
//!
//! The pricing model's quantities carry their dimension in the type:
//! [`units::Secs`] (virtual seconds — [`units::Micros`] normalizes in),
//! [`units::Bytes`] / [`units::Kib`] / [`units::Elems`] (sizes), and
//! [`units::GbPerS`] (link bandwidth). Only dimensionally valid operators
//! exist — `Bytes / GbPerS → Secs`, `Secs + Secs`, `Kib::elems(strategy,
//! wire) → Elems` — so mixing microseconds into a seconds sum, dividing by
//! the wrong width, or truncating a byte count is a **compile error**, not
//! a band drift. Struct boundaries ([`metrics::Breakdown`],
//! [`collectives::CommReport`], [`audit::Ledger`], [`simnet::LinkParams`],
//! the engine reports) are typed; float internals are untouched, so every
//! committed baseline stays byte-identical. The one checked door from
//! `Bytes` to scaled floats is [`units::Bytes::scale_round`].
//! `scripts/lint_units.py` (CI `lint` job) keeps the boundary honest:
//! CAST-TRUNC rejects truncating float→int `as` casts outside `units::`,
//! MAP-ITER rejects hash-order iteration in modules that feed reports or
//! the priced clock, RAW-UNIT rejects new unit-suffixed raw fields.

pub mod audit;
pub mod bsp;
pub mod cluster;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod easgd;
pub mod loader;
pub mod metrics;
pub mod models;
pub mod mpi;
pub mod plan;
pub mod precision;
pub mod runtime;
pub mod sgd;
pub mod simnet;
pub mod testkit;
pub mod trace;
pub mod units;
pub mod util;

pub use coordinator::Session;
