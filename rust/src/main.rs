//! `tmpi` — the Theano-MPI-rs launcher (the paper's process-management CLI).
//!
//! ```text
//! tmpi train  [--config run.toml] [--plan auto|file.toml] [--model m] ...
//! tmpi easgd  [--config run.toml] [--plan auto|file.toml] [--alpha a] ...
//! tmpi plan   [--model m] [--batch b] [--workers k] [--topology t] [--mode bsp|easgd]
//! tmpi repro  <fig3|table1|table2|table3|fig4|fig5|easgd|easgd-grid|all> [--iters n]
//! tmpi topo   <copper|mosaic>
//! tmpi info
//! ```
//!
//! Artifacts dir defaults to ./artifacts ($TMPI_ARTIFACTS overrides);
//! reports land in ./runs.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use theano_mpi::bsp::{run_bsp, BspConfig};
use theano_mpi::collectives::{OverlapMode, StrategyKind, WireFormat};
use theano_mpi::config;
use theano_mpi::easgd::{run_easgd, EasgdConfig, Transport};
use theano_mpi::models;
use theano_mpi::plan::{self, validate_sizing_kib, ExchangePlan, PlanInputs, PlanMode};
use theano_mpi::sgd::{LrSchedule, Scheme};
use theano_mpi::Session;

/// Where `tmpi plan` / `--plan auto` cache fingerprinted plan files.
const PLAN_CACHE_DIR: &str = "runs/plans";

/// Minimal flag parser: `--key value` pairs after the subcommand.
/// Flags live in a `BTreeMap` so anything that enumerates them (errors,
/// debug dumps) comes out in one fixed order.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut positional = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = argv
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{key} needs a value"))?
                .clone();
            flags.insert(key.to_string(), val);
            i += 2;
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Args { positional, flags })
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_(&self, key: &str) -> Result<Option<usize>> {
        self.get(key).map(|v| v.parse::<usize>().map_err(|e| anyhow!("--{key}: {e}"))).transpose()
    }

    fn f64_(&self, key: &str) -> Result<Option<f64>> {
        self.get(key).map(|v| v.parse::<f64>().map_err(|e| anyhow!("--{key}: {e}"))).transpose()
    }
}

fn artifacts_dir() -> String {
    std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn session() -> Result<Session> {
    Session::new(artifacts_dir(), "runs")
}

fn apply_bsp_flags(cfg: &mut BspConfig, args: &Args) -> Result<()> {
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(k) = args.usize_("workers")? {
        cfg.workers = k;
    }
    if let Some(n) = args.usize_("iters")? {
        cfg.iters = n;
    }
    if let Some(b) = args.usize_("batch")? {
        cfg.batch = b;
    }
    if let Some(s) = args.get("scheme") {
        cfg.scheme = Scheme::parse(s).ok_or_else(|| anyhow!("bad --scheme"))?;
    }
    if let Some(s) = args.get("strategy") {
        cfg.plan.strategy = StrategyKind::from_name(s)?;
    }
    // preferred spelling; also selects hier:<inner> compositions
    if let Some(s) = args.get("exchange") {
        cfg.plan.strategy = StrategyKind::from_name(s)?;
    }
    if let Some(w) = args.get("wire") {
        cfg.plan.wire = Some(WireFormat::from_name(w)?);
    }
    if let Some(lr) = args.f64_("lr")? {
        cfg.lr = LrSchedule::Const { base: lr };
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = t.to_string();
    }
    if let Some(e) = args.usize_("eval-every")? {
        cfg.eval_every = e;
    }
    if let Some(s) = args.get("sim-model") {
        cfg.sim_model = Some(s.to_string());
    }
    if let Some(l) = args.get("loader") {
        cfg.use_loader = l == "parallel";
    }
    if let Some(q) = args.usize_("prefetch-depth")? {
        cfg.prefetch_depth = q;
    }
    if let Some(c) = args.usize_("cache-mib")? {
        cfg.cache_mib = c;
    }
    if let Some(c) = args.get("cuda-aware") {
        cfg.cuda_aware = c == "true";
    }
    if let Some(s) = args.usize_("seed")? {
        cfg.seed = s as u64;
    }
    if let Some(c) = args.usize_("chunk-kib")? {
        cfg.plan.chunk_kib = validate_sizing_kib("--chunk-kib", c)?;
    }
    if let Some(p) = args.get("pipeline") {
        cfg.plan.pipeline = match p {
            "true" => true,
            "false" => false,
            _ => bail!("bad --pipeline (true|false)"),
        };
    }
    if let Some(o) = args.get("overlap") {
        cfg.plan.overlap = OverlapMode::from_name(o)?;
    }
    if let Some(b) = args.usize_("bucket-kib")? {
        cfg.plan.bucket_kib = validate_sizing_kib("--bucket-kib", b)?;
    }
    Ok(())
}

/// The full-scale model the planner prices for a runnable config: an
/// explicit `sim_model` wins, else the proxy's full-scale counterpart,
/// else the model name itself.
fn plan_model(model: &str, sim_model: &Option<String>) -> String {
    sim_model
        .clone()
        .or_else(|| models::full_scale_of(model).map(str::to_string))
        .unwrap_or_else(|| model.to_string())
}

/// Resolve `--plan auto|<path>` into an [`ExchangePlan`]. `auto` searches
/// (or reloads) the fingerprinted cache entry under [`PLAN_CACHE_DIR`].
fn resolve_plan(
    spec: &str,
    model: String,
    batch: usize,
    workers: usize,
    topology: String,
    cuda_aware: bool,
    mode: PlanMode,
) -> Result<ExchangePlan> {
    if spec != "auto" {
        let p = plan::load_plan(std::path::Path::new(spec))?;
        println!("plan: {} (from {spec})", p.summary());
        return Ok(p);
    }
    let inputs = PlanInputs {
        model,
        // the planner needs a real batch for the backward-overlap budget;
        // 32 is the paper's common per-worker batch when none is set yet
        batch: if batch == 0 { 32 } else { batch },
        workers,
        topology,
        cuda_aware,
        mode,
    };
    let (p, path, hit) = plan::auto_plan(&inputs, std::path::Path::new(PLAN_CACHE_DIR))?;
    println!(
        "plan: {} ({} {path:?})",
        p.summary(),
        if hit { "cached" } else { "searched ->" }
    );
    Ok(p)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => config::bsp_from_file(std::path::Path::new(path))?,
        None => BspConfig::quick("mlp", 2, 50),
    };
    apply_bsp_flags(&mut cfg, args)?;
    if let Some(spec) = args.get("plan") {
        cfg.plan = resolve_plan(
            spec,
            plan_model(&cfg.model, &cfg.sim_model),
            cfg.batch,
            cfg.workers,
            cfg.topology.clone(),
            cfg.cuda_aware,
            PlanMode::Bsp,
        )?;
        // explicit exchange flags still win over the loaded plan
        apply_bsp_flags(&mut cfg, args)?;
    }
    if cfg.eval_every == 0 {
        cfg.eval_every = (cfg.iters / 10).max(1);
    }
    let sess = session()?;
    println!(
        "training {} x{} workers, {} iters, scheme={} strategy={} topo={}",
        cfg.model,
        cfg.workers,
        cfg.iters,
        cfg.scheme.name(),
        cfg.plan.strategy.name(),
        cfg.topology
    );
    let rep = run_bsp(&sess.rt, &cfg)?;
    println!(
        "done: vtime={:.2}s throughput={:.1} ex/s final_loss={:.4} final_val_err={:.3}",
        rep.vtime_total, rep.throughput, rep.final_train_loss, rep.final_val_err
    );
    // components() enumerates every Breakdown field exhaustively, so a new
    // charge kind shows up here without touching the printer
    let comps = rep
        .breakdown
        .components()
        .iter()
        .filter(|&&(name, v)| {
            v > 0.0 && !theano_mpi::metrics::Breakdown::MEMO_FIELDS.contains(&name)
        })
        .map(|&(name, v)| format!("{name}={v:.2}s"))
        .collect::<Vec<_>>()
        .join(" ");
    println!(
        "breakdown: {comps} | comm={:.2}s (kernel {:.1}%)",
        rep.breakdown.comm(),
        rep.breakdown.kernel_share_of_comm() * 100.0
    );
    if cfg.plan.overlap.bucketed() {
        println!(
            "overlap ({}): comm hidden under backward = {:.2}s, overlap_fraction = {:.1}%",
            cfg.plan.overlap.name(),
            rep.breakdown.comm_hidden,
            rep.overlap_fraction * 100.0
        );
    }
    if let Some(l) = &rep.loader {
        let path = if l.prefetch_depth == 0 {
            "direct".to_string()
        } else {
            format!("parallel q={}", l.prefetch_depth)
        };
        let mut line = format!(
            "loader ({path}): {} batches, stall={:.2}s, hidden under compute={:.2}s",
            l.batches_loaded, rep.breakdown.load_stall, rep.breakdown.load_hidden
        );
        if l.cache.capacity_bytes > 0 {
            line.push_str(&format!(
                ", cache hit-rate={:.0}% ({} hits/{} misses/{} evictions)",
                l.cache.hit_rate() * 100.0,
                l.cache.hits,
                l.cache.misses,
                l.cache.evictions
            ));
        }
        println!("{line}");
    }
    let rows: Vec<String> = rep
        .curve
        .iter()
        .map(|p| format!("{},{:.4},{:.6},{:.4}", p.iter, p.vtime, p.train_loss, p.val_err))
        .collect();
    let path = sess.write_csv("train_curve.csv", "iter,vtime_s,train_loss,val_err", &rows)?;
    println!("curve -> {path:?}");
    Ok(())
}

fn cmd_easgd(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => config::easgd_from_file(std::path::Path::new(path))?,
        None => EasgdConfig::quick("mlp", 4, 100),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(k) = args.usize_("workers")? {
        cfg.workers = k;
    }
    if let Some(n) = args.usize_("iters")? {
        cfg.iters = n;
    }
    if let Some(a) = args.f64_("alpha")? {
        cfg.alpha = a;
    }
    if let Some(t) = args.usize_("tau")? {
        cfg.tau = t;
    }
    if let Some(t) = args.get("transport") {
        cfg.transport = match t {
            "mpi" => Transport::CudaAwareMpi,
            "shm" => Transport::PlatoonShm,
            _ => bail!("bad --transport (mpi|shm)"),
        };
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = t.to_string();
    }
    // resolve --plan before the per-knob flags so explicit flags win
    if let Some(spec) = args.get("plan") {
        cfg.plan = resolve_plan(
            spec,
            plan_model(&cfg.model, &cfg.sim_model),
            cfg.batch,
            cfg.workers,
            cfg.topology.clone(),
            true,
            PlanMode::Easgd,
        )?;
    }
    if let Some(s) = args.usize_("servers")? {
        if s == 0 {
            bail!("--servers must be >= 1 (got 0)");
        }
        cfg.plan.servers = s;
    }
    if let Some(c) = args.usize_("chunk-kib")? {
        cfg.plan.chunk_kib = validate_sizing_kib("--chunk-kib", c)?;
    }
    if let Some(p) = args.get("pipeline") {
        cfg.plan.pipeline = match p {
            "true" => true,
            "false" => false,
            _ => bail!("bad --pipeline (true|false)"),
        };
    }
    if let Some(s) = args.get("exchange") {
        cfg.plan.strategy = StrategyKind::from_name(s)?;
    }
    // dense wires only: the elastic exchange ships full parameters
    if let Some(w) = args.get("wire") {
        let fmt = WireFormat::from_name(w)?;
        if fmt.compressed() {
            bail!("--wire {}: elastic exchange ships full parameters (use f32|f16|bf16)", fmt.name());
        }
        cfg.plan.wire = Some(fmt);
    }
    if cfg.eval_every == 0 {
        cfg.eval_every = (cfg.iters / 5).max(1);
    }
    let sess = session()?;
    println!(
        "easgd {} x{} workers, {} server shard(s), alpha={} tau={} transport={}",
        cfg.model,
        cfg.workers,
        cfg.plan.servers,
        cfg.alpha,
        cfg.tau,
        cfg.transport.name()
    );
    let rep = run_easgd(&sess.rt, &cfg)?;
    println!(
        "done: vtime={:.2}s throughput={:.1} ex/s comm/exchange={:.4}s final_val_err={:.3}",
        rep.vtime_total, rep.throughput, rep.comm_per_exchange, rep.final_val_err
    );
    println!(
        "queue: wait mean={:.6}s p95={:.6}s per exchange; shard busy = [{}]",
        rep.queue_wait_mean,
        rep.queue_wait_p95,
        rep.shard_busy
            .iter()
            .map(|b| format!("{:.0}%", b * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    );
    Ok(())
}

/// `tmpi plan` — search the exchange space for a model + fabric, print the
/// scored candidates, and cache the winner under its fingerprint.
fn cmd_plan(args: &Args) -> Result<()> {
    let mode = PlanMode::from_name(args.get("mode").unwrap_or("bsp"))?;
    let model = plan_model(args.get("model").unwrap_or("alexnet"), &None);
    let topology = args
        .get("topology")
        .map(str::to_string)
        .unwrap_or_else(|| models::paper_topology(&model).to_string());
    let inputs = PlanInputs {
        model,
        batch: args.usize_("batch")?.unwrap_or(32),
        workers: args.usize_("workers")?.unwrap_or(8),
        topology,
        cuda_aware: args.get("cuda-aware").map(|c| c == "true").unwrap_or(true),
        mode,
    };
    println!(
        "planning {} batch={} k={} topo={} mode={} (fingerprint {:016x})",
        inputs.model,
        inputs.batch,
        inputs.workers,
        inputs.topology,
        inputs.mode.name(),
        inputs.fingerprint()?
    );
    let choice = plan::search(&inputs)?;
    println!("scored {} candidates; hand-picked baselines:", choice.evaluated);
    for (p, s) in &choice.default_scores {
        println!("  {:<44} {:.6e} s", p.summary(), s.0);
    }
    println!("winner: {:<36} {:.6e} s", choice.plan.summary(), choice.score.0);
    println!();
    print!("{}", choice.plan.to_toml());
    let path = plan::store_plan(&inputs, &choice, std::path::Path::new(PLAN_CACHE_DIR))?;
    println!("\ncached -> {path:?} (tmpi train --plan auto picks this up)");
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| {
            anyhow!("repro needs a target: fig3|table1|table2|table3|fig4|fig5|easgd|easgd-grid|all")
        })?;
    let iters = args.usize_("iters")?;
    let sess = session()?;
    let run = |name: &str, sess: &Session| -> Result<String> {
        match name {
            "fig3" => sess.fig3(),
            "table2" => sess.table2(),
            "table3" => sess.table3(),
            "fig4" => sess.fig4(iters.unwrap_or(120)),
            "fig5" => sess.fig5(iters.unwrap_or(120)),
            "table1" => sess.table1(iters.unwrap_or(120)),
            "easgd" => sess.easgd_compare(iters.unwrap_or(60)),
            "easgd-grid" => sess.easgd_grid(iters.unwrap_or(120)),
            other => bail!("unknown repro target '{other}'"),
        }
    };
    if what == "all" {
        for name in ["table2", "fig3", "table3", "easgd", "easgd-grid", "fig4", "fig5", "table1"] {
            println!("==> {name}");
            println!("{}", run(name, &sess)?);
        }
    } else {
        println!("{}", run(what, &sess)?);
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let sess = session()?;
    println!("artifacts: {}", artifacts_dir());
    println!("models:");
    let mut names: Vec<_> = sess.rt.manifest.models.keys().collect();
    names.sort();
    for name in names {
        let m = &sess.rt.manifest.models[name];
        println!(
            "  {name:<12} kind={} params={} batches={:?}",
            m.kind,
            m.param_count,
            m.batches.keys().collect::<Vec<_>>()
        );
    }
    println!("full-scale (Table 2):");
    for name in ["alexnet", "googlenet", "vggnet"] {
        let m = &sess.rt.manifest.full_scale[name];
        println!("  {name:<12} depth={} params={}", m.depth, m.params);
    }
    println!("artifacts: {} compiled lazily from HLO text", sess.rt.manifest.artifacts.len());
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: tmpi <train|easgd|plan|repro|topo|info> [flags]\n\
         \n\
         tmpi train --model mlp --workers 4 --iters 100 --exchange asa --scheme subgd\n\
         tmpi train --model mlp --workers 8 --chunk-kib 256 --pipeline true\n\
         tmpi train --model alexnet --workers 8 --overlap wfbp --bucket-kib 4096 --topology copper\n\
         tmpi train --model mlp --workers 16 --topology copper --exchange hier:asa16\n\
         tmpi train --model alexnet --workers 8 --wire topk:0.01 --overlap wfbp  # f32|f16|bf16|topk:<p>|onebit|sf\n\
         tmpi train --model alexnet --loader parallel --prefetch-depth 4 --cache-mib 64\n\
         tmpi train --config examples/configs/alexnet_bsp.toml\n\
         tmpi easgd --model mlp --workers 4 --alpha 0.5 --tau 1 --transport mpi\n\
         tmpi easgd --model mlp --workers 8 --tau 1 --servers 4 --topology copper\n\
         tmpi plan --model alexnet --batch 128 --workers 8 --topology mosaic  # search + cache\n\
         tmpi plan --model googlenet --workers 4 --mode easgd\n\
         tmpi train --model alexnet --workers 8 --plan auto      # cached/searched plan\n\
         tmpi train --config run.toml --plan runs/plans/alexnet-mosaic-k8-0123456789abcdef.toml\n\
         tmpi repro <fig3|table1|table2|table3|fig4|fig5|easgd|easgd-grid|all> [--iters n]\n\
         tmpi topo <copper|mosaic>\n\
         tmpi info"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else { usage() };
    let args = parse_args(&argv[1..])?;
    match cmd {
        "train" => cmd_train(&args),
        "easgd" => cmd_easgd(&args),
        "plan" => cmd_plan(&args),
        "repro" => cmd_repro(&args),
        "topo" => {
            let name = args.positional.first().map(|s| s.as_str()).unwrap_or("copper");
            let sess = session()?;
            println!("{}", sess.topo(name)?);
            Ok(())
        }
        "info" => cmd_info(),
        _ => usage(),
    }
}
