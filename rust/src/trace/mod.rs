//! Chrome-tracing export: worker timelines for `chrome://tracing`.
//!
//! Collects `(worker, name, start, duration)` spans on the **virtual**
//! clock — compute / barrier-wait / exchange per superstep — and writes the
//! Trace Event Format JSON. Handy for seeing the BSP straggler structure
//! and the comm/compute overlap at a glance.

use std::fs;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::util::json::{arr, num, obj, s, Json};

/// One span on a worker's virtual timeline.
#[derive(Clone, Debug)]
pub struct Span {
    pub worker: usize,
    pub name: String,
    /// virtual seconds
    pub start: f64,
    pub dur: f64,
}

/// Thread-safe span collector.
#[derive(Default)]
pub struct Trace {
    spans: Mutex<Vec<Span>>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn record(&self, worker: usize, name: &str, start: f64, dur: f64) {
        self.spans
            .lock()
            .unwrap()
            .push(Span { worker, name: name.to_string(), start, dur });
    }

    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize to Trace Event Format (microsecond timestamps).
    pub fn to_json(&self) -> Json {
        let spans = self.spans.lock().unwrap();
        let events: Vec<Json> = spans
            .iter()
            .map(|sp| {
                obj(vec![
                    ("name", s(&sp.name)),
                    ("cat", s("bsp")),
                    ("ph", s("X")),
                    ("ts", num(sp.start * 1e6)),
                    ("dur", num(sp.dur * 1e6)),
                    ("pid", num(0.0)),
                    ("tid", num(sp.worker as f64)),
                ])
            })
            .collect();
        obj(vec![("traceEvents", arr(events)), ("displayTimeUnit", s("ms"))])
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_serializes() {
        let t = Trace::new();
        t.record(0, "compute", 0.0, 0.5);
        t.record(1, "exchange", 0.5, 0.1);
        assert_eq!(t.len(), 2);
        let j = t.to_json().to_string();
        assert!(j.contains("\"traceEvents\""));
        assert!(j.contains("\"compute\""));
        // parses back
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("traceEvents").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn writes_file() {
        let t = Trace::new();
        t.record(0, "x", 0.0, 1.0);
        let p = std::env::temp_dir().join(format!("tmpi_trace_{}.json", std::process::id()));
        t.write(&p).unwrap();
        assert!(p.exists());
        let _ = std::fs::remove_file(p);
    }
}
