//! Sharded EASGD parameter server — S independent shard queues.
//!
//! The paper's §4 framework serializes every elastic exchange through one
//! parameter server; at τ=1 and k=8 the server queue dominates comm
//! overhead. PS-based frameworks scale past this contention by sharding the
//! center variable across server processes (the regime Shi et al.,
//! arXiv:1711.05979, model): here the center is split into `servers`
//! rank-segment-aligned slices (`split_even`, the same MPI_Scatterv
//! convention the collectives use), one server rank per slice on its own
//! simulated GPU. A worker pushes its S slices concurrently — with a
//! round-robin start offset so the k simultaneous *real* sends spread
//! over the shard channels instead of all copying into shard 0's channel
//! first; the *virtual* pricing is provably independent of send order
//! (arrival-ordered serving, see below, and the determinism test) — each
//! shard runs its own `server_clock` queue with the existing
//! handling-cost model, and the worker's exchange completes at the max
//! over its slice round-trips.
//!
//! **Arrival-ordered, deterministic queueing.** Each shard serves pushes in
//! *virtual arrival* order (`arrival = sent_clock + down_wire`), keying the
//! queue as `server_clock = max(server_clock, arrival) + handle_cost`. Real
//! thread scheduling must not leak into the virtual clock, so the server is
//! conservative: it serves the earliest-arrival pending push only once no
//! headless live worker could still produce an earlier one. A worker's next
//! arrival is bounded below by `last_finish + up + down` (its previous push
//! here was replied at `last_finish`, and the reply plus the next push must
//! cross the wire), so the shard blocks for that worker's message only when
//! the bound does not exceed the candidate arrival. With `split_even`
//! slices the bound clears the globally-earliest pending arrival by ~3
//! wire legs plus a handling cost, which keeps the serve loop deadlock-free
//! (the proof needs near-equal slices: a worker's outstanding push to
//! *another* shard prices the same bytes ±1 element).
//!
//! Queue-wait observability: a worker derives, per exchange, the wait of
//! the *binding* slice (the one that completed last) as
//! `finish − arrival − handle` — both sides compute from one shared
//! [`ShardPrices`], so no metadata rides the wire.

use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, bail, Result};

use crate::cluster::Topology;
use crate::mpi::{self, tags, Comm, Msg, Payload};
use crate::precision::Wire;
use crate::simnet::LinkParams;
use crate::units::Secs;
use crate::util::split_even;

use super::EasgdConfig;

/// How the center variable maps onto worker and server ranks: ranks
/// `0..workers` are workers, rank `workers + j` serves slice `j`.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub workers: usize,
    pub servers: usize,
    /// (offset, len) of each shard's slice of the flat center vector —
    /// `split_even(elems, servers)`, remainder on the lowest shards.
    pub slices: Vec<(usize, usize)>,
}

impl ShardPlan {
    pub fn new(elems: usize, workers: usize, servers: usize) -> Result<ShardPlan> {
        if workers == 0 {
            bail!("easgd needs at least one worker");
        }
        if servers == 0 {
            bail!("servers must be >= 1 (got 0)");
        }
        if servers > elems.max(1) {
            bail!("servers = {servers} exceeds the {elems}-element center variable");
        }
        Ok(ShardPlan { workers, servers, slices: split_even(elems, servers) })
    }

    /// Workers plus one rank (and one simulated GPU) per shard.
    pub fn world_size(&self) -> usize {
        self.workers + self.servers
    }

    /// Global rank (= simulated GPU id) of shard `j`'s server.
    pub fn server_rank(&self, shard: usize) -> usize {
        self.workers + shard
    }
}

/// Simulated prices of one elastic exchange, per (shard, worker) pair.
/// Worker and server threads share one instance so the queue keying and the
/// worker-derived queue wait agree exactly.
#[derive(Clone, Debug)]
pub struct ShardPrices {
    /// `wire_half[shard][worker]`: scaled one-way wire time of the shard's
    /// slice on this worker's path (down and up legs are symmetric).
    pub wire_half: Vec<Vec<f64>>,
    /// `handle[shard][worker]`: scaled server occupancy per push (elastic
    /// update, chunk-pipelined under the incoming stream when configured).
    pub handle: Vec<Vec<f64>>,
    /// Packed wire of the exchange (`None` = full-width f32). Shared here
    /// so worker packing and server unpacking agree without metadata on
    /// the wire.
    pub wire: Option<Wire>,
}

impl ShardPrices {
    pub fn new(
        cfg: &EasgdConfig,
        topo: &Topology,
        links: &LinkParams,
        plan: &ShardPlan,
        comm_scale: f64,
    ) -> ShardPrices {
        let wire = cfg.elastic_wire();
        let mut wire_half = Vec::with_capacity(plan.servers);
        let mut handle = Vec::with_capacity(plan.servers);
        for (j, &(_, len)) in plan.slices.iter().enumerate() {
            // a packed wire shrinks what moves, not the f32 elastic update
            let full_bytes = 4 * len as u64;
            // both packed wires (f16/bf16) move 2 bytes per element
            let wire_bytes = if wire.is_some() { 2 * len as u64 } else { full_bytes };
            let mut w_row = Vec::with_capacity(plan.workers);
            let mut h_row = Vec::with_capacity(plan.workers);
            for w in 0..plan.workers {
                let rt = super::exchange_cost(
                    cfg.transport,
                    topo,
                    links,
                    w,
                    plan.server_rank(j),
                    wire_bytes,
                );
                w_row.push(rt / 2.0 * comm_scale);
                let handle = super::server_handle_cost(cfg, links, full_bytes, rt / 2.0);
                h_row.push(handle * comm_scale);
            }
            wire_half.push(w_row);
            handle.push(h_row);
        }
        ShardPrices { wire_half, handle, wire }
    }
}

/// What one shard server reports when every worker has stopped.
#[derive(Clone, Debug)]
pub struct ServerOut {
    pub shard: usize,
    /// Final center slice (real values — the data path is exercised).
    pub center: Vec<f32>,
    /// Worker ranks in virtual-arrival serve order (the order the serial
    /// host reference of the differential suite replays).
    pub served: Vec<usize>,
    /// Total handling occupancy charged to this shard's queue.
    pub busy: f64,
    /// Final shard clock; `busy / clock_end` is the shard's busy fraction.
    pub clock_end: f64,
}

/// One worker-side elastic exchange's timing result.
#[derive(Clone, Copy, Debug)]
pub struct ExchangeTiming {
    /// Worker clock after the exchange: max over slice round-trips.
    pub new_clock: Secs,
    /// `new_clock - clock` — what `comm_per_exchange` aggregates.
    pub t_comm: Secs,
    /// Queue wait of the binding slice (the round-trip that completed
    /// last): `finish − arrival − handle`, the wait that actually extended
    /// this exchange. `t_comm − queue_wait` is pure wire + handling.
    pub queue_wait: Secs,
}

/// First half of [`worker_exchange`]: send all S slice pushes without
/// blocking. Public (with [`worker_collect`]) so the race explorer can
/// interpose a delivery-schedule gate between the sends and the replies.
pub fn worker_push(
    comm: &mut Comm,
    rank: usize,
    plan: &ShardPlan,
    wire: Option<Wire>,
    params: &[f32],
    clock: Secs,
) -> Result<()> {
    let s = plan.servers;
    for i in 0..s {
        let j = (rank + i) % s;
        let (lo, len) = plan.slices[j];
        let slice = &params[lo..lo + len];
        let payload = match wire {
            Some(w) => {
                let mut bits = Vec::new();
                w.pack(slice, &mut bits);
                Payload::U16(bits)
            }
            None => Payload::F32(slice.to_vec()),
        };
        comm.send(plan.server_rank(j), tags::EASGD_PUSH, payload, clock.0)?;
    }
    Ok(())
}

/// Second half of [`worker_exchange`]: receive every shard's center reply,
/// apply the elastic update in place, and price the exchange at the max
/// over slice round-trips.
#[allow(clippy::too_many_arguments)]
pub fn worker_collect(
    comm: &mut Comm,
    rank: usize,
    plan: &ShardPlan,
    prices: &ShardPrices,
    alpha: f32,
    params: &mut [f32],
    clock: Secs,
) -> Result<ExchangeTiming> {
    let s = plan.servers;
    let Secs(clock) = clock;
    let mut new_clock = clock;
    let mut queue_wait = 0.0;
    for j in 0..s {
        let m = comm.recv(plan.server_rank(j), tags::EASGD_PULL)?;
        let center = match m.payload {
            Payload::U16(bits) => {
                let mut vals = Vec::new();
                prices.wire.unwrap_or(Wire::F16).unpack(&bits, &mut vals);
                vals
            }
            other => other.into_f32()?,
        };
        let (lo, len) = plan.slices[j];
        for (w, c) in params[lo..lo + len].iter_mut().zip(&center) {
            *w -= alpha * (*w - c);
        }
        let finish = m.sent_clock;
        let done = finish + prices.wire_half[j][rank];
        if done > new_clock {
            new_clock = done;
            queue_wait =
                (finish - (clock + prices.wire_half[j][rank]) - prices.handle[j][rank]).max(0.0);
        }
    }
    Ok(ExchangeTiming {
        new_clock: Secs(new_clock),
        t_comm: Secs(new_clock - clock),
        queue_wait: Secs(queue_wait),
    })
}

/// Push all S slices of `params`, pull the S center slices back, apply
/// the elastic update in place, and price the exchange at the max over
/// slice round-trips. The round-robin start offset only staggers the
/// *real* channel copies; virtual arrival times carry the send clock, so
/// the priced queueing is independent of the physical send order.
#[allow(clippy::too_many_arguments)]
pub fn worker_exchange(
    comm: &mut Comm,
    rank: usize,
    plan: &ShardPlan,
    prices: &ShardPrices,
    alpha: f32,
    params: &mut [f32],
    clock: Secs,
) -> Result<ExchangeTiming> {
    worker_push(comm, rank, plan, prices.wire, params, clock)?;
    worker_collect(comm, rank, plan, prices, alpha, params, clock)
}

/// Serve one shard until every worker has sent its stop control. See the
/// module docs for the conservative arrival-ordered queue discipline.
pub fn server_shard_main(
    comm: &mut Comm,
    plan: &ShardPlan,
    shard: usize,
    prices: &ShardPrices,
    alpha: f32,
    mut center: Vec<f32>,
) -> Result<ServerOut> {
    let k = plan.workers;
    // the typed serve-queue clock: max(clock, arrival) + handle per push,
    // with occupancy tracked for the busy-fraction report
    let mut queue = crate::audit::ServerClock::new();
    let mut served = Vec::new();
    // one pending push per worker (workers block on their replies, so at
    // most one is outstanding), plus the liveness bound per worker
    let mut heads: Vec<Option<Msg>> = (0..k).map(|_| None).collect();
    let mut alive = vec![true; k];
    let mut last_finish = vec![f64::NEG_INFINITY; k];
    loop {
        let pick = loop {
            // earliest pending virtual arrival (ties: lowest worker rank)
            let mut best: Option<(f64, usize)> = None;
            for (w, h) in heads.iter().enumerate() {
                if let Some(m) = h {
                    let arrival = m.sent_clock + prices.wire_half[shard][w];
                    let better = match best {
                        Some((a, _)) => arrival < a,
                        None => true,
                    };
                    if better {
                        best = Some((arrival, w));
                    }
                }
            }
            if let Some((arrival, w)) = best {
                // safe only if no headless live worker can still arrive
                // earlier (or tie): its next arrival is ≥ last reply time
                // plus the up and down legs
                let safe = (0..k).all(|v| {
                    v == w
                        || heads[v].is_some()
                        || !alive[v]
                        || last_finish[v]
                            + prices.wire_half[shard][v]
                            + prices.wire_half[shard][v]
                            > arrival
                });
                if safe {
                    break Some((arrival, w));
                }
            } else if alive.iter().all(|a| !a) {
                break None;
            }
            let m = comm.recv_any_of(&[tags::EASGD_PUSH, tags::CTL])?;
            let from = m.from;
            debug_assert!(from < k, "shard server heard from rank {from}");
            match m.payload {
                Payload::Ctl(_) => alive[from] = false,
                _ => heads[from] = Some(m),
            }
        };
        let Some((arrival, w)) = pick else { break };
        let m = heads[w].take().unwrap();
        let wire = prices.wire.unwrap_or(Wire::F16);
        let (wvals, packed) = match m.payload {
            Payload::F32(v) => (v, false),
            Payload::U16(bits) => {
                let mut vals = Vec::new();
                wire.unpack(&bits, &mut vals);
                (vals, true)
            }
            _ => return Err(anyhow!("unexpected payload at shard server")),
        };
        // queueing: handling starts when both shard and message are ready
        let finish = queue.serve(Secs(arrival), Secs(prices.handle[shard][w])).0;
        last_finish[w] = finish;
        // reply with the center as seen by this worker (pre-update)
        let reply = if packed {
            let mut bits = Vec::new();
            wire.pack(&center, &mut bits);
            Payload::U16(bits)
        } else {
            Payload::F32(center.clone())
        };
        comm.send(w, tags::EASGD_PULL, reply, finish)?;
        for (c, wi) in center.iter_mut().zip(&wvals) {
            *c += alpha * (wi - *c);
        }
        served.push(w);
    }
    debug_assert!(queue.audit().is_ok(), "{:?}", queue.audit());
    Ok(ServerOut { shard, center, served, busy: queue.busy().0, clock_end: queue.clock().0 })
}

/// Aggregate result of a [`measure_sharded`] probe.
#[derive(Clone, Debug, Default)]
pub struct ShardProbe {
    pub comm_total: f64,
    pub comm_per_exchange: f64,
    /// Binding-slice queue wait per exchange, workers in rank order.
    pub queue_waits: Vec<f64>,
    pub queue_wait_mean: f64,
    pub queue_wait_p95: f64,
    /// Per-shard `busy / clock_end`.
    pub shard_busy: Vec<f64>,
    /// Final center slices by shard.
    pub centers: Vec<Vec<f32>>,
    /// Per-shard serve order (worker ranks).
    pub served: Vec<Vec<usize>>,
    /// Final worker parameter vectors in rank order.
    pub final_params: Vec<Vec<f32>>,
    /// Per-worker virtual clocks in rank order (ledger-derived).
    pub worker_clocks: Vec<f64>,
    /// Per-worker time decompositions in rank order — each reconciles with
    /// its `worker_clocks` entry by construction (`audit::Ledger`).
    pub breakdowns: Vec<crate::metrics::Breakdown>,
    /// Max worker clock.
    pub vtime: f64,
}

/// Deterministic synthetic worker parameters for probes and their serial
/// reference replays.
pub fn probe_params(rank: usize, elems: usize) -> Vec<f32> {
    (0..elems).map(|i| ((rank * 31 + i * 7) % 997) as f32 * 1e-3).collect()
}

/// Deterministic synthetic initial center for probes and replays.
pub fn probe_center(elems: usize) -> Vec<f32> {
    (0..elems).map(|i| (i % 13) as f32 * 0.01).collect()
}

/// Comm-only contention probe: `cfg.workers` workers exchange an
/// `elems`-element vector against `cfg.plan.servers` shard queues every round,
/// advancing their clocks by `compute_s` between exchanges — the EASGD
/// queueing model without a `Runtime` (benches and the differential suite
/// run this without artifacts). Real buffers move; τ is effectively 1.
pub fn measure_sharded(
    cfg: &EasgdConfig,
    elems: usize,
    rounds: usize,
    compute_s: f64,
    comm_scale: f64,
) -> Result<ShardProbe> {
    let plan = Arc::new(ShardPlan::new(elems, cfg.workers, cfg.plan.servers)?);
    let topo = Topology::by_name(&cfg.topology, plan.world_size())
        .ok_or_else(|| anyhow!("unknown topology '{}'", cfg.topology))?;
    let links = LinkParams::default();
    let prices = Arc::new(ShardPrices::new(cfg, &topo, &links, &plan, comm_scale));
    let alpha = cfg.alpha as f32;

    enum Out {
        Worker {
            comm_time: f64,
            waits: Vec<f64>,
            clock: f64,
            breakdown: crate::metrics::Breakdown,
            params: Vec<f32>,
        },
        Server(ServerOut),
    }

    let world = mpi::world(plan.world_size());
    let mut handles = Vec::new();
    for (rank, comm) in world.into_iter().enumerate() {
        let plan = plan.clone();
        let prices = prices.clone();
        handles.push(thread::spawn(move || -> Result<Out> {
            let mut comm = comm;
            if rank >= plan.workers {
                let shard = rank - plan.workers;
                let (lo, len) = plan.slices[shard];
                let init = probe_center(elems)[lo..lo + len].to_vec();
                let out = server_shard_main(&mut comm, &plan, shard, &prices, alpha, init)?;
                Ok(Out::Server(out))
            } else {
                use crate::audit::{ChargeKind, Ledger};
                let mut params = probe_params(rank, elems);
                let mut led = Ledger::new();
                let mut comm_time = 0.0f64;
                let mut waits = Vec::with_capacity(rounds);
                for _ in 0..rounds {
                    led.charge(ChargeKind::Compute, "probe.compute", Secs(compute_s));
                    let t = worker_exchange(
                        &mut comm,
                        rank,
                        &plan,
                        &prices,
                        alpha,
                        &mut params,
                        led.clock(),
                    )?;
                    // queue wait split out, then land exactly on the priced
                    // completion time (virtual arrivals downstream are
                    // bit-sensitive to this clock)
                    led.charge(ChargeKind::CommQueue, "probe.queue", t.queue_wait);
                    led.advance_to(ChargeKind::CommTransfer, "probe.exchange", t.new_clock);
                    comm_time += t.t_comm.0;
                    waits.push(t.queue_wait.0);
                }
                for j in 0..plan.servers {
                    comm.send(
                        plan.server_rank(j),
                        tags::CTL,
                        Payload::Ctl("stop".into()),
                        led.clock().0,
                    )?;
                }
                let (clock, breakdown) = led.finish();
                Ok(Out::Worker { comm_time, waits, clock: clock.0, breakdown, params })
            }
        }));
    }

    let mut probe = ShardProbe {
        shard_busy: vec![0.0; plan.servers],
        centers: vec![Vec::new(); plan.servers],
        served: vec![Vec::new(); plan.servers],
        ..Default::default()
    };
    let mut exchanges = 0usize;
    for h in handles {
        match h.join().map_err(|_| anyhow!("sharded probe thread panicked"))?? {
            Out::Worker { comm_time, waits, clock, breakdown, params } => {
                probe.comm_total += comm_time;
                exchanges += waits.len();
                probe.queue_waits.extend(waits);
                probe.vtime = probe.vtime.max(clock);
                probe.worker_clocks.push(clock);
                probe.breakdowns.push(breakdown);
                probe.final_params.push(params);
            }
            Out::Server(s) => {
                probe.shard_busy[s.shard] =
                    if s.clock_end > 0.0 { s.busy / s.clock_end } else { 0.0 };
                probe.centers[s.shard] = s.center;
                probe.served[s.shard] = s.served;
            }
        }
    }
    probe.comm_per_exchange = probe.comm_total / exchanges.max(1) as f64;
    probe.queue_wait_mean = crate::util::mean(&probe.queue_waits);
    probe.queue_wait_p95 = crate::util::quantile(&probe.queue_waits, 0.95);
    Ok(probe)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::StrategyKind;

    #[test]
    fn plan_slices_cover_and_validate() {
        let p = ShardPlan::new(10, 2, 3).unwrap();
        assert_eq!(p.slices, vec![(0, 4), (4, 3), (7, 3)]);
        assert_eq!(p.world_size(), 5);
        assert_eq!(p.server_rank(2), 4);
        assert!(ShardPlan::new(10, 0, 1).is_err());
        let err = ShardPlan::new(10, 4, 0).unwrap_err().to_string();
        assert!(err.contains("servers"), "{err}");
        let err = ShardPlan::new(10, 4, 11).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn prices_scale_with_slice_bytes_and_wire_format() {
        let mut cfg = EasgdConfig::quick("mlp", 4, 1);
        cfg.plan.servers = 2;
        cfg.topology = "mosaic".into();
        let plan = ShardPlan::new(1 << 20, 4, 2).unwrap();
        let topo = Topology::by_name("mosaic", plan.world_size()).unwrap();
        let links = LinkParams::default();
        let f32p = ShardPrices::new(&cfg, &topo, &links, &plan, 1.0);
        assert_eq!(f32p.wire, None);
        cfg.plan.strategy = StrategyKind::Asa16;
        let f16p = ShardPrices::new(&cfg, &topo, &links, &plan, 1.0);
        assert_eq!(f16p.wire, Some(Wire::F16));
        for j in 0..2 {
            for w in 0..4 {
                assert!(f32p.wire_half[j][w] > 0.0);
                assert!(f16p.wire_half[j][w] < f32p.wire_half[j][w], "f16 wire must shrink");
                // the elastic update stays f32 regardless of the wire
                assert_eq!(f16p.handle[j][w], f32p.handle[j][w]);
            }
        }
        // an explicit dense override wins over the strategy-derived default
        cfg.plan.wire = Some(crate::collectives::WireFormat::F32);
        let forced = ShardPrices::new(&cfg, &topo, &links, &plan, 1.0);
        assert_eq!(forced.wire, None);
        assert_eq!(forced.wire_half[0][0], f32p.wire_half[0][0]);
        cfg.plan.strategy = StrategyKind::Asa;
        cfg.plan.wire = Some(crate::collectives::WireFormat::Bf16);
        let bf = ShardPrices::new(&cfg, &topo, &links, &plan, 1.0);
        assert_eq!(bf.wire, Some(Wire::Bf16));
        assert_eq!(bf.wire_half[0][0], f16p.wire_half[0][0]);
        // comm_scale stretches both wire and handling linearly
        let scaled = ShardPrices::new(&cfg, &topo, &links, &plan, 3.0);
        assert!((scaled.handle[0][0] - 3.0 * f16p.handle[0][0]).abs() < 1e-15);
        assert!((scaled.wire_half[0][0] - 3.0 * f16p.wire_half[0][0]).abs() < 1e-15);
    }

    #[test]
    fn chunk_pipelining_shrinks_handle_per_shard() {
        let mut cfg = EasgdConfig::quick("mlp", 2, 1);
        cfg.plan.servers = 2;
        let plan = ShardPlan::new(2 << 20, 2, 2).unwrap(); // 4 MiB slices
        let topo = Topology::by_name("mosaic", plan.world_size()).unwrap();
        let links = LinkParams::default();
        let mono = ShardPrices::new(&cfg, &topo, &links, &plan, 1.0);
        cfg.plan.chunk_kib = 256;
        cfg.plan.pipeline = true;
        let piped = ShardPrices::new(&cfg, &topo, &links, &plan, 1.0);
        assert!(piped.handle[0][0] < mono.handle[0][0]);
        assert_eq!(piped.wire_half[0][0], mono.wire_half[0][0]);
    }
}
