//! Asynchronous EASGD — the paper's §4 async framework.
//!
//! Re-implements elastic averaging SGD [25] the way Theano-MPI did: a
//! parameter **server** holds the center variable; each worker runs local
//! momentum-SGD steps and, every τ iterations, performs an elastic exchange
//! with the server over CUDA-aware `MPI_SendRecv` (no Round-Robin):
//!
//! ```text
//! worker:  send w  ──►  server: c += α (w − c)   (uses c before update)
//!          w −= α (w − c_recv)   ◄── reply c
//! ```
//!
//! Two transports reproduce the paper's comparison:
//! * [`Transport::CudaAwareMpi`] — device-to-device SendRecv priced by the
//!   simnet path between the worker's and server's GPUs.
//! * [`Transport::PlatoonShm`] — the Platoon baseline: posix-shm style
//!   host-staged exchange (D2H + two host copies through a lock-guarded
//!   shared segment + H2D), the path the paper beats by 42 % at τ=1.
//!
//! The server serializes exchanges (real queueing): each request is
//! handled at `max(server_clock, arrival)` plus a handling cost — keyed on
//! the message's *arrival* (`sent + down_wire`), served in deterministic
//! virtual-arrival order — so comm overhead includes genuine contention
//! when τ is small and k large. `servers = S` splits the center variable
//! across S independent shard queues ([`shard`]), the scale-out that
//! collapses that contention; the per-exchange queue wait and per-shard
//! busy fraction surface in [`EasgdReport`].

pub mod shard;

use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::cluster::Topology;
use crate::collectives::{StrategyKind, WireFormat};
use crate::data::{FeatureDataset, ImageDataset, ImageSpec};
use crate::metrics::Breakdown;
use crate::models;
use crate::mpi::{self, tags, Payload};
use crate::plan::ExchangePlan;
use crate::precision::Wire;
use crate::runtime::{HostTensor, Runtime};
use crate::sgd::LrSchedule;
use crate::simnet::{phase_time, LinkParams, Transfer};
use crate::units::{Bytes, Secs};

use shard::{ShardPlan, ShardPrices};

/// How worker↔server bytes move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    CudaAwareMpi,
    PlatoonShm,
}

impl Transport {
    pub fn name(self) -> &'static str {
        match self {
            Transport::CudaAwareMpi => "cuda-aware-mpi",
            Transport::PlatoonShm => "platoon-shm",
        }
    }
}

#[derive(Clone, Debug)]
pub struct EasgdConfig {
    pub model: String,
    pub workers: usize,
    pub batch: usize,
    /// moving rate α (paper grid-searches, best 0.5)
    pub alpha: f64,
    /// averaging period τ (exchange every τ local iters; paper best τ=1)
    pub tau: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    /// local iterations per worker
    pub iters: usize,
    pub eval_every: usize,
    pub topology: String,
    pub transport: Transport,
    pub seed: u64,
    /// scale exchange time to a full-scale model (like BSP's sim_model)
    pub sim_model: Option<String>,
    /// Every exchange-shaping knob in one [`ExchangePlan`]. `plan.strategy`
    /// is the wire-format *driver* here (EASGD's exchange is worker↔server
    /// point-to-point, so only the name's wire matters: an asa16-family
    /// strategy moves w/c as f16 halves); `plan.wire` is the explicit dense
    /// override (compressed formats are rejected at the config/CLI layer —
    /// the elastic exchange ships full parameters, not gradients, so there
    /// is no error-feedback stream for a sparsifier to ride on);
    /// `plan.servers` shards the center variable into rank-segment-aligned
    /// slices, one server rank and one request queue per slice. BSP-only
    /// axes (`overlap`, `bucket_kib`) are ignored.
    pub plan: ExchangePlan,
}

impl EasgdConfig {
    pub fn quick(model: &str, workers: usize, iters: usize) -> EasgdConfig {
        EasgdConfig {
            model: model.to_string(),
            workers,
            batch: 0,
            alpha: 0.5,
            tau: 1,
            lr: LrSchedule::Const { base: 0.01 },
            momentum: 0.9,
            iters,
            eval_every: 0,
            topology: "mosaic".to_string(),
            transport: Transport::CudaAwareMpi,
            seed: 42,
            sim_model: None,
            plan: ExchangePlan::default(),
        }
    }

    /// Resolve the packed wire of the elastic exchange: an explicit dense
    /// `wire` override wins; otherwise an asa16-family `exchange` implies
    /// f16. `None` means full-width f32 (no packing).
    pub fn elastic_wire(&self) -> Option<Wire> {
        match self.plan.wire {
            Some(WireFormat::F32) => None,
            Some(WireFormat::F16) => Some(Wire::F16),
            Some(WireFormat::Bf16) => Some(Wire::Bf16),
            // config/CLI reject compressed formats here; treat any that
            // slip through as full-width rather than corrupt the payload
            Some(_) => None,
            None => self.plan.strategy.half_wire().then_some(Wire::F16),
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct EasgdReport {
    pub workers: usize,
    pub iters: usize,
    pub tau: usize,
    pub alpha: f64,
    /// parameter-server shards the center variable was split across
    pub servers: usize,
    /// max worker virtual clock
    pub vtime_total: Secs,
    /// mean per-worker comm overhead per exchange (sim seconds)
    pub comm_per_exchange: Secs,
    /// total comm overhead summed across workers
    pub comm_total: Secs,
    /// mean per-exchange queue wait (binding slice; sim seconds)
    pub queue_wait_mean: Secs,
    /// p95 per-exchange queue wait across all workers' exchanges
    pub queue_wait_p95: Secs,
    /// per-shard `busy / clock_end` — how loaded each server queue ran
    pub shard_busy: Vec<f64>,
    pub breakdown: Breakdown,
    pub throughput: f64,
    pub final_val_err: f64,
    pub curve: Vec<(usize, f64, f64)>, // (iter, vtime, val_err)
}

/// Price one worker↔server round trip (w down, c back) on the transport.
fn exchange_cost(
    transport: Transport,
    topo: &Topology,
    links: &LinkParams,
    worker_gpu: usize,
    server_gpu: usize,
    bytes: u64,
) -> f64 {
    match transport {
        Transport::CudaAwareMpi => {
            let down = phase_time(
                topo,
                links,
                &[Transfer { src: worker_gpu, dst: server_gpu, bytes: Bytes(bytes) }],
                true,
            );
            let up = phase_time(
                topo,
                links,
                &[Transfer { src: server_gpu, dst: worker_gpu, bytes: Bytes(bytes) }],
                true,
            );
            down.0 + up.0
        }
        Transport::PlatoonShm => {
            // posix_ipc shared memory on one node: D2H, copy into the shm
            // segment, copy out, H2D — each way — plus GIL-ish serialization
            // handled by the server queue.
            let pcie = links.pcie_time(Bytes(bytes)).0;
            let shm_copy = bytes as f64 / (links.host_mem_gbps.0 * 1e9);
            2.0 * (pcie + 2.0 * shm_copy + pcie)
        }
    }
}

/// Server-side handling cost per request (elastic update on c).
fn server_update_cost(transport: Transport, links: &LinkParams, bytes: u64) -> f64 {
    match transport {
        // server applies c += α(w−c) on GPU
        Transport::CudaAwareMpi => links.gpu_reduce_time(Bytes(2 * bytes)).0,
        // Platoon's server updates on host under the GIL
        Transport::PlatoonShm => links.host_reduce_time(Bytes(2 * bytes)).0,
    }
}

/// Server occupancy per request when the exchange streams in `chunk_kib`
/// chunks: the elastic update of chunk i−1 runs while chunk i is still on
/// the wire (the worker's wire charge covers that arrival time), so only
/// the *last* chunk's update extends the server's busy window. The hidden
/// portion is clamped by the incoming stream itself (`down_wire`, the
/// one-way w-down transfer time): updates cannot hide under wire time that
/// does not exist, so shrinking `chunk_kib` cannot shrink the cost below
/// `full - down_wire`.
fn server_handle_cost(cfg: &EasgdConfig, links: &LinkParams, bytes: u64, down_wire: f64) -> f64 {
    let full = server_update_cost(cfg.transport, links, bytes);
    if cfg.plan.chunk_kib == 0 || !cfg.plan.pipeline {
        return full;
    }
    let chunks = (bytes as usize).div_ceil(cfg.plan.chunk_kib * 1024).max(1) as f64;
    // updates of chunks 0..m-1 overlap the arrival of chunks 1..m
    let hidden = (full - full / chunks).min(down_wire * (chunks - 1.0) / chunks).max(0.0);
    full - hidden
}

pub fn run_easgd(rt: &Arc<Runtime>, cfg: &EasgdConfig) -> Result<EasgdReport> {
    let mut cfg = cfg.clone();
    let info = rt
        .manifest
        .models
        .get(&cfg.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", cfg.model))?
        .clone();
    if cfg.batch == 0 {
        cfg.batch = info.batch;
    }
    if info.kind != "cls" {
        return Err(anyhow!("easgd runner supports classifier models"));
    }
    let is_flat = info.input_shape.len() == 2;
    let arts = models::artifacts_for(&info, &cfg.model, cfg.batch)?;
    rt.warmup(&arts.train)?;
    rt.warmup(&arts.eval).ok();

    // worker GPUs 0..k-1, shard servers on GPUs k..k+S-1 (each its own
    // simulated GPU; own nodes on mosaic)
    let plan = Arc::new(ShardPlan::new(info.param_count, cfg.workers, cfg.plan.servers)?);
    let topo = Topology::by_name(&cfg.topology, plan.world_size())
        .ok_or_else(|| anyhow!("unknown topology"))?;
    let links = LinkParams::default();
    let comm_scale = match &cfg.sim_model {
        Some(fs) => {
            models::full_scale_bytes(&rt.manifest, fs)? as f64 / (4.0 * info.param_count as f64)
        }
        None => 1.0,
    };
    let prices = Arc::new(ShardPrices::new(&cfg, &topo, &links, &plan, comm_scale));

    let init = Arc::new(rt.init_params(&cfg.model)?);

    let dataset: Arc<EasgdData> = if is_flat {
        Arc::new(EasgdData::Features(FeatureDataset::new(
            info.input_shape[1],
            info.classes.unwrap_or(16),
            cfg.seed,
        )))
    } else {
        let mut spec = ImageSpec::default();
        spec.classes = info.classes.unwrap_or(16);
        spec.seed = cfg.seed;
        Arc::new(EasgdData::Images(ImageDataset::new(spec)))
    };

    // images are staged host->device every iteration (same PCIe pricing as
    // the BSP loader, on this run's fabric); flat-feature batches are tiny
    // and in-memory, so they carry no H2D charge
    let h2d_s = match dataset.as_ref() {
        EasgdData::Images(d) => {
            let s = &d.spec;
            links.pcie_time(Bytes((cfg.batch * s.channels * s.crop_hw * s.crop_hw * 4) as u64))
        }
        EasgdData::Features(_) => Secs::ZERO,
    };

    // world: ranks 0..k-1 workers, ranks k..k+S-1 shard servers
    let world = mpi::world(plan.world_size());
    let mut handles = Vec::new();
    for (rank, comm) in world.into_iter().enumerate() {
        let cfg = cfg.clone();
        let plan = plan.clone();
        let prices = prices.clone();
        let init = init.clone();
        if rank >= cfg.workers {
            handles.push(thread::spawn(move || -> Result<RankOut> {
                let mut comm = comm;
                let shard = rank - plan.workers;
                let (lo, len) = plan.slices[shard];
                let slice = init[lo..lo + len].to_vec();
                let out = shard::server_shard_main(
                    &mut comm,
                    &plan,
                    shard,
                    &prices,
                    cfg.alpha as f32,
                    slice,
                )?;
                Ok(RankOut::Server(out))
            }));
        } else {
            let rt = rt.clone();
            let info = info.clone();
            let arts = models::artifacts_for(&info, &cfg.model, cfg.batch)?;
            let dataset = dataset.clone();
            handles.push(thread::spawn(move || -> Result<RankOut> {
                let out = worker_main(
                    rank, comm, &rt, &cfg, &plan, &prices, &init, &info, &arts, &dataset,
                    h2d_s,
                )?;
                Ok(RankOut::Worker(out))
            }));
        }
    }

    let mut report = EasgdReport {
        workers: cfg.workers,
        iters: cfg.iters,
        tau: cfg.tau,
        alpha: cfg.alpha,
        servers: cfg.plan.servers,
        shard_busy: vec![0.0; cfg.plan.servers],
        ..Default::default()
    };
    let mut exchanges = 0usize;
    let mut waits: Vec<f64> = Vec::new();
    for h in handles {
        match h.join().map_err(|_| anyhow!("easgd thread panicked"))?? {
            RankOut::Worker(w) => {
                report.vtime_total = report.vtime_total.max(w.clock);
                report.comm_total += w.comm_time;
                exchanges += w.exchanges;
                report.breakdown.add(&w.breakdown);
                waits.extend(w.queue_waits);
                if !w.curve.is_empty() {
                    report.curve = w.curve;
                    report.final_val_err = report.curve.last().unwrap().2;
                }
            }
            RankOut::Server(s) => {
                report.shard_busy[s.shard] =
                    if s.clock_end > 0.0 { s.busy / s.clock_end } else { 0.0 };
            }
        }
    }
    report.comm_per_exchange = report.comm_total / exchanges.max(1) as f64;
    report.queue_wait_mean = Secs(crate::util::mean(&waits));
    report.queue_wait_p95 = Secs(crate::util::quantile(&waits, 0.95));
    report.throughput =
        (cfg.iters * cfg.batch * cfg.workers) as f64 / report.vtime_total.0.max(1e-12);
    Ok(report)
}

/// What one rank's thread returns to [`run_easgd`].
enum RankOut {
    Worker(WorkerOut),
    Server(shard::ServerOut),
}

/// EASGD data source: flat features (MLP) or the image pipeline.
pub enum EasgdData {
    Features(FeatureDataset),
    Images(ImageDataset),
}

impl EasgdData {
    /// (x flat, y, x-shape) for a batch drawn by `rng`.
    fn train_batch(
        &self,
        rng: &mut crate::util::Rng,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<usize>) {
        match self {
            EasgdData::Features(fd) => {
                let mut xs = Vec::with_capacity(batch * fd.dim);
                let mut ys = Vec::with_capacity(batch);
                for _ in 0..batch {
                    let (x, y) = fd.example(rng.next_u64() % 1_000_000);
                    xs.extend(x);
                    ys.push(y);
                }
                (xs, ys, vec![batch, fd.dim])
            }
            EasgdData::Images(ds) => {
                let s = &ds.spec;
                let mean = ds.mean_image();
                let off = (s.store_hw - s.crop_hw) / 2;
                let px = s.channels * s.crop_hw * s.crop_hw;
                let mut xs = Vec::with_capacity(batch * px);
                let mut ys = Vec::with_capacity(batch);
                for _ in 0..batch {
                    let (img, label) = ds.example(rng.next_u64() % 1_000_000);
                    xs.extend(crate::data::crop(&img, &mean, s, off, off, false));
                    ys.push(label);
                }
                (xs, ys, vec![batch, s.channels, s.crop_hw, s.crop_hw])
            }
        }
    }

    fn eval_batch(&self, batch: usize) -> (Vec<f32>, Vec<i32>, Vec<usize>) {
        match self {
            EasgdData::Features(fd) => {
                let (xs, ys) = fd.eval_batch(batch);
                (xs, ys, vec![batch, fd.dim])
            }
            EasgdData::Images(ds) => {
                let (xs, ys) = ds.eval_batch(0, batch);
                let s = &ds.spec;
                (xs, ys, vec![batch, s.channels, s.crop_hw, s.crop_hw])
            }
        }
    }
}

struct WorkerOut {
    clock: Secs,
    comm_time: Secs,
    exchanges: usize,
    breakdown: Breakdown,
    curve: Vec<(usize, f64, f64)>,
    /// binding-slice queue wait of each exchange, in order
    queue_waits: Vec<f64>,
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    rank: usize,
    mut comm: mpi::Comm,
    rt: &Arc<Runtime>,
    cfg: &EasgdConfig,
    plan: &ShardPlan,
    prices: &ShardPrices,
    init: &Arc<Vec<f32>>,
    info: &crate::runtime::ModelInfo,
    arts: &models::ModelArtifacts,
    dataset: &Arc<EasgdData>,
    h2d_s: Secs,
) -> Result<WorkerOut> {
    let mut params = (**init).clone();
    let mut momentum = vec![0.0f32; params.len()];
    // all virtual-time charges go through the ledger (breakdown==clock by
    // construction; see rust/src/audit)
    let mut led = crate::audit::Ledger::new();
    let mut comm_time = Secs::ZERO;
    let mut exchanges = 0usize;
    let mut curve = Vec::new();
    let mut queue_waits = Vec::new();
    let alpha = cfg.alpha as f32;

    // per-worker eval (rank 0 records the curve)
    let eval = if rank == 0 && cfg.eval_every > 0 {
        let (xs, ys, shape) = dataset.eval_batch(info.eval_batch);
        Some((HostTensor::f32(shape, xs), HostTensor::i32(vec![info.eval_batch], ys)))
    } else {
        None
    };

    let mut rng = crate::util::Rng::new(cfg.seed).fork(100 + rank as u64);

    for iter in 0..cfg.iters {
        let lr = cfg.lr.at(iter) as f32;
        // in-memory batch (EASGD study focuses on comm, not the loader) —
        // but the device staging is still a real PCIe crossing for images
        let (xs, ys, shape) = dataset.train_batch(&mut rng, cfg.batch);
        if h2d_s > 0.0 {
            led.charge(crate::audit::ChargeKind::H2d, "easgd.h2d", h2d_s);
        }
        let res = rt.exec(
            &arts.train,
            vec![
                HostTensor::f32(vec![params.len()], std::mem::take(&mut params)),
                HostTensor::f32(vec![momentum.len()], std::mem::take(&mut momentum)),
                HostTensor::f32(shape, xs),
                HostTensor::i32(vec![cfg.batch], ys),
                HostTensor::scalar_f32(lr),
                HostTensor::scalar_f32(cfg.momentum as f32),
            ],
        )?;
        let mut outs = res.outputs.into_iter();
        params = outs.next().unwrap().into_f32()?;
        momentum = outs.next().unwrap().into_f32()?;
        led.charge(crate::audit::ChargeKind::Compute, "easgd.train", Secs(res.exec_time));

        // elastic exchange every τ iterations: push/pull all S slices
        // concurrently (asa16-family wire formats really round-trip w and
        // c through f16 at half the priced bytes); completion is the max
        // over slice round-trips, and the binding slice's queue wait is
        // split out of the comm charge
        if (iter + 1) % cfg.tau == 0 {
            let t = shard::worker_exchange(
                &mut comm,
                rank,
                plan,
                prices,
                alpha,
                &mut params,
                led.clock(),
            )?;
            // queue wait first, then advance_to lands the clock on the
            // exchange's completion time *exactly* — downstream virtual
            // arrivals (and their tie-breaks) depend on it bit-for-bit
            led.charge(crate::audit::ChargeKind::CommQueue, "easgd.queue", t.queue_wait);
            led.advance_to(crate::audit::ChargeKind::CommTransfer, "easgd.exchange", t.new_clock);
            comm_time += t.t_comm;
            queue_waits.push(t.queue_wait.0);
            exchanges += 1;
        }

        if rank == 0 && cfg.eval_every > 0 && (iter + 1) % cfg.eval_every == 0 {
            let (ex, ey) = eval.as_ref().unwrap();
            let r = rt.exec(
                &arts.eval,
                vec![HostTensor::f32(vec![params.len()], params.clone()), ex.clone(), ey.clone()],
            )?;
            let correct = r.outputs[1].scalar_i32()? as f64;
            curve.push((iter + 1, led.clock().0, 1.0 - correct / info.eval_batch as f64));
        }
    }

    // tell every shard server we're done
    for j in 0..plan.servers {
        comm.send(plan.server_rank(j), tags::CTL, Payload::Ctl("stop".into()), led.clock().0)?;
    }
    let (clock, bd) = led.finish();
    Ok(WorkerOut { clock, comm_time, exchanges, breakdown: bd, curve, queue_waits })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipelined_server_handle_cost_shrinks_with_chunks_but_is_wire_clamped() {
        let links = LinkParams::default();
        let bytes = 8 << 20; // 8 MiB of parameters
        let mut cfg = EasgdConfig::quick("mlp", 4, 10);
        let full = server_handle_cost(&cfg, &links, bytes, 1.0);
        assert!(full > 0.0);
        cfg.plan.chunk_kib = 1024; // 8 chunks; ample wire to hide under
        let piped = server_handle_cost(&cfg, &links, bytes, 1.0);
        assert!((piped - full / 8.0).abs() < 1e-15, "piped={piped} full={full}");
        // updates cannot hide under wire time that does not exist
        assert_eq!(server_handle_cost(&cfg, &links, bytes, 0.0), full);
        cfg.plan.chunk_kib = 4; // absurdly fine chunking must not price below
        let tiny_wire = full * 0.25;
        let clamped = server_handle_cost(&cfg, &links, bytes, tiny_wire);
        assert!(clamped >= full - tiny_wire, "clamped={clamped} full={full}");
        cfg.plan.pipeline = false;
        assert_eq!(server_handle_cost(&cfg, &links, bytes, 1.0), full);
    }

    #[test]
    fn half_wire_exchange_halves_priced_bytes() {
        let links = LinkParams::default();
        let topo = Topology::by_name("mosaic", 3).unwrap();
        let full = exchange_cost(Transport::CudaAwareMpi, &topo, &links, 0, 2, 8 << 20);
        let half = exchange_cost(Transport::CudaAwareMpi, &topo, &links, 0, 2, 4 << 20);
        assert!(half < full);
        // the knob that selects it
        let mut cfg = EasgdConfig::quick("mlp", 2, 10);
        assert!(!cfg.plan.strategy.half_wire());
        cfg.plan.strategy = StrategyKind::from_name("hier:asa16").unwrap();
        assert!(cfg.plan.strategy.half_wire());
    }

    #[test]
    fn f16_payload_roundtrip_matches_wire_model() {
        // the real packing the worker/server paths use
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut bits = Vec::new();
        Wire::F16.pack(&xs, &mut bits);
        assert_eq!(bits.len(), xs.len());
        let mut back = Vec::new();
        Wire::F16.unpack(&bits, &mut back);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn exchange_cost_positive_on_both_transports() {
        let links = LinkParams::default();
        let topo = Topology::by_name("copper", 5).unwrap();
        for t in [Transport::CudaAwareMpi, Transport::PlatoonShm] {
            let c = exchange_cost(t, &topo, &links, 0, 4, 4 << 20);
            assert!(c > 0.0, "{t:?}");
        }
    }
}
