//! The leader: experiment drivers that regenerate every table and figure.
//!
//! `Session` owns the shared `Runtime` and an output directory (`runs/` by
//! default). Each driver returns the rendered table (also printed by the
//! CLI) and writes machine-readable CSV/JSON next to it. The experiment ↔
//! paper mapping lives in DESIGN.md §5.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::bsp::{run_bsp, BspConfig, BspReport};
use crate::cluster::Topology;
use crate::collectives::{
    CommReport, ExchangeCtx, ReduceOp, StrategyKind, WfbpOutcome, WfbpPlan, WireFormat,
};
use crate::easgd::{run_easgd, EasgdConfig, Transport};
use crate::metrics::Table;
use crate::models;
use crate::runtime::Runtime;
use crate::sgd::{LrSchedule, Scheme};
use crate::simnet::LinkParams;
use crate::units::{Kib, Secs};

pub struct Session {
    pub rt: Arc<Runtime>,
    pub out_dir: PathBuf,
}

impl Session {
    pub fn new(artifacts_dir: impl AsRef<Path>, out_dir: impl AsRef<Path>) -> Result<Session> {
        let rt = Arc::new(Runtime::load(artifacts_dir)?);
        let out_dir = out_dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&out_dir)?;
        Ok(Session { rt, out_dir })
    }

    pub fn write_csv(&self, name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
        let path = self.out_dir.join(name);
        let mut text = String::from(header);
        text.push('\n');
        for r in rows {
            text.push_str(r);
            text.push('\n');
        }
        std::fs::write(&path, text).with_context(|| format!("{path:?}"))?;
        Ok(path)
    }

    // -----------------------------------------------------------------------
    // Communication-only measurement (Fig. 3 / Table 3 backbone): run one
    // exchange of a buffer across k worker threads on a topology and return
    // the rank-0 report with times scaled to `full_bytes`.
    pub fn measure_exchange(
        &self,
        strategy: StrategyKind,
        k: usize,
        topology: &str,
        full_bytes: u64,
        cuda_aware: bool,
    ) -> Result<CommReport> {
        self.measure_exchange_opts(strategy, k, topology, full_bytes, cuda_aware, 0, false)
    }

    /// [`measure_exchange`](Self::measure_exchange) with the chunked
    /// pipeline scheduler engaged: `chunks > 1` splits the probe into that
    /// many pipeline chunks (so the full-scale chunk size is
    /// `full_bytes / chunks`); `pipeline` toggles the comm/compute overlap
    /// (off = serially-priced chunking, the ablation).
    #[allow(clippy::too_many_arguments)]
    pub fn measure_exchange_opts(
        &self,
        strategy: StrategyKind,
        k: usize,
        topology: &str,
        full_bytes: u64,
        cuda_aware: bool,
        chunks: usize,
        pipeline: bool,
    ) -> Result<CommReport> {
        let topo = Topology::by_name(topology, k)
            .ok_or_else(|| anyhow::anyhow!("unknown topology '{topology}'"))?;
        self.measure_exchange_on(strategy, k, topo, full_bytes, cuda_aware, chunks, pipeline)
    }

    /// [`measure_exchange_opts`](Self::measure_exchange_opts) against an
    /// explicit [`Topology`] — the GPUs-per-node ablations probe
    /// [`Topology::grid`] fabrics that have no preset name.
    #[allow(clippy::too_many_arguments)]
    pub fn measure_exchange_on(
        &self,
        strategy: StrategyKind,
        k: usize,
        topo: Topology,
        full_bytes: u64,
        cuda_aware: bool,
        chunks: usize,
        pipeline: bool,
    ) -> Result<CommReport> {
        probe_exchange_rt(
            strategy,
            WireFormat::F32,
            k,
            topo,
            full_bytes,
            cuda_aware,
            chunks,
            pipeline,
            None,
            Some(self.rt.clone()),
        )
    }

    // -----------------------------------------------------------------------
    /// **Fig. 3**: computation vs relative communication overhead of AR /
    /// ASA / ASA16 while training AlexNet-128b on 8 single-GPU nodes.
    pub fn fig3(&self) -> Result<String> {
        let k = 8;
        let model = "alexnet";
        let batch = 128;
        let bytes = models::full_scale_bytes(&self.rt.manifest, model)?;
        let train_per_iter =
            models::paper_train_5120(model, batch).unwrap() / (5120.0 / batch as f64);

        let mut table = Table::new(&[
            "strategy", "comm/iter (s)", "train/iter (s)", "comm/train", "vs AR", "kernel %",
        ]);
        let mut rows = Vec::new();
        let mut ar_time = 0.0;
        for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16] {
            let rep = self.measure_exchange(strat, k, "mosaic", bytes, true)?;
            let t = rep.sim_total().0;
            if strat == StrategyKind::Ar {
                ar_time = t;
            }
            table.row(vec![
                strat.name().to_uppercase(),
                format!("{t:.3}"),
                format!("{train_per_iter:.3}"),
                format!("{:.2}", t / train_per_iter),
                format!("{:.2}x", ar_time / t),
                format!("{:.1}%", rep.kernel_share() * 100.0),
            ]);
            rows.push(format!(
                "{},{t:.6},{train_per_iter:.6},{:.6},{:.4}",
                strat.name(),
                t / train_per_iter,
                ar_time / t
            ));
        }
        self.write_csv("fig3.csv", "strategy,comm_s,train_s,comm_over_train,speedup_vs_ar", &rows)?;
        Ok(format!(
            "Fig. 3 — AlexNet-128b on mosaic (8 nodes x 1 GPU), paper: ASA ~3x, ASA16 ~6x vs AR\n{}",
            table.render()
        ))
    }

    // -----------------------------------------------------------------------
    /// **Table 2**: structural comparison (exact parameter counts).
    pub fn table2(&self) -> Result<String> {
        let mut table = Table::new(&["model", "depth", "params", "paper", "match"]);
        let mut rows = Vec::new();
        for name in ["alexnet", "googlenet", "vggnet"] {
            let m = &self.rt.manifest.full_scale[name];
            table.row(vec![
                name.to_string(),
                m.depth.to_string(),
                m.params.to_string(),
                m.paper_params.to_string(),
                if m.params == m.paper_params { "exact" } else { "MISMATCH" }.to_string(),
            ]);
            rows.push(format!("{name},{},{},{}", m.depth, m.params, m.paper_params));
        }
        self.write_csv("table2.csv", "model,depth,params,paper_params", &rows)?;
        Ok(format!("Table 2 — structural comparison\n{}", table.render()))
    }

    // -----------------------------------------------------------------------
    /// **Table 3**: communication overhead per 5,120 images / 8-GPU speedup
    /// for AlexNet-128b/32b, GoogLeNet-32b (mosaic) and VGGNet-32b (copper).
    pub fn table3(&self) -> Result<String> {
        let k = 8;
        let rows_spec: &[(&str, usize)] =
            &[("alexnet", 128), ("alexnet", 32), ("googlenet", 32), ("vggnet", 32)];
        let mut table = Table::new(&[
            "model", "train1GPU/5120 (s)", "AR (s/x)", "ASA (s/x)", "ASA16 (s/x)",
        ]);
        let mut rows = Vec::new();
        for &(model, batch) in rows_spec {
            let topo = models::paper_topology(model);
            let bytes = models::full_scale_bytes(&self.rt.manifest, model)?;
            let t1 = models::paper_train_5120(model, batch).unwrap();
            let iters_per_5120 = 5120.0 / (batch as f64 * k as f64);
            let mut cells =
                vec![format!("{model}-{batch}b ({topo})"), format!("{t1:.1}")];
            let mut csv = format!("{model},{batch},{topo},{t1}");
            for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16] {
                let rep = self.measure_exchange(strat, k, topo, bytes, true)?;
                let comm_5120 = rep.sim_total().0 * iters_per_5120;
                let total = t1 / k as f64 + comm_5120;
                let speedup = t1 / total;
                cells.push(format!("{comm_5120:.2}/{speedup:.1}x"));
                csv.push_str(&format!(",{comm_5120:.4},{speedup:.3}"));
            }
            table.row(cells);
            rows.push(csv);
        }
        self.write_csv(
            "table3.csv",
            "model,batch,topology,train1gpu_s,ar_comm_s,ar_speedup,asa_comm_s,asa_speedup,asa16_comm_s,asa16_speedup",
            &rows,
        )?;
        Ok(format!(
            "Table 3 — comm overhead per 5,120 images (s) / speedup on 8 GPUs\n\
             (paper: ASA 2.94/4.9x + ASA16 1.83/5.7x on AlexNet-32b; 1.96/7.2x + 1.76/7.3x on GoogLeNet)\n{}",
            table.render()
        ))
    }

    // -----------------------------------------------------------------------
    /// Convergence suite behind **Table 1 / Fig. 4 / Fig. 5**: BSP proxy
    /// runs at k ∈ scales. Returns (report per run, csv rows).
    pub fn convergence(
        &self,
        model: &str,
        scales: &[usize],
        batch: usize,
        iters: usize,
        lrs: &[f64],
        strategy: StrategyKind,
        tag: &str,
    ) -> Result<Vec<(usize, BspReport)>> {
        let mut out = Vec::new();
        let mut curve_rows: Vec<String> = Vec::new();
        for (i, &k) in scales.iter().enumerate() {
            let mut cfg = BspConfig::quick(model, k, iters);
            cfg.batch = batch;
            cfg.scheme = Scheme::Subgd;
            cfg.plan.strategy = strategy;
            cfg.lr = match model {
                // GoogLeNet policy (footnote 13): poly 0.5
                "googlenet" => LrSchedule::Poly { base: lrs[i], power: 0.5, max_iters: iters },
                // AlexNet policy: /10 every "20 epochs" ~ 40% of the run
                _ => LrSchedule::StepDecay { base: lrs[i], factor: 0.1, every: (iters * 2) / 5 },
            };
            cfg.eval_every = (iters / 12).max(1);
            cfg.sim_model = models::full_scale_of(model).map(|s| s.to_string());
            cfg.topology = models::full_scale_of(model)
                .map(models::paper_topology)
                .unwrap_or("mosaic")
                .to_string();
            cfg.seed = 42;
            let rep = run_bsp(&self.rt, &cfg)?;
            for p in &rep.curve {
                curve_rows.push(format!(
                    "{k},{batch},{},{:.4},{:.6},{:.4}",
                    p.iter, p.vtime, p.train_loss, p.val_err
                ));
            }
            out.push((k, rep));
        }
        self.write_csv(
            &format!("{tag}_curves.csv"),
            "workers,batch,iter,vtime_s,train_loss,val_err",
            &curve_rows,
        )?;
        Ok(out)
    }

    /// **Fig. 4**: AlexNet-proxy validation error at k ∈ {1,2,4,8} (+ the
    /// 8-worker small-batch recovery row).
    pub fn fig4(&self, iters: usize) -> Result<String> {
        let runs = self.convergence(
            "alexnet",
            &[1, 2, 4, 8],
            32,
            iters,
            &[0.01, 0.01, 0.01, 0.005],
            StrategyKind::Asa,
            "fig4",
        )?;
        // the paper's recovery: 8 workers at a smaller per-worker batch
        let small = self.convergence(
            "alexnet",
            &[8],
            8,
            iters,
            &[0.005],
            StrategyKind::Asa,
            "fig4_smallbatch",
        )?;
        let mut table =
            Table::new(&["workers", "batch", "eff.batch", "final val err", "final loss"]);
        for (k, rep) in runs.iter().chain(small.iter()) {
            table.row(vec![
                k.to_string(),
                rep.batch.to_string(),
                (k * rep.batch).to_string(),
                format!("{:.3}", rep.final_val_err),
                format!("{:.3}", rep.final_train_loss),
            ]);
        }
        Ok(format!(
            "Fig. 4 — AlexNet-proxy convergence vs scale (paper: larger effective batch converges worse;\n\
             smaller per-worker batch at 8 GPUs recovers it)\n{}",
            table.render()
        ))
    }

    /// **Fig. 5**: GoogLeNet-proxy validation error at k ∈ {2,4,8} with the
    /// poly(0.5) policy and per-scale LRs from Table 1.
    pub fn fig5(&self, iters: usize) -> Result<String> {
        let runs = self.convergence(
            "googlenet",
            &[2, 4, 8],
            32,
            iters,
            &[0.007, 0.005, 0.005],
            StrategyKind::Asa,
            "fig5",
        )?;
        let mut table = Table::new(&["workers", "batch", "final val err", "final loss"]);
        for (k, rep) in &runs {
            table.row(vec![
                k.to_string(),
                rep.batch.to_string(),
                format!("{:.3}", rep.final_val_err),
                format!("{:.3}", rep.final_train_loss),
            ]);
        }
        Ok(format!("Fig. 5 — GoogLeNet-proxy convergence vs scale\n{}", table.render()))
    }

    /// **Table 1**: accuracy/speedup trade-off. Accuracy from proxy
    /// convergence runs (incl. ASA16 rows — real half-precision exchange);
    /// speedup from the full-scale comm simulation (Table 3 machinery).
    pub fn table1(&self, iters: usize) -> Result<String> {
        let k_speedup = |model: &str, batch: usize, strat: StrategyKind, k: usize| -> Result<f64> {
            if k == 1 {
                return Ok(1.0);
            }
            let fs = models::full_scale_of(model).unwrap();
            let topo = models::paper_topology(fs);
            let bytes = models::full_scale_bytes(&self.rt.manifest, fs)?;
            // paper's 1-GPU time is batch-dependent; fall back to bs=32 row
            let t1 = models::paper_train_5120(fs, batch)
                .or_else(|| models::paper_train_5120(fs, 32))
                .unwrap();
            let rep = self.measure_exchange(strat, k, topo, bytes, true)?;
            let iters_per_5120 = 5120.0 / (batch as f64 * k as f64);
            let total = t1 / k as f64 + rep.sim_total().0 * iters_per_5120;
            Ok(t1 / total)
        };

        let mut table = Table::new(&[
            "row", "workers", "LR", "BS", "val err", "speedup(sim)",
        ]);
        let mut csv = Vec::new();

        // AlexNet rows at k=1,2,4,8 (bs 32 proxy; paper used 128 at full scale)
        let alex = self.convergence(
            "alexnet",
            &[1, 2, 4, 8],
            32,
            iters,
            &[0.01, 0.01, 0.01, 0.005],
            StrategyKind::Asa,
            "table1_alexnet",
        )?;
        let alex_lr = [0.01, 0.01, 0.01, 0.005];
        for ((k, rep), lr) in alex.iter().zip(alex_lr) {
            let sp = k_speedup("alexnet", 32, StrategyKind::Asa, *k)?;
            table.row(vec![
                "AlexNet".into(),
                k.to_string(),
                format!("{lr}"),
                rep.batch.to_string(),
                format!("{:.3}", rep.final_val_err),
                format!("{sp:.1}x"),
            ]);
            csv.push(format!("alexnet,{k},{lr},{},{:.4},{sp:.3}", rep.batch, rep.final_val_err));
        }
        // 8GPU small-batch + fp16 rows
        let small = self.convergence(
            "alexnet", &[8], 8, iters, &[0.005], StrategyKind::Asa, "table1_alexnet_small",
        )?;
        let sp = k_speedup("alexnet", 8, StrategyKind::Asa, 8)?;
        table.row(vec![
            "AlexNet-smallBS".into(),
            "8".into(),
            "0.005".into(),
            "8".into(),
            format!("{:.3}", small[0].1.final_val_err),
            format!("{sp:.1}x"),
        ]);
        csv.push(format!("alexnet_small,8,0.005,8,{:.4},{sp:.3}", small[0].1.final_val_err));

        let fp16 = self.convergence(
            "alexnet", &[8], 8, iters, &[0.005], StrategyKind::Asa16, "table1_alexnet_fp16",
        )?;
        let sp = k_speedup("alexnet", 8, StrategyKind::Asa16, 8)?;
        table.row(vec![
            "AlexNet-fp16".into(),
            "8".into(),
            "0.005".into(),
            "8".into(),
            format!("{:.3}", fp16[0].1.final_val_err),
            format!("{sp:.1}x"),
        ]);
        csv.push(format!("alexnet_fp16,8,0.005,8,{:.4},{sp:.3}", fp16[0].1.final_val_err));

        // GoogLeNet rows
        let goog = self.convergence(
            "googlenet",
            &[2, 4, 8],
            32,
            iters,
            &[0.007, 0.005, 0.005],
            StrategyKind::Asa,
            "table1_googlenet",
        )?;
        for ((k, rep), lr) in goog.iter().zip([0.007, 0.005, 0.005]) {
            let sp = k_speedup("googlenet", 32, StrategyKind::Asa, *k)?;
            table.row(vec![
                "GoogLeNet".into(),
                k.to_string(),
                format!("{lr}"),
                rep.batch.to_string(),
                format!("{:.3}", rep.final_val_err),
                format!("{sp:.1}x"),
            ]);
            csv.push(format!("googlenet,{k},{lr},{},{:.4},{sp:.3}", rep.batch, rep.final_val_err));
        }
        let gfp16 = self.convergence(
            "googlenet", &[8], 32, iters, &[0.005], StrategyKind::Asa16, "table1_googlenet_fp16",
        )?;
        let sp = k_speedup("googlenet", 32, StrategyKind::Asa16, 8)?;
        table.row(vec![
            "GoogLeNet-fp16".into(),
            "8".into(),
            "0.005".into(),
            "32".into(),
            format!("{:.3}", gfp16[0].1.final_val_err),
            format!("{sp:.1}x"),
        ]);
        csv.push(format!("googlenet_fp16,8,0.005,32,{:.4},{sp:.3}", gfp16[0].1.final_val_err));

        self.write_csv("table1.csv", "row,workers,lr,batch,val_err,speedup", &csv)?;
        Ok(format!(
            "Table 1 — accuracy/speedup trade-off (proxy accuracy, full-scale simulated speedup)\n{}",
            table.render()
        ))
    }

    // -----------------------------------------------------------------------
    /// **§4 EASGD**: comm overhead of the CUDA-aware MPI transport vs the
    /// Platoon-like shm baseline at τ=1 (paper: 42 % lower), same model/k.
    pub fn easgd_compare(&self, iters: usize) -> Result<String> {
        let mut results = Vec::new();
        for transport in [Transport::PlatoonShm, Transport::CudaAwareMpi] {
            let mut cfg = EasgdConfig::quick("mlp", 4, iters);
            cfg.transport = transport;
            cfg.tau = 1;
            cfg.topology = "copper".to_string(); // Platoon is single-node
            cfg.sim_model = Some("alexnet".to_string());
            let rep = run_easgd(&self.rt, &cfg)?;
            results.push((transport, rep));
        }
        let shm = results[0].1.comm_per_exchange;
        let mpi = results[1].1.comm_per_exchange;
        let reduction = (shm - mpi) / shm * 100.0;
        let mut table = Table::new(&[
            "transport", "comm/exchange (s)", "total comm (s)", "queue p95 (s)",
            "throughput (ex/s)",
        ]);
        let mut rows = Vec::new();
        for (t, rep) in &results {
            table.row(vec![
                t.name().to_string(),
                format!("{:.4}", rep.comm_per_exchange),
                format!("{:.3}", rep.comm_total),
                format!("{:.4}", rep.queue_wait_p95),
                format!("{:.1}", rep.throughput),
            ]);
            rows.push(format!(
                "{},{},{},{}",
                t.name(),
                rep.comm_per_exchange,
                rep.comm_total,
                rep.queue_wait_p95
            ));
        }
        self.write_csv(
            "easgd_compare.csv",
            "transport,comm_per_exchange_s,comm_total_s,queue_wait_p95_s",
            &rows,
        )?;
        Ok(format!(
            "EASGD comm overhead at tau=1 (AlexNet-scale exchange, 1 node): \
             CUDA-aware MPI is {reduction:.0}% lower than the Platoon-shm baseline (paper: 42%)\n{}",
            table.render()
        ))
    }

    /// **§4 EASGD grid**: α × τ search (paper best: α=0.5, τ=1).
    pub fn easgd_grid(&self, iters: usize) -> Result<String> {
        let mut table = Table::new(&["alpha", "tau", "final val err", "throughput (ex/s)"]);
        let mut rows = Vec::new();
        let mut best: Option<(f64, usize, f64)> = None;
        for &alpha in &[0.1, 0.3, 0.5, 0.9] {
            for &tau in &[1usize, 2, 4, 8] {
                let mut cfg = EasgdConfig::quick("mlp", 4, iters);
                cfg.alpha = alpha;
                cfg.tau = tau;
                cfg.eval_every = (iters / 4).max(1);
                cfg.lr = LrSchedule::Const { base: 0.05 };
                let rep = run_easgd(&self.rt, &cfg)?;
                table.row(vec![
                    format!("{alpha}"),
                    tau.to_string(),
                    format!("{:.3}", rep.final_val_err),
                    format!("{:.1}", rep.throughput),
                ]);
                rows.push(format!("{alpha},{tau},{:.4},{:.2}", rep.final_val_err, rep.throughput));
                if best.map(|(_, _, e)| rep.final_val_err < e).unwrap_or(true) {
                    best = Some((alpha, tau, rep.final_val_err));
                }
            }
        }
        self.write_csv("easgd_grid.csv", "alpha,tau,val_err,throughput", &rows)?;
        let (ba, bt, be) = best.unwrap();
        Ok(format!(
            "EASGD grid search (paper best: alpha=0.5, tau=1, 21.12% top-5)\n\
             best here: alpha={ba}, tau={bt}, val_err={be:.3}\n{}",
            table.render()
        ))
    }

    // -----------------------------------------------------------------------
    /// **Fig. 6**: topology rendering.
    pub fn topo(&self, name: &str) -> Result<String> {
        let t = Topology::by_name(name, 8)
            .ok_or_else(|| anyhow::anyhow!("unknown topology '{name}'"))?;
        Ok(t.render())
    }
}

// ---------------------------------------------------------------------------
// Runtime-free comm probes — the CI bench-smoke path.
//
// Simulated exchange times depend only on the topology model, never on the
// AOT artifacts, so benches and the bench-regression gate can price
// exchanges in containers without `artifacts/` (Pallas kernels unbound:
// the data path falls back to host arithmetic, and `Ring` charges no GPU
// kernel time — the values are deterministic and identical on every
// machine, which is what makes the committed baselines comparable).

/// One exchange of a `full_bytes`-sized model across `k` workers, priced
/// without a runtime. `chunks > 1` engages the chunked pipeline scheduler;
/// `pipeline = false` is the serially-priced ablation.
pub fn probe_exchange(
    strategy: StrategyKind,
    k: usize,
    topo: Topology,
    full_bytes: u64,
    cuda_aware: bool,
    chunks: usize,
    pipeline: bool,
) -> Result<CommReport> {
    probe_exchange_rt(
        strategy,
        WireFormat::F32,
        k,
        topo,
        full_bytes,
        cuda_aware,
        chunks,
        pipeline,
        None,
        None,
    )
}

/// [`probe_exchange`] with an explicit wire format — the wire-sweep bench
/// probe. `sf_bytes` is the full-scale sufficient-factor byte hint for the
/// `sf` wire (`None` or a hint ≥ dense rides the dense fallback); it is
/// scaled onto the capped probe buffer at the same ratio as the vector, so
/// the codec's byte ratio — and therefore every repriced band — is exactly
/// the full-scale one.
#[allow(clippy::too_many_arguments)]
pub fn probe_exchange_wire(
    strategy: StrategyKind,
    fmt: WireFormat,
    k: usize,
    topo: Topology,
    full_bytes: u64,
    cuda_aware: bool,
    chunks: usize,
    pipeline: bool,
    sf_bytes: Option<u64>,
) -> Result<CommReport> {
    probe_exchange_rt(
        strategy, fmt, k, topo, full_bytes, cuda_aware, chunks, pipeline, sf_bytes, None,
    )
}

/// Shared probe: real buffers are capped at 1M f32; sim time scales
/// linearly to `full_bytes`. With a runtime, the Pallas kernels run on the
/// data path (`Session::measure_exchange*`); without, host fallbacks.
#[allow(clippy::too_many_arguments)]
fn probe_exchange_rt(
    strategy: StrategyKind,
    fmt: WireFormat,
    k: usize,
    topo: Topology,
    full_bytes: u64,
    cuda_aware: bool,
    chunks: usize,
    pipeline: bool,
    sf_bytes: Option<u64>,
    rt: Option<Arc<Runtime>>,
) -> Result<CommReport> {
    let probe_elems: usize = 1_000_000.min((full_bytes / 4) as usize).max(1);
    let scale = full_bytes as f64 / (4.0 * probe_elems as f64);
    let chunk_elems = if chunks > 1 { probe_elems.div_ceil(chunks) } else { 0 };
    // the sf hint shrinks with the probe so the byte *ratio* is full-scale
    let probe_sf = sf_bytes.map(|b| (b as f64 / scale).round() as u64);
    let links = LinkParams::default();

    let world = crate::mpi::world(k);
    let mut handles = Vec::new();
    for (rank, mut comm) in world.into_iter().enumerate() {
        let topo = topo.clone();
        let rt = rt.clone();
        handles.push(std::thread::spawn(move || -> Result<CommReport> {
            let mut buf: Vec<f32> =
                (0..probe_elems).map(|i| ((rank * 31 + i) % 1000) as f32 * 1e-3).collect();
            let kernels = rt.as_ref().map(|r| r.kernels());
            let strat: Box<dyn crate::collectives::ExchangeStrategy> = if chunk_elems > 0 {
                Box::new(crate::collectives::ChunkedPipeline::new(
                    strategy.build(fmt),
                    chunk_elems,
                    pipeline,
                ))
            } else {
                strategy.build(fmt)
            };
            let mut ctx = ExchangeCtx {
                comm: &mut comm,
                topo: &topo,
                links: &links,
                kernels: kernels.as_ref(),
                cuda_aware,
                chunk_elems: 0,
                slice_off: 0,
                sf_bytes: probe_sf,
            };
            strat.exchange(&mut buf, ReduceOp::Sum, &mut ctx)
        }));
    }
    let mut rep = CommReport::default();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.join().map_err(|_| anyhow::anyhow!("exchange worker panicked"))??;
        if i == 0 {
            rep = r;
        }
    }
    rep.scale_times(scale);
    Ok(rep)
}

/// One wait-free (or post-backward, `overlap = false`) bucketed exchange
/// of a model described by its per-layer `(name, params)` table, priced
/// without a runtime — the WFBP bench/gate probe.
///
/// The bucket plan is built at full scale (`bucket_kib` of real gradient
/// bytes, 0 = one bucket per layer) and projected onto the capped probe
/// vector; `backward_total` is the full-scale backward-pass seconds the
/// exchange may hide under. `chunk_kib > 0` additionally chunk-pipelines
/// each bucket's exchange.
#[allow(clippy::too_many_arguments)]
pub fn probe_wfbp(
    strategy: StrategyKind,
    k: usize,
    topo: Topology,
    layers: &[(String, usize)],
    cuda_aware: bool,
    bucket_kib: usize,
    chunk_kib: usize,
    backward_total: f64,
    overlap: bool,
) -> Result<WfbpOutcome> {
    let full_elems: usize = layers.iter().map(|(_, p)| p).sum();
    let probe_elems: usize = 1_000_000.min(full_elems).max(1);
    let comm_scale = full_elems.max(1) as f64 / probe_elems as f64;
    // bucket/chunk budgets are on-wire KiB: wire-width-aware sizing (the
    // probes run the f32 wire, so asa16's native half wire is the only
    // width that differs here)
    let bucket_elems = Kib(bucket_kib).elems(strategy, WireFormat::F32).0;
    let plan = Arc::new(WfbpPlan::from_layers(layers, bucket_elems).project(probe_elems));
    // a full-scale chunk size maps onto the probe at the same ratio
    let chunk_elems = if chunk_kib > 0 {
        ((Kib(chunk_kib).elems(strategy, WireFormat::F32).0 as f64 / comm_scale).round() as usize)
            .max(1)
    } else {
        0
    };
    let links = LinkParams::default();

    let world = crate::mpi::world(k);
    let mut handles = Vec::new();
    for (rank, mut comm) in world.into_iter().enumerate() {
        let topo = topo.clone();
        let plan = plan.clone();
        handles.push(std::thread::spawn(move || -> Result<WfbpOutcome> {
            let mut buf: Vec<f32> =
                (0..probe_elems).map(|i| ((rank * 31 + i) % 1000) as f32 * 1e-3).collect();
            let inner: Box<dyn crate::collectives::ExchangeStrategy> = if chunk_elems > 0 {
                Box::new(crate::collectives::ChunkedPipeline::new(
                    strategy.build(WireFormat::F32),
                    chunk_elems,
                    true,
                ))
            } else {
                strategy.build(WireFormat::F32)
            };
            let mut ctx = ExchangeCtx {
                comm: &mut comm,
                topo: &topo,
                links: &links,
                kernels: None,
                cuda_aware,
                chunk_elems: 0,
                slice_off: 0,
                sf_bytes: None,
            };
            crate::collectives::exchange_wfbp(
                inner.as_ref(),
                &plan,
                &mut buf,
                ReduceOp::Sum,
                &mut ctx,
                Secs(backward_total),
                comm_scale,
                overlap,
            )
        }));
    }
    let mut out = WfbpOutcome::default();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.join().map_err(|_| anyhow::anyhow!("wfbp worker panicked"))??;
        if i == 0 {
            out = r;
        }
    }
    Ok(out)
}
