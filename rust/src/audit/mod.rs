//! Typed charge ledger — the single place virtual time is spent.
//!
//! Every correctness bug this repo has shipped was a cost-accounting bug:
//! a clock advance without a matching `Breakdown` charge, a component
//! double-charged, or a new field silently missing from a total. The
//! ledger makes that bug class structural instead of behavioral: engines
//! (`bsp`, `easgd`, `easgd::shard`) never touch `clock` or `Breakdown`
//! fields directly — they call [`Ledger::charge`] with a [`ChargeKind`]
//! and a source tag, and the ledger derives *both* the clock and the
//! breakdown from the same charge stream, so `breakdown == clock` holds
//! by construction. `scripts/lint_charges.py` rejects raw clock /
//! `Breakdown` arithmetic outside this module at CI time, and every
//! charge carries a [`Secs`] — handing the ledger a microsecond or byte
//! quantity is a compile error (`crate::units`).
//!
//! Charge-kind taxonomy (what advances the clock):
//!
//! | kind            | clock | meaning                                        |
//! |-----------------|-------|------------------------------------------------|
//! | `Compute`       | yes   | PJRT train/grad execution (real, measured)     |
//! | `CommTransfer`  | yes   | simulated wire time of an exchange             |
//! | `CommKernel`    | yes   | simulated GPU sum/cast kernels in an exchange  |
//! | `CommQueue`     | yes   | waiting on peers: EASGD shard queue, BSP barrier straggle |
//! | `HostReduce`    | yes   | host CPU reduction (the AR baseline)           |
//! | `H2d`           | yes   | simulated H2D staging of input batches         |
//! | `LoadStall`     | yes   | blocked on the parallel loader                 |
//! | `Apply`         | yes   | SUBGD `sgd_apply` execution (real, measured)   |
//! | `CommHidden`    | no    | memo: comm hidden under backward compute       |
//! | `LoadHidden`    | no    | memo: loader disk+decode hidden under compute  |
//!
//! `CommHidden` and `LoadHidden` are memo kinds: the clock never paid
//! them, so they are charged through [`Ledger::charge_hidden`] /
//! [`Ledger::charge_hidden_load`], which also record the serial budget
//! each hidden memo must stay under ("hidden time is bounded by what the
//! serial schedule would have paid" — [`Ledger::audit`] checks both).
//!
//! **Adding a new `ChargeKind`:** add the variant here, map it to a
//! `Breakdown` field in [`Ledger::slot`] (the exhaustive match makes
//! forgetting impossible), add the field to `metrics::Breakdown` (its
//! exhaustive destructuring in `total`/`add`/`components` forces the
//! totals/printer decision), and extend the taxonomy table above and in
//! the README.
//!
//! Violations are `debug_assert`ed at the charge site in every run
//! (tests run in debug, so the whole suite exercises them) and recorded
//! so [`Ledger::audit`] / [`Ledger::finish`] also fail in release-mode
//! runs that ask.

use crate::metrics::Breakdown;
use crate::units::Secs;

/// What a charge pays for. See the module-level taxonomy table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChargeKind {
    Compute,
    CommTransfer,
    CommKernel,
    CommQueue,
    /// Memo only — never advances the clock; charge via
    /// [`Ledger::charge_hidden`].
    CommHidden,
    HostReduce,
    H2d,
    LoadStall,
    /// Memo only — never advances the clock; charge via
    /// [`Ledger::charge_hidden_load`].
    LoadHidden,
    Apply,
}

impl ChargeKind {
    /// Does this kind advance the virtual clock? Exhaustive so a new
    /// kind must decide.
    pub fn on_clock(self) -> bool {
        match self {
            ChargeKind::Compute
            | ChargeKind::CommTransfer
            | ChargeKind::CommKernel
            | ChargeKind::CommQueue
            | ChargeKind::HostReduce
            | ChargeKind::H2d
            | ChargeKind::LoadStall
            | ChargeKind::Apply => true,
            ChargeKind::CommHidden | ChargeKind::LoadHidden => false,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ChargeKind::Compute => "compute",
            ChargeKind::CommTransfer => "comm_transfer",
            ChargeKind::CommKernel => "comm_kernel",
            ChargeKind::CommQueue => "comm_queue",
            ChargeKind::CommHidden => "comm_hidden",
            ChargeKind::HostReduce => "host_reduce",
            ChargeKind::H2d => "h2d",
            ChargeKind::LoadStall => "load_stall",
            ChargeKind::LoadHidden => "load_hidden",
            ChargeKind::Apply => "apply",
        }
    }
}

/// Negative-charge tolerance: charges may carry float cancellation noise
/// (e.g. `new_clock - clock` after a `.max(0.0)` wait split) but never a
/// genuinely negative duration.
const NEG_EPS: f64 = 1e-12;

/// A worker's virtual clock and its `Breakdown`, derived from one charge
/// stream so they cannot disagree.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    clock: Secs,
    bd: Breakdown,
    /// Serial-comm budget declared alongside `CommHidden` memos.
    hidden_budget: Secs,
    /// Serial-load budget declared alongside `LoadHidden` memos.
    load_hidden_budget: Secs,
    /// First recorded violation (also `debug_assert`ed at the site).
    err: Option<String>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    pub fn clock(&self) -> Secs {
        self.clock
    }

    /// Copy of the derived breakdown.
    pub fn breakdown(&self) -> Breakdown {
        self.bd
    }

    fn note(&mut self, msg: String) {
        debug_assert!(false, "ledger violation: {msg}");
        if self.err.is_none() {
            self.err = Some(msg);
        }
    }

    /// The breakdown slot a kind accumulates into. Exhaustive on both
    /// sides: a new `ChargeKind` or `Breakdown` field fails to compile
    /// until it is mapped.
    fn slot(&mut self, kind: ChargeKind) -> &mut Secs {
        let Breakdown {
            compute,
            comm_transfer,
            comm_kernel,
            comm_queue,
            comm_hidden,
            host_reduce,
            load_stall,
            load_hidden,
            h2d,
            apply,
        } = &mut self.bd;
        match kind {
            ChargeKind::Compute => compute,
            ChargeKind::CommTransfer => comm_transfer,
            ChargeKind::CommKernel => comm_kernel,
            ChargeKind::CommQueue => comm_queue,
            ChargeKind::CommHidden => comm_hidden,
            ChargeKind::HostReduce => host_reduce,
            ChargeKind::LoadStall => load_stall,
            ChargeKind::LoadHidden => load_hidden,
            ChargeKind::H2d => h2d,
            ChargeKind::Apply => apply,
        }
    }

    /// Charge `secs` of `kind`, advancing the clock when the kind is on
    /// it. `tag` names the site ("bsp.barrier", "easgd.exchange", …) for
    /// violation messages.
    pub fn charge(&mut self, kind: ChargeKind, tag: &'static str, secs: Secs) {
        if !secs.is_finite() || secs < -NEG_EPS {
            self.note(format!("[{tag}] bad {} charge: {secs}", kind.name()));
            return;
        }
        if !kind.on_clock() {
            self.note(format!(
                "[{tag}] memo kind {} must go through charge_hidden/charge_hidden_load",
                kind.name()
            ));
            return;
        }
        *self.slot(kind) += secs;
        self.clock += secs;
    }

    /// Charge the gap up to an externally reconciled clock (a barrier's
    /// max, an exchange's completion time) and land on it *exactly* —
    /// the clock must not drift by re-derived float sums when downstream
    /// virtual arrivals depend on it bit-for-bit.
    pub fn advance_to(&mut self, kind: ChargeKind, tag: &'static str, new_clock: Secs) {
        let delta = new_clock - self.clock;
        if !delta.is_finite() || delta < -NEG_EPS {
            self.note(format!(
                "[{tag}] clock would move backwards: {} -> {new_clock}",
                self.clock
            ));
            return;
        }
        if !kind.on_clock() {
            self.note(format!(
                "[{tag}] memo kind {} must go through charge_hidden/charge_hidden_load",
                kind.name()
            ));
            return;
        }
        *self.slot(kind) += delta;
        self.clock = new_clock;
    }

    /// Memo `hidden` seconds of comm that overlap already-paid time
    /// (wait-free backprop). `overlapped_under` is the serial comm the
    /// hidden time came out of — the audit bound: comm cannot hide more
    /// time than the exchange would have cost serially.
    pub fn charge_hidden(&mut self, tag: &'static str, hidden: Secs, overlapped_under: Secs) {
        self.memo(ChargeKind::CommHidden, tag, hidden, overlapped_under);
    }

    /// Memo `hidden` seconds of loader disk+decode that the parallel
    /// loader child overlapped under compute (Alg. 1). `overlapped_under`
    /// is the load time the direct path would have paid — the audit
    /// bound: the loader cannot hide more time than the load cost.
    pub fn charge_hidden_load(&mut self, tag: &'static str, hidden: Secs, overlapped_under: Secs) {
        self.memo(ChargeKind::LoadHidden, tag, hidden, overlapped_under);
    }

    /// Shared memo path: off-clock charge + its serial budget. Exhaustive
    /// over the memo kinds so a new one must pick a budget slot.
    fn memo(&mut self, kind: ChargeKind, tag: &'static str, hidden: Secs, overlapped_under: Secs) {
        debug_assert!(!kind.on_clock());
        if !hidden.is_finite() || hidden < -NEG_EPS {
            self.note(format!("[{tag}] bad hidden charge: {hidden}"));
            return;
        }
        if hidden.0 > overlapped_under.0 + NEG_EPS.max(1e-9 * overlapped_under.0.abs()) {
            self.note(format!(
                "[{tag}] hidden {hidden} exceeds its overlap budget {overlapped_under}"
            ));
            return;
        }
        *self.slot(kind) += hidden;
        match kind {
            ChargeKind::CommHidden => self.hidden_budget += overlapped_under,
            ChargeKind::LoadHidden => self.load_hidden_budget += overlapped_under,
            _ => unreachable!("memo() is only called with memo kinds"),
        }
    }

    /// Charge one exchange's [`CommReport`](crate::collectives::CommReport),
    /// overlap-aware: pipelined/wait-free savings (`sim_overlapped`) are
    /// hidden kernel time first (the usual case — sums/casts under the
    /// wire), then wire time, then host reduction. The three visible
    /// charges sum to `sim_total() * scale`, so the clock advances by
    /// exactly what the strategy priced.
    pub fn charge_report(
        &mut self,
        tag: &'static str,
        rep: &crate::collectives::CommReport,
        scale: f64,
    ) {
        let k_hidden = rep.sim_overlapped.min(rep.sim_kernel);
        let t_hidden = (rep.sim_overlapped - k_hidden).min(rep.sim_transfer);
        let h_hidden = (rep.sim_overlapped - k_hidden - t_hidden).min(rep.sim_host_reduce);
        self.charge(ChargeKind::CommKernel, tag, (rep.sim_kernel - k_hidden) * scale);
        self.charge(ChargeKind::CommTransfer, tag, (rep.sim_transfer - t_hidden) * scale);
        self.charge(ChargeKind::HostReduce, tag, (rep.sim_host_reduce - h_hidden) * scale);
    }

    /// Check every ledger invariant: breakdown reconciles with the clock,
    /// no component negative, hidden time within its declared overlap
    /// budget, and no violation recorded by an earlier charge.
    pub fn audit(&self) -> Result<(), String> {
        if let Some(err) = &self.err {
            return Err(err.clone());
        }
        let total = self.bd.total();
        let tol = 1e-9 * total.abs().max(self.clock.abs()).max(1.0);
        if (total - self.clock).abs() > tol {
            return Err(format!("breakdown {total} != clock {}", self.clock));
        }
        for (name, v) in self.bd.components() {
            if !(v >= -NEG_EPS) || !v.is_finite() {
                return Err(format!("component {name} = {v}"));
            }
        }
        if self.bd.comm_hidden > self.hidden_budget + tol {
            return Err(format!(
                "hidden {} exceeds overlapped-comm budget {}",
                self.bd.comm_hidden, self.hidden_budget
            ));
        }
        if self.bd.load_hidden > self.load_hidden_budget + tol {
            return Err(format!(
                "hidden load {} exceeds overlapped-load budget {}",
                self.bd.load_hidden, self.load_hidden_budget
            ));
        }
        Ok(())
    }

    /// Close the ledger: audit (debug-asserted — every `cargo test` run
    /// exercises it) and hand back the derived clock and breakdown.
    pub fn finish(self) -> (Secs, Breakdown) {
        debug_assert!(self.audit().is_ok(), "{:?}", self.audit());
        (self.clock, self.bd)
    }
}

/// A shard server's queue clock: requests serve at
/// `max(clock, arrival) + handle`, and total occupancy accumulates —
/// the one self-referential clock update the engines need outside
/// [`Ledger`], typed so the lint can reject ad-hoc copies.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerClock {
    clock: Secs,
    busy: Secs,
}

impl ServerClock {
    pub fn new() -> ServerClock {
        ServerClock::default()
    }

    /// Serve one request; returns its finish time (the new clock).
    pub fn serve(&mut self, arrival: Secs, handle: Secs) -> Secs {
        debug_assert!(
            arrival.is_finite() && handle.is_finite() && handle >= 0.0,
            "bad serve: arrival={arrival} handle={handle}"
        );
        self.clock = self.clock.max(arrival) + handle;
        self.busy += handle;
        self.clock
    }

    pub fn clock(&self) -> Secs {
        self.clock
    }

    /// Total handling occupancy — never exceeds the clock when arrivals
    /// are non-negative.
    pub fn busy(&self) -> Secs {
        self.busy
    }

    pub fn audit(&self) -> Result<(), String> {
        if self.busy < 0.0 || self.clock < 0.0 {
            return Err(format!("negative server time: busy={} clock={}", self.busy, self.clock));
        }
        if self.busy > self.clock + 1e-9 * self.clock.max(1.0) {
            return Err(format!("server busy {} exceeds its clock {}", self.busy, self.clock));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CommReport;

    #[test]
    fn ledger_reconciles_by_construction() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::Compute, "t", Secs(1.5));
        l.charge(ChargeKind::H2d, "t", Secs(0.25));
        l.charge(ChargeKind::CommTransfer, "t", Secs(0.5));
        l.charge(ChargeKind::Apply, "t", Secs(0.125));
        l.audit().unwrap();
        let (clock, bd) = l.finish();
        assert!((clock - Secs(2.375)).abs() < 1e-12);
        assert!((bd.total() - clock).abs() < 1e-12);
        assert!((bd.compute - Secs(1.5)).abs() < 1e-12);
    }

    #[test]
    fn advance_to_lands_exactly() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::Compute, "t", Secs(0.1 + 0.2)); // 0.30000000000000004
        let target = 1.0000000000000002f64;
        l.advance_to(ChargeKind::CommQueue, "t", Secs(target));
        assert_eq!(l.clock().0.to_bits(), target.to_bits(), "no float drift allowed");
        l.audit().unwrap();
        let (_, bd) = l.finish();
        assert!(bd.comm_queue > 0.69 && bd.comm_queue < 0.71);
    }

    #[test]
    fn hidden_is_memo_and_budget_bounded() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::CommTransfer, "t", Secs(0.2));
        l.charge_hidden("t", Secs(0.5), Secs(0.8));
        assert!((l.clock() - Secs(0.2)).abs() < 1e-12, "hidden must not advance the clock");
        let bd = l.breakdown();
        assert!((bd.comm_hidden - Secs(0.5)).abs() < 1e-12);
        assert!((bd.total() - Secs(0.2)).abs() < 1e-12, "memo stays out of total()");
        l.audit().unwrap();
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "ledger violation"))]
    fn hidden_beyond_budget_is_a_violation() {
        let mut l = Ledger::new();
        l.charge_hidden("t", Secs(1.0), Secs(0.5));
        // release builds record instead of panicking
        assert!(l.audit().is_err());
    }

    #[test]
    fn hidden_load_is_memo_and_budget_bounded() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::LoadStall, "t", Secs(0.1));
        l.charge_hidden_load("t", Secs(0.3), Secs(0.4));
        assert!((l.clock() - Secs(0.1)).abs() < 1e-12, "hidden load must not advance the clock");
        let bd = l.breakdown();
        assert!((bd.load_hidden - Secs(0.3)).abs() < 1e-12);
        assert!((bd.total() - Secs(0.1)).abs() < 1e-12, "memo stays out of total()");
        // the two memo budgets are independent: comm budget unused here
        assert!((bd.comm_hidden - Secs(0.0)).abs() < 1e-12);
        l.audit().unwrap();
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "ledger violation"))]
    fn hidden_load_beyond_budget_is_a_violation() {
        let mut l = Ledger::new();
        l.charge_hidden_load("t", Secs(1.0), Secs(0.5));
        assert!(l.audit().is_err());
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "ledger violation"))]
    fn memo_kind_rejected_by_charge() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::LoadHidden, "t", Secs(0.5));
        assert!(l.audit().is_err());
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "ledger violation"))]
    fn negative_charge_is_a_violation() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::Compute, "t", Secs(-0.5));
        assert!(l.audit().is_err());
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "ledger violation"))]
    fn clock_cannot_move_backwards() {
        let mut l = Ledger::new();
        l.charge(ChargeKind::Compute, "t", Secs(1.0));
        l.advance_to(ChargeKind::CommQueue, "t", Secs(0.5));
        assert!(l.audit().is_err());
    }

    #[test]
    fn charge_report_advances_clock_by_sim_total() {
        let rep = CommReport {
            sim_transfer: Secs(0.9),
            sim_kernel: Secs(0.05),
            sim_host_reduce: Secs(0.3),
            sim_overlapped: Secs(0.1),
            ..Default::default()
        };
        let mut l = Ledger::new();
        l.charge_report("t", &rep, 2.0);
        let want = rep.sim_total() * 2.0;
        assert!((l.clock() - want).abs() < 1e-12 * want.max(1.0), "{} vs {want}", l.clock());
        let bd = l.breakdown();
        // overlap hides kernel time first: 0.05 kernel fully hidden, the
        // remaining 0.05 of overlap comes off the wire
        assert!((bd.comm_kernel - Secs(0.0)).abs() < 1e-12);
        assert!((bd.comm_transfer - Secs((0.9 - 0.05) * 2.0)).abs() < 1e-12);
        assert!((bd.host_reduce - Secs(0.6)).abs() < 1e-12);
        l.audit().unwrap();
    }

    #[test]
    fn every_kind_maps_to_a_distinct_slot() {
        let kinds = [
            ChargeKind::Compute,
            ChargeKind::CommTransfer,
            ChargeKind::CommKernel,
            ChargeKind::CommQueue,
            ChargeKind::HostReduce,
            ChargeKind::H2d,
            ChargeKind::LoadStall,
            ChargeKind::Apply,
        ];
        let mut l = Ledger::new();
        for (i, k) in kinds.iter().enumerate() {
            assert!(k.on_clock());
            l.charge(*k, "t", Secs((i + 1) as f64));
        }
        assert!(!ChargeKind::CommHidden.on_clock());
        assert!(!ChargeKind::LoadHidden.on_clock());
        let (clock, bd) = l.finish();
        assert!((clock - Secs(36.0)).abs() < 1e-12);
        let named: Vec<Secs> = bd.components().iter().map(|&(_, v)| v).collect();
        // 8 on-clock slots hold 1..=8, the memo slots stay 0
        let mut nonzero: Vec<f64> =
            named.iter().copied().filter(|v| *v > 0.0).map(|v| v.0).collect();
        nonzero.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(nonzero, (1..=8).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn server_clock_queues_and_audits() {
        let mut q = ServerClock::new();
        assert_eq!(q.serve(Secs(1.0), Secs(0.5)), 1.5);
        assert_eq!(q.serve(Secs(1.0), Secs(0.5)), 2.0, "busy server queues the second request");
        assert_eq!(q.serve(Secs(10.0), Secs(0.25)), 10.25, "idle server waits for the arrival");
        assert!((q.busy() - Secs(1.25)).abs() < 1e-12);
        q.audit().unwrap();
    }
}
