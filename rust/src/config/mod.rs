//! Config system: a TOML-subset parser + typed experiment configs.
//!
//! The offline container vendors no TOML crate, so this is an in-tree
//! parser covering the subset the framework uses: `[section]` headers and
//! `key = value` pairs with strings, integers, floats, booleans and flat
//! arrays. `BspConfig`/`EasgdConfig` build from a parsed file via
//! `from_table`, with every field optional over the `quick()` defaults —
//! the launcher (`tmpi train --config run.toml`) is driven by this.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::bsp::BspConfig;
use crate::collectives::{OverlapMode, StrategyKind, WireFormat};
use crate::easgd::{EasgdConfig, Transport};
use crate::plan::{validate_sizing_kib, ExchangePlan};
use crate::sgd::{LrSchedule, Scheme};

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// section -> key -> value
pub type Table = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse the TOML subset. Unknown syntax is a hard error (configs should
/// never be silently misread).
pub fn parse(text: &str) -> Result<Table> {
    let mut out: Table = BTreeMap::new();
    let mut section = String::new();
    out.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected 'key = value'", lineno + 1))?;
        let value = parse_value(v.trim())
            .with_context(|| format!("line {}: bad value '{}'", lineno + 1, v.trim()))?;
        out.get_mut(&section).unwrap().insert(k.trim().to_string(), value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // naive but safe for our configs: '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Value> {
    if let Some(inner) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value")
}

/// Read `[train]`-section BSP config over `BspConfig::quick` defaults.
pub fn bsp_from_file(path: &Path) -> Result<BspConfig> {
    let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
    let table = parse(&text)?;
    bsp_from_table(&table)
}

pub fn bsp_from_table(table: &Table) -> Result<BspConfig> {
    let t = table.get("train").or_else(|| table.get("")).ok_or_else(|| anyhow!("no [train]"))?;
    let mut cfg = BspConfig::quick(
        t.get("model").map(|v| v.as_str()).transpose()?.unwrap_or("mlp"),
        t.get("workers").map(|v| v.as_usize()).transpose()?.unwrap_or(2),
        t.get("iters").map(|v| v.as_usize()).transpose()?.unwrap_or(50),
    );
    if let Some(v) = t.get("batch") {
        cfg.batch = v.as_usize()?;
    }
    if let Some(v) = t.get("scheme") {
        cfg.scheme = Scheme::parse(v.as_str()?).ok_or_else(|| anyhow!("bad scheme"))?;
    }
    if let Some(v) = t.get("momentum") {
        cfg.momentum = v.as_f64()?;
    }
    if let Some(v) = t.get("eval_every") {
        cfg.eval_every = v.as_usize()?;
    }
    if let Some(v) = t.get("topology") {
        cfg.topology = v.as_str()?.to_string();
    }
    if let Some(v) = t.get("cuda_aware") {
        cfg.cuda_aware = v.as_bool()?;
    }
    if let Some(v) = t.get("seed") {
        cfg.seed = v.as_usize()? as u64;
    }
    if let Some(v) = t.get("use_loader") {
        cfg.use_loader = v.as_bool()?;
    }
    if let Some(v) = t.get("prefetch_depth") {
        cfg.prefetch_depth = v.as_usize()?;
    }
    if let Some(v) = t.get("cache_mib") {
        cfg.cache_mib = v.as_usize()?;
    }
    if let Some(v) = t.get("sim_model") {
        cfg.sim_model = Some(v.as_str()?.to_string());
    }
    if let Some(v) = t.get("data_dir") {
        cfg.data_dir = Some(PathBuf::from(v.as_str()?));
    }
    if let Some(v) = t.get("exchange_momentum") {
        cfg.exchange_momentum = v.as_bool()?;
    }
    // legacy exchange knobs in [train] fill the embedded plan...
    apply_plan_keys(&mut cfg.plan, t)?;
    // ...and an explicit [plan] section (e.g. pasted from `tmpi plan`
    // output) wins over them key by key
    if let Some(p) = table.get("plan") {
        apply_plan_keys(&mut cfg.plan, p)?;
    }
    cfg.lr = lr_from(t)?;
    Ok(cfg)
}

/// Apply exchange-plan keys from a key-value section over `plan`'s current
/// values: `strategy`/`exchange` (the latter wins when both appear), `wire`,
/// `chunk_kib`, `pipeline`, `overlap`, `bucket_kib`, `servers`. Written-out
/// sizing zeros are rejected ([`validate_sizing_kib`]) — omitting the key
/// is how the monolithic/off default is spelled.
pub fn apply_plan_keys(plan: &mut ExchangePlan, t: &BTreeMap<String, Value>) -> Result<()> {
    if let Some(v) = t.get("strategy") {
        plan.strategy = StrategyKind::from_name(v.as_str()?)?;
    }
    // `exchange` is the preferred spelling (it also selects `hier:<inner>`
    // compositions); it wins when both keys are present
    if let Some(v) = t.get("exchange") {
        plan.strategy = StrategyKind::from_name(v.as_str()?)?;
    }
    // gradient wire format: dense (f32|f16|bf16) or compressed
    // (topk:<p>|onebit|sf); compressed wires carry per-rank error feedback
    if let Some(v) = t.get("wire") {
        plan.wire = Some(WireFormat::from_name(v.as_str()?)?);
    }
    if let Some(v) = t.get("chunk_kib") {
        plan.chunk_kib = validate_sizing_kib("chunk_kib", v.as_usize()?)?;
    }
    if let Some(v) = t.get("pipeline") {
        plan.pipeline = v.as_bool()?;
    }
    // wait-free backprop: when to exchange gradients vs the backward pass
    if let Some(v) = t.get("overlap") {
        plan.overlap = OverlapMode::from_name(v.as_str()?)?;
    }
    if let Some(v) = t.get("bucket_kib") {
        plan.bucket_kib = validate_sizing_kib("bucket_kib", v.as_usize()?)?;
    }
    // parameter-server shards (EASGD; BSP ignores the axis); same message
    // as ShardPlan::new's run-time validation
    if let Some(v) = t.get("servers") {
        plan.servers = v.as_usize()?;
        if plan.servers == 0 {
            bail!("servers must be >= 1 (got 0)");
        }
    }
    Ok(())
}

/// Parse a standalone plan file (`tmpi plan` output / `--plan <path>`):
/// a `[plan]` section applied over [`ExchangePlan::default`].
pub fn plan_from_file(path: &Path) -> Result<ExchangePlan> {
    let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
    plan_from_text(&text)
}

pub fn plan_from_text(text: &str) -> Result<ExchangePlan> {
    let table = parse(text)?;
    let t = table.get("plan").ok_or_else(|| anyhow!("no [plan] section"))?;
    let mut plan = ExchangePlan::default();
    apply_plan_keys(&mut plan, t)?;
    Ok(plan)
}

/// lr schedule keys: lr (base) + lr_policy = "const"|"step"|"poly" (+
/// lr_step_every, lr_step_factor, lr_poly_power, lr_max_iters)
fn lr_from(t: &BTreeMap<String, Value>) -> Result<LrSchedule> {
    let base = t.get("lr").map(|v| v.as_f64()).transpose()?.unwrap_or(0.01);
    let policy = t.get("lr_policy").map(|v| v.as_str()).transpose()?.unwrap_or("const");
    Ok(match policy {
        "const" => LrSchedule::Const { base },
        "step" => LrSchedule::StepDecay {
            base,
            factor: t.get("lr_step_factor").map(|v| v.as_f64()).transpose()?.unwrap_or(0.1),
            every: t.get("lr_step_every").map(|v| v.as_usize()).transpose()?.unwrap_or(100),
        },
        "poly" => LrSchedule::Poly {
            base,
            power: t.get("lr_poly_power").map(|v| v.as_f64()).transpose()?.unwrap_or(0.5),
            max_iters: t.get("lr_max_iters").map(|v| v.as_usize()).transpose()?.unwrap_or(1000),
        },
        p => bail!("unknown lr_policy '{p}'"),
    })
}

/// Read `[easgd]`-section config.
pub fn easgd_from_file(path: &Path) -> Result<EasgdConfig> {
    let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
    let table = parse(&text)?;
    let t = table.get("easgd").ok_or_else(|| anyhow!("no [easgd] section"))?;
    let mut cfg = EasgdConfig::quick(
        t.get("model").map(|v| v.as_str()).transpose()?.unwrap_or("mlp"),
        t.get("workers").map(|v| v.as_usize()).transpose()?.unwrap_or(2),
        t.get("iters").map(|v| v.as_usize()).transpose()?.unwrap_or(50),
    );
    if let Some(v) = t.get("batch") {
        cfg.batch = v.as_usize()?;
    }
    if let Some(v) = t.get("alpha") {
        cfg.alpha = v.as_f64()?;
    }
    if let Some(v) = t.get("tau") {
        cfg.tau = v.as_usize()?;
    }
    if let Some(v) = t.get("momentum") {
        cfg.momentum = v.as_f64()?;
    }
    if let Some(v) = t.get("eval_every") {
        cfg.eval_every = v.as_usize()?;
    }
    if let Some(v) = t.get("topology") {
        cfg.topology = v.as_str()?.to_string();
    }
    if let Some(v) = t.get("transport") {
        cfg.transport = match v.as_str()? {
            "cuda-aware-mpi" | "mpi" => Transport::CudaAwareMpi,
            "platoon-shm" | "shm" => Transport::PlatoonShm,
            x => bail!("bad transport '{x}'"),
        };
    }
    if let Some(v) = t.get("seed") {
        cfg.seed = v.as_usize()? as u64;
    }
    if let Some(v) = t.get("sim_model") {
        cfg.sim_model = Some(v.as_str()?.to_string());
    }
    // legacy exchange knobs in [easgd] fill the embedded plan (the
    // `exchange` strategy name is the wire-format driver here), then an
    // explicit [plan] section wins key by key
    apply_plan_keys(&mut cfg.plan, t)?;
    if let Some(p) = table.get("plan") {
        apply_plan_keys(&mut cfg.plan, p)?;
    }
    // elastic exchange wire override: dense formats only — the center
    // pull/push ships full parameters, not gradients, so sparsifying
    // wires have no error-feedback stream to ride on
    if let Some(fmt) = cfg.plan.wire {
        if fmt.compressed() {
            bail!(
                "easgd wire '{}' unsupported: elastic exchange ships full \
                 parameters, not gradients (use f32|f16|bf16)",
                fmt.name()
            );
        }
    }
    cfg.lr = lr_from(t)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
[train]
model = "alexnet"        # inline comment
workers = 8
iters = 200
batch = 32
scheme = "subgd"
strategy = "asa16"
wire = "f16"
lr = 0.005
lr_policy = "step"
lr_step_every = 40
topology = "mosaic"
cuda_aware = true
sim_model = "alexnet"
chunk_kib = 4096
pipeline = true
use_loader = true
prefetch_depth = 4
cache_mib = 64

[easgd]
model = "mlp"
workers = 4
iters = 100
alpha = 0.5
tau = 1
transport = "platoon-shm"
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(SAMPLE).unwrap();
        assert_eq!(t["train"]["workers"], Value::Int(8));
        assert_eq!(t["train"]["model"], Value::Str("alexnet".into()));
        assert_eq!(t["train"]["cuda_aware"], Value::Bool(true));
        assert_eq!(t["easgd"]["alpha"], Value::Float(0.5));
    }

    #[test]
    fn bsp_config_roundtrip() {
        let t = parse(SAMPLE).unwrap();
        let cfg = bsp_from_table(&t).unwrap();
        assert_eq!(cfg.model, "alexnet");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.scheme, Scheme::Subgd);
        assert_eq!(cfg.plan.strategy, StrategyKind::Asa16);
        assert_eq!(cfg.plan.wire, Some(WireFormat::F16));
        assert_eq!(cfg.sim_model.as_deref(), Some("alexnet"));
        assert_eq!(cfg.plan.chunk_kib, 4096);
        assert!(cfg.plan.pipeline);
        assert!(cfg.use_loader);
        assert_eq!(cfg.prefetch_depth, 4);
        assert_eq!(cfg.cache_mib, 64);
        match cfg.lr {
            LrSchedule::StepDecay { base, every, .. } => {
                assert!((base - 0.005).abs() < 1e-12);
                assert_eq!(every, 40);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn exchange_key_selects_hier_and_wins_over_strategy() {
        use crate::collectives::FlatKind;
        let t = parse("[train]\nstrategy = \"asa\"\nexchange = \"hier:asa16\"").unwrap();
        let cfg = bsp_from_table(&t).unwrap();
        assert_eq!(cfg.plan.strategy, StrategyKind::Hier { inner: FlatKind::Asa16 });
        // and alone
        let t = parse("[train]\nexchange = \"hier:ring\"").unwrap();
        assert_eq!(
            bsp_from_table(&t).unwrap().plan.strategy,
            StrategyKind::Hier { inner: FlatKind::Ring }
        );
    }

    #[test]
    fn easgd_exchange_key_parses_and_rejects_bad_inner() {
        let p = std::env::temp_dir().join(format!("tmpi_cfg_ex_{}.toml", std::process::id()));
        std::fs::write(&p, "[easgd]\nworkers = 2\nexchange = \"hier:asa16\"").unwrap();
        let cfg = easgd_from_file(&p).unwrap();
        assert!(cfg.plan.strategy.half_wire());
        std::fs::write(&p, "[easgd]\nexchange = \"hier:warp\"").unwrap();
        let err = easgd_from_file(&p).unwrap_err().to_string();
        assert!(err.contains("warp") && err.contains("asa16"), "{err}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn overlap_and_bucket_kib_keys_parse_and_reject_bad_modes() {
        let t = parse("[train]\noverlap = \"wfbp\"\nbucket_kib = 4096").unwrap();
        let cfg = bsp_from_table(&t).unwrap();
        assert_eq!(cfg.plan.overlap, OverlapMode::Wfbp);
        assert_eq!(cfg.plan.bucket_kib, 4096);
        // the serial ablation and the default
        let t = parse("[train]\noverlap = \"post\"").unwrap();
        assert_eq!(bsp_from_table(&t).unwrap().plan.overlap, OverlapMode::Post);
        let t = parse("[train]\nworkers = 2").unwrap();
        let cfg = bsp_from_table(&t).unwrap();
        assert_eq!(cfg.plan.overlap, OverlapMode::None);
        assert_eq!(cfg.plan.bucket_kib, 0);
        // bad mode names the valid set
        let t = parse("[train]\noverlap = \"sometimes\"").unwrap();
        let err = bsp_from_table(&t).unwrap_err().to_string();
        assert!(err.contains("sometimes") && err.contains("wfbp"), "{err}");
    }

    #[test]
    fn wire_key_parses_compressed_formats_and_rejects_junk() {
        let t = parse("[train]\nwire = \"topk:0.01\"").unwrap();
        assert_eq!(bsp_from_table(&t).unwrap().plan.wire, Some(WireFormat::TopK { p: 0.01 }));
        let t = parse("[train]\nwire = \"onebit\"").unwrap();
        assert_eq!(bsp_from_table(&t).unwrap().plan.wire, Some(WireFormat::OneBit));
        let t = parse("[train]\nwire = \"sf\"").unwrap();
        assert_eq!(bsp_from_table(&t).unwrap().plan.wire, Some(WireFormat::Sf));
        // default stays full-width
        let t = parse("[train]\nworkers = 2").unwrap();
        assert_eq!(bsp_from_table(&t).unwrap().plan.wire_format(), WireFormat::F32);
        // bad name lists the valid family
        let t = parse("[train]\nwire = \"q4\"").unwrap();
        let err = bsp_from_table(&t).unwrap_err().to_string();
        assert!(err.contains("q4") && err.contains("topk"), "{err}");
    }

    #[test]
    fn easgd_wire_key_allows_dense_and_rejects_compressed() {
        let p = std::env::temp_dir().join(format!("tmpi_cfg_wire_{}.toml", std::process::id()));
        std::fs::write(&p, "[easgd]\nworkers = 2\nwire = \"bf16\"").unwrap();
        assert_eq!(easgd_from_file(&p).unwrap().plan.wire, Some(WireFormat::Bf16));
        // unset leaves the strategy-derived default
        std::fs::write(&p, "[easgd]\nworkers = 2").unwrap();
        assert_eq!(easgd_from_file(&p).unwrap().plan.wire, None);
        std::fs::write(&p, "[easgd]\nwire = \"onebit\"").unwrap();
        let err = easgd_from_file(&p).unwrap_err().to_string();
        assert!(err.contains("full") && err.contains("parameters"), "{err}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn bad_strategy_error_names_the_valid_set() {
        let t = parse("[train]\nstrategy = \"warpspeed\"").unwrap();
        let err = bsp_from_table(&t).unwrap_err().to_string();
        assert!(err.contains("warpspeed"), "{err}");
        assert!(err.contains("asa16"), "{err}");
        // and case-insensitive names parse fine
        let t = parse("[train]\nstrategy = \"RING\"").unwrap();
        assert_eq!(bsp_from_table(&t).unwrap().plan.strategy, StrategyKind::Ring);
    }

    #[test]
    fn arrays_and_errors() {
        let t = parse("xs = [1, 2, 3]\nname = \"a\"").unwrap();
        assert_eq!(
            t[""]["xs"],
            Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert!(parse("broken line").is_err());
        assert!(parse("k = @nope").is_err());
    }

    #[test]
    fn easgd_servers_key_parses_and_rejects_zero() {
        let p = std::env::temp_dir().join(format!("tmpi_cfg_srv_{}.toml", std::process::id()));
        std::fs::write(&p, "[easgd]\nworkers = 8\nservers = 4").unwrap();
        let cfg = easgd_from_file(&p).unwrap();
        assert_eq!(cfg.plan.servers, 4);
        // default stays the single-server paper model
        std::fs::write(&p, "[easgd]\nworkers = 8").unwrap();
        assert_eq!(easgd_from_file(&p).unwrap().plan.servers, 1);
        std::fs::write(&p, "[easgd]\nservers = 0").unwrap();
        let err = easgd_from_file(&p).unwrap_err().to_string();
        assert!(err.contains("servers"), "{err}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn written_out_sizing_zeros_are_rejected() {
        // ISSUE 10 satellite: `chunk_kib = 0` / `bucket_kib = 0` used to
        // pass straight through as_usize(); an explicit 0 is a typo'd real
        // size, not a way to spell the default
        for (section, key) in
            [("train", "chunk_kib"), ("train", "bucket_kib"), ("easgd", "chunk_kib")]
        {
            let text = format!("[{section}]\nworkers = 2\n{key} = 0");
            let t = parse(&text).unwrap();
            let err = if section == "train" {
                bsp_from_table(&t).unwrap_err().to_string()
            } else {
                let p =
                    std::env::temp_dir().join(format!("tmpi_cfg_zero_{}.toml", std::process::id()));
                std::fs::write(&p, &text).unwrap();
                let e = easgd_from_file(&p).unwrap_err().to_string();
                let _ = std::fs::remove_file(p);
                e
            };
            assert!(err.contains(&format!("{key} = 0")), "{err}");
            assert!(err.contains("1..=1048576"), "{err}");
            assert!(err.contains("omit the key"), "{err}");
        }
        // the upper bound is enforced too
        let t = parse("[train]\nchunk_kib = 1048577").unwrap();
        let err = bsp_from_table(&t).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // omitting the keys keeps the monolithic/off defaults
        let t = parse("[train]\nworkers = 2").unwrap();
        let cfg = bsp_from_table(&t).unwrap();
        assert_eq!(cfg.plan.chunk_kib, 0);
        assert_eq!(cfg.plan.bucket_kib, 0);
    }

    #[test]
    fn plan_section_wins_over_legacy_train_keys() {
        let t = parse(
            "[train]\nexchange = \"asa\"\nchunk_kib = 1024\n\n[plan]\nexchange = \"ring\"",
        )
        .unwrap();
        let cfg = bsp_from_table(&t).unwrap();
        // [plan] overrides the keys it names; the rest keep legacy values
        assert_eq!(cfg.plan.strategy, StrategyKind::Ring);
        assert_eq!(cfg.plan.chunk_kib, 1024);
        // same layering for [easgd]
        let p = std::env::temp_dir().join(format!("tmpi_cfg_plan_{}.toml", std::process::id()));
        std::fs::write(&p, "[easgd]\nworkers = 4\nservers = 2\n\n[plan]\nservers = 4").unwrap();
        assert_eq!(easgd_from_file(&p).unwrap().plan.servers, 4);
        // compressed wires stay rejected even when smuggled via [plan]
        std::fs::write(&p, "[easgd]\nworkers = 4\n\n[plan]\nwire = \"onebit\"").unwrap();
        let err = easgd_from_file(&p).unwrap_err().to_string();
        assert!(err.contains("full") && err.contains("parameters"), "{err}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn standalone_plan_files_parse_and_require_the_section() {
        let plan = plan_from_text(
            "# cached by tmpi plan\n[plan]\nexchange = \"asa16\"\nchunk_kib = 256\n\
             pipeline = false\noverlap = \"wfbp\"\nbucket_kib = 4096\nservers = 2",
        )
        .unwrap();
        assert_eq!(plan.strategy, StrategyKind::Asa16);
        assert_eq!(plan.chunk_kib, 256);
        assert!(!plan.pipeline);
        assert_eq!(plan.overlap, OverlapMode::Wfbp);
        assert_eq!(plan.bucket_kib, 4096);
        assert_eq!(plan.servers, 2);
        assert_eq!(plan.wire, None);
        let err = plan_from_text("[train]\nexchange = \"asa\"").unwrap_err().to_string();
        assert!(err.contains("[plan]"), "{err}");
    }

    #[test]
    fn easgd_config_from_text() {
        let t = parse(SAMPLE).unwrap();
        let _ = t;
        let p = std::env::temp_dir().join(format!("tmpi_cfg_{}.toml", std::process::id()));
        std::fs::write(&p, SAMPLE).unwrap();
        let cfg = easgd_from_file(&p).unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.tau, 1);
        assert_eq!(cfg.transport, Transport::PlatoonShm);
        let _ = std::fs::remove_file(p);
    }
}
