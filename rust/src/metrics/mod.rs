//! Timing accounting and paper-style reporting.
//!
//! The paper's headline metric is **data throughput speedup**: the change in
//! total (train + communication) time to process a fixed number of examples
//! (footnote 4). `Breakdown` carries exactly that decomposition per worker,
//! and `speedup` computes the ratio the tables report.

use std::time::Instant;

use crate::units::Secs;

/// Per-worker virtual-time decomposition of a run. Every component is a
/// [`Secs`] — the dimensional type system makes charging a microsecond or
/// byte quantity into a lane a compile error.
///
/// Fields are only ever charged through [`audit::Ledger`](crate::audit::Ledger)
/// (enforced by `scripts/lint_charges.py`), and every aggregate here —
/// [`comm`](Self::comm), [`total`](Self::total), [`add`](Self::add),
/// [`components`](Self::components) — destructures the struct exhaustively,
/// so adding a field without deciding where it belongs fails to compile.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    /// PJRT execution of train/grad steps (real, measured).
    pub compute: Secs,
    /// Simulated wire time of parameter exchange (incl. EASGD server
    /// handling).
    pub comm_transfer: Secs,
    /// Simulated GPU kernel time inside exchange (sum / cast).
    pub comm_kernel: Secs,
    /// Time spent waiting on peers: EASGD shard-queue waits beyond an
    /// exchange's own wire + handling, and BSP barrier straggle.
    pub comm_queue: Secs,
    /// Exchange time hidden under the backward pass by wait-free backprop
    /// (`overlap = "wfbp"`). Memo only: the clock never paid it, so it is
    /// *not* part of [`comm`](Self::comm) or [`total`](Self::total) —
    /// `comm + comm_hidden` is what the post-backward path would have cost.
    pub comm_hidden: Secs,
    /// Simulated host CPU reduction time (the AR baseline's butterfly
    /// summation rounds).
    pub host_reduce: Secs,
    /// Time blocked waiting for the parallel loader (overlap miss).
    pub load_stall: Secs,
    /// Loader disk+decode time the parallel child hid under compute
    /// (Alg. 1's overlap win). Memo only: the clock never paid it, so it
    /// is *not* part of [`total`](Self::total) — `load_stall + load_hidden`
    /// is what the direct (synchronous) loader would have paid.
    pub load_hidden: Secs,
    /// Simulated H2D staging of input batches. Charged on *both* loader
    /// paths — the PCIe crossing is real either way; the parallel child
    /// only overlaps the disk+decode part (see `load_hidden`).
    pub h2d: Secs,
    /// SUBGD second half: sgd_apply execution (real, measured).
    pub apply: Secs,
}

impl Breakdown {
    /// Everything exchange-related the clock paid: wire, kernels, peer
    /// waits, and host reduction.
    pub fn comm(&self) -> Secs {
        let Breakdown {
            compute: _,
            comm_transfer,
            comm_kernel,
            comm_queue,
            comm_hidden: _, // memo: the clock never paid it
            host_reduce,
            load_stall: _,
            load_hidden: _, // memo: the clock never paid it
            h2d: _,
            apply: _,
        } = *self;
        comm_transfer + comm_kernel + comm_queue + host_reduce
    }

    /// Sum of every component — reconciles with the virtual clock exactly
    /// (barrier straggle is charged to `comm_queue` by the ledger).
    pub fn total(&self) -> Secs {
        let Breakdown {
            compute,
            comm_transfer: _, // via comm()
            comm_kernel: _,
            comm_queue: _,
            comm_hidden: _, // memo: the clock never paid it
            host_reduce: _,
            load_stall,
            load_hidden: _, // memo: the clock never paid it
            h2d,
            apply,
        } = *self;
        compute + self.comm() + load_stall + h2d + apply
    }

    pub fn add(&mut self, other: &Breakdown) {
        let Breakdown {
            compute,
            comm_transfer,
            comm_kernel,
            comm_queue,
            comm_hidden,
            host_reduce,
            load_stall,
            load_hidden,
            h2d,
            apply,
        } = *other;
        self.compute += compute;
        self.comm_transfer += comm_transfer;
        self.comm_kernel += comm_kernel;
        self.comm_queue += comm_queue;
        self.comm_hidden += comm_hidden;
        self.host_reduce += host_reduce;
        self.load_stall += load_stall;
        self.load_hidden += load_hidden;
        self.h2d += h2d;
        self.apply += apply;
    }

    /// Every component, named — the one source printers and audits iterate
    /// so a new field shows up everywhere or nowhere compiles.
    pub fn components(&self) -> [(&'static str, Secs); 10] {
        let Breakdown {
            compute,
            comm_transfer,
            comm_kernel,
            comm_queue,
            comm_hidden,
            host_reduce,
            load_stall,
            load_hidden,
            h2d,
            apply,
        } = *self;
        [
            ("compute", compute),
            ("comm_transfer", comm_transfer),
            ("comm_kernel", comm_kernel),
            ("comm_queue", comm_queue),
            ("comm_hidden", comm_hidden),
            ("host_reduce", host_reduce),
            ("load_stall", load_stall),
            ("load_hidden", load_hidden),
            ("h2d", h2d),
            ("apply", apply),
        ]
    }

    /// The memo fields (never on the clock) — printers that report "time
    /// spent" filter these, overlap reporting reads them explicitly.
    pub const MEMO_FIELDS: [&'static str; 2] = ["comm_hidden", "load_hidden"];

    /// Fraction of exchange time spent in the GPU kernel (paper §3.2
    /// measures 1.6 % for the ASA summation kernel).
    pub fn kernel_share_of_comm(&self) -> f64 {
        if self.comm() <= 0.0 {
            0.0
        } else {
            self.comm_kernel / self.comm()
        }
    }
}

/// Data throughput speedup of a k-worker run vs the 1-GPU baseline,
/// normalized to the same number of examples (paper footnote 4/5).
pub fn speedup(t1_per_example: f64, tk_per_example: f64) -> f64 {
    if tk_per_example <= 0.0 {
        return 0.0;
    }
    t1_per_example / tk_per_example
}

/// Wall-clock stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Fixed-width table printer (the `tmpi repro …` stdout format).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals() {
        let b = Breakdown {
            compute: Secs(1.0),
            comm_transfer: Secs(0.5),
            comm_kernel: Secs(0.01),
            comm_queue: Secs(0.04),
            comm_hidden: Secs(0.33),
            host_reduce: Secs(0.07),
            load_stall: Secs(0.1),
            load_hidden: Secs(0.11),
            h2d: Secs(0.2),
            apply: Secs(0.05),
        };
        assert!((b.comm() - Secs(0.62)).abs() < 1e-12);
        // comm_hidden / load_hidden are memos of time NOT paid: never in totals
        assert!((b.total() - Secs(1.97)).abs() < 1e-12);
        assert!((b.kernel_share_of_comm() - 0.01 / 0.62).abs() < 1e-12);
        let mut sum = b;
        sum.add(&b);
        assert!((sum.total() - Secs(3.94)).abs() < 1e-12);
        assert!((sum.comm_queue - Secs(0.08)).abs() < 1e-12);
        assert!((sum.comm_hidden - Secs(0.66)).abs() < 1e-12);
        assert!((sum.load_hidden - Secs(0.22)).abs() < 1e-12);
        assert!((sum.host_reduce - Secs(0.14)).abs() < 1e-12);
        assert!((sum.h2d - Secs(0.4)).abs() < 1e-12);
    }

    /// Regression for the piecemeal-added-field hazard: a fully-populated
    /// `Breakdown` must satisfy `total() == sum of every on-clock field`
    /// and `components()` must enumerate each field exactly once, so an
    /// addition that skips `total()`/`add()`/printers cannot land silently.
    #[test]
    fn fully_populated_breakdown_reconciles_with_field_sum() {
        // distinct powers of two: any omission or double-count is visible
        let b = Breakdown {
            compute: Secs(1.0),
            comm_transfer: Secs(2.0),
            comm_kernel: Secs(4.0),
            comm_queue: Secs(8.0),
            comm_hidden: Secs(16.0),
            host_reduce: Secs(32.0),
            load_stall: Secs(64.0),
            load_hidden: Secs(512.0),
            h2d: Secs(128.0),
            apply: Secs(256.0),
        };
        let comps = b.components();
        assert_eq!(comps.len(), 10);
        let mut names: Vec<&str> = comps.iter().map(|&(n, _)| n).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 10, "components() must enumerate each field once");
        let sum_all: Secs = comps.iter().map(|&(_, v)| v).sum();
        assert!((sum_all - Secs(1023.0)).abs() < 1e-12);
        // total() == field sum minus the memo fields
        assert!((b.total() - (sum_all - b.comm_hidden - b.load_hidden)).abs() < 1e-12);
        assert!((b.total() - Secs(495.0)).abs() < 1e-12);
        assert!((b.comm() - Secs(2.0 + 4.0 + 8.0 + 32.0)).abs() < 1e-12);
        for m in Breakdown::MEMO_FIELDS {
            assert!(comps.iter().any(|&(n, _)| n == m), "memo field {m} missing");
        }
    }

    #[test]
    fn speedup_ratio() {
        assert!((speedup(1.0, 0.125) - 8.0).abs() < 1e-12);
        assert_eq!(speedup(1.0, 0.0), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "speedup"]);
        t.row(vec!["alexnet".into(), "6.7x".into()]);
        t.row(vec!["vgg".into(), "4.9x".into()]);
        let r = t.render();
        assert!(r.contains("alexnet  6.7x"), "{r}");
    }
}
