//! Timing accounting and paper-style reporting.
//!
//! The paper's headline metric is **data throughput speedup**: the change in
//! total (train + communication) time to process a fixed number of examples
//! (footnote 4). `Breakdown` carries exactly that decomposition per worker,
//! and `speedup` computes the ratio the tables report.

use std::time::Instant;

/// Per-worker virtual-time decomposition of a run (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    /// PJRT execution of train/grad steps (real, measured).
    pub compute: f64,
    /// Simulated wire time of parameter exchange (incl. host reduction on
    /// the AR baseline and EASGD server handling).
    pub comm_transfer: f64,
    /// Simulated GPU kernel time inside exchange (sum / cast).
    pub comm_kernel: f64,
    /// EASGD: time exchanges sat in a shard server's queue beyond their
    /// own wire + handling (the contention sharded servers collapse).
    pub comm_queue: f64,
    /// Exchange time hidden under the backward pass by wait-free backprop
    /// (`overlap = "wfbp"`). Memo only: the clock never paid it, so it is
    /// *not* part of [`comm`](Self::comm) or [`total`](Self::total) —
    /// `comm + comm_hidden` is what the post-backward path would have cost.
    pub comm_hidden: f64,
    /// Time blocked waiting for the parallel loader (overlap miss).
    pub load_stall: f64,
    /// Simulated H2D staging of input batches (the direct loader path;
    /// the parallel loader overlaps it in the child).
    pub h2d: f64,
    /// SUBGD second half: sgd_apply execution (real, measured).
    pub apply: f64,
}

impl Breakdown {
    pub fn comm(&self) -> f64 {
        self.comm_transfer + self.comm_kernel + self.comm_queue
    }

    /// Sum of every component — reconciles with the virtual clock (exactly
    /// for a single worker; a lower bound under barrier straggling).
    pub fn total(&self) -> f64 {
        self.compute + self.comm() + self.load_stall + self.h2d + self.apply
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.compute += other.compute;
        self.comm_transfer += other.comm_transfer;
        self.comm_kernel += other.comm_kernel;
        self.comm_queue += other.comm_queue;
        self.comm_hidden += other.comm_hidden;
        self.load_stall += other.load_stall;
        self.h2d += other.h2d;
        self.apply += other.apply;
    }

    /// Fraction of exchange time spent in the GPU kernel (paper §3.2
    /// measures 1.6 % for the ASA summation kernel).
    pub fn kernel_share_of_comm(&self) -> f64 {
        if self.comm() <= 0.0 {
            0.0
        } else {
            self.comm_kernel / self.comm()
        }
    }
}

/// Data throughput speedup of a k-worker run vs the 1-GPU baseline,
/// normalized to the same number of examples (paper footnote 4/5).
pub fn speedup(t1_per_example: f64, tk_per_example: f64) -> f64 {
    if tk_per_example <= 0.0 {
        return 0.0;
    }
    t1_per_example / tk_per_example
}

/// Wall-clock stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Fixed-width table printer (the `tmpi repro …` stdout format).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals() {
        let b = Breakdown {
            compute: 1.0,
            comm_transfer: 0.5,
            comm_kernel: 0.01,
            comm_queue: 0.04,
            comm_hidden: 0.33,
            load_stall: 0.1,
            h2d: 0.2,
            apply: 0.05,
        };
        assert!((b.comm() - 0.55).abs() < 1e-12);
        // comm_hidden is a memo of time NOT paid: never in the totals
        assert!((b.total() - 1.9).abs() < 1e-12);
        assert!((b.kernel_share_of_comm() - 0.01 / 0.55).abs() < 1e-12);
        let mut sum = b;
        sum.add(&b);
        assert!((sum.total() - 3.8).abs() < 1e-12);
        assert!((sum.comm_queue - 0.08).abs() < 1e-12);
        assert!((sum.comm_hidden - 0.66).abs() < 1e-12);
        assert!((sum.h2d - 0.4).abs() < 1e-12);
    }

    #[test]
    fn speedup_ratio() {
        assert!((speedup(1.0, 0.125) - 8.0).abs() < 1e-12);
        assert_eq!(speedup(1.0, 0.0), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "speedup"]);
        t.row(vec!["alexnet".into(), "6.7x".into()]);
        t.row(vec!["vgg".into(), "4.9x".into()]);
        let r = t.render();
        assert!(r.contains("alexnet  6.7x"), "{r}");
    }
}
