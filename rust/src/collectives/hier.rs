//! Hierarchical two-level exchange — the paper's §7 "better inter-node
//! strategy" future work, in the hybrid intra/inter-node style of Poseidon
//! (Zhang et al. 2015) and the topology-aware schemes Shi et al. (2017)
//! show dominating flat collectives at high GPU-per-node counts.
//!
//! On copper, a flat strategy pushes every one of a node's 8 GPUs' traffic
//! through the node's single NIC (`shared_nic_serializes`). The hierarchy
//! instead:
//!
//! 1. **switch level (up)** — every GPU under a PCIe switch sends its
//!    vector to the switch leader over GPUDirect P2P; the leader sums
//!    (Pallas sum kernel when bound, host loop otherwise);
//! 2. **socket level (up)** — switch leaders forward their partial sums
//!    across the QPI to the node leader, which sums again;
//! 3. **leader level** — node leaders run any flat inner strategy
//!    (`ar|asa|asa16|ring`) across nodes only, over a group view of the
//!    communicator ([`Comm::push_group`](crate::mpi::Comm::push_group)) and
//!    a [`Topology::subset`](crate::cluster::Topology::subset) — so
//!    per-node NIC traffic drops from ~8× the vector to the inner
//!    strategy's leader-only footprint (8× less vs flat ASA/AR on copper);
//! 4. **socket + switch level (down)** — the result broadcasts back down
//!    the same tree.
//!
//! Monolithically the tree's intra-node legs cost more wire time than a
//! neighbour-placed flat ring; the hierarchy wins by *streaming*: each
//! level occupies a distinct serial fabric resource (switch PCIe up, host
//! RAM/QPI, NIC, switch PCIe down), so under [`ChunkedPipeline`] chunk *i*'s
//! leader-level NIC leg runs while chunk *i+1* climbs its intra-node tree.
//! The per-level [`Leg`]s this strategy reports feed
//! [`flow_pipeline_time`](crate::simnet::flow_pipeline_time), which prices
//! exactly that flow-shop overlap (the up and down socket hops share the
//! host-RAM machine, so their contention is never overlapped away).
//!
//! Accounting caveat: only node leaders run the leader-level inner
//! exchange, so a non-leader rank's `CommReport` omits that level. Rank 0
//! is always a node leader (it leads node 0), so rank 0's report — the one
//! every driver and test reads — is complete. `Mean` divides once by the
//! global rank count on the node leaders after the inner `Sum`.

use anyhow::Result;

use crate::mpi::{tags, Payload};
use crate::simnet::{
    phase_cost, split_traffic, Leg, Transfer, MACHINE_HOST, MACHINE_INTER, MACHINE_INTRA_DOWN,
    MACHINE_INTRA_UP,
};
use crate::units::{Bytes, Secs};

use super::{
    host_add, host_scale, CommReport, ExchangeCtx, ExchangeStrategy, FlatKind, ReduceOp,
    StrategyKind, WireFormat,
};

/// Two-level hierarchical exchange over a flat inner strategy.
#[derive(Clone)]
pub struct Hierarchical {
    inner: FlatKind,
    fmt: WireFormat,
}

impl Hierarchical {
    pub fn new(inner: FlatKind, fmt: WireFormat) -> Hierarchical {
        Hierarchical { inner, fmt }
    }

    /// The flat strategy the node leaders run.
    pub fn inner(&self) -> FlatKind {
        self.inner
    }

    /// Price one tree level (a phase of concurrent transfers): wire time,
    /// global byte split, the flow-pipeline leg, and — for up-tree levels
    /// that end in a summation of `sum_elems` f32 copies — the kernel
    /// charge (gated on bound kernels like `Ring`'s, and charged at the
    /// global maximum so every rank books the same phase).
    fn charge_level(
        &self,
        rep: &mut CommReport,
        ctx: &ExchangeCtx<'_, '_>,
        transfers: &[Transfer],
        machine: usize,
        sum_elems: Option<usize>,
    ) {
        let c = phase_cost(ctx.topo, ctx.links, transfers, ctx.cuda_aware);
        rep.sim_transfer += c.total();
        rep.sim_latency += c.latency;
        rep.sim_intra += c.total();
        rep.phases += 1;
        let s = split_traffic(ctx.topo, transfers);
        rep.wire_intra_bytes += s.intra_bytes;
        rep.wire_inter_bytes += s.inter_bytes;
        rep.legs.push(Leg { machine, transfer: c.total(), latency: c.latency });
        if let Some(elems) = sum_elems {
            if ctx.kernels.is_some() {
                rep.sim_kernel += ctx.links.gpu_reduce_time(Bytes(4 * elems as u64));
            }
        }
    }
}

/// Leader-side reduction of gathered copies into `buf` (Pallas sum kernel
/// when bound, host loop otherwise — the ASA sum path).
fn reduce_into(
    buf: &mut [f32],
    copies: &[Vec<f32>],
    ctx: &ExchangeCtx<'_, '_>,
    rep: &mut CommReport,
) -> Result<()> {
    if copies.is_empty() || buf.is_empty() {
        return Ok(());
    }
    if let Some(kn) = ctx.kernels {
        let mut refs: Vec<&[f32]> = Vec::with_capacity(copies.len() + 1);
        refs.push(&*buf);
        for c in copies {
            refs.push(c.as_slice());
        }
        let out = kn.sum_parts(&refs)?;
        rep.real_kernel += Secs(out.exec_time);
        buf.copy_from_slice(&out.value);
    } else {
        for c in copies {
            host_add(buf, c);
        }
    }
    Ok(())
}

impl ExchangeStrategy for Hierarchical {
    fn name(&self) -> &'static str {
        StrategyKind::Hier { inner: self.inner }.name()
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        let k = ctx.comm.size;
        let rank = ctx.comm.rank;
        let n = buf.len();
        let mut rep = CommReport { strategy: self.name().into(), ..Default::default() };
        if k == 1 {
            return Ok(rep);
        }
        let sw_groups = ctx.topo.switch_groups(k);
        let node_groups = ctx.topo.node_groups(k);
        let leaders: Vec<usize> = node_groups.iter().map(|g| g[0]).collect();
        let my_sw = sw_groups.iter().find(|g| g.contains(&rank)).unwrap().clone();
        let my_node = node_groups.iter().find(|g| g.contains(&rank)).unwrap().clone();
        let sw_leader = my_sw[0];
        let node_leader = my_node[0];
        // the switch leaders inside one node group (node leader is first)
        let sw_leaders_of = |node_group: &[usize]| -> Vec<usize> {
            node_group
                .iter()
                .copied()
                .filter(|r| sw_groups.iter().any(|g| g[0] == *r))
                .collect()
        };

        // ---- switch level, up: members -> switch leader (P2P) ------------
        let bytes = Bytes(4 * n as u64);
        let level_a: Vec<Transfer> = sw_groups
            .iter()
            .flat_map(|g| {
                let leader = g[0];
                g[1..].iter().map(move |&m| Transfer { src: m, dst: leader, bytes })
            })
            .collect();
        if !level_a.is_empty() {
            if rank != sw_leader {
                ctx.comm.send(sw_leader, tags::HIER_UP, Payload::F32(buf.to_vec()), 0.0)?;
                rep.wire_bytes += bytes;
            } else {
                let mut copies = Vec::with_capacity(my_sw.len() - 1);
                for &m in &my_sw[1..] {
                    copies.push(ctx.comm.recv(m, tags::HIER_UP)?.payload.into_f32()?);
                }
                reduce_into(buf, &copies, ctx, &mut rep)?;
            }
            let g_max = sw_groups.iter().map(|g| g.len()).max().unwrap();
            self.charge_level(&mut rep, ctx, &level_a, MACHINE_INTRA_UP, Some(g_max * n));
        }

        // ---- socket level, up: switch leaders -> node leader (QPI) -------
        let mut level_b: Vec<Transfer> = Vec::new();
        let mut s_max = 1usize;
        for g in &node_groups {
            let sls = sw_leaders_of(g);
            s_max = s_max.max(sls.len());
            for &sl in &sls {
                if sl != g[0] {
                    level_b.push(Transfer { src: sl, dst: g[0], bytes });
                }
            }
        }
        if !level_b.is_empty() {
            if rank == node_leader {
                let sls = sw_leaders_of(&my_node);
                let mut copies = Vec::with_capacity(sls.len().saturating_sub(1));
                for &sl in &sls {
                    if sl != rank {
                        copies.push(ctx.comm.recv(sl, tags::HIER_UP + 1)?.payload.into_f32()?);
                    }
                }
                reduce_into(buf, &copies, ctx, &mut rep)?;
            } else if rank == sw_leader {
                ctx.comm.send(node_leader, tags::HIER_UP + 1, Payload::F32(buf.to_vec()), 0.0)?;
                rep.wire_bytes += bytes;
            }
            self.charge_level(&mut rep, ctx, &level_b, MACHINE_HOST, Some(s_max * n));
        }

        // ---- leader level: inner strategy across node leaders ------------
        if leaders.len() > 1 {
            let sub_topo = ctx.topo.subset(&leaders);
            if rank == node_leader {
                let frame = ctx.comm.push_group(&leaders)?;
                let res = {
                    let mut sub_ctx = ExchangeCtx {
                        comm: &mut *ctx.comm,
                        topo: &sub_topo,
                        links: ctx.links,
                        kernels: ctx.kernels,
                        cuda_aware: ctx.cuda_aware,
                        chunk_elems: ctx.chunk_elems,
                        slice_off: ctx.slice_off,
                        sf_bytes: ctx.sf_bytes,
                    };
                    self.inner.build(self.fmt).exchange(buf, ReduceOp::Sum, &mut sub_ctx)
                };
                ctx.comm.pop_group(frame);
                let sub = res?;
                rep.legs.push(Leg {
                    machine: MACHINE_INTER,
                    transfer: sub.sim_transfer,
                    latency: sub.sim_latency,
                });
                rep.sim_inter += sub.sim_transfer;
                rep.merge(&sub);
            }
            // non-leaders wait for the broadcast; their report omits this
            // level (rank 0 always leads node 0, so its report is complete)
        }

        // ---- mean: one global scale on the node leaders ------------------
        if op == ReduceOp::Mean && rank == node_leader {
            host_scale(buf, 1.0 / k as f32);
        }

        // ---- socket level, down: node leader -> switch leaders -----------
        let level_d: Vec<Transfer> =
            level_b.iter().map(|t| Transfer { src: t.dst, dst: t.src, bytes: t.bytes }).collect();
        if !level_d.is_empty() {
            if rank == node_leader {
                for &sl in &sw_leaders_of(&my_node) {
                    if sl != rank {
                        ctx.comm.send(sl, tags::HIER_DOWN, Payload::F32(buf.to_vec()), 0.0)?;
                        rep.wire_bytes += bytes;
                    }
                }
            } else if rank == sw_leader {
                let m = ctx.comm.recv(node_leader, tags::HIER_DOWN)?;
                buf.copy_from_slice(&m.payload.into_f32()?);
            }
            self.charge_level(&mut rep, ctx, &level_d, MACHINE_HOST, None);
        }

        // ---- switch level, down: switch leader -> members ----------------
        let level_e: Vec<Transfer> =
            level_a.iter().map(|t| Transfer { src: t.dst, dst: t.src, bytes: t.bytes }).collect();
        if !level_e.is_empty() {
            if rank == sw_leader {
                for &m in &my_sw[1..] {
                    ctx.comm.send(m, tags::HIER_DOWN + 1, Payload::F32(buf.to_vec()), 0.0)?;
                    rep.wire_bytes += bytes;
                }
            } else {
                let m = ctx.comm.recv(sw_leader, tags::HIER_DOWN + 1)?;
                buf.copy_from_slice(&m.payload.into_f32()?);
            }
            self.charge_level(&mut rep, ctx, &level_e, MACHINE_INTRA_DOWN, None);
        }
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Asa, Ring};
    use super::*;
    use crate::cluster::Topology;
    use crate::testkit;

    /// The shared exchange harness, pinned to a hier composition.
    fn run_hier(
        inner: FlatKind,
        k: usize,
        bufs: Vec<Vec<f32>>,
        op: ReduceOp,
        topo: Topology,
    ) -> (Vec<Vec<f32>>, CommReport) {
        assert_eq!(bufs.len(), k);
        testkit::run_exchange(StrategyKind::Hier { inner }, None, bufs, op, &topo)
    }

    fn expected(bufs: &[Vec<f32>], mean: bool) -> Vec<f32> {
        let mut out = vec![0.0f32; bufs[0].len()];
        for b in bufs {
            for (o, x) in out.iter_mut().zip(b) {
                *o += x;
            }
        }
        if mean {
            for o in out.iter_mut() {
                *o /= bufs.len() as f32;
            }
        }
        out
    }

    fn mk_bufs(k: usize, n: usize) -> Vec<Vec<f32>> {
        (0..k)
            .map(|r| (0..n).map(|i| (((r * 131 + i * 17) % 997) as f32 - 498.0) * 1e-3).collect())
            .collect()
    }

    #[test]
    fn hier_matches_host_sum_on_copper_and_mosaic() {
        for inner in [FlatKind::Ar, FlatKind::Asa, FlatKind::Ring] {
            for (k, topo) in [
                (16usize, Topology::copper(2)),
                (8, Topology::copper(1)),
                (5, Topology::mosaic(5)),
                (11, Topology::copper(2)),
                (2, Topology::grid(1, 2, 1)),
            ] {
                for n in [0usize, 1, 3, 64, 1003] {
                    let bufs = mk_bufs(k, n);
                    let want = expected(&bufs, false);
                    let (outs, _) = run_hier(inner, k, bufs, ReduceOp::Sum, topo.clone());
                    for (r, out) in outs.iter().enumerate() {
                        testkit::allclose(out, &want, 1e-4, 1e-4).unwrap_or_else(|e| {
                            panic!("{:?} k={k} n={n} rank={r}: {e}", inner)
                        });
                    }
                }
            }
        }
    }

    #[test]
    fn hier_mean_divides_by_global_rank_count() {
        let k = 16;
        let n = 257;
        let bufs = mk_bufs(k, n);
        let want = expected(&bufs, true);
        let (outs, _) = run_hier(FlatKind::Ring, k, bufs, ReduceOp::Mean, Topology::copper(2));
        for out in &outs {
            testkit::allclose(out, &want, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn hier_asa16_close_within_half_precision() {
        let k = 16;
        let n = 512;
        let bufs = mk_bufs(k, n);
        let want = expected(&bufs, false);
        let (outs, rep) =
            run_hier(FlatKind::Asa16, k, bufs, ReduceOp::Sum, Topology::copper(2));
        for out in &outs {
            testkit::allclose(out, &want, 2e-2, 2e-2).unwrap();
        }
        // the leader-level inner moved half-width bytes across the NIC
        assert!(rep.wire_inter_bytes > 0);
    }

    #[test]
    fn hier_all_ranks_agree_exactly_for_f32_inners() {
        for inner in [FlatKind::Ar, FlatKind::Asa, FlatKind::Ring] {
            let (outs, _) =
                run_hier(inner, 16, mk_bufs(16, 777), ReduceOp::Sum, Topology::copper(2));
            for out in &outs[1..] {
                assert_eq!(out, &outs[0], "{inner:?}: broadcast must leave ranks identical");
            }
        }
    }

    #[test]
    fn hier_cuts_nic_bytes_vs_flat_inner_on_copper() {
        use super::super::allreduce::tests::run_collective;
        let k = 16;
        let n = 40_000;
        let topo = Topology::copper(2);
        let mk = || mk_bufs(k, n);
        let (_, flat_asa) = run_collective(Asa, k, mk(), ReduceOp::Sum, topo.clone());
        let (_, flat_ring) = run_collective(Ring, k, mk(), ReduceOp::Sum, topo.clone());
        let (_, h_asa) = run_hier(FlatKind::Asa, k, mk(), ReduceOp::Sum, topo.clone());
        let (_, h_ring) = run_hier(FlatKind::Ring, k, mk(), ReduceOp::Sum, topo);
        assert!(
            h_asa.wire_inter_bytes < flat_asa.wire_inter_bytes,
            "hier:asa {} !< asa {}",
            h_asa.wire_inter_bytes,
            flat_asa.wire_inter_bytes
        );
        assert!(h_ring.wire_inter_bytes < flat_ring.wire_inter_bytes);
        // the paper's motivation: ~8x on copper's 8-GPU nodes for all-pairs
        // flat strategies (every GPU pushed ~the full vector through the NIC)
        assert!(
            flat_asa.wire_inter_bytes.as_f64() / h_asa.wire_inter_bytes.as_f64() > 7.0,
            "expected ~8x NIC cut, got {}x",
            flat_asa.wire_inter_bytes.as_f64() / h_asa.wire_inter_bytes.as_f64()
        );
    }

    #[test]
    fn hier_report_splits_transfer_into_intra_and_inter() {
        let (_, rep) =
            run_hier(FlatKind::Ring, 16, mk_bufs(16, 10_000), ReduceOp::Sum, Topology::copper(2));
        assert!(rep.sim_intra > 0.0 && rep.sim_inter > 0.0);
        assert!((rep.sim_intra + rep.sim_inter - rep.sim_transfer).abs() < 1e-12);
        // 5 legs on copper-2: switch up, socket up, leaders, socket down,
        // switch down
        assert_eq!(rep.legs.len(), 5);
        let leg_total: Secs = rep.legs.iter().map(|l| l.transfer).sum();
        assert!((leg_total - rep.sim_transfer).abs() < 1e-12);
        // host fallback: no GPU kernel charge (ring-style gating)
        assert_eq!(rep.sim_kernel, 0.0);
    }

    #[test]
    fn hier_on_mosaic_degenerates_to_inner() {
        use super::super::allreduce::tests::run_collective;
        let k = 5;
        let n = 1003;
        let topo = Topology::mosaic(k);
        let (flat_outs, flat) =
            run_collective(Ring, k, mk_bufs(k, n), ReduceOp::Sum, topo.clone());
        let (h_outs, h) = run_hier(FlatKind::Ring, k, mk_bufs(k, n), ReduceOp::Sum, topo);
        assert_eq!(flat_outs, h_outs, "1 GPU/node: hier is exactly its inner");
        assert!((flat.sim_transfer - h.sim_transfer).abs() < 1e-15);
        assert_eq!(flat.wire_inter_bytes, h.wire_inter_bytes);
        assert_eq!(h.legs.len(), 1, "only the leader-level leg");
    }

    #[test]
    fn hier_single_node_skips_the_inner_strategy() {
        // all ranks under one node: tree up + broadcast down, no NIC bytes
        let (outs, rep) =
            run_hier(FlatKind::Asa, 8, mk_bufs(8, 501), ReduceOp::Sum, Topology::copper(1));
        let want = expected(&mk_bufs(8, 501), false);
        for out in &outs {
            testkit::allclose(out, &want, 1e-4, 1e-4).unwrap();
        }
        assert_eq!(rep.wire_inter_bytes, 0);
        assert_eq!(rep.sim_inter, 0.0);
        assert!(rep.sim_intra > 0.0);
        assert_eq!(rep.legs.len(), 4, "up x2 + down x2, no inter leg");
    }

    #[test]
    fn hier_builds_from_strategy_kind() {
        let s = StrategyKind::Hier { inner: FlatKind::Asa16 }.build(WireFormat::Bf16);
        assert_eq!(s.name(), "hier:asa16");
    }
}
