//! Gradient-compression wire formats with error feedback (`wire = ...`).
//!
//! The paper's last contribution is cutting the bytes the exchange moves;
//! asa16's native 16-bit value wire was this repo's only answer. This
//! module adds the rest of the family as a *codec layer* any strategy runs
//! through:
//!
//! * `f32` — the identity wire (no codec; bit-identical to the pre-wire
//!   behavior).
//! * `f16` / `bf16` — 16-bit value wire. Rides asa16's native pack/unpack
//!   where the strategy supports it; elsewhere the codec rounds values
//!   through [`crate::precision::Wire`] and ships 2 bytes/elem.
//! * `topk:<p>` — ship exactly `⌈p·n⌉` largest-|x| elements as
//!   (u32 index, f32 value) pairs: `8·⌈p·n⌉` bytes (Shi et al. 2017's
//!   bandwidth-bound regime; a win for small `p`, a *loss* past p = 0.5
//!   where the 8-byte pairs outweigh dense f32).
//! * `onebit` — one sign bit per element plus a single f32 scale
//!   (`mean |x|`): `⌈n/8⌉ + 4` bytes, the 1-bit SGD wire.
//! * `sf` — Poseidon-style sufficient factors (Zhang et al. 2015): an fc
//!   layer's gradient is `Σ_b δ_b·x_bᵀ`, so ranks can ship the factors —
//!   `B·(n_in + n_out)` values instead of `n_in·n_out`. Values are exact
//!   (the factors reconstruct the dense gradient), so only the *pricing*
//!   changes, and only where the WFBP bucket loop provides the factor-size
//!   hint ([`super::ExchangeCtx::sf_bytes`], set for all-fc buckets);
//!   everywhere else `sf` falls back to the dense wire.
//!
//! ## Error feedback
//!
//! Lossy wires are convergence-preserving by construction: each rank keeps
//! a per-element residual buffer, folds it into the next send
//! (`send = grad + residual`), and banks what the codec dropped
//! (`residual' = send − decode(encode(send))`). For value-exact codecs
//! (topk, sf, f32) `decode(sent) + residual' == send` holds *bitwise*
//! (each element's decoded value is either the sent value or 0); for
//! value-rounding codecs (f16/bf16/onebit) the residual is the exact f32
//! difference by definition. Residual indexing is by absolute offset in
//! the rank's flat vector ([`super::ExchangeCtx::slice_off`]), so the
//! chunked pipeline and WFBP buckets hit the same residual elements the
//! monolithic exchange would.
//!
//! ## Pricing
//!
//! The codec encodes *before* any transfer, so every wire leg carries the
//! compressed byte count. [`super::super::simnet::phase_cost`]'s bandwidth
//! term is exactly linear in a uniform byte scaling, so the codec prices
//! the inner exchange dense and rescales:
//! `sim_transfer' = sim_latency + (sim_transfer − sim_latency)·r` with
//! `r = codec_bytes / (4·n)` — exact, and mirrored verbatim by
//! `scripts/pricing_model.py`. Encode/decode cost is charged to
//! `sim_kernel` (the audit ledger's `CommKernel` lane, like asa16's
//! pack/unpack casts): encode reads grad + residual
//! (`gpu_cast_time(8n)`), decode writes the dense buffer
//! (`gpu_cast_time(4n)`). `sf` charges nothing — the factors fall out of
//! the backward pass. The dense-equivalent bytes land in
//! [`super::CommReport::wire_raw_bytes`] so the compression ratio is
//! observable end to end.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::precision::Wire;
use crate::units::{Bytes, Kib};

use super::{CommReport, ExchangeCtx, ExchangeStrategy, ReduceOp, StrategyKind};

/// Wire-format selection (`wire =` in TOML, `--wire` on the CLI).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WireFormat {
    /// Dense f32 — the identity wire.
    F32,
    /// 16-bit IEEE half value wire.
    F16,
    /// bfloat16 value wire.
    Bf16,
    /// Top-k sparsification: ship the `⌈p·n⌉` largest-|x| elements.
    TopK { p: f64 },
    /// 1-bit sign wire with a single mean-|x| scale.
    OneBit,
    /// Poseidon sufficient factors for all-fc WFBP buckets; dense fallback
    /// elsewhere.
    Sf,
}

impl WireFormat {
    /// The valid names, for error messages and help text.
    pub const NAMES: &'static str = "f32|f16|bf16|topk:<p>|onebit|sf";

    /// Case-insensitive name lookup; `topk:<p>` takes `0 < p ≤ 1`.
    pub fn parse(s: &str) -> Option<WireFormat> {
        let lower = s.to_ascii_lowercase();
        if let Some(p) = lower.strip_prefix("topk:") {
            let p: f64 = p.parse().ok()?;
            if p > 0.0 && p <= 1.0 && p.is_finite() {
                return Some(WireFormat::TopK { p });
            }
            return None;
        }
        match lower.as_str() {
            "f32" => Some(WireFormat::F32),
            "f16" | "half" => Some(WireFormat::F16),
            "bf16" => Some(WireFormat::Bf16),
            "onebit" | "1bit" => Some(WireFormat::OneBit),
            "sf" => Some(WireFormat::Sf),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) that fails naming the valid formats — what
    /// config files and `--wire` surface.
    pub fn from_name(s: &str) -> Result<WireFormat> {
        Self::parse(s)
            .ok_or_else(|| anyhow!("unknown wire format '{s}' (valid: {})", Self::NAMES))
    }

    /// Canonical name (`topk:<p>` prints its fraction).
    pub fn name(self) -> String {
        match self {
            WireFormat::F32 => "f32".to_string(),
            WireFormat::F16 => "f16".to_string(),
            WireFormat::Bf16 => "bf16".to_string(),
            WireFormat::TopK { p } => format!("topk:{p}"),
            WireFormat::OneBit => "onebit".to_string(),
            WireFormat::Sf => "sf".to_string(),
        }
    }

    /// Formats whose on-wire byte count is data-shaped (not a fixed per-
    /// element width a native strategy could ship). These always go
    /// through the codec and replace asa16's native half wire.
    pub fn compressed(self) -> bool {
        matches!(self, WireFormat::TopK { .. } | WireFormat::OneBit | WireFormat::Sf)
    }

    /// The 16-bit value wire this format maps to, or `default` when it is
    /// not a half-precision format (what asa16's native path packs with).
    pub fn half_or(self, default: Wire) -> Wire {
        match self {
            WireFormat::F16 => Wire::F16,
            WireFormat::Bf16 => Wire::Bf16,
            _ => default,
        }
    }

    /// Does shipping this format through a strategy whose native wire is
    /// `native_half` (asa16 / hier:asa16) require the codec layer?
    pub fn needs_codec(self, native_half: bool) -> bool {
        match self {
            WireFormat::F32 => false,
            WireFormat::F16 | WireFormat::Bf16 => !native_half,
            _ => true,
        }
    }
}

/// Nominal on-wire bytes per f32 element for *sizing* (chunk/bucket KiB →
/// element counts), not pricing: topk's true byte count is data-independent
/// (`8·⌈p·n⌉ ≈ 8p·n`) but sf's depends on the runtime factor hint, so sf
/// sizes at its dense fallback. Clamped below at one bit per element.
pub fn wire_bytes_per_elem(strategy: StrategyKind, fmt: WireFormat) -> f64 {
    let bpe = match fmt {
        WireFormat::F32 => {
            if strategy.half_wire() {
                2.0
            } else {
                4.0
            }
        }
        WireFormat::F16 | WireFormat::Bf16 => 2.0,
        WireFormat::TopK { p } => 8.0 * p,
        WireFormat::OneBit => 0.125,
        WireFormat::Sf => 4.0,
    };
    bpe.max(0.125)
}

/// Elements per `kib` KiB of *on-wire* bytes for a strategy × wire — the
/// one shared sizing rule for `chunk_kib` and `bucket_kib`. The pre-wire
/// code hardcoded `kib * 1024 / 4` (f32 width) everywhere, so an asa16
/// chunk of "256 KiB" was only 128 KiB on the wire and the flow-shop
/// pipeline was priced at the wrong granularity; this computes the element
/// count from the active wire's width instead. The f32 × full-width path
/// reproduces `kib * 1024 / 4` exactly (bit-identical bands). Thin alias
/// of [`Kib::elems`], the typed sizing rule.
pub fn elems_per_kib(kib: usize, strategy: StrategyKind, fmt: WireFormat) -> usize {
    Kib(kib).elems(strategy, fmt).0
}

/// One codec application: the values the wire delivers (dense, with
/// whatever the codec dropped zeroed/rounded away) and the bytes one rank
/// pays to ship them.
pub struct Encoded {
    pub decoded: Vec<f32>,
    pub wire_bytes: u64,
}

/// `⌈p·n⌉` clamped to `[1, n]` — how many elements `topk:<p>` ships.
pub fn topk_count(n: usize, p: f64) -> usize {
    if n == 0 {
        return 0;
    }
    ((p * n as f64).ceil() as usize).clamp(1, n)
}

/// The indices `topk:<p>` selects: exactly [`topk_count`] of them, largest
/// |x| first, ties broken toward the lower index (deterministic across
/// ranks and delivery schedules).
pub fn topk_indices(xs: &[f32], p: f64) -> Vec<u32> {
    let m = topk_count(xs.len(), p);
    let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        let fa = xs[a as usize].abs();
        let fb = xs[b as usize].abs();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    idx.truncate(m);
    idx
}

/// Encode `xs` for the wire. Pure and deterministic in `xs` (and the sf
/// hint), so every rank and every delivery schedule encodes identically —
/// the race explorer's schedule-independence rests on this.
pub fn encode(fmt: WireFormat, xs: &[f32], sf_bytes: Option<u64>) -> Encoded {
    let n = xs.len();
    let dense = 4 * n as u64;
    match fmt {
        WireFormat::F32 => Encoded { decoded: xs.to_vec(), wire_bytes: dense },
        WireFormat::F16 | WireFormat::Bf16 => {
            let w = if fmt == WireFormat::F16 { Wire::F16 } else { Wire::Bf16 };
            let decoded = xs.iter().map(|&x| w.unpack_one(w.pack_one(x))).collect();
            Encoded { decoded, wire_bytes: 2 * n as u64 }
        }
        WireFormat::TopK { p } => {
            let mut decoded = vec![0.0f32; n];
            let idx = topk_indices(xs, p);
            for &i in &idx {
                decoded[i as usize] = xs[i as usize];
            }
            // (u32 index, f32 value) per shipped element
            Encoded { decoded, wire_bytes: 8 * idx.len() as u64 }
        }
        WireFormat::OneBit => {
            // f64 accumulation in element order, rounded to f32 once —
            // bit-reproducible and mirrored by the Python port
            let scale = if n == 0 {
                0.0f32
            } else {
                (xs.iter().map(|&x| x.abs() as f64).sum::<f64>() / n as f64) as f32
            };
            let decoded = xs
                .iter()
                .map(|&x| if x.to_bits() >> 31 == 1 { -scale } else { scale })
                .collect();
            Encoded { decoded, wire_bytes: n.div_ceil(8) as u64 + 4 }
        }
        WireFormat::Sf => {
            // value-exact: the factors reconstruct the dense gradient, so
            // only the priced bytes change, and only under a real hint
            let wire_bytes = match sf_bytes {
                Some(b) if b < dense => b,
                _ => dense,
            };
            Encoded { decoded: xs.to_vec(), wire_bytes }
        }
    }
}

/// Error-feedback codec wrapper: encodes the (residual-folded) buffer,
/// hands the decoded values to any inner [`ExchangeStrategy`], and
/// reprices the inner report at the compressed byte count. Built at the
/// outermost strategy level by [`StrategyKind::build`]; the chunked
/// pipeline and WFBP bucket loop drive it per slice, with
/// [`ExchangeCtx::slice_off`] keeping the residual aligned.
pub struct WireCodec {
    inner: Box<dyn ExchangeStrategy>,
    fmt: WireFormat,
    /// Per-rank error-feedback residual, indexed by absolute offset in the
    /// flat vector (each worker thread owns its own strategy instance).
    residual: Mutex<Vec<f32>>,
}

impl WireCodec {
    pub fn new(inner: Box<dyn ExchangeStrategy>, fmt: WireFormat) -> WireCodec {
        WireCodec { inner, fmt, residual: Mutex::new(Vec::new()) }
    }

    pub fn fmt(&self) -> WireFormat {
        self.fmt
    }

    /// Snapshot of the residual buffer — a test/diagnostic hook for the
    /// conservation properties (`decode(sent) + residual' == send`).
    pub fn residual_snapshot(&self) -> Vec<f32> {
        self.residual.lock().unwrap().clone()
    }
}

impl ExchangeStrategy for WireCodec {
    fn name(&self) -> &'static str {
        "wire-codec"
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        let n = buf.len();
        let off = ctx.slice_off;
        let sf_hint = if self.fmt == WireFormat::Sf { ctx.sf_bytes } else { None };
        {
            // send = grad + residual; bank residual' = send − decoded
            let mut res = self.residual.lock().unwrap();
            if res.len() < off + n {
                res.resize(off + n, 0.0);
            }
            for (i, v) in buf.iter_mut().enumerate() {
                *v += res[off + i];
            }
            let enc = encode(self.fmt, buf, sf_hint);
            for (i, v) in buf.iter_mut().enumerate() {
                res[off + i] = *v - enc.decoded[i];
                *v = enc.decoded[i];
            }
            drop(res);
            let mut rep = self.inner.exchange(buf, op, ctx)?;
            // exact repricing: phase_cost's bandwidth term is linear in a
            // uniform byte scaling; latency is per-message and stays
            let r = enc.wire_bytes as f64 / (4.0 * n.max(1) as f64);
            let raw = rep.wire_bytes;
            rep.wire_raw_bytes = raw;
            rep.wire_bytes = raw.scale_round(r);
            rep.wire_intra_bytes = rep.wire_intra_bytes.scale_round(r);
            rep.wire_inter_bytes = rep.wire_inter_bytes.scale_round(r);
            rep.sim_transfer = rep.sim_latency + (rep.sim_transfer - rep.sim_latency) * r;
            rep.sim_intra *= r;
            rep.sim_inter *= r;
            for leg in &mut rep.legs {
                leg.transfer = leg.latency + (leg.transfer - leg.latency) * r;
            }
            // encode reads grad + residual, decode writes the dense buffer;
            // sf's factors fall out of the backward pass (no codec kernel)
            if self.fmt != WireFormat::Sf {
                rep.sim_kernel += ctx.links.gpu_cast_time(Bytes(8 * n as u64));
                rep.sim_kernel += ctx.links.gpu_cast_time(Bytes(4 * n as u64));
            }
            rep.strategy = format!("{}/{}", rep.strategy, self.fmt.name());
            Ok(rep)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_format_parse_roundtrip_and_errors() {
        for fmt in [
            WireFormat::F32,
            WireFormat::F16,
            WireFormat::Bf16,
            WireFormat::TopK { p: 0.01 },
            WireFormat::OneBit,
            WireFormat::Sf,
        ] {
            assert_eq!(WireFormat::parse(&fmt.name()), Some(fmt));
        }
        assert_eq!(WireFormat::parse("TOPK:0.5"), Some(WireFormat::TopK { p: 0.5 }));
        assert_eq!(WireFormat::parse("1bit"), Some(WireFormat::OneBit));
        assert_eq!(WireFormat::parse("half"), Some(WireFormat::F16));
        for bad in ["f8", "topk", "topk:0", "topk:1.5", "topk:-0.1", "topk:nan", ""] {
            assert_eq!(WireFormat::parse(bad), None, "{bad:?} must not parse");
        }
        let err = WireFormat::from_name("f8").unwrap_err().to_string();
        assert!(err.contains("f8") && err.contains("onebit"), "{err}");
    }

    #[test]
    fn needs_codec_matrix() {
        assert!(!WireFormat::F32.needs_codec(false));
        assert!(!WireFormat::F32.needs_codec(true));
        assert!(WireFormat::F16.needs_codec(false));
        assert!(!WireFormat::F16.needs_codec(true), "asa16 ships f16 natively");
        assert!(!WireFormat::Bf16.needs_codec(true));
        for fmt in [WireFormat::TopK { p: 0.1 }, WireFormat::OneBit, WireFormat::Sf] {
            assert!(fmt.needs_codec(false) && fmt.needs_codec(true), "{}", fmt.name());
            assert!(fmt.compressed());
        }
        assert!(!WireFormat::F16.compressed());
    }

    #[test]
    fn topk_count_is_ceil_and_clamped() {
        assert_eq!(topk_count(1000, 0.01), 10);
        assert_eq!(topk_count(1001, 0.01), 11, "ceil, not round");
        assert_eq!(topk_count(10, 0.0001), 1, "at least one element");
        assert_eq!(topk_count(10, 1.0), 10);
        assert_eq!(topk_count(0, 0.5), 0);
    }

    #[test]
    fn topk_selects_largest_magnitudes_with_deterministic_ties() {
        let xs = [1.0, -3.0, 2.0, -2.0, 0.5];
        // |x|: 1, 3, 2, 2, 0.5 — top-3 is {1, 2, 3}: the |2.0| tie breaks
        // toward the lower index (2 before 3)
        assert_eq!(topk_indices(&xs, 0.6), vec![1, 2, 3]);
        let enc = encode(WireFormat::TopK { p: 0.6 }, &xs, None);
        assert_eq!(enc.decoded, vec![0.0, -3.0, 2.0, -2.0, 0.0]);
        assert_eq!(enc.wire_bytes, 24);
        // an all-ties vector keeps index order
        let ties = [7.0f32; 4];
        assert_eq!(topk_indices(&ties, 0.5), vec![0, 1]);
    }

    #[test]
    fn onebit_ships_sign_and_mean_scale() {
        let xs = [1.0f32, -2.0, 3.0, -4.0];
        let enc = encode(WireFormat::OneBit, &xs, None);
        let scale = ((1.0 + 2.0 + 3.0 + 4.0) / 4.0) as f32;
        assert_eq!(enc.decoded, vec![scale, -scale, scale, -scale]);
        assert_eq!(enc.wire_bytes, 1 + 4, "4 sign bits pack into 1 byte + f32 scale");
        let big = encode(WireFormat::OneBit, &[0.5; 17], None);
        assert_eq!(big.wire_bytes, 3 + 4, "17 bits → 3 bytes");
        // an all-zero vector round-trips to zero (scale 0, positive signs)
        let z = encode(WireFormat::OneBit, &[0.0; 8], None);
        assert!(z.decoded.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sf_uses_hint_only_when_it_wins() {
        let xs = [1.0f32; 100];
        let hinted = encode(WireFormat::Sf, &xs, Some(80));
        assert_eq!(hinted.wire_bytes, 80);
        assert_eq!(hinted.decoded, xs.to_vec(), "sf is value-exact");
        let no_hint = encode(WireFormat::Sf, &xs, None);
        assert_eq!(no_hint.wire_bytes, 400, "dense fallback");
        let bad_hint = encode(WireFormat::Sf, &xs, Some(500));
        assert_eq!(bad_hint.wire_bytes, 400, "a hint worse than dense is ignored");
    }

    #[test]
    fn value_exact_codecs_conserve_bitwise() {
        // topk/sf/f32: decoded + residual == send, element-exact in f32
        let xs: Vec<f32> = (0..97).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.37).collect();
        for fmt in [WireFormat::TopK { p: 0.13 }, WireFormat::Sf, WireFormat::F32] {
            let enc = encode(fmt, &xs, None);
            for (i, (&x, &d)) in xs.iter().zip(&enc.decoded).enumerate() {
                let residual = x - d;
                assert_eq!(
                    (d + residual).to_bits(),
                    x.to_bits(),
                    "{} elem {i}: {d} + {residual} != {x}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn elems_per_kib_is_wire_width_aware() {
        // f32 full-width reproduces the historical integer rule exactly
        for kib in [1usize, 7, 256, 4096] {
            assert_eq!(
                elems_per_kib(kib, StrategyKind::Asa, WireFormat::F32),
                kib * 1024 / 4
            );
        }
        // asa16's 2-byte wire fits twice the elements per on-wire KiB —
        // the sizing bug this helper fixes
        assert_eq!(
            elems_per_kib(256, StrategyKind::Asa16, WireFormat::F32),
            256 * 1024 / 2
        );
        assert_eq!(
            elems_per_kib(256, StrategyKind::Hier { inner: super::super::FlatKind::Asa16 }, WireFormat::F32),
            256 * 1024 / 2
        );
        // codec wires size at their own width
        assert_eq!(elems_per_kib(1, StrategyKind::Asa, WireFormat::F16), 512);
        assert_eq!(
            elems_per_kib(1, StrategyKind::Asa, WireFormat::TopK { p: 0.01 }),
            (1024.0 / 0.125f64).floor() as usize,
            "topk:0.01 nominal 0.08 B/elem clamps at one bit/elem"
        );
        assert_eq!(elems_per_kib(1, StrategyKind::Asa, WireFormat::OneBit), 8192);
        assert_eq!(elems_per_kib(1, StrategyKind::Asa, WireFormat::Sf), 256);
    }
}
