//! Ring allreduce — the paper's future-work "better inter-node strategy".
//!
//! Reduce-scatter ring (k-1 steps) + allgather ring (k-1 steps); each step
//! moves N/k elements to the next neighbour. Total wire traffic per rank is
//! 2·(k-1)/k·N — the same as ASA — but every step is neighbour-to-neighbour,
//! which on switch-heavy fabrics avoids the all-pairs contention of the
//! Alltoall phase. Included as an ablation (DESIGN.md §6): on mosaic's
//! one-GPU-per-node fabric the two are nearly equivalent; on copper's
//! multi-GPU nodes the ring's neighbour placement wins.

use anyhow::Result;

use crate::mpi::{tags, Payload};
use crate::simnet::{phase_cost, split_traffic, Transfer};
use crate::units::Bytes;
use crate::util::split_even;

use super::{host_add, host_scale, CommReport, ExchangeCtx, ExchangeStrategy, ReduceOp};

#[derive(Clone)]
pub struct Ring;

impl ExchangeStrategy for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        let k = ctx.comm.size;
        let rank = ctx.comm.rank;
        let n = buf.len();
        let mut rep = CommReport { strategy: "ring".into(), ..Default::default() };
        if k == 1 {
            return Ok(rep);
        }
        let parts = split_even(n, k);
        let next = (rank + 1) % k;
        let prev = (rank + k - 1) % k;

        // price one ring step with every rank's *actual* segment for that
        // step (ragged vectors have unequal segments; charging the largest
        // for all k transfers overstates shared-resource contention). Every
        // rank builds the same global transfer set, keeping clocks identical.
        let (topo, links, cuda) = (ctx.topo, ctx.links, ctx.cuda_aware);
        let step_transfers = |seg_of_rank: &dyn Fn(usize) -> usize| -> Vec<Transfer> {
            (0..k)
                .map(|r| Transfer {
                    src: r,
                    dst: (r + 1) % k,
                    bytes: Bytes(4 * parts[seg_of_rank(r)].1 as u64),
                })
                .collect()
        };
        let step_cost = |rep: &mut CommReport, seg_of_rank: &dyn Fn(usize) -> usize| {
            let transfers = step_transfers(seg_of_rank);
            let s = split_traffic(topo, &transfers);
            rep.wire_intra_bytes += s.intra_bytes;
            rep.wire_inter_bytes += s.inter_bytes;
            phase_cost(topo, links, &transfers, cuda)
        };

        // --- reduce-scatter: after k-1 steps, rank owns the full sum of
        // segment (rank+1) mod k ------------------------------------------------
        for step in 0..k - 1 {
            let send_seg = (rank + k - step) % k;
            let recv_seg = (rank + k - step - 1) % k;
            let (soff, slen) = parts[send_seg];
            let payload = Payload::F32(buf[soff..soff + slen].to_vec());
            ctx.comm.send(next, tags::EXCHANGE + step as u64, payload, 0.0)?;
            let m = ctx.comm.recv(prev, tags::EXCHANGE + step as u64)?;
            let (roff, rlen) = parts[recv_seg];
            let incoming = m.payload.into_f32()?;
            host_add(&mut buf[roff..roff + rlen], &incoming);
            rep.wire_bytes += Bytes(4 * slen as u64);
            let c = step_cost(&mut rep, &|r| (r + k - step) % k);
            rep.sim_transfer += c.total();
            rep.sim_latency += c.latency;
            // the per-step partial sum is a GPU kernel only when kernels are
            // bound; the host fallback must not charge device time
            if ctx.kernels.is_some() {
                rep.sim_kernel += ctx.links.gpu_reduce_time(Bytes(4 * rlen as u64));
            }
            rep.phases += 1;
        }

        let own_seg = (rank + 1) % k;
        if op == ReduceOp::Mean {
            let (off, len) = parts[own_seg];
            host_scale(&mut buf[off..off + len], 1.0 / k as f32);
        }

        // --- allgather ring: circulate the reduced segments -------------------
        for step in 0..k - 1 {
            let send_seg = (rank + 1 + k - step) % k;
            let recv_seg = (rank + k - step) % k;
            let (soff, slen) = parts[send_seg];
            let payload = Payload::F32(buf[soff..soff + slen].to_vec());
            ctx.comm.send(next, tags::ALLGATHER + step as u64, payload, 0.0)?;
            let m = ctx.comm.recv(prev, tags::ALLGATHER + step as u64)?;
            let (roff, rlen) = parts[recv_seg];
            let incoming = m.payload.into_f32()?;
            debug_assert_eq!(incoming.len(), rlen);
            buf[roff..roff + rlen].copy_from_slice(&incoming);
            rep.wire_bytes += Bytes(4 * slen as u64);
            let c = step_cost(&mut rep, &|r| (r + 1 + k - step) % k);
            rep.sim_transfer += c.total();
            rep.sim_latency += c.latency;
            rep.phases += 1;
        }
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use super::super::allreduce::tests::run_collective;
    use super::*;
    use crate::cluster::Topology;
    use crate::testkit;

    fn expected(bufs: &[Vec<f32>], mean: bool) -> Vec<f32> {
        let mut out = vec![0.0f32; bufs[0].len()];
        for b in bufs {
            for (o, x) in out.iter_mut().zip(b) {
                *o += x;
            }
        }
        if mean {
            for o in out.iter_mut() {
                *o /= bufs.len() as f32;
            }
        }
        out
    }

    #[test]
    fn ring_matches_sum_for_world_sizes_and_ragged_n() {
        for k in [2usize, 3, 4, 5, 8] {
            for n in [1usize, 7, 64, 1003] {
                let bufs: Vec<Vec<f32>> = (0..k)
                    .map(|r| (0..n).map(|i| ((r + 2) * (i + 3)) as f32 * 0.01).collect())
                    .collect();
                let want = expected(&bufs, false);
                let (outs, rep) = run_collective(Ring, k, bufs, ReduceOp::Sum, Topology::mosaic(k));
                for (r, out) in outs.iter().enumerate() {
                    testkit::allclose(out, &want, 1e-5, 1e-5)
                        .unwrap_or_else(|e| panic!("k={k} n={n} rank={r}: {e}"));
                }
                assert_eq!(rep.phases, 2 * (k - 1));
            }
        }
    }

    #[test]
    fn ring_mean() {
        let k = 3;
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![(r * 3) as f32; 10]).collect();
        let want = expected(&bufs, true);
        let (outs, _) = run_collective(Ring, k, bufs, ReduceOp::Mean, Topology::mosaic(k));
        for out in &outs {
            testkit::allclose(out, &want, 1e-6, 1e-6).unwrap();
        }
    }

    #[test]
    fn ring_prices_real_segment_bytes_and_gates_kernel_charge() {
        use crate::simnet::{phase_time, LinkParams, Transfer};
        use crate::util::split_even;
        // ragged n on copper: steps whose segments share a host-memory /
        // QPI resource carry unequal byte counts, so honest per-step
        // pricing lands strictly below the old price-every-step-at-max_seg
        let k = 8;
        let n = 1003;
        let topo = Topology::copper(1);
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![r as f32; n]).collect();
        let (_, rep) = run_collective(Ring, k, bufs, ReduceOp::Sum, topo.clone());
        // host fallback (no kernels bound): no GPU kernel time charged
        assert_eq!(rep.sim_kernel, 0.0, "host fallback must not charge GPU time");
        assert!(rep.sim_transfer > 0.0);
        // the old model's price: 2(k-1) steps, all at the largest segment
        let links = LinkParams::default();
        let parts = split_even(n, k);
        let max_seg = parts.iter().map(|p| p.1).max().unwrap() as u64;
        let transfers: Vec<Transfer> = (0..k)
            .map(|r| Transfer { src: r, dst: (r + 1) % k, bytes: Bytes(4 * max_seg) })
            .collect();
        let old = 2.0 * (k - 1) as f64 * phase_time(&topo, &links, &transfers, true);
        assert!(rep.sim_transfer < old, "new={} !< old={old}", rep.sim_transfer);
    }

    #[test]
    fn ring_kernel_charge_requires_bound_kernels() {
        // mosaic ragged world, host fallback: sim_kernel stays zero while
        // data still matches the sum (covered above); aligned n behaves
        // identically to the old pricing on contention-free fabrics
        let k = 4;
        let n = 1000; // divides evenly: per-step pricing == max_seg pricing
        let topo = Topology::mosaic(k);
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![(r + 1) as f32; n]).collect();
        let (_, rep) = run_collective(Ring, k, bufs, ReduceOp::Sum, topo);
        assert_eq!(rep.sim_kernel, 0.0);
        assert_eq!(rep.phases, 2 * (k - 1));
    }

    #[test]
    fn ring_wire_bytes_match_asa() {
        // both move ~2*(k-1)/k*N per rank
        let k = 4;
        let n = 4096;
        let mk = || (0..k).map(|r| vec![r as f32; n]).collect::<Vec<_>>();
        let (_, ring) = run_collective(Ring, k, mk(), ReduceOp::Sum, Topology::mosaic(k));
        let (_, asa) = run_collective(super::super::Asa, k, mk(), ReduceOp::Sum, Topology::mosaic(k));
        let diff = ring.wire_bytes.abs_diff(asa.wire_bytes);
        assert!(diff <= 8 * (k as u64), "ring={} asa={}", ring.wire_bytes, asa.wire_bytes);
    }
}
