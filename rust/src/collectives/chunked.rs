//! Chunked, pipelined exchange scheduler — comm/compute overlap (Poseidon
//! [Zhang et al. 2015]-style, the overlap ratio Shi et al. 2017 model).
//!
//! Every monolithic strategy in this module exchanges the whole flat vector
//! as one blocking phase sequence: wire time and kernel time add up. This
//! scheduler splits the vector into chunks and drives any inner
//! [`ExchangeStrategy`] chunk-by-chunk through a software pipeline, so chunk
//! *i*'s wire transfer overlaps chunk *i−1*'s summation/cast kernels. The
//! overlap is priced in the `simnet` virtual clock by
//! [`pipeline_time`](crate::simnet::pipeline_time): the wire and the kernel
//! engine are serial resources, a chunk's kernels are gated on its own
//! transfer, and later chunks' per-message latency rides under the stream.
//!
//! **Chunk boundaries are rank-segment-aligned**, which makes the data path
//! *bit-identical* to the monolithic exchange: the global vector is first
//! split into the k rank segments every strategy would use
//! (`split_even(n, k)`), and chunk *c* gathers slice *c* of every rank
//! segment. Because `split_even` places its remainder on the lowest
//! indices, the inner exchange's own `split_even(chunk_len, k)` lands
//! exactly on those slices (proved in `aligned_split_matches_inner_split`),
//! so each element keeps its owner rank and therefore its exact f32
//! reduction order. Chunking changes only *when* bytes move, never *what*
//! is computed.

use anyhow::Result;

use crate::simnet::{flow_pipeline_time, pipeline_time, FlowJob, PipelineStage};
use crate::units::Secs;
use crate::util::split_even;

use super::{CommReport, ExchangeCtx, ExchangeStrategy, ReduceOp};

/// Wrap an inner strategy in the chunked pipeline scheduler.
pub struct ChunkedPipeline {
    inner: Box<dyn ExchangeStrategy>,
    /// Elements per chunk (> 0; buffers no larger than this run monolithic).
    chunk_elems: usize,
    /// Overlap chunk transfers with the previous chunk's kernels. `false`
    /// prices the chunks serially — the ablation that isolates the win.
    pipeline: bool,
}

impl ChunkedPipeline {
    pub fn new(inner: Box<dyn ExchangeStrategy>, chunk_elems: usize, pipeline: bool) -> Self {
        assert!(chunk_elems > 0, "chunk_elems must be positive");
        ChunkedPipeline { inner, chunk_elems, pipeline }
    }

    /// Elements per chunk this scheduler was configured with.
    pub fn chunk_elems(&self) -> usize {
        self.chunk_elems
    }
}

impl ExchangeStrategy for ChunkedPipeline {
    fn name(&self) -> &'static str {
        "chunked"
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        let k = ctx.comm.size;
        let n = buf.len();
        if k <= 1 || n <= self.chunk_elems {
            let mut rep = self.inner.exchange(buf, op, ctx)?;
            rep.chunks = 1;
            return Ok(rep);
        }

        let m = n.div_ceil(self.chunk_elems);
        // chunk c of the pipeline = slice c of every global rank segment
        let parts = split_even(n, k);
        let slices: Vec<Vec<(usize, usize)>> = parts
            .iter()
            .map(|&(off, len)| {
                split_even(len, m).into_iter().map(|(o, l)| (off + o, l)).collect()
            })
            .collect();

        let mut rep = CommReport {
            strategy: format!("chunked({})", self.inner.name()),
            ..Default::default()
        };
        let mut stages: Vec<PipelineStage> = Vec::with_capacity(m);
        let mut jobs: Vec<FlowJob> = Vec::with_capacity(m);
        let mut legged = true;
        let saved_chunk = ctx.chunk_elems;
        let saved_off = ctx.slice_off;
        ctx.chunk_elems = self.chunk_elems;
        // a codec inner keys its error-feedback residual off slice_off; the
        // chunk gather interleaves rank segments, so a true vector offset
        // does not exist — a stable synthetic one (cumulative elements of
        // previous chunks) is deterministic per (n, k, m) and disjoint per
        // chunk, which is all the residual needs
        let mut cum_elems = 0usize;
        for c in 0..m {
            let chunk_len: usize = (0..k).map(|r| slices[r][c].1).sum();
            if chunk_len == 0 {
                // deterministic in (n, k, m): every rank skips the same c
                continue;
            }
            let mut chunk_buf = Vec::with_capacity(chunk_len);
            for r in 0..k {
                let (o, l) = slices[r][c];
                chunk_buf.extend_from_slice(&buf[o..o + l]);
            }
            ctx.slice_off = saved_off + cum_elems;
            cum_elems += chunk_len;
            let sub = self.inner.exchange(&mut chunk_buf, op, ctx)?;
            let mut pos = 0;
            for r in 0..k {
                let (o, l) = slices[r][c];
                buf[o..o + l].copy_from_slice(&chunk_buf[pos..pos + l]);
                pos += l;
            }
            rep.merge(&sub);
            rep.chunks += 1;
            let kernel = sub.sim_kernel + sub.sim_host_reduce;
            legged &= !sub.legs.is_empty();
            jobs.push(FlowJob { legs: sub.legs, kernel });
            stages.push(PipelineStage {
                transfer: sub.sim_transfer,
                latency: sub.sim_latency,
                kernel,
            });
        }
        ctx.chunk_elems = saved_chunk;
        ctx.slice_off = saved_off;

        if self.pipeline {
            let serial: Secs = stages.iter().map(|s| s.transfer + s.kernel).sum();
            // a per-level leg breakdown (the hierarchical strategy) engages
            // the multi-machine flow-shop: chunk i's NIC leg overlaps chunk
            // i+1's intra-node tree. Flat inners keep the two-resource
            // wire/kernel pipeline.
            let makespan = if legged && !jobs.is_empty() {
                flow_pipeline_time(&jobs)
            } else {
                pipeline_time(&stages)
            };
            rep.sim_overlapped = (serial - makespan).max(0.0);
        }
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use std::thread;

    use super::super::allreduce::tests::run_collective;
    use super::super::{Asa, FlatKind, StrategyKind, WireFormat};
    use super::*;
    use crate::cluster::Topology;
    use crate::mpi;
    use crate::simnet::LinkParams;

    /// The alignment property the bit-identity argument rests on: gathering
    /// slice c of every rank segment yields a chunk whose own
    /// `split_even(chunk_len, k)` is exactly those slice lengths.
    #[test]
    fn aligned_split_matches_inner_split() {
        for n in [1usize, 7, 64, 1003, 100_000] {
            for k in [1usize, 2, 3, 5, 8] {
                for m in [1usize, 2, 3, 7, 16] {
                    let parts = split_even(n, k);
                    let slices: Vec<Vec<(usize, usize)>> =
                        parts.iter().map(|&(_, len)| split_even(len, m)).collect();
                    for c in 0..m {
                        let lens: Vec<usize> = (0..k).map(|r| slices[r][c].1).collect();
                        let chunk_len: usize = lens.iter().sum();
                        let want: Vec<usize> =
                            split_even(chunk_len, k).into_iter().map(|(_, l)| l).collect();
                        assert_eq!(lens, want, "n={n} k={k} m={m} c={c}");
                    }
                }
            }
        }
    }

    fn chunked(kind: StrategyKind, chunk_elems: usize, pipeline: bool) -> ChunkedPipeline {
        ChunkedPipeline::new(kind.build(WireFormat::F32), chunk_elems, pipeline)
    }

    /// Run strategy monolithic and chunked on identical inputs; both the
    /// data and the cross-rank agreement must be exact.
    fn run_equivalence(kind: StrategyKind, k: usize, n: usize, chunk_elems: usize, op: ReduceOp) {
        let mk = || -> Vec<Vec<f32>> {
            (0..k)
                .map(|r| (0..n).map(|i| (((r * 131 + i * 17) % 997) as f32 - 498.0) * 1e-3).collect())
                .collect()
        };
        let topo = Topology::mosaic(k);
        let (mono, _) = run_threads(kind.build(WireFormat::F32), k, mk(), op, topo.clone());
        let (chun, rep) = run_threads(
            Box::new(chunked(kind, chunk_elems, true)),
            k,
            mk(),
            op,
            topo,
        );
        for (r, (a, b)) in mono.iter().zip(&chun).enumerate() {
            assert_eq!(a, b, "{}: rank {r} diverged (k={k} n={n} chunk={chunk_elems})", kind.name());
        }
        if n > chunk_elems && k > 1 {
            assert!(rep.chunks >= 2, "expected chunking, got {} chunks", rep.chunks);
        }
    }

    /// Thread harness for boxed strategies (run_collective wants Clone).
    fn run_threads(
        strat: Box<dyn ExchangeStrategy>,
        k: usize,
        bufs: Vec<Vec<f32>>,
        op: ReduceOp,
        topo: Topology,
    ) -> (Vec<Vec<f32>>, CommReport) {
        let strat = std::sync::Arc::new(strat);
        let world = mpi::world(k);
        let links = LinkParams::default();
        let handles: Vec<_> = world
            .into_iter()
            .zip(bufs)
            .map(|(mut comm, mut buf)| {
                let topo = topo.clone();
                let strat = strat.clone();
                thread::spawn(move || {
                    let mut ctx = ExchangeCtx {
                        comm: &mut comm,
                        topo: &topo,
                        links: &links,
                        kernels: None,
                        cuda_aware: true,
                        chunk_elems: 0,
                        slice_off: 0,
                        sf_bytes: None,
                    };
                    let rep = strat.exchange(&mut buf, op, &mut ctx).unwrap();
                    (buf, rep)
                })
            })
            .collect();
        let mut outs = Vec::new();
        let mut rep0 = CommReport::default();
        for (i, h) in handles.into_iter().enumerate() {
            let (buf, rep) = h.join().unwrap();
            if i == 0 {
                rep0 = rep;
            }
            outs.push(buf);
        }
        (outs, rep0)
    }

    #[test]
    fn chunked_is_bit_identical_to_monolithic_for_all_strategies() {
        // the acceptance property: chunking must never change the data path
        for kind in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring]
        {
            for k in [2usize, 3, 8] {
                let n = 1003; // ragged on purpose
                for chunk in [n.div_ceil(2), n.div_ceil(3), n.div_ceil(8)] {
                    run_equivalence(kind, k, n, chunk, ReduceOp::Sum);
                }
            }
        }
        // mean path too (weight averaging under AWAGD)
        run_equivalence(StrategyKind::Asa, 4, 777, 100, ReduceOp::Mean);
        run_equivalence(StrategyKind::Ring, 3, 500, 77, ReduceOp::Mean);
    }

    #[test]
    fn small_buffer_falls_back_to_monolithic() {
        let k = 4;
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![r as f32; 64]).collect();
        let (_, rep) = run_threads(
            Box::new(chunked(StrategyKind::Asa, 1024, true)),
            k,
            bufs,
            ReduceOp::Sum,
            Topology::mosaic(k),
        );
        assert_eq!(rep.chunks, 1);
        assert_eq!(rep.sim_overlapped, 0.0);
    }

    #[test]
    fn pipelined_chunks_strictly_beat_monolithic_on_copper() {
        // the acceptance criterion: on the copper fabric at >= 4 workers the
        // overlap strictly reduces sim_total for the same strategy, because
        // the summation kernels of chunk i-1 hide under chunk i's transfer
        // while the chunk stream pipelines the per-message latency away
        let n = 1_000_000;
        for k in [4usize, 8] {
            let topo = Topology::by_name("copper", k).unwrap();
            let mk = || (0..k).map(|r| vec![r as f32 * 0.5; n]).collect::<Vec<_>>();
            let (_, mono) =
                run_threads(StrategyKind::Asa.build(WireFormat::F32), k, mk(), ReduceOp::Sum, topo.clone());
            let (_, piped) = run_threads(
                Box::new(chunked(StrategyKind::Asa, n / 8, true)),
                k,
                mk(),
                ReduceOp::Sum,
                topo.clone(),
            );
            let (_, serial) = run_threads(
                Box::new(chunked(StrategyKind::Asa, n / 8, false)),
                k,
                mk(),
                ReduceOp::Sum,
                topo,
            );
            assert!(piped.sim_overlapped > 0.0, "k={k}: no overlap recorded");
            assert!(
                piped.sim_total() < mono.sim_total(),
                "k={k}: piped {} !< mono {}",
                piped.sim_total(),
                mono.sim_total()
            );
            // the ablation: chunking without the pipeline must not win
            assert!(
                serial.sim_total() >= mono.sim_total() - Secs(1e-12),
                "k={k}: serial chunking should not beat monolithic"
            );
            assert!(piped.effective_gbps() > mono.effective_gbps(), "k={k}");
        }
    }

    #[test]
    fn overlap_never_exceeds_kernel_time() {
        // sanity on the accounting: you cannot hide more than you have
        let k = 4;
        let n = 400_000;
        let topo = Topology::by_name("copper", k).unwrap();
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![r as f32; n]).collect();
        let (_, rep) = run_threads(
            Box::new(chunked(StrategyKind::Asa, n / 16, true)),
            k,
            bufs,
            ReduceOp::Sum,
            topo,
        );
        assert!(rep.sim_overlapped > 0.0);
        assert!(
            rep.sim_overlapped
                <= rep.sim_kernel + rep.sim_host_reduce + rep.sim_latency + Secs(1e-12),
            "overlapped {} > hideable {}",
            rep.sim_overlapped,
            rep.sim_kernel + rep.sim_host_reduce + rep.sim_latency
        );
    }

    #[test]
    fn chunked_hier_overlaps_levels_and_beats_flat_ring_on_copper() {
        // the hier acceptance property: chunked(hier:ring) streams chunks
        // through the level flow-shop (switch PCIe / host RAM / NIC) and
        // beats both the monolithic and the chunked flat ring on copper at
        // 8 GPUs/node x 2 nodes, while the data stays a correct allreduce
        // on every rank (allclose, not bit-identity: the leader-level
        // segmentation shifts with the chunk size)
        let k = 16;
        let n = 200_000;
        let topo = Topology::by_name("copper", k).unwrap();
        let mk = || -> Vec<Vec<f32>> {
            (0..k)
                .map(|r| (0..n).map(|i| ((r * 31 + i) % 1000) as f32 * 1e-3).collect())
                .collect()
        };
        let mut want = vec![0.0f32; n];
        for b in mk() {
            for (o, x) in want.iter_mut().zip(&b) {
                *o += x;
            }
        }
        let hier = StrategyKind::Hier { inner: FlatKind::Ring };
        let (outs, piped) = run_threads(
            Box::new(ChunkedPipeline::new(hier.build(WireFormat::F32), n / 8, true)),
            k,
            mk(),
            ReduceOp::Sum,
            topo.clone(),
        );
        for (r, out) in outs.iter().enumerate() {
            crate::testkit::allclose(out, &want, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("rank {r}: {e}"));
        }
        assert!(piped.sim_overlapped > 0.0, "no cross-level overlap recorded");
        assert_eq!(piped.chunks, 8);
        let (_, flat_mono) =
            run_threads(StrategyKind::Ring.build(WireFormat::F32), k, mk(), ReduceOp::Sum, topo.clone());
        let (_, flat_piped) = run_threads(
            Box::new(ChunkedPipeline::new(StrategyKind::Ring.build(WireFormat::F32), n / 8, true)),
            k,
            mk(),
            ReduceOp::Sum,
            topo,
        );
        assert!(
            piped.sim_total() < flat_mono.sim_total(),
            "hier piped {} !< flat mono {}",
            piped.sim_total(),
            flat_mono.sim_total()
        );
        assert!(
            piped.sim_total() < flat_piped.sim_total(),
            "hier piped {} !< flat piped {}",
            piped.sim_total(),
            flat_piped.sim_total()
        );
        // and strictly fewer NIC bytes than the flat inner it wraps
        assert!(piped.wire_inter_bytes < flat_mono.wire_inter_bytes);
    }

    #[test]
    fn chunked_hier_serial_ablation_does_not_overlap() {
        let k = 16;
        let n = 64_000;
        let topo = Topology::by_name("copper", k).unwrap();
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![r as f32; n]).collect();
        let hier = StrategyKind::Hier { inner: FlatKind::Ring };
        let (_, serial) = run_threads(
            Box::new(ChunkedPipeline::new(hier.build(WireFormat::F32), n / 8, false)),
            k,
            bufs,
            ReduceOp::Sum,
            topo,
        );
        assert_eq!(serial.sim_overlapped, 0.0);
        assert_eq!(serial.chunks, 8);
    }

    #[test]
    fn chunked_wire_bytes_match_monolithic() {
        let k = 4;
        let n = 8192;
        let mk = || (0..k).map(|r| vec![r as f32; n]).collect::<Vec<_>>();
        let (_, mono) = run_collective(Asa, k, mk(), ReduceOp::Sum, Topology::mosaic(k));
        let (_, chun) = run_threads(
            Box::new(chunked(StrategyKind::Asa, n / 4, true)),
            k,
            mk(),
            ReduceOp::Sum,
            Topology::mosaic(k),
        );
        assert_eq!(mono.wire_bytes, chun.wire_bytes);
    }
}
