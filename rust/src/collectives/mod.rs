//! Parameter-exchange strategies — the paper's central contribution (§3.2).
//!
//! All strategies implement [`ExchangeStrategy`]: a collective over the flat
//! f32 parameter/gradient vector that every rank calls simultaneously.
//! Buffers really move through the `mpi` layer and the arithmetic really
//! runs (host loops for AR, the L1 Pallas sum/cast kernels for ASA/ASA16);
//! wire time is charged from the `simnet` topology model.
//!
//! * [`HostAllreduce`] (**AR**) — the `MPI_Allreduce` baseline. OpenMPI
//!   1.8.7's CUDA-aware allreduce still stages through host memory because
//!   the reduction arithmetic runs on the CPU: D2H, a recursive-doubling
//!   butterfly between host buffers, host summation each round, H2D.
//! * [`Asa`] (**ASA**) — CUDA-aware *Alltoall-sum-Allgather* (Fig. 2):
//!   transfer and arithmetic separated; Alltoall/Allgather move device
//!   buffers directly (no host staging within a PCIe switch), and each
//!   rank's segment sum runs as the Pallas summation kernel.
//! * [`Asa16`] (**ASA16**) — ASA with 16-bit wire format: pack to half
//!   (Pallas cast kernel), exchange half the bytes, sum at full precision
//!   (§3.2: "transfer of parameters at half-precision while summing them at
//!   full precision"). The numeric degradation is real — Table 1's fp16
//!   accuracy rows come from running exactly this path.
//! * [`Ring`] — ring allreduce (reduce-scatter + allgather), the paper's
//!   "better inter-node strategy" future work; included as an ablation.

mod allreduce;
mod asa;
mod ring;

pub use allreduce::HostAllreduce;
pub use asa::{Asa, Asa16};
pub use ring::Ring;

use anyhow::Result;

use crate::cluster::Topology;
use crate::mpi::Comm;
use crate::precision::Wire;
use crate::runtime::Kernels;
use crate::simnet::LinkParams;

/// Reduction applied across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// SUBGD: gradients are summed.
    Sum,
    /// AWAGD: weights are averaged.
    Mean,
}

/// Everything a strategy needs from the calling worker.
pub struct ExchangeCtx<'a, 'k> {
    pub comm: &'a mut Comm,
    pub topo: &'a Topology,
    pub links: &'a LinkParams,
    /// Pallas kernel handles; `None` falls back to host arithmetic (used by
    /// unit tests without artifacts and by the AR baseline, which sums on
    /// the host by definition).
    pub kernels: Option<&'a Kernels<'k>>,
    /// GPUDirect P2P available (paper §3.2/6; affects intra-switch paths).
    pub cuda_aware: bool,
}

/// Per-exchange accounting (one rank's view; identical across ranks since
/// the simulated phases are global).
#[derive(Clone, Debug, Default)]
pub struct CommReport {
    pub strategy: String,
    /// Bytes this rank moved (sent) across all phases.
    pub wire_bytes: u64,
    /// Simulated transfer time (s).
    pub sim_transfer: f64,
    /// Simulated GPU kernel time inside the exchange: sums + casts (s).
    pub sim_kernel: f64,
    /// Simulated host CPU reduction time (AR only) (s).
    pub sim_host_reduce: f64,
    /// Measured PJRT wall time of the real kernels (diagnostic).
    pub real_kernel: f64,
    /// Number of communication phases.
    pub phases: usize,
}

impl CommReport {
    /// Total simulated exchange time — what the virtual clock advances by.
    pub fn sim_total(&self) -> f64 {
        self.sim_transfer + self.sim_kernel + self.sim_host_reduce
    }

    /// Share of exchange time in GPU kernels (paper: 1.6 % for the ASA sum).
    pub fn kernel_share(&self) -> f64 {
        let t = self.sim_total();
        if t > 0.0 {
            self.sim_kernel / t
        } else {
            0.0
        }
    }
}

/// A collective parameter-exchange strategy.
pub trait ExchangeStrategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Collectively reduce `buf` across all ranks of `ctx.comm` in place.
    /// Every rank must call this with an equal-length buffer.
    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport>;
}

/// Strategy selection by name (config files / CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Ar,
    Asa,
    Asa16,
    Ring,
}

impl StrategyKind {
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s {
            "ar" | "allreduce" => Some(StrategyKind::Ar),
            "asa" => Some(StrategyKind::Asa),
            "asa16" => Some(StrategyKind::Asa16),
            "ring" => Some(StrategyKind::Ring),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Ar => "ar",
            StrategyKind::Asa => "asa",
            StrategyKind::Asa16 => "asa16",
            StrategyKind::Ring => "ring",
        }
    }

    pub fn build(self, wire: Wire) -> Box<dyn ExchangeStrategy> {
        match self {
            StrategyKind::Ar => Box::new(HostAllreduce),
            StrategyKind::Asa => Box::new(Asa),
            StrategyKind::Asa16 => Box::new(Asa16::new(wire)),
            StrategyKind::Ring => Box::new(Ring),
        }
    }
}

/// Host-side elementwise add (the AR baseline's reduction, and the fallback
/// when no kernels are bound).
pub(crate) fn host_add(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

pub(crate) fn host_scale(buf: &mut [f32], s: f32) {
    for v in buf.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_kind_parse_roundtrip() {
        for k in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
            assert_eq!(StrategyKind::parse(k.name()), Some(k));
        }
        assert_eq!(StrategyKind::parse("allreduce"), Some(StrategyKind::Ar));
        assert_eq!(StrategyKind::parse("nope"), None);
    }

    #[test]
    fn report_totals() {
        let r = CommReport {
            sim_transfer: 0.9,
            sim_kernel: 0.016,
            sim_host_reduce: 0.0,
            ..Default::default()
        };
        assert!((r.sim_total() - 0.916).abs() < 1e-12);
        assert!((r.kernel_share() - 0.016 / 0.916).abs() < 1e-9);
    }
}
