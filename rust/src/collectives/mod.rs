//! Parameter-exchange strategies — the paper's central contribution (§3.2).
//!
//! All strategies implement [`ExchangeStrategy`]: a collective over the flat
//! f32 parameter/gradient vector that every rank calls simultaneously.
//! Buffers really move through the `mpi` layer and the arithmetic really
//! runs (host loops for AR, the L1 Pallas sum/cast kernels for ASA/ASA16);
//! wire time is charged from the `simnet` topology model.
//!
//! * [`HostAllreduce`] (**AR**) — the `MPI_Allreduce` baseline. OpenMPI
//!   1.8.7's CUDA-aware allreduce still stages through host memory because
//!   the reduction arithmetic runs on the CPU: D2H, a recursive-doubling
//!   butterfly between host buffers, host summation each round, H2D.
//! * [`Asa`] (**ASA**) — CUDA-aware *Alltoall-sum-Allgather* (Fig. 2):
//!   transfer and arithmetic separated; Alltoall/Allgather move device
//!   buffers directly (no host staging within a PCIe switch), and each
//!   rank's segment sum runs as the Pallas summation kernel.
//! * [`Asa16`] (**ASA16**) — ASA with 16-bit wire format: pack to half
//!   (Pallas cast kernel), exchange half the bytes, sum at full precision
//!   (§3.2: "transfer of parameters at half-precision while summing them at
//!   full precision"). The numeric degradation is real — Table 1's fp16
//!   accuracy rows come from running exactly this path.
//! * [`Ring`] — ring allreduce (reduce-scatter + allgather), the paper's
//!   "better inter-node strategy" future work; included as an ablation.

mod allreduce;
mod asa;
mod chunked;
mod hier;
mod ring;
pub mod wfbp;
pub mod wire;

pub use allreduce::HostAllreduce;
pub use asa::{Asa, Asa16};
pub use chunked::ChunkedPipeline;
pub use hier::Hierarchical;
pub use ring::Ring;
pub use wfbp::{exchange_wfbp, OverlapMode, WfbpOutcome, WfbpPlan};
pub use wire::{WireCodec, WireFormat};

use anyhow::{anyhow, Result};

use crate::cluster::Topology;
use crate::mpi::Comm;
use crate::precision::Wire;
use crate::runtime::Kernels;
use crate::simnet::{Leg, LinkParams};
use crate::units::{Bytes, Secs};

/// Reduction applied across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// SUBGD: gradients are summed.
    Sum,
    /// AWAGD: weights are averaged.
    Mean,
}

/// Everything a strategy needs from the calling worker.
pub struct ExchangeCtx<'a, 'k> {
    pub comm: &'a mut Comm,
    pub topo: &'a Topology,
    pub links: &'a LinkParams,
    /// Pallas kernel handles; `None` falls back to host arithmetic (used by
    /// unit tests without artifacts and by the AR baseline, which sums on
    /// the host by definition).
    pub kernels: Option<&'a Kernels<'k>>,
    /// GPUDirect P2P available (paper §3.2/6; affects intra-switch paths).
    pub cuda_aware: bool,
    /// Accounting metadata: elements per pipeline chunk this exchange runs
    /// under (0 = monolithic). Set by the [`ChunkedPipeline`] scheduler on
    /// its inner per-chunk calls; no strategy branches on it today — it
    /// exists so tracing/kernels can observe the chunking regime.
    pub chunk_elems: usize,
    /// Absolute offset of `buf` within the rank's full flat vector. The
    /// chunked scheduler and the WFBP bucket loop set it on their per-slice
    /// inner calls so [`wire::WireCodec`] keeps its error-feedback residual
    /// aligned with the elements actually in `buf`.
    pub slice_off: usize,
    /// On-wire bytes of the current slice in sufficient-factor form
    /// (Poseidon-style `4·B·(n_in+n_out)` for an all-fc WFBP bucket), set
    /// by the WFBP bucket loop from [`wfbp::WfbpBucket::sf_elems`]. `None`
    /// makes the `sf` wire fall back to the dense wire.
    pub sf_bytes: Option<u64>,
}

/// Per-exchange accounting (one rank's view; identical across ranks since
/// the simulated phases are global). `PartialEq` is bit-level — the race
/// explorer asserts reports identical across delivery schedules.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommReport {
    pub strategy: String,
    /// Bytes this rank moved (sent) across all phases.
    pub wire_bytes: Bytes,
    /// Dense f32 bytes this rank *would* have sent had every value shipped
    /// uncompressed — the numerator of the observable compression ratio.
    /// 0 means "nothing was compressed" (raw == `wire_bytes`); the asa16
    /// native half wire and every [`wire::WireCodec`] format set it.
    pub wire_raw_bytes: Bytes,
    /// Simulated transfer time, latency included.
    pub sim_transfer: Secs,
    /// Latency component of `sim_transfer` (per-message terms).
    pub sim_latency: Secs,
    /// Simulated GPU kernel time inside the exchange: sums + casts.
    pub sim_kernel: Secs,
    /// Simulated host CPU reduction time (AR only).
    pub sim_host_reduce: Secs,
    /// Time hidden by the chunked pipeline's comm/compute overlap:
    /// chunk *i*'s wire transfer runs under chunk *i−1*'s kernels.
    /// Zero for monolithic exchanges.
    pub sim_overlapped: Secs,
    /// Measured PJRT wall time of the real kernels (diagnostic).
    pub real_kernel: Secs,
    /// Number of communication phases.
    pub phases: usize,
    /// Pipeline chunks this exchange was driven in (0 or 1 = monolithic).
    pub chunks: usize,
    /// Global bytes the whole exchange moved on intra-node paths (P2P or
    /// QPI), summed over every rank's transfers — identical across ranks.
    pub wire_intra_bytes: Bytes,
    /// Global bytes that crossed a node boundary (the NIC traffic the
    /// hierarchical exchange exists to cut).
    pub wire_inter_bytes: Bytes,
    /// Transfer time of the intra-node tree levels (`hier` only; flat
    /// strategies leave the intra/inter time split at zero).
    pub sim_intra: Secs,
    /// Transfer time of the leader-level inter-node exchange (`hier` only).
    pub sim_inter: Secs,
    /// Per-level wire legs of one exchange (`hier` only): the chunked
    /// scheduler prices cross-level overlap from these via
    /// [`flow_pipeline_time`](crate::simnet::flow_pipeline_time).
    pub legs: Vec<Leg>,
}

impl CommReport {
    /// Total simulated exchange time — what the virtual clock advances by.
    /// Overlapped time is real wall-clock saving, so it subtracts.
    pub fn sim_total(&self) -> Secs {
        self.sim_transfer + self.sim_kernel + self.sim_host_reduce - self.sim_overlapped
    }

    /// Wire bytes per simulated second — the effective exchange bandwidth
    /// a worker observes (rises when the pipeline hides kernel time).
    pub fn effective_gbps(&self) -> f64 {
        let t = self.sim_total();
        if t > 0.0 {
            self.wire_bytes.as_f64() / t.0 / 1e9
        } else {
            0.0
        }
    }

    /// Dense-equivalent bytes over actual on-wire bytes (≥ 1 for every
    /// shipped wire format; 1.0 when nothing was compressed).
    pub fn compression_ratio(&self) -> f64 {
        if self.wire_raw_bytes == 0 || self.wire_bytes == 0 {
            1.0
        } else {
            self.wire_raw_bytes.as_f64() / self.wire_bytes.as_f64()
        }
    }

    /// Accumulate a sub-exchange's accounting into this report — used by
    /// the chunked scheduler (per chunk) and the hierarchical strategy
    /// (leader-level sub-report). `strategy`, `chunks` and `legs` are the
    /// caller's to manage. Exhaustive destructuring: a new field must be
    /// either accumulated or explicitly left to the caller here.
    pub fn merge(&mut self, sub: &CommReport) {
        let CommReport {
            strategy: _, // caller's to manage
            wire_bytes,
            wire_raw_bytes,
            sim_transfer,
            sim_latency,
            sim_kernel,
            sim_host_reduce,
            sim_overlapped,
            real_kernel,
            phases,
            chunks: _, // caller's to manage
            wire_intra_bytes,
            wire_inter_bytes,
            sim_intra,
            sim_inter,
            legs: _, // caller's to manage
        } = sub;
        self.wire_bytes += *wire_bytes;
        self.wire_raw_bytes += *wire_raw_bytes;
        self.wire_intra_bytes += *wire_intra_bytes;
        self.wire_inter_bytes += *wire_inter_bytes;
        self.sim_transfer += *sim_transfer;
        self.sim_latency += *sim_latency;
        self.sim_kernel += *sim_kernel;
        self.sim_host_reduce += *sim_host_reduce;
        self.sim_overlapped += *sim_overlapped;
        self.sim_intra += *sim_intra;
        self.sim_inter += *sim_inter;
        self.real_kernel += *real_kernel;
        self.phases += phases;
    }

    /// Accumulate a whole exchange's report into a per-run aggregate (the
    /// BSP `comm` total): [`merge`](Self::merge) plus the per-exchange
    /// fields merge leaves to the caller — `chunks` sum, `strategy` takes
    /// the latest name, `legs` (a single exchange's wire shape) stay
    /// untouched. This replaces the old ad-hoc accumulator in `bsp`, which
    /// silently dropped the intra/inter byte and time splits.
    pub fn absorb(&mut self, sub: &CommReport) {
        let CommReport {
            strategy,
            wire_bytes: _, // summed by merge()
            wire_raw_bytes: _,
            sim_transfer: _,
            sim_latency: _,
            sim_kernel: _,
            sim_host_reduce: _,
            sim_overlapped: _,
            real_kernel: _,
            phases: _,
            chunks,
            wire_intra_bytes: _,
            wire_inter_bytes: _,
            sim_intra: _,
            sim_inter: _,
            legs: _, // one exchange's wire shape: meaningless to sum
        } = sub;
        self.merge(sub);
        self.strategy = strategy.clone();
        self.chunks += chunks;
    }

    /// Scale every simulated time and byte count by `s` — how probe-sized
    /// exchanges map onto full-scale models (`Session::measure_exchange*`)
    /// and how the WFBP scheduler joins probe-domain wire times with
    /// real-seconds bucket release times.
    pub fn scale_times(&mut self, s: f64) {
        if s == 1.0 {
            return;
        }
        self.sim_transfer *= s;
        self.sim_latency *= s;
        self.sim_kernel *= s;
        self.sim_host_reduce *= s;
        self.sim_overlapped *= s;
        self.sim_intra *= s;
        self.sim_inter *= s;
        // scale_round rounds: `as u64` would floor, silently dropping
        // bytes under fractional probe→full projection scales
        self.wire_bytes = self.wire_bytes.scale_round(s);
        self.wire_raw_bytes = self.wire_raw_bytes.scale_round(s);
        self.wire_intra_bytes = self.wire_intra_bytes.scale_round(s);
        self.wire_inter_bytes = self.wire_inter_bytes.scale_round(s);
        for leg in &mut self.legs {
            leg.transfer *= s;
            leg.latency *= s;
        }
    }

    /// Share of exchange time in GPU kernels (paper: 1.6 % for the ASA sum).
    pub fn kernel_share(&self) -> f64 {
        let t = self.sim_total();
        if t > 0.0 {
            self.sim_kernel / t
        } else {
            0.0
        }
    }
}

/// A collective parameter-exchange strategy.
pub trait ExchangeStrategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Collectively reduce `buf` across all ranks of `ctx.comm` in place.
    /// Every rank must call this with an equal-length buffer.
    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport>;
}

/// Flat strategies — directly selectable, and the inner collective a
/// [`StrategyKind::Hier`] composition runs across node leaders.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlatKind {
    Ar,
    Asa,
    Asa16,
    Ring,
}

impl FlatKind {
    /// The valid flat names, for error messages and help text.
    pub const NAMES: &'static str = "ar|allreduce|asa|asa16|ring";

    pub fn parse(s: &str) -> Option<FlatKind> {
        match s.to_ascii_lowercase().as_str() {
            "ar" | "allreduce" => Some(FlatKind::Ar),
            "asa" => Some(FlatKind::Asa),
            "asa16" => Some(FlatKind::Asa16),
            "ring" => Some(FlatKind::Ring),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FlatKind::Ar => "ar",
            FlatKind::Asa => "asa",
            FlatKind::Asa16 => "asa16",
            FlatKind::Ring => "ring",
        }
    }

    /// Build the *native* strategy for this wire format — no codec wrapping
    /// (that is [`StrategyKind::build`]'s job, at the outermost level only).
    /// `fmt` selects asa16's 16-bit value wire; a compressed format
    /// replaces the native half wire entirely (the codec owns the on-wire
    /// byte account), so asa16 degrades to plain ASA under it.
    pub fn build(self, fmt: WireFormat) -> Box<dyn ExchangeStrategy> {
        match self {
            FlatKind::Ar => Box::new(HostAllreduce),
            FlatKind::Asa => Box::new(Asa),
            FlatKind::Asa16 if fmt.compressed() => Box::new(Asa),
            FlatKind::Asa16 => Box::new(Asa16::new(fmt.half_or(Wire::F16))),
            FlatKind::Ring => Box::new(Ring),
        }
    }
}

/// A flat kind *is* a strategy kind — the correspondence the hier
/// benchmarks and tests use to compare a composition against its inner.
impl From<FlatKind> for StrategyKind {
    fn from(f: FlatKind) -> StrategyKind {
        match f {
            FlatKind::Ar => StrategyKind::Ar,
            FlatKind::Asa => StrategyKind::Asa,
            FlatKind::Asa16 => StrategyKind::Asa16,
            FlatKind::Ring => StrategyKind::Ring,
        }
    }
}

/// Strategy selection by name (config files / CLI). `hier:<inner>` composes
/// the two-level hierarchical exchange over any flat inner (`hier` alone
/// defaults to `hier:ring`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Ar,
    Asa,
    Asa16,
    Ring,
    Hier { inner: FlatKind },
}

impl StrategyKind {
    /// The valid names, for error messages and help text.
    pub const NAMES: &'static str = "ar|allreduce|asa|asa16|ring|hier:<inner>";

    /// Case-insensitive name lookup ("ASA16" or "HIER:Ring" from a config
    /// file is valid).
    pub fn parse(s: &str) -> Option<StrategyKind> {
        let lower = s.to_ascii_lowercase();
        if lower == "hier" {
            return Some(StrategyKind::Hier { inner: FlatKind::Ring });
        }
        if let Some(rest) = lower.strip_prefix("hier:") {
            return FlatKind::parse(rest).map(|inner| StrategyKind::Hier { inner });
        }
        match lower.as_str() {
            "ar" | "allreduce" => Some(StrategyKind::Ar),
            "asa" => Some(StrategyKind::Asa),
            "asa16" => Some(StrategyKind::Asa16),
            "ring" => Some(StrategyKind::Ring),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) that fails with an error naming the valid
    /// strategies — what config files and CLI flags surface to the user.
    /// A bad hier inner (`hier:warp`) names the valid inner set.
    pub fn from_name(s: &str) -> Result<StrategyKind> {
        if let Some(rest) = s.to_ascii_lowercase().strip_prefix("hier:") {
            return FlatKind::parse(rest)
                .map(|inner| StrategyKind::Hier { inner })
                .ok_or_else(|| {
                    anyhow!(
                        "unknown inner strategy '{rest}' for hier (valid: hier:{{{}}})",
                        FlatKind::NAMES
                    )
                });
        }
        Self::parse(s)
            .ok_or_else(|| anyhow!("unknown exchange strategy '{s}' (valid: {})", Self::NAMES))
    }

    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Ar => "ar",
            StrategyKind::Asa => "asa",
            StrategyKind::Asa16 => "asa16",
            StrategyKind::Ring => "ring",
            StrategyKind::Hier { inner } => match inner {
                FlatKind::Ar => "hier:ar",
                FlatKind::Asa => "hier:asa",
                FlatKind::Asa16 => "hier:asa16",
                FlatKind::Ring => "hier:ring",
            },
        }
    }

    /// Does this strategy move wire bytes at 16-bit precision? (EASGD uses
    /// this to pick the elastic exchange's wire format.)
    pub fn half_wire(self) -> bool {
        matches!(
            self,
            StrategyKind::Asa16 | StrategyKind::Hier { inner: FlatKind::Asa16 }
        )
    }

    /// Build the full exchange for `fmt`: the native strategy, wrapped in
    /// the [`WireCodec`] error-feedback layer whenever `fmt` asks for a
    /// wire the strategy cannot ship natively. `WireFormat::F32` always
    /// returns the bare strategy (bit-identical to the pre-wire behavior);
    /// f16/bf16 ride asa16's native value wire where available and the
    /// codec elsewhere; topk/onebit/sf always go through the codec, at the
    /// outermost level only (chunk/bucket sub-calls see the codec because
    /// the chunked and WFBP schedulers drive *this* strategy per slice).
    pub fn build(self, fmt: WireFormat) -> Box<dyn ExchangeStrategy> {
        let base: Box<dyn ExchangeStrategy> = match self {
            StrategyKind::Ar => FlatKind::Ar.build(fmt),
            StrategyKind::Asa => FlatKind::Asa.build(fmt),
            StrategyKind::Asa16 => FlatKind::Asa16.build(fmt),
            StrategyKind::Ring => FlatKind::Ring.build(fmt),
            StrategyKind::Hier { inner } => Box::new(Hierarchical::new(inner, fmt)),
        };
        if fmt.needs_codec(self.half_wire()) {
            Box::new(WireCodec::new(base, fmt))
        } else {
            base
        }
    }
}

/// Host-side elementwise add (the AR baseline's reduction, and the fallback
/// when no kernels are bound).
pub(crate) fn host_add(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

pub(crate) fn host_scale(buf: &mut [f32], s: f32) {
    for v in buf.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_kind_parse_roundtrip() {
        for k in [
            StrategyKind::Ar,
            StrategyKind::Asa,
            StrategyKind::Asa16,
            StrategyKind::Ring,
            StrategyKind::Hier { inner: FlatKind::Ar },
            StrategyKind::Hier { inner: FlatKind::Asa },
            StrategyKind::Hier { inner: FlatKind::Asa16 },
            StrategyKind::Hier { inner: FlatKind::Ring },
        ] {
            assert_eq!(StrategyKind::parse(k.name()), Some(k));
        }
        assert_eq!(StrategyKind::parse("allreduce"), Some(StrategyKind::Ar));
        assert_eq!(
            StrategyKind::parse("hier"),
            Some(StrategyKind::Hier { inner: FlatKind::Ring })
        );
        assert_eq!(
            StrategyKind::parse("hier:allreduce"),
            Some(StrategyKind::Hier { inner: FlatKind::Ar })
        );
        assert_eq!(StrategyKind::parse("nope"), None);
        assert_eq!(StrategyKind::parse("hier:warp"), None);
        assert_eq!(StrategyKind::parse("hier:hier:ring"), None, "hier does not nest");
    }

    #[test]
    fn strategy_kind_parse_is_case_insensitive() {
        assert_eq!(StrategyKind::parse("ASA16"), Some(StrategyKind::Asa16));
        assert_eq!(StrategyKind::parse("Ring"), Some(StrategyKind::Ring));
        assert_eq!(StrategyKind::parse("AllReduce"), Some(StrategyKind::Ar));
        assert_eq!(
            StrategyKind::parse("HIER:Asa16"),
            Some(StrategyKind::Hier { inner: FlatKind::Asa16 })
        );
    }

    #[test]
    fn from_name_error_lists_valid_strategies() {
        let err = StrategyKind::from_name("warp").unwrap_err().to_string();
        assert!(err.contains("warp"), "{err}");
        assert!(err.contains("asa16") && err.contains("ring"), "{err}");
        assert_eq!(StrategyKind::from_name("ASA").unwrap(), StrategyKind::Asa);
        // a bad hier inner names the valid inner set specifically
        let err = StrategyKind::from_name("hier:warp").unwrap_err().to_string();
        assert!(err.contains("warp") && err.contains("hier"), "{err}");
        assert!(err.contains(FlatKind::NAMES), "{err}");
        assert_eq!(
            StrategyKind::from_name("hier:ring").unwrap(),
            StrategyKind::Hier { inner: FlatKind::Ring }
        );
    }

    #[test]
    fn half_wire_matrix() {
        assert!(StrategyKind::Asa16.half_wire());
        assert!(StrategyKind::Hier { inner: FlatKind::Asa16 }.half_wire());
        assert!(!StrategyKind::Asa.half_wire());
        assert!(!StrategyKind::Hier { inner: FlatKind::Ring }.half_wire());
    }

    #[test]
    fn merge_accumulates_all_accounting() {
        let sub = CommReport {
            wire_bytes: Bytes(10),
            wire_raw_bytes: Bytes(40),
            wire_intra_bytes: Bytes(6),
            wire_inter_bytes: Bytes(4),
            sim_transfer: Secs(1.0),
            sim_latency: Secs(0.1),
            sim_kernel: Secs(0.2),
            sim_host_reduce: Secs(0.3),
            sim_overlapped: Secs(0.05),
            sim_intra: Secs(0.7),
            sim_inter: Secs(0.3),
            real_kernel: Secs(0.01),
            phases: 3,
            ..Default::default()
        };
        let mut rep = CommReport::default();
        rep.merge(&sub);
        rep.merge(&sub);
        assert_eq!(rep.wire_bytes, 20);
        assert_eq!(rep.wire_raw_bytes, 80);
        assert_eq!(rep.wire_intra_bytes, 12);
        assert_eq!(rep.wire_inter_bytes, 8);
        assert_eq!(rep.phases, 6);
        assert!((rep.sim_transfer - Secs(2.0)).abs() < 1e-12);
        assert!((rep.sim_intra - Secs(1.4)).abs() < 1e-12);
        assert!((rep.sim_inter - Secs(0.6)).abs() < 1e-12);
        assert!((rep.sim_overlapped - Secs(0.1)).abs() < 1e-12);
        assert!(rep.legs.is_empty(), "merge leaves legs to the caller");
    }

    #[test]
    fn absorb_keeps_intra_inter_split_and_sums_chunks() {
        let sub = CommReport {
            strategy: "hier:ring".into(),
            wire_bytes: Bytes(10),
            wire_intra_bytes: Bytes(6),
            wire_inter_bytes: Bytes(4),
            sim_transfer: Secs(1.0),
            sim_intra: Secs(0.7),
            sim_inter: Secs(0.3),
            phases: 2,
            chunks: 4,
            ..Default::default()
        };
        let mut agg = CommReport::default();
        agg.absorb(&sub);
        agg.absorb(&sub);
        assert_eq!(agg.strategy, "hier:ring");
        assert_eq!(agg.chunks, 8, "absorb sums chunks (merge leaves them)");
        // the regression absorb() exists for: the per-run aggregate must
        // keep the intra/inter byte and time splits
        assert_eq!(agg.wire_intra_bytes, 12);
        assert_eq!(agg.wire_inter_bytes, 8);
        assert!((agg.sim_intra - Secs(1.4)).abs() < 1e-12);
        assert!((agg.sim_inter - Secs(0.6)).abs() < 1e-12);
        assert_eq!(agg.phases, 4);
    }

    #[test]
    fn scale_times_scales_every_time_and_byte_field() {
        let mut rep = CommReport {
            wire_bytes: Bytes(100),
            wire_raw_bytes: Bytes(400),
            wire_intra_bytes: Bytes(60),
            wire_inter_bytes: Bytes(40),
            sim_transfer: Secs(1.0),
            sim_latency: Secs(0.1),
            sim_kernel: Secs(0.2),
            sim_host_reduce: Secs(0.3),
            sim_overlapped: Secs(0.05),
            sim_intra: Secs(0.7),
            sim_inter: Secs(0.3),
            legs: vec![Leg { machine: 2, transfer: Secs(0.5), latency: Secs(0.01) }],
            ..Default::default()
        };
        let total = rep.sim_total();
        rep.scale_times(2.0);
        assert_eq!(rep.wire_bytes, 200);
        assert_eq!(rep.wire_raw_bytes, 800);
        assert_eq!(rep.wire_intra_bytes, 120);
        assert_eq!(rep.wire_inter_bytes, 80);
        assert!((rep.sim_total() - 2.0 * total).abs() < 1e-12);
        assert!((rep.legs[0].transfer - Secs(1.0)).abs() < 1e-12);
        assert!((rep.legs[0].latency - Secs(0.02)).abs() < 1e-12);
        // identity scale is a no-op fast path
        let before = rep.sim_transfer;
        rep.scale_times(1.0);
        assert_eq!(rep.sim_transfer, before);
    }

    #[test]
    fn scale_times_rounds_bytes_instead_of_truncating() {
        // the probe→full projection regression: `as u64` floored the
        // scaled byte fields, so a fractional comm_scale silently dropped
        // bytes (e.g. 61M elems over a 1M probe scales by 60.965224)
        let mut rep = CommReport {
            wire_bytes: Bytes(999),
            wire_raw_bytes: Bytes(1_998),
            wire_intra_bytes: Bytes(333),
            wire_inter_bytes: Bytes(667),
            ..Default::default()
        };
        rep.scale_times(1.5);
        assert_eq!(rep.wire_bytes, 1_499, "999*1.5 = 1498.5 rounds up");
        assert_eq!(rep.wire_raw_bytes, 2_997);
        assert_eq!(rep.wire_intra_bytes, 500, "333*1.5 = 499.5 rounds up");
        assert_eq!(rep.wire_inter_bytes, 1_001, "667*1.5 = 1000.5, not 1000");
        // a probe-shaped fractional scale keeps the relative error at
        // rounding level, not a whole truncated byte per field
        let mut probe = CommReport { wire_bytes: Bytes(4_000_000), ..Default::default() };
        let scale = 60_965_224.0 / 1_000_000.0;
        probe.scale_times(scale);
        assert_eq!(probe.wire_bytes, 243_860_896);
    }

    #[test]
    fn compression_ratio_reads_raw_over_wire() {
        let none = CommReport { wire_bytes: Bytes(100), ..Default::default() };
        assert_eq!(none.compression_ratio(), 1.0, "raw=0 marks uncompressed");
        let half =
            CommReport { wire_bytes: Bytes(50), wire_raw_bytes: Bytes(100), ..Default::default() };
        assert_eq!(half.compression_ratio(), 2.0);
        let empty = CommReport::default();
        assert_eq!(empty.compression_ratio(), 1.0);
    }

    #[test]
    fn report_totals() {
        let r = CommReport {
            sim_transfer: Secs(0.9),
            sim_kernel: Secs(0.016),
            sim_host_reduce: Secs(0.0),
            ..Default::default()
        };
        assert!((r.sim_total() - Secs(0.916)).abs() < 1e-12);
        assert!((r.kernel_share() - 0.016 / 0.916).abs() < 1e-9);
    }

    #[test]
    fn overlap_subtracts_from_total_and_raises_effective_bandwidth() {
        let base = CommReport {
            wire_bytes: Bytes(1_000_000_000),
            sim_transfer: Secs(1.0),
            sim_kernel: Secs(0.25),
            ..Default::default()
        };
        let overlapped = CommReport { sim_overlapped: Secs(0.2), ..base.clone() };
        assert!((base.sim_total() - Secs(1.25)).abs() < 1e-12);
        assert!((overlapped.sim_total() - Secs(1.05)).abs() < 1e-12);
        assert!(overlapped.effective_gbps() > base.effective_gbps());
    }
}
