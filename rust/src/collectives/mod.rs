//! Parameter-exchange strategies — the paper's central contribution (§3.2).
//!
//! All strategies implement [`ExchangeStrategy`]: a collective over the flat
//! f32 parameter/gradient vector that every rank calls simultaneously.
//! Buffers really move through the `mpi` layer and the arithmetic really
//! runs (host loops for AR, the L1 Pallas sum/cast kernels for ASA/ASA16);
//! wire time is charged from the `simnet` topology model.
//!
//! * [`HostAllreduce`] (**AR**) — the `MPI_Allreduce` baseline. OpenMPI
//!   1.8.7's CUDA-aware allreduce still stages through host memory because
//!   the reduction arithmetic runs on the CPU: D2H, a recursive-doubling
//!   butterfly between host buffers, host summation each round, H2D.
//! * [`Asa`] (**ASA**) — CUDA-aware *Alltoall-sum-Allgather* (Fig. 2):
//!   transfer and arithmetic separated; Alltoall/Allgather move device
//!   buffers directly (no host staging within a PCIe switch), and each
//!   rank's segment sum runs as the Pallas summation kernel.
//! * [`Asa16`] (**ASA16**) — ASA with 16-bit wire format: pack to half
//!   (Pallas cast kernel), exchange half the bytes, sum at full precision
//!   (§3.2: "transfer of parameters at half-precision while summing them at
//!   full precision"). The numeric degradation is real — Table 1's fp16
//!   accuracy rows come from running exactly this path.
//! * [`Ring`] — ring allreduce (reduce-scatter + allgather), the paper's
//!   "better inter-node strategy" future work; included as an ablation.

mod allreduce;
mod asa;
mod chunked;
mod ring;

pub use allreduce::HostAllreduce;
pub use asa::{Asa, Asa16};
pub use chunked::ChunkedPipeline;
pub use ring::Ring;

use anyhow::{anyhow, Result};

use crate::cluster::Topology;
use crate::mpi::Comm;
use crate::precision::Wire;
use crate::runtime::Kernels;
use crate::simnet::LinkParams;

/// Reduction applied across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// SUBGD: gradients are summed.
    Sum,
    /// AWAGD: weights are averaged.
    Mean,
}

/// Everything a strategy needs from the calling worker.
pub struct ExchangeCtx<'a, 'k> {
    pub comm: &'a mut Comm,
    pub topo: &'a Topology,
    pub links: &'a LinkParams,
    /// Pallas kernel handles; `None` falls back to host arithmetic (used by
    /// unit tests without artifacts and by the AR baseline, which sums on
    /// the host by definition).
    pub kernels: Option<&'a Kernels<'k>>,
    /// GPUDirect P2P available (paper §3.2/6; affects intra-switch paths).
    pub cuda_aware: bool,
    /// Accounting metadata: elements per pipeline chunk this exchange runs
    /// under (0 = monolithic). Set by the [`ChunkedPipeline`] scheduler on
    /// its inner per-chunk calls; no strategy branches on it today — it
    /// exists so tracing/kernels can observe the chunking regime.
    pub chunk_elems: usize,
}

/// Per-exchange accounting (one rank's view; identical across ranks since
/// the simulated phases are global).
#[derive(Clone, Debug, Default)]
pub struct CommReport {
    pub strategy: String,
    /// Bytes this rank moved (sent) across all phases.
    pub wire_bytes: u64,
    /// Simulated transfer time (s), latency included.
    pub sim_transfer: f64,
    /// Latency component of `sim_transfer` (per-message terms, s).
    pub sim_latency: f64,
    /// Simulated GPU kernel time inside the exchange: sums + casts (s).
    pub sim_kernel: f64,
    /// Simulated host CPU reduction time (AR only) (s).
    pub sim_host_reduce: f64,
    /// Time hidden by the chunked pipeline's comm/compute overlap (s):
    /// chunk *i*'s wire transfer runs under chunk *i−1*'s kernels.
    /// Zero for monolithic exchanges.
    pub sim_overlapped: f64,
    /// Measured PJRT wall time of the real kernels (diagnostic).
    pub real_kernel: f64,
    /// Number of communication phases.
    pub phases: usize,
    /// Pipeline chunks this exchange was driven in (0 or 1 = monolithic).
    pub chunks: usize,
}

impl CommReport {
    /// Total simulated exchange time — what the virtual clock advances by.
    /// Overlapped time is real wall-clock saving, so it subtracts.
    pub fn sim_total(&self) -> f64 {
        self.sim_transfer + self.sim_kernel + self.sim_host_reduce - self.sim_overlapped
    }

    /// Wire bytes per simulated second — the effective exchange bandwidth
    /// a worker observes (rises when the pipeline hides kernel time).
    pub fn effective_gbps(&self) -> f64 {
        let t = self.sim_total();
        if t > 0.0 {
            self.wire_bytes as f64 / t / 1e9
        } else {
            0.0
        }
    }

    /// Share of exchange time in GPU kernels (paper: 1.6 % for the ASA sum).
    pub fn kernel_share(&self) -> f64 {
        let t = self.sim_total();
        if t > 0.0 {
            self.sim_kernel / t
        } else {
            0.0
        }
    }
}

/// A collective parameter-exchange strategy.
pub trait ExchangeStrategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Collectively reduce `buf` across all ranks of `ctx.comm` in place.
    /// Every rank must call this with an equal-length buffer.
    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport>;
}

/// Strategy selection by name (config files / CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Ar,
    Asa,
    Asa16,
    Ring,
}

impl StrategyKind {
    /// The valid names, for error messages and help text.
    pub const NAMES: &'static str = "ar|allreduce|asa|asa16|ring";

    /// Case-insensitive name lookup ("ASA16" from a config file is valid).
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.to_ascii_lowercase().as_str() {
            "ar" | "allreduce" => Some(StrategyKind::Ar),
            "asa" => Some(StrategyKind::Asa),
            "asa16" => Some(StrategyKind::Asa16),
            "ring" => Some(StrategyKind::Ring),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) that fails with an error naming the valid
    /// strategies — what config files and CLI flags surface to the user.
    pub fn from_name(s: &str) -> Result<StrategyKind> {
        Self::parse(s)
            .ok_or_else(|| anyhow!("unknown exchange strategy '{s}' (valid: {})", Self::NAMES))
    }

    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Ar => "ar",
            StrategyKind::Asa => "asa",
            StrategyKind::Asa16 => "asa16",
            StrategyKind::Ring => "ring",
        }
    }

    pub fn build(self, wire: Wire) -> Box<dyn ExchangeStrategy> {
        match self {
            StrategyKind::Ar => Box::new(HostAllreduce),
            StrategyKind::Asa => Box::new(Asa),
            StrategyKind::Asa16 => Box::new(Asa16::new(wire)),
            StrategyKind::Ring => Box::new(Ring),
        }
    }
}

/// Host-side elementwise add (the AR baseline's reduction, and the fallback
/// when no kernels are bound).
pub(crate) fn host_add(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

pub(crate) fn host_scale(buf: &mut [f32], s: f32) {
    for v in buf.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_kind_parse_roundtrip() {
        for k in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
            assert_eq!(StrategyKind::parse(k.name()), Some(k));
        }
        assert_eq!(StrategyKind::parse("allreduce"), Some(StrategyKind::Ar));
        assert_eq!(StrategyKind::parse("nope"), None);
    }

    #[test]
    fn strategy_kind_parse_is_case_insensitive() {
        assert_eq!(StrategyKind::parse("ASA16"), Some(StrategyKind::Asa16));
        assert_eq!(StrategyKind::parse("Ring"), Some(StrategyKind::Ring));
        assert_eq!(StrategyKind::parse("AllReduce"), Some(StrategyKind::Ar));
    }

    #[test]
    fn from_name_error_lists_valid_strategies() {
        let err = StrategyKind::from_name("warp").unwrap_err().to_string();
        assert!(err.contains("warp"), "{err}");
        assert!(err.contains("asa16") && err.contains("ring"), "{err}");
        assert_eq!(StrategyKind::from_name("ASA").unwrap(), StrategyKind::Asa);
    }

    #[test]
    fn report_totals() {
        let r = CommReport {
            sim_transfer: 0.9,
            sim_kernel: 0.016,
            sim_host_reduce: 0.0,
            ..Default::default()
        };
        assert!((r.sim_total() - 0.916).abs() < 1e-12);
        assert!((r.kernel_share() - 0.016 / 0.916).abs() < 1e-9);
    }

    #[test]
    fn overlap_subtracts_from_total_and_raises_effective_bandwidth() {
        let base = CommReport {
            wire_bytes: 1_000_000_000,
            sim_transfer: 1.0,
            sim_kernel: 0.25,
            ..Default::default()
        };
        let overlapped = CommReport { sim_overlapped: 0.2, ..base.clone() };
        assert!((base.sim_total() - 1.25).abs() < 1e-12);
        assert!((overlapped.sim_total() - 1.05).abs() < 1e-12);
        assert!(overlapped.effective_gbps() > base.effective_gbps());
    }
}
