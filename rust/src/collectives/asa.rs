//! ASA / ASA16: CUDA-aware Alltoall-sum-Allgather (paper §3.2, Fig. 2).
//!
//! The flat vector is split into k near-equal segments. Phase 1 (Alltoall):
//! rank j receives everyone's copy of segment j, device-to-device. Sum: rank
//! j reduces its k copies with the Pallas summation kernel (the paper's GPU
//! sum, measured at 1.6 % of comm time). Phase 2 (Allgather): rank j
//! broadcasts the reduced segment j to everyone. Wire traffic per rank is
//! ~2·(k-1)/k·N versus AR's host-staged log2(k)·N with CPU sums — the
//! source of the ~3× communication win (Fig. 3).
//!
//! ASA16 packs each outgoing buffer to 16-bit halves with the Pallas cast
//! kernel and unpacks before summation, halving bytes on both phases while
//! summing at f32 (the further ~2× of Fig. 3). Accuracy loss is real and
//! propagates to Table 1's fp16 rows.

use anyhow::Result;

use crate::mpi::{tags, Payload};
use crate::precision::Wire;
use crate::simnet::{phase_cost, split_traffic, Transfer};
use crate::units::{Bytes, Secs};
use crate::util::split_even;

use super::{host_add, host_scale, CommReport, ExchangeCtx, ExchangeStrategy, ReduceOp};

#[derive(Clone)]
pub struct Asa;

#[derive(Clone)]
pub struct Asa16 {
    wire: Wire,
}

impl Asa16 {
    pub fn new(wire: Wire) -> Asa16 {
        Asa16 { wire }
    }
}

/// Shared ASA skeleton; `half` enables the 16-bit wire format.
fn asa_exchange(
    buf: &mut [f32],
    op: ReduceOp,
    ctx: &mut ExchangeCtx<'_, '_>,
    half: Option<Wire>,
) -> Result<CommReport> {
    let k = ctx.comm.size;
    let rank = ctx.comm.rank;
    let n = buf.len();
    let name = if half.is_some() { "asa16" } else { "asa" };
    let mut rep = CommReport { strategy: name.into(), ..Default::default() };
    if k == 1 {
        return Ok(rep);
    }
    let parts = split_even(n, k);
    let elem_bytes: u64 = if half.is_some() { 2 } else { 4 };

    // --- Phase 1: Alltoall — send segment j to rank j -----------------------
    let mut my_parts: Vec<Vec<f32>> = Vec::with_capacity(k);
    {
        for j in 0..k {
            if j == rank {
                continue;
            }
            let (off, len) = parts[j];
            let seg = &buf[off..off + len];
            match half {
                Some(wire) => {
                    let (bits, t) = pack(ctx, wire, seg, &mut rep)?;
                    rep.real_kernel += Secs(t);
                    ctx.comm.send(j, tags::EXCHANGE, Payload::U16(bits), 0.0)?;
                }
                None => {
                    ctx.comm.send(j, tags::EXCHANGE, Payload::F32(seg.to_vec()), 0.0)?;
                }
            }
            rep.wire_bytes += Bytes(elem_bytes * len as u64);
            if half.is_some() {
                // dense-equivalent bytes, so compression_ratio() sees the
                // native half wire like any codec wire
                rep.wire_raw_bytes += Bytes(4 * len as u64);
            }
        }
        let (my_off, my_len) = parts[rank];
        // own copy participates in the sum without touching the wire
        my_parts.push(buf[my_off..my_off + my_len].to_vec());
        for j in 0..k {
            if j == rank {
                continue;
            }
            let m = ctx.comm.recv(j, tags::EXCHANGE)?;
            let seg = match half {
                Some(wire) => {
                    let bits = m.payload.into_u16()?;
                    let (vals, t) = unpack(ctx, wire, &bits, &mut rep)?;
                    rep.real_kernel += Secs(t);
                    vals
                }
                None => m.payload.into_f32()?,
            };
            my_parts.push(seg);
        }
    }
    // simulated time of the alltoall phase (all pairs concurrently)
    let mut transfers = Vec::new();
    for src in 0..k {
        for dst in 0..k {
            if src != dst {
                let bytes = Bytes(elem_bytes * parts[dst].1 as u64);
                transfers.push(Transfer { src, dst, bytes });
            }
        }
    }
    let cost = phase_cost(ctx.topo, ctx.links, &transfers, ctx.cuda_aware);
    rep.sim_transfer += cost.total();
    rep.sim_latency += cost.latency;
    rep.phases += 1;
    let s = split_traffic(ctx.topo, &transfers);
    rep.wire_intra_bytes += s.intra_bytes;
    rep.wire_inter_bytes += s.inter_bytes;

    // --- Sum: reduce my k copies on the "GPU" (Pallas sum-stack kernel) -----
    let (_, my_len) = parts[rank];
    let mut reduced = if my_len == 0 {
        Vec::new()
    } else if let Some(kn) = ctx.kernels {
        let refs: Vec<&[f32]> = my_parts.iter().map(|v| v.as_slice()).collect();
        let out = kn.sum_parts(&refs)?;
        rep.real_kernel += Secs(out.exec_time);
        out.value
    } else {
        let mut acc = my_parts[0].clone();
        for p in &my_parts[1..] {
            host_add(&mut acc, p);
        }
        acc
    };
    // the paper's measurement point: GPU summation over k·seg bytes.
    // Charged at the LARGEST segment: the following allgather cannot start
    // until the slowest rank's kernel finishes, and clocks must stay
    // identical across ranks (segments differ by ±1 element).
    let max_len = parts.iter().map(|p| p.1).max().unwrap_or(0);
    rep.sim_kernel += ctx.links.gpu_reduce_time(Bytes(4 * (k * max_len) as u64));
    if op == ReduceOp::Mean {
        host_scale(&mut reduced, 1.0 / k as f32);
        rep.sim_kernel += ctx.links.gpu_reduce_time(Bytes(4 * max_len as u64)) * 0.5;
    }

    // --- Phase 2: Allgather — broadcast my reduced segment ------------------
    for j in 0..k {
        if j == rank {
            continue;
        }
        match half {
            Some(wire) => {
                let (bits, t) = pack(ctx, wire, &reduced, &mut rep)?;
                rep.real_kernel += Secs(t);
                ctx.comm.send(j, tags::ALLGATHER, Payload::U16(bits), 0.0)?;
            }
            None => {
                ctx.comm.send(j, tags::ALLGATHER, Payload::F32(reduced.clone()), 0.0)?;
            }
        }
        rep.wire_bytes += Bytes(elem_bytes * reduced.len() as u64);
        if half.is_some() {
            rep.wire_raw_bytes += Bytes(4 * reduced.len() as u64);
        }
    }
    {
        let (off, len) = parts[rank];
        buf[off..off + len].copy_from_slice(&reduced);
    }
    for j in 0..k {
        if j == rank {
            continue;
        }
        let m = ctx.comm.recv(j, tags::ALLGATHER)?;
        let (off, len) = parts[j];
        match half {
            Some(wire) => {
                let bits = m.payload.into_u16()?;
                let (vals, t) = unpack(ctx, wire, &bits, &mut rep)?;
                rep.real_kernel += Secs(t);
                buf[off..off + len].copy_from_slice(&vals);
            }
            None => {
                buf[off..off + len].copy_from_slice(&m.payload.into_f32()?);
            }
        }
    }
    let mut transfers = Vec::new();
    for src in 0..k {
        for dst in 0..k {
            if src != dst {
                let bytes = Bytes(elem_bytes * parts[src].1 as u64);
                transfers.push(Transfer { src, dst, bytes });
            }
        }
    }
    let cost = phase_cost(ctx.topo, ctx.links, &transfers, ctx.cuda_aware);
    rep.sim_transfer += cost.total();
    rep.sim_latency += cost.latency;
    rep.phases += 1;
    let s = split_traffic(ctx.topo, &transfers);
    rep.wire_intra_bytes += s.intra_bytes;
    rep.wire_inter_bytes += s.inter_bytes;

    Ok(rep)
}

/// Pack via the Pallas cast kernel when bound, else the bit-exact host mirror.
fn pack(
    ctx: &ExchangeCtx<'_, '_>,
    wire: Wire,
    xs: &[f32],
    rep: &mut CommReport,
) -> Result<(Vec<u16>, f64)> {
    rep.sim_kernel += ctx.links.gpu_cast_time(Bytes(4 * xs.len() as u64));
    if let Some(kn) = ctx.kernels {
        let out = kn.pack(wire, xs)?;
        Ok((out.value, out.exec_time))
    } else {
        let mut bits = Vec::new();
        wire.pack(xs, &mut bits);
        Ok((bits, 0.0))
    }
}

fn unpack(
    ctx: &ExchangeCtx<'_, '_>,
    wire: Wire,
    bits: &[u16],
    rep: &mut CommReport,
) -> Result<(Vec<f32>, f64)> {
    rep.sim_kernel += ctx.links.gpu_cast_time(Bytes(2 * bits.len() as u64));
    if let Some(kn) = ctx.kernels {
        let out = kn.unpack(wire, bits)?;
        Ok((out.value, out.exec_time))
    } else {
        let mut vals = Vec::new();
        wire.unpack(bits, &mut vals);
        Ok((vals, 0.0))
    }
}

impl ExchangeStrategy for Asa {
    fn name(&self) -> &'static str {
        "asa"
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        asa_exchange(buf, op, ctx, None)
    }
}

impl ExchangeStrategy for Asa16 {
    fn name(&self) -> &'static str {
        "asa16"
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        asa_exchange(buf, op, ctx, Some(self.wire))
    }
}

#[cfg(test)]
mod tests {
    use super::super::allreduce::tests::run_collective;
    use super::*;
    use crate::cluster::Topology;
    use crate::testkit;

    fn expected_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        let mut out = vec![0.0f32; bufs[0].len()];
        for b in bufs {
            for (o, x) in out.iter_mut().zip(b) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn asa_matches_sum_for_all_world_sizes() {
        for k in [2usize, 3, 4, 5, 8] {
            for n in [1usize, 5, 1000, 1003] {
                let bufs: Vec<Vec<f32>> = (0..k)
                    .map(|r| (0..n).map(|i| ((r + 1) * (i + 1)) as f32 * 0.001).collect())
                    .collect();
                let want = expected_sum(&bufs);
                let (outs, rep) =
                    run_collective(Asa, k, bufs, ReduceOp::Sum, Topology::mosaic(k));
                for out in &outs {
                    testkit::allclose(out, &want, 1e-5, 1e-5)
                        .unwrap_or_else(|e| panic!("k={k} n={n}: {e}"));
                }
                assert_eq!(rep.phases, 2);
                assert!(rep.sim_kernel > 0.0, "ASA sums on GPU");
                assert_eq!(rep.sim_host_reduce, 0.0, "ASA never reduces on host");
            }
        }
    }

    #[test]
    fn asa_mean_matches() {
        let k = 4;
        let n = 64;
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![(r + 1) as f32; n]).collect();
        let (outs, _) = run_collective(Asa, k, bufs, ReduceOp::Mean, Topology::mosaic(k));
        for out in &outs {
            for v in out {
                assert!((v - 2.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn asa16_sum_is_approximate_but_close() {
        let k = 4;
        let n = 512;
        let bufs: Vec<Vec<f32>> = (0..k)
            .map(|r| (0..n).map(|i| ((r * n + i) as f32 * 0.01).sin()).collect())
            .collect();
        let want = expected_sum(&bufs);
        let (outs, rep) =
            run_collective(Asa16::new(Wire::F16), k, bufs, ReduceOp::Sum, Topology::mosaic(k));
        // half precision: ~1e-3 relative error expected, not exact
        for out in &outs {
            testkit::allclose(out, &want, 5e-3, 5e-3).unwrap();
        }
        assert!(rep.wire_bytes > 0);
    }

    #[test]
    fn asa16_halves_wire_bytes() {
        let k = 4;
        let n = 4096;
        let mk = |_: usize| (0..k).map(|r| vec![r as f32; n]).collect::<Vec<_>>();
        let (_, rep32) = run_collective(Asa, k, mk(0), ReduceOp::Sum, Topology::mosaic(k));
        let (_, rep16) =
            run_collective(Asa16::new(Wire::F16), k, mk(0), ReduceOp::Sum, Topology::mosaic(k));
        assert_eq!(rep32.wire_bytes, 2 * rep16.wire_bytes);
        assert!(rep16.sim_transfer < rep32.sim_transfer);
        // the native half wire reports its dense-equivalent bytes too
        assert_eq!(rep32.wire_raw_bytes, 0, "f32 wire is uncompressed");
        assert_eq!(rep16.wire_raw_bytes, rep32.wire_bytes);
        assert!((rep16.compression_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn asa_faster_than_ar_on_mosaic8_alexnet_scale() {
        // Fig. 3's headline: ASA ≈3× and ASA16 ≈6× faster comm than AR for
        // AlexNet (60.97M params) on 8 single-GPU nodes. Use a scaled-down
        // buffer (same ratio structure — times are linear in bytes).
        let k = 8;
        let n = 60_965; // 1/1000 of AlexNet params
        let mk = || (0..k).map(|r| vec![r as f32; n]).collect::<Vec<_>>();
        let (_, ar) = run_collective(
            super::super::HostAllreduce,
            k,
            mk(),
            ReduceOp::Sum,
            Topology::mosaic(k),
        );
        let (_, asa) = run_collective(Asa, k, mk(), ReduceOp::Sum, Topology::mosaic(k));
        let (_, asa16) =
            run_collective(Asa16::new(Wire::F16), k, mk(), ReduceOp::Sum, Topology::mosaic(k));
        let r_asa = ar.sim_total() / asa.sim_total();
        let r_asa16 = ar.sim_total() / asa16.sim_total();
        assert!(r_asa > 1.8 && r_asa < 5.0, "AR/ASA = {r_asa}");
        assert!(r_asa16 > 3.5 && r_asa16 < 9.0, "AR/ASA16 = {r_asa16}");
        assert!(r_asa16 > r_asa);
    }

    #[test]
    fn asa_kernel_share_is_small_like_paper() {
        // §3.2: the GPU summation kernel takes ~1.6 % of total comm time.
        let k = 8;
        let n = 609_652; // 1/100 AlexNet
        let bufs = (0..k).map(|r| vec![r as f32; n]).collect::<Vec<_>>();
        let (_, rep) = run_collective(Asa, k, bufs, ReduceOp::Sum, Topology::mosaic(k));
        let share = rep.kernel_share();
        assert!(share > 0.001 && share < 0.08, "kernel share = {share}");
    }
}
