//! Wait-free backprop (WFBP): layer-bucketed gradient exchange that
//! overlaps the backward pass (Poseidon [Zhang et al. 2015], the layer-wise
//! comm/compute overlap Shi et al. 2017 identify as the scaling lever).
//!
//! Every exchange this crate priced before was monolithic *in time*: the
//! worker finishes the whole backward pass, then exchanges (the chunked
//! pipeline only overlaps comm with its own kernels). But layer *i*'s
//! gradients are ready long before layer 0's — backprop visits layers from
//! the output down — so the top layers' gradients can be on the wire while
//! the bottom layers are still computing. For fc-heavy models (AlexNet:
//! ~96 % of parameters in fc6-8, which backprop reaches *first* and which
//! cost almost no backward compute) nearly the whole exchange hides under
//! the conv backward tail.
//!
//! ## The model
//!
//! * **Layer table** — per-layer parameter counts in forward (exchange)
//!   order, from `manifest.full_scale[..].layers` / `segments`, the proxy
//!   model's own segment table, or [`crate::models::proxy_layer_split`].
//! * **Backward cost model** — backprop visits layers last-to-first; layer
//!   *i*'s backward compute weight is `params_i` for fc layers and
//!   `params_i ×` [`CONV_COMPUTE_REUSE`] for conv layers (each conv weight
//!   is re-used at every spatial position; 169 ≈ a 13×13 feature map is the
//!   documented proxy — the classic "convs: ~90 % of compute, ~5 % of
//!   params; fc: the reverse" split). [`release_fractions`] turns the
//!   weights into the fraction of the backward pass after which each
//!   layer's gradients exist.
//! * **Buckets** — [`WfbpPlan::from_layers`] coalesces layers, walking from
//!   the top of the network down, into buckets of at least `bucket_kib`
//!   (`0` = one bucket per layer). A bucket releases when its *last*
//!   (input-most) layer's gradients are ready.
//! * **Timeline** — each bucket's exchange is priced by the inner strategy
//!   (any of ar|asa|asa16|ring|hier:*, optionally chunk-pipelined) and
//!   scheduled on the joint compute+comm timeline
//!   [`crate::simnet::wfbp_timeline`]: the backward "machine" feeds bucket
//!   release times, the wire machines serve FIFO, and the makespan prices
//!   bucket *i*'s wire time hiding under layers *i−1..0*'s remaining
//!   backward compute.
//!
//! ## What WFBP does and does not change
//!
//! WFBP changes *when* bytes move, never *what* is exchanged: the data path
//! runs the same inner exchange over the same bucket slices whether the
//! timeline overlaps (`overlap = "wfbp"`) or prices serially after the
//! backward pass (`overlap = "post"`, the ablation) — the two are
//! bit-identical by construction and pinned by `tests/wfbp_overlap.rs`.
//! With a single bucket the data path and the price both reduce exactly to
//! today's post-backward exchange.

use anyhow::{anyhow, Result};

use crate::simnet::{wfbp_timeline, FlowJob, Leg, TimedJob, MACHINE_WIRE};
use crate::units::Secs;

use super::{CommReport, ExchangeCtx, ExchangeStrategy, ReduceOp};

/// Fraction of a measured fwd+bwd gradient step that is backward compute —
/// the overlap budget WFBP hides wire time under. The standard 1:2
/// forward:backward FLOP ratio of dense nets (each backward layer computes
/// both an input-gradient and a weight-gradient pass).
pub const BWD_FRACTION: f64 = 2.0 / 3.0;

/// Backward-compute weight multiplier for conv layers: each conv parameter
/// is re-used at every output spatial position, so per *parameter* a conv
/// layer costs far more compute than an fc layer. 169 = 13×13, an average
/// feature-map size — the documented proxy behind "convs hold ~5 % of the
/// parameters but ~90 % of the compute" (Krizhevsky 2014).
pub const CONV_COMPUTE_REUSE: f64 = 169.0;

/// When to exchange gradients relative to the backward pass (BSP/SUBGD).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverlapMode {
    /// Whole-vector exchange after the step — the pre-WFBP behavior.
    #[default]
    None,
    /// Layer buckets exchanged *after* the backward pass, priced serially —
    /// the ablation that isolates the wait-free win from the bucketing.
    Post,
    /// Wait-free backprop: each bucket's exchange starts the moment its
    /// gradients are ready, overlapping the remaining backward compute.
    Wfbp,
}

impl OverlapMode {
    /// The valid names, for error messages and help text.
    pub const NAMES: &'static str = "none|post|wfbp";

    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Some(OverlapMode::None),
            "post" => Some(OverlapMode::Post),
            "wfbp" => Some(OverlapMode::Wfbp),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) that fails naming the valid modes — what the
    /// config file and `--overlap` flag surface.
    pub fn from_name(s: &str) -> Result<OverlapMode> {
        Self::parse(s)
            .ok_or_else(|| anyhow!("unknown overlap mode '{s}' (valid: {})", Self::NAMES))
    }

    pub fn name(self) -> &'static str {
        match self {
            OverlapMode::None => "none",
            OverlapMode::Post => "post",
            OverlapMode::Wfbp => "wfbp",
        }
    }

    /// Does this mode exchange per-layer buckets (vs the whole vector)?
    pub fn bucketed(self) -> bool {
        self != OverlapMode::None
    }
}

/// Layer-name classification for the backward cost model: fully-connected
/// layers (and fc-style classifier heads) get no spatial compute re-use.
pub fn is_fc_layer(name: &str) -> bool {
    let lower = name.to_ascii_lowercase();
    lower.contains("fc") || lower.contains("classifier") || lower.contains("dense")
}

/// Backward-compute weight of one layer under the documented proxy model.
pub fn backward_weight(name: &str, params: usize) -> f64 {
    if is_fc_layer(name) {
        params as f64
    } else {
        params as f64 * CONV_COMPUTE_REUSE
    }
}

/// Per-layer gradient-ready times as fractions of the total backward pass.
///
/// Backprop visits layers last-to-first; layer *i*'s gradients are ready
/// once the backward compute of layers `i..L` has run, so
/// `out[i] = Σ_{j>=i} w_j / Σ w_j`. `out[0] == 1.0` always (the input-most
/// layer finishes the pass); `out` is non-increasing in `i`.
pub fn release_fractions(layers: &[(String, usize)]) -> Vec<f64> {
    let total: f64 = layers.iter().map(|(n, p)| backward_weight(n, *p)).sum();
    if total <= 0.0 {
        return vec![1.0; layers.len()];
    }
    let mut out = vec![0.0; layers.len()];
    let mut cum = 0.0;
    for i in (0..layers.len()).rev() {
        cum += backward_weight(&layers[i].0, layers[i].1);
        out[i] = cum / total;
    }
    // guard accumulation round-off: layer 0 is by definition the last ready
    out[0] = 1.0;
    out
}

/// One gradient bucket: a contiguous slice of the flat parameter vector
/// plus the fraction of the backward pass after which it is released.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WfbpBucket {
    pub off: usize,
    pub len: usize,
    /// Release time as a fraction of the backward pass ((0, 1]).
    pub release_frac: f64,
    /// Sufficient-factor element count for the `sf` wire: `Σ B·(n_in+n_out)`
    /// over the bucket's layers when *every* layer in the bucket is an fc
    /// layer with known dims ([`WfbpPlan::annotate_sf`]), else 0 (no hint —
    /// the sf wire falls back to dense for this bucket).
    pub sf_elems: usize,
}

/// Bucket partition of a model's flat parameter vector, in release
/// (exchange) order: top-of-network buckets first, ascending
/// `release_frac`, the final bucket (containing layer 0) at 1.0.
#[derive(Clone, Debug)]
pub struct WfbpPlan {
    pub buckets: Vec<WfbpBucket>,
    /// Vector length the bucket offsets index into.
    pub total_elems: usize,
}

impl WfbpPlan {
    /// Coalesce `layers` (forward order, `(name, params)`) into buckets of
    /// at least `bucket_elems` elements, walking from the top of the
    /// network down (gradient-ready order). `bucket_elems == 0` gives one
    /// bucket per layer. A bucket's release is its input-most layer's.
    pub fn from_layers(layers: &[(String, usize)], bucket_elems: usize) -> WfbpPlan {
        let total_elems: usize = layers.iter().map(|(_, p)| p).sum();
        if layers.is_empty() || total_elems == 0 {
            return WfbpPlan { buckets: vec![], total_elems };
        }
        let rel = release_fractions(layers);
        let mut offs = Vec::with_capacity(layers.len());
        let mut off = 0;
        for (_, p) in layers {
            offs.push(off);
            off += p;
        }
        let mut buckets = Vec::new();
        let mut acc = 0usize;
        let mut hi_end = total_elems; // exclusive end of the open bucket
        for i in (0..layers.len()).rev() {
            acc += layers[i].1;
            if (acc >= bucket_elems.max(1) || i == 0) && acc > 0 {
                buckets.push(WfbpBucket {
                    off: offs[i],
                    len: hi_end - offs[i],
                    release_frac: rel[i],
                    sf_elems: 0,
                });
                hi_end = offs[i];
                acc = 0;
            }
        }
        WfbpPlan { buckets, total_elems }
    }

    /// One bucket spanning the whole vector, released at the end of the
    /// backward pass — the plan under which WFBP prices exactly as the
    /// post-backward exchange.
    pub fn single(n: usize) -> WfbpPlan {
        WfbpPlan {
            buckets: vec![WfbpBucket { off: 0, len: n, release_frac: 1.0, sf_elems: 0 }],
            total_elems: n,
        }
    }

    /// Annotate each bucket with its sufficient-factor element count for
    /// the `sf` wire (Poseidon): an fc layer's gradient is `Σ_b δ_b·x_bᵀ`,
    /// so shipping the factors costs `batch·(n_in + n_out)` elements
    /// instead of the dense `n_in·n_out`. A bucket gets a hint only when
    /// every layer it covers is an fc layer with an entry in `dims`
    /// (`(name, n_in, n_out)`); mixed or unknown buckets keep `sf_elems = 0`
    /// and ride the dense wire. Call at full scale — the same `layers`
    /// table the plan was built from — *before* [`project`](Self::project),
    /// which scales the hints along with the boundaries.
    pub fn annotate_sf(
        &mut self,
        layers: &[(String, usize)],
        dims: &[(String, usize, usize)],
        batch: usize,
    ) {
        let mut offs = Vec::with_capacity(layers.len());
        let mut off = 0usize;
        for (_, p) in layers {
            offs.push(off);
            off += p;
        }
        if off != self.total_elems || batch == 0 {
            return;
        }
        for b in &mut self.buckets {
            if b.len == 0 {
                continue;
            }
            let mut sf = 0usize;
            let mut all_fc = true;
            for (i, (name, p)) in layers.iter().enumerate() {
                if *p == 0 || offs[i] + p <= b.off || offs[i] >= b.off + b.len {
                    continue;
                }
                match dims.iter().find(|(dn, _, _)| dn == name) {
                    Some(&(_, n_in, n_out)) if is_fc_layer(name) => {
                        sf += batch * (n_in + n_out);
                    }
                    _ => {
                        all_fc = false;
                        break;
                    }
                }
            }
            b.sf_elems = if all_fc { sf } else { 0 };
        }
    }

    /// Project the plan onto an `n`-element vector, preserving the bucket
    /// *proportions* and release times — how a full-scale layer table maps
    /// onto the capped comm probe or a proxy model's parameter vector.
    /// Boundaries round to the nearest element, stay monotone, and keep
    /// covering `[0, n)` disjointly; buckets may round to zero length.
    pub fn project(&self, n: usize) -> WfbpPlan {
        if self.total_elems == 0 || self.total_elems == n {
            let mut out = self.clone();
            out.total_elems = n;
            if self.total_elems == 0 && n > 0 {
                return WfbpPlan::single(n);
            }
            return out;
        }
        let t = self.total_elems as u128;
        let scale = |x: usize| -> usize { ((x as u128 * n as u128 + t / 2) / t) as usize };
        let buckets = self
            .buckets
            .iter()
            .map(|b| {
                let off = scale(b.off);
                let end = scale(b.off + b.len);
                WfbpBucket {
                    off,
                    len: end - off,
                    release_frac: b.release_frac,
                    sf_elems: scale(b.sf_elems),
                }
            })
            .collect();
        WfbpPlan { buckets, total_elems: n }
    }

    /// Number of non-empty buckets.
    pub fn n_buckets(&self) -> usize {
        self.buckets.iter().filter(|b| b.len > 0).count()
    }
}

/// Everything one wait-free exchange reports. All times are in the final
/// (comm-scaled) virtual-clock domain. `PartialEq` is bit-level, for the
/// race explorer's schedule-independence asserts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WfbpOutcome {
    /// Merged per-bucket accounting; `sim_total()` equals `comm_visible`.
    pub comm: CommReport,
    /// What the post-backward path would charge: Σ bucket exchange times.
    pub serial_comm: Secs,
    /// Exchange time the worker clock actually pays beyond the backward
    /// pass: `max(makespan − backward, 0)` under WFBP, `serial_comm` post.
    pub comm_visible: Secs,
    /// Exchange time hidden under backward compute: `serial − visible`.
    pub comm_hidden: Secs,
    /// Joint compute+comm makespan from the start of the backward pass.
    pub makespan: Secs,
    /// `comm_hidden / serial_comm` ∈ [0, 1] (0 when there is no comm).
    pub overlap_fraction: f64,
    /// Non-empty buckets exchanged.
    pub buckets: usize,
}

/// Run one wait-free (or post-backward, with `overlap = false`) bucketed
/// exchange of `buf` through `inner`, collectively across `ctx.comm`.
///
/// `backward_total` is the backward-pass time (seconds) whose tail the
/// bucket exchanges overlap; `comm_scale` maps the probe-sized simulated
/// wire times into the caller's time domain (1.0 when `buf` is full-scale)
/// — bucket *releases* are already in real seconds, so the two domains
/// must be joined here rather than by scaling the merged report afterward.
///
/// Every rank must call this with the same plan, op and flags; the data
/// path (which elements each inner exchange reduces, in which order) is
/// identical for `overlap` true and false.
#[allow(clippy::too_many_arguments)]
pub fn exchange_wfbp(
    inner: &dyn ExchangeStrategy,
    plan: &WfbpPlan,
    buf: &mut [f32],
    op: ReduceOp,
    ctx: &mut ExchangeCtx<'_, '_>,
    backward_total: Secs,
    comm_scale: f64,
    overlap: bool,
) -> Result<WfbpOutcome> {
    if plan.total_elems != buf.len() {
        return Err(anyhow!(
            "wfbp plan covers {} elems, buffer has {} (project() the plan first)",
            plan.total_elems,
            buf.len()
        ));
    }
    let mut rep =
        CommReport { strategy: format!("wfbp({})", inner.name()), ..Default::default() };
    let mut jobs: Vec<TimedJob> = Vec::with_capacity(plan.buckets.len());
    let mut serial = Secs::ZERO;
    let mut buckets_run = 0usize;
    let saved_off = ctx.slice_off;
    let saved_sf = ctx.sf_bytes;
    for b in &plan.buckets {
        if b.len == 0 {
            // deterministic in the plan: every rank skips the same buckets
            continue;
        }
        // a codec inner keys its residual off the bucket's vector offset;
        // the sf wire prices this bucket at its factor bytes when annotated
        ctx.slice_off = saved_off + b.off;
        ctx.sf_bytes = if b.sf_elems > 0 { Some(4 * b.sf_elems as u64) } else { saved_sf };
        let mut sub = inner.exchange(&mut buf[b.off..b.off + b.len], op, ctx)?;
        sub.scale_times(comm_scale);
        serial += sub.sim_total();
        let job = if sub.chunks > 1 {
            // chunk-pipelined inner: the bucket occupies the wire for its
            // internal (already overlap-priced) makespan as one block
            FlowJob {
                legs: vec![Leg {
                    machine: MACHINE_WIRE,
                    transfer: sub.sim_total(),
                    latency: sub.sim_latency.min(sub.sim_total()),
                }],
                kernel: Secs::ZERO,
            }
        } else if !sub.legs.is_empty() {
            // hierarchical inner: per-level legs stream through the level
            // flow-shop across buckets, exactly as the chunked scheduler
            FlowJob { legs: sub.legs.clone(), kernel: sub.sim_kernel + sub.sim_host_reduce }
        } else {
            FlowJob {
                legs: vec![Leg {
                    machine: MACHINE_WIRE,
                    transfer: sub.sim_transfer,
                    latency: sub.sim_latency,
                }],
                kernel: sub.sim_kernel + sub.sim_host_reduce,
            }
        };
        jobs.push(TimedJob { release: b.release_frac * backward_total, job });
        let chunks = sub.chunks.max(1);
        sub.legs.clear(); // merge() leaves legs/chunks to the caller
        rep.merge(&sub);
        rep.chunks += chunks;
        buckets_run += 1;
    }
    ctx.slice_off = saved_off;
    ctx.sf_bytes = saved_sf;

    let (makespan, comm_visible) = if overlap {
        let m = wfbp_timeline(&jobs);
        (m, (m - backward_total).max(0.0))
    } else {
        (backward_total + serial, serial)
    };
    let comm_hidden = (serial - comm_visible).max(0.0);
    // after this, rep.sim_total() == comm_visible: the virtual clock charge
    rep.sim_overlapped += comm_hidden;
    let overlap_fraction = if serial > 0.0 { comm_hidden / serial } else { 0.0 };
    Ok(WfbpOutcome {
        comm: rep,
        serial_comm: serial,
        comm_visible,
        comm_hidden,
        makespan,
        overlap_fraction,
        buckets: buckets_run,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(spec: &[(&str, usize)]) -> Vec<(String, usize)> {
        spec.iter().map(|(n, p)| (n.to_string(), *p)).collect()
    }

    /// The AlexNet shape in miniature: conv layers first, fc layers last.
    fn fc_heavy() -> Vec<(String, usize)> {
        table(&[("conv1", 100), ("conv2", 300), ("fc6", 4000), ("fc7", 2000), ("fc8", 600)])
    }

    #[test]
    fn overlap_mode_parse_roundtrip_and_errors() {
        for m in [OverlapMode::None, OverlapMode::Post, OverlapMode::Wfbp] {
            assert_eq!(OverlapMode::parse(m.name()), Some(m));
        }
        assert_eq!(OverlapMode::parse("WFBP"), Some(OverlapMode::Wfbp));
        assert_eq!(OverlapMode::parse("off"), Some(OverlapMode::None));
        assert_eq!(OverlapMode::parse("sometime"), None);
        let err = OverlapMode::from_name("later").unwrap_err().to_string();
        assert!(err.contains("later") && err.contains("wfbp"), "{err}");
        assert!(!OverlapMode::None.bucketed());
        assert!(OverlapMode::Post.bucketed() && OverlapMode::Wfbp.bucketed());
    }

    #[test]
    fn fc_layers_classified_by_name() {
        assert!(is_fc_layer("fc6"));
        assert!(is_fc_layer("loss3/classifier"));
        assert!(is_fc_layer("Dense_0"));
        assert!(!is_fc_layer("conv1"));
        assert!(!is_fc_layer("inception_3a/5x5"));
        assert!(backward_weight("conv1", 10) > backward_weight("fc6", 10));
    }

    #[test]
    fn release_fractions_are_monotone_and_fc_releases_early() {
        let t = fc_heavy();
        let rel = release_fractions(&t);
        assert_eq!(rel.len(), 5);
        assert_eq!(rel[0], 1.0, "input-most layer finishes the pass");
        for w in rel.windows(2) {
            assert!(w[0] >= w[1], "release fracs must be non-increasing: {rel:?}");
        }
        // fc8 (top) releases first; the 6600 fc params carry weight 6600
        // while the 400 conv params carry 400*169 = 67600: all fc grads are
        // ready within the first ~9% of the backward pass
        assert!(rel[2] < 0.1, "fc6 release {rel:?}");
        assert!(rel[1] > 0.9, "conv2 releases late: {rel:?}");
    }

    #[test]
    fn uniform_weights_when_no_fc() {
        let t = table(&[("conv1", 100), ("conv2", 100), ("conv3", 200)]);
        let rel = release_fractions(&t);
        assert!((rel[2] - 0.5).abs() < 1e-12);
        assert!((rel[1] - 0.75).abs() < 1e-12);
        assert_eq!(rel[0], 1.0);
    }

    #[test]
    fn per_layer_buckets_cover_disjointly_in_release_order() {
        let t = fc_heavy();
        let plan = WfbpPlan::from_layers(&t, 0);
        assert_eq!(plan.buckets.len(), 5);
        assert_eq!(plan.total_elems, 7000);
        // release order: fc8 (end of vector) first, conv1 last
        assert_eq!(plan.buckets[0].off, 6400);
        assert_eq!(plan.buckets[0].len, 600);
        assert_eq!(plan.buckets[4].off, 0);
        assert_eq!(plan.buckets[4].len, 100);
        assert_eq!(plan.buckets[4].release_frac, 1.0);
        let mut cover: Vec<(usize, usize)> =
            plan.buckets.iter().map(|b| (b.off, b.len)).collect();
        cover.sort_unstable();
        let mut off = 0;
        for (o, l) in cover {
            assert_eq!(o, off);
            off += l;
        }
        assert_eq!(off, 7000);
        for w in plan.buckets.windows(2) {
            assert!(w[0].release_frac <= w[1].release_frac);
        }
    }

    #[test]
    fn bucket_elems_coalesces_layers() {
        let t = fc_heavy();
        // 2500-elem buckets: fc8+fc7 (2600), fc6 (4000), conv2+conv1 (400,
        // closed by the i==0 rule even though undersized)
        let plan = WfbpPlan::from_layers(&t, 2500);
        assert_eq!(plan.buckets.len(), 3);
        assert_eq!(
            plan.buckets[0],
            WfbpBucket { off: 4400, len: 2600, release_frac: release_fractions(&t)[3], sf_elems: 0 }
        );
        assert_eq!(plan.buckets[1].off, 400);
        assert_eq!(plan.buckets[1].len, 4000);
        assert_eq!(plan.buckets[2].off, 0);
        assert_eq!(plan.buckets[2].len, 400);
        assert_eq!(plan.buckets[2].release_frac, 1.0);
        // one huge bucket degenerates to single()
        let one = WfbpPlan::from_layers(&t, usize::MAX);
        assert_eq!(one.buckets.len(), 1);
        assert_eq!(
            one.buckets[0],
            WfbpBucket { off: 0, len: 7000, release_frac: 1.0, sf_elems: 0 }
        );
    }

    #[test]
    fn project_preserves_cover_and_proportions() {
        let t = fc_heavy();
        let plan = WfbpPlan::from_layers(&t, 0);
        for n in [7000usize, 1003, 70, 5, 700_000] {
            let p = plan.project(n);
            assert_eq!(p.total_elems, n);
            assert_eq!(p.buckets.len(), plan.buckets.len());
            let mut cover: Vec<(usize, usize)> =
                p.buckets.iter().map(|b| (b.off, b.len)).collect();
            cover.sort_unstable();
            let mut off = 0;
            for (o, l) in cover {
                assert_eq!(o, off, "n={n}");
                off += l;
            }
            assert_eq!(off, n, "n={n}");
            for (a, b) in plan.buckets.iter().zip(&p.buckets) {
                assert_eq!(a.release_frac, b.release_frac);
            }
        }
        // identity projection keeps exact boundaries
        let same = plan.project(7000);
        assert_eq!(same.buckets, plan.buckets);
    }

    #[test]
    fn annotate_sf_marks_all_fc_buckets_only() {
        let t = fc_heavy();
        // fc dims chosen so n_in*n_out + n_out == the table's param counts
        let dims = vec![
            ("fc6".to_string(), 19usize, 200usize),   // 19*200+200 = 4000
            ("fc7".to_string(), 19, 100),             // 19*100+100 = 2000
            ("fc8".to_string(), 29, 20),              // 29*20+20 = 600
        ];
        let batch = 16;
        // per-layer buckets: the three fc buckets get hints, convs none
        let mut plan = WfbpPlan::from_layers(&t, 0);
        plan.annotate_sf(&t, &dims, batch);
        assert_eq!(plan.buckets[0].sf_elems, batch * (29 + 20), "fc8");
        assert_eq!(plan.buckets[1].sf_elems, batch * (19 + 100), "fc7");
        assert_eq!(plan.buckets[2].sf_elems, batch * (19 + 200), "fc6");
        assert_eq!(plan.buckets[3].sf_elems, 0, "conv2");
        assert_eq!(plan.buckets[4].sf_elems, 0, "conv1");
        // coalesced: the fc8+fc7 bucket sums both layers' factors; the
        // conv-containing buckets stay dense
        let mut co = WfbpPlan::from_layers(&t, 2500);
        co.annotate_sf(&t, &dims, batch);
        assert_eq!(co.buckets[0].sf_elems, batch * (29 + 20) + batch * (19 + 100));
        assert_eq!(co.buckets[1].sf_elems, batch * (19 + 200));
        assert_eq!(co.buckets[2].sf_elems, 0);
        // an fc layer missing from the dims table disqualifies its bucket
        let mut partial = WfbpPlan::from_layers(&t, 0);
        partial.annotate_sf(&t, &dims[..2], batch);
        assert_eq!(partial.buckets[0].sf_elems, 0, "fc8 has no dims entry");
        assert_eq!(partial.buckets[1].sf_elems, batch * (19 + 100));
        // a mismatched layer table is a no-op, not a misalignment
        let mut wrong = WfbpPlan::from_layers(&t, 0);
        wrong.annotate_sf(&t[..3], &dims, batch);
        assert!(wrong.buckets.iter().all(|b| b.sf_elems == 0));
    }

    #[test]
    fn project_scales_sf_hints_with_boundaries() {
        let t = fc_heavy();
        let dims = vec![
            ("fc6".to_string(), 19usize, 200usize),
            ("fc7".to_string(), 19, 100),
            ("fc8".to_string(), 29, 20),
        ];
        let mut plan = WfbpPlan::from_layers(&t, 0);
        plan.annotate_sf(&t, &dims, 16);
        let half = plan.project(3500);
        for (a, b) in plan.buckets.iter().zip(&half.buckets) {
            let want = ((a.sf_elems as u128 * 3500 + 3500) / 7000) as usize;
            assert_eq!(b.sf_elems, want);
        }
        // identity projection keeps the hints exactly
        assert_eq!(plan.project(7000).buckets, plan.buckets);
    }

    #[test]
    fn empty_and_zero_layer_tables() {
        let empty = WfbpPlan::from_layers(&[], 0);
        assert_eq!(empty.n_buckets(), 0);
        let zeros = WfbpPlan::from_layers(&table(&[("a", 0), ("b", 0)]), 0);
        assert_eq!(zeros.total_elems, 0);
        assert_eq!(zeros.n_buckets(), 0);
        // projecting an empty plan onto a real vector falls back to single
        assert_eq!(empty.project(64).buckets, WfbpPlan::single(64).buckets);
    }
}
