//! AR baseline: host-staged `MPI_Allreduce` (recursive doubling).
//!
//! Paper §3.2: "the CUDA-aware version of [MPI_Allreduce] in OpenMPI 1.8.7
//! does not give much improvement since any collective MPI function with
//! arithmetic operations still needs to copy data to host memory." So the
//! cost structure is: D2H of the full vector, ⌈log2 k⌉ butterfly rounds of
//! full-vector host-to-host transfers each followed by a CPU summation, and
//! a final H2D. Non-power-of-two worker counts fold the excess ranks into
//! the butterfly (MPICH-style pre/post phases).
//!
//! Each butterfly round is priced with its *actual* dist-peers: on copper
//! the dist=1 round pairs switch-local GPUs while the dist=8 round pairs
//! GPUs across the NIC, so a single representative round would underprice
//! the fabric (and misattribute the NIC byte split). On mosaic every round
//! crosses nodes, so per-round pricing reproduces the old numbers exactly.

use anyhow::Result;

use crate::mpi::{tags, Payload};
use crate::simnet::{split_traffic, PhaseCost, Transfer};
use crate::units::{Bytes, Secs};

use super::{host_add, host_scale, CommReport, ExchangeCtx, ExchangeStrategy, ReduceOp};

#[derive(Clone)]
pub struct HostAllreduce;

impl ExchangeStrategy for HostAllreduce {
    fn name(&self) -> &'static str {
        "ar"
    }

    fn exchange(
        &self,
        buf: &mut [f32],
        op: ReduceOp,
        ctx: &mut ExchangeCtx<'_, '_>,
    ) -> Result<CommReport> {
        let k = ctx.comm.size;
        let rank = ctx.comm.rank;
        let bytes = Bytes(4 * buf.len() as u64);
        let mut rep = CommReport { strategy: "ar".into(), ..Default::default() };
        if k == 1 {
            return Ok(rep);
        }

        // D2H once per rank (all ranks in parallel: one PCIe crossing each).
        rep.sim_transfer += ctx.links.pcie_time(bytes);
        rep.sim_latency += ctx.links.pcie_lat_us.to_secs();

        // Fold-down for non-power-of-two k: ranks >= p2 send to (r - p2).
        let p2 = k.next_power_of_two() >> usize::from(!k.is_power_of_two());
        let extra = k - p2; // ranks p2..k fold into 0..extra
        if rank >= p2 {
            let dst = rank - p2;
            ctx.comm.send(dst, tags::REDUCE, Payload::F32(buf.to_vec()), 0.0)?;
        } else if rank < extra {
            let m = ctx.comm.recv(rank + p2, tags::REDUCE)?;
            host_add(buf, &m.payload.into_f32()?);
        }
        if extra > 0 {
            let folds: Vec<Transfer> = (p2..k)
                .map(|r| Transfer { src: r, dst: r - p2, bytes })
                .collect();
            // host-level traffic: buffers already staged in host RAM
            let c = host_phase(ctx, &folds);
            rep.sim_transfer += c.total();
            rep.sim_latency += c.latency;
            rep.sim_host_reduce += ctx.links.host_reduce_time(bytes);
            rep.phases += 1;
            let s = split_traffic(ctx.topo, &folds);
            rep.wire_intra_bytes += s.intra_bytes;
            rep.wire_inter_bytes += s.inter_bytes;
            if rank < extra {
                rep.wire_bytes += Bytes(0); // received only
            } else if rank >= p2 {
                rep.wire_bytes += bytes;
            }
        }

        // Butterfly over ranks 0..p2.
        if rank < p2 {
            let mut dist = 1;
            while dist < p2 {
                let peer = rank ^ dist;
                let m =
                    ctx.comm.sendrecv(peer, tags::REDUCE + dist as u64, Payload::F32(buf.to_vec()), 0.0)?;
                host_add(buf, &m.payload.into_f32()?);
                rep.wire_bytes += bytes;
                dist <<= 1;
            }
        }
        // each round priced with its actual dist-peers (see module docs)
        let rounds = p2.trailing_zeros() as usize;
        if rounds > 0 {
            let mut dist = 1;
            while dist < p2 {
                let per_round: Vec<Transfer> =
                    (0..p2).map(|r| Transfer { src: r, dst: r ^ dist, bytes }).collect();
                let c = host_phase(ctx, &per_round);
                rep.sim_transfer += c.total();
                rep.sim_latency += c.latency;
                rep.sim_host_reduce += ctx.links.host_reduce_time(bytes);
                rep.phases += 1;
                let s = split_traffic(ctx.topo, &per_round);
                rep.wire_intra_bytes += s.intra_bytes;
                rep.wire_inter_bytes += s.inter_bytes;
                dist <<= 1;
            }
        }

        // Unfold: results back to the folded ranks.
        if extra > 0 {
            if rank < extra {
                ctx.comm.send(rank + p2, tags::REDUCE + 99, Payload::F32(buf.to_vec()), 0.0)?;
                rep.wire_bytes += bytes;
            } else if rank >= p2 {
                let m = ctx.comm.recv(rank - p2, tags::REDUCE + 99)?;
                buf.copy_from_slice(&m.payload.into_f32()?);
            }
            let unfolds: Vec<Transfer> = (p2..k)
                .map(|r| Transfer { src: r - p2, dst: r, bytes })
                .collect();
            let c = host_phase(ctx, &unfolds);
            rep.sim_transfer += c.total();
            rep.sim_latency += c.latency;
            rep.phases += 1;
            let s = split_traffic(ctx.topo, &unfolds);
            rep.wire_intra_bytes += s.intra_bytes;
            rep.wire_inter_bytes += s.inter_bytes;
        }

        // H2D once per rank.
        rep.sim_transfer += ctx.links.pcie_time(bytes);
        rep.sim_latency += ctx.links.pcie_lat_us.to_secs();

        if op == ReduceOp::Mean {
            host_scale(buf, 1.0 / k as f32);
            rep.sim_host_reduce += ctx.links.host_reduce_time(bytes) * 0.5;
        }
        Ok(rep)
    }
}

/// Phase cost for host-resident buffers: NIC/QPI crossings only (the D2H /
/// H2D PCIe legs are charged once, outside the butterfly).
fn host_phase(ctx: &ExchangeCtx<'_, '_>, transfers: &[Transfer]) -> PhaseCost {
    // Model by re-using the device-level phase pricing minus PCIe: we price
    // a same-node host->host move as a QPI-or-memcpy and cross-node as NIC.
    // Implemented by pricing the full path and subtracting the PCIe legs
    // would couple us to internals; instead price with a host-level topology
    // trick: transfers between GPUs on the same switch cost host memcpy.
    let p = ctx.links;
    let mut nic_out = vec![0.0f64; ctx.topo.n_nodes];
    let mut nic_in = vec![0.0f64; ctx.topo.n_nodes];
    let mut mem = vec![0.0f64; ctx.topo.n_nodes];
    let mut qpi = vec![0.0f64; ctx.topo.n_nodes];
    let mut lat: f64 = 0.0;
    let ib = p.ib_gbps(ctx.topo.ib).0;
    for t in transfers {
        if t.src == t.dst || t.bytes == 0 {
            continue;
        }
        let (a, b) = (ctx.topo.gpus[t.src], ctx.topo.gpus[t.dst]);
        let gb = t.bytes.as_f64() / 1e9;
        if a.node != b.node {
            nic_out[a.node] += gb / ib;
            nic_in[b.node] += gb / ib;
            mem[a.node] += gb / p.host_mem_gbps.0;
            mem[b.node] += gb / p.host_mem_gbps.0;
            lat = lat.max(p.ib_lat_us.0 * 1e-6);
        } else if a.socket != b.socket {
            qpi[a.node] += gb / p.qpi_gbps.0;
            lat = lat.max(p.qpi_lat_us.0 * 1e-6);
        } else {
            mem[a.node] += gb / p.host_mem_gbps.0;
        }
    }
    let max = |v: &[f64]| v.iter().copied().fold(0.0, f64::max);
    PhaseCost {
        bandwidth: Secs(max(&nic_out).max(max(&nic_in)).max(max(&mem)).max(max(&qpi))),
        latency: Secs(lat),
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::simnet::LinkParams;
    use std::thread;

    /// Run a collective across k threads over a topology; return rank-0 buf
    /// and report.
    pub(crate) fn run_collective<S: ExchangeStrategy + Clone + 'static>(
        strat: S,
        k: usize,
        bufs: Vec<Vec<f32>>,
        op: ReduceOp,
        topo: Topology,
    ) -> (Vec<Vec<f32>>, CommReport) {
        let world = crate::mpi::world(k);
        let links = LinkParams::default();
        let handles: Vec<_> = world
            .into_iter()
            .zip(bufs)
            .map(|(mut comm, mut buf)| {
                let topo = topo.clone();
                let strat = strat.clone();
                thread::spawn(move || {
                    let mut ctx = ExchangeCtx {
                        comm: &mut comm,
                        topo: &topo,
                        links: &links,
                        kernels: None,
                        cuda_aware: true,
                        chunk_elems: 0,
                        slice_off: 0,
                        sf_bytes: None,
                    };
                    let rep = strat.exchange(&mut buf, op, &mut ctx).unwrap();
                    (buf, rep)
                })
            })
            .collect();
        let mut outs = Vec::new();
        let mut rep0 = CommReport::default();
        for (i, h) in handles.into_iter().enumerate() {
            let (buf, rep) = h.join().unwrap();
            if i == 0 {
                rep0 = rep;
            }
            outs.push(buf);
        }
        (outs, rep0)
    }

    fn expected_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        let mut out = vec![0.0f32; bufs[0].len()];
        for b in bufs {
            for (o, x) in out.iter_mut().zip(b) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn allreduce_sums_for_all_world_sizes() {
        for k in [2usize, 3, 4, 5, 8] {
            let n = 1000;
            let bufs: Vec<Vec<f32>> =
                (0..k).map(|r| (0..n).map(|i| (r * n + i) as f32 * 0.01).collect()).collect();
            let want = expected_sum(&bufs);
            let (outs, rep) =
                run_collective(HostAllreduce, k, bufs, ReduceOp::Sum, Topology::mosaic(k));
            for (r, out) in outs.iter().enumerate() {
                crate::testkit::allclose(out, &want, 1e-5, 1e-4)
                    .unwrap_or_else(|e| panic!("k={k} rank={r}: {e}"));
            }
            assert!(rep.sim_total() > 0.0);
            assert!(rep.sim_host_reduce > 0.0, "AR must reduce on host");
        }
    }

    #[test]
    fn allreduce_mean() {
        let k = 4;
        let bufs: Vec<Vec<f32>> = (0..k).map(|r| vec![r as f32; 16]).collect();
        let (outs, _) =
            run_collective(HostAllreduce, k, bufs, ReduceOp::Mean, Topology::mosaic(k));
        for out in &outs {
            for v in out {
                assert!((v - 1.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn single_rank_is_noop() {
        let bufs = vec![vec![3.0f32; 8]];
        let (outs, rep) =
            run_collective(HostAllreduce, 1, bufs, ReduceOp::Sum, Topology::mosaic(1));
        assert_eq!(outs[0], vec![3.0f32; 8]);
        assert_eq!(rep.sim_total(), 0.0);
    }
}
