//! Parallel-SGD semantics: schemes and learning-rate schedules (paper §4).
//!
//! **AWAGD** — average weights after gradient descent: each worker applies a
//! full local momentum-SGD step, then workers average parameters; the
//! learning rate is scaled with worker count k ([15], [7]).
//!
//! **SUBGD** — sum updates before gradient descent: workers exchange (sum)
//! raw gradients and apply one update; the LR is *not* scaled. The paper
//! proves ([19]) the two are equivalent when workers stay synchronized, and
//! trains Figs. 4–5 with SUBGD.

/// Which parallel-SGD scheme the BSP engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Local full step (train artifact) + weight averaging.
    Awagd,
    /// Grad-only step (grad artifact) + gradient sum + sgd_apply artifact.
    Subgd,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Awagd => "awagd",
            Scheme::Subgd => "subgd",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "awagd" => Some(Scheme::Awagd),
            "subgd" => Some(Scheme::Subgd),
            _ => None,
        }
    }
}

/// Learning-rate schedules used in the paper's benchmarks.
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Const {
        base: f64,
    },
    /// AlexNet policy: scale down by `factor` every `every` iterations
    /// (the paper: /10 every 20 epochs).
    StepDecay {
        base: f64,
        factor: f64,
        every: usize,
    },
    /// GoogLeNet policy (footnote 13): base * (1 - iter/max_iters)^power
    /// with power = 0.5.
    Poly {
        base: f64,
        power: f64,
        max_iters: usize,
    },
}

impl LrSchedule {
    pub fn at(&self, iter: usize) -> f64 {
        match *self {
            LrSchedule::Const { base } => base,
            LrSchedule::StepDecay { base, factor, every } => {
                base * factor.powi((iter / every.max(1)) as i32)
            }
            LrSchedule::Poly { base, power, max_iters } => {
                let frac = 1.0 - (iter as f64 / max_iters.max(1) as f64).min(1.0);
                base * frac.powf(power)
            }
        }
    }
}

/// Host-side momentum SGD (reference/EASGD local steps without artifacts):
/// v' = mu*v - lr*g ; w' = w + v'.
pub fn momentum_step(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(w.len(), v.len());
    debug_assert_eq!(w.len(), g.len());
    for i in 0..w.len() {
        v[i] = mu * v[i] - lr * g[i];
        w[i] += v[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_matches_paper_policy() {
        // /10 every "20 epochs" (expressed in iterations)
        let s = LrSchedule::StepDecay { base: 0.01, factor: 0.1, every: 100 };
        assert!((s.at(0) - 0.01).abs() < 1e-12);
        assert!((s.at(99) - 0.01).abs() < 1e-12);
        assert!((s.at(100) - 0.001).abs() < 1e-12);
        assert!((s.at(250) - 0.0001).abs() < 1e-12);
    }

    #[test]
    fn poly_decays_to_zero_with_sqrt_shape() {
        let s = LrSchedule::Poly { base: 0.01, power: 0.5, max_iters: 100 };
        assert!((s.at(0) - 0.01).abs() < 1e-12);
        let mid = s.at(75);
        assert!((mid - 0.005).abs() < 1e-9, "{mid}"); // sqrt(0.25) = 0.5
        assert_eq!(s.at(100), 0.0);
        assert_eq!(s.at(1000), 0.0); // clamped past max
    }

    #[test]
    fn momentum_step_reference() {
        let mut w = vec![1.0f32, 2.0];
        let mut v = vec![0.5f32, -0.5];
        momentum_step(&mut w, &mut v, &[1.0, 1.0], 0.1, 0.9);
        // v' = 0.9*0.5 - 0.1 = 0.35 ; w' = 1.35
        assert!((v[0] - 0.35).abs() < 1e-6);
        assert!((w[0] - 1.35).abs() < 1e-6);
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("awagd"), Some(Scheme::Awagd));
        assert_eq!(Scheme::parse("subgd"), Some(Scheme::Subgd));
        assert_eq!(Scheme::parse("x"), None);
    }
}
