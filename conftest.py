"""pytest root conftest: make `compile.*` importable when running
`pytest python/tests/` from the repository root (the Makefile equivalently
runs pytest from inside python/)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
