//! Asynchronous EASGD demo (§4): elastic workers against a parameter server.
//!
//! ```bash
//! cargo run --release --offline --example easgd_async
//! ```
//!
//! Runs 4 elastic workers at τ=1, α=0.5 over both transports — CUDA-aware
//! MPI SendRecv and the Platoon-like posix-shm baseline — at AlexNet-scale
//! exchange bytes, reproducing the paper's comm-overhead comparison, then
//! shows a τ sweep (communication frequency vs convergence).

use std::sync::Arc;

use theano_mpi::easgd::{run_easgd, EasgdConfig, Transport};
use theano_mpi::runtime::Runtime;
use theano_mpi::sgd::LrSchedule;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);

    println!("== EASGD transports at tau=1 (AlexNet-scale exchange, single copper node) ==");
    let mut per = Vec::new();
    for transport in [Transport::PlatoonShm, Transport::CudaAwareMpi] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 80);
        cfg.transport = transport;
        cfg.topology = "copper".into();
        cfg.sim_model = Some("alexnet".into());
        cfg.lr = LrSchedule::Const { base: 0.05 };
        let rep = run_easgd(&rt, &cfg)?;
        println!(
            "{:<16} comm/exchange {:.4}s   total comm {:.3}s   throughput {:.0} ex/s",
            transport.name(),
            rep.comm_per_exchange,
            rep.comm_total,
            rep.throughput
        );
        per.push(rep.comm_per_exchange);
    }
    let reduction = (per[0] - per[1]) / per[0] * 100.0;
    println!("=> CUDA-aware MPI comm overhead is {reduction:.0}% lower (paper: 42%)");

    println!("\n== tau sweep (alpha=0.5) ==");
    println!("{:>4} {:>10} {:>12} {:>10}", "tau", "val_err", "comm tot(s)", "ex/s");
    for tau in [1usize, 2, 4, 8] {
        let mut cfg = EasgdConfig::quick("mlp", 4, 120);
        cfg.tau = tau;
        cfg.eval_every = 30;
        cfg.lr = LrSchedule::Const { base: 0.05 };
        let rep = run_easgd(&rt, &cfg)?;
        println!(
            "{tau:>4} {:>10.3} {:>12.4} {:>10.0}",
            rep.final_val_err, rep.comm_total, rep.throughput
        );
    }
    Ok(())
}
