//! End-to-end validation driver (DESIGN.md §5): train a ~10.5M-parameter
//! transformer LM for a few hundred BSP steps across simulated workers.
//!
//! ```bash
//! cargo run --release --offline --example e2e_train_transformer \
//!     [-- --workers 4 --iters 200 --strategy asa]
//! ```
//!
//! Proves all layers compose on a real workload:
//!   L1 — the Pallas tiled matmul runs inside every dense projection of the
//!        forward AND backward pass (custom VJP), plus the ASA sum and the
//!        fp16 cast kernels inside the exchange;
//!   L2 — the jax transformer train step, AOT-lowered to HLO text;
//!   L3 — the rust BSP engine: ranked workers, barriers, ASA exchange over
//!        the mosaic fabric, virtual-time accounting.
//!
//! The corpus is a Markov chain with 4 successors per state, so the optimal
//! next-token loss is ln(4) ≈ 1.386: the loss curve dropping from ~ln(2048)
//! ≈ 7.6 toward that floor is the correctness signal. The curve lands in
//! runs/e2e_loss.csv and is recorded in EXPERIMENTS.md.

use std::sync::Arc;

use theano_mpi::bsp::{run_bsp, BspConfig};
use theano_mpi::collectives::StrategyKind;
use theano_mpi::runtime::Runtime;
use theano_mpi::sgd::{LrSchedule, Scheme};
use theano_mpi::Session;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let workers = get("--workers", 4);
    let iters = get("--iters", 200);
    let strategy = args
        .iter()
        .position(|a| a == "--strategy")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| StrategyKind::parse(s))
        .unwrap_or(StrategyKind::Asa);

    let sess = Session::new(
        std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "runs",
    )?;
    let rt: &Arc<Runtime> = &sess.rt;
    let n_params = rt.manifest.models["transformer"].param_count;

    let mut cfg = BspConfig::quick("transformer", workers, iters);
    cfg.scheme = Scheme::Subgd;
    cfg.plan.strategy = strategy;
    cfg.lr = LrSchedule::StepDecay { base: 3e-3, factor: 0.5, every: iters / 2 };
    cfg.momentum = 0.9;
    cfg.eval_every = (iters / 20).max(5);
    cfg.seed = 1;

    println!(
        "== e2e: transformer LM ({:.1}M params) x{workers} workers, {iters} BSP steps, {} exchange ==",
        n_params as f64 / 1e6,
        strategy.name()
    );
    println!("optimal loss floor = ln(4) = 1.386 (Markov corpus)");
    let t0 = std::time::Instant::now();
    let rep = run_bsp(rt, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\niter  vtime(s)  train_loss  token_err");
    for p in &rep.curve {
        println!("{:>4}  {:>8.2}  {:>10.4}  {:>9.3}", p.iter, p.vtime, p.train_loss, p.val_err);
    }
    let rows: Vec<String> = rep
        .curve
        .iter()
        .map(|p| format!("{},{:.4},{:.6},{:.4}", p.iter, p.vtime, p.train_loss, p.val_err))
        .collect();
    let path = sess.write_csv("e2e_loss.csv", "iter,vtime_s,train_loss,token_err", &rows)?;

    println!(
        "\nwall {wall:.0}s | virtual {:.1}s | throughput {:.1} seq/s (virtual)",
        rep.vtime_total, rep.throughput
    );
    println!(
        "breakdown: compute {:.1}s | comm {:.2}s (kernel {:.1}%) | apply {:.1}s | {} wire bytes/exchange",
        rep.breakdown.compute,
        rep.breakdown.comm(),
        rep.breakdown.kernel_share_of_comm() * 100.0,
        rep.breakdown.apply,
        rep.comm.wire_bytes / rep.iters.max(1) as u64,
    );
    println!("loss curve -> {path:?}");

    let first = rep.curve.first().map(|p| p.train_loss).unwrap_or(f64::NAN);
    let last = rep.final_train_loss;
    let first_err = rep.curve.first().map(|p| p.val_err).unwrap_or(f64::NAN);
    // success = clear learning signal: loss down >= 0.5 nats from ~ln(vocab)
    // and token error off its random-chance start (the full descent to the
    // ln(4) floor takes a few thousand steps; the recorded 150-step run
    // drops 7.69 -> 6.84 with token error 0.999 -> 0.871 — EXPERIMENTS.md)
    anyhow::ensure!(
        last < first - 0.5 && rep.final_val_err < first_err - 0.05,
        "no learning signal: loss {first:.3} -> {last:.3}, err {first_err:.3} -> {:.3}",
        rep.final_val_err
    );
    println!("e2e OK: loss {first:.3} -> {last:.3}, token err {first_err:.3} -> {:.3}",
        rep.final_val_err);
    Ok(())
}
