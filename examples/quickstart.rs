//! Quickstart: train a small MLP data-parallel on 2 simulated GPUs.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```
//!
//! Walks the whole stack: loads the AOT artifacts (L2 jax model + L1 Pallas
//! kernels), spins up a 2-worker BSP world on the mosaic topology, trains
//! with SUBGD + the ASA exchange, and prints the loss curve and the
//! train/comm breakdown.

use std::sync::Arc;

use theano_mpi::bsp::{run_bsp, BspConfig};
use theano_mpi::collectives::StrategyKind;
use theano_mpi::runtime::Runtime;
use theano_mpi::sgd::{LrSchedule, Scheme};

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);

    let mut cfg = BspConfig::quick("mlp", 2, 60);
    cfg.scheme = Scheme::Subgd;
    cfg.plan.strategy = StrategyKind::Asa;
    cfg.lr = LrSchedule::Const { base: 0.05 };
    cfg.eval_every = 10;

    println!("== theano-mpi-rs quickstart: MLP x2 workers, SUBGD + ASA ==");
    let rep = run_bsp(&rt, &cfg)?;

    println!("\niter  vtime(s)  train_loss  val_err");
    for p in &rep.curve {
        println!("{:>4}  {:>8.3}  {:>10.4}  {:>7.3}", p.iter, p.vtime, p.train_loss, p.val_err);
    }
    println!(
        "\nthroughput: {:.0} examples/s (virtual)  compute {:.2}s | comm {:.3}s | apply {:.2}s",
        rep.throughput,
        rep.breakdown.compute,
        rep.breakdown.comm(),
        rep.breakdown.apply,
    );
    assert!(rep.final_train_loss < 1.0, "training failed to converge");
    println!("quickstart OK");
    Ok(())
}
