//! Parallel loading demo — Algorithm 1 (§3.3): overlap disk + preprocess +
//! H2D with training.
//!
//! ```bash
//! cargo run --release --offline --example parallel_loading
//! ```
//!
//! Trains the AlexNet proxy twice on the same on-disk synthetic shard: once
//! loading synchronously in the worker (`direct`), once with the spawned
//! loader child double-buffering ahead (`parallel`), and reports how much
//! of the load time the overlap hides.

use std::sync::Arc;

use theano_mpi::bsp::{run_bsp, BspConfig};
use theano_mpi::runtime::Runtime;
use theano_mpi::sgd::LrSchedule;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);

    let mut results = Vec::new();
    for parallel in [false, true] {
        let mut cfg = BspConfig::quick("alexnet", 2, 24);
        cfg.batch = 32;
        cfg.use_loader = parallel;
        cfg.lr = LrSchedule::Const { base: 0.01 };
        cfg.seed = 7;
        let rep = run_bsp(&rt, &cfg)?;
        let mode = if parallel { "parallel (Alg. 1)" } else { "direct" };
        println!(
            "{mode:<18} vtime {:>7.2}s  compute {:>6.2}s  load-stall {:>6.3}s  throughput {:>6.1} ex/s",
            rep.vtime_total,
            rep.breakdown.compute,
            rep.breakdown.load_stall,
            rep.throughput
        );
        results.push(rep);
    }
    let direct = results[0].breakdown.load_stall;
    let par = results[1].breakdown.load_stall;
    let hidden = (1.0 - par / direct.max(1e-12)) * 100.0;
    println!("\n=> the loader child hides {hidden:.0}% of data-loading time behind fwd/bwd");
    assert!(par <= direct, "parallel loading should not stall more than direct");
    Ok(())
}
