//! Compare parameter-exchange strategies at full model scale (Fig. 3 / §3.2).
//!
//! ```bash
//! cargo run --release --offline --example comm_strategies [-- <model> <workers>]
//! ```
//!
//! Exchanges buffers sized to the *true* Table 2 parameter counts of
//! AlexNet / GoogLeNet / VGGNet over the paper's topologies and prints the
//! per-iteration communication cost of MPI_Allreduce (AR), CUDA-aware
//! Alltoall-sum-Allgather (ASA), its fp16 variant (ASA16), and the ring
//! allreduce ablation.

use theano_mpi::collectives::StrategyKind;
use theano_mpi::models;
use theano_mpi::Session;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("alexnet");
    let workers: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);

    let sess = Session::new(
        std::env::var("TMPI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "runs",
    )?;
    let bytes = models::full_scale_bytes(&sess.rt.manifest, model)?;
    let topo = models::paper_topology(model);
    println!(
        "== exchange of {model} ({:.1} MB) across {workers} workers on {topo} ==",
        bytes as f64 / 1e6
    );
    println!("{:<8} {:>12} {:>12} {:>10} {:>10}", "strategy", "transfer(s)", "kernel(s)", "total(s)", "kernel%");
    let mut base = None;
    for strat in [StrategyKind::Ar, StrategyKind::Asa, StrategyKind::Asa16, StrategyKind::Ring] {
        let rep = sess.measure_exchange(strat, workers, topo, bytes, true)?;
        let total = rep.sim_total();
        base.get_or_insert(total);
        println!(
            "{:<8} {:>12.4} {:>12.4} {:>10.4} {:>9.1}%   ({:.2}x vs AR)",
            strat.name(),
            rep.sim_transfer,
            rep.sim_kernel,
            total,
            rep.kernel_share() * 100.0,
            base.unwrap() / total,
        );
    }

    // CUDA-awareness ablation (paper §3.2: the point of GPUDirect P2P)
    println!("\n-- ASA with vs without CUDA-aware transfers (copper, 8 GPUs) --");
    for aware in [true, false] {
        let rep = sess.measure_exchange(StrategyKind::Asa, 8, "copper", bytes, aware)?;
        println!("cuda_aware={aware:<5}  total {:.4}s", rep.sim_total());
    }
    Ok(())
}
