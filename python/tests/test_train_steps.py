"""L2 training semantics: loss decreases, scheme equivalences (paper §4).

The key property the paper proves in [19] and relies on throughout: with a
constant effective batch, SUBGD (sum updates before GD) equals sequential SGD
on the concatenated batch, and AWAGD with LR scaled by k is equivalent to
SUBGD. We assert both numerically for the MLP.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as modellib
from compile.flatparams import ParamSpec
from compile.models import mlp

CFG = mlp.config()
SPEC = ParamSpec(mlp.param_shapes(CFG))


def _data(bs, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((bs, CFG["in_dim"])).astype(np.float32))
    y = jnp.asarray(rng.integers(0, CFG["classes"], bs).astype(np.int32))
    return x, y


def _init():
    fp = SPEC.flatten([jnp.asarray(p) for p in mlp.init_params(CFG, seed=0)])
    return fp, jnp.zeros_like(fp)


def test_train_step_decreases_loss():
    fp, fm = _init()
    x, y = _data(64)
    step = jax.jit(modellib.make_train_step(mlp, CFG, SPEC))
    losses = []
    for _ in range(10):
        fp, fm, loss = step(fp, fm, x, y, jnp.float32(0.05), jnp.float32(0.9))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_grad_step_plus_apply_equals_train_step():
    """grad_step + momentum update == train_step (k=1 SUBGD == local step)."""
    fp, fm = _init()
    x, y = _data(32, seed=1)
    train = jax.jit(modellib.make_train_step(mlp, CFG, SPEC))
    grad = jax.jit(modellib.make_grad_step(mlp, CFG, SPEC))

    lr, mu = jnp.float32(0.01), jnp.float32(0.9)
    fp1, fm1, loss1 = train(fp, fm, x, y, lr, mu)
    g, loss2 = grad(fp, x, y)
    v = mu * fm - lr * g
    fp2, fm2 = fp + v, v
    np.testing.assert_allclose(loss1, loss2, rtol=1e-6)
    np.testing.assert_allclose(fp1, fp2, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(fm1, fm2, rtol=1e-5, atol=1e-7)


def test_subgd_equals_sequential_sgd_constant_effective_batch():
    """Sum of k workers' grads on batch shards == grad on the full batch
    (cross-entropy means: average of shard means = full mean when shards are
    equal size), so SUBGD reproduces sequential SGD exactly — paper §4."""
    fp, _ = _init()
    k = 4
    x, y = _data(64, seed=2)
    grad = jax.jit(modellib.make_grad_step(mlp, CFG, SPEC))

    g_full, _ = grad(fp, x, y)
    shards = [(x[i::k], y[i::k]) for i in range(k)]
    g_avg = sum(grad(fp, xs, ys)[0] for xs, ys in shards) / k
    np.testing.assert_allclose(g_full, g_avg, rtol=2e-4, atol=1e-6)


def test_awagd_lr_scaling_equivalence():
    """AWAGD at lr*k after averaging weights == SUBGD at lr with summed
    updates, when workers start from identical params (paper §4, [15])."""
    fp, fm = _init()
    k = 2
    x, y = _data(64, seed=3)
    shards = [(x[i::k], y[i::k]) for i in range(k)]
    lr, mu = 0.01, 0.9

    # AWAGD: each worker steps at lr (per-worker), then average weights+mom.
    # Summed-update form: w' = w + mean_i(v_i) with v_i = mu*v - lr*g_i.
    train = jax.jit(modellib.make_train_step(mlp, CFG, SPEC))
    outs = [train(fp, fm, xs, ys, jnp.float32(lr * k), jnp.float32(mu)) for xs, ys in shards]
    w_awagd = sum(o[0] for o in outs) / k

    grad = jax.jit(modellib.make_grad_step(mlp, CFG, SPEC))
    g_sum = sum(grad(fp, xs, ys)[0] for xs, ys in shards)
    v = mu * fm - lr * g_sum
    w_subgd = fp + v
    np.testing.assert_allclose(w_awagd, w_subgd, rtol=1e-4, atol=1e-6)


def test_eval_step_counts_correct():
    fp, _ = _init()
    ev = jax.jit(modellib.make_eval_step(mlp, CFG, SPEC))
    x, y = _data(CFG["eval_batch"], seed=4)
    loss, correct = ev(fp, x, y)
    assert 0 <= int(correct) <= CFG["eval_batch"]
    assert np.isfinite(float(loss))
