"""Minimal offline stand-in for the `hypothesis` API test_kernels.py uses.

The container has no `hypothesis` wheel and no network. This shim keeps the
property tests runnable: `@given(...)` draws `max_examples` pseudo-random
examples from the declared strategies with a per-test deterministic seed
(plus the min/max boundary example first, which is where block-alignment
bugs live). When the real `hypothesis` is installed, test_kernels.py
imports it instead and this module is unused.

Supported surface: `given`, `settings.register_profile/load_profile`,
`strategies.integers/floats/sampled_from`.
"""

import functools
import inspect
import random
import zlib


class _Profile:
    def __init__(self, max_examples=10, deadline=None):
        self.max_examples = max_examples
        self.deadline = deadline


class settings:  # noqa: N801 - mirrors hypothesis' lowercase class name
    _profiles = {}
    _current = _Profile()

    def __init__(self, max_examples=10, deadline=None):
        self.max_examples = max_examples
        self.deadline = deadline

    @classmethod
    def register_profile(cls, name, max_examples=10, deadline=None):
        cls._profiles[name] = _Profile(max_examples, deadline)

    @classmethod
    def load_profile(cls, name):
        cls._current = cls._profiles.get(name, _Profile())


class _Strategy:
    """A strategy is a draw function plus optional boundary examples."""

    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = tuple(boundaries)

    def draw(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: rng.randint(min_value, max_value),
            boundaries=(min_value, max_value),
        )

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: rng.uniform(min_value, max_value),
            boundaries=(min_value, max_value),
        )

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq), boundaries=(seq[0], seq[-1]))


st = strategies


def given(**param_strategies):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper():
            n = settings._current.max_examples
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            names = list(param_strategies)
            for case in range(n):
                drawn = {}
                for name in names:
                    strat = param_strategies[name]
                    # case 0: all minima; case 1: all maxima; then random
                    if case < 2 and strat.boundaries:
                        drawn[name] = strat.boundaries[min(case, len(strat.boundaries) - 1)]
                    else:
                        drawn[name] = strat.draw(rng)
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {case}: {drawn!r}: {e}"
                    ) from e

        # pytest must see a zero-argument test, not the wrapped params
        # (functools.wraps sets __wrapped__, which inspect.signature follows)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorator
