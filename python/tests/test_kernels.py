"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-block-aligned and degenerate sizes)
and values; assert_allclose at tight tolerances. These are the core
correctness signal for everything the rust hot path executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: deterministic in-repo fallback
    from _hypothesis_compat import given, settings, st

from compile.kernels import fp16, matmul, ref, sgd, sumreduce

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    got = matmul.matmul(x, w)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (256, 512, 256, 128, 128, 128),  # exactly block-aligned
        (257, 513, 259, 128, 128, 128),  # one past alignment
        (8, 128, 128, 256, 256, 512),    # smaller than one block
        (300, 100, 40, 64, 128, 512),
    ],
)
def test_matmul_block_shapes(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(0)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    got = matmul.matmul(x, w, bm, bn, bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(2, 24),
    k=st.integers(2, 24),
    n=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_vjp_matches_ref_grads(m, k, n, seed):
    """The custom VJP (same Pallas kernel, transposed) must match jnp grads."""
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)

    def f_pallas(x, w):
        return jnp.sum(jnp.tanh(matmul.matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(ref.matmul_ref(x, w)))

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-5)


@given(
    k=st.integers(1, 9),
    n=st.integers(1, 200_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_sum_stack_matches_ref(k, n, seed):
    rng = np.random.default_rng(seed)
    s = _arr(rng, k, n)
    np.testing.assert_allclose(
        sumreduce.sum_stack(s), ref.sumreduce_ref(s), rtol=1e-5, atol=1e-5
    )


def test_sum_stack_block_boundary_exact():
    # padding region must contribute exactly zero
    for n in (65535, 65536, 65537, 1, 127, 128, 129):
        s = jnp.ones((4, n), jnp.float32)
        np.testing.assert_array_equal(sumreduce.sum_stack(s), 4.0 * jnp.ones(n))


@given(
    n=st.integers(1, 200_000),
    seed=st.integers(0, 2**31 - 1),
    wire=st.sampled_from(["f16", "bf16"]),
)
def test_fp16_pack_unpack_roundtrip(n, seed, wire):
    rng = np.random.default_rng(seed)
    x = _arr(rng, n)
    bits = fp16.fp16_pack(x, wire=wire)
    np.testing.assert_array_equal(bits, ref.fp16_pack_ref(x, wire))
    back = fp16.fp16_unpack(bits, wire=wire)
    np.testing.assert_array_equal(back, ref.fp16_unpack_ref(bits, wire))
    # round-trip error bounded by half-precision ulp of the magnitude
    tol = 1e-2 if wire == "bf16" else 1e-3
    np.testing.assert_allclose(back, x, rtol=tol, atol=tol)


def test_fp16_special_values():
    x = jnp.asarray([0.0, -0.0, 1.0, -1.0, 65504.0, 1e-8, 123.456], jnp.float32)
    bits = fp16.fp16_pack(x)
    np.testing.assert_array_equal(bits, ref.fp16_pack_ref(x))
    back = fp16.fp16_unpack(bits)
    assert float(back[0]) == 0.0 and float(back[2]) == 1.0
    assert float(back[4]) == 65504.0  # f16 max maps exactly


@given(
    n=st.integers(1, 300_000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    scale=st.floats(0.01, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_update_matches_ref(n, lr, mu, scale, seed):
    rng = np.random.default_rng(seed)
    w, v, g = _arr(rng, n), _arr(rng, n), _arr(rng, n)
    w2, v2 = sgd.sgd_update(w, v, g, lr, mu, scale)
    rw, rv = ref.sgd_update_ref(w, v, g, np.float32(lr), np.float32(mu), np.float32(scale))
    np.testing.assert_allclose(w2, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v2, rv, rtol=1e-5, atol=1e-6)


def test_vmem_footprints_within_budget():
    """DESIGN §Perf: one grid step must fit a 16 MB VMEM budget."""
    assert matmul.vmem_footprint_bytes(256, 256, 512) <= 16 << 20
    assert sumreduce.vmem_footprint_bytes(8, 65536) <= 16 << 20
